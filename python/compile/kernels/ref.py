"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact pure-`jax.numpy`
counterpart here. pytest (and hypothesis sweeps) assert `assert_allclose`
between kernel and oracle across shapes/dtypes — this is the core
correctness signal for Layer 1.

Conventions (shared with attention.py / model.py):
  * attention tensors are laid out `(batch, heads, seq, head_dim)`;
  * prompts are right-padded to the compiled sequence length; a per-batch
    `lens` vector marks the true prompt length. Causal masking makes pad
    *keys* unreachable from real queries, and pad-query outputs are
    discarded by the caller (see DESIGN.md for the cache-slot argument);
  * decode reads cache slots `j <= pos` (inclusive: slot `pos` holds the
    KV of the token being decoded).
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_prefill(q, k, v, *, sm_scale=None):
    """Causal multi-head attention over a full (padded) prompt.

    Args:
      q, k, v: f32[batch, heads, seq, head_dim]
      sm_scale: softmax scale; defaults to 1/sqrt(head_dim).

    Returns:
      f32[batch, heads, seq, head_dim]
    """
    b, h, s, d = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * sm_scale
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    logits = jnp.where(kj <= qi, logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)


def attention_decode(q, k_cache, v_cache, pos, *, sm_scale=None):
    """Single-step decode attention against a KV cache.

    Args:
      q: f32[batch, heads, head_dim] — query for the token at slot `pos`.
      k_cache, v_cache: f32[batch, heads, max_seq, head_dim].
      pos: i32[batch] — slot of the current token; slots `<= pos` are live.

    Returns:
      f32[batch, heads, head_dim]
    """
    b, h, s, d = k_cache.shape
    if sm_scale is None:
        sm_scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bhd,bhkd->bhk", q, k_cache).astype(jnp.float32) * sm_scale
    live = jnp.arange(s)[None, None, :] <= pos[:, None, None]
    logits = jnp.where(live, logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhk,bhkd->bhd", probs, v_cache.astype(jnp.float32)).astype(q.dtype)


def swiglu_ffn(x, w_gate, w_up, w_down):
    """SwiGLU feed-forward: (silu(x @ w_gate) * (x @ w_up)) @ w_down.

    Args:
      x: f32[rows, d_model]
      w_gate, w_up: f32[d_model, d_ff]
      w_down: f32[d_ff, d_model]
    """
    gate = x @ w_gate
    up = x @ w_up
    act = gate * jnp.reciprocal(1.0 + jnp.exp(-gate)) * up  # silu(gate) * up
    return act @ w_down


def rmsnorm(x, weight, eps=1e-5):
    """RMSNorm over the last axis (L2 building block, used by model.py)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jnp.reciprocal(jnp.sqrt(var + eps)) * weight).astype(x.dtype)
