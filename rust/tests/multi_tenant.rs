//! Multi-tenant workload integration tests (ISSUE-8 acceptance
//! criteria, DESIGN.md §15).
//!
//! * **Golden inertness**: the shipped configs declare no `[tenant.*]`
//!   or `[admission]` tables, so tenancy must stay fully inert — no
//!   per-tier summary, no shed records, untagged requests — and runs
//!   stay deterministic to the bit on `rapid-600.toml`.
//! * **`scenarios/flash-crowd-curtail.toml`**: the shipped trace-replay
//!   study runs end to end, conserves every request (shed arrivals are
//!   accounted as SLO-violation records, never dropped), keeps
//!   interactive attainment >= batch once prioritization fires, and
//!   the study-level check holds rapid >= static goodput under the
//!   curtailment window.
//! * **Admission shedding**: a queue-depth policy under overload sheds
//!   work lowest-tier-first while the record count still matches the
//!   trace length exactly.
//! * **Decode preemption**: with tenant classes and saturated decode
//!   batches, higher-tier requests displace batch-tier decodes;
//!   preempted work still completes (conservation) because its
//!   `tokens_done` progress is preserved across the swap.

use rapid::config::ClusterConfig;
use rapid::scenario::{Scenario, Study};
use rapid::sim::{self, SimOptions};
use rapid::types::Slo;
use rapid::workload::tracespec::{
    assign_tenants, TraceSpec, TIER_BATCH, TIER_INTERACTIVE,
};

#[path = "support/mod.rs"]
mod support;
use support::{assert_bit_identical, shipped_config};

/// A tenant-tagged trace at `qps_per_gpu` x 8 GPUs: half interactive,
/// 30% standard, 20% batch at a relaxed SLO.
fn tenant_config_and_trace(
    extra: &str,
    qps_per_gpu: f64,
    n: usize,
) -> (ClusterConfig, rapid::workload::Trace) {
    let toml = format!(
        "preset = \"rapid-600\"\n\
         [tenant.chat]\nshare = 0.5\ntier = \"interactive\"\n\
         [tenant.api]\nshare = 0.3\ntier = \"standard\"\n\
         [tenant.jobs]\nshare = 0.2\ntier = \"batch\"\nslo_scale = 4.0\n\
         {extra}"
    );
    let cfg = ClusterConfig::from_toml(&toml).expect("tenant config parses");
    let spec = TraceSpec::preset("mt-4400x1200").unwrap();
    let qps = qps_per_gpu * cfg.n_gpus as f64;
    let mut trace = spec.build(7, qps, n, Slo::paper_default());
    assign_tenants(&mut trace, &cfg.tenants, 7);
    (cfg, trace)
}

#[test]
fn untenanted_shipped_config_stays_inert_and_deterministic() {
    let cfg = shipped_config("rapid-600.toml");
    assert!(cfg.tenants.is_empty(), "shipped configs declare no tenants");
    let spec = TraceSpec::preset("synth-8192x256").unwrap();
    let trace = spec.build(3, 10.0, 150, Slo::paper_default());
    let a = sim::run(&cfg, &trace, &SimOptions::default());
    let b = sim::run(&cfg, &trace, &SimOptions::default());
    assert_bit_identical(&a, &b);
    // No tenancy artifacts anywhere: untagged records, no shed, no
    // per-tier summary, no preemptions.
    assert!(a.records.iter().all(|r| r.tenant == 0 && !r.shed));
    assert!(a.summary().tenants.is_none());
    assert_eq!(a.preempted_by_tier, [0, 0, 0]);
    assert!(a.tenant_tiers.is_empty());
}

#[test]
fn flash_crowd_curtail_scenario_end_to_end() {
    let path = format!(
        "{}/scenarios/flash-crowd-curtail.toml",
        env!("CARGO_MANIFEST_DIR")
    );
    let scenario = Scenario::from_toml_file(&path).expect("shipped scenario parses");
    assert!(scenario.trace.is_some());
    assert_eq!(scenario.base.tenants.len(), 3);
    let study = Study::new(scenario).run(Some(2)).expect("study runs");
    assert_eq!(study.cells.len(), 2, "static and rapid cells");
    for cell in &study.cells {
        let res = cell.result().expect("sim cell");
        // Zero requests lost: shed arrivals become records too.
        assert_eq!(res.records.len(), study.scenario.requests);
        let tiers = cell.tenants().expect("per-tier summary");
        let total: u64 = tiers.iter().map(|t| t.requests).sum();
        assert_eq!(total as usize, study.scenario.requests);
        assert!(
            cell.checks.iter().all(|c| c.pass),
            "cell {:?} checks: {:?}",
            cell.coords,
            cell.checks
        );
        // The tier contract, asserted directly as well as via the
        // ShapeCheck: once shedding/preemption fired, interactive
        // must attain at least what batch attains.
        let shed: u64 = tiers.iter().map(|t| t.shed).sum();
        let preempted: u64 = tiers.iter().map(|t| t.preempted).sum();
        if shed + preempted > 0 {
            assert!(
                tiers[TIER_INTERACTIVE as usize].attainment + 1e-9
                    >= tiers[TIER_BATCH as usize].attainment,
                "interactive {:?} vs batch {:?}",
                tiers[TIER_INTERACTIVE as usize],
                tiers[TIER_BATCH as usize]
            );
        }
    }
    // Study-level tentpole claim: rapid >= static goodput under the
    // pure-curtailment profile.
    let study_checks = study.study_checks();
    assert!(
        study_checks.iter().any(|c| c.what.contains("static")),
        "{study_checks:?}"
    );
    assert!(
        study_checks.iter().all(|c| c.pass),
        "{study_checks:?}"
    );
}

#[test]
fn queue_depth_admission_sheds_lowest_tier_first() {
    let (cfg, trace) =
        tenant_config_and_trace("[admission]\nmode = \"queue-depth\"\nqueue_depth = 4\n", 6.0, 400);
    let res = sim::run(&cfg, &trace, &SimOptions::default());
    // Conservation: every arrival is a record, shed or finished.
    assert_eq!(res.records.len(), trace.len());
    let tiers = res.summary().tenants.expect("per-tier summary");
    let shed: u64 = tiers.iter().map(|t| t.shed).sum();
    assert!(shed > 0, "overload at 6 qps/GPU with depth 4 must shed");
    assert_eq!(
        res.records.iter().filter(|r| r.shed).count() as u64,
        shed,
        "summary shed matches the flagged records"
    );
    // Lowest tier first: batch sheds at least the interactive rate
    // (queue-depth thresholds are 4x apart), and the attainment order
    // follows.
    let b = &tiers[TIER_BATCH as usize];
    let i = &tiers[TIER_INTERACTIVE as usize];
    assert!(b.requests > 0 && i.requests > 0);
    assert!(
        b.shed as f64 / b.requests as f64 >= i.shed as f64 / i.requests as f64,
        "batch shed rate {}/{} vs interactive {}/{}",
        b.shed,
        b.requests,
        i.shed,
        i.requests
    );
    assert!(i.attainment + 1e-9 >= b.attainment);
}

#[test]
fn decode_preemption_promotes_interactive_over_batch() {
    // No admission table: overload pressure lands entirely on the
    // decode batches, so the preemption path (not shedding) is what
    // prioritizes the interactive tier here.
    let (cfg, trace) = tenant_config_and_trace("", 8.0, 300);
    let res = sim::run(&cfg, &trace, &SimOptions::default());
    assert_eq!(res.records.len(), trace.len(), "preemption never loses work");
    assert!(res.records.iter().all(|r| !r.shed));
    let preempted: u64 = res.preempted_by_tier.iter().sum();
    assert!(
        preempted > 0,
        "saturated decode batches with mixed tiers must preempt"
    );
    // Only lower tiers are ever victims: an interactive decode cannot
    // be displaced (the swap requires promote_tier < victim_tier).
    assert_eq!(res.preempted_by_tier[TIER_INTERACTIVE as usize], 0);
    let tiers = res.summary().tenants.expect("per-tier summary");
    assert!(
        tiers[TIER_INTERACTIVE as usize].attainment + 1e-9
            >= tiers[TIER_BATCH as usize].attainment
    );
}
