//! KV memory subsystem integration tests (ISSUE-7 acceptance criteria,
//! DESIGN.md §14).
//!
//! * **Golden inertness**: the shipped configs declare no `[mem]` table,
//!   so the subsystem must stay fully inert — no memory summary, no
//!   occupancy trace — and runs stay deterministic to the bit on
//!   `rapid-600.toml`, `two-node-4p4d.toml` and `hetero-4p4d.toml`.
//! * **`scenarios/mem-pressure.toml`**: every capped cell keeps resident
//!   KV within HBM capacity at every occupancy sample (the per-cell
//!   ShapeCheck) while conserving every request under admission
//!   backpressure.
//! * **`scenarios/multi-turn.toml`**: the prefix cache actually hits,
//!   and the cache-enabled cell's mean TTFT is no worse than the
//!   cache-off cell running the byte-identical trace (the study-level
//!   ShapeCheck).
//! * **Recover-after-fail re-admission**: a GPU failure under a tight
//!   capacity budget invalidates that GPU's blocks and reservations,
//!   re-admits its in-flight work elsewhere, and the fleet converges
//!   back — losing zero requests, deterministically.
//! * **Ring backpressure regression**: with `batch.ring_slots` squeezed
//!   to near nothing, failure-driven redispatch must defer through the
//!   retransfer FIFO instead of over-committing the ring (the pre-fix
//!   over-commit trips a live `debug_assert` in these builds).

use rapid::env::EnvProfile;
use rapid::mem::MemConfig;
use rapid::scenario::{Scenario, Study};
use rapid::sim::{self, SimOptions};
use rapid::types::Slo;
use rapid::util::rng::Rng;
use rapid::workload::{build_trace, sonnet::Sonnet, ArrivalProcess};

#[path = "support/mod.rs"]
mod support;
use support::{assert_bit_identical, shipped_config};

fn trace(n: usize, qps: f64, input: u32, output: u32) -> rapid::workload::Trace {
    let mut ap = ArrivalProcess::poisson(Rng::new(91), qps);
    let mut sizes = Sonnet::new(Rng::new(92), input, output);
    build_trace(n, &mut ap, &mut sizes, Slo::paper_default())
}

/// Mean time-to-first-token (us) across a cell's records.
fn mean_ttft(res: &rapid::metrics::RunResult) -> f64 {
    let sum: f64 = res.records.iter().map(|r| r.ttft() as f64).sum();
    sum / res.records.len() as f64
}

#[test]
fn no_mem_table_stays_inert_on_shipped_configs() {
    for (file, n, qps, input, output) in [
        ("rapid-600.toml", 200, 16.0, 3000, 32),
        ("two-node-4p4d.toml", 200, 20.0, 2048, 64),
        ("hetero-4p4d.toml", 200, 14.0, 3000, 32),
    ] {
        let cfg = shipped_config(file);
        assert!(cfg.mem.is_none(), "{file} must not declare a [mem] table");
        let t = trace(n, qps, input, output);
        let a = sim::run(&cfg, &t, &SimOptions::default());
        // Inert: no summary, no occupancy samples, ever.
        assert!(a.mem.is_none(), "{file}: no [mem] table must mean no memory summary");
        assert!(a.mem_trace.is_empty(), "{file}: no [mem] table must mean no occupancy trace");
        // And deterministic to the bit (the golden comparator now also
        // covers the mem fields).
        let b = sim::run(&cfg, &t, &SimOptions::default());
        assert_bit_identical(&a, &b);
    }
}

#[test]
fn mem_pressure_scenario_keeps_resident_kv_within_capacity() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/mem-pressure.toml");
    let mut scenario = Scenario::from_toml_file(path).expect("shipped scenario loads");
    scenario.requests = 150; // keep the test quick; CI smoke runs it too
    let study = Study::new(scenario).run(Some(2)).expect("study runs");
    assert_eq!(study.cells.len(), 8, "4 mem cells x 2 rates");
    let (passed, total) = study.checks_passed();
    assert_eq!(passed, total, "per-cell invariants (incl. HBM capacity) hold");
    let mut capped = 0;
    for cell in &study.cells {
        let res = cell.result().expect("cell ran");
        // Admission backpressure must never lose a request.
        assert_eq!(res.records.len(), 150, "{:?}", cell.coords);
        let is_capped = cell.coords.iter().any(|(k, v)| k == "mem" && v != "none");
        assert_eq!(res.mem.is_some(), is_capped, "{:?}", cell.coords);
        if let Some(mem) = res.mem {
            capped += 1;
            assert!(
                mem.peak_occupancy <= 1.0 + 1e-9,
                "{:?}: peak occupancy {}",
                cell.coords,
                mem.peak_occupancy
            );
            assert!(!res.mem_trace.is_empty(), "capped cells must trace occupancy");
            // Plain (single-turn) traffic never parks prefix blocks.
            assert_eq!(mem.prefix_lookups, 0, "{:?}", cell.coords);
        }
    }
    assert_eq!(capped, 6, "hbm:8/16/32 x 2 rates carry memory summaries");
    // The tightest pool actually fills: hbm:8 at the hot rate runs near
    // capacity (otherwise the scenario exercises nothing).
    let peak = study
        .cells
        .iter()
        .filter(|c| c.coords.iter().any(|(k, v)| k == "mem" && v == "hbm:8"))
        .filter_map(|c| c.result().and_then(|r| r.summary().mem))
        .map(|m| m.peak_occupancy)
        .fold(0.0f64, f64::max);
    assert!(peak > 0.25, "hbm:8 cells must see real pressure, peak {peak}");
}

#[test]
fn multi_turn_prefix_cache_hits_and_wins_ttft() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/multi-turn.toml");
    let mut scenario = Scenario::from_toml_file(path).expect("shipped scenario loads");
    scenario.requests = 200;
    let study = Study::new(scenario).run(Some(2)).expect("study runs");
    assert_eq!(study.cells.len(), 2, "cache-off and cache-on cells");
    let (passed, total) = study.checks_passed();
    assert_eq!(passed, total, "per-cell invariants hold");
    let off = study.cells[0].result().expect("cache-off cell ran");
    let on = study.cells[1].result().expect("cache-on cell ran");
    assert!(off.mem.is_none(), "multiturn-only atom must not activate the subsystem");
    let mem = on.mem.expect("hbm atom activates the subsystem");
    assert!(mem.prefix_lookups > 0, "later turns must look up the cache");
    assert!(mem.prefix_hits > 0, "the prefix cache must actually hit");
    assert!(mem.hit_rate > 0.0 && mem.hit_rate <= 1.0, "hit rate {}", mem.hit_rate);
    // Both cells run the byte-identical trace, so the cache win is a
    // direct apples-to-apples TTFT comparison...
    assert_eq!(off.records.len(), on.records.len());
    assert!(
        mean_ttft(on) <= mean_ttft(off) + 1e-9,
        "cached mean TTFT {:.1} us must not exceed uncached {:.1} us",
        mean_ttft(on),
        mean_ttft(off)
    );
    // ...and the study-level ShapeCheck says the same thing.
    let checks = study.study_checks();
    let cache: Vec<_> = checks.iter().filter(|c| c.what.contains("prefix cache")).collect();
    assert_eq!(cache.len(), 1, "one cache-on cell gets a TTFT comparison");
    assert!(cache[0].pass, "{}: {}", cache[0].what, cache[0].detail);
}

#[test]
fn gpu_failure_under_pressure_readmits_and_converges() {
    // Static 4P4D, tight 2 GB pools (~9 concurrent 1.5K-token contexts
    // per GPU), and a decode-GPU failure mid-run: the failure must
    // invalidate gpu5's reservations, re-admit its in-flight decodes on
    // the survivors' pools (waiting for headroom when full), and lose
    // nothing.
    let mut cfg = rapid::config::presets::p4d4(600.0);
    cfg.mem = Some(MemConfig {
        hbm_gb: Some(2.0),
        ..Default::default()
    });
    cfg.env = EnvProfile::parse_compact("fail:8:5+recover:20:5").unwrap();
    cfg.validate().unwrap();
    let n = 300;
    let t = trace(n, 8.0, 1500, 32);
    let r = sim::run(&cfg, &t, &SimOptions::default());
    assert_eq!(r.records.len(), n, "pressure + failure must lose zero requests");
    let unique: std::collections::HashSet<u64> = r.records.iter().map(|x| x.id.0).collect();
    assert_eq!(unique.len(), n, "no request recorded twice");
    for rec in &r.records {
        assert!(rec.arrival <= rec.prefill_start, "{rec:?}");
        assert!(rec.prefill_start <= rec.first_token && rec.first_token <= rec.finish);
    }
    let mem = r.mem.expect("[mem] table activates the subsystem");
    assert!(mem.peak_occupancy <= 1.0 + 1e-9, "capacity holds through the failure");
    // Fleet converges back after recovery, same as the env-only test.
    let &(_, p_end, d_end) = r.role_trace.last().unwrap();
    assert_eq!((p_end, d_end), (4, 4), "fleet converges back after recovery");
    // Deterministic under pressure + failure.
    let r2 = sim::run(&cfg, &t, &SimOptions::default());
    assert_bit_identical(&r, &r2);
}

#[test]
fn squeezed_ring_defers_redispatch_without_overcommit() {
    // Regression for the ring over-commit: redispatching a failed GPU's
    // decodes used to skip the slot check and publish past ring_slots.
    // With 2 slots, a hot prefill rate, a tight pool and a mid-run
    // failure, the redispatch path MUST defer through the retransfer
    // FIFO — the old over-commit trips the live debug_assert
    // (`ring_used <= ring_slots`) in this build. Conservation plus
    // bit-determinism pin the drain order.
    let mut cfg = rapid::config::presets::p4d4(600.0);
    cfg.batch.ring_slots = 2;
    cfg.mem = Some(MemConfig {
        hbm_gb: Some(2.0),
        ..Default::default()
    });
    cfg.env = EnvProfile::parse_compact("fail:6:5+recover:18:5").unwrap();
    cfg.validate().unwrap();
    let n = 300;
    let t = trace(n, 12.0, 3000, 32);
    let r = sim::run(&cfg, &t, &SimOptions::default());
    assert_eq!(r.records.len(), n, "a full ring must defer, never drop");
    let unique: std::collections::HashSet<u64> = r.records.iter().map(|x| x.id.0).collect();
    assert_eq!(unique.len(), n, "no request recorded twice");
    for rec in &r.records {
        assert!(rec.prefill_start <= rec.first_token && rec.first_token <= rec.finish);
    }
    let r2 = sim::run(&cfg, &t, &SimOptions::default());
    assert_bit_identical(&r, &r2);
}
