//! Environment disturbance handling on the cluster core (DESIGN.md §12).
//!
//! `Event::Env` entries from the expanded [`crate::env::EnvProfile`]
//! timeline land here. The split of responsibilities:
//!
//! * the **core** applies the mandatory safety work for every policy —
//!   budget steps shed committed power inside SKU floors immediately,
//!   failures requeue all queued/in-flight work (prefill re-runs, decode
//!   items re-fetch their KV over the ring) and re-spread the dead GPU's
//!   power uniformly (the same DISTRIBUTEUNIFORMPOWER a role move
//!   triggers), thermal derates clamp the GPU's ceiling;
//! * the **policy** is then consulted via `on_env_event` — a dynamic
//!   policy reclaims restored budget immediately
//!   (`EnvResponse::RedistributeUniform`), the static one by definition
//!   leaves its caps where the shed put them.
//!
//! Failure conservation invariant: no request is ever lost. Queued and
//! in-flight prefill work re-routes (the prompt must be recomputed —
//! its KV died with the GPU); decode items keep their generated-token
//! count and pay a fresh KV transfer to a surviving peer; work with no
//! surviving peer parks in the orphan pools and re-enters on the next
//! recovery (or is recorded as an SLO violation at the hard stop).

use crate::env::{CapScope, EnvDisturbance};
use crate::sim::event::Event;
use crate::sim::worker;
use crate::types::{GpuId, Role};
use crate::util::slab::SlotId;

use super::policy::EnvResponse;
use super::Cluster;

impl Cluster {
    /// Apply environment timeline entry `idx` at the current time.
    /// Guarded no-ops (failing a dead GPU, recovering a live one,
    /// clearing an underated ceiling) are dropped entirely: they enter
    /// neither `env_applied` (which defines the resilience window) nor
    /// the policy hook.
    pub(crate) fn on_env(&mut self, idx: usize) {
        let ev = self.env_timeline[idx];
        let now = self.now;
        let applied = match ev.what {
            EnvDisturbance::CapChange { scope: CapScope::Cluster, watts } => {
                self.power.set_cluster_budget(now, watts);
                self.budget_trace.push((now, watts));
                true
            }
            EnvDisturbance::CapChange { scope: CapScope::Node(nd), watts } => {
                self.power.set_node_budget(now, nd, watts);
                true
            }
            EnvDisturbance::GpuFail { gpu } => {
                let live = !self.gpus[gpu].failed;
                if live {
                    self.fail_gpu(gpu);
                }
                live
            }
            EnvDisturbance::GpuRecover { gpu } => {
                let down = self.gpus[gpu].failed;
                if down {
                    self.recover_gpu(gpu);
                }
                down
            }
            EnvDisturbance::ThermalThrottle { gpu, max_w } => {
                // Applies even to a failed GPU: the thermal envelope is
                // physical, so a recovery mid-throttle rejoins under the
                // derated ceiling.
                self.power.derate_gpu(now, GpuId(gpu), max_w);
                true
            }
            EnvDisturbance::ThermalClear { gpu } => {
                let derated =
                    self.power.max_of(GpuId(gpu)) < self.power.rated_max_of(GpuId(gpu));
                self.power.restore_gpu(now, GpuId(gpu));
                derated
            }
        };
        if !applied {
            return;
        }
        if self.obs.is_some() {
            let (kind, gpu): (&'static str, i64) = match ev.what {
                EnvDisturbance::CapChange { scope: CapScope::Cluster, .. } => ("cap-cluster", -1),
                EnvDisturbance::CapChange { scope: CapScope::Node(_), .. } => ("cap-node", -1),
                EnvDisturbance::GpuFail { gpu } => ("gpu-fail", gpu as i64),
                EnvDisturbance::GpuRecover { gpu } => ("gpu-recover", gpu as i64),
                EnvDisturbance::ThermalThrottle { gpu, .. } => ("thermal-throttle", gpu as i64),
                EnvDisturbance::ThermalClear { gpu } => ("thermal-clear", gpu as i64),
            };
            // Audited before the policy's own rebalance: `committed`
            // reflects exactly what the mandatory safety work left on
            // the books (the sum a `budget_trace` reconciliation sees).
            let committed = self.power.committed_total();
            if let Some(o) = self.obs.as_deref_mut() {
                o.record(crate::obs::ObsEvent::EnvApplied { at: now, kind, gpu });
                match ev.what {
                    EnvDisturbance::CapChange { scope: CapScope::Cluster, watts } => {
                        o.record(crate::obs::ObsEvent::BudgetChange {
                            at: now,
                            node: -1,
                            watts,
                            committed,
                        });
                    }
                    EnvDisturbance::CapChange { scope: CapScope::Node(nd), watts } => {
                        o.record(crate::obs::ObsEvent::BudgetChange {
                            at: now,
                            node: nd as i64,
                            watts,
                            committed,
                        });
                    }
                    _ => {}
                }
            }
        }
        // Let the policy rebalance immediately instead of waiting for
        // its next latency window / sampling tick.
        if self.policy.on_env_event(now, &ev) == EnvResponse::RedistributeUniform {
            let settle = self.power.distribute_uniform(now);
            self.events.push(settle, Event::PowerPoll);
        }
        if let Some(at) = self.power.next_pending_at() {
            self.events.push(at, Event::PowerPoll);
        }
        self.env_applied.push((now, ev.what.to_string()));
        self.cap_trace.push((now, self.power.targets()));
    }

    /// A GPU drops out of the fleet. Epoch-bumps it so in-flight
    /// completions go stale, requeues everything it held, takes it out
    /// of the power books, and re-spreads its watts.
    fn fail_gpu(&mut self, gi: usize) {
        let node = self.node_of(gi);
        let mut reqs: Vec<SlotId> = Vec::new();
        let mut items: Vec<SlotId> = Vec::new();
        {
            let g = &mut self.gpus[gi];
            g.failed = true;
            g.draining_to = None;
            g.epoch += 1;
            g.busy = false;
            // Prefill-side work: queued, batched mid-flight, and
            // published-but-unsent all lose their (local) KV — the
            // prompts must be recomputed elsewhere. (The re-route resets
            // each slot's progress fields; the slab entry survives.)
            reqs.extend(g.pf_queue.drain(..));
            g.pf_queued_tokens = 0;
            reqs.extend(g.pf_batch.drain(..));
            reqs.extend(g.publish_wait.drain(..));
            reqs.extend(g.co_queue.drain(..));
            g.co_tokens = 0;
            reqs.extend(g.co_finishing.drain(..));
            // Decode-side work keeps its progress: the KV re-fetches
            // over the ring to a surviving peer.
            items.extend(g.dec_pending.drain(..));
            items.extend(g.dec_active.drain(..));
        }
        // The dead GPU's HBM is gone: reservations, and every cached
        // prefix block in all tiers (they hang off its node agent).
        self.mem.invalidate_gpu(gi);
        // Out of the role lists and pick indexes before the requeue
        // loops below route anything.
        self.refresh_worker(gi);
        for s in reqs {
            if let Some(o) = self.obs.as_deref_mut() {
                let req = self.store.get(s).req.id.0;
                o.record(crate::obs::ObsEvent::Requeue {
                    at: self.now,
                    req,
                    gpu: gi,
                    why: "gpu-failed",
                });
            }
            self.route_request(s);
        }
        for s in items {
            if let Some(o) = self.obs.as_deref_mut() {
                let req = self.store.get(s).req.id.0;
                o.record(crate::obs::ObsEvent::Requeue {
                    at: self.now,
                    req,
                    gpu: gi,
                    why: "kv-refetch",
                });
            }
            self.redispatch_decode(gi, node, Some(gi), s);
        }
        self.power.set_offline(self.now, GpuId(gi), true);
        let settle = self.power.distribute_uniform(self.now);
        self.events.push(settle, Event::PowerPoll);
        self.record_roles();
    }

    /// A failed GPU rejoins: back on the power books at its floor, a
    /// uniform re-spread raises it, stranded orphans re-enter, and (for
    /// prefill) it steals half the deepest peer queue so convergence
    /// does not wait for new arrivals.
    fn recover_gpu(&mut self, gi: usize) {
        {
            let g = &mut self.gpus[gi];
            g.failed = false;
            g.epoch += 1;
            g.busy = false;
        }
        // Back into the role lists and pick indexes before orphans route.
        self.refresh_worker(gi);
        self.power.set_offline(self.now, GpuId(gi), false);
        let settle = self.power.distribute_uniform(self.now);
        self.events.push(settle, Event::PowerPoll);
        self.record_roles();
        let reqs = std::mem::take(&mut self.orphan_reqs);
        for s in reqs {
            self.route_request(s);
        }
        let node = self.node_of(gi);
        let items = std::mem::take(&mut self.orphan_items);
        for s in items {
            self.redispatch_decode(gi, node, None, s);
        }
        let role = self.gpus[gi].role;
        worker::behavior(role).kick(self, gi);
        if role == Role::Prefill {
            self.steal_prefill_work(gi);
        }
        // Publishers stalled while every decode worker was down retry
        // (publish_wait only ever lives on live prefill-role workers).
        let mut k = 0;
        while k < self.prefill_ids.len() {
            let i = self.prefill_ids[k];
            if !self.gpus[i].publish_wait.is_empty() {
                self.try_publish(i);
                self.kick_prefill(i);
            }
            k += 1;
        }
    }

    /// Send a decode item (whose KV lives on `via`'s node ring) to a
    /// surviving worker, paying the KV re-transfer; parks it in the
    /// orphan pool when no worker survives.
    pub(crate) fn redispatch_decode(
        &mut self,
        via: usize,
        src_node: usize,
        exclude: Option<usize>,
        slot: SlotId,
    ) {
        // A full ring used to over-commit its slot count here; defer
        // instead (deterministic backpressure) and drain FIFO as slots
        // free in `on_kv_arrive`.
        if self.ring_free(src_node) == 0 {
            self.retransfer_wait[src_node].push_back((via, slot));
            return;
        }
        let target = match self.cfg.topology {
            crate::config::Topology::Coalesced => self.pick_coalesced_gpu(exclude),
            crate::config::Topology::Disaggregated { .. } => {
                self.pick_decode_gpu(exclude, src_node)
            }
        };
        let Some(target) = target else {
            self.orphan_items.push(slot);
            return;
        };
        // The new host must fit the context (the caller no longer holds
        // a reservation: failure wiped it, a drain released it, or the
        // item came from the orphan pool). A pool that cannot evict
        // enough parks the item until a completion or recovery retries.
        if self.mem.active() {
            let bytes = self.kv_bytes_for_slot(target.0, slot);
            match self.mem.reserve(target.0, bytes) {
                Ok(ev) => {
                    self.note_eviction(target.0, ev);
                    self.reindex(target.0);
                }
                Err(()) => {
                    self.orphan_items.push(slot);
                    return;
                }
            }
        }
        let same_node = self.node_of(target.0) == src_node;
        // The re-fetch moves the *live* context — prompt plus generated
        // tokens — not just the original prompt KV.
        let ctx = self.store.get(slot).ctx_tokens();
        let t = self
            .fleet
            .kv_transfer_time_between(via, target.0, ctx, same_node);
        self.ring_used[src_node] += 1; // the re-transfer occupies a slot
        debug_assert!(self.ring_used[src_node] <= self.cfg.batch.ring_slots);
        self.events.push(
            self.now + t,
            Event::KvArrive { gpu: target.0, src_node, slot },
        );
    }

    /// Least-loaded accepting coalesced worker (failure re-dispatch on
    /// the coalesced topology), via the reused routing scratch and the
    /// same load view `route_coalesced` ranks by.
    fn pick_coalesced_gpu(&mut self, exclude: Option<usize>) -> Option<GpuId> {
        let mut loads = std::mem::take(&mut self.scratch_loads);
        self.fill_coalesced_loads(exclude, &mut loads);
        let pick = crate::coordinator::router::pick_decode(&loads);
        self.scratch_loads = loads;
        pick
    }
}
