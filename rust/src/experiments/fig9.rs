//! Fig 9: how dynamic RAPID manages power and GPUs over the mixed Sonnet
//! trace at 2.0 QPS/GPU — (a) DynPower's cap timeline, (b) DynGPU's role
//! timeline, (c) full RAPID's combined behaviour with the paper's ①-⑤
//! milestones:
//!   ① power moves to prefill first,
//!   ② a decode GPU is reassigned to prefill when power saturates,
//!   ③ combined allocation satisfies phase-1 SLOs,
//!   ④ at the phase boundary resources start flowing back,
//!   ⑤ decode-heavy steady state: most GPUs on decode, uniform caps.

use crate::config::{presets, ClusterConfig};
use crate::experiments::ShapeCheck;
use crate::metrics::RunResult;
use crate::scenario::{mixed_phases_trace, Axis, Scenario, Study, WorkloadSpec};
use crate::types::{Micros, SECOND};
use crate::workload::sonnet::MixedPhasesSpec;

pub struct Fig9 {
    pub spec: MixedPhasesSpec,
    /// Phase-1/phase-2 boundary (arrival of the first decode-heavy req).
    pub phase_boundary: Micros,
    pub dyn_power: (ClusterConfig, RunResult),
    pub dyn_gpu: (ClusterConfig, RunResult),
    pub rapid: (ClusterConfig, RunResult),
}

/// The three dynamic schemes over the default mixed trace.
pub fn scenario(seed: u64, requests_per_phase: usize) -> Scenario {
    // The default spec's substrate peak-load rate, expressed per GPU so
    // the cell reconstructs the identical node-level rate.
    let rate_per_gpu = MixedPhasesSpec::default().rate_qps / 8.0;
    Scenario::new("fig9", presets::p4d4(600.0))
        .seed(seed)
        .requests(2 * requests_per_phase)
        .workload(WorkloadSpec::MixedPhases)
        .rate(rate_per_gpu)
        .axis(Axis::Config(vec![
            presets::dyn_power_600(),
            presets::dyn_gpu_600(),
            presets::rapid_600(),
        ]))
}

pub fn run(seed: u64, requests_per_phase: usize) -> Fig9 {
    let spec = MixedPhasesSpec {
        prefill_heavy_count: requests_per_phase,
        decode_heavy_count: requests_per_phase,
        ..Default::default()
    };
    let study = Study::new(scenario(seed, requests_per_phase))
        .run(None)
        .expect("fig9 scenario");
    // The same deterministic trace every cell ran (seed + spec derive it).
    let trace = mixed_phases_trace(seed, 2 * requests_per_phase, spec.rate_qps);
    let phase_boundary = trace.requests[requests_per_phase].arrival;
    let mut results = study
        .cells
        .into_iter()
        .map(|c| {
            let cfg = c.config.clone();
            (cfg, c.into_result().expect("sim cell"))
        })
        .collect::<Vec<_>>()
        .into_iter();
    let mut take = || results.next().unwrap();
    Fig9 {
        spec,
        phase_boundary,
        dyn_power: take(),
        dyn_gpu: take(),
        rapid: take(),
    }
}

/// Mean prefill-pool cap in a time window of a cap trace, given roles.
fn mean_caps_in(
    result: &RunResult,
    from: Micros,
    to: Micros,
) -> Option<Vec<f64>> {
    let rows: Vec<&(Micros, Vec<f64>)> = result
        .cap_trace
        .iter()
        .filter(|(t, _)| *t >= from && *t < to)
        .collect();
    if rows.is_empty() {
        return None;
    }
    let n = rows[0].1.len();
    let mut mean = vec![0.0; n];
    for (_, caps) in &rows {
        for (i, c) in caps.iter().enumerate() {
            mean[i] += c;
        }
    }
    for m in &mut mean {
        *m /= rows.len() as f64;
    }
    Some(mean)
}

/// Role counts at the end of a window (from the role trace).
fn roles_at(result: &RunResult, t: Micros) -> (usize, usize) {
    result
        .role_trace
        .iter()
        .take_while(|(rt, _, _)| *rt <= t)
        .last()
        .map(|&(_, p, d)| (p, d))
        .unwrap_or((0, 0))
}

/// Peak prefill GPU count over a window.
fn max_prefill_in(result: &RunResult, from: Micros, to: Micros) -> usize {
    result
        .role_trace
        .iter()
        .filter(|(t, _, _)| *t >= from && *t <= to)
        .map(|&(_, p, _)| p)
        .max()
        .unwrap_or(0)
}

impl Fig9 {
    pub fn render(&self) -> String {
        let pb = self.phase_boundary;
        let mut out = format!(
            "Mixed Sonnet @{:.2} QPS/GPU (peak-load point); phase boundary at {:.0}s\n",
            self.spec.rate_qps / 8.0,
            pb as f64 / SECOND as f64
        );
        for (label, (_, res)) in [
            ("(a) 4P4D-DynPower", &self.dyn_power),
            ("(b) DynGPU-600W", &self.dyn_gpu),
            ("(c) DynGPU-DynPower", &self.rapid),
        ] {
            out.push_str(&format!("\n{label}: attainment={:.1}%\n", res.attainment() * 100.0));
            out.push_str("  role timeline (t_s, prefill, decode):\n");
            for &(t, p, d) in res.role_trace.iter().take(24) {
                out.push_str(&format!("    {:>6.1} {p}P {d}D\n", t as f64 / 1e6));
            }
            if let Some(m1) = mean_caps_in(res, 0, pb) {
                out.push_str(&format!(
                    "  mean caps phase1: {:?}\n",
                    m1.iter().map(|c| c.round()).collect::<Vec<_>>()
                ));
            }
            if let Some(m2) = mean_caps_in(res, pb, pb * 2) {
                out.push_str(&format!(
                    "  mean caps phase2: {:?}\n",
                    m2.iter().map(|c| c.round()).collect::<Vec<_>>()
                ));
            }
            out.push_str(&format!("  decisions: {}\n", res.decisions.len()));
            for (t, d) in res.decisions.iter().take(12) {
                out.push_str(&format!("    {:>6.1}s {d}\n", *t as f64 / 1e6));
            }
        }
        out
    }

    pub fn checks(&self) -> Vec<ShapeCheck> {
        let pb = self.phase_boundary;
        let (_, dp) = &self.dyn_power;
        let (_, dg) = &self.dyn_gpu;
        let (_, ra) = &self.rapid;
        let mut checks = Vec::new();

        // (a) DynPower: prefill caps rise toward max during phase 1, fall
        // back to uniform in phase 2.
        if let (Some(m1), Some(m2)) = (mean_caps_in(dp, pb / 4, pb), mean_caps_in(dp, pb + pb / 2, pb * 2)) {
            let prefill_phase1 = m1[..4].iter().sum::<f64>() / 4.0;
            let decode_phase1 = m1[4..].iter().sum::<f64>() / 4.0;
            let spread2 = m2.iter().fold(0f64, |a, &c| a.max(c)) - m2.iter().fold(f64::MAX, |a, &c| a.min(c));
            checks.push(ShapeCheck::new(
                "(a) DynPower raises prefill caps above decode in phase 1",
                prefill_phase1 > decode_phase1 + 50.0,
                format!("prefill={prefill_phase1:.0} decode={decode_phase1:.0}"),
            ));
            checks.push(ShapeCheck::new(
                "(a) phase 2 returns toward uniform caps (paper: all at 600 W)",
                spread2 < 120.0,
                format!("cap spread={spread2:.0} W"),
            ));
        }
        // (b) DynGPU: prefill pool grows in phase 1, decode pool dominates
        // in phase 2 (paper: up to 6 prefill, then 7 decode).
        let peak_p = max_prefill_in(dg, 0, pb);
        let (p2, d2) = roles_at(dg, pb * 2 - SECOND);
        checks.push(ShapeCheck::new(
            "(b) DynGPU grows the prefill pool beyond 4 in phase 1 (paper: up to 6)",
            peak_p >= 5,
            format!("peak prefill GPUs = {peak_p}"),
        ));
        checks.push(ShapeCheck::new(
            "(b) DynGPU shifts the majority to decode in phase 2 (paper: 7 decode)",
            d2 >= 5 && p2 >= 1,
            format!("end of phase 2: {p2}P {d2}D"),
        ));
        // (c) full RAPID: both mechanisms appear, in order (power before
        // GPU moves), and it beats both single-mechanism schemes.
        let first_power = ra
            .decisions
            .iter()
            .find(|(_, d)| d.contains("MovePower"))
            .map(|&(t, _)| t);
        let first_gpu = ra
            .decisions
            .iter()
            .find(|(_, d)| d.contains("MoveGpu"))
            .map(|&(t, _)| t);
        checks.push(ShapeCheck::new(
            "(c) RAPID moves power first, then GPUs (milestones 1-2)",
            matches!((first_power, first_gpu), (Some(p), Some(g)) if p <= g),
            format!("first power: {first_power:?}, first gpu: {first_gpu:?}"),
        ));
        checks.push(ShapeCheck::new(
            "(c) full RAPID attains >= both single-mechanism schemes",
            ra.attainment() >= dp.attainment() - 0.02
                && ra.attainment() >= dg.attainment() - 0.02,
            format!(
                "rapid={:.2} dynpower={:.2} dyngpu={:.2}",
                ra.attainment(),
                dp.attainment(),
                dg.attainment()
            ),
        ));
        checks
    }
}
