//! `rapid explain <request-id>`: a text timeline of one request's hops
//! through the cluster, with per-stage latency attribution derived from
//! the recorded event log.

use crate::metrics::RunResult;
use crate::obs::ObsEvent;
use crate::types::Micros;

fn secs(t: Micros) -> f64 {
    t as f64 / 1e6
}

/// Per-stage latency attribution accumulated while walking a request's
/// events in order.
#[derive(Default)]
struct Stages {
    arrival: Option<Micros>,
    prefill_queued: Option<Micros>,
    first_token: Option<Micros>,
    kv_send: Option<Micros>,
    kv_arrive: Option<Micros>,
    decode_admit: Option<Micros>,
    finish: Option<Micros>,
    /// Simulated time spent displaced from a decode batch (preempted or
    /// requeued), summed across segments.
    displaced: Micros,
    displaced_since: Option<Micros>,
    preemptions: u64,
}

/// Render the timeline for request `req`. Returns an error message when
/// the run carries no observability report or never saw the request.
pub fn explain(result: &RunResult, req: u64) -> Result<String, String> {
    let obs = result
        .obs
        .as_deref()
        .ok_or_else(|| "run has no observability report (record with `rapid trace`)".to_string())?;
    let mine: Vec<&ObsEvent> = obs.events.iter().filter(|e| e.req() == Some(req)).collect();
    if mine.is_empty() {
        return Err(format!(
            "request r{req} not found in the event log ({} events{})",
            obs.events.len(),
            if obs.dropped > 0 {
                format!(", {} dropped by the ring — raise the trace capacity", obs.dropped)
            } else {
                String::new()
            }
        ));
    }

    let mut st = Stages::default();
    let mut lines = Vec::new();
    let mut line = |at: Micros, what: String| lines.push(format!("  t={:>9.3}s  {what}", secs(at)));

    for ev in &mine {
        match **ev {
            ObsEvent::Arrival { at, tenant, input, output, .. } => {
                st.arrival = Some(at);
                line(at, format!("arrival          tenant {tenant}, {input} in / {output} out"));
            }
            ObsEvent::Shed { at, in_system, .. } => {
                line(at, format!("SHED             admission refused ({in_system} in system)"));
            }
            ObsEvent::PrefixHit { at, tokens, .. } => {
                line(at, format!("prefix hit       {tokens} prompt tokens cached"));
            }
            ObsEvent::PrefillQueued { at, gpu, .. } => {
                if st.prefill_queued.is_none() {
                    st.prefill_queued = Some(at);
                }
                if let Some(since) = st.displaced_since.take() {
                    st.displaced += at - since;
                }
                line(at, format!("prefill queued   gpu{gpu}"));
            }
            ObsEvent::FirstToken { at, gpu, .. } => {
                st.first_token = Some(at);
                let d = st.prefill_queued.map(|q| at - q).unwrap_or(0);
                line(at, format!("first token      gpu{gpu}  (+{:.3}s queue+prefill)", secs(d)));
            }
            ObsEvent::KvSend { at, src, dst, .. } => {
                if st.kv_send.is_none() {
                    st.kv_send = Some(at);
                }
                line(at, format!("kv send          gpu{src} -> gpu{dst}"));
            }
            ObsEvent::KvArrive { at, gpu, .. } => {
                st.kv_arrive = Some(at);
                let d = st.kv_send.map(|s| at - s).unwrap_or(0);
                line(at, format!("kv arrive        gpu{gpu}  (+{:.3}s transfer)", secs(d)));
            }
            ObsEvent::DecodeAdmit { at, gpu, .. } => {
                if st.decode_admit.is_none() {
                    st.decode_admit = Some(at);
                }
                if let Some(since) = st.displaced_since.take() {
                    st.displaced += at - since;
                }
                line(at, format!("decode admit     gpu{gpu}"));
            }
            ObsEvent::Preempt { at, by, gpu, victim_tier, by_tier, .. } => {
                st.preemptions += 1;
                st.displaced_since = Some(at);
                line(
                    at,
                    format!("PREEMPTED        gpu{gpu} by r{by} (tier {victim_tier} -> {by_tier})"),
                );
            }
            ObsEvent::Requeue { at, gpu, why, .. } => {
                st.displaced_since.get_or_insert(at);
                line(at, format!("requeue          gpu{gpu} ({why})"));
            }
            ObsEvent::Finish { at, gpu, tokens, .. } => {
                st.finish = Some(at);
                line(at, format!("finish           gpu{gpu}  ({tokens} tokens)"));
            }
            _ => {}
        }
    }

    let mut head = format!("request r{req} — {} events", mine.len());
    if st.preemptions > 0 {
        head.push_str(&format!(", preempted {}x", st.preemptions));
    }

    // Attribution: each stage from the timestamps that bound it.
    let mut attr: Vec<String> = Vec::new();
    if let (Some(a), Some(q)) = (st.arrival, st.prefill_queued) {
        attr.push(format!("route {:.3}s", secs(q - a)));
    }
    if let (Some(q), Some(f)) = (st.prefill_queued, st.first_token) {
        attr.push(format!("queue+prefill {:.3}s", secs(f - q)));
    }
    if let (Some(s), Some(v)) = (st.kv_send, st.kv_arrive) {
        attr.push(format!("kv {:.3}s", secs(v - s)));
    }
    if let (Some(v), Some(d)) = (st.kv_arrive, st.decode_admit) {
        attr.push(format!("decode wait {:.3}s", secs(d - v)));
    }
    if let (Some(d), Some(f)) = (st.decode_admit, st.finish) {
        attr.push(format!("decode {:.3}s", secs(f - d)));
    }
    if st.displaced > 0 {
        attr.push(format!("displaced {:.3}s", secs(st.displaced)));
    }
    if let (Some(a), Some(f)) = (st.arrival, st.finish) {
        attr.push(format!("total {:.3}s", secs(f - a)));
    }

    let mut out = String::new();
    out.push_str(&head);
    out.push('\n');
    for l in &lines {
        out.push_str(l);
        out.push('\n');
    }
    if !attr.is_empty() {
        out.push_str("stage attribution: ");
        out.push_str(&attr.join(" · "));
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ObsReport;

    fn result_with(events: Vec<ObsEvent>) -> RunResult {
        let mut r = RunResult::default();
        r.duration = 2_000_000;
        r.obs = Some(Box::new(ObsReport { events, node_of: vec![0, 0], ..ObsReport::default() }));
        r
    }

    #[test]
    fn renders_a_full_lifecycle_with_attribution() {
        let r = result_with(vec![
            ObsEvent::Arrival { at: 0, req: 5, tenant: 1, input: 800, output: 32 },
            ObsEvent::PrefillQueued { at: 1_000, req: 5, gpu: 0 },
            ObsEvent::FirstToken { at: 101_000, req: 5, gpu: 0 },
            ObsEvent::KvSend { at: 101_000, req: 5, src: 0, dst: 1, arrive_at: 105_000 },
            ObsEvent::KvArrive { at: 105_000, req: 5, gpu: 1 },
            ObsEvent::DecodeAdmit { at: 106_000, req: 5, gpu: 1 },
            ObsEvent::Preempt { at: 500_000, victim: 5, by: 9, gpu: 1, victim_tier: 2, by_tier: 0 },
            ObsEvent::DecodeAdmit { at: 700_000, req: 5, gpu: 1 },
            ObsEvent::Finish { at: 900_000, req: 5, gpu: 1, tokens: 32 },
        ]);
        let text = explain(&r, 5).unwrap();
        assert!(text.starts_with("request r5"), "{text}");
        assert!(text.contains("preempted 1x"), "{text}");
        assert!(text.contains("PREEMPTED"), "{text}");
        assert!(text.contains("queue+prefill 0.100s"), "{text}");
        assert!(text.contains("kv 0.004s"), "{text}");
        assert!(text.contains("displaced 0.200s"), "{text}");
        assert!(text.contains("total 0.900s"), "{text}");
    }

    #[test]
    fn unknown_request_reports_cleanly() {
        let r = result_with(vec![ObsEvent::FirstToken { at: 1, req: 2, gpu: 0 }]);
        let err = explain(&r, 99).unwrap_err();
        assert!(err.contains("r99"), "{err}");
        assert!(explain(&RunResult::default(), 1).is_err());
    }
}
