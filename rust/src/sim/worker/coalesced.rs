//! Coalesced worker behavior: Sarathi-style chunked prefill co-scheduled
//! with the resident decode batch — the vLLM baseline the paper
//! disaggregates away from.

use crate::cluster::Cluster;
use crate::coordinator::batcher;
use crate::sim::event::{DecodeItem, Event};
use crate::sim::worker::RoleBehavior;
use crate::types::{GpuId, Role};

pub struct CoalescedBehavior;

impl RoleBehavior for CoalescedBehavior {
    fn role(&self) -> Role {
        Role::Coalesced
    }

    fn kick(&self, cl: &mut Cluster, gi: usize) {
        cl.kick_coalesced(gi);
    }

    fn on_step_done(&self, cl: &mut Cluster, gi: usize, epoch: u64) {
        cl.on_coalesced_step(gi, epoch);
    }
}

impl Cluster {
    pub(crate) fn kick_coalesced(&mut self, gi: usize) {
        // Chunk budget is a per-SKU constant (heterogeneous fleets may
        // mix chunk sizes; the implicit fleet reads cfg.perf as before).
        let chunk_budget = self.model_of(gi).cfg().chunk_tokens;
        let g = &mut self.gpus[gi];
        if g.busy || g.failed || g.role != Role::Coalesced {
            return;
        }
        if g.co_queue.is_empty() && g.dec_active.is_empty() && g.dec_pending.is_empty() {
            return;
        }
        // Admit locally-finished prefills (they sit in dec_pending).
        let n = batcher::decode_admissions(
            g.dec_active.len(),
            g.dec_pending.len(),
            &self.cfg.batch,
        );
        for _ in 0..n {
            let item = g.dec_pending.pop_front().unwrap();
            g.dec_active.push(item);
        }
        // Take the next prefill chunk directly over the meta queue —
        // same packing as `batcher::take_chunk` (head-first, spilling
        // into later prompts when the head completes inside the budget)
        // but in place: no cloned progress queue per iteration.
        let now = self.now;
        let done_before = g.co_queue.front().map_or(0, |c| c.prog.done_tokens);
        let mut used = 0u32;
        while used < chunk_budget {
            let Some(head) = g.co_queue.front_mut() else { break };
            if head.started.is_none() {
                // The chunk reached this prompt: its execution starts now.
                head.started = Some(now);
            }
            used += head.prog.advance(chunk_budget - used);
            if head.prog.complete() {
                let meta = g.co_queue.pop_front().unwrap();
                g.co_finishing
                    .push((meta.prog.request, meta.started.unwrap_or(now)));
            } else {
                break;
            }
        }
        g.co_step_chunk = used;
        if used == 0 && g.dec_active.is_empty() {
            return; // nothing to do this iteration
        }
        g.busy = true;
        let batch = g.dec_active.len();
        let ctx = g.mean_ctx();
        let power = self.power.effective(GpuId(gi), self.now);
        let t = self
            .model_of(gi)
            .coalesced_step_time(used, done_before, batch, ctx, power);
        self.gpus[gi].dec_step_time = t;
        let epoch = self.gpus[gi].epoch;
        self.events
            .push(self.now + t, Event::StepDone { gpu: gi, epoch });
    }

    pub(crate) fn on_coalesced_step(&mut self, gi: usize, epoch: u64) {
        if self.gpus[gi].epoch != epoch {
            return;
        }
        let step = self.gpus[gi].dec_step_time;
        self.gpus[gi].busy = false;
        // Prefill completions: first token now; join local decode.
        // Drain-and-restore keeps co_finishing's capacity across steps.
        let mut finishing = std::mem::take(&mut self.gpus[gi].co_finishing);
        let dynamic = self.policy.is_dynamic();
        for (req, started) in finishing.drain(..) {
            if dynamic {
                let ratio = (self.now - req.arrival) as f64 / req.slo.ttft as f64;
                self.policy.observe_ttft(self.now, ratio);
            }
            if req.output_tokens <= 1 {
                let now = self.now;
                self.push_record(&req, started, now, now);
                continue;
            }
            self.gpus[gi].dec_pending.push_back(DecodeItem {
                req,
                prefill_start: started,
                first_token: self.now,
                tokens_done: 1,
                cached_tokens: 0,
            });
        }
        self.gpus[gi].co_finishing = finishing;
        // Decode completions, into the shared finished-items scratch.
        let mut ratio_sum = 0.0;
        let mut finished = std::mem::take(&mut self.scratch_done);
        finished.clear();
        let mut tpot_sample = None;
        {
            let g = &mut self.gpus[gi];
            let mut idx = 0;
            while idx < g.dec_active.len() {
                g.dec_active[idx].tokens_done += 1;
                ratio_sum += step as f64 / g.dec_active[idx].req.slo.tpot as f64;
                if g.dec_active[idx].remaining() == 0 {
                    finished.push(g.dec_active.swap_remove(idx));
                } else {
                    idx += 1;
                }
            }
            let n = g.dec_active.len() + finished.len();
            if n > 0 {
                tpot_sample = Some(ratio_sum / n as f64);
            }
        }
        if dynamic {
            if let Some(ratio) = tpot_sample {
                self.policy.observe_tpot(self.now, ratio);
            }
        }
        for item in finished.drain(..) {
            let now = self.now;
            self.push_record(&item.req, item.prefill_start, item.first_token, now);
        }
        self.scratch_done = finished;
        self.kick_coalesced(gi);
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::Cluster;
    use crate::config::presets;
    use crate::coordinator::batcher::ChunkProgress;
    use crate::sim::engine::SimOptions;
    use crate::sim::gpu::ChunkMeta;
    use crate::types::{Request, RequestId, Slo};
    use crate::workload::Trace;

    fn req(id: u64, input: u32) -> Request {
        Request {
            id: RequestId(id),
            arrival: 0,
            input_tokens: input,
            output_tokens: 8,
            slo: Slo::paper_default(),
            tenant: 0,
        }
    }

    fn cluster() -> Cluster {
        Cluster::new(
            presets::coalesced(750.0),
            Trace::default(),
            SimOptions::default(),
        )
    }

    #[test]
    fn chunk_packs_across_prompts_in_place() {
        // The Sarathi packing invariant the in-place loop must keep: a
        // head that finishes inside the budget spills exactly the
        // remaining budget into the next prompt.
        let mut cl = cluster();
        let budget = cl.cfg.perf.chunk_tokens;
        assert!(budget > 300, "test assumes the first prompt fits one chunk");
        for (id, toks) in [(0u64, 300u32), (1, 5000)] {
            cl.gpus[0].co_queue.push_back(ChunkMeta {
                prog: ChunkProgress::new(req(id, toks)),
                started: None,
            });
        }
        cl.kick_coalesced(0);
        let g = &cl.gpus[0];
        assert_eq!(g.co_step_chunk, budget);
        assert_eq!(g.co_finishing.len(), 1);
        assert_eq!(g.co_finishing[0].0.id.0, 0);
        assert_eq!(g.co_finishing[0].1, 0, "head's started stamp");
        let head = g.co_queue.front().unwrap();
        assert_eq!(head.prog.request.id.0, 1);
        assert_eq!(head.prog.done_tokens, budget - 300);
        assert_eq!(head.started, Some(0), "reached prompt is marked started");
        assert!(g.busy);
    }

    #[test]
    fn kick_with_empty_queue_is_a_noop() {
        let mut cl = cluster();
        cl.kick_coalesced(0);
        assert!(!cl.gpus[0].busy);
        assert_eq!(cl.gpus[0].co_step_chunk, 0);
    }
}
