//! Core domain types shared by the simulator, coordinator and runtime.
//!
//! Time is a `u64` microsecond count (`Micros`) everywhere so the same
//! coordinator logic runs under the discrete-event simulator (virtual
//! time) and the real serving path (wall time).

use std::fmt;

/// Microseconds since experiment start (virtual or wall).
pub type Micros = u64;

/// One second in `Micros`.
pub const SECOND: Micros = 1_000_000;
/// One millisecond in `Micros`.
pub const MILLIS: Micros = 1_000;

/// Watts as f64 (power values are small; precision is not a concern).
pub type Watts = f64;

/// Unique, monotonically-assigned request identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

impl fmt::Display for RequestId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "r{}", self.0)
    }
}

/// Index of a GPU within the node (0..n_gpus).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuId(pub usize);

impl fmt::Display for GpuId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "gpu{}", self.0)
    }
}

/// Which inference phase a GPU currently serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Role {
    Prefill,
    Decode,
    /// Chunked-prefill baseline: both phases share the GPU (vLLM coalesced).
    Coalesced,
}

impl fmt::Display for Role {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Role::Prefill => write!(f, "prefill"),
            Role::Decode => write!(f, "decode"),
            Role::Coalesced => write!(f, "coalesced"),
        }
    }
}

/// An inference request as the coordinator sees it. Plain old data —
/// `Copy` keeps the simulator's hot paths free of per-request heap
/// traffic (requests move through the event heap by value).
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub id: RequestId,
    /// Arrival time at the router.
    pub arrival: Micros,
    /// Prompt length in tokens.
    pub input_tokens: u32,
    /// Number of tokens to generate (including the first token produced
    /// by prefill).
    pub output_tokens: u32,
    /// SLO this request is judged against (provider tier).
    pub slo: Slo,
    /// Tenant id: 0 = untenanted, else a 1-based index into the
    /// config's tenant-class list (see `workload::tracespec`).
    pub tenant: u8,
}

impl Request {
    /// KV-cache bytes this request's prompt occupies (used for transfer
    /// latency and memory accounting).
    pub fn kv_bytes(&self, bytes_per_token: u64) -> u64 {
        self.input_tokens as u64 * bytes_per_token
    }
}

/// Latency service-level objectives (paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    /// Time-to-first-token target.
    pub ttft: Micros,
    /// Time-per-output-token target (mean over the request's decode).
    pub tpot: Micros,
}

impl Slo {
    pub const fn new(ttft: Micros, tpot: Micros) -> Self {
        Slo { ttft, tpot }
    }

    /// The paper's baseline SLO: TTFT = 1 s, TPOT = 40 ms.
    pub const fn paper_default() -> Self {
        Slo::new(SECOND, 40 * MILLIS)
    }

    /// Uniformly scale both targets (paper Fig 7's 0.5x–2x sweep).
    pub fn scaled(&self, factor: f64) -> Self {
        Slo {
            ttft: (self.ttft as f64 * factor) as Micros,
            tpot: (self.tpot as f64 * factor) as Micros,
        }
    }
}

/// Completion record for one request; the unit of all paper metrics.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: RequestId,
    pub arrival: Micros,
    /// When prefill execution began (end of queueing delay).
    pub prefill_start: Micros,
    /// First token produced (end of prefill): TTFT = first_token - arrival.
    pub first_token: Micros,
    /// Last token produced.
    pub finish: Micros,
    pub input_tokens: u32,
    pub output_tokens: u32,
    pub slo: Slo,
    /// Tenant id carried over from the request (0 = untenanted).
    pub tenant: u8,
    /// Shed by admission control: accounted (never dropped silently)
    /// as an SLO-violating record with no service.
    pub shed: bool,
}

impl RequestRecord {
    pub fn ttft(&self) -> Micros {
        self.first_token.saturating_sub(self.arrival)
    }

    /// Queueing component of TTFT (paper Fig 6 breakdown).
    pub fn queueing_delay(&self) -> Micros {
        self.prefill_start.saturating_sub(self.arrival)
    }

    /// Execution component of TTFT (paper Fig 6 breakdown).
    pub fn exec_time(&self) -> Micros {
        self.first_token.saturating_sub(self.prefill_start)
    }

    /// Mean time per output token after the first (paper §4 definition).
    /// KV-transfer latency lands here, not in TTFT (pull model).
    pub fn tpot(&self) -> Micros {
        if self.output_tokens <= 1 {
            return 0;
        }
        self.finish.saturating_sub(self.first_token) / (self.output_tokens as u64 - 1)
    }

    /// Goodput predicate: did the request meet *both* SLOs?
    pub fn attained(&self) -> bool {
        self.ttft() <= self.slo.ttft && self.tpot() <= self.slo.tpot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: Micros, start: Micros, first: Micros, finish: Micros, out: u32) -> RequestRecord {
        RequestRecord {
            id: RequestId(1),
            arrival,
            prefill_start: start,
            first_token: first,
            finish,
            input_tokens: 100,
            output_tokens: out,
            slo: Slo::paper_default(),
            tenant: 0,
            shed: false,
        }
    }

    #[test]
    fn ttft_and_breakdown() {
        let r = rec(0, 300_000, 800_000, 5_000_000, 10);
        assert_eq!(r.ttft(), 800_000);
        assert_eq!(r.queueing_delay(), 300_000);
        assert_eq!(r.exec_time(), 500_000);
        assert_eq!(r.ttft(), r.queueing_delay() + r.exec_time());
    }

    #[test]
    fn tpot_mean_over_remaining_tokens() {
        // 9 tokens after the first over 4.2 s -> 466.6 ms each
        let r = rec(0, 0, 800_000, 5_000_000, 10);
        assert_eq!(r.tpot(), 4_200_000 / 9);
    }

    #[test]
    fn tpot_single_token_is_zero() {
        let r = rec(0, 0, 800_000, 800_000, 1);
        assert_eq!(r.tpot(), 0);
    }

    #[test]
    fn attainment_requires_both_slos() {
        // TTFT ok (0.8s <= 1s), TPOT ok (fast decode)
        let good = rec(0, 0, 800_000, 1_000_000, 10);
        assert!(good.attained());
        // TTFT violated
        let slow_prefill = rec(0, 0, 1_200_000, 1_400_000, 10);
        assert!(!slow_prefill.attained());
        // TPOT violated: 9 tokens over 4.2s >> 40ms
        let slow_decode = rec(0, 0, 800_000, 5_000_000, 10);
        assert!(!slow_decode.attained());
    }

    #[test]
    fn slo_scaling() {
        let s = Slo::paper_default().scaled(0.5);
        assert_eq!(s.ttft, 500 * MILLIS);
        assert_eq!(s.tpot, 20 * MILLIS);
    }

    #[test]
    fn kv_bytes_scale_with_prompt() {
        let r = Request {
            id: RequestId(0),
            arrival: 0,
            input_tokens: 4096,
            output_tokens: 128,
            slo: Slo::paper_default(),
            tenant: 0,
        };
        assert_eq!(r.kv_bytes(131_072), 4096 * 131_072);
    }
}
