//! Fig 6: TTFT decomposition — queueing delay vs execution time — for
//! uniform 4P4D-600W relative to non-uniform 4P-750W/4D-450W at
//! 1.5 QPS/GPU (LongBench). The paper's story: the uniform config's
//! prefill is only ~15% slower *per request*, but that deficit compounds
//! into queueing backpressure, so queueing delay (not exec time) is what
//! blows up.

use crate::config::presets;
use crate::experiments::ShapeCheck;
use crate::scenario::{Axis, Scenario, Study};
use crate::types::{Micros, SECOND};

pub struct Fig6 {
    /// Per-time-bucket (t, mean queueing delay, mean exec time), uniform.
    pub uniform: Vec<(Micros, f64, f64)>,
    /// Same for the non-uniform config.
    pub nonuniform: Vec<(Micros, f64, f64)>,
    /// Mean exec-time ratio uniform/non-uniform (paper: ~1.15).
    pub exec_ratio: f64,
    /// Mean queueing-delay ratio uniform/non-uniform (paper: >> 1).
    pub queue_ratio: f64,
}

fn buckets(records: &[crate::types::RequestRecord], bucket: Micros) -> Vec<(Micros, f64, f64)> {
    if records.is_empty() {
        return Vec::new();
    }
    let max_t = records.iter().map(|r| r.first_token).max().unwrap();
    let n = (max_t / bucket + 1) as usize;
    let mut q = vec![0.0; n];
    let mut e = vec![0.0; n];
    let mut c = vec![0u32; n];
    for r in records {
        let b = ((r.first_token / bucket) as usize).min(n - 1);
        q[b] += r.queueing_delay() as f64;
        e[b] += r.exec_time() as f64;
        c[b] += 1;
    }
    (0..n)
        .filter(|&i| c[i] > 0)
        .map(|i| (i as Micros * bucket, q[i] / c[i] as f64, e[i] / c[i] as f64))
        .collect()
}

/// Mean exec time over requests that saw (almost) no queueing — the
/// isolated per-request execution cost the paper's ~15% refers to
/// (congested batches conflate batch size with power effects).
fn uncongested_exec(records: &[crate::types::RequestRecord]) -> f64 {
    let xs: Vec<f64> = records
        .iter()
        .filter(|r| r.queueing_delay() < 100_000)
        .map(|r| r.exec_time() as f64 / r.input_tokens.max(1) as f64)
        .collect();
    if xs.is_empty() {
        return 1.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Two config cells (uniform vs non-uniform) at the figure's one rate.
pub fn scenario(seed: u64, n: usize) -> Scenario {
    Scenario::new("fig6", presets::p4d4(600.0))
        .seed(seed)
        .requests(n)
        .axis(Axis::Config(vec![
            presets::p4d4(600.0),
            presets::p4_750_d4_450(),
        ]))
        .axis(Axis::RatePerGpu(vec![1.5]))
}

pub fn run(seed: u64, n: usize) -> Fig6 {
    let study = Study::new(scenario(seed, n)).run(None).expect("fig6 scenario");
    let uni = study.cells[0].result().expect("sim cell");
    let non = study.cells[1].result().expect("sim cell");
    let (qu, _eu) = uni.ttft_breakdown();
    let (qn, _en) = non.ttft_breakdown();
    Fig6 {
        uniform: buckets(&uni.records, 10 * SECOND),
        nonuniform: buckets(&non.records, 10 * SECOND),
        exec_ratio: uncongested_exec(&uni.records) / uncongested_exec(&non.records),
        queue_ratio: qu / qn.max(1.0),
    }
}

impl Fig6 {
    pub fn render(&self) -> String {
        let mut out = String::from(
            "TTFT decomposition over time (means per 10 s bucket, ms)\n",
        );
        out.push_str("   t(s)   uniform-queue  uniform-exec  nonunif-queue  nonunif-exec\n");
        for i in 0..self.uniform.len().min(self.nonuniform.len()) {
            let (t, qu, eu) = self.uniform[i];
            let (_, qn, en) = self.nonuniform[i];
            out.push_str(&format!(
                "{:>7} {:>14.1} {:>13.1} {:>14.1} {:>13.1}\n",
                t / SECOND,
                qu / 1000.0,
                eu / 1000.0,
                qn / 1000.0,
                en / 1000.0
            ));
        }
        out.push_str(&format!(
            "\nexec ratio (uniform/non-uniform): {:.2} (paper ~1.15)\n",
            self.exec_ratio
        ));
        out.push_str(&format!(
            "queue ratio (uniform/non-uniform): {:.2} (paper: dominates)\n",
            self.queue_ratio
        ));
        out
    }

    pub fn checks(&self) -> Vec<ShapeCheck> {
        vec![
            ShapeCheck::new(
                "uniform exec time modestly slower (paper: ~15%)",
                (1.02..=1.4).contains(&self.exec_ratio),
                format!("{:.2}x", self.exec_ratio),
            ),
            ShapeCheck::new(
                "queueing delay compounds far beyond the exec gap",
                self.queue_ratio > self.exec_ratio * 1.5,
                format!("queue {:.1}x vs exec {:.2}x", self.queue_ratio, self.exec_ratio),
            ),
            ShapeCheck::new(
                "non-uniform queueing stays mostly negligible",
                {
                    let mean_q_non: f64 = self
                        .nonuniform
                        .iter()
                        .map(|&(_, q, _)| q)
                        .sum::<f64>()
                        / self.nonuniform.len().max(1) as f64;
                    mean_q_non < 500_000.0 // < 0.5 s mean queueing
                },
                "mean non-uniform queueing < 0.5 s".to_string(),
            ),
        ]
    }
}
