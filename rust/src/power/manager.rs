//! Cluster power manager: hierarchical budget enforcement + the paper's
//! source-before-sink shifting protocol.
//!
//! Owns every GPU's `CapState` and guarantees the §2.2 safety protocol at
//! two levels: the total *allowed* power of each node never exceeds that
//! node's budget, and the cluster-wide total never exceeds the cluster
//! budget (which may bind first — a facility-level constraint). When
//! power moves between pools the source caps are lowered and given time
//! to settle before the sink caps rise. Raises are queued as pending
//! operations released by `poll(now)`.
//!
//! The single-node constructor (`new`) is the paper's testbed: one node
//! whose budget is also the cluster budget.

use std::cell::Cell;

use crate::power::capper::{CapState, RampProfile};
use crate::types::{GpuId, Micros, Watts};

#[derive(Debug)]
pub enum PowerError {
    BudgetExceeded { total: Watts, budget: Watts },
    NodeBudgetExceeded { node: usize, total: Watts, budget: Watts },
    OutOfLimits { cap: Watts, min: Watts, max: Watts },
    EmptyPool(&'static str),
}

impl std::fmt::Display for PowerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PowerError::BudgetExceeded { total, budget } => write!(
                f,
                "cap change would exceed cluster budget: {total:.0} W > {budget:.0} W"
            ),
            PowerError::NodeBudgetExceeded { node, total, budget } => write!(
                f,
                "cap change would exceed node {node} budget: {total:.0} W > {budget:.0} W"
            ),
            PowerError::OutOfLimits { cap, min, max } => {
                write!(f, "cap {cap:.0} W outside limits [{min:.0}, {max:.0}]")
            }
            PowerError::EmptyPool(which) => write!(f, "no gpus in {which} pool"),
        }
    }
}

impl std::error::Error for PowerError {}

/// A deferred cap raise, released once the paired lowers have settled.
#[derive(Debug, Clone)]
struct PendingRaise {
    gpu: GpuId,
    cap: Watts,
    at: Micros,
}

/// Outcome of a `move_power` call (for logging / Fig 9 traces).
#[derive(Debug, Clone, PartialEq)]
pub struct PowerMove {
    pub lowered: Vec<(GpuId, Watts)>,
    pub raised: Vec<(GpuId, Watts)>,
    /// When the raises take effect (sources settled).
    pub effective_at: Micros,
}

#[derive(Debug)]
pub struct PowerManager {
    caps: Vec<CapState>,
    /// Node index of each GPU (same length as `caps`).
    node_of: Vec<usize>,
    /// Per-node power budgets (W).
    node_budgets: Vec<Watts>,
    /// Cluster-wide budget (W); binds when tighter than the node sum.
    cluster_budget: Watts,
    pending: Vec<PendingRaise>,
    profile: RampProfile,
    enforce: bool,
    /// Per-GPU cap floor/ceiling (W) — uniform MIN_P/MAX_P on a
    /// homogeneous fleet, the SKU envelope per GPU on a mixed one.
    min_of: Vec<Watts>,
    max_of: Vec<Watts>,
    /// Rated (undegraded) ceiling per GPU: `max_of` returns here when a
    /// thermal derate clears.
    rated_max: Vec<Watts>,
    /// Failed GPUs: excluded from every budget sum, uniform split and
    /// cap trace until they recover (environment subsystem).
    offline: Vec<bool>,
    /// Per-GPU committed cap (target ∨ pending raises, 0 when offline),
    /// kept current by `refresh_committed` at every mutation so budget
    /// sums never rescan `caps`/`pending`.
    committed_of: Vec<Watts>,
    /// GPUs of each node in ascending id order — the summation order the
    /// node totals have always used (bit-identity invariant).
    node_members: Vec<Vec<usize>>,
    /// Cached folds of `committed_of`. Dirty-tracked rather than
    /// delta-updated: f64 addition is not associative, so the only sum
    /// that is bit-identical to the historical `Vec` fold is a refold
    /// over the same values in the same order. Queries between
    /// mutations are O(1); a mutation marks only the touched node (and
    /// the cluster) dirty.
    cluster_sum: Cell<Watts>,
    cluster_dirty: Cell<bool>,
    node_sum: Vec<Cell<Watts>>,
    node_dirty: Vec<Cell<bool>>,
}

impl PowerManager {
    /// Single-node manager: node budget == cluster budget (the paper's
    /// testbed shape).
    pub fn new(
        initial_caps: &[Watts],
        budget: Watts,
        enforce: bool,
        min_w: Watts,
        max_w: Watts,
    ) -> Self {
        PowerManager::with_nodes(
            initial_caps,
            vec![0; initial_caps.len()],
            vec![budget],
            budget,
            enforce,
            min_w,
            max_w,
        )
    }

    /// Hierarchical manager with uniform per-GPU limits: `node_of[i]` is
    /// GPU i's node; each node has its own budget; `cluster_budget` caps
    /// the whole fleet.
    pub fn with_nodes(
        initial_caps: &[Watts],
        node_of: Vec<usize>,
        node_budgets: Vec<Watts>,
        cluster_budget: Watts,
        enforce: bool,
        min_w: Watts,
        max_w: Watts,
    ) -> Self {
        let n = initial_caps.len();
        PowerManager::with_limits(
            initial_caps,
            node_of,
            node_budgets,
            cluster_budget,
            enforce,
            vec![min_w; n],
            vec![max_w; n],
        )
    }

    /// Fully general manager: per-GPU cap limits (heterogeneous SKU
    /// envelopes) on top of the hierarchical budgets.
    pub fn with_limits(
        initial_caps: &[Watts],
        node_of: Vec<usize>,
        node_budgets: Vec<Watts>,
        cluster_budget: Watts,
        enforce: bool,
        min_of: Vec<Watts>,
        max_of: Vec<Watts>,
    ) -> Self {
        assert_eq!(initial_caps.len(), node_of.len());
        assert_eq!(initial_caps.len(), min_of.len());
        assert_eq!(initial_caps.len(), max_of.len());
        assert!(node_of.iter().all(|&n| n < node_budgets.len()));
        let mut node_members: Vec<Vec<usize>> = vec![Vec::new(); node_budgets.len()];
        for (i, &nd) in node_of.iter().enumerate() {
            node_members[nd].push(i);
        }
        let n_nodes = node_budgets.len();
        PowerManager {
            caps: initial_caps.iter().map(|&w| CapState::new(w)).collect(),
            offline: vec![false; initial_caps.len()],
            node_of,
            node_budgets,
            cluster_budget,
            pending: Vec::new(),
            profile: RampProfile::default(),
            enforce,
            min_of,
            rated_max: max_of.clone(),
            max_of,
            // No pending, nobody offline: committed == initial targets.
            committed_of: initial_caps.to_vec(),
            node_members,
            cluster_sum: Cell::new(0.0),
            cluster_dirty: Cell::new(true),
            node_sum: (0..n_nodes).map(|_| Cell::new(0.0)).collect(),
            node_dirty: (0..n_nodes).map(|_| Cell::new(true)).collect(),
        }
    }

    pub fn n_gpus(&self) -> usize {
        self.caps.len()
    }

    pub fn n_nodes(&self) -> usize {
        self.node_budgets.len()
    }

    /// Cluster-wide budget (W).
    pub fn budget(&self) -> Watts {
        self.cluster_budget
    }

    pub fn node_budget(&self, node: usize) -> Watts {
        self.node_budgets[node]
    }

    pub fn node_of(&self, gpu: GpuId) -> usize {
        self.node_of[gpu.0]
    }

    /// Cap floor of one GPU (W).
    pub fn min_of(&self, gpu: GpuId) -> Watts {
        self.min_of[gpu.0]
    }

    /// Cap ceiling of one GPU (W).
    pub fn max_of(&self, gpu: GpuId) -> Watts {
        self.max_of[gpu.0]
    }

    pub fn profile(&self) -> &RampProfile {
        &self.profile
    }

    /// Target cap of one GPU (what was last requested).
    pub fn target(&self, gpu: GpuId) -> Watts {
        self.caps[gpu.0].target()
    }

    /// Effective (firmware-enforced) cap right now, mid-transient.
    pub fn effective(&self, gpu: GpuId, now: Micros) -> Watts {
        self.caps[gpu.0].effective(now)
    }

    /// Recompute one GPU's committed cap (target plus any pending raise;
    /// a failed GPU draws nothing and counts for nothing) after a
    /// mutation, dirtying the affected sums only when the value moved.
    fn refresh_committed(&mut self, i: usize) {
        let mut c = if self.offline[i] { 0.0 } else { self.caps[i].target() };
        for p in &self.pending {
            if p.gpu.0 == i {
                c = c.max(p.cap);
            }
        }
        if c.to_bits() != self.committed_of[i].to_bits() {
            self.committed_of[i] = c;
            self.cluster_dirty.set(true);
            self.node_dirty[self.node_of[i]].set(true);
        }
    }

    /// Refold the whole committed view in one pass — for bulk rewrites
    /// (uniform redistribution, budget sheds) where per-GPU refreshes
    /// would rescan `pending` once per GPU.
    fn rebuild_committed(&mut self) {
        for i in 0..self.caps.len() {
            self.committed_of[i] =
                if self.offline[i] { 0.0 } else { self.caps[i].target() };
        }
        for p in &self.pending {
            let c = &mut self.committed_of[p.gpu.0];
            *c = c.max(p.cap);
        }
        self.cluster_dirty.set(true);
        for d in &self.node_dirty {
            d.set(true);
        }
    }

    /// Sum of target caps plus any pending raises (the committed power).
    /// O(1) between mutations; a dirty cache refolds `committed_of` in
    /// GPU-id order — the summation order this total has always used, so
    /// the result is bit-identical to the historical per-call rebuild.
    pub fn committed_total(&self) -> Watts {
        if self.cluster_dirty.get() {
            self.cluster_sum.set(self.committed_of.iter().sum());
            self.cluster_dirty.set(false);
        }
        self.cluster_sum.get()
    }

    /// Committed power of one node (cached like `committed_total`; the
    /// refold runs over the node's members in ascending id order).
    pub fn committed_node_total(&self, node: usize) -> Watts {
        if self.node_dirty[node].get() {
            let s: Watts = self.node_members[node]
                .iter()
                .map(|&i| self.committed_of[i])
                .sum();
            self.node_sum[node].set(s);
            self.node_dirty[node].set(false);
        }
        self.node_sum[node].get()
    }

    fn check_limits(&self, gpu: GpuId, cap: Watts) -> Result<(), PowerError> {
        let (min, max) = (self.min_of[gpu.0], self.max_of[gpu.0]);
        if cap < min - 1e-9 || cap > max + 1e-9 {
            return Err(PowerError::OutOfLimits { cap, min, max });
        }
        Ok(())
    }

    /// Immediately retarget one GPU's cap (checked against both budget
    /// levels).
    pub fn set_cap(&mut self, now: Micros, gpu: GpuId, cap: Watts) -> Result<Micros, PowerError> {
        self.check_limits(gpu, cap)?;
        if self.enforce {
            let delta = (cap - self.caps[gpu.0].target()).max(0.0);
            if delta > 0.0 {
                let total = self.committed_total() + delta;
                if total > self.cluster_budget + 1e-6 {
                    return Err(PowerError::BudgetExceeded {
                        total,
                        budget: self.cluster_budget,
                    });
                }
                let node = self.node_of[gpu.0];
                let node_total = self.committed_node_total(node) + delta;
                if node_total > self.node_budgets[node] + 1e-6 {
                    return Err(PowerError::NodeBudgetExceeded {
                        node,
                        total: node_total,
                        budget: self.node_budgets[node],
                    });
                }
            }
        }
        let d = self.caps[gpu.0].set_target(now, cap, &self.profile);
        self.refresh_committed(gpu.0);
        Ok(d)
    }

    /// Move `total_w` watts from `sources` to `sinks` (split evenly inside
    /// each pool, clamped to limits and to both budget levels). Sources
    /// lower now; sinks raise after every source's settle deadline.
    /// Returns what actually moved — the clamps can reduce it (the
    /// controller's POWERLIMITSREACHED signal).
    pub fn move_power(
        &mut self,
        now: Micros,
        sources: &[GpuId],
        sinks: &[GpuId],
        total_w: Watts,
        sink_ceiling: Watts,
    ) -> Result<PowerMove, PowerError> {
        self.move_power_impl(now, sources, sinks, None, None, total_w, sink_ceiling)
    }

    /// Marginal-weighted variant for heterogeneous fleets: `src_weights`
    /// skews how much each source gives up (flatter marginal
    /// tokens/s-per-watt curve ⇒ larger weight ⇒ cheaper donor) and
    /// `sink_weights` skews how the moved watts land (steeper curve ⇒
    /// larger weight ⇒ more watts). Uniform weights reduce bit-exactly
    /// to [`PowerManager::move_power`].
    #[allow(clippy::too_many_arguments)]
    pub fn move_power_weighted(
        &mut self,
        now: Micros,
        sources: &[GpuId],
        sinks: &[GpuId],
        src_weights: &[f64],
        sink_weights: &[f64],
        total_w: Watts,
        sink_ceiling: Watts,
    ) -> Result<PowerMove, PowerError> {
        assert_eq!(sources.len(), src_weights.len());
        assert_eq!(sinks.len(), sink_weights.len());
        self.move_power_impl(
            now,
            sources,
            sinks,
            Some(src_weights),
            Some(sink_weights),
            total_w,
            sink_ceiling,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn move_power_impl(
        &mut self,
        now: Micros,
        sources: &[GpuId],
        sinks: &[GpuId],
        src_weights: Option<&[f64]>,
        sink_weights: Option<&[f64]>,
        total_w: Watts,
        sink_ceiling: Watts,
    ) -> Result<PowerMove, PowerError> {
        if sources.is_empty() {
            return Err(PowerError::EmptyPool("source"));
        }
        if sinks.is_empty() {
            return Err(PowerError::EmptyPool("sink"));
        }
        // A pending raise on a source would land *after* we lower it and
        // overshoot the budget: cancel source-side pending raises first.
        self.pending.retain(|p| !sources.contains(&p.gpu));
        for &g in sources {
            self.refresh_committed(g.0);
        }
        // Sink room must account for raises already committed to them.
        let committed_cap = |mgr: &Self, g: GpuId| {
            let mut c = mgr.caps[g.0].target();
            for p in &mgr.pending {
                if p.gpu == g {
                    c = c.max(p.cap);
                }
            }
            c
        };
        // How much does each source owe? Uniform split by default; with
        // weights, donor i owes total_w * w_i / Σw.
        let wanted: Vec<Watts> = match src_weights {
            None => {
                let per_source = total_w / sources.len() as f64;
                vec![per_source; sources.len()]
            }
            Some(ws) => {
                let sum: f64 = ws.iter().sum();
                if sum <= 0.0 {
                    let per_source = total_w / sources.len() as f64;
                    vec![per_source; sources.len()]
                } else {
                    ws.iter().map(|w| (total_w * w) / sum).collect()
                }
            }
        };
        // How much can each side actually absorb?
        let mut takeable = 0.0;
        for (&g, &want) in sources.iter().zip(&wanted) {
            let cur = self.caps[g.0].target();
            let new = (cur - want).max(self.min_of[g.0]);
            takeable += cur - new;
        }
        // Per-sink ceiling: the requested pool ceiling intersected with
        // each sink's own SKU envelope.
        let ceiling_of = |mgr: &Self, g: GpuId| sink_ceiling.min(mgr.max_of[g.0]);
        let mut givable = 0.0;
        for &g in sinks {
            givable += (ceiling_of(self, g) - committed_cap(self, g)).max(0.0);
        }
        let moved = takeable.min(givable);
        if moved < 1.0 {
            // Nothing meaningful can move; report zero-move.
            return Ok(PowerMove {
                lowered: Vec::new(),
                raised: Vec::new(),
                effective_at: now,
            });
        }
        // Scale the lowers down if sinks can't absorb everything.
        let scale = moved / takeable;
        let mut settle_deadline = now;
        // (gpu, new target, watts given up) — the third field drives the
        // rollback below when budget clamps strand part of the move.
        let mut lowered_full: Vec<(GpuId, Watts, Watts)> = Vec::new();
        for (&g, &want) in sources.iter().zip(&wanted) {
            let cur = self.caps[g.0].target();
            let reduce = (cur - ((cur - want).max(self.min_of[g.0]))) * scale;
            let new = cur - reduce;
            let d = self.caps[g.0].set_target(now, new, &self.profile);
            self.refresh_committed(g.0);
            settle_deadline = settle_deadline.max(d);
            lowered_full.push((g, new, reduce));
        }
        // Queue the raises for after the sources settle, clamped by the
        // sink's cap room and by whatever node/cluster headroom is left
        // now that the lowers are committed. With weights, a sink's
        // share scales with weight × room instead of room alone (but
        // never exceeds its actual cap room).
        let actual_room: Vec<Watts> = sinks
            .iter()
            .map(|&g| (ceiling_of(self, g) - committed_cap(self, g)).max(0.0))
            .collect();
        let per_sink_room: Vec<Watts> = actual_room
            .iter()
            .enumerate()
            .map(|(i, &room)| room * sink_weights.map_or(1.0, |ws| ws[i].max(0.0)))
            .collect();
        let room_total: f64 = per_sink_room.iter().sum();
        let mut node_room: Vec<Watts> = (0..self.node_budgets.len())
            .map(|nd| {
                if self.enforce {
                    (self.node_budgets[nd] - self.committed_node_total(nd)).max(0.0)
                } else {
                    f64::INFINITY
                }
            })
            .collect();
        let mut cluster_room = if self.enforce {
            (self.cluster_budget - self.committed_total()).max(0.0)
        } else {
            f64::INFINITY
        };
        let mut raised = Vec::new();
        let mut granted_total = 0.0;
        for ((&g, &room), &cap_room) in sinks.iter().zip(&per_sink_room).zip(&actual_room) {
            if room <= 0.0 {
                continue;
            }
            let mut share = moved * room / room_total;
            if sink_weights.is_some() {
                // A heavily-weighted sink's proportional share can exceed
                // what its own cap envelope absorbs; spill is handed back
                // to the sources by the stranded-watts rollback below.
                share = share.min(cap_room);
            }
            let nd = self.node_of[g.0];
            let grant = share.min(node_room[nd]).min(cluster_room);
            if grant <= 0.0 {
                continue;
            }
            node_room[nd] -= grant;
            cluster_room -= grant;
            granted_total += grant;
            let cap = committed_cap(self, g) + grant;
            self.pending.push(PendingRaise {
                gpu: g,
                cap,
                at: settle_deadline,
            });
            self.refresh_committed(g.0);
            raised.push((g, cap));
        }
        // Budget clamps (a full sink node, or the cluster cap) can strand
        // part of the move: the sources were lowered by `moved` but only
        // `granted_total` was re-granted. Hand the stranded watts back to
        // the sources — otherwise every blocked MovePower retry ratchets
        // the donor pool toward the floor while the sinks gain nothing.
        // Restores are clamped by the same headrooms, so grants that
        // consumed a shared node's freed room stay legal.
        let excess = moved - granted_total;
        if excess > 1e-9 {
            for i in 0..lowered_full.len() {
                let (g, _, gave) = lowered_full[i];
                let mut restore = excess * gave / moved;
                if self.enforce {
                    let nd = self.node_of[g.0];
                    let node_head =
                        (self.node_budgets[nd] - self.committed_node_total(nd)).max(0.0);
                    let cluster_head =
                        (self.cluster_budget - self.committed_total()).max(0.0);
                    restore = restore.min(node_head).min(cluster_head);
                }
                if restore <= 0.0 {
                    continue;
                }
                let cap = (self.caps[g.0].target() + restore).min(self.max_of[g.0]);
                let d = self.caps[g.0].set_target(now, cap, &self.profile);
                self.refresh_committed(g.0);
                settle_deadline = settle_deadline.max(d);
                lowered_full[i].1 = cap;
            }
        }
        let lowered = lowered_full.into_iter().map(|(g, new, _)| (g, new)).collect();
        Ok(PowerMove {
            lowered,
            raised,
            effective_at: settle_deadline,
        })
    }

    /// Set every GPU to its node's uniform share (paper:
    /// DISTRIBUTEUNIFORMPOWER after a role move), additionally limited by
    /// the cluster-wide per-GPU share when the cluster budget binds.
    /// Lower-first/raise-later sequencing applies here too. Offline
    /// (failed) GPUs are skipped and do not dilute the shares.
    pub fn distribute_uniform(&mut self, now: Micros) -> Micros {
        let online = self.offline.iter().filter(|&&off| !off).count().max(1);
        let per_gpu_cluster = self.cluster_budget / online as f64;
        // Per-node online counts in one sweep (a per-GPU rescan made this
        // quadratic on kilo-node fleets).
        let mut node_online = vec![0usize; self.node_budgets.len()];
        for (i, &nd) in self.node_of.iter().enumerate() {
            if !self.offline[i] {
                node_online[nd] += 1;
            }
        }
        let uniform_of: Vec<Watts> = (0..self.caps.len())
            .map(|i| {
                let nd = self.node_of[i];
                (self.node_budgets[nd] / node_online[nd] as f64)
                    .min(per_gpu_cluster)
                    .clamp(self.min_of[i], self.max_of[i])
            })
            .collect();
        self.pending.clear();
        let mut settle = now;
        // Phase 1: all lowers immediately.
        for i in 0..self.caps.len() {
            if !self.offline[i] && self.caps[i].target() > uniform_of[i] {
                let d = self.caps[i].set_target(now, uniform_of[i], &self.profile);
                settle = settle.max(d);
            }
        }
        // Phase 2: raises queued after the lowers settle.
        for i in 0..self.caps.len() {
            if !self.offline[i] && self.caps[i].target() < uniform_of[i] {
                self.pending.push(PendingRaise {
                    gpu: GpuId(i),
                    cap: uniform_of[i],
                    at: settle,
                });
            }
        }
        self.rebuild_committed();
        settle
    }

    // ------------------------------------------------------------------
    // environment disturbances (DESIGN.md §12)
    // ------------------------------------------------------------------

    /// Step the cluster-wide budget (grid curtailment). A decrease sheds
    /// committed power immediately — pending raises planned under the
    /// old budget are dropped, then every online GPU's cap is lowered in
    /// proportion to its slack above its floor until the new budget
    /// holds. An increase frees headroom but raises nothing by itself.
    /// Returns the settle deadline of the lowers.
    pub fn set_cluster_budget(&mut self, now: Micros, budget: Watts) -> Micros {
        self.cluster_budget = budget;
        self.shed_into_budgets(now)
    }

    /// Step one node's budget; same shedding semantics.
    pub fn set_node_budget(&mut self, now: Micros, node: usize, budget: Watts) -> Micros {
        self.node_budgets[node] = budget;
        self.shed_into_budgets(now)
    }

    /// Re-establish both budget levels after a step: node pools first,
    /// then the cluster pool.
    fn shed_into_budgets(&mut self, now: Micros) -> Micros {
        if !self.enforce {
            return now;
        }
        let mut settle = now;
        for nd in 0..self.node_budgets.len() {
            settle = settle.max(self.shed_pool(now, Some(nd)));
        }
        settle.max(self.shed_pool(now, None))
    }

    /// Shed the pool (`Some(node)` or the whole cluster) down to its
    /// budget: cancel the pool's pending raises, then lower each online
    /// member proportionally to its slack above its floor. GPUs already
    /// at their floor cannot shed further (an infeasible budget is
    /// reported by `budget_ok`, exactly like an infeasible construction).
    fn shed_pool(&mut self, now: Micros, node: Option<usize>) -> Micros {
        let budget = match node {
            Some(nd) => self.node_budgets[nd],
            None => self.cluster_budget,
        };
        // Cached totals make the common case — a pool already within its
        // stepped budget — O(1) instead of a full fleet rescan.
        let committed = match node {
            Some(nd) => self.committed_node_total(nd),
            None => self.committed_total(),
        };
        if committed <= budget + 1e-9 {
            return now;
        }
        // Over budget: raises planned under the old budget are void.
        let pending = std::mem::take(&mut self.pending);
        self.pending = pending
            .into_iter()
            .filter(|p| {
                let i = p.gpu.0;
                self.offline[i] || node.map_or(false, |nd| self.node_of[i] != nd)
            })
            .collect();
        // A node-scoped shed walks only that node's members (ascending
        // ids, same order as before) instead of the whole fleet.
        let pool_len = match node {
            Some(nd) => self.node_members[nd].len(),
            None => self.caps.len(),
        };
        let member = |mgr: &Self, k: usize| match node {
            Some(nd) => mgr.node_members[nd][k],
            None => k,
        };
        let mut total = 0.0;
        let mut slack = 0.0;
        for k in 0..pool_len {
            let i = member(self, k);
            if self.offline[i] {
                continue;
            }
            total += self.caps[i].target();
            slack += (self.caps[i].target() - self.min_of[i]).max(0.0);
        }
        let cut = (total - budget).min(slack);
        if cut <= 1e-9 || slack <= 0.0 {
            // The pending cancellation above still changed the books.
            self.rebuild_committed();
            return now;
        }
        let mut settle = now;
        for k in 0..pool_len {
            let i = member(self, k);
            if self.offline[i] {
                continue;
            }
            let s = (self.caps[i].target() - self.min_of[i]).max(0.0);
            if s <= 0.0 {
                continue;
            }
            let new = self.caps[i].target() - cut * s / slack;
            let d = self.caps[i].set_target(now, new, &self.profile);
            settle = settle.max(d);
        }
        self.rebuild_committed();
        settle
    }

    /// Thermal derating: lower one GPU's cap ceiling to `ceiling`
    /// (clamped into `[floor, rated max]`), clamping its target and any
    /// pending raise down with it. Returns the settle deadline of the
    /// lower (or `now` when the cap already fits).
    pub fn derate_gpu(&mut self, now: Micros, gpu: GpuId, ceiling: Watts) -> Micros {
        let i = gpu.0;
        let ceil = ceiling.clamp(self.min_of[i], self.rated_max[i]);
        self.max_of[i] = ceil;
        for p in &mut self.pending {
            if p.gpu == gpu {
                p.cap = p.cap.min(ceil);
            }
        }
        let d = if self.caps[i].target() > ceil {
            self.caps[i].set_target(now, ceil, &self.profile)
        } else {
            now
        };
        self.refresh_committed(i);
        d
    }

    /// Thermal derating ends: the rated ceiling returns. The cap itself
    /// stays where the derate left it until a policy raises it.
    pub fn restore_gpu(&mut self, now: Micros, gpu: GpuId) -> Micros {
        self.max_of[gpu.0] = self.rated_max[gpu.0];
        now
    }

    /// Rated (undegraded) ceiling of one GPU.
    pub fn rated_max_of(&self, gpu: GpuId) -> Watts {
        self.rated_max[gpu.0]
    }

    /// Mark a GPU failed/recovered. Failed GPUs drop out of every
    /// budget sum and the uniform split, and their pending raises are
    /// cancelled. A recovering GPU rejoins at its cap floor — callers
    /// redistribute (lower-first) immediately after, so the floor is the
    /// only power it can claim unilaterally.
    pub fn set_offline(&mut self, now: Micros, gpu: GpuId, offline: bool) {
        let i = gpu.0;
        if self.offline[i] == offline {
            return;
        }
        self.offline[i] = offline;
        if offline {
            self.pending.retain(|p| p.gpu != gpu);
        } else {
            self.caps[i].set_target(now, self.min_of[i], &self.profile);
        }
        self.refresh_committed(i);
    }

    /// Is this GPU currently failed?
    pub fn is_offline(&self, gpu: GpuId) -> bool {
        self.offline[gpu.0]
    }

    /// Apply any pending raises that are due; returns them for logging.
    pub fn poll(&mut self, now: Micros) -> Vec<(GpuId, Watts)> {
        let mut applied = Vec::new();
        let mut due = Vec::new();
        let pending = std::mem::take(&mut self.pending);
        for p in pending {
            if p.at <= now {
                due.push(p);
            } else {
                self.pending.push(p);
            }
        }
        // Split first so each refresh below sees the final pending list;
        // poll does no budget checks, so applying after the split is
        // order-equivalent. A poll with nothing due touches no GPU.
        for p in due {
            // Raise within limits; budget holds by construction.
            let cap = p.cap.clamp(self.min_of[p.gpu.0], self.max_of[p.gpu.0]);
            self.caps[p.gpu.0].set_target(now, cap, &self.profile);
            self.refresh_committed(p.gpu.0);
            applied.push((p.gpu, cap));
        }
        applied
    }

    /// Earliest pending-raise deadline (so the DES can schedule a poll).
    pub fn next_pending_at(&self) -> Option<Micros> {
        self.pending.iter().map(|p| p.at).min()
    }

    /// Budget invariant on committed power at both levels
    /// (property-tested).
    pub fn budget_ok(&self) -> bool {
        if !self.enforce {
            return true;
        }
        if self.committed_total() > self.cluster_budget + 1e-6 {
            return false;
        }
        (0..self.node_budgets.len())
            .all(|nd| self.committed_node_total(nd) <= self.node_budgets[nd] + 1e-6)
    }

    /// All target caps (Fig 9a trace).
    pub fn targets(&self) -> Vec<Watts> {
        self.caps
            .iter()
            .zip(&self.offline)
            .map(|(c, &off)| if off { 0.0 } else { c.target() })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SECOND;

    fn manager_4p4d() -> PowerManager {
        PowerManager::new(&[600.0; 8], 4800.0, true, 400.0, 750.0)
    }

    /// Two 4-GPU nodes, 2400 W each, with a cluster cap that may bind.
    fn manager_two_nodes(cluster_budget: Watts) -> PowerManager {
        PowerManager::with_nodes(
            &[500.0; 8],
            vec![0, 0, 0, 0, 1, 1, 1, 1],
            vec![2400.0, 2400.0],
            cluster_budget,
            true,
            400.0,
            750.0,
        )
    }

    #[test]
    fn set_cap_respects_budget() {
        let mut m = manager_4p4d();
        // Raising one GPU to 750 would commit 4950 W.
        let err = m.set_cap(0, GpuId(0), 750.0).unwrap_err();
        assert!(matches!(err, PowerError::BudgetExceeded { .. }));
        // Lowering is always fine.
        m.set_cap(0, GpuId(0), 450.0).unwrap();
        // Now there's headroom for a raise elsewhere.
        m.set_cap(1 * SECOND, GpuId(1), 750.0).unwrap();
        assert!(m.budget_ok());
    }

    #[test]
    fn set_cap_respects_limits() {
        let mut m = manager_4p4d();
        assert!(m.set_cap(0, GpuId(0), 300.0).is_err());
        assert!(m.set_cap(0, GpuId(0), 800.0).is_err());
    }

    #[test]
    fn move_power_sequences_source_before_sink() {
        let mut m = manager_4p4d();
        let sources: Vec<GpuId> = (4..8).map(GpuId).collect();
        let sinks: Vec<GpuId> = (0..4).map(GpuId).collect();
        let mv = m
            .move_power(0, &sources, &sinks, 200.0, 750.0)
            .unwrap();
        assert_eq!(mv.lowered.len(), 4);
        assert!(mv.effective_at > 0, "raises must wait for settle");
        // Sinks unchanged until poll after effective_at.
        assert_eq!(m.target(GpuId(0)), 600.0);
        assert!(m.poll(mv.effective_at - 1).is_empty());
        let applied = m.poll(mv.effective_at);
        assert_eq!(applied.len(), 4);
        assert!((m.target(GpuId(0)) - 650.0).abs() < 1e-6);
        assert!((m.target(GpuId(4)) - 550.0).abs() < 1e-6);
        assert!(m.budget_ok());
    }

    #[test]
    fn move_power_clamps_at_min() {
        let mut m = PowerManager::new(&[420.0, 420.0, 600.0, 600.0], 4800.0, true, 400.0, 750.0);
        let mv = m
            .move_power(0, &[GpuId(0), GpuId(1)], &[GpuId(2), GpuId(3)], 200.0, 750.0)
            .unwrap();
        // Each source can only give 20 W.
        let total_lowered: f64 = mv
            .lowered
            .iter()
            .map(|&(g, new)| 420.0 - new.max(400.0) + (g.0 as f64) * 0.0)
            .sum();
        assert!(total_lowered <= 40.0 + 1e-6, "lowered {total_lowered}");
        m.poll(mv.effective_at);
        assert!(m.budget_ok());
        for i in 0..2 {
            assert!(m.target(GpuId(i)) >= 400.0 - 1e-9);
        }
    }

    #[test]
    fn move_power_respects_sink_ceiling() {
        let mut m = manager_4p4d();
        let mv = m
            .move_power(0, &[GpuId(4)], &[GpuId(0)], 200.0, 650.0)
            .unwrap();
        m.poll(mv.effective_at);
        assert!(m.target(GpuId(0)) <= 650.0 + 1e-9);
    }

    #[test]
    fn move_power_zero_when_sinks_full() {
        let mut m = PowerManager::new(&[750.0, 400.0], 1150.0, true, 400.0, 750.0);
        let mv = m
            .move_power(0, &[GpuId(1)], &[GpuId(0)], 100.0, 750.0)
            .unwrap();
        assert!(mv.raised.is_empty(), "sink already at max: {mv:?}");
        // Source untouched by a zero-move.
        assert_eq!(m.target(GpuId(1)), 400.0);
    }

    #[test]
    fn move_power_zero_when_sources_at_floor() {
        // The saturated-pool case in the donor direction: every source
        // already sits at MIN_P, so nothing can be taken.
        let mut m = PowerManager::new(&[400.0, 400.0, 500.0, 500.0], 1800.0, true, 400.0, 750.0);
        let mv = m
            .move_power(0, &[GpuId(0), GpuId(1)], &[GpuId(2), GpuId(3)], 100.0, 750.0)
            .unwrap();
        assert!(mv.lowered.is_empty() && mv.raised.is_empty(), "{mv:?}");
        assert_eq!(m.target(GpuId(0)), 400.0);
        assert_eq!(m.target(GpuId(2)), 500.0);
        assert!(m.budget_ok());
    }

    #[test]
    fn distribute_uniform_converges_to_budget_share() {
        let mut m = PowerManager::new(
            &[750.0, 750.0, 750.0, 750.0, 450.0, 450.0, 450.0, 450.0],
            4800.0,
            true,
            400.0,
            750.0,
        );
        let settle = m.distribute_uniform(0);
        m.poll(settle);
        for i in 0..8 {
            assert!((m.target(GpuId(i)) - 600.0).abs() < 1e-6);
        }
        assert!(m.budget_ok());
    }

    #[test]
    fn committed_total_counts_pending() {
        let mut m = manager_4p4d();
        let mv = m
            .move_power(0, &[GpuId(4)], &[GpuId(0)], 100.0, 750.0)
            .unwrap();
        // Before the raise lands, committed must already include it so a
        // concurrent set_cap cannot double-spend the headroom.
        assert!(m.committed_total() >= 4800.0 - 1e-6);
        let err = m.set_cap(1, GpuId(1), 700.0);
        assert!(err.is_err(), "double-spend must be rejected");
        m.poll(mv.effective_at);
        assert!(m.budget_ok());
    }

    #[test]
    fn unenforced_budget_allows_oversubscription() {
        let mut m = PowerManager::new(&[750.0; 8], 4800.0, false, 400.0, 750.0);
        // 6000 W committed but enforce=false (Fig 3's uncapped run).
        assert!(m.committed_total() > m.budget());
        assert!(m.budget_ok());
        m.set_cap(0, GpuId(0), 750.0).unwrap();
    }

    #[test]
    fn next_pending_at_reports_earliest() {
        let mut m = manager_4p4d();
        assert!(m.next_pending_at().is_none());
        let mv = m
            .move_power(0, &[GpuId(4)], &[GpuId(0)], 50.0, 750.0)
            .unwrap();
        assert_eq!(m.next_pending_at(), Some(mv.effective_at));
    }

    // ------------------------------------------------------------------
    // hierarchical-budget edge cases
    // ------------------------------------------------------------------

    #[test]
    fn node_budget_below_cap_floor_rejects_every_raise() {
        // 4 GPUs at the 400 W floor under a 1500 W node budget: already
        // oversubscribed (1600 committed). The manager must flag it and
        // refuse to make it worse.
        let mut m = PowerManager::new(&[400.0; 4], 1500.0, true, 400.0, 750.0);
        assert!(!m.budget_ok(), "floor above budget must be flagged");
        assert!(m.set_cap(0, GpuId(0), 450.0).is_err());
        // distribute_uniform clamps to the floor but cannot repair it.
        let settle = m.distribute_uniform(0);
        m.poll(settle);
        for i in 0..4 {
            assert_eq!(m.target(GpuId(i)), 400.0);
        }
        assert!(!m.budget_ok());
    }

    #[test]
    fn per_node_budget_binds_inside_cluster_headroom() {
        // Cluster has room (4800 total vs 4000 committed) but node 0 is
        // full: a raise on node 0 must fail citing the node budget.
        let mut m = PowerManager::with_nodes(
            &[600.0, 600.0, 600.0, 600.0, 400.0, 400.0, 400.0, 400.0],
            vec![0, 0, 0, 0, 1, 1, 1, 1],
            vec![2400.0, 2400.0],
            4800.0,
            true,
            400.0,
            750.0,
        );
        let err = m.set_cap(0, GpuId(0), 650.0).unwrap_err();
        assert!(matches!(err, PowerError::NodeBudgetExceeded { node: 0, .. }), "{err}");
        // The same watts fit on node 1.
        m.set_cap(0, GpuId(4), 450.0).unwrap();
        assert!(m.budget_ok());
    }

    #[test]
    fn cluster_cap_binds_before_any_node_cap() {
        // Node budgets allow 2400 W each (4800 total) but the facility
        // grants only 4100 W: raises stop at the cluster line even though
        // both nodes individually have headroom.
        let mut m = manager_two_nodes(4100.0);
        assert_eq!(m.committed_total(), 4000.0);
        // 150 W raise would fit node 0 (2000 -> 2150 < 2400) but not the
        // cluster (4000 -> 4150 > 4100).
        let err = m.set_cap(0, GpuId(0), 650.0).unwrap_err();
        assert!(matches!(err, PowerError::BudgetExceeded { .. }), "{err}");
        // A 100 W raise exactly consumes the cluster headroom.
        m.set_cap(0, GpuId(0), 600.0).unwrap();
        assert!(m.budget_ok());
        assert!((m.committed_total() - 4100.0).abs() < 1e-6);
        // No further raise anywhere, on either node.
        assert!(m.set_cap(1, GpuId(4), 450.0).is_err());
    }

    #[test]
    fn move_power_respects_cluster_cap_across_nodes() {
        // Moving power from node 0 sources to node 1 sinks keeps both
        // node totals and the cluster total legal at every step.
        let mut m = manager_two_nodes(4100.0);
        let mv = m
            .move_power(0, &[GpuId(0), GpuId(1)], &[GpuId(4), GpuId(5)], 150.0, 750.0)
            .unwrap();
        assert!(!mv.lowered.is_empty());
        m.poll(mv.effective_at);
        assert!(m.budget_ok(), "cluster/node budgets violated after cross-node move");
        assert!(m.committed_node_total(0) <= 2400.0 + 1e-6);
        assert!(m.committed_node_total(1) <= 2400.0 + 1e-6);
        assert!(m.committed_total() <= 4100.0 + 1e-6);
    }

    #[test]
    fn move_power_against_saturated_sink_node() {
        // Node 1 is at its node budget: raises on it are capped at zero
        // even though the sinks' per-GPU cap room says otherwise.
        let mut m = PowerManager::with_nodes(
            &[450.0, 450.0, 600.0, 600.0],
            vec![0, 0, 1, 1],
            vec![1800.0, 1200.0],
            3000.0,
            true,
            400.0,
            750.0,
        );
        assert_eq!(m.committed_node_total(1), 1200.0);
        let mv = m
            .move_power(0, &[GpuId(0), GpuId(1)], &[GpuId(2), GpuId(3)], 100.0, 750.0)
            .unwrap();
        m.poll(mv.effective_at);
        assert!(m.committed_node_total(1) <= 1200.0 + 1e-6, "node 1 overfilled");
        assert!(m.budget_ok());
        // The stranded watts must be handed back to the sources, not
        // destroyed — otherwise blocked retries ratchet donors to the floor.
        assert!(mv.raised.is_empty(), "sink node full: {mv:?}");
        for i in 0..2 {
            assert!(
                (m.target(GpuId(i)) - 450.0).abs() < 1e-6,
                "source {i} not restored: {}",
                m.target(GpuId(i))
            );
        }
    }

    // ------------------------------------------------------------------
    // per-GPU (SKU-envelope) limits + weighted moves
    // ------------------------------------------------------------------

    /// 2 big GPUs ([400, 750]) + 2 small GPUs ([250, 400]) on one node.
    fn manager_mixed_envelopes() -> PowerManager {
        PowerManager::with_limits(
            &[600.0, 600.0, 400.0, 400.0],
            vec![0; 4],
            vec![2400.0],
            2400.0,
            true,
            vec![400.0, 400.0, 250.0, 250.0],
            vec![750.0, 750.0, 400.0, 400.0],
        )
    }

    #[test]
    fn per_gpu_limits_bound_set_cap() {
        let mut m = manager_mixed_envelopes();
        // Raising a small GPU above its 400 W envelope fails even though
        // the uniform MAX would allow it.
        let err = m.set_cap(0, GpuId(2), 450.0).unwrap_err();
        assert!(matches!(err, PowerError::OutOfLimits { max, .. } if max == 400.0), "{err}");
        // Its floor is lower than the big GPUs' floor.
        m.set_cap(0, GpuId(2), 300.0).unwrap();
        assert!(m.set_cap(0, GpuId(0), 300.0).is_err());
        assert_eq!(m.min_of(GpuId(0)), 400.0);
        assert_eq!(m.max_of(GpuId(2)), 400.0);
    }

    #[test]
    fn move_power_respects_sku_ceiling_of_each_sink() {
        // Sinks: one big (room up to 750) and one small pinned at 400.
        let mut m = manager_mixed_envelopes();
        m.set_cap(0, GpuId(0), 500.0).unwrap();
        let mv = m
            .move_power(SECOND, &[GpuId(1)], &[GpuId(0), GpuId(3)], 200.0, 750.0)
            .unwrap();
        m.poll(mv.effective_at);
        assert!(m.target(GpuId(0)) <= 750.0 + 1e-9);
        assert!(m.target(GpuId(3)) <= 400.0 + 1e-9, "small sink must stay in envelope");
        assert!(m.budget_ok());
    }

    #[test]
    fn move_power_respects_sku_floor_of_each_source() {
        let mut m = manager_mixed_envelopes();
        // Small sources can only go to 250; big source to 400.
        let mv = m
            .move_power(0, &[GpuId(1), GpuId(2)], &[GpuId(0)], 600.0, 750.0)
            .unwrap();
        m.poll(mv.effective_at);
        assert!(m.target(GpuId(1)) >= 400.0 - 1e-9);
        assert!(m.target(GpuId(2)) >= 250.0 - 1e-9);
        assert!(m.budget_ok());
    }

    #[test]
    fn weighted_move_skews_toward_heavy_sink() {
        let mut m = PowerManager::new(&[600.0, 450.0, 450.0, 400.0], 4800.0, true, 400.0, 750.0);
        // Sink 1 gets 3x the weight of sink 2: with equal room it should
        // receive ~3x the watts.
        let mv = m
            .move_power_weighted(
                0,
                &[GpuId(0)],
                &[GpuId(1), GpuId(2)],
                &[1.0],
                &[3.0, 1.0],
                120.0,
                750.0,
            )
            .unwrap();
        m.poll(mv.effective_at);
        let g1 = m.target(GpuId(1)) - 450.0;
        let g2 = m.target(GpuId(2)) - 450.0;
        assert!((g1 + g2 - 120.0).abs() < 1e-6, "all watts land: {g1} + {g2}");
        assert!((g1 / g2 - 3.0).abs() < 1e-6, "3:1 split, got {g1}:{g2}");
        assert!(m.budget_ok());
    }

    #[test]
    fn weighted_move_skews_donation_toward_heavy_source() {
        let mut m = PowerManager::new(&[600.0, 600.0, 400.0, 400.0], 4800.0, true, 400.0, 750.0);
        let mv = m
            .move_power_weighted(
                0,
                &[GpuId(0), GpuId(1)],
                &[GpuId(2), GpuId(3)],
                &[3.0, 1.0],
                &[1.0, 1.0],
                80.0,
                750.0,
            )
            .unwrap();
        m.poll(mv.effective_at);
        let d0 = 600.0 - m.target(GpuId(0));
        let d1 = 600.0 - m.target(GpuId(1));
        assert!((d0 / d1 - 3.0).abs() < 1e-6, "3:1 donation, got {d0}:{d1}");
        assert!(m.budget_ok());
    }

    #[test]
    fn uniform_weights_match_unweighted_move_exactly() {
        let caps = [620.0, 580.0, 460.0, 440.0];
        let mut a = PowerManager::new(&caps, 4800.0, true, 400.0, 750.0);
        let mut b = PowerManager::new(&caps, 4800.0, true, 400.0, 750.0);
        let srcs = [GpuId(0), GpuId(1)];
        let sinks = [GpuId(2), GpuId(3)];
        let mv_a = a.move_power(0, &srcs, &sinks, 130.0, 650.0).unwrap();
        let mv_b = b
            .move_power_weighted(0, &srcs, &sinks, &[1.0, 1.0], &[1.0, 1.0], 130.0, 650.0)
            .unwrap();
        assert_eq!(mv_a, mv_b, "uniform weights must be bit-identical");
        a.poll(mv_a.effective_at);
        b.poll(mv_b.effective_at);
        for i in 0..4 {
            assert_eq!(a.target(GpuId(i)).to_bits(), b.target(GpuId(i)).to_bits());
        }
    }

    #[test]
    fn weighted_share_clamped_to_sink_cap_room() {
        // Sink 1 is nearly full (room 10 W) but heavily weighted: its
        // share clamps to the room and the spill returns to the source.
        let mut m = PowerManager::new(&[700.0, 740.0, 400.0, 400.0], 4800.0, true, 400.0, 750.0);
        let mv = m
            .move_power_weighted(
                0,
                &[GpuId(0)],
                &[GpuId(1), GpuId(2)],
                &[1.0],
                &[100.0, 1.0],
                200.0,
                750.0,
            )
            .unwrap();
        m.poll(mv.effective_at);
        assert!(m.target(GpuId(1)) <= 750.0 + 1e-9);
        assert!(m.budget_ok());
        // Whatever could not land was restored to the source.
        let given = 700.0 - m.target(GpuId(0));
        let landed = (m.target(GpuId(1)) - 740.0) + (m.target(GpuId(2)) - 400.0);
        assert!((given - landed).abs() < 1e-6, "given {given} vs landed {landed}");
    }

    #[test]
    fn distribute_uniform_clamps_to_sku_envelopes() {
        let mut m = manager_mixed_envelopes();
        // Uniform share would be 600 W; small GPUs clamp to 400.
        let settle = m.distribute_uniform(0);
        m.poll(settle);
        assert!((m.target(GpuId(0)) - 600.0).abs() < 1e-6);
        assert!((m.target(GpuId(2)) - 400.0).abs() < 1e-6);
        assert!(m.budget_ok());
    }

    // ------------------------------------------------------------------
    // environment disturbances: budget steps, derating, offline GPUs
    // ------------------------------------------------------------------

    #[test]
    fn cluster_budget_step_sheds_proportionally_above_floors() {
        let mut m = manager_4p4d();
        let settle = m.set_cluster_budget(SECOND, 4000.0);
        assert!(settle > SECOND, "lowers take settle time");
        // Uniform slack (8 x 200 W above floor) -> uniform 100 W shed.
        for i in 0..8 {
            assert!((m.target(GpuId(i)) - 500.0).abs() < 1e-6, "gpu {i}");
        }
        assert!((m.committed_total() - 4000.0).abs() < 1e-6);
        assert!(m.budget_ok());
        // Raises are now judged against the curtailed budget.
        assert!(m.set_cap(2 * SECOND, GpuId(0), 750.0).is_err());
        // Restoring the budget frees headroom but raises nothing.
        m.set_cluster_budget(3 * SECOND, 4800.0);
        assert_eq!(m.target(GpuId(0)), 500.0);
        assert!(m.budget_ok());
        m.set_cap(4 * SECOND, GpuId(0), 750.0).unwrap();
    }

    #[test]
    fn uneven_slack_sheds_in_proportion() {
        let mut m = PowerManager::new(&[700.0, 700.0, 450.0, 450.0], 4800.0, true, 400.0, 750.0);
        // Slack: 300, 300, 50, 50 (total 700). Shed 350 => halve each slack.
        m.set_cluster_budget(0, 1950.0);
        assert!((m.target(GpuId(0)) - 550.0).abs() < 1e-6);
        assert!((m.target(GpuId(2)) - 425.0).abs() < 1e-6);
        assert!(m.budget_ok());
    }

    #[test]
    fn budget_below_floor_clamps_at_floors_and_flags() {
        let mut m = manager_4p4d();
        m.set_cluster_budget(0, 3000.0); // floor is 8 x 400 = 3200
        for i in 0..8 {
            assert!((m.target(GpuId(i)) - 400.0).abs() < 1e-6, "gpu {i}");
        }
        assert!(!m.budget_ok(), "infeasible curtailment must be flagged");
    }

    #[test]
    fn node_budget_step_sheds_only_that_node() {
        let mut m = manager_two_nodes(4800.0); // 8 x 500 W, 2400 W/node
        m.set_node_budget(SECOND, 0, 1800.0);
        for i in 0..4 {
            assert!((m.target(GpuId(i)) - 450.0).abs() < 1e-6, "node-0 gpu {i}");
        }
        for i in 4..8 {
            assert_eq!(m.target(GpuId(i)), 500.0, "node 1 untouched");
        }
        assert!(m.budget_ok());
    }

    #[test]
    fn budget_step_cancels_pending_raises() {
        let mut m = manager_4p4d();
        let mv = m.move_power(0, &[GpuId(4)], &[GpuId(0)], 100.0, 750.0).unwrap();
        assert!(m.next_pending_at().is_some());
        m.set_cluster_budget(1, 4000.0);
        assert!(
            m.next_pending_at().is_none(),
            "raises planned under the old budget are void"
        );
        m.poll(mv.effective_at);
        assert!(m.budget_ok());
        assert!(m.committed_total() <= 4000.0 + 1e-6);
    }

    #[test]
    fn derate_clamps_target_and_pending_then_restore_lifts_only_ceiling() {
        let mut m = manager_4p4d();
        // Queue a raise on gpu0, then derate it below the queued cap.
        let mv = m.move_power(0, &[GpuId(4)], &[GpuId(0)], 100.0, 750.0).unwrap();
        let settle = m.derate_gpu(1, GpuId(0), 450.0);
        assert!(settle > 1);
        assert_eq!(m.max_of(GpuId(0)), 450.0);
        assert_eq!(m.rated_max_of(GpuId(0)), 750.0);
        assert!((m.target(GpuId(0)) - 450.0).abs() < 1e-6);
        m.poll(mv.effective_at);
        assert!(m.target(GpuId(0)) <= 450.0 + 1e-9, "pending raise clamped to derated ceiling");
        assert!(m.set_cap(SECOND, GpuId(0), 500.0).is_err());
        m.restore_gpu(2 * SECOND, GpuId(0));
        assert_eq!(m.max_of(GpuId(0)), 750.0);
        assert!(m.target(GpuId(0)) <= 450.0 + 1e-9, "restore lifts the ceiling, not the cap");
        m.set_cap(3 * SECOND, GpuId(0), 600.0).unwrap();
        // Requests below the floor clamp to the floor.
        m.derate_gpu(4 * SECOND, GpuId(1), 300.0);
        assert_eq!(m.max_of(GpuId(1)), 400.0);
        assert!(m.budget_ok());
    }

    #[test]
    fn offline_gpu_excluded_from_budget_and_uniform_split() {
        let mut m = manager_4p4d();
        m.set_offline(0, GpuId(7), true);
        assert!(m.is_offline(GpuId(7)));
        assert!((m.committed_total() - 7.0 * 600.0).abs() < 1e-6);
        assert_eq!(m.targets()[7], 0.0, "failed GPU provisions nothing");
        let settle = m.distribute_uniform(SECOND);
        m.poll(settle);
        for i in 0..7 {
            assert!(
                (m.target(GpuId(i)) - 4800.0 / 7.0).abs() < 1e-6,
                "freed budget spreads over the 7 online GPUs (gpu {i})"
            );
        }
        assert!(m.budget_ok());
        // Recovery: rejoin at the floor, then redistribute.
        m.set_offline(2 * SECOND, GpuId(7), false);
        assert!((m.target(GpuId(7)) - 400.0).abs() < 1e-6, "rejoins at the floor");
        let settle = m.distribute_uniform(2 * SECOND);
        m.poll(settle);
        for i in 0..8 {
            assert!((m.target(GpuId(i)) - 600.0).abs() < 1e-6, "gpu {i}");
        }
        assert!(m.budget_ok());
    }

    #[test]
    fn weighted_move_zero_when_every_sink_at_sku_ceiling() {
        // The previously-untested saturation path: every sink pinned at
        // its own SKU ceiling — the move must be a zero-move with the
        // source untouched (no donor ratchet).
        let mut m = manager_mixed_envelopes();
        m.set_cap(0, GpuId(1), 400.0).unwrap();
        m.set_cap(1, GpuId(0), 750.0).unwrap(); // big sink at 750 (its max)
        // gpu2 sits at 400 == its small-SKU max already.
        let mv = m
            .move_power_weighted(
                2,
                &[GpuId(1)],
                &[GpuId(0), GpuId(2)],
                &[1.0],
                &[5.0, 3.0],
                150.0,
                750.0,
            )
            .unwrap();
        assert!(mv.raised.is_empty() && mv.lowered.is_empty(), "{mv:?}");
        assert_eq!(m.target(GpuId(1)), 400.0, "source untouched by a zero-move");
        m.poll(mv.effective_at);
        assert!(m.budget_ok());
    }

    #[test]
    fn weighted_move_zero_when_pool_ceiling_binds_every_sink() {
        // Same saturation through the *pool* ceiling: sinks sit at the
        // decode ceiling, so even with cap room to 750 nothing moves.
        let mut m = manager_4p4d();
        let mv = m
            .move_power_weighted(
                0,
                &[GpuId(4), GpuId(5)],
                &[GpuId(0), GpuId(1)],
                &[1.0, 2.0],
                &[3.0, 1.0],
                120.0,
                600.0, // == current sink caps
            )
            .unwrap();
        assert!(mv.raised.is_empty() && mv.lowered.is_empty(), "{mv:?}");
        assert_eq!(m.target(GpuId(4)), 600.0);
        assert!(m.budget_ok());
    }

    /// The historical `committed_caps()` rebuild, kept verbatim as the
    /// reference the cached sums must reproduce bit-for-bit.
    fn reference_committed(m: &PowerManager) -> Vec<Watts> {
        let mut per_gpu: Vec<Watts> = m
            .caps
            .iter()
            .zip(&m.offline)
            .map(|(c, &off)| if off { 0.0 } else { c.target() })
            .collect();
        for p in &m.pending {
            per_gpu[p.gpu.0] = per_gpu[p.gpu.0].max(p.cap);
        }
        per_gpu
    }

    fn assert_totals_bit_exact(m: &PowerManager, what: &str) {
        let per_gpu = reference_committed(m);
        let want: Watts = per_gpu.iter().sum();
        assert_eq!(
            m.committed_total().to_bits(),
            want.to_bits(),
            "cluster total drifted after {what}: {} vs {}",
            m.committed_total(),
            want
        );
        for nd in 0..m.n_nodes() {
            let want_nd: Watts = per_gpu
                .iter()
                .zip(&m.node_of)
                .filter(|(_, &n)| n == nd)
                .map(|(c, _)| c)
                .sum();
            assert_eq!(
                m.committed_node_total(nd).to_bits(),
                want_nd.to_bits(),
                "node {nd} total drifted after {what}"
            );
        }
    }

    #[test]
    fn cached_totals_match_rebuild_bit_exactly_through_all_mutations() {
        for (label, mut m) in [
            ("4p4d", manager_4p4d()),
            ("two-node", manager_two_nodes(4100.0)),
        ] {
            assert_totals_bit_exact(&m, "construction");
            m.set_cap(0, GpuId(0), 450.0).unwrap();
            assert_totals_bit_exact(&m, "set_cap lower");
            let _ = m.set_cap(0, GpuId(1), 750.0); // may reject on two-node
            assert_totals_bit_exact(&m, "set_cap raise");
            let mv = m
                .move_power(SECOND, &[GpuId(4), GpuId(5)], &[GpuId(0), GpuId(2)], 90.0, 750.0)
                .unwrap();
            assert_totals_bit_exact(&m, "move_power (pending queued)");
            assert!(m.poll(mv.effective_at - 1).is_empty());
            assert_totals_bit_exact(&m, "poll with nothing due");
            m.poll(mv.effective_at);
            assert_totals_bit_exact(&m, "poll applying raises");
            m.derate_gpu(2 * SECOND, GpuId(0), 430.0);
            assert_totals_bit_exact(&m, "derate_gpu");
            m.restore_gpu(3 * SECOND, GpuId(0));
            assert_totals_bit_exact(&m, "restore_gpu");
            m.set_offline(3 * SECOND, GpuId(7), true);
            assert_totals_bit_exact(&m, "set_offline(true)");
            let settle = m.distribute_uniform(4 * SECOND);
            assert_totals_bit_exact(&m, "distribute_uniform (pending queued)");
            m.poll(settle);
            assert_totals_bit_exact(&m, "poll after distribute_uniform");
            m.set_offline(5 * SECOND, GpuId(7), false);
            assert_totals_bit_exact(&m, "set_offline(false)");
            m.set_cluster_budget(6 * SECOND, 3700.0);
            assert_totals_bit_exact(&m, "cluster budget shed");
            m.set_node_budget(7 * SECOND, 0, 1700.0);
            assert_totals_bit_exact(&m, "node budget shed");
            m.set_cluster_budget(8 * SECOND, 4800.0);
            let settle = m.distribute_uniform(8 * SECOND);
            m.poll(settle);
            assert_totals_bit_exact(&m, &format!("{label}: final redistribute"));
        }
    }

    #[test]
    fn distribute_uniform_respects_binding_cluster_budget() {
        // Cluster budget 4000 < node sum 4800: uniform share is the
        // cluster-limited 500 W, not the node share of 600 W.
        let mut m = manager_two_nodes(4000.0);
        m.set_cap(0, GpuId(0), 400.0).unwrap();
        let settle = m.distribute_uniform(SECOND);
        m.poll(settle);
        for i in 0..8 {
            assert!((m.target(GpuId(i)) - 500.0).abs() < 1e-6, "gpu {i}");
        }
        assert!(m.budget_ok());
    }
}
