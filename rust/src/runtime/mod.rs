//! PJRT runtime: load AOT artifacts (HLO text + weights + manifest) and
//! execute the model from rust. Python never runs on this path.

pub mod engine;
pub mod manifest;
pub mod tokenizer;

pub use engine::{DecodeOut, Engine, KvCache, PrefillOut};
pub use manifest::{Manifest, ModelSpec, VariantKind, VariantSpec};

use anyhow::Result;

/// Returns the PJRT platform name for the CPU client (smoke test).
pub fn platform() -> Result<String> {
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(client.platform_name())
}
