"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes; every property asserts allclose against
the oracle. These tests are the root of the repo's correctness chain: the
L2 model builds on these kernels, and the rust runtime executes the HLO
they lower into.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _keys(seed, n):
    k = jax.random.PRNGKey(seed)
    return [jax.random.fold_in(k, i) for i in range(n)]


# ---------------------------------------------------------------------------
# prefill attention
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_prefill_matches_ref(b, h, s_blocks, d, seed):
    s = 64 * s_blocks
    kq, kk, kv = _keys(seed, 3)
    q, k, v = _rand(kq, (b, h, s, d)), _rand(kk, (b, h, s, d)), _rand(kv, (b, h, s, d))
    out = A.prefill_attention(q, k, v)
    ref = R.attention_prefill(q, k, v)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


@given(
    block_q=st.sampled_from([16, 32, 64, 128]),
    block_kv=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_prefill_block_shape_invariance(block_q, block_kv, seed):
    """Output must not depend on the tiling choice."""
    if block_q % block_kv:
        block_kv = block_q
    kq, kk, kv = _keys(seed, 3)
    b, h, s, d = 1, 2, 128, 16
    q, k, v = _rand(kq, (b, h, s, d)), _rand(kk, (b, h, s, d)), _rand(kv, (b, h, s, d))
    out = A.prefill_attention(q, k, v, block_q=block_q, block_kv=block_kv)
    ref = R.attention_prefill(q, k, v)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_prefill_causality():
    """Perturbing future keys/values must not change earlier outputs."""
    kq, kk, kv = _keys(0, 3)
    b, h, s, d = 1, 2, 128, 16
    q, k, v = _rand(kq, (b, h, s, d)), _rand(kk, (b, h, s, d)), _rand(kv, (b, h, s, d))
    base = A.prefill_attention(q, k, v)
    k2 = k.at[:, :, 64:, :].set(999.0)
    v2 = v.at[:, :, 64:, :].set(-999.0)
    pert = A.prefill_attention(q, k2, v2)
    np.testing.assert_allclose(base[:, :, :64], pert[:, :, :64], atol=1e-6)


def test_prefill_scale_override():
    kq, kk, kv = _keys(1, 3)
    q, k, v = (_rand(x, (1, 1, 64, 8)) for x in (kq, kk, kv))
    out = A.prefill_attention(q, k, v, sm_scale=0.5)
    ref = R.attention_prefill(q, k, v, sm_scale=0.5)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_prefill_rejects_untileable():
    q = jnp.zeros((1, 1, 100, 8))
    with pytest.raises(ValueError):
        A.prefill_attention(q, q, q, block_q=64)


def test_prefill_numerics_large_logits():
    """Online softmax must stay finite with large score magnitudes."""
    kq, kk, kv = _keys(2, 3)
    q = _rand(kq, (1, 1, 64, 16), scale=30.0)
    k = _rand(kk, (1, 1, 64, 16), scale=30.0)
    v = _rand(kv, (1, 1, 64, 16))
    out = A.prefill_attention(q, k, v)
    assert np.isfinite(np.asarray(out)).all()
    ref = R.attention_prefill(q, k, v)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_decode_matches_ref(b, h, s_blocks, d, seed):
    s = 64 * s_blocks
    kq, kk, kv, kp = _keys(seed, 4)
    q = _rand(kq, (b, h, d))
    kc, vc = _rand(kk, (b, h, s, d)), _rand(kv, (b, h, s, d))
    pos = jax.random.randint(kp, (b,), 0, s, jnp.int32)
    out = A.decode_attention(q, kc, vc, pos)
    ref = R.attention_decode(q, kc, vc, pos)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_decode_mask_excludes_dead_slots():
    """Garbage beyond pos must never leak into the output."""
    kq, kk, kv = _keys(3, 3)
    b, h, s, d = 2, 2, 128, 16
    q = _rand(kq, (b, h, d))
    kc, vc = _rand(kk, (b, h, s, d)), _rand(kv, (b, h, s, d))
    pos = jnp.array([10, 70], jnp.int32)
    base = A.decode_attention(q, kc, vc, pos)
    kc2 = kc.at[0, :, 11:, :].set(1e4).at[1, :, 71:, :].set(1e4)
    vc2 = vc.at[0, :, 11:, :].set(-1e4).at[1, :, 71:, :].set(-1e4)
    pert = A.decode_attention(q, kc2, vc2, pos)
    np.testing.assert_allclose(base, pert, atol=1e-6)


def test_decode_pos_zero():
    """pos=0 attends to exactly one slot: output == v[0]."""
    kq, kk, kv = _keys(4, 3)
    b, h, s, d = 1, 2, 64, 8
    q = _rand(kq, (b, h, d))
    kc, vc = _rand(kk, (b, h, s, d)), _rand(kv, (b, h, s, d))
    out = A.decode_attention(q, kc, vc, jnp.zeros((b,), jnp.int32))
    np.testing.assert_allclose(out, vc[:, :, 0, :], atol=1e-5, rtol=1e-5)


@given(block_kv=st.sampled_from([8, 16, 32, 64, 128]), seed=st.integers(0, 2**16))
def test_decode_block_shape_invariance(block_kv, seed):
    kq, kk, kv, kp = _keys(seed, 4)
    b, h, s, d = 2, 2, 128, 16
    q = _rand(kq, (b, h, d))
    kc, vc = _rand(kk, (b, h, s, d)), _rand(kv, (b, h, s, d))
    pos = jax.random.randint(kp, (b,), 0, s, jnp.int32)
    out = A.decode_attention(q, kc, vc, pos, block_kv=block_kv)
    ref = R.attention_decode(q, kc, vc, pos)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------


@given(
    n_blocks=st.integers(1, 4),
    dm=st.sampled_from([16, 32, 64]),
    dff=st.sampled_from([32, 48, 176]),
    seed=st.integers(0, 2**16),
)
def test_swiglu_matches_ref(n_blocks, dm, dff, seed):
    n = 64 * n_blocks
    kx, kg, ku, kd = _keys(seed, 4)
    x = _rand(kx, (n, dm))
    wg, wu = _rand(kg, (dm, dff), scale=0.3), _rand(ku, (dm, dff), scale=0.3)
    wd = _rand(kd, (dff, dm), scale=0.3)
    out = A.swiglu_ffn(x, wg, wu, wd)
    ref = R.swiglu_ffn(x, wg, wu, wd)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_swiglu_small_batch_block():
    """Rows smaller than the block (decode path, batch < 64)."""
    kx, kg, ku, kd = _keys(5, 4)
    x = _rand(kx, (4, 32))
    wg, wu = _rand(kg, (32, 48), scale=0.3), _rand(ku, (32, 48), scale=0.3)
    wd = _rand(kd, (48, 32), scale=0.3)
    out = A.swiglu_ffn(x, wg, wu, wd, block_rows=4)
    np.testing.assert_allclose(out, R.swiglu_ffn(x, wg, wu, wd), atol=1e-4, rtol=1e-4)


def test_swiglu_zero_input_is_zero():
    x = jnp.zeros((64, 16))
    w = jnp.ones((16, 32)) * 0.1
    wd = jnp.ones((32, 16)) * 0.1
    out = A.swiglu_ffn(x, w, w, wd)
    np.testing.assert_allclose(out, jnp.zeros((64, 16)), atol=1e-7)


# ---------------------------------------------------------------------------
# kernels must lower inside jit (the AOT requirement)
# ---------------------------------------------------------------------------


def test_kernels_lower_under_jit():
    kq, kk, kv = _keys(6, 3)
    b, h, s, d = 1, 2, 64, 16
    q, k, v = (_rand(x, (b, h, s, d)) for x in (kq, kk, kv))

    @jax.jit
    def fn(q, k, v):
        return A.prefill_attention(q, k, v)

    np.testing.assert_allclose(fn(q, k, v), R.attention_prefill(q, k, v), atol=3e-5, rtol=3e-5)
