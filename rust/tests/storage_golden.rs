//! Golden equivalence suite for the study-scale storage rework: slab
//! request storage, shared trace arenas, and the SoA hot state are pure
//! performance changes, so they must not perturb a single bit of any
//! RunResult or emitted report.
//!
//! Four anchors: the rapid-600 and hetero-4p4d shipped configs through
//! `sim::run` vs `sim::run_shared` (same `Arc<Trace>` reused twice),
//! and the flash-crowd-curtail + kilo-grid shipped scenarios run
//! arena-backed vs per-cell trace builds, at 1 and 4 threads, compared
//! record-by-record and byte-for-byte through the emitters.

#[path = "support/mod.rs"]
mod support;

use std::sync::Arc;

use rapid::scenario::{emit, longbench_trace, Scenario, Study};
use rapid::sim::{self, SimOptions};
use rapid::types::Slo;

fn run_vs_run_shared(config_file: &str, seed: u64) {
    let cfg = support::shipped_config(config_file);
    let trace = longbench_trace(
        seed,
        1.25 * cfg.total_gpus() as f64,
        120,
        Slo::paper_default(),
    );
    let opts = SimOptions::default();
    let owned = sim::run(&cfg, &trace, &opts);
    let shared = Arc::new(trace);
    let a = sim::run_shared(&cfg, &shared, &opts);
    // Second run off the SAME Arc: an engine that mutated the shared
    // trace on its first pass would diverge here.
    let b = sim::run_shared(&cfg, &shared, &opts);
    support::assert_bit_identical(&owned, &a);
    support::assert_bit_identical(&owned, &b);
}

#[test]
fn run_shared_matches_run_on_rapid_600() {
    run_vs_run_shared("rapid-600.toml", 17);
}

#[test]
fn run_shared_matches_run_on_hetero_4p4d() {
    run_vs_run_shared("hetero-4p4d.toml", 23);
}

fn shipped_scenario(name: &str, requests: usize) -> Scenario {
    let path = format!("{}/scenarios/{name}", env!("CARGO_MANIFEST_DIR"));
    let mut s = Scenario::from_toml_file(&path)
        .unwrap_or_else(|e| panic!("{name}: {e}"));
    s.requests = requests;
    s
}

/// The tentpole equivalence: `Study::run` (shared trace arena) against
/// `Study::run_uncached` (per-cell trace builds, the pre-arena code
/// path kept as the golden reference), serial and fanned out.
fn assert_arena_golden(s: Scenario) {
    let arena1 = Study::new(s.clone()).run(Some(1)).unwrap();
    let arena4 = Study::new(s.clone()).run(Some(4)).unwrap();
    let fresh1 = Study::new(s).run_uncached(Some(1)).unwrap();

    for (label, study) in [("1 thread", &arena1), ("4 threads", &arena4)] {
        assert_eq!(study.cells.len(), fresh1.cells.len(), "{label}");
        for (a, b) in study.cells.iter().zip(&fresh1.cells) {
            assert_eq!(a.coords, b.coords, "{label}");
            if let (Some(ra), Some(rb)) = (a.result(), b.result()) {
                support::assert_bit_identical(ra, rb);
            }
        }
    }
    // And the full reports: emitter output is the artifact studies ship,
    // so compare the exact bytes, not just the record series.
    let golden_json = emit::emit(&fresh1, emit::Format::Json);
    let golden_csv = emit::emit(&fresh1, emit::Format::Csv);
    assert_eq!(emit::emit(&arena1, emit::Format::Json), golden_json);
    assert_eq!(emit::emit(&arena4, emit::Format::Json), golden_json);
    assert_eq!(emit::emit(&arena1, emit::Format::Csv), golden_csv);
    assert_eq!(emit::emit(&arena4, emit::Format::Csv), golden_csv);
}

#[test]
fn arena_study_bit_identical_on_flash_crowd_curtail() {
    assert_arena_golden(shipped_scenario("flash-crowd-curtail.toml", 40));
}

#[test]
fn arena_study_bit_identical_on_kilo_grid() {
    assert_arena_golden(shipped_scenario("kilo-grid.toml", 40));
}
