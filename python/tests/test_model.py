"""L2 correctness: prefill/decode cache protocol vs the no-cache oracle.

`full_forward` is built purely from ref.py math (no Pallas), so agreement
between (prefill -> decode -> decode ...) and full_forward validates both
the Pallas kernels in model context and the cache-slot protocol the rust
engine relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

settings.register_profile("model", deadline=None, max_examples=8)
settings.load_profile("model")

CFG = M.ModelConfig(
    vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=48, max_seq=128, prefill_seq=64
)
PARAMS = M.init_params(CFG, seed=3)

ATOL = 5e-4


def _tokens(seed, b, s, vocab):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, vocab, jnp.int32)


@given(b=st.integers(1, 3), seed=st.integers(0, 2**16))
def test_prefill_matches_full_forward(b, seed):
    tokens = _tokens(seed, b, CFG.prefill_seq, CFG.vocab)
    lens = jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(seed), 1), (b,), 1, CFG.prefill_seq + 1
    ).astype(jnp.int32)
    logits, kc, vc = M.prefill(CFG, PARAMS, tokens, lens)
    full = M.full_forward(CFG, PARAMS, tokens)
    for i in range(b):
        np.testing.assert_allclose(
            logits[i], full[i, int(lens[i]) - 1], atol=ATOL, rtol=ATOL
        )


def test_cache_shapes():
    tokens = _tokens(0, 2, CFG.prefill_seq, CFG.vocab)
    lens = jnp.full((2,), CFG.prefill_seq, jnp.int32)
    logits, kc, vc = M.prefill(CFG, PARAMS, tokens, lens)
    expect = (CFG.n_layers, 2, CFG.n_heads, CFG.max_seq, CFG.head_dim)
    assert kc.shape == expect and vc.shape == expect
    assert logits.shape == (2, CFG.vocab)
    # Slots beyond prefill_seq must be zero (they are dead until written).
    assert np.all(np.asarray(kc[:, :, :, CFG.prefill_seq :, :]) == 0.0)


@given(seed=st.integers(0, 2**16), steps=st.integers(1, 4))
def test_decode_chain_matches_full_forward(seed, steps):
    """prefill + N greedy decode steps == full forward on the grown seq."""
    b = 2
    tokens = _tokens(seed, b, CFG.prefill_seq, CFG.vocab)
    lens = jnp.array([CFG.prefill_seq // 2, CFG.prefill_seq], jnp.int32)
    logits, kc, vc = M.prefill(CFG, PARAMS, tokens, lens)

    grown = [np.asarray(tokens[i, : int(lens[i])]).tolist() for i in range(b)]
    pos = lens
    for _ in range(steps):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(b):
            grown[i].append(int(nxt[i]))
        logits, kc, vc = M.decode(CFG, PARAMS, nxt, pos, kc, vc)
        pos = pos + 1

    for i in range(b):
        seq = jnp.array(grown[i], jnp.int32)[None, :]
        full = M.full_forward(CFG, PARAMS, seq)
        np.testing.assert_allclose(logits[i], full[0, -1], atol=ATOL, rtol=ATOL)


def test_decode_batch_independence():
    """Each batch lane must evolve independently (no cross-lane leaks)."""
    tokens = _tokens(11, 2, CFG.prefill_seq, CFG.vocab)
    lens = jnp.array([20, 40], jnp.int32)
    logits2, kc2, vc2 = M.prefill(CFG, PARAMS, tokens, lens)
    logits1, kc1, vc1 = M.prefill(
        CFG, PARAMS, tokens[:1], lens[:1]
    )
    np.testing.assert_allclose(logits2[0], logits1[0], atol=ATOL, rtol=ATOL)

    nxt2 = jnp.argmax(logits2, -1).astype(jnp.int32)
    nxt1 = nxt2[:1]
    d2, _, _ = M.decode(CFG, PARAMS, nxt2, lens, kc2, vc2)
    d1, _, _ = M.decode(CFG, PARAMS, nxt1, lens[:1], kc1, vc1)
    np.testing.assert_allclose(d2[0], d1[0], atol=ATOL, rtol=ATOL)


def test_rope_position_sensitivity():
    """Same token at different positions must produce different K."""
    x = jnp.ones((1, 1, 2, 8), jnp.float32)
    r0 = M._rope(x, jnp.array([[[0, 1]]], jnp.int32), 10000.0)
    assert not np.allclose(r0[0, 0, 0], r0[0, 0, 1])


def test_rope_norm_preservation():
    """RoPE is a rotation: per-pair L2 norm is preserved."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 1, 4, 16), jnp.float32)
    pos = jnp.array([[[0, 3, 7, 100]]], jnp.int32)
    r = M._rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(r), axis=-1),
        atol=1e-4,
        rtol=1e-4,
    )


def test_param_specs_order_stable():
    """The AOT calling convention depends on this exact order."""
    names = [n for n, _ in CFG.param_specs()]
    assert names[0] == "embed"
    assert names[-2:] == ["final_norm", "lm_head"]
    assert names[1:10] == [
        "layer0.attn_norm",
        "layer0.wq",
        "layer0.wk",
        "layer0.wv",
        "layer0.wo",
        "layer0.ffn_norm",
        "layer0.w_gate",
        "layer0.w_up",
        "layer0.w_down",
    ]


def test_init_params_deterministic():
    p1 = M.init_params(CFG, seed=9)
    p2 = M.init_params(CFG, seed=9)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])
    p3 = M.init_params(CFG, seed=10)
    assert not np.allclose(p1["embed"], p3["embed"])


def test_default_config_is_the_served_model():
    cfg = M.ModelConfig()
    assert cfg.head_dim * cfg.n_heads == cfg.d_model
    assert cfg.prefill_seq <= cfg.max_seq
    assert cfg.prefill_seq % 64 == 0  # tileable by the kernel defaults
