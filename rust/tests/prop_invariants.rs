//! Property tests on coordinator/power/simulator invariants, using the
//! in-repo property framework (`rapid::util::check`). Each property runs
//! across randomized workloads, configurations and seeds.

use rapid::config::{presets, ClusterConfig, ControlPolicy, ControllerConfig, Topology};
use rapid::coordinator::{Action, Controller, Snapshot};
use rapid::power::PowerManager;
use rapid::sim::{self, SimOptions};
use rapid::types::{GpuId, Micros, Slo, MILLIS, SECOND};
use rapid::util::check::{ensure, property, CaseResult, Gen};
use rapid::workload::{build_trace, sonnet::Sonnet, ArrivalProcess, Trace};

fn random_config(g: &mut Gen) -> ClusterConfig {
    let mut cfg = match *g.choice(&[0, 1, 2, 3, 4]) {
        0 => presets::p4d4(600.0),
        1 => presets::p5d3_600(),
        2 => presets::p4_750_d4_450(),
        3 => presets::rapid_600(),
        _ => presets::dyn_gpu_600(),
    };
    // Jitter the controller knobs inside legal ranges.
    cfg.controller.queue_threshold = g.usize_range(2, 12);
    cfg.controller.cooldown = g.u64_range(500, 4000) * MILLIS;
    cfg.batch.ring_slots = g.usize_range(4, 64);
    cfg
}

fn random_trace(g: &mut Gen, n: usize) -> Trace {
    let qps = g.f64_range(2.0, 24.0);
    let input = g.u64_range(128, 6000) as u32;
    let output = g.u64_range(4, 300) as u32;
    let seed = g.u64_range(0, 1 << 32);
    let mut ap = ArrivalProcess::poisson(rapid::util::rng::Rng::new(seed), qps);
    let mut sizes = Sonnet::new(rapid::util::rng::Rng::new(seed ^ 7), input, output);
    build_trace(n, &mut ap, &mut sizes, Slo::paper_default())
}

#[test]
fn prop_every_request_gets_exactly_one_record() {
    property("request conservation", 40, |g| {
        let cfg = random_config(g);
        let trace = random_trace(g, 120);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        ensure(
            res.records.len() == trace.len(),
            format!("{} records for {} requests", res.records.len(), trace.len()),
        )?;
        let mut ids: Vec<u64> = res.records.iter().map(|r| r.id.0).collect();
        ids.sort();
        ids.dedup();
        ensure(ids.len() == trace.len(), "duplicate or missing record ids")
    });
}

#[test]
fn prop_records_causally_ordered() {
    property("causal ordering", 30, |g| {
        let cfg = random_config(g);
        let trace = random_trace(g, 100);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        for r in &res.records {
            ensure(r.arrival <= r.prefill_start, format!("{r:?}"))?;
            ensure(r.prefill_start <= r.first_token, format!("{r:?}"))?;
            ensure(r.first_token <= r.finish, format!("{r:?}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_power_draw_never_exceeds_enforced_budget() {
    property("budget safety", 30, |g| {
        let mut cfg = random_config(g);
        cfg.enforce_budget = true;
        let trace = random_trace(g, 150);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        ensure(
            res.node_power.max() <= cfg.node_budget_w + 10.0,
            format!("peak {} > budget {}", res.node_power.max(), cfg.node_budget_w),
        )
    });
}

#[test]
fn prop_roles_always_cover_both_phases() {
    property("min one GPU per phase", 25, |g| {
        let mut cfg = random_config(g);
        cfg.control = if g.bool() {
            ControlPolicy::DynPowerGpu
        } else {
            ControlPolicy::DynGpu
        };
        let trace = random_trace(g, 200);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        for &(t, p, d) in &res.role_trace {
            ensure(
                p >= 1 && d >= 1 && p + d == cfg.n_gpus,
                format!("at t={t}: {p}P {d}D of {}", cfg.n_gpus),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_caps_stay_within_limits() {
    property("cap limits", 25, |g| {
        let cfg = random_config(g);
        let trace = random_trace(g, 150);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        let (lo, hi) = (cfg.controller.min_gpu_w - 1.0, cfg.controller.max_gpu_w + 1.0);
        for (t, caps) in &res.cap_trace {
            for &c in caps {
                ensure((lo..=hi).contains(&c), format!("cap {c} at t={t}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_decision_spacing_respects_cooldown() {
    property("cooldown hysteresis", 20, |g| {
        let mut cfg = presets::rapid_600();
        cfg.controller.cooldown = g.u64_range(1000, 5000) * MILLIS;
        cfg.controller.queue_threshold = 3;
        let trace = random_trace(g, 250);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        for w in res.decisions.windows(2) {
            let gap = w[1].0 - w[0].0;
            ensure(
                gap + MILLIS >= cfg.controller.cooldown,
                format!("decisions {} us apart < cooldown {}", gap, cfg.controller.cooldown),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_goodput_monotone_in_slo_relaxation() {
    property("slo monotonicity", 15, |g| {
        let cfg = presets::p4d4(600.0);
        let base = random_trace(g, 150);
        let strict = sim::run(
            &cfg,
            &base.clone().with_slo(Slo::new(500 * MILLIS, 15 * MILLIS)),
            &SimOptions::default(),
        );
        let relaxed = sim::run(
            &cfg,
            &base.with_slo(Slo::new(4 * SECOND, 200 * MILLIS)),
            &SimOptions::default(),
        );
        ensure(
            relaxed.attainment() >= strict.attainment() - 1e-9,
            format!("{} < {}", relaxed.attainment(), strict.attainment()),
        )
    });
}

#[test]
fn prop_power_manager_never_double_spends() {
    property("manager budget", 60, |g| {
        let n = g.usize_range(2, 10);
        let budget = g.f64_range(400.0 * n as f64, 750.0 * n as f64);
        let init = (budget / n as f64).min(750.0).max(400.0);
        let mut m = PowerManager::new(&vec![init; n], budget, true, 400.0, 750.0);
        let mut now = 0u64;
        for _ in 0..30 {
            now += g.u64_range(1, 500) * MILLIS;
            m.poll(now);
            let op = g.usize_range(0, 3);
            match op {
                0 => {
                    let gpu = GpuId(g.usize_range(0, n));
                    let cap = g.f64_range(400.0, 750.0);
                    let _ = m.set_cap(now, gpu, cap);
                }
                1 => {
                    let split = g.usize_range(1, n);
                    let sources: Vec<GpuId> = (0..split).map(GpuId).collect();
                    let sinks: Vec<GpuId> = (split..n).map(GpuId).collect();
                    if !sinks.is_empty() {
                        let _ = m.move_power(now, &sources, &sinks, g.f64_range(10.0, 400.0), 750.0);
                    }
                }
                _ => {
                    m.distribute_uniform(now);
                }
            }
            ensure(m.budget_ok(), format!("budget violated after op {op} at {now}"))?;
        }
        // Let everything settle; still within budget.
        m.poll(now + 10 * SECOND);
        ensure(m.budget_ok(), "budget violated after final settle")
    });
}

#[test]
fn prop_coalesced_and_disaggregated_complete_same_workload() {
    property("topology completeness", 15, |g| {
        let trace = random_trace(g, 80);
        for topo in [Topology::Coalesced, Topology::Disaggregated { prefill: 4, decode: 4 }] {
            let mut cfg = presets::p4d4(600.0);
            if topo == Topology::Coalesced {
                cfg = presets::coalesced(600.0);
            }
            let res = sim::run(&cfg, &trace, &SimOptions::default());
            ensure(
                res.records.len() == trace.len(),
                format!("{:?} lost requests", cfg.topology),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_alternating_pressure_never_oscillates_within_cooldown() {
    // Paper §3.3's oscillation guard: even under worst-case alternating
    // TTFT/TPOT pressure, the controller must never emit two consecutive
    // *opposing* actions inside one cooldown window. (The implementation
    // guarantees the stronger property — any two consecutive actions are
    // at least `cooldown` apart — which we also check.)
    property("cooldown oscillation guard", 40, |g| {
        let mut cfg = ControllerConfig::default();
        cfg.cooldown = g.u64_range(500, 6000) * MILLIS;
        cfg.gpu_cooldown = cfg.cooldown.max(g.u64_range(500, 8000) * MILLIS);
        cfg.queue_threshold = g.usize_range(0, 6);
        let policy = *g.choice(&[
            ControlPolicy::DynPower,
            ControlPolicy::DynGpu,
            ControlPolicy::DynPowerGpu,
        ]);
        let mut c = Controller::new(cfg.clone(), policy);
        // Flip the pressure direction every `flip_every` ticks — chosen so
        // several flips land inside a single cooldown window.
        let tick = cfg.tick;
        let flip_every = g.usize_range(1, 5);
        let saturate = g.bool();
        let mut actions: Vec<(Micros, Action)> = Vec::new();
        for step in 1..=300usize {
            let now = step as Micros * tick;
            let ttft_phase = (step / flip_every) % 2 == 0;
            for i in 0..4 {
                let jitter = i as Micros;
                if ttft_phase {
                    c.observe_ttft(now - jitter, 1.7);
                    c.observe_tpot(now - jitter, 0.3);
                } else {
                    c.observe_ttft(now - jitter, 0.3);
                    c.observe_tpot(now - jitter, 1.7);
                }
            }
            let snap = Snapshot {
                now,
                prefill_queue: 50, // always above the queue threshold
                decode_queue: 10,
                prefill_gpus: 4,
                decode_gpus: 4,
                prefill_power_saturated: saturate,
                decode_power_saturated: saturate,
            };
            if let Some(a) = c.decide(&snap) {
                actions.push((now, a));
            }
        }
        let donor = |a: &Action| match a {
            Action::MovePower { from } | Action::MoveGpu { from } => *from,
        };
        for w in actions.windows(2) {
            let (t0, a0) = (w[0].0, &w[0].1);
            let (t1, a1) = (w[1].0, &w[1].1);
            let gap = t1 - t0;
            ensure(
                gap + MILLIS >= cfg.cooldown,
                format!("consecutive actions {gap} us apart < cooldown {}", cfg.cooldown),
            )?;
            if donor(a0) != donor(a1) {
                ensure(
                    gap + MILLIS >= cfg.cooldown,
                    format!(
                        "opposing actions ({a0:?} then {a1:?}) only {gap} us apart \
                         inside one cooldown window ({})",
                        cfg.cooldown
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_multi_node_budgets_hold_at_both_levels() {
    property("hierarchical budget safety", 10, |g| {
        let nodes = g.usize_range(2, 4);
        let mut cfg = presets::scaled_to_nodes(presets::rapid_600(), nodes);
        // Start below the per-node budget, then shave the cluster budget
        // into [committed, node-sum) so the cluster cap genuinely binds.
        cfg.prefill_cap_w = 500.0;
        cfg.decode_cap_w = 500.0;
        let node_sum = cfg.node_budget_w * nodes as f64;
        let committed = cfg.total_initial_caps() * nodes as f64;
        cfg.cluster_budget_w = Some(g.f64_range(committed, node_sum));
        cfg.validate().map_err(|e| e.to_string())?;
        let trace = random_trace(g, 150);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        for (nd, series) in res.node_power_by_node.iter().enumerate() {
            ensure(
                series.max() <= cfg.node_budget_w + 10.0,
                format!("node {nd} peak {} > {}", series.max(), cfg.node_budget_w),
            )?;
        }
        ensure(
            res.node_power.max() <= cfg.cluster_budget() + 10.0,
            format!(
                "cluster peak {} > cluster budget {}",
                res.node_power.max(),
                cfg.cluster_budget()
            ),
        )
    });
}

#[test]
fn prop_higher_rate_never_improves_tail_latency() {
    property("load monotonicity (p90 ttft)", 12, |g| {
        let cfg = presets::p4d4(600.0);
        let seed = g.u64_range(0, 1 << 30);
        let mk = |qps: f64| {
            let mut ap = ArrivalProcess::poisson(rapid::util::rng::Rng::new(seed), qps);
            let mut sizes = Sonnet::new(rapid::util::rng::Rng::new(seed ^ 3), 2048, 64);
            build_trace(200, &mut ap, &mut sizes, Slo::paper_default())
        };
        let low = sim::run(&cfg, &mk(4.0), &SimOptions::default());
        let high = sim::run(&cfg, &mk(30.0), &SimOptions::default());
        ensure(
            high.ttft_percentile(90.0) >= low.ttft_percentile(90.0) * 0.8,
            format!(
                "p90 ttft high={} low={}",
                high.ttft_percentile(90.0),
                low.ttft_percentile(90.0)
            ),
        )
    });
}
