//! Counting global allocator (compiled only with `--features alloc-count`).
//!
//! The DES claims an allocation-free steady state (DESIGN.md §10/§16):
//! after warmup, stepping events must not touch the heap. That claim is
//! enforced — not just asserted in prose — by `rust/tests/alloc_steady.rs`,
//! which installs this allocator via the `#[global_allocator]` hook in
//! `lib.rs`, warms a `rapid-600` run past every amortized-growth window,
//! and requires the allocation counter delta across 1 000 simulated
//! events to be exactly zero.
//!
//! Only allocation *events* are counted (alloc / realloc / alloc_zeroed);
//! frees are deliberately ignored — a steady state that frees without
//! allocating is impossible, and counting frees would double-charge
//! drain-and-restore patterns.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Thin wrapper over the system allocator that bumps a global counter on
/// every allocation-side call.
pub struct CountingAlloc;

// SAFETY: defers entirely to `System`, which upholds the GlobalAlloc
// contract; the counter bump has no effect on the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// Total allocation events since process start. Diff two reads to count
/// allocations across a region.
pub fn allocation_count() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}
