//! Slab-backed request storage: one `ReqState` per in-flight request.
//!
//! Every request alive inside the cluster — queued for prefill, mid
//! chunked-prefill, in transit over the KV ring, resident in a decode
//! batch, or parked in a waiter pool — lives in exactly one slot of the
//! cluster's [`RequestStore`]. Queues, batches and events carry copyable
//! 8-byte [`SlotId`]s instead of owned `Request` structs, so moving a
//! request between pools is an integer push, not a memcpy of the whole
//! struct, and the `Event` enum stays small enough for the calendar
//! queue's pre-sized buckets.
//!
//! `ReqState` folds the fields formerly spread across `DecodeItem`
//! (decode-phase bookkeeping) and `ChunkMeta`/`ChunkProgress`
//! (chunked-prefill bookkeeping) into one record, because a request
//! transitions through those phases in place — only the slot's fields
//! change, never its address. Slots are inserted at arrival (after
//! admission control) and removed exactly where a record is pushed; the
//! generation check in [`SlotId`] turns any use-after-free into a panic
//! instead of silently reading the slot's next occupant.

use crate::types::{Micros, Request};
use crate::util::slab::{Slab, SlotId};

pub use crate::util::slab::SlotId as ReqSlot;

/// Per-request simulation state, stored once in the cluster's slab.
#[derive(Debug, Clone)]
pub struct ReqState {
    pub req: Request,
    /// When the request's prefill batch (or first coalesced chunk) began.
    pub prefill_start: Micros,
    /// When the first output token was produced.
    pub first_token: Micros,
    /// Output tokens generated so far *including* the prefill-produced
    /// first token.
    pub tokens_done: u32,
    /// Prompt tokens served from the prefix cache (skipped at prefill
    /// but still resident context for decode and KV accounting). Zero
    /// unless the memory subsystem is active and the lookup hit.
    pub cached_tokens: u32,
    /// Chunked-prefill progress (coalesced GPUs only): prompt tokens
    /// already processed.
    pub chunk_done: u32,
    /// When the first chunk of this prompt began executing (coalesced
    /// GPUs only; `None` until scheduled).
    pub started: Option<Micros>,
}

impl ReqState {
    /// Fresh state for a request entering the cluster.
    pub fn new(req: Request) -> Self {
        ReqState {
            req,
            prefill_start: 0,
            first_token: 0,
            tokens_done: 0,
            cached_tokens: 0,
            chunk_done: 0,
            started: None,
        }
    }

    /// Live context length (prompt + generated) — drives KV-read cost.
    pub fn ctx_tokens(&self) -> u32 {
        self.req.input_tokens + self.cached_tokens + self.tokens_done
    }

    /// Output tokens still to generate.
    pub fn remaining(&self) -> u32 {
        self.req.output_tokens.saturating_sub(self.tokens_done)
    }

    /// Prompt tokens this chunked prefill has yet to process.
    pub fn chunk_remaining(&self) -> u32 {
        self.req.input_tokens - self.chunk_done
    }

    /// Advance the chunked prefill by up to `budget` tokens; returns
    /// tokens consumed (the `ChunkProgress::advance` contract).
    pub fn chunk_advance(&mut self, budget: u32) -> u32 {
        let step = self.chunk_remaining().min(budget);
        self.chunk_done += step;
        step
    }

    /// Has the chunked prefill consumed the whole prompt?
    pub fn chunk_complete(&self) -> bool {
        self.chunk_done >= self.req.input_tokens
    }
}

/// The cluster-owned slab of in-flight request state.
pub type RequestStore = Slab<ReqState>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{RequestId, Slo};

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request {
            id: RequestId(id),
            arrival: 0,
            input_tokens: input,
            output_tokens: output,
            slo: Slo::paper_default(),
            tenant: 0,
        }
    }

    #[test]
    fn context_and_remaining_match_decode_item_semantics() {
        let mut st = ReqState::new(req(0, 500, 10));
        st.tokens_done = 3;
        assert_eq!(st.ctx_tokens(), 503);
        assert_eq!(st.remaining(), 7);
        st.cached_tokens = 200;
        assert_eq!(st.ctx_tokens(), 703);
    }

    #[test]
    fn chunk_advance_matches_chunk_progress_semantics() {
        let mut st = ReqState::new(req(0, 5000, 8));
        assert_eq!(st.chunk_advance(2048), 2048);
        assert_eq!(st.chunk_advance(2048), 2048);
        assert!(!st.chunk_complete());
        assert_eq!(st.chunk_advance(2048), 904);
        assert!(st.chunk_complete());
        assert_eq!(st.chunk_remaining(), 0);
    }

    #[test]
    fn store_round_trip() {
        let mut store: RequestStore = RequestStore::with_capacity(4);
        let a = store.insert(ReqState::new(req(7, 100, 4)));
        store.get_mut(a).tokens_done = 2;
        assert_eq!(store.get(a).req.id.0, 7);
        assert_eq!(store.get(a).remaining(), 2);
        let st = store.remove(a);
        assert_eq!(st.tokens_done, 2);
        assert!(store.is_empty());
    }
}
