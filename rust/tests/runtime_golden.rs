//! Cross-language golden test: the rust PJRT runtime must reproduce the
//! exact greedy continuation python/jax computed at export time
//! (artifacts/golden.json). This pins L1 (Pallas), L2 (JAX), the AOT
//! bridge and the rust execution path to each other bit-for-bit at the
//! argmax level.

#![cfg(feature = "pjrt")]

use rapid::runtime::Engine;
use rapid::util::json::Json;

#[test]
fn rust_reproduces_python_greedy_tokens() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let golden_path = std::path::Path::new(dir).join("golden.json");
    if !golden_path.exists() {
        eprintln!("golden.json missing; skipping");
        return;
    }
    let golden = Json::parse(&std::fs::read_to_string(&golden_path).unwrap()).unwrap();
    let prompt: Vec<i64> = golden
        .get("prompt_tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_u64().unwrap() as i64)
        .collect();
    let expect: Vec<i64> = golden
        .get("greedy")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_u64().unwrap() as i64)
        .collect();

    let eng = Engine::load(dir).expect("engine");
    let out = eng.prefill(&[prompt.clone()]).expect("prefill");
    let mut got = vec![out.tokens[0]];
    let mut kv = out.kv;
    let mut tok = out.tokens[0];
    let mut pos = prompt.len() as i64;
    for _ in 1..expect.len() {
        let step = eng.decode(&[tok], &[pos], &kv).expect("decode");
        kv = step.kv;
        tok = step.tokens[0];
        got.push(tok);
        pos += 1;
    }
    assert_eq!(got, expect, "rust greedy tokens diverge from python");
}
