//! Real PJRT serving path: disaggregated prefill/decode workers over the
//! AOT-compiled model, with power-cap pacing from the calibrated model.
//!
//! Threading model: PJRT wrapper types are not `Send` (raw pointers) and
//! the CPU client is a single device, so one **executor thread** owns the
//! [`Engine`] plus a KV-cache table, and serves `ExecJob`s over a
//! channel; caches are referenced across threads by opaque ids. The
//! logical "GPUs" are worker threads that batch requests, submit jobs,
//! and apply *power pacing*: a worker capped at `w` watts stretches each
//! execution by `speedup(max)/speedup(w)`, so the power→latency
//! behaviour of the simulator holds on the real path too (same
//! [`PowerModel`]).
//!
//! Data flow (paper §3.2): router -> prefill worker (FIFO token-budget
//! batch) -> KV ring ([`crate::kv::KvRing`], ids only) -> decode worker
//! (group continuous batching) -> records.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::PerfModelConfig;
use crate::kv::KvRing;
use crate::power::PowerModel;
use crate::runtime::{tokenizer, Engine};
use crate::types::{Micros, RequestId, RequestRecord, Slo, Watts};
use crate::util::rng::Rng;
use crate::util::stats::percentile;

/// A request on the real serving path.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
}

type KvId = u64;

/// Jobs the executor thread runs (all PJRT calls live there).
enum ExecJob {
    Prefill {
        prompts: Vec<Vec<i64>>,
        reply: mpsc::Sender<Result<(Vec<i64>, KvId, Micros)>>,
    },
    Decode {
        tokens: Vec<i64>,
        pos: Vec<i64>,
        kv: KvId,
        reply: mpsc::Sender<Result<(Vec<i64>, Micros)>>,
    },
    FreeKv(KvId),
    Shutdown,
}

/// What travels through the KV ring: a prefilled group ready to decode.
struct DecodeGroup {
    ids: Vec<u64>,
    arrivals: Vec<Instant>,
    prefill_starts: Vec<Instant>,
    first_token: Instant,
    prompts_len: Vec<usize>,
    budgets: Vec<usize>,
    last_tokens: Vec<i64>,
    kv: KvId,
    kv_batch: usize,
}

/// Completed request with timings + generated text.
pub struct ServeOutcome {
    pub record: RequestRecord,
    pub text: String,
}

/// Per-pool power caps for the demo (pacing only; the CPU is the "GPU").
#[derive(Debug, Clone, Copy)]
pub struct ServeCaps {
    pub prefill_w: Watts,
    pub decode_w: Watts,
}

impl Default for ServeCaps {
    fn default() -> Self {
        ServeCaps {
            prefill_w: 750.0,
            decode_w: 450.0,
        }
    }
}

/// Pacing factor for a phase at `cap` watts.
fn pacing(model: &PowerModel, cap: Watts, is_prefill: bool) -> f64 {
    if is_prefill {
        model.prefill_speedup(750.0) / model.prefill_speedup(cap)
    } else {
        model.decode_speedup(750.0) / model.decode_speedup(cap)
    }
}

fn executor_loop(engine: Engine, jobs: mpsc::Receiver<ExecJob>) {
    let mut table: HashMap<KvId, crate::runtime::KvCache> = HashMap::new();
    let mut next_id: KvId = 1;
    while let Ok(job) = jobs.recv() {
        match job {
            ExecJob::Prefill { prompts, reply } => {
                let t0 = Instant::now();
                let res = engine.prefill(&prompts).map(|out| {
                    let id = next_id;
                    next_id += 1;
                    table.insert(id, out.kv);
                    (out.tokens, id, t0.elapsed().as_micros() as Micros)
                });
                let _ = reply.send(res);
            }
            ExecJob::Decode {
                tokens,
                pos,
                kv,
                reply,
            } => {
                let t0 = Instant::now();
                let res = match table.remove(&kv) {
                    None => Err(anyhow!("unknown kv id {kv}")),
                    Some(cache) => engine.decode(&tokens, &pos, &cache).map(|out| {
                        table.insert(kv, out.kv);
                        (out.tokens, t0.elapsed().as_micros() as Micros)
                    }),
                };
                let _ = reply.send(res);
            }
            ExecJob::FreeKv(id) => {
                table.remove(&id);
            }
            ExecJob::Shutdown => break,
        }
    }
}

/// Aggregate run statistics (stable across CPU noise: per-step means).
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Mean paced decode-step wall time (us).
    pub decode_step_us: f64,
    /// Mean paced prefill-batch wall time (us).
    pub prefill_exec_us: f64,
    pub decode_steps: usize,
    pub prefill_batches: usize,
}

struct Shared {
    jobs: Mutex<mpsc::Sender<ExecJob>>,
    ring: KvRing<DecodeGroup>,
    prefill_queue: Mutex<VecDeque<(ServeRequest, Instant)>>,
    outcomes: Mutex<Vec<ServeOutcome>>,
    decode_steps_us: Mutex<Vec<f64>>,
    prefill_execs_us: Mutex<Vec<f64>>,
    done_submitting: AtomicBool,
    completed: AtomicUsize,
    total: usize,
    model: PowerModel,
    caps: ServeCaps,
    prefill_seq: usize,
    start: Instant,
}

impl Shared {
    fn since_start(&self, t: Instant) -> Micros {
        t.duration_since(self.start).as_micros() as Micros
    }

    fn send(&self, job: ExecJob) -> bool {
        self.jobs.lock().unwrap().send(job).is_ok()
    }

    fn finished(&self) -> bool {
        self.completed.load(Ordering::Acquire) >= self.total
    }
}

fn prefill_worker(sh: Arc<Shared>, max_batch: usize) {
    let stretch = pacing(&sh.model, sh.caps.prefill_w, true);
    loop {
        let batch: Vec<(ServeRequest, Instant)> = {
            let mut q = sh.prefill_queue.lock().unwrap();
            let n = q.len().min(max_batch);
            q.drain(..n).collect()
        };
        if batch.is_empty() {
            if sh.done_submitting.load(Ordering::Acquire) || sh.finished() {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        let start = Instant::now();
        let prompts: Vec<Vec<i64>> = batch
            .iter()
            .map(|(r, _)| {
                let mut t = tokenizer::encode(&r.prompt);
                t.truncate(sh.prefill_seq);
                t
            })
            .collect();
        let (tx, rx) = mpsc::channel();
        if !sh.send(ExecJob::Prefill {
            prompts: prompts.clone(),
            reply: tx,
        }) {
            return;
        }
        let Ok(Ok((tokens, kv_id, exec_us))) = rx.recv() else { return };
        // Power pacing: stretch wall time to the capped-GPU latency.
        std::thread::sleep(Duration::from_micros(
            (exec_us as f64 * (stretch - 1.0)) as u64,
        ));
        sh.prefill_execs_us
            .lock()
            .unwrap()
            .push(exec_us as f64 * stretch);
        let first = Instant::now();
        let group = DecodeGroup {
            ids: batch.iter().map(|(r, _)| r.id).collect(),
            arrivals: batch.iter().map(|(_, a)| *a).collect(),
            prefill_starts: batch.iter().map(|_| start).collect(),
            first_token: first,
            prompts_len: prompts.iter().map(|p| p.len()).collect(),
            budgets: batch.iter().map(|(r, _)| r.max_new_tokens.max(1)).collect(),
            kv_batch: {
                // The engine picked the smallest variant >= batch len; the
                // decode step must use the same lane count.
                let mut b = 1;
                for &cand in &[1usize, 2, 4, 8] {
                    if cand >= batch.len() {
                        b = cand;
                        break;
                    }
                }
                b
            },
            last_tokens: tokens,
            kv: kv_id,
        };
        // Backpressure: spin while the ring is full (paper's prefill stall).
        sh.ring
            .publish_blocking(group, || std::thread::sleep(Duration::from_millis(1)));
    }
}

fn decode_worker(sh: Arc<Shared>) {
    let stretch = pacing(&sh.model, sh.caps.decode_w, false);
    loop {
        let Some(group) = sh.ring.try_consume() else {
            if sh.finished() {
                return;
            }
            let quiescent = sh.done_submitting.load(Ordering::Acquire)
                && sh.ring.in_flight() == 0
                && sh.prefill_queue.lock().unwrap().is_empty();
            if quiescent {
                // Give in-flight prefill batches a moment, then re-check.
                std::thread::sleep(Duration::from_millis(5));
                if sh.ring.in_flight() == 0 && sh.finished() {
                    return;
                }
            }
            std::thread::sleep(Duration::from_millis(1));
            continue;
        };
        let lanes = group.ids.len();
        let batch = group.kv_batch;
        let mut pos: Vec<i64> = (0..batch)
            .map(|i| *group.prompts_len.get(i).unwrap_or(&1) as i64)
            .collect();
        let mut toks = group.last_tokens.clone();
        toks.resize(batch, 0);
        let max_steps = group.budgets.iter().copied().max().unwrap_or(1);
        let mut finish: Vec<Option<Instant>> = vec![None; lanes];
        let mut generated: Vec<Vec<i64>> = (0..lanes).map(|i| vec![toks[i]]).collect();
        for lane in 0..lanes {
            if group.budgets[lane] <= 1 {
                finish[lane] = Some(group.first_token);
            }
        }
        for step in 1..max_steps {
            let (tx, rx) = mpsc::channel();
            if !sh.send(ExecJob::Decode {
                tokens: toks.clone(),
                pos: pos.clone(),
                kv: group.kv,
                reply: tx,
            }) {
                return;
            }
            let Ok(Ok((next, exec_us))) = rx.recv() else { return };
            std::thread::sleep(Duration::from_micros(
                (exec_us as f64 * (stretch - 1.0)) as u64,
            ));
            sh.decode_steps_us
                .lock()
                .unwrap()
                .push(exec_us as f64 * stretch);
            let now = Instant::now();
            for lane in 0..lanes {
                if step < group.budgets[lane] {
                    generated[lane].push(next[lane]);
                    if step + 1 >= group.budgets[lane] {
                        finish[lane] = Some(now);
                    }
                }
            }
            toks = next;
            for p in &mut pos {
                *p += 1;
            }
        }
        sh.send(ExecJob::FreeKv(group.kv));
        let now = Instant::now();
        let mut outcomes = sh.outcomes.lock().unwrap();
        for lane in 0..lanes {
            let fin = finish[lane].unwrap_or(now);
            outcomes.push(ServeOutcome {
                record: RequestRecord {
                    id: RequestId(group.ids[lane]),
                    arrival: sh.since_start(group.arrivals[lane]),
                    prefill_start: sh.since_start(group.prefill_starts[lane]),
                    first_token: sh.since_start(group.first_token),
                    finish: sh.since_start(fin),
                    input_tokens: group.prompts_len[lane] as u32,
                    output_tokens: group.budgets[lane] as u32,
                    slo: Slo::paper_default(),
                    tenant: 0,
                    shed: false,
                },
                text: tokenizer::decode(&generated[lane]),
            });
            sh.completed.fetch_add(1, Ordering::AcqRel);
        }
    }
}

/// Serve `requests` through a disaggregated worker topology and return
/// completion records. `qps` drives Poisson arrivals in real time.
pub fn serve(
    artifacts: &str,
    requests: Vec<ServeRequest>,
    qps: f64,
    prefill_workers: usize,
    decode_workers: usize,
    caps: ServeCaps,
) -> Result<(Vec<ServeOutcome>, RunStats)> {
    // PJRT types are !Send: build the engine *inside* the executor thread
    // and hand back the manifest facts the workers need.
    let (jobs_tx, jobs_rx) = mpsc::channel();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();
    let artifacts_path = artifacts.to_string();
    let executor = std::thread::spawn(move || {
        match Engine::load(&artifacts_path).context("loading artifacts") {
            Ok(engine) => {
                let info = (
                    engine.manifest.model.prefill_seq,
                    *engine.prefill_batches().last().unwrap_or(&1),
                );
                let _ = ready_tx.send(Ok(info));
                executor_loop(engine, jobs_rx);
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e));
            }
        }
    });
    let (prefill_seq, max_batch) = ready_rx
        .recv()
        .map_err(|_| anyhow!("executor died during engine load"))??;

    let total = requests.len();
    let sh = Arc::new(Shared {
        jobs: Mutex::new(jobs_tx.clone()),
        ring: KvRing::new(32),
        prefill_queue: Mutex::new(VecDeque::new()),
        outcomes: Mutex::new(Vec::new()),
        decode_steps_us: Mutex::new(Vec::new()),
        prefill_execs_us: Mutex::new(Vec::new()),
        done_submitting: AtomicBool::new(false),
        completed: AtomicUsize::new(0),
        total,
        model: PowerModel::new(PerfModelConfig::default()),
        caps,
        prefill_seq,
        start: Instant::now(),
    });

    let mut handles = Vec::new();
    for _ in 0..prefill_workers.max(1) {
        let s = Arc::clone(&sh);
        handles.push(std::thread::spawn(move || prefill_worker(s, max_batch)));
    }
    for _ in 0..decode_workers.max(1) {
        let s = Arc::clone(&sh);
        handles.push(std::thread::spawn(move || decode_worker(s)));
    }

    // Poisson arrivals in real time.
    let mut rng = Rng::new(7);
    for r in requests {
        let gap = rng.exponential(qps.max(0.1));
        std::thread::sleep(Duration::from_secs_f64(gap.min(0.5)));
        sh.prefill_queue
            .lock()
            .unwrap()
            .push_back((r, Instant::now()));
    }
    sh.done_submitting.store(true, Ordering::Release);

    let deadline = Instant::now() + Duration::from_secs(600);
    while !sh.finished() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    for h in handles {
        let _ = h.join();
    }
    let _ = jobs_tx.send(ExecJob::Shutdown);
    let _ = executor.join();

    let sh = Arc::try_unwrap(sh).map_err(|_| anyhow!("worker leaked shared state"))?;
    let mut outcomes = sh.outcomes.into_inner().unwrap();
    outcomes.sort_by_key(|o| o.record.id.0);
    let dec = sh.decode_steps_us.into_inner().unwrap();
    let pre = sh.prefill_execs_us.into_inner().unwrap();
    let stats = RunStats {
        decode_step_us: if dec.is_empty() { 0.0 } else { dec.iter().sum::<f64>() / dec.len() as f64 },
        prefill_exec_us: if pre.is_empty() { 0.0 } else { pre.iter().sum::<f64>() / pre.len() as f64 },
        decode_steps: dec.len(),
        prefill_batches: pre.len(),
    };
    Ok((outcomes, stats))
}

/// Render a latency/throughput report for a finished run.
pub fn report(outcomes: &[ServeOutcome], wall_secs: f64) -> String {
    let ttfts: Vec<f64> = outcomes.iter().map(|o| o.record.ttft() as f64).collect();
    let tpots: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.record.output_tokens > 1)
        .map(|o| o.record.tpot() as f64)
        .collect();
    let total_tokens: u64 = outcomes
        .iter()
        .map(|o| o.record.output_tokens as u64)
        .sum();
    let mut out = String::new();
    out.push_str(&format!(
        "completed {} requests in {wall_secs:.1}s ({:.2} req/s, {:.1} tok/s)\n",
        outcomes.len(),
        outcomes.len() as f64 / wall_secs.max(1e-9),
        total_tokens as f64 / wall_secs.max(1e-9),
    ));
    if !ttfts.is_empty() {
        out.push_str(&format!(
            "TTFT  p50 {:>7.1} ms | p90 {:>7.1} ms | max {:>7.1} ms\n",
            percentile(&ttfts, 50.0) / 1000.0,
            percentile(&ttfts, 90.0) / 1000.0,
            percentile(&ttfts, 100.0) / 1000.0,
        ));
    }
    if !tpots.is_empty() {
        out.push_str(&format!(
            "TPOT  p50 {:>7.1} ms | p90 {:>7.1} ms | max {:>7.1} ms\n",
            percentile(&tpots, 50.0) / 1000.0,
            percentile(&tpots, 90.0) / 1000.0,
            percentile(&tpots, 100.0) / 1000.0,
        ));
    }
    out
}

/// CLI demo: synthesize prompts, serve them, print the report.
pub fn serve_demo(
    artifacts: &str,
    n_requests: usize,
    qps: f64,
    prefill_workers: usize,
    decode_workers: usize,
) -> Result<()> {
    let corpus = [
        "disaggregation separates prefill from decode",
        "the node budget is 4800 watts across eight GPUs",
        "prefill is compute bound and loves high power caps",
        "decode is memory bound and flattens early",
        "queue buildup is an early indicator of stress",
        "power moves first and GPUs move when power saturates",
    ];
    let requests: Vec<ServeRequest> = (0..n_requests)
        .map(|i| ServeRequest {
            id: i as u64,
            prompt: corpus[i % corpus.len()].to_string(),
            max_new_tokens: 8 + (i % 3) * 8,
        })
        .collect();
    println!(
        "serving {n_requests} requests @ {qps} qps over {prefill_workers}P/{decode_workers}D \
         (pacing: 750 W prefill / 450 W decode)"
    );
    let t0 = Instant::now();
    let (outcomes, stats) = serve(
        artifacts,
        requests,
        qps,
        prefill_workers,
        decode_workers,
        ServeCaps::default(),
    )?;
    println!("{}", report(&outcomes, t0.elapsed().as_secs_f64()));
    println!(
        "mean paced decode step {:.1} ms over {} steps; prefill batch {:.1} ms over {}",
        stats.decode_step_us / 1000.0,
        stats.decode_steps,
        stats.prefill_exec_us / 1000.0,
        stats.prefill_batches
    );
    for o in outcomes.iter().take(3) {
        println!(
            "  {}: ttft={}ms out={:?}...",
            o.record.id,
            o.record.ttft() / 1000,
            &o.text.chars().take(24).collect::<String>()
        );
    }
    Ok(())
}
