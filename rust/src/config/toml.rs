//! TOML-subset parser (offline substitute for the `toml` crate).
//!
//! Supports the grammar the config system uses: `[section]` and
//! `[section.sub]` tables, `key = value` with string / integer / float /
//! boolean / homogeneous-array values, comments, and bare or quoted keys.
//! Not supported (by design): multi-line strings, inline tables, dates,
//! array-of-tables.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Numeric coercion: integers read as floats too.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Flat document: dotted-path -> value (`power.budget_w = 4800`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Document {
    pub entries: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl Document {
    pub fn parse(text: &str) -> Result<Document, TomlError> {
        let mut doc = Document::default();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError {
                line: lineno + 1,
                msg: msg.to_string(),
            };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated table header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err("empty table name"));
                }
                prefix = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
            let key = unquote(line[..eq].trim()).map_err(|m| err(&m))?;
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            let path = if prefix.is_empty() {
                key
            } else {
                format!("{prefix}.{key}")
            };
            if doc.entries.insert(path.clone(), value).is_some() {
                return Err(err(&format!("duplicate key '{path}'")));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }

    pub fn get_i64(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_i64)
    }

    pub fn get_f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_f64)
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    pub fn get_array(&self, path: &str) -> Option<&[Value]> {
        self.get(path).and_then(Value::as_array)
    }

    /// All keys under a section prefix (for unknown-key validation).
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        self.entries.keys().filter_map(move |k| {
            k.strip_prefix(prefix)
                .and_then(|rest| rest.strip_prefix('.'))
                .map(|_| k.as_str())
        })
    }

    /// Strict unknown-key validation: every key in the document must be
    /// declared in `tables` — `(table, fields)` pairs where `""` names
    /// the top level — or live under a `dynamic` table family:
    /// `("sku", FIELDS)` accepts `sku.<any-name>.<field>` for any
    /// single-segment name. A misspelled key returns a friendly error
    /// naming the key, its table, and the keys that table accepts,
    /// instead of being silently ignored.
    pub fn check_known_keys(
        &self,
        tables: &[(&str, &[&str])],
        dynamic: &[(&str, &[&str])],
    ) -> Result<(), String> {
        'keys: for key in self.entries.keys() {
            let (table, field) = match key.rsplit_once('.') {
                Some((t, f)) => (t, f),
                None => ("", key.as_str()),
            };
            for (family, fields) in dynamic {
                if let Some(name) = table.strip_prefix(family).and_then(|r| r.strip_prefix('.')) {
                    if !name.contains('.') {
                        if fields.contains(&field) {
                            continue 'keys;
                        }
                        return Err(format!(
                            "unknown key '{field}' in table [{family}.{name}] (valid keys: {})",
                            fields.join(", ")
                        ));
                    }
                }
            }
            for (known_table, fields) in tables {
                if table == *known_table {
                    if fields.contains(&field) {
                        continue 'keys;
                    }
                    let wher = if table.is_empty() {
                        "at the top level".to_string()
                    } else {
                        format!("in table [{table}]")
                    };
                    return Err(format!(
                        "unknown key '{field}' {wher} (valid keys: {})",
                        fields.join(", ")
                    ));
                }
            }
            let mut valid: Vec<String> = dynamic
                .iter()
                .map(|(f, _)| format!("[{f}.<name>]"))
                .collect();
            valid.extend(
                tables
                    .iter()
                    .filter(|(t, _)| !t.is_empty())
                    .map(|(t, _)| format!("[{t}]")),
            );
            return Err(format!(
                "unknown table for key '{key}' (valid tables: {})",
                valid.join(", ")
            ));
        }
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings must not start a comment.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => {
                escaped = !escaped;
                continue;
            }
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        escaped = false;
    }
    line
}

fn unquote(key: &str) -> Result<String, String> {
    if let Some(inner) = key.strip_prefix('"') {
        inner
            .strip_suffix('"')
            .map(|s| s.to_string())
            .ok_or_else(|| "unterminated quoted key".to_string())
    } else if key
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.')
    {
        Ok(key.to_string())
    } else {
        Err(format!("invalid bare key '{key}'"))
    }
}

fn parse_value(text: &str) -> Result<Value, String> {
    let text = text.trim();
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(unescape(inner)?));
    }
    if text == "true" {
        return Ok(Value::Bool(true));
    }
    if text == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = split_top_level(inner)
            .into_iter()
            .map(|s| parse_value(s.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    let clean = text.replace('_', "");
    if !text.contains('.') && !text.contains('e') && !text.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{text}'"))
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape '\\{:?}'", other)),
        }
    }
    Ok(out)
}

/// Split an array body on commas that are not inside strings or brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Document::parse(
            r#"
# top comment
name = "rapid"   # trailing comment
[power]
budget_w = 4800
per_gpu_max = 750.0
capped = true
[power.ramp]
settle_ms = 300
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("rapid"));
        assert_eq!(doc.get_i64("power.budget_w"), Some(4800));
        assert_eq!(doc.get_f64("power.per_gpu_max"), Some(750.0));
        assert_eq!(doc.get_bool("power.capped"), Some(true));
        assert_eq!(doc.get_i64("power.ramp.settle_ms"), Some(300));
    }

    #[test]
    fn int_coerces_to_f64() {
        let doc = Document::parse("x = 5").unwrap();
        assert_eq!(doc.get_f64("x"), Some(5.0));
        assert_eq!(doc.get_i64("x"), Some(5));
    }

    #[test]
    fn arrays() {
        let doc = Document::parse(r#"caps = [750, 750, 450.5, 450]"#).unwrap();
        let a = doc.get("caps").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(a[0].as_f64(), Some(750.0));
        assert_eq!(a[2].as_f64(), Some(450.5));
        // Path-based accessor used by the scenario loader.
        let doc = Document::parse("[axes]\nrate = [0.5, 1.0]").unwrap();
        assert_eq!(doc.get_array("axes.rate").unwrap().len(), 2);
        assert!(doc.get_array("axes.missing").is_none());
    }

    #[test]
    fn nested_arrays_and_strings_with_commas() {
        let doc = Document::parse(r#"x = [[1, 2], [3, 4]]"#).unwrap();
        let outer = doc.get("x").unwrap().as_array().unwrap();
        assert_eq!(outer.len(), 2);
        let doc2 = Document::parse(r#"s = ["a,b", "c#d"]"#).unwrap();
        let a = doc2.get("s").unwrap().as_array().unwrap();
        assert_eq!(a[0].as_str(), Some("a,b"));
        assert_eq!(a[1].as_str(), Some("c#d"));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = Document::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc.get_str("tag"), Some("a#b"));
    }

    #[test]
    fn underscored_numbers() {
        let doc = Document::parse("n = 1_000_000").unwrap();
        assert_eq!(doc.get_i64("n"), Some(1_000_000));
    }

    #[test]
    fn escapes_in_strings() {
        let doc = Document::parse(r#"s = "line\nbreak\t\"q\"""#).unwrap();
        assert_eq!(doc.get_str("s"), Some("line\nbreak\t\"q\""));
    }

    #[test]
    fn duplicate_key_rejected() {
        let err = Document::parse("a = 1\na = 2").unwrap_err();
        assert!(err.to_string().contains("duplicate"));
        assert_eq!(err.line, 2);
    }

    #[test]
    fn bad_syntax_reports_line() {
        let err = Document::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(Document::parse("[unclosed").is_err());
        assert!(Document::parse("x = ").is_err());
        assert!(Document::parse(r#"x = "unterminated"#).is_err());
    }

    #[test]
    fn keys_under_prefix() {
        let doc = Document::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        let keys: Vec<&str> = doc.keys_under("a").collect();
        assert_eq!(keys, vec!["a.x", "a.y"]);
    }

    #[test]
    fn check_known_keys_names_key_and_table() {
        let tables: &[(&str, &[&str])] = &[("", &["name"]), ("power", &["budget_w"])];
        let dynamic: &[(&str, &[&str])] = &[("sku", &["max_w"])];
        let ok = Document::parse("name = \"x\"\n[power]\nbudget_w = 1\n[sku.h100]\nmax_w = 700")
            .unwrap();
        ok.check_known_keys(tables, dynamic).unwrap();
        // Misspelled field in a known table: names key, table, and the
        // valid keys.
        let bad = Document::parse("[power]\nbudget_watts = 1").unwrap();
        let msg = bad.check_known_keys(tables, dynamic).unwrap_err();
        assert!(msg.contains("'budget_watts'") && msg.contains("[power]"), "{msg}");
        assert!(msg.contains("budget_w"), "{msg}");
        // Unknown top-level key.
        let msg = Document::parse("nam = \"x\"")
            .unwrap()
            .check_known_keys(tables, dynamic)
            .unwrap_err();
        assert!(msg.contains("'nam'") && msg.contains("top level"), "{msg}");
        // Unknown table lists the valid ones, including dynamic families.
        let msg = Document::parse("[powr]\nbudget_w = 1")
            .unwrap()
            .check_known_keys(tables, dynamic)
            .unwrap_err();
        assert!(msg.contains("powr.budget_w") && msg.contains("[sku.<name>]"), "{msg}");
        // Bad field inside a dynamic table.
        let msg = Document::parse("[sku.h100]\nmax_watts = 700")
            .unwrap()
            .check_known_keys(tables, dynamic)
            .unwrap_err();
        assert!(msg.contains("'max_watts'") && msg.contains("[sku.h100]"), "{msg}");
    }
}
