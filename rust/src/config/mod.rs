//! Configuration system: TOML-subset parser + typed schema + presets.

pub mod schema;
pub mod toml;

pub use schema::{
    presets, BatchConfig, ClusterConfig, ConfigError, ControlPolicy, ControllerConfig,
    PerfModelConfig, Topology,
};
