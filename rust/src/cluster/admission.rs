//! Admission control for multi-tenant runs (DESIGN.md §15).
//!
//! Activation: an `[admission]` table (any `admission.*` key) in a
//! config or scenario file. Without one the subsystem is structurally
//! inert — `AdmissionState::admit` is never consulted and the run is
//! bit-identical to a build without this module.
//!
//! Two policies beyond `none`:
//!
//! * **queue-depth** — shed an arrival when the number of requests in
//!   the system reaches `queue_depth × mult(tier)`, where higher
//!   priority tiers get a larger multiplier (interactive 4×, standard
//!   2×, batch 1×). Under overload the batch tier saturates its
//!   threshold first, so the lowest-priority work sheds first and the
//!   interactive tier keeps admitting the longest.
//! * **token-bucket** — per-tenant buckets refilled at
//!   `bucket_rps × share` (the untenanted id 0 gets the full rate),
//!   capped at `bucket_burst`; an arrival takes one token or sheds.
//!   This is per-tenant rate isolation: one tenant's flash crowd
//!   cannot starve another's admission budget.
//!
//! A shed request is *accounted, not dropped*: the cluster records an
//! immediate SLO-violation record with the `shed` flag set, so request
//! conservation (`records.len() == n_requests`) still holds and
//! attainment counts the miss.

use crate::config::toml::Document;
use crate::types::{Micros, SECOND};
use crate::workload::tracespec::{TenantClass, TIER_INTERACTIVE, TIER_STANDARD};

/// Which shedding policy an `[admission]` table selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Admit everything (the default: structurally inert).
    None,
    /// Shed when the in-system count reaches a tier-scaled threshold.
    QueueDepth,
    /// Per-tenant token buckets (rate isolation).
    TokenBucket,
}

/// Parsed `[admission]` table. The default (`mode = None`) admits
/// everything and keeps every run bit-identical to pre-admission code.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionConfig {
    pub mode: AdmissionMode,
    /// Base in-system threshold for `queue-depth` (batch tier's limit;
    /// standard tolerates 2×, interactive 4×).
    pub queue_depth: usize,
    /// Full refill rate for `token-bucket` (tokens/s before the
    /// per-tenant share split).
    pub bucket_rps: f64,
    /// Bucket capacity (burst tolerance), in tokens.
    pub bucket_burst: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            mode: AdmissionMode::None,
            queue_depth: 64,
            bucket_rps: 8.0,
            bucket_burst: 16.0,
        }
    }
}

impl AdmissionConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.mode == AdmissionMode::QueueDepth && self.queue_depth == 0 {
            return Err("admission.queue_depth must be > 0".into());
        }
        if self.mode == AdmissionMode::TokenBucket
            && (self.bucket_rps <= 0.0 || self.bucket_burst < 1.0)
        {
            return Err("admission needs bucket_rps > 0 and bucket_burst >= 1".into());
        }
        Ok(())
    }

    /// Parse an `[admission]` table from a TOML document. Returns
    /// `Ok(None)` when no `admission.*` key is present (the subsystem
    /// stays inert); a present table must name its `mode`.
    pub fn from_doc(doc: &Document) -> Result<Option<AdmissionConfig>, String> {
        if !doc.entries.keys().any(|k| k.starts_with("admission.")) {
            return Ok(None);
        }
        let mut cfg = AdmissionConfig::default();
        cfg.mode = match doc.get_str("admission.mode") {
            Some("none") => AdmissionMode::None,
            Some("queue-depth") => AdmissionMode::QueueDepth,
            Some("token-bucket") => AdmissionMode::TokenBucket,
            Some(other) => {
                return Err(format!(
                    "unknown admission.mode '{other}' (none | queue-depth | token-bucket)"
                ))
            }
            None => return Err("[admission] table needs a mode key".into()),
        };
        if let Some(v) = doc.get_i64("admission.queue_depth") {
            cfg.queue_depth = v as usize;
        }
        if let Some(v) = doc.get_f64("admission.bucket_rps") {
            cfg.bucket_rps = v;
        }
        if let Some(v) = doc.get_f64("admission.bucket_burst") {
            cfg.bucket_burst = v;
        }
        cfg.validate()?;
        Ok(Some(cfg))
    }
}

/// Runtime admission state: the parsed config plus per-tenant token
/// buckets. Deterministic — refills are a pure function of event time,
/// never wall clock.
#[derive(Debug)]
pub struct AdmissionState {
    cfg: AdmissionConfig,
    /// Per-tenant buckets: (tokens, last refill time). Index = tenant
    /// id (0 = untenanted).
    buckets: Vec<(f64, Micros)>,
    /// Per-tenant arrival share (bucket refill split; id 0 gets 1.0).
    shares: Vec<f64>,
}

impl AdmissionState {
    pub fn new(cfg: AdmissionConfig, tenants: &[TenantClass]) -> Self {
        let mut shares = vec![1.0];
        shares.extend(tenants.iter().map(|t| t.share));
        AdmissionState {
            buckets: vec![(cfg.bucket_burst, 0); shares.len()],
            shares,
            cfg,
        }
    }

    /// Does `admit` need consulting at all? False keeps the arrival
    /// path bit-identical to pre-admission code.
    pub fn active(&self) -> bool {
        self.cfg.mode != AdmissionMode::None
    }

    /// Queue-depth headroom multiplier: higher-priority tiers tolerate
    /// deeper backlogs before shedding, so batch sheds first.
    fn depth_mult(tier: u8) -> usize {
        match tier {
            TIER_INTERACTIVE => 4,
            TIER_STANDARD => 2,
            _ => 1,
        }
    }

    /// Admit or shed one arrival. `in_system` is the number of
    /// requests arrived but not yet recorded (the cluster's live load
    /// proxy).
    pub fn admit(&mut self, now: Micros, tenant: u8, tier: u8, in_system: usize) -> bool {
        match self.cfg.mode {
            AdmissionMode::None => true,
            AdmissionMode::QueueDepth => {
                in_system <= self.cfg.queue_depth * Self::depth_mult(tier)
            }
            AdmissionMode::TokenBucket => {
                let idx = (tenant as usize).min(self.buckets.len() - 1);
                let rate = self.cfg.bucket_rps * self.shares[idx];
                let (tokens, last) = &mut self.buckets[idx];
                let dt_s = now.saturating_sub(*last) as f64 / SECOND as f64;
                *last = now;
                *tokens = (*tokens + dt_s * rate).min(self.cfg.bucket_burst);
                if *tokens >= 1.0 {
                    *tokens -= 1.0;
                    true
                } else {
                    false
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::tracespec::TIER_BATCH;

    fn tenants() -> Vec<TenantClass> {
        vec![
            TenantClass { name: "chat".into(), share: 0.5, tier: TIER_INTERACTIVE, slo_scale: 1.0 },
            TenantClass { name: "jobs".into(), share: 0.5, tier: TIER_BATCH, slo_scale: 4.0 },
        ]
    }

    #[test]
    fn default_is_inert_and_admits_everything() {
        let mut st = AdmissionState::new(AdmissionConfig::default(), &[]);
        assert!(!st.active());
        assert!(st.admit(0, 0, TIER_STANDARD, usize::MAX / 2));
    }

    #[test]
    fn queue_depth_sheds_batch_before_interactive() {
        let cfg = AdmissionConfig {
            mode: AdmissionMode::QueueDepth,
            queue_depth: 10,
            ..AdmissionConfig::default()
        };
        let mut st = AdmissionState::new(cfg, &tenants());
        assert!(st.active());
        // At depth 11 the batch tier (threshold 10) sheds while the
        // standard (20) and interactive (40) tiers still admit.
        assert!(!st.admit(0, 2, TIER_BATCH, 11));
        assert!(st.admit(0, 0, TIER_STANDARD, 11));
        assert!(st.admit(0, 1, TIER_INTERACTIVE, 11));
        // Interactive sheds last, at 4x the base threshold.
        assert!(!st.admit(0, 1, TIER_INTERACTIVE, 41));
    }

    #[test]
    fn token_bucket_isolates_tenants_and_refills() {
        let cfg = AdmissionConfig {
            mode: AdmissionMode::TokenBucket,
            bucket_rps: 2.0,
            bucket_burst: 2.0,
            ..AdmissionConfig::default()
        };
        let mut st = AdmissionState::new(cfg, &tenants());
        // Tenant 1 (share 0.5 -> 1 token/s) burns its 2-token burst...
        assert!(st.admit(0, 1, TIER_INTERACTIVE, 0));
        assert!(st.admit(0, 1, TIER_INTERACTIVE, 0));
        assert!(!st.admit(0, 1, TIER_INTERACTIVE, 0));
        // ...without touching tenant 2's bucket.
        assert!(st.admit(0, 2, TIER_BATCH, 0));
        // One second refills one token for tenant 1.
        assert!(st.admit(SECOND, 1, TIER_INTERACTIVE, 0));
        assert!(!st.admit(SECOND, 1, TIER_INTERACTIVE, 0));
    }

    #[test]
    fn from_doc_parses_and_rejects() {
        let doc = Document::parse("[admission]\nmode = \"queue-depth\"\nqueue_depth = 32").unwrap();
        let cfg = AdmissionConfig::from_doc(&doc).unwrap().unwrap();
        assert_eq!(cfg.mode, AdmissionMode::QueueDepth);
        assert_eq!(cfg.queue_depth, 32);
        // Absent table -> None (inert).
        let doc = Document::parse("preset = \"rapid-600\"").unwrap();
        assert!(AdmissionConfig::from_doc(&doc).unwrap().is_none());
        // A present table must name its mode; bad modes are named back.
        let doc = Document::parse("[admission]\nqueue_depth = 32").unwrap();
        assert!(AdmissionConfig::from_doc(&doc).unwrap_err().contains("mode"));
        let doc = Document::parse("[admission]\nmode = \"yolo\"").unwrap();
        assert!(AdmissionConfig::from_doc(&doc).unwrap_err().contains("yolo"));
        // Structural validation.
        let doc = Document::parse("[admission]\nmode = \"queue-depth\"\nqueue_depth = 0").unwrap();
        assert!(AdmissionConfig::from_doc(&doc).is_err());
        let doc =
            Document::parse("[admission]\nmode = \"token-bucket\"\nbucket_rps = -1").unwrap();
        assert!(AdmissionConfig::from_doc(&doc).is_err());
    }
}
