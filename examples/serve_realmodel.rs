//! End-to-end validation driver (the run recorded in EXPERIMENTS.md):
//! load the real AOT-compiled mini-Llama, serve batched Poisson traffic
//! through the full disaggregated coordinator topology (router -> prefill
//! workers -> KV ring -> decode workers) on PJRT CPU, and report
//! latency/throughput — proving all three layers compose.
//!
//! The run is repeated under two power-cap pacings to show the paper's
//! asymmetry on the *real* path: raising the prefill cap cuts TTFT, while
//! raising the decode cap above its knee does nothing.
//!
//! Run: `cargo run --release --example serve_realmodel [-- <n> <qps>]`

use rapid::server::{report, serve, ServeCaps, ServeRequest};
use rapid::util::stats::percentile;

fn mk_requests(n: usize) -> Vec<ServeRequest> {
    let corpus = [
        "the compound annual growth rate of generative ai revenue is astounding",
        "data centers are projected to consume a large share of total power",
        "disaggregation separates the prefill and decode phases of inference",
        "power rather than compute has become the dominant limiter",
        "goodput tracks requests that meet both latency targets",
        "the scheduler reacts to queue growth before violations become severe",
        "a cooldown period prevents oscillatory reallocation behaviour",
        "prefill is compute intensive and decode is memory intensive",
    ];
    (0..n)
        .map(|i| ServeRequest {
            id: i as u64,
            prompt: corpus[i % corpus.len()].to_string(),
            max_new_tokens: 8 + (i % 4) * 4,
        })
        .collect()
}

/// Returns (p50 TTFT us, mean paced decode step us, mean paced prefill us).
fn run_once(
    artifacts: &str,
    n: usize,
    qps: f64,
    caps: ServeCaps,
) -> anyhow::Result<(f64, f64, f64)> {
    let t0 = std::time::Instant::now();
    let (outcomes, stats) = serve(artifacts, mk_requests(n), qps, 2, 2, caps)?;
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(outcomes.len(), n, "all requests must complete");
    println!(
        "caps {:>3.0}W prefill / {:>3.0}W decode:",
        caps.prefill_w, caps.decode_w
    );
    println!("{}", report(&outcomes, wall));
    println!(
        "mean paced decode step {:.1} ms | paced prefill batch {:.1} ms\n",
        stats.decode_step_us / 1000.0,
        stats.prefill_exec_us / 1000.0
    );
    let ttfts: Vec<f64> = outcomes.iter().map(|o| o.record.ttft() as f64).collect();
    Ok((
        percentile(&ttfts, 50.0),
        stats.decode_step_us,
        stats.prefill_exec_us,
    ))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let artifacts = "artifacts";
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    // High default rate: all requests arrive quickly, so every run forms
    // the same full batches and per-batch means are comparable across
    // power-cap settings.
    let qps: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(20.0);

    println!("== E2E: mini-Llama on PJRT CPU, 2 prefill + 2 decode workers ==\n");
    // Paper's static winner: max prefill power, decode at 450 W.
    let (ttft_hi, step_450, prefill_750) = run_once(
        artifacts,
        n,
        qps,
        ServeCaps {
            prefill_w: 750.0,
            decode_w: 450.0,
        },
    )?;
    // Starved prefill: the TTFT cost of low prefill power.
    let (ttft_lo, _, prefill_400) = run_once(
        artifacts,
        n,
        qps,
        ServeCaps {
            prefill_w: 400.0,
            decode_w: 450.0,
        },
    )?;
    // Decode above the knee: the paced step should improve only mildly.
    let (_, step_600, _) = run_once(
        artifacts,
        n,
        qps,
        ServeCaps {
            prefill_w: 750.0,
            decode_w: 600.0,
        },
    )?;

    println!("== paper-shape checks on the real path ==");
    // Per-step paced means are far more stable than end-to-end latency,
    // but this is a shared CPU: the bands are wide to tolerate background
    // load (run on a quiet machine for tight numbers). End-to-end TTFT is
    // reported for context — it amplifies through queueing.
    let prefill_gain = prefill_400 / prefill_750.max(1.0);
    println!(
        "  [{}] prefill 400->750 W pacing speeds up prefill (x{prefill_gain:.2}, model ~1.8; \
         end-to-end TTFT p50 {:.0} -> {:.0} ms)",
        if (1.2..4.0).contains(&prefill_gain) { "PASS" } else { "FAIL" },
        ttft_lo / 1000.0,
        ttft_hi / 1000.0,
    );
    let decode_gain = step_450 / step_600.max(1.0);
    println!(
        "  [{}] decode 450->600 W pacing helps the step only mildly (x{decode_gain:.2}, model ~1.16)",
        if (0.5..2.0).contains(&decode_gain) { "PASS" } else { "FAIL" }
    );
    Ok(())
}
