//! Workload generation: statistical replicas of the paper's datasets.
//!
//! The coordinator only observes `(arrival, input_tokens, output_tokens)`,
//! so a dataset is reproduced by matching those marginals:
//!
//! * [`longbench`] — long-tailed prompt lengths capped at 8 K tokens with
//!   modest outputs (paper §4: "LongBench … maximum of 8K input tokens");
//! * [`sonnet`] — fixed-size prompts/outputs for controlled experiments
//!   (8K/128 prefill-heavy, 512/512 decode-heavy), including the Fig 8/9
//!   two-phase mixed trace;
//! * [`arrivals`] — Poisson arrival processes plus a bursty variant.

pub mod arrivals;
pub mod longbench;
pub mod sonnet;
pub mod trace;

pub use arrivals::{ArrivalProcess, Burstiness};
pub use trace::Trace;

use crate::types::{Micros, Request, RequestId, Slo};

/// Anything that can produce the token-size profile of request `i`.
pub trait SizeSampler {
    /// (input_tokens, output_tokens) for the i-th request.
    fn sample(&mut self, i: usize) -> (u32, u32);
}

/// Assemble a full trace from an arrival process + size sampler + SLO.
pub fn build_trace<S: SizeSampler>(
    n: usize,
    arrivals: &mut ArrivalProcess,
    sizes: &mut S,
    slo: Slo,
) -> Trace {
    let mut requests = Vec::with_capacity(n);
    let mut t: Micros = 0;
    for i in 0..n {
        t = arrivals.next_after(t);
        let (input_tokens, output_tokens) = sizes.sample(i);
        requests.push(Request {
            id: RequestId(i as u64),
            arrival: t,
            input_tokens,
            output_tokens,
            slo,
        });
    }
    Trace { requests }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    struct Fixed;
    impl SizeSampler for Fixed {
        fn sample(&mut self, _i: usize) -> (u32, u32) {
            (100, 10)
        }
    }

    #[test]
    fn build_trace_monotone_arrivals_and_ids() {
        let mut ap = ArrivalProcess::poisson(Rng::new(1), 10.0);
        let trace = build_trace(100, &mut ap, &mut Fixed, Slo::paper_default());
        assert_eq!(trace.requests.len(), 100);
        for w in trace.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
            assert!(w[0].id < w[1].id);
        }
    }
}
