//! Quickstart: the smallest end-to-end use of the RAPID stack.
//!
//! 1. Load the AOT artifacts (run `make artifacts` first).
//! 2. Serve a handful of prompts through the disaggregated
//!    prefill/decode workers on the PJRT CPU runtime.
//! 3. Print per-request TTFT/TPOT and the throughput report.
//!
//! Run: `cargo run --release --example quickstart`

use rapid::server::{serve, report, ServeCaps, ServeRequest};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let prompts = [
        "hello, disaggregated world",
        "prefill wants power",
        "decode wants slots",
        "the budget is fixed",
    ];
    let requests: Vec<ServeRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| ServeRequest {
            id: i as u64,
            prompt: p.to_string(),
            max_new_tokens: 8,
        })
        .collect();

    println!("loading {artifacts}/ and serving {} prompts...", requests.len());
    let t0 = std::time::Instant::now();
    let (outcomes, _stats) = serve(&artifacts, requests, 8.0, 1, 1, ServeCaps::default())?;
    for o in &outcomes {
        println!(
            "  {}: ttft={:>5.1} ms  tpot={:>6.1} ms  {} tokens",
            o.record.id,
            o.record.ttft() as f64 / 1000.0,
            o.record.tpot() as f64 / 1000.0,
            o.record.output_tokens,
        );
    }
    println!("\n{}", report(&outcomes, t0.elapsed().as_secs_f64()));
    Ok(())
}
