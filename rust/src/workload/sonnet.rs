//! Sonnet-style controlled workloads (paper §4, §5.2).
//!
//! The paper uses Sonnet to "verify the robustness of the dynamic RAPID
//! algorithm for varying input sizes and distributions in a controlled
//! manner". `Sonnet` emits fixed-size requests with small jitter;
//! `MixedPhases` reproduces the Fig 8/9 trace structure: a prefill-heavy
//! phase followed by a decode-heavy phase, with the TPOT SLO tightening
//! from 40 ms to 20 ms in phase two.
//!
//! Substitution note (DESIGN.md §2): the paper's token budgets
//! (8K/128 then 500/500 at 2.0 QPS/GPU) presume its testbed's
//! prefill:decode capacity ratio. On our calibrated substrate the same
//! *stress pattern* — phase 1 saturates the prefill pool, phase 2
//! saturates the decode pool, each relieved by ~2 extra GPUs — lands at
//! 4K/64 then 128/1280 at ~1.05 QPS/GPU. The controller sees the same
//! signals; only the absolute token counts differ.

use crate::types::{Micros, Request, RequestId, Slo, MILLIS, SECOND};
use crate::util::rng::Rng;
use crate::workload::{ArrivalProcess, SizeSampler, Trace};

/// Fixed-size sampler with ±`jitter_frac` uniform jitter.
#[derive(Debug, Clone)]
pub struct Sonnet {
    rng: Rng,
    pub input_tokens: u32,
    pub output_tokens: u32,
    pub jitter_frac: f64,
}

impl Sonnet {
    pub fn new(rng: Rng, input_tokens: u32, output_tokens: u32) -> Self {
        Sonnet {
            rng,
            input_tokens,
            output_tokens,
            jitter_frac: 0.05,
        }
    }

    fn jitter(&mut self, v: u32) -> u32 {
        if self.jitter_frac == 0.0 {
            return v;
        }
        let f = 1.0 + self.rng.range_f64(-self.jitter_frac, self.jitter_frac);
        ((v as f64 * f) as u32).max(1)
    }
}

impl SizeSampler for Sonnet {
    fn sample(&mut self, _i: usize) -> (u32, u32) {
        (self.jitter(self.input_tokens), self.jitter(self.output_tokens))
    }
}

/// Parameters of the Fig 8/9 two-phase synthetic workload.
#[derive(Debug, Clone, Copy)]
pub struct MixedPhasesSpec {
    pub prefill_heavy_count: usize,
    pub decode_heavy_count: usize,
    /// Node-level arrival rate (QPS) for both phases.
    pub rate_qps: f64,
    pub ttft_slo: Micros,
    /// TPOT SLO during the prefill-heavy phase (paper: 40 ms).
    pub tpot_slo_phase1: Micros,
    /// TPOT SLO during the decode-heavy phase (paper: 20 ms).
    pub tpot_slo_phase2: Micros,
    /// (input, output) tokens of the prefill-heavy phase.
    pub heavy_shape: (u32, u32),
    /// (input, output) tokens of the decode-heavy phase.
    pub light_shape: (u32, u32),
}

impl Default for MixedPhasesSpec {
    fn default() -> Self {
        MixedPhasesSpec {
            prefill_heavy_count: 1000,
            decode_heavy_count: 1000,
            // The paper's 2.0 QPS/GPU maps to ~1.05 on this substrate
            // (see module docs).
            rate_qps: 8.4,
            ttft_slo: SECOND,
            tpot_slo_phase1: 40 * MILLIS,
            tpot_slo_phase2: 20 * MILLIS,
            heavy_shape: (4096, 64),
            light_shape: (128, 1280),
        }
    }
}

/// Build the Fig 8/9 trace: phase 1 = 8K/128 @40ms TPOT SLO, phase 2 =
/// 500/500 @20ms TPOT SLO, Poisson arrivals throughout.
pub fn mixed_phases(seed: u64, spec: MixedPhasesSpec) -> Trace {
    let mut root = Rng::new(seed);
    let mut ap = ArrivalProcess::poisson(root.fork(0), spec.rate_qps);
    let mut heavy = Sonnet::new(root.fork(1), spec.heavy_shape.0, spec.heavy_shape.1);
    let mut light = Sonnet::new(root.fork(2), spec.light_shape.0, spec.light_shape.1);
    let mut requests = Vec::with_capacity(spec.prefill_heavy_count + spec.decode_heavy_count);
    let mut t: Micros = 0;
    for i in 0..(spec.prefill_heavy_count + spec.decode_heavy_count) {
        t = ap.next_after(t);
        let phase1 = i < spec.prefill_heavy_count;
        let (input_tokens, output_tokens) = if phase1 {
            heavy.sample(i)
        } else {
            light.sample(i)
        };
        let slo = Slo::new(
            spec.ttft_slo,
            if phase1 {
                spec.tpot_slo_phase1
            } else {
                spec.tpot_slo_phase2
            },
        );
        requests.push(Request {
            id: RequestId(i as u64),
            arrival: t,
            input_tokens,
            output_tokens,
            slo,
            tenant: 0,
        });
    }
    Trace { requests, ..Trace::default() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sonnet_sizes_near_targets() {
        let mut s = Sonnet::new(Rng::new(1), 8192, 128);
        for i in 0..1000 {
            let (inp, out) = s.sample(i);
            assert!((7700..=8700).contains(&inp), "inp={inp}");
            assert!((121..=135).contains(&out), "out={out}");
        }
    }

    #[test]
    fn sonnet_zero_jitter_is_exact() {
        let mut s = Sonnet::new(Rng::new(2), 512, 512);
        s.jitter_frac = 0.0;
        assert_eq!(s.sample(0), (512, 512));
    }

    #[test]
    fn mixed_phases_shape() {
        let trace = mixed_phases(42, MixedPhasesSpec::default());
        assert_eq!(trace.requests.len(), 2000);
        // Phase 1: prefill heavy
        let p1 = &trace.requests[..1000];
        assert!(p1.iter().all(|r| r.input_tokens > 3500 && r.output_tokens < 100));
        assert!(p1.iter().all(|r| r.slo.tpot == 40 * MILLIS));
        // Phase 2: decode heavy, tighter TPOT
        let p2 = &trace.requests[1000..];
        assert!(p2.iter().all(|r| r.input_tokens < 200 && r.output_tokens > 1000));
        assert!(p2.iter().all(|r| r.slo.tpot == 20 * MILLIS));
        // Arrivals monotone across the phase boundary.
        for w in trace.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn mixed_phases_deterministic_per_seed() {
        let a = mixed_phases(7, MixedPhasesSpec::default());
        let b = mixed_phases(7, MixedPhasesSpec::default());
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.input_tokens, y.input_tokens);
        }
    }
}
