//! Power subsystem: performance/power model, cap ramp dynamics, and the
//! node-level power manager that enforces the budget + source-before-sink
//! shifting protocol (paper §2).

pub mod capper;
pub mod manager;
pub mod model;

pub use capper::{CapState, RampProfile};
pub use manager::{PowerError, PowerManager, PowerMove};
pub use model::PowerModel;
