//! Calibrated power→performance model (paper Fig 4, DESIGN.md §4).
//!
//! The paper measured Llama-3.1-8B on an MI300X at caps 400–750 W:
//!   * prefill (compute-bound) speeds up ≈1.8x from 400 W to 750 W and
//!     flattens above ~700 W;
//!   * decode (memory-bound) speeds up ≈1.3–1.5x and flattens above
//!     ~600 W — the asymmetry RAPID exploits.
//!
//! We model each phase's speedup (relative to 400 W) as a saturating
//! exponential with the knee/max taken from the figure, and derive batch
//! latencies from calibrated base rates. Power *draw* is modelled as
//! idle + utilization-dependent dynamic power, clipped by the cap.

use crate::config::PerfModelConfig;
use crate::types::{Micros, Watts};

/// Reference power of the paper's speedup curves (lowest cap in Fig 4).
/// Per-SKU models may anchor lower via `PerfModelConfig::ref_w`.
pub const REF_W: Watts = 400.0;

/// Saturating speedup curve: 1.0 at `ref`, `max` at/above `knee`.
/// Exponential approach keeps the marginal gain per 50 W step roughly
/// matching Fig 4 (steady gains, then a flat tail).
fn saturating_speedup(power: Watts, ref_w: Watts, knee: Watts, max: f64) -> f64 {
    if knee <= ref_w {
        return max; // degenerate curve: flat at max everywhere
    }
    // No upper clamp needed: anything at/above the knee is flat at max,
    // and a `clamp(ref_w, CONST)` would panic for SKUs anchored above
    // the constant.
    let p = power.max(ref_w);
    if p >= knee {
        return max;
    }
    // Normalized position in [0,1] with an exponential shoulder.
    let x = (p - ref_w) / (knee - ref_w);
    let k = 0.5; // shoulder sharpness: 600 W prefill ≈ 15% slower than 750 W (§5.1)
    let frac = (1.0 - (-k * x).exp()) / (1.0 - (-k_f()).exp());
    1.0 + (max - 1.0) * frac.min(1.0)
}

#[inline]
fn k_f() -> f64 {
    0.5
}

/// The whole-node performance/power model. Cheap to copy; all methods are
/// pure so both the DES and the real-serving pacer share it.
#[derive(Debug, Clone)]
pub struct PowerModel {
    cfg: PerfModelConfig,
}

impl PowerModel {
    pub fn new(cfg: PerfModelConfig) -> Self {
        PowerModel { cfg }
    }

    pub fn cfg(&self) -> &PerfModelConfig {
        &self.cfg
    }

    /// Prefill speedup at `power` relative to the curve floor `ref_w`
    /// (Fig 4a; 400 W on the paper's MI300X-class part).
    pub fn prefill_speedup(&self, power: Watts) -> f64 {
        saturating_speedup(
            power,
            self.cfg.ref_w,
            self.cfg.prefill_knee_w,
            self.cfg.prefill_speedup_max,
        )
    }

    /// Decode speedup at `power` relative to the curve floor (Fig 4b).
    pub fn decode_speedup(&self, power: Watts) -> f64 {
        saturating_speedup(
            power,
            self.cfg.ref_w,
            self.cfg.decode_knee_w,
            self.cfg.decode_speedup_max,
        )
    }

    /// Prompt-processing rate (tokens/s) of one prefill GPU at `power`.
    /// `prefill_rate_tps` is quoted at `rated_w` (750 W for the paper's
    /// part); other SKUs quote at their own rated power.
    pub fn prefill_rate(&self, power: Watts) -> f64 {
        let at_max = self.cfg.prefill_rate_tps;
        let su_max = self.prefill_speedup(self.cfg.rated_w);
        at_max * self.prefill_speedup(power) / su_max
    }

    /// Execution time of a prefill batch totalling `tokens` prompt tokens.
    pub fn prefill_batch_time(&self, tokens: u32, power: Watts) -> Micros {
        let secs = tokens as f64 / self.prefill_rate(power);
        self.cfg.prefill_overhead + (secs * 1e6) as Micros
    }

    /// One decode iteration with `batch` active requests whose mean live
    /// context is `mean_ctx_tokens`, at `power`. Memory-bound: base
    /// (weight streaming) + per-request scheduling + per-request KV reads
    /// proportional to context length.
    pub fn decode_step_time(&self, batch: usize, mean_ctx_tokens: f64, power: Watts) -> Micros {
        if batch == 0 {
            return 0;
        }
        let ctx = mean_ctx_tokens.min(self.cfg.decode_kv_ctx_cap_tokens);
        let kv = self.cfg.decode_kv_us_per_ktok * (ctx / 1000.0);
        let at_rated = self.cfg.decode_base as f64
            + (self.cfg.decode_per_req as f64 + kv) * batch as f64;
        let su_rated = self.decode_speedup(self.cfg.decode_rated_w);
        (at_rated * su_rated / self.decode_speedup(power)) as Micros
    }

    /// Latency of a chunked-prefill coalesced iteration: a prefill chunk of
    /// `chunk_tokens` (having already processed `done_tokens` of the same
    /// prompt) co-scheduled with `decode_batch` decode requests
    /// (Sarathi-style). Two interference terms the disaggregated path does
    /// not pay: cross-chunk attention re-reads (`chunk_reread_frac` of the
    /// prompt prefix re-touched per chunk) and the piggybacked decode cost.
    pub fn coalesced_step_time(
        &self,
        chunk_tokens: u32,
        done_tokens: u32,
        decode_batch: usize,
        mean_ctx_tokens: f64,
        power: Watts,
    ) -> Micros {
        let prefill_part = if chunk_tokens > 0 {
            let effective =
                chunk_tokens as f64 + self.cfg.chunk_reread_frac * done_tokens as f64;
            self.prefill_batch_time(effective as u32, power)
        } else {
            0
        };
        let decode_part = self.decode_step_time(decode_batch, mean_ctx_tokens, power);
        // Overlap factor: chunked prefill hides part of the decode cost
        // inside the chunk's compute, but interference remains (the
        // motivation for disaggregation).
        if chunk_tokens > 0 {
            prefill_part + (decode_part as f64 * 0.6) as Micros
        } else {
            decode_part
        }
    }

    /// KV-cache transfer time for `tokens` over the intra-node link.
    pub fn kv_transfer_time(&self, tokens: u32) -> Micros {
        let bytes = tokens as u64 * self.cfg.kv_bytes_per_token;
        ((bytes as f64 / self.cfg.xgmi_bw) * 1e6) as Micros
    }

    /// KV-cache transfer time between nodes (RDMA-class link, slower than
    /// XGMI — the locality cost cross-node routing weighs).
    pub fn kv_transfer_time_cross_node(&self, tokens: u32) -> Micros {
        let bytes = tokens as u64 * self.cfg.kv_bytes_per_token;
        ((bytes as f64 / self.cfg.inter_node_bw) * 1e6) as Micros
    }

    /// Transfer time picking the right link for the hop.
    pub fn kv_transfer_time_between(&self, tokens: u32, same_node: bool) -> Micros {
        if same_node {
            self.kv_transfer_time(tokens)
        } else {
            self.kv_transfer_time_cross_node(tokens)
        }
    }

    /// KV transfer time at an explicit link bandwidth (bytes/s). The
    /// fleet layer uses this with the *slower endpoint's* bandwidth when
    /// the two ends of a hop are different SKUs.
    pub fn kv_transfer_time_at_bw(&self, tokens: u32, bw: f64) -> Micros {
        let bytes = tokens as u64 * self.cfg.kv_bytes_per_token;
        ((bytes as f64 / bw) * 1e6) as Micros
    }

    /// Instantaneous power draw of a GPU at `cap` with `util` in [0,1].
    /// Prefill saturates its cap; decode tops out near its knee (it cannot
    /// pull much more power even uncapped — memory-bound). The result is
    /// clamped into `[idle_w, cap]` (degenerating to `cap` when the cap
    /// sits below idle, and to 0 for a nonsensical negative cap), so
    /// per-SKU power accounting can never go negative or exceed the cap.
    pub fn draw(&self, cap: Watts, util: f64, is_prefill: bool) -> Watts {
        let util = util.clamp(0.0, 1.0);
        let cap = cap.max(0.0);
        let ceiling = if is_prefill {
            cap
        } else {
            // Decode rarely draws far above its knee even when allowed.
            cap.min(self.cfg.decode_knee_w + 20.0)
        };
        let dynamic = (ceiling - self.cfg.idle_w).max(0.0) * util;
        (self.cfg.idle_w + dynamic).clamp(self.cfg.idle_w.min(cap), cap)
    }

    /// Idle draw (W).
    pub fn idle_w(&self) -> Watts {
        self.cfg.idle_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> PowerModel {
        PowerModel::new(PerfModelConfig::default())
    }

    #[test]
    fn speedup_anchors_match_paper() {
        let m = model();
        assert!((m.prefill_speedup(400.0) - 1.0).abs() < 1e-9);
        assert!((m.prefill_speedup(750.0) - 1.8).abs() < 1e-9);
        assert!((m.decode_speedup(400.0) - 1.0).abs() < 1e-9);
        let d600 = m.decode_speedup(600.0);
        assert!((d600 - 1.45).abs() < 1e-9, "decode flat by 600: {d600}");
        // above the knee: flat
        assert_eq!(m.decode_speedup(700.0), m.decode_speedup(750.0));
    }

    #[test]
    fn speedups_monotone_in_power() {
        let m = model();
        let mut last_p = 0.0;
        let mut last_d = 0.0;
        for w in (400..=750).step_by(50) {
            let p = m.prefill_speedup(w as f64);
            let d = m.decode_speedup(w as f64);
            assert!(p >= last_p && d >= last_d, "monotone at {w}");
            last_p = p;
            last_d = d;
        }
    }

    #[test]
    fn prefill_600_vs_750_gap_about_15pct() {
        // Paper §5.1: 600 W prefill is ~15% slower than 750 W.
        let m = model();
        let t600 = m.prefill_batch_time(4096, 600.0);
        let t750 = m.prefill_batch_time(4096, 750.0);
        let slowdown = t600 as f64 / t750 as f64;
        assert!(
            (1.08..=1.25).contains(&slowdown),
            "600W/750W prefill ratio {slowdown}"
        );
    }

    #[test]
    fn decode_power_insensitive_above_knee() {
        let m = model();
        let t600 = m.decode_step_time(16, 2000.0, 600.0);
        let t750 = m.decode_step_time(16, 2000.0, 750.0);
        assert_eq!(t600, t750, "decode gains above 600 W should be zero");
        let t450 = m.decode_step_time(16, 2000.0, 450.0);
        assert!(t450 > t600, "decode slower below the knee");
        // ... but not catastrophically (Fig 4b spans ~1.45x total)
        assert!((t450 as f64 / t600 as f64) < 1.45);
    }

    #[test]
    fn decode_step_scales_with_context() {
        let m = model();
        let short = m.decode_step_time(8, 500.0, 600.0);
        let long = m.decode_step_time(8, 2000.0, 600.0);
        assert!(long > short, "KV reads grow with context");
        // ... but saturate once the stream is bandwidth-bound.
        let capped = m.decode_step_time(8, 2500.0, 600.0);
        let beyond = m.decode_step_time(8, 8000.0, 600.0);
        assert_eq!(capped, beyond, "KV cost saturates past the cap");
    }

    #[test]
    fn prefill_batch_time_scales_with_tokens() {
        let m = model();
        let t1 = m.prefill_batch_time(1024, 750.0);
        let t4 = m.prefill_batch_time(4096, 750.0);
        let ratio = (t4 - m.cfg().prefill_overhead) as f64
            / (t1 - m.cfg().prefill_overhead) as f64;
        assert!((ratio - 4.0).abs() < 0.01);
    }

    #[test]
    fn decode_step_scales_with_batch() {
        let m = model();
        assert!(m.decode_step_time(32, 1000.0, 600.0) > m.decode_step_time(1, 1000.0, 600.0));
        assert_eq!(m.decode_step_time(0, 1000.0, 600.0), 0);
    }

    #[test]
    fn coalesced_step_shows_interference() {
        let m = model();
        let pure_prefill = m.prefill_batch_time(512, 750.0);
        let mixed = m.coalesced_step_time(512, 0, 16, 1000.0, 750.0);
        assert!(mixed > pure_prefill, "decode piggyback adds interference");
        let pure_decode = m.coalesced_step_time(0, 0, 16, 1000.0, 750.0);
        assert_eq!(pure_decode, m.decode_step_time(16, 1000.0, 750.0));
    }

    #[test]
    fn chunk_reread_taxes_deep_chunks() {
        // A chunk late in a long prompt costs more than the first chunk.
        let m = model();
        let first = m.coalesced_step_time(512, 0, 0, 0.0, 750.0);
        let deep = m.coalesced_step_time(512, 7680, 0, 0.0, 750.0);
        assert!(deep > first, "re-read tax: {deep} <= {first}");
        // One-shot prefill of the whole prompt beats the sum of chunks.
        let oneshot = m.prefill_batch_time(8192, 750.0);
        let chunked: u64 = (0..16)
            .map(|i| m.coalesced_step_time(512, i * 512, 0, 0.0, 750.0))
            .sum();
        assert!(chunked > oneshot, "chunked {chunked} <= oneshot {oneshot}");
    }

    #[test]
    fn kv_transfer_reasonable() {
        let m = model();
        // 4096 tokens * 128 KiB = 512 MiB over 64 GB/s ≈ 8.4 ms
        let t = m.kv_transfer_time(4096);
        assert!((7_000..10_000).contains(&t), "t={t}");
    }

    #[test]
    fn cross_node_transfer_slower_than_xgmi() {
        let m = model();
        let local = m.kv_transfer_time_between(4096, true);
        let remote = m.kv_transfer_time_between(4096, false);
        assert_eq!(local, m.kv_transfer_time(4096));
        assert!(
            remote > local * 2,
            "RDMA hop must clearly exceed XGMI: {remote} vs {local}"
        );
    }

    #[test]
    fn draw_respects_cap_and_idle() {
        let m = model();
        assert_eq!(m.draw(750.0, 0.0, true), m.idle_w());
        assert_eq!(m.draw(750.0, 1.0, true), 750.0);
        // decode can't pull 750 even when allowed
        assert!(m.draw(750.0, 1.0, false) <= 620.0 + 1e-9);
        // cap always wins
        assert!(m.draw(450.0, 1.0, true) <= 450.0);
    }

    #[test]
    fn rate_at_750_matches_config() {
        let m = model();
        assert!((m.prefill_rate(750.0) - 9_300.0).abs() < 1e-6);
    }

    #[test]
    fn draw_clamps_util_above_one() {
        let m = model();
        assert_eq!(m.draw(750.0, 3.5, true), m.draw(750.0, 1.0, true));
        assert_eq!(m.draw(600.0, -1.0, true), m.idle_w());
    }

    #[test]
    fn draw_cap_below_idle_returns_cap() {
        // A cap below idle cannot be honored by lowering draw below the
        // floor; the firmware cap wins and the draw pins at the cap.
        let m = model();
        let idle = m.idle_w();
        assert!(idle > 100.0, "test assumes idle around 140 W");
        assert_eq!(m.draw(100.0, 0.0, true), 100.0);
        assert_eq!(m.draw(100.0, 1.0, false), 100.0);
        // Nonsensical negative cap degrades to zero, never negative.
        assert_eq!(m.draw(-50.0, 1.0, true), 0.0);
        assert!(m.draw(-50.0, 0.3, false) >= 0.0);
    }

    #[test]
    fn draw_never_leaves_idle_cap_interval() {
        let m = model();
        for cap in [400.0, 500.0, 600.0, 750.0] {
            for util in [0.0, 0.3, 0.7, 1.0, 2.0] {
                for pf in [true, false] {
                    let d = m.draw(cap, util, pf);
                    assert!(d >= m.idle_w() - 1e-9 && d <= cap + 1e-9, "{cap} {util} {pf}: {d}");
                }
            }
        }
    }

    #[test]
    fn explicit_bw_transfer_matches_link_helpers() {
        let m = model();
        assert_eq!(
            m.kv_transfer_time_at_bw(4096, m.cfg().xgmi_bw),
            m.kv_transfer_time(4096)
        );
        assert_eq!(
            m.kv_transfer_time_at_bw(4096, m.cfg().inter_node_bw),
            m.kv_transfer_time_cross_node(4096)
        );
    }

    #[test]
    fn shifted_curve_anchor_rescales_rates() {
        // A SKU whose curve spans [250, 400] W: speedup 1.0 at 250,
        // flat at its max by 400, with the rate quoted at rated_w.
        let cfg = PerfModelConfig {
            ref_w: 250.0,
            rated_w: 400.0,
            prefill_knee_w: 390.0,
            prefill_speedup_max: 1.4,
            prefill_rate_tps: 5_000.0,
            ..PerfModelConfig::default()
        };
        let m = PowerModel::new(cfg);
        assert!((m.prefill_speedup(250.0) - 1.0).abs() < 1e-9);
        assert!((m.prefill_speedup(400.0) - 1.4).abs() < 1e-9);
        assert!((m.prefill_rate(400.0) - 5_000.0).abs() < 1e-6);
        assert!(m.prefill_rate(250.0) < 5_000.0);
    }
}
