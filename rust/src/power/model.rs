//! Calibrated power→performance model (paper Fig 4, DESIGN.md §4).
//!
//! The paper measured Llama-3.1-8B on an MI300X at caps 400–750 W:
//!   * prefill (compute-bound) speeds up ≈1.8x from 400 W to 750 W and
//!     flattens above ~700 W;
//!   * decode (memory-bound) speeds up ≈1.3–1.5x and flattens above
//!     ~600 W — the asymmetry RAPID exploits.
//!
//! We model each phase's speedup (relative to 400 W) as a saturating
//! exponential with the knee/max taken from the figure, and derive batch
//! latencies from calibrated base rates. Power *draw* is modelled as
//! idle + utilization-dependent dynamic power, clipped by the cap.

use crate::config::PerfModelConfig;
use crate::types::{Micros, Watts};

/// Reference power for the speedup curves (lowest cap in Fig 4).
pub const REF_W: Watts = 400.0;

/// Saturating speedup curve: 1.0 at `REF_W`, `max` at/above `knee`.
/// Exponential approach keeps the marginal gain per 50 W step roughly
/// matching Fig 4 (steady gains, then a flat tail).
fn saturating_speedup(power: Watts, knee: Watts, max: f64) -> f64 {
    let p = power.clamp(REF_W, 1000.0);
    if p >= knee {
        return max;
    }
    // Normalized position in [0,1] with an exponential shoulder.
    let x = (p - REF_W) / (knee - REF_W);
    let k = 0.5; // shoulder sharpness: 600 W prefill ≈ 15% slower than 750 W (§5.1)
    let frac = (1.0 - (-k * x).exp()) / (1.0 - (-k_f()).exp());
    1.0 + (max - 1.0) * frac.min(1.0)
}

#[inline]
fn k_f() -> f64 {
    0.5
}

/// The whole-node performance/power model. Cheap to copy; all methods are
/// pure so both the DES and the real-serving pacer share it.
#[derive(Debug, Clone)]
pub struct PowerModel {
    cfg: PerfModelConfig,
}

impl PowerModel {
    pub fn new(cfg: PerfModelConfig) -> Self {
        PowerModel { cfg }
    }

    pub fn cfg(&self) -> &PerfModelConfig {
        &self.cfg
    }

    /// Prefill speedup at `power` relative to 400 W (Fig 4a).
    pub fn prefill_speedup(&self, power: Watts) -> f64 {
        saturating_speedup(power, self.cfg.prefill_knee_w, self.cfg.prefill_speedup_max)
    }

    /// Decode speedup at `power` relative to 400 W (Fig 4b).
    pub fn decode_speedup(&self, power: Watts) -> f64 {
        saturating_speedup(power, self.cfg.decode_knee_w, self.cfg.decode_speedup_max)
    }

    /// Prompt-processing rate (tokens/s) of one prefill GPU at `power`.
    pub fn prefill_rate(&self, power: Watts) -> f64 {
        let at_max = self.cfg.prefill_rate_tps;
        let su_max = self.prefill_speedup(750.0);
        at_max * self.prefill_speedup(power) / su_max
    }

    /// Execution time of a prefill batch totalling `tokens` prompt tokens.
    pub fn prefill_batch_time(&self, tokens: u32, power: Watts) -> Micros {
        let secs = tokens as f64 / self.prefill_rate(power);
        self.cfg.prefill_overhead + (secs * 1e6) as Micros
    }

    /// One decode iteration with `batch` active requests whose mean live
    /// context is `mean_ctx_tokens`, at `power`. Memory-bound: base
    /// (weight streaming) + per-request scheduling + per-request KV reads
    /// proportional to context length.
    pub fn decode_step_time(&self, batch: usize, mean_ctx_tokens: f64, power: Watts) -> Micros {
        if batch == 0 {
            return 0;
        }
        let ctx = mean_ctx_tokens.min(self.cfg.decode_kv_ctx_cap_tokens);
        let kv = self.cfg.decode_kv_us_per_ktok * (ctx / 1000.0);
        let at_600 = self.cfg.decode_base as f64
            + (self.cfg.decode_per_req as f64 + kv) * batch as f64;
        let su_600 = self.decode_speedup(600.0);
        (at_600 * su_600 / self.decode_speedup(power)) as Micros
    }

    /// Latency of a chunked-prefill coalesced iteration: a prefill chunk of
    /// `chunk_tokens` (having already processed `done_tokens` of the same
    /// prompt) co-scheduled with `decode_batch` decode requests
    /// (Sarathi-style). Two interference terms the disaggregated path does
    /// not pay: cross-chunk attention re-reads (`chunk_reread_frac` of the
    /// prompt prefix re-touched per chunk) and the piggybacked decode cost.
    pub fn coalesced_step_time(
        &self,
        chunk_tokens: u32,
        done_tokens: u32,
        decode_batch: usize,
        mean_ctx_tokens: f64,
        power: Watts,
    ) -> Micros {
        let prefill_part = if chunk_tokens > 0 {
            let effective =
                chunk_tokens as f64 + self.cfg.chunk_reread_frac * done_tokens as f64;
            self.prefill_batch_time(effective as u32, power)
        } else {
            0
        };
        let decode_part = self.decode_step_time(decode_batch, mean_ctx_tokens, power);
        // Overlap factor: chunked prefill hides part of the decode cost
        // inside the chunk's compute, but interference remains (the
        // motivation for disaggregation).
        if chunk_tokens > 0 {
            prefill_part + (decode_part as f64 * 0.6) as Micros
        } else {
            decode_part
        }
    }

    /// KV-cache transfer time for `tokens` over the intra-node link.
    pub fn kv_transfer_time(&self, tokens: u32) -> Micros {
        let bytes = tokens as u64 * self.cfg.kv_bytes_per_token;
        ((bytes as f64 / self.cfg.xgmi_bw) * 1e6) as Micros
    }

    /// KV-cache transfer time between nodes (RDMA-class link, slower than
    /// XGMI — the locality cost cross-node routing weighs).
    pub fn kv_transfer_time_cross_node(&self, tokens: u32) -> Micros {
        let bytes = tokens as u64 * self.cfg.kv_bytes_per_token;
        ((bytes as f64 / self.cfg.inter_node_bw) * 1e6) as Micros
    }

    /// Transfer time picking the right link for the hop.
    pub fn kv_transfer_time_between(&self, tokens: u32, same_node: bool) -> Micros {
        if same_node {
            self.kv_transfer_time(tokens)
        } else {
            self.kv_transfer_time_cross_node(tokens)
        }
    }

    /// Instantaneous power draw of a GPU at `cap` with `util` in [0,1].
    /// Prefill saturates its cap; decode tops out near its knee (it cannot
    /// pull much more power even uncapped — memory-bound).
    pub fn draw(&self, cap: Watts, util: f64, is_prefill: bool) -> Watts {
        let util = util.clamp(0.0, 1.0);
        let ceiling = if is_prefill {
            cap
        } else {
            // Decode rarely draws far above its knee even when allowed.
            cap.min(self.cfg.decode_knee_w + 20.0)
        };
        let dynamic = (ceiling - self.cfg.idle_w).max(0.0) * util;
        (self.cfg.idle_w + dynamic).min(cap)
    }

    /// Idle draw (W).
    pub fn idle_w(&self) -> Watts {
        self.cfg.idle_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> PowerModel {
        PowerModel::new(PerfModelConfig::default())
    }

    #[test]
    fn speedup_anchors_match_paper() {
        let m = model();
        assert!((m.prefill_speedup(400.0) - 1.0).abs() < 1e-9);
        assert!((m.prefill_speedup(750.0) - 1.8).abs() < 1e-9);
        assert!((m.decode_speedup(400.0) - 1.0).abs() < 1e-9);
        let d600 = m.decode_speedup(600.0);
        assert!((d600 - 1.45).abs() < 1e-9, "decode flat by 600: {d600}");
        // above the knee: flat
        assert_eq!(m.decode_speedup(700.0), m.decode_speedup(750.0));
    }

    #[test]
    fn speedups_monotone_in_power() {
        let m = model();
        let mut last_p = 0.0;
        let mut last_d = 0.0;
        for w in (400..=750).step_by(50) {
            let p = m.prefill_speedup(w as f64);
            let d = m.decode_speedup(w as f64);
            assert!(p >= last_p && d >= last_d, "monotone at {w}");
            last_p = p;
            last_d = d;
        }
    }

    #[test]
    fn prefill_600_vs_750_gap_about_15pct() {
        // Paper §5.1: 600 W prefill is ~15% slower than 750 W.
        let m = model();
        let t600 = m.prefill_batch_time(4096, 600.0);
        let t750 = m.prefill_batch_time(4096, 750.0);
        let slowdown = t600 as f64 / t750 as f64;
        assert!(
            (1.08..=1.25).contains(&slowdown),
            "600W/750W prefill ratio {slowdown}"
        );
    }

    #[test]
    fn decode_power_insensitive_above_knee() {
        let m = model();
        let t600 = m.decode_step_time(16, 2000.0, 600.0);
        let t750 = m.decode_step_time(16, 2000.0, 750.0);
        assert_eq!(t600, t750, "decode gains above 600 W should be zero");
        let t450 = m.decode_step_time(16, 2000.0, 450.0);
        assert!(t450 > t600, "decode slower below the knee");
        // ... but not catastrophically (Fig 4b spans ~1.45x total)
        assert!((t450 as f64 / t600 as f64) < 1.45);
    }

    #[test]
    fn decode_step_scales_with_context() {
        let m = model();
        let short = m.decode_step_time(8, 500.0, 600.0);
        let long = m.decode_step_time(8, 2000.0, 600.0);
        assert!(long > short, "KV reads grow with context");
        // ... but saturate once the stream is bandwidth-bound.
        let capped = m.decode_step_time(8, 2500.0, 600.0);
        let beyond = m.decode_step_time(8, 8000.0, 600.0);
        assert_eq!(capped, beyond, "KV cost saturates past the cap");
    }

    #[test]
    fn prefill_batch_time_scales_with_tokens() {
        let m = model();
        let t1 = m.prefill_batch_time(1024, 750.0);
        let t4 = m.prefill_batch_time(4096, 750.0);
        let ratio = (t4 - m.cfg().prefill_overhead) as f64
            / (t1 - m.cfg().prefill_overhead) as f64;
        assert!((ratio - 4.0).abs() < 0.01);
    }

    #[test]
    fn decode_step_scales_with_batch() {
        let m = model();
        assert!(m.decode_step_time(32, 1000.0, 600.0) > m.decode_step_time(1, 1000.0, 600.0));
        assert_eq!(m.decode_step_time(0, 1000.0, 600.0), 0);
    }

    #[test]
    fn coalesced_step_shows_interference() {
        let m = model();
        let pure_prefill = m.prefill_batch_time(512, 750.0);
        let mixed = m.coalesced_step_time(512, 0, 16, 1000.0, 750.0);
        assert!(mixed > pure_prefill, "decode piggyback adds interference");
        let pure_decode = m.coalesced_step_time(0, 0, 16, 1000.0, 750.0);
        assert_eq!(pure_decode, m.decode_step_time(16, 1000.0, 750.0));
    }

    #[test]
    fn chunk_reread_taxes_deep_chunks() {
        // A chunk late in a long prompt costs more than the first chunk.
        let m = model();
        let first = m.coalesced_step_time(512, 0, 0, 0.0, 750.0);
        let deep = m.coalesced_step_time(512, 7680, 0, 0.0, 750.0);
        assert!(deep > first, "re-read tax: {deep} <= {first}");
        // One-shot prefill of the whole prompt beats the sum of chunks.
        let oneshot = m.prefill_batch_time(8192, 750.0);
        let chunked: u64 = (0..16)
            .map(|i| m.coalesced_step_time(512, i * 512, 0, 0.0, 750.0))
            .sum();
        assert!(chunked > oneshot, "chunked {chunked} <= oneshot {oneshot}");
    }

    #[test]
    fn kv_transfer_reasonable() {
        let m = model();
        // 4096 tokens * 128 KiB = 512 MiB over 64 GB/s ≈ 8.4 ms
        let t = m.kv_transfer_time(4096);
        assert!((7_000..10_000).contains(&t), "t={t}");
    }

    #[test]
    fn cross_node_transfer_slower_than_xgmi() {
        let m = model();
        let local = m.kv_transfer_time_between(4096, true);
        let remote = m.kv_transfer_time_between(4096, false);
        assert_eq!(local, m.kv_transfer_time(4096));
        assert!(
            remote > local * 2,
            "RDMA hop must clearly exceed XGMI: {remote} vs {local}"
        );
    }

    #[test]
    fn draw_respects_cap_and_idle() {
        let m = model();
        assert_eq!(m.draw(750.0, 0.0, true), m.idle_w());
        assert_eq!(m.draw(750.0, 1.0, true), 750.0);
        // decode can't pull 750 even when allowed
        assert!(m.draw(750.0, 1.0, false) <= 620.0 + 1e-9);
        // cap always wins
        assert!(m.draw(450.0, 1.0, true) <= 450.0);
    }

    #[test]
    fn rate_at_750_matches_config() {
        let m = model();
        assert!((m.prefill_rate(750.0) - 9_300.0).abs() < 1e-6);
    }
}
