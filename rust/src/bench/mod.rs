//! Bench harness (offline substitute for `criterion`).
//!
//! Used by every `benches/*` target (all `harness = false`) and by the
//! `rapid bench` subcommand: warmup, timed iterations, mean / p50 / p99 /
//! min / max, per-iteration batch sizes for throughput, and a
//! machine-readable [`BenchReport`] with a stable JSON schema that the CI
//! regression gate consumes (see [`report`] and DESIGN.md §10).
//!
//! The hot-path suite itself lives in [`hotpath`]; `benches/hotpath_micro`
//! and `rapid bench` both run it in-process so the numbers CI gates on
//! are the numbers developers see locally.

pub mod hotpath;
pub mod report;

pub use report::{BenchReport, Comparison, SCHEMA_VERSION};

use std::time::Instant;

use crate::util::stats::percentile_sorted;

/// Timing result of one benchmark case.
#[derive(Debug, Clone, PartialEq)]
pub struct Timing {
    pub name: String,
    /// Timed iterations (after the warmup/calibration pass).
    pub iters: usize,
    /// Work items per iteration; `per_sec` = `batch / mean`. `1` for
    /// plain latency cases, the simulated-event count for whole-sim runs.
    pub batch: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub min_us: f64,
    pub max_us: f64,
}

impl Timing {
    /// A one-shot wall-clock measurement (figure benches record one of
    /// these per run; all the order statistics collapse to the total).
    pub fn single(name: &str, total_us: f64) -> Timing {
        Timing {
            name: name.to_string(),
            iters: 1,
            batch: 1,
            mean_us: total_us,
            p50_us: total_us,
            p99_us: total_us,
            min_us: total_us,
            max_us: total_us,
        }
    }

    /// Throughput in items per second (batch items per mean iteration).
    pub fn per_sec(&self) -> f64 {
        if self.mean_us <= 0.0 {
            return 0.0;
        }
        self.batch as f64 / (self.mean_us / 1e6)
    }

    /// Median time per work item — what regression comparisons use:
    /// batch-normalized so whole-sim runs at different request counts
    /// stay comparable, median so one noisy CI iteration cannot fake a
    /// regression.
    pub fn per_item_p50_us(&self) -> f64 {
        self.p50_us / self.batch.max(1) as f64
    }

    /// Has this entry actually been measured? Bootstrap baselines carry
    /// zeroed entries ("not yet recorded") that gates must skip.
    pub fn is_recorded(&self) -> bool {
        self.per_item_p50_us().is_finite() && self.per_item_p50_us() > 0.0
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>8} iters  mean {:>10.1} us  p50 {:>10.1} us  p99 {:>10.1} us",
            self.name, self.iters, self.mean_us, self.p50_us, self.p99_us
        );
        if self.batch > 1 {
            s.push_str(&format!("  ({:.2} M/s)", self.per_sec() / 1e6));
        }
        s
    }
}

/// Time `f` with warmup; iteration count adapts so the run takes roughly
/// `target_ms` total (bounded by `max_iters`).
pub fn bench<F: FnMut()>(name: &str, target_ms: u64, max_iters: usize, f: F) -> Timing {
    bench_batch(name, 1, target_ms, max_iters, f)
}

/// [`bench`] for cases where each iteration processes `batch` items, so
/// the timing carries a meaningful events-per-second throughput.
pub fn bench_batch<F: FnMut()>(
    name: &str,
    batch: usize,
    target_ms: u64,
    max_iters: usize,
    mut f: F,
) -> Timing {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_ms as f64 / 1000.0 / once) as usize).clamp(3, max_iters.max(3));
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    let mean_us = samples.iter().sum::<f64>() / samples.len() as f64;
    samples.sort_by(|a, b| a.total_cmp(b));
    Timing {
        name: name.to_string(),
        iters,
        batch: batch.max(1),
        mean_us,
        p50_us: percentile_sorted(&samples, 50.0),
        p99_us: percentile_sorted(&samples, 99.0),
        min_us: samples[0],
        max_us: samples[samples.len() - 1],
    }
}

/// `--NAME VALUE` / `--NAME=VALUE` from this process's argv. Bench
/// binaries are `harness = false` mains, so flags arrive verbatim after
/// `cargo bench --bench X -- ...`. A following argument that is itself a
/// flag does not count as a value (`--json --compare b.json` must not
/// write a file named `--compare`).
pub fn arg_value(name: &str) -> Option<String> {
    let flag = format!("--{name}");
    let eq = format!("--{name}=");
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| *a == flag) {
        return args.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
    }
    args.iter().find_map(|a| a.strip_prefix(&eq).map(str::to_string))
}

/// The `--json PATH` flag every bench target accepts. Panics when the
/// flag is present but its path is missing or flag-shaped — a silently
/// unwritten report would only surface later as a confusing missing
/// artifact.
pub fn json_arg() -> Option<String> {
    let present = std::env::args().any(|a| a == "--json" || a.starts_with("--json="));
    let v = arg_value("json").filter(|s| !s.is_empty());
    if present && v.is_none() {
        panic!("--json requires a path argument");
    }
    v
}

/// Standard figure-bench epilogue: print the `<suite>: P/T shape checks
/// passed in Xs` line and honor `--json` — the one place the eight
/// `fig*` benches share their closing format.
pub fn finish_figure_bench(
    suite: &str,
    t0: std::time::Instant,
    checks: &[crate::scenario::ShapeCheck],
) {
    let failed = checks.iter().filter(|c| !c.pass).count();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{suite}: {}/{} shape checks passed in {wall:.1}s",
        checks.len() - failed,
        checks.len()
    );
    write_figure_report(suite, wall, checks.len() - failed, checks.len());
}

/// Shared `--json` handling for the figure benches: one wall-clock entry
/// named `<suite>/total` plus shape-check counts in `meta`. No-op when
/// `--json` was not passed; panics on an unwritable path (bench binaries
/// then exit non-zero, and in-process callers still unwind).
pub fn write_figure_report(suite: &str, wall_s: f64, checks_passed: usize, checks_total: usize) {
    let Some(path) = json_arg() else { return };
    let mut r = BenchReport::new(suite);
    r.entries.push(Timing::single(&format!("{suite}/total"), wall_s * 1e6));
    r.meta.insert("checks_passed".into(), checks_passed.to_string());
    r.meta.insert("checks_total".into(), checks_total.to_string());
    r.write(&path).unwrap_or_else(|e| panic!("bench json: {e}"));
    println!("wrote {path}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut acc = 0u64;
        let t = bench("noop-ish", 10, 1000, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(t.iters >= 3);
        assert!(t.mean_us >= 0.0);
        assert!(t.min_us <= t.mean_us && t.mean_us <= t.max_us);
        assert_eq!(t.batch, 1);
        assert!(t.report().contains("noop-ish"));
    }

    #[test]
    fn per_sec_scales_with_batch() {
        let mut t = Timing::single("x", 1000.0); // 1 ms
        assert!((t.per_sec() - 1000.0).abs() < 1e-6);
        t.batch = 100;
        assert!((t.per_sec() - 100_000.0).abs() < 1e-6);
        assert!(t.report().contains("M/s"));
    }

    #[test]
    fn batch_timings_report_throughput() {
        let t = bench_batch("b", 50, 5, 500, || {
            std::hint::black_box(0u64);
        });
        assert_eq!(t.batch, 50);
        assert!(t.per_sec() > 0.0);
    }

    #[test]
    fn zero_mean_has_zero_throughput() {
        let mut t = Timing::single("z", 0.0);
        t.batch = 10;
        assert_eq!(t.per_sec(), 0.0);
    }
}
