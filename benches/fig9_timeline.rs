//! Fig 9: dynamic power/GPU management timelines
//!
//! `cargo bench --bench fig9_timeline` regenerates the figure's rows/series and
//! validates the paper-shape assertions (DESIGN.md §6). Absolute numbers
//! differ from the paper (simulated substrate); shapes must hold.

fn main() {
    let n: usize = std::env::var("RAPID_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);
    let t0 = std::time::Instant::now();
    let f = rapid::experiments::fig9::run(42, n.min(800));
    println!("{}", f.render());
    let checks = f.checks();
    println!("{}", rapid::experiments::render_checks(&checks));
    rapid::bench::finish_figure_bench("fig9_timeline", t0, &checks);
}
