//! Golden suite for the observability subsystem (DESIGN.md §17).
//!
//! The contract under test, in order of importance:
//!
//! * **Disabled path is invisible**: `obs_events: 0` (the default)
//!   constructs no sink and a run is bit-identical — through the full
//!   [`support::assert_bit_identical`] comparator — to one recorded
//!   with the sink enabled, once the enabled run's `obs` report is
//!   stripped. Recording observes; it never steers.
//! * **Traced cell == study cell**: `Study::run_traced` reproduces the
//!   exact `RunResult` of the matching `Study::run` grid cell, obs
//!   report aside.
//! * **Export determinism**: the Chrome-trace JSON of a traced run is
//!   byte-identical across repeat runs, `RAPID_SWEEP_THREADS`
//!   settings, and the `RAPID_EVENTQ=heap` event-queue backend — and
//!   is valid Chrome Trace Event JSON with per-track monotone
//!   timestamps.
//! * **Audit reconciliation**: every cluster-level `BudgetChange`
//!   event matches `budget_trace` 1:1 and to the bit; `PowerMove`
//!   events agree with their counter and ok-moves stay within the
//!   budget they recorded; every `CapApplied` timestamp appears in
//!   `cap_trace`.
//! * **`rapid explain`**: a preempted multi-turn request renders a
//!   timeline with the preemption and stage attribution, identically
//!   across reruns.

#[path = "support/mod.rs"]
mod support;

use std::collections::{BTreeMap, BTreeSet};

use rapid::config::ClusterConfig;
use rapid::obs::chrome::chrome_trace;
use rapid::obs::{explain::explain, ObsEvent};
use rapid::scenario::{longbench_trace, Scenario, Study};
use rapid::sim::{self, SimOptions, TRACE_EVENT_CAPACITY};
use rapid::types::{Micros, Slo};
use rapid::util::json::Json;
use rapid::workload::tracespec::{assign_tenants, TraceSpec};

fn traced_opts() -> SimOptions {
    SimOptions {
        obs_events: TRACE_EVENT_CAPACITY,
        ..SimOptions::default()
    }
}

fn shipped_scenario(name: &str, requests: usize) -> Scenario {
    let path = format!("{}/scenarios/{name}", env!("CARGO_MANIFEST_DIR"));
    let mut s = Scenario::from_toml_file(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
    s.requests = requests;
    s
}

/// The tentpole golden: an untraced run must be bit-identical to a
/// traced run of the same inputs (report stripped), and the report
/// itself must be present and self-consistent.
fn disabled_vs_enabled(config_file: &str, seed: u64) {
    let cfg = support::shipped_config(config_file);
    let trace = longbench_trace(
        seed,
        1.25 * cfg.total_gpus() as f64,
        120,
        Slo::paper_default(),
    );
    let off = sim::run(&cfg, &trace, &SimOptions::default());
    assert!(off.obs.is_none(), "untraced runs carry no report");

    let mut on = sim::run(&cfg, &trace, &traced_opts());
    let report = *on.obs.take().expect("traced run carries a report");
    assert!(!report.events.is_empty());
    assert_eq!(report.dropped, 0, "ring must hold a 120-request run");
    assert_eq!(report.counters.arrivals as usize, trace.len());
    assert_eq!(report.counters.finishes as usize, trace.len());
    assert!(report.counters.gpu_steps > 0);
    assert_eq!(report.node_of.len(), cfg.total_gpus());

    // With the report stripped, every series — records, decisions,
    // cap/budget/power/mem traces — must match to the bit.
    support::assert_bit_identical(&off, &on);
}

#[test]
fn recording_is_invisible_on_rapid_600() {
    disabled_vs_enabled("rapid-600.toml", 17);
}

#[test]
fn recording_is_invisible_on_hetero_4p4d() {
    disabled_vs_enabled("hetero-4p4d.toml", 23);
}

#[test]
fn traced_cell_matches_study_cell_on_flash_crowd_curtail() {
    let selector = vec![("policy".to_string(), "rapid".to_string())];
    let s = shipped_scenario("flash-crowd-curtail.toml", 40);
    let study = Study::new(s.clone()).run(Some(1)).expect("study runs");
    let (spec, mut traced) = Study::new(s).run_traced(&selector).expect("traced run");
    assert!(spec.coords.iter().any(|(k, v)| k == "policy" && v == "rapid"));

    let report = *traced.obs.take().expect("traced run carries a report");
    assert!(report.counters.arrivals > 0);
    assert!(report.counters.arrivals >= report.counters.finishes);

    let cell = study
        .cells
        .iter()
        .find(|c| c.coords == spec.coords)
        .expect("selector names a grid cell");
    support::assert_bit_identical(cell.result().expect("sim cell"), &traced);
}

#[test]
fn run_traced_rejects_unknown_selectors() {
    let s = shipped_scenario("flash-crowd-curtail.toml", 10);
    let err = Study::new(s)
        .run_traced(&[("policy".to_string(), "nope".to_string())])
        .expect_err("unknown value must not silently pick a cell");
    let msg = err.to_string();
    assert!(msg.contains("policy=nope"), "{msg}");
    assert!(msg.contains("policy=rapid"), "error lists the grid: {msg}");
}

fn traced_flash_crowd_json() -> String {
    let s = shipped_scenario("flash-crowd-curtail.toml", 40);
    let (_, res) = Study::new(s).run_traced(&[]).expect("traced run");
    chrome_trace(&res)
}

#[test]
fn chrome_export_is_valid_and_byte_identical_across_backends() {
    let golden = traced_flash_crowd_json();

    // Validity: parses, declares ms display units, and every event
    // carries the required Chrome-trace keys with timestamps monotone
    // per (pid, tid) track (metadata events excepted).
    let doc = Json::parse(&golden).expect("chrome trace is valid JSON");
    assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty());
    let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    for ev in events {
        assert!(ev.get("name").and_then(Json::as_str).is_some(), "{ev:?}");
        let ph = ev.get("ph").and_then(Json::as_str).expect("ph");
        let pid = ev.get("pid").and_then(Json::as_u64).expect("pid");
        let tid = ev.get("tid").and_then(Json::as_u64).expect("tid");
        if ph == "M" {
            continue; // metadata names tracks; carries no timestamp order
        }
        let ts = ev.get("ts").and_then(Json::as_f64).expect("ts");
        if let Some(prev) = last_ts.insert((pid, tid), ts) {
            assert!(ts >= prev, "track ({pid},{tid}) went backwards: {prev} -> {ts}");
        }
    }

    // Byte-identity: repeat run, forced fan-out width, and the heap
    // event-queue backend must all export the exact same bytes.
    assert_eq!(traced_flash_crowd_json(), golden, "repeat run");
    std::env::set_var("RAPID_SWEEP_THREADS", "4");
    let wide = traced_flash_crowd_json();
    std::env::remove_var("RAPID_SWEEP_THREADS");
    assert_eq!(wide, golden, "RAPID_SWEEP_THREADS=4");
    std::env::set_var("RAPID_EVENTQ", "heap");
    let heap = traced_flash_crowd_json();
    std::env::remove_var("RAPID_EVENTQ");
    assert_eq!(heap, golden, "RAPID_EVENTQ=heap");
}

#[test]
fn power_audit_reconciles_with_budget_and_cap_traces() {
    // A compact grid whose curtailment windows (10 s period offsets)
    // land inside the ~25 s arrival span, so the cluster budget really
    // steps mid-run and the audit has something to reconcile.
    let toml = "name = \"audit-curtail\"\n\
         seed = 42\n\
         requests = 240\n\
         rate_per_gpu = 1.2\n\
         [workload]\nkind = \"longbench\"\n\
         [slo]\nttft_ms = 1000\ntpot_ms = 40\n\
         [base]\npreset = \"rapid-600\"\n\
         [axes]\npolicy = [\"rapid\"]\nenv = [\"curtail:20:0.5:0.7:10\"]\n";
    let selector = vec![("policy".to_string(), "rapid".to_string())];
    let s = Scenario::from_toml(toml).expect("audit scenario parses");
    let (_, res) = Study::new(s).run_traced(&selector).expect("traced run");
    let obs = res.obs.as_deref().expect("traced run carries a report");
    assert_eq!(obs.dropped, 0, "1:1 reconciliation needs the full log");

    // Cluster-level BudgetChange audit events mirror budget_trace
    // exactly: same count, same instants, bit-identical watts.
    let changes: Vec<(Micros, f64)> = obs
        .events
        .iter()
        .filter_map(|e| match *e {
            ObsEvent::BudgetChange { at, node: -1, watts, .. } => Some((at, watts)),
            _ => None,
        })
        .collect();
    assert!(!changes.is_empty(), "curtailment must register a budget change");
    assert_eq!(changes.len(), res.budget_trace.len());
    for ((ea, ew), (ba, bw)) in changes.iter().zip(&res.budget_trace) {
        assert_eq!(ea, ba, "audit instant must match budget_trace");
        assert_eq!(ew.to_bits(), bw.to_bits(), "audit watts must match budget_trace");
    }

    // PowerMove audit: the resident events agree with the counter
    // (no drops), and every accepted move stayed within the budget it
    // recorded at decision time.
    let mut moves = 0u64;
    for e in &obs.events {
        if let ObsEvent::PowerMove { ok, watts, budget, committed_after, .. } = *e {
            moves += 1;
            assert!(watts >= 0.0);
            if ok {
                assert!(
                    committed_after <= budget + 1e-6,
                    "accepted move overcommitted: {committed_after} > {budget}"
                );
            }
        }
    }
    assert_eq!(moves, obs.counters.power_moves);

    // Every deferred cap application the audit saw is a real cap_trace
    // sample instant.
    let cap_times: BTreeSet<Micros> = res.cap_trace.iter().map(|(t, _)| *t).collect();
    for e in &obs.events {
        if let ObsEvent::CapApplied { at, .. } = *e {
            assert!(cap_times.contains(&at), "CapApplied at {at} missing from cap_trace");
        }
    }
}

/// The multi-tenant saturation recipe from `rust/tests/multi_tenant.rs`
/// (proven to preempt), rewritten into 4-turn conversations the way
/// `build_cell_trace` does it: multi-turn first, tenant tags second.
fn preempting_multiturn() -> (ClusterConfig, rapid::workload::Trace) {
    let toml = "preset = \"rapid-600\"\n\
         [tenant.chat]\nshare = 0.5\ntier = \"interactive\"\n\
         [tenant.api]\nshare = 0.3\ntier = \"standard\"\n\
         [tenant.jobs]\nshare = 0.2\ntier = \"batch\"\nslo_scale = 4.0\n";
    let cfg = ClusterConfig::from_toml(toml).expect("tenant config parses");
    let spec = TraceSpec::preset("mt-4400x1200").unwrap();
    let mut trace = spec.build(7, 8.0 * cfg.n_gpus as f64, 300, Slo::paper_default());
    rapid::workload::make_multiturn(&mut trace, 4, 0.5);
    assign_tenants(&mut trace, &cfg.tenants, 7);
    (cfg, trace)
}

#[test]
fn explain_renders_a_preempted_multiturn_request_deterministically() {
    let (cfg, trace) = preempting_multiturn();
    let res = sim::run(&cfg, &trace, &traced_opts());
    let obs = res.obs.as_deref().expect("traced run carries a report");

    let victim = obs
        .events
        .iter()
        .find_map(|e| match *e {
            ObsEvent::Preempt { victim, .. } => Some(victim),
            _ => None,
        })
        .expect("saturated mixed-tier decode batches must preempt");
    assert!(obs.counters.preemptions > 0);

    let text = explain(&res, victim).expect("victim has a timeline");
    assert!(text.starts_with(&format!("request r{victim}")), "{text}");
    assert!(text.contains("preempted"), "{text}");
    assert!(text.contains("PREEMPTED"), "{text}");
    assert!(text.contains("arrival"), "{text}");
    assert!(text.contains("stage attribution:"), "{text}");
    assert!(text.contains("displaced"), "displacement must be attributed: {text}");
    assert!(text.contains("total "), "{text}");

    // Unknown ids fail with a pointer at the log, not a panic.
    assert!(explain(&res, u64::MAX).is_err());

    // Deterministic: the rerun renders the byte-identical timeline.
    let res2 = sim::run(&cfg, &trace, &traced_opts());
    assert_eq!(explain(&res2, victim).expect("rerun timeline"), text);
}
