//! Central request router (paper §3.2).
//!
//! "A central scheduler process receives incoming requests, routes them
//! to a specific worker, and coordinates inter-stage communication."
//! Routing is least-loaded: prefill by queued prompt tokens (prompt cost
//! is token-proportional), decode by active+pending request count
//! (decode cost is batch-slot-proportional). On heterogeneous fleets
//! every load is first normalized by the worker's SKU throughput
//! (`perf_scale`), so "least loaded" means *soonest drained*, not
//! smallest queue — a part with 2x the prompt rate legitimately holds
//! 2x the backlog. Homogeneous fleets have `perf_scale == 1.0`
//! everywhere, which reduces bit-exactly to the raw comparisons.

use std::cmp::Ordering;

use crate::types::GpuId;

/// Load summary of one candidate worker, as the router sees it.
#[derive(Debug, Clone, Copy)]
pub struct WorkerLoad {
    pub gpu: GpuId,
    /// Node hosting this worker (cross-node KV transfers are slower).
    pub node: usize,
    /// Queued prompt tokens (prefill) — the unit of prefill backlog.
    pub queued_tokens: u64,
    /// Queued + active requests — the unit of decode occupancy.
    pub requests: usize,
    /// Workers mid-drain are not eligible.
    pub accepting: bool,
    /// Relative SKU throughput of this worker (1.0 = the fleet's
    /// reference part): prefill rate for prefill pools, step rate for
    /// decode pools. Loads divide by it before comparison.
    pub perf_scale: f64,
    /// KV memory pressure in request units (HBM occupancy scaled by the
    /// decode batch limit; DESIGN.md §14). Exactly `0.0` when the mem
    /// subsystem is inactive or the GPU is uncapped — adding it then is
    /// the identity on every finite non-negative load, so the comparator
    /// reduces bit-exactly to the capacity-blind router.
    pub mem_pressure: f64,
}

impl WorkerLoad {
    /// Throughput-normalized prefill backlog (≈ seconds to drain).
    #[inline]
    fn eff_tokens(&self) -> f64 {
        self.queued_tokens as f64 / self.perf_scale + self.mem_pressure
    }

    /// Throughput-normalized decode occupancy.
    #[inline]
    fn eff_requests(&self) -> f64 {
        self.requests as f64 / self.perf_scale + self.mem_pressure
    }
}

#[inline]
fn prefill_order(a: &WorkerLoad, b: &WorkerLoad) -> Ordering {
    a.eff_tokens()
        .total_cmp(&b.eff_tokens())
        .then(a.requests.cmp(&b.requests))
        .then(a.gpu.0.cmp(&b.gpu.0))
}

#[inline]
fn decode_order(a: &WorkerLoad, b: &WorkerLoad) -> Ordering {
    a.eff_requests()
        .total_cmp(&b.eff_requests())
        .then(a.queued_tokens.cmp(&b.queued_tokens))
        .then(a.gpu.0.cmp(&b.gpu.0))
}

/// Pick the prefill worker with the least (throughput-normalized)
/// queued prompt tokens.
///
/// Called once per arrival/publish on the simulator's hot path — the
/// cluster core reuses one scratch `Vec<WorkerLoad>` across calls so a
/// routing decision allocates nothing.
#[inline]
pub fn pick_prefill(loads: &[WorkerLoad]) -> Option<GpuId> {
    loads
        .iter()
        .filter(|l| l.accepting)
        .min_by(|a, b| prefill_order(a, b))
        .map(|l| l.gpu)
}

/// Pick the decode worker with the fewest (throughput-normalized)
/// resident requests.
#[inline]
pub fn pick_decode(loads: &[WorkerLoad]) -> Option<GpuId> {
    loads
        .iter()
        .filter(|l| l.accepting)
        .min_by(|a, b| decode_order(a, b))
        .map(|l| l.gpu)
}

/// Extra (normalized) resident requests we tolerate on a same-node
/// decode worker before paying a cross-node KV transfer instead
/// (locality bias).
pub const LOCALITY_SLACK_REQS: usize = 4;

/// Pick a decode worker preferring `node` (where the KV cache already
/// lives): take the least-loaded local worker unless a remote worker is
/// more than `LOCALITY_SLACK_REQS` normalized requests lighter.
#[inline]
pub fn pick_decode_prefer_node(loads: &[WorkerLoad], node: usize) -> Option<GpuId> {
    let global = pick_decode(loads)?;
    let global_load = loads
        .iter()
        .find(|l| l.gpu == global)
        .map(WorkerLoad::eff_requests)
        .unwrap_or(0.0);
    let local = loads
        .iter()
        .filter(|l| l.accepting && l.node == node)
        .min_by(|a, b| decode_order(a, b));
    match local {
        Some(l) if l.eff_requests() <= global_load + LOCALITY_SLACK_REQS as f64 => Some(l.gpu),
        _ => Some(global),
    }
}

// ---------------------------------------------------------------------
// Incremental load indexes (thousand-node routing)
// ---------------------------------------------------------------------

/// Sort key of one worker inside a [`LoadIndex`], ordered exactly like
/// the linear comparators: normalized load first, then the role's raw
/// tie-breaker, then GPU id.
///
/// The float comparison is encoded as integer bits: for non-negative
/// finite f64 values (loads always are — counts divided by a positive
/// scale), `total_cmp` order equals unsigned order of `to_bits()`, so a
/// plain lexicographic `Ord` on this struct reproduces `prefill_order` /
/// `decode_order` bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct LoadKey {
    eff_bits: u64,
    tie: u64,
    gpu: usize,
}

impl LoadKey {
    /// Key for a prefill worker: normalized queued prompt tokens plus
    /// the memory-pressure term, ties by raw queued request count.
    pub fn prefill(
        queued_tokens: u64,
        requests: usize,
        perf_scale: f64,
        pressure: f64,
        gpu: usize,
    ) -> Self {
        let eff = queued_tokens as f64 / perf_scale + pressure;
        debug_assert!(eff >= 0.0 && eff.is_finite());
        LoadKey { eff_bits: eff.to_bits(), tie: requests as u64, gpu }
    }

    /// Key for a decode worker: normalized resident+pending requests
    /// plus the memory-pressure term, ties by raw queued tokens (always
    /// 0 for decode pools today).
    pub fn decode(
        requests: usize,
        queued_tokens: u64,
        perf_scale: f64,
        pressure: f64,
        gpu: usize,
    ) -> Self {
        let eff = requests as f64 / perf_scale + pressure;
        debug_assert!(eff >= 0.0 && eff.is_finite());
        LoadKey { eff_bits: eff.to_bits(), tie: queued_tokens, gpu }
    }

    pub fn gpu(&self) -> GpuId {
        GpuId(self.gpu)
    }

    fn eff(&self) -> f64 {
        f64::from_bits(self.eff_bits)
    }
}

/// Incrementally-maintained pick index for one worker role: an ordered
/// set of [`LoadKey`]s cluster-wide plus one per node, updated in
/// O(log n) whenever a worker's load or eligibility changes. Picks read
/// the set minimum instead of scanning every GPU, making routing
/// O(log n) on thousand-GPU fleets. Only *accepting* workers are ever
/// resident, matching the `accepting` filter of the linear scans.
#[derive(Debug)]
pub struct LoadIndex {
    /// Current (key, node) of each GPU; `None` = not indexed.
    entries: Vec<Option<(LoadKey, usize)>>,
    global: std::collections::BTreeSet<LoadKey>,
    by_node: Vec<std::collections::BTreeSet<LoadKey>>,
}

impl LoadIndex {
    pub fn new(n_gpus: usize, n_nodes: usize) -> Self {
        LoadIndex {
            entries: vec![None; n_gpus],
            global: std::collections::BTreeSet::new(),
            by_node: vec![std::collections::BTreeSet::new(); n_nodes],
        }
    }

    /// Install `key` as `gpu`'s current load (or remove it with `None`).
    /// Idempotent and cheap when the key is unchanged.
    pub fn update(&mut self, gpu: usize, node: usize, key: Option<LoadKey>) {
        if let Some((old, old_node)) = self.entries[gpu] {
            if Some(old) == key && old_node == node {
                return;
            }
            self.global.remove(&old);
            self.by_node[old_node].remove(&old);
        }
        self.entries[gpu] = key.map(|k| {
            self.global.insert(k);
            self.by_node[node].insert(k);
            (k, node)
        });
    }

    pub fn len(&self) -> usize {
        self.global.len()
    }

    pub fn is_empty(&self) -> bool {
        self.global.is_empty()
    }

    /// Least-loaded indexed worker, skipping `exclude` (≤ 2 set probes).
    pub fn pick(&self, exclude: Option<usize>) -> Option<GpuId> {
        self.global
            .iter()
            .find(|k| Some(k.gpu) != exclude)
            .map(LoadKey::gpu)
    }

    /// Indexed [`pick_decode_prefer_node`]: the node-local minimum wins
    /// unless the global minimum is more than `LOCALITY_SLACK_REQS`
    /// normalized requests lighter — the same arithmetic on the same
    /// values as the linear reference, so picks are identical.
    pub fn pick_prefer_node(&self, node: usize, exclude: Option<usize>) -> Option<GpuId> {
        let global = self.global.iter().find(|k| Some(k.gpu) != exclude)?;
        let local = self.by_node[node].iter().find(|k| Some(k.gpu) != exclude);
        match local {
            Some(l) if l.eff() <= global.eff() + LOCALITY_SLACK_REQS as f64 => Some(l.gpu()),
            _ => Some(global.gpu()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(gpu: usize, tokens: u64, reqs: usize, accepting: bool) -> WorkerLoad {
        scaled_load(gpu, tokens, reqs, accepting, 1.0)
    }

    fn scaled_load(
        gpu: usize,
        tokens: u64,
        reqs: usize,
        accepting: bool,
        scale: f64,
    ) -> WorkerLoad {
        WorkerLoad {
            gpu: GpuId(gpu),
            node: gpu / 8,
            queued_tokens: tokens,
            requests: reqs,
            accepting,
            perf_scale: scale,
            mem_pressure: 0.0,
        }
    }

    #[test]
    fn prefill_prefers_fewest_tokens() {
        let loads = [load(0, 5000, 1, true), load(1, 200, 9, true), load(2, 3000, 0, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(1)));
    }

    #[test]
    fn decode_prefers_fewest_requests() {
        let loads = [load(0, 0, 7, true), load(1, 0, 2, true), load(2, 0, 4, true)];
        assert_eq!(pick_decode(&loads), Some(GpuId(1)));
    }

    #[test]
    fn draining_workers_skipped() {
        let loads = [load(0, 0, 0, false), load(1, 9000, 30, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(1)));
        assert_eq!(pick_decode(&loads), Some(GpuId(1)));
        let none = [load(0, 0, 0, false)];
        assert_eq!(pick_prefill(&none), None);
    }

    #[test]
    fn ties_break_by_gpu_id_for_determinism() {
        let loads = [load(2, 100, 1, true), load(0, 100, 1, true), load(1, 100, 1, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(0)));
        assert_eq!(pick_decode(&loads), Some(GpuId(0)));
    }

    #[test]
    fn empty_pool_is_none() {
        assert_eq!(pick_prefill(&[]), None);
        assert_eq!(pick_decode(&[]), None);
        assert_eq!(pick_decode_prefer_node(&[], 0), None);
    }

    #[test]
    fn locality_keeps_kv_on_node_when_loads_close() {
        // gpu 1 is on node 0 (local, slightly busier), gpu 9 on node 1.
        let loads = [load(1, 0, 3, true), load(9, 0, 1, true)];
        assert_eq!(pick_decode_prefer_node(&loads, 0), Some(GpuId(1)));
        // Without a local candidate it falls back to the global pick.
        assert_eq!(pick_decode_prefer_node(&loads, 2), Some(GpuId(9)));
    }

    #[test]
    fn locality_yields_to_big_imbalance() {
        // Local worker is far busier than the remote one: pay the link.
        let loads = [load(1, 0, 30, true), load(9, 0, 1, true)];
        assert_eq!(pick_decode_prefer_node(&loads, 0), Some(GpuId(9)));
    }

    #[test]
    fn locality_skips_draining_local_workers() {
        let loads = [load(1, 0, 0, false), load(9, 0, 5, true)];
        assert_eq!(pick_decode_prefer_node(&loads, 0), Some(GpuId(9)));
    }

    // ------------------------------------------------------------------
    // heterogeneous (SKU-normalized) routing
    // ------------------------------------------------------------------

    #[test]
    fn prefill_normalizes_backlog_by_throughput() {
        // GPU 0 is 2x faster and holds 2x - 1 tokens: it drains sooner,
        // so it wins despite the raw queue being deeper.
        let loads = [scaled_load(0, 3999, 0, true, 2.0), scaled_load(1, 2000, 0, true, 1.0)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(0)));
        // At exactly 2x the tokens the drain times tie: requests, then
        // gpu id break it deterministically.
        let tie = [scaled_load(0, 4000, 1, true, 2.0), scaled_load(1, 2000, 1, true, 1.0)];
        assert_eq!(pick_prefill(&tie), Some(GpuId(0)));
        // A slow part with a small queue still loses to a fast empty one.
        let slow = [scaled_load(0, 0, 0, true, 2.0), scaled_load(1, 100, 0, true, 0.5)];
        assert_eq!(pick_prefill(&slow), Some(GpuId(0)));
    }

    #[test]
    fn decode_normalizes_occupancy_by_throughput() {
        // 6 requests on a 2x part == 3 normalized < 4 on the 1x part.
        let loads = [scaled_load(0, 0, 6, true, 2.0), scaled_load(1, 0, 4, true, 1.0)];
        assert_eq!(pick_decode(&loads), Some(GpuId(0)));
    }

    #[test]
    fn perf_scale_exact_ties_break_by_requests_then_id() {
        // Normalized prefill backlogs tie exactly (4000/2.0 == 2000/1.0):
        // the raw request count breaks the tie...
        let deep_fast = scaled_load(5, 4000, 3, true, 2.0);
        let shallow_slow = scaled_load(1, 2000, 1, true, 1.0);
        assert_eq!(pick_prefill(&[deep_fast, shallow_slow]), Some(GpuId(1)));
        // ...and with requests tied too, the lowest GPU id wins, so the
        // pick is deterministic regardless of scale combinations.
        let full_tie = scaled_load(7, 4000, 1, true, 2.0);
        assert_eq!(pick_prefill(&[full_tie, shallow_slow]), Some(GpuId(1)));
        assert_eq!(pick_prefill(&[shallow_slow, full_tie]), Some(GpuId(1)), "order-free");
        // Decode: normalized occupancy ties (8/2.0 == 4/1.0) break by
        // queued tokens, then id.
        let busy_fast = scaled_load(2, 5, 8, true, 2.0);
        let calm_slow = scaled_load(4, 0, 4, true, 1.0);
        assert_eq!(pick_decode(&[busy_fast, calm_slow]), Some(GpuId(4)));
        let token_tie = scaled_load(6, 0, 8, true, 2.0);
        assert_eq!(pick_decode(&[token_tie, calm_slow]), Some(GpuId(4)), "id breaks full tie");
    }

    #[test]
    fn perf_scale_tiny_and_fractional_scales_stay_finite_and_ordered() {
        // A severely derated part (scale 0.25) holding a small queue
        // still loses to a healthy empty one; zero-queue entries compare
        // equal across any scale (0/s == 0.0) and fall to the id tie.
        let derated = scaled_load(3, 100, 0, true, 0.25);
        let healthy = scaled_load(5, 0, 0, true, 1.0);
        assert_eq!(pick_prefill(&[derated, healthy]), Some(GpuId(5)));
        let idle_a = scaled_load(9, 0, 0, true, 0.25);
        let idle_b = scaled_load(4, 0, 0, true, 2.0);
        assert_eq!(pick_prefill(&[idle_a, idle_b]), Some(GpuId(4)));
    }

    #[test]
    fn locality_slack_compares_normalized_loads() {
        // Local worker (node 0) is a slow part: 6 raw / 0.5 = 12
        // normalized, more than slack above the remote's 1 — pay the hop.
        let loads = [scaled_load(1, 0, 6, true, 0.5), scaled_load(9, 0, 1, true, 1.0)];
        assert_eq!(pick_decode_prefer_node(&loads, 0), Some(GpuId(9)));
        // A fast local part with the same raw queue stays local:
        // 6 / 2.0 = 3 normalized <= 1 + 4 slack.
        let fast = [scaled_load(1, 0, 6, true, 2.0), scaled_load(9, 0, 1, true, 1.0)];
        assert_eq!(pick_decode_prefer_node(&fast, 0), Some(GpuId(1)));
    }

    // ------------------------------------------------------------------
    // incremental LoadIndex vs the linear reference
    // ------------------------------------------------------------------

    /// Mirror of the cluster's fill-then-pick path: build loads from the
    /// same state the index sees, drop non-accepting entries entirely
    /// (the index never holds them; the linear pick filters them).
    fn reference_loads(state: &[(u64, usize, bool, f64)], decode: bool) -> Vec<WorkerLoad> {
        state
            .iter()
            .enumerate()
            .map(|(gpu, &(tokens, reqs, accepting, scale))| WorkerLoad {
                gpu: GpuId(gpu),
                node: gpu / 8,
                queued_tokens: if decode { 0 } else { tokens },
                requests: reqs,
                accepting,
                perf_scale: scale,
                mem_pressure: 0.0,
            })
            .collect()
    }

    fn sync_index(idx: &mut LoadIndex, state: &[(u64, usize, bool, f64)], decode: bool) {
        for (gpu, &(tokens, reqs, accepting, scale)) in state.iter().enumerate() {
            let key = accepting.then(|| {
                if decode {
                    LoadKey::decode(reqs, 0, scale, 0.0, gpu)
                } else {
                    LoadKey::prefill(tokens, reqs, scale, 0.0, gpu)
                }
            });
            idx.update(gpu, gpu / 8, key);
        }
    }

    #[test]
    fn index_matches_linear_reference_under_random_churn() {
        // Random enqueue/step/eligibility-flip sequences on fleets from
        // 8 to 1024 GPUs; after every mutation the indexed pick must
        // equal the linear scan, including exact ties and the
        // prefer-node slack comparison.
        let mut rng = crate::util::rng::Rng::new(0x10AD);
        for &n in &[8usize, 24, 128, 1024] {
            let nodes = n.div_ceil(8);
            // (queued_tokens, requests, accepting, perf_scale) per GPU.
            // Scales drawn from the shipped SKU table values plus 1.0.
            let scales = [1.0, 1.45, 0.62, 2.0];
            let mut state: Vec<(u64, usize, bool, f64)> = (0..n)
                .map(|i| (0, 0, true, scales[i % scales.len()]))
                .collect();
            let mut pf = LoadIndex::new(n, nodes);
            let mut dec = LoadIndex::new(n, nodes);
            for step in 0..600 {
                let g = rng.index(n);
                match rng.index(5) {
                    // enqueue: tokens arrive (small range forces ties)
                    0 => state[g].0 += rng.range_u64(0, 3) * 512,
                    // step: drain tokens / finish requests
                    1 => {
                        state[g].0 = state[g].0.saturating_sub(1024);
                        state[g].1 = state[g].1.saturating_sub(1);
                    }
                    // admission: request lands
                    2 => state[g].1 += rng.index(3),
                    // drain/fail: leaves both pools
                    3 => state[g].2 = false,
                    // recover/flip back in
                    _ => state[g].2 = true,
                }
                sync_index(&mut pf, &state, false);
                sync_index(&mut dec, &state, true);
                let pf_loads = reference_loads(&state, false);
                let dec_loads = reference_loads(&state, true);
                assert_eq!(pf.pick(None), pick_prefill(&pf_loads), "step {step} n {n}");
                assert_eq!(dec.pick(None), pick_decode(&dec_loads), "step {step} n {n}");
                let node = rng.index(nodes);
                assert_eq!(
                    dec.pick_prefer_node(node, None),
                    pick_decode_prefer_node(&dec_loads, node),
                    "step {step} n {n} node {node}"
                );
                // Excluded picks mirror fill_decode_loads' exclude arg.
                let ex = rng.index(n);
                let mut without: Vec<WorkerLoad> = dec_loads.clone();
                without.retain(|l| l.gpu.0 != ex);
                assert_eq!(
                    dec.pick_prefer_node(node, Some(ex)),
                    pick_decode_prefer_node(&without, node),
                    "step {step} n {n} exclude {ex}"
                );
            }
        }
    }

    #[test]
    fn index_exact_ties_break_like_the_comparators() {
        // Two workers with bit-equal normalized loads: requests, then
        // gpu id decide, exactly as `prefill_order`.
        let mut idx = LoadIndex::new(4, 1);
        idx.update(2, 0, Some(LoadKey::prefill(4000, 1, 2.0, 0.0, 2)));
        idx.update(1, 0, Some(LoadKey::prefill(2000, 1, 1.0, 0.0, 1)));
        assert_eq!(idx.pick(None), Some(GpuId(1)), "id breaks the full tie");
        idx.update(1, 0, Some(LoadKey::prefill(2000, 3, 1.0, 0.0, 1)));
        assert_eq!(idx.pick(None), Some(GpuId(2)), "requests break the eff tie");
        // Removal restores the other candidate.
        idx.update(2, 0, None);
        assert_eq!(idx.pick(None), Some(GpuId(1)));
        idx.update(1, 0, None);
        assert_eq!(idx.pick(None), None);
    }

    #[test]
    fn index_prefer_node_falls_back_without_local_candidates() {
        let mut idx = LoadIndex::new(16, 2);
        idx.update(9, 1, Some(LoadKey::decode(1, 0, 1.0, 0.0, 9)));
        // No node-0 candidate: global pick wins.
        assert_eq!(idx.pick_prefer_node(0, None), Some(GpuId(9)));
        // A local worker within slack takes over.
        idx.update(1, 0, Some(LoadKey::decode(5, 0, 1.0, 0.0, 1)));
        assert_eq!(idx.pick_prefer_node(0, None), Some(GpuId(1)));
        // Beyond slack the remote worker wins again.
        idx.update(1, 0, Some(LoadKey::decode(6, 0, 1.0, 0.0, 1)));
        assert_eq!(idx.pick_prefer_node(0, None), Some(GpuId(9)));
    }
}
