//! Shared substrates: PRNG, statistics, JSON, parallel fan-out,
//! property testing, slab storage.

#[cfg(feature = "alloc-count")]
pub mod alloc_count;
pub mod check;
pub mod json;
pub mod par;
pub mod rng;
pub mod slab;
pub mod stats;
