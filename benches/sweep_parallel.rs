//! Parallel sweep runner speedup: a 4-point Fig-5-style rate sweep run
//! serially (RAPID_SWEEP_THREADS=1) vs fanned across all cores, with a
//! bit-identical-results check (each sweep point derives everything from
//! its seed, so thread count must not change a single number).
//!
//! `cargo bench --bench sweep_parallel`
//! Acceptance: >= 2x wall-clock speedup on a multi-core runner.

use rapid::config::presets;
use rapid::experiments::{rate_sweep, sweep_threads, RatePoint};
use rapid::types::Slo;

const RATES: &[f64] = &[0.75, 1.25, 1.75, 2.25];

fn run_once(n: usize) -> Vec<RatePoint> {
    let cfg = presets::p4_750_d4_450();
    rate_sweep(&cfg, RATES, 42, n, Slo::paper_default())
}

fn main() {
    let n: usize = std::env::var("RAPID_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1500);

    std::env::set_var("RAPID_SWEEP_THREADS", "1");
    let t0 = std::time::Instant::now();
    let serial = run_once(n);
    let t_serial = t0.elapsed().as_secs_f64();

    std::env::remove_var("RAPID_SWEEP_THREADS");
    let cores = sweep_threads();
    let t1 = std::time::Instant::now();
    let parallel = run_once(n);
    let t_parallel = t1.elapsed().as_secs_f64();

    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.qps_per_gpu, b.qps_per_gpu);
        assert_eq!(a.attainment, b.attainment, "thread count changed results!");
        assert_eq!(a.goodput_qps, b.goodput_qps);
    }

    let speedup = t_serial / t_parallel.max(1e-9);
    println!(
        "sweep_parallel: {} points x {n} reqs | serial {t_serial:.2}s | \
         parallel({cores} threads) {t_parallel:.2}s | speedup {speedup:.2}x",
        RATES.len()
    );
    let expected = if cores >= 4 { 2.0 } else { 1.2 };
    println!(
        "  [{}] parallel sweep >= {expected}x over serial on this {cores}-core runner",
        if speedup >= expected { "PASS" } else { "FAIL" }
    );
}
