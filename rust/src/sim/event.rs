//! Discrete-event machinery: the event heap and event types.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::types::{Micros, Request};

/// A request travelling the decode pipeline (KV handle + bookkeeping).
#[derive(Debug, Clone)]
pub struct DecodeItem {
    pub req: Request,
    pub prefill_start: Micros,
    pub first_token: Micros,
    /// Output tokens generated so far *including* the prefill-produced
    /// first token.
    pub tokens_done: u32,
}

impl DecodeItem {
    /// Live context length (prompt + generated) — drives KV-read cost.
    pub fn ctx_tokens(&self) -> u32 {
        self.req.input_tokens + self.tokens_done
    }

    pub fn remaining(&self) -> u32 {
        self.req.output_tokens.saturating_sub(self.tokens_done)
    }
}

/// Simulation events. Variants carry the minimum needed; `epoch` guards
/// against stale completions after a GPU role change.
#[derive(Debug)]
pub enum Event {
    /// Next trace arrival is due.
    Arrival,
    /// The in-flight work unit on `gpu` finished (a prefill batch, a
    /// decode iteration or a coalesced chunked-prefill iteration — the
    /// GPU's current role behavior interprets it; see `sim::worker`).
    StepDone { gpu: usize, epoch: u64 },
    /// A KV transfer landed on decode `gpu`; `src_node` owns the ring
    /// slot being released.
    KvArrive { gpu: usize, src_node: usize, item: DecodeItem },
    /// Controller (policy) tick.
    ControllerTick,
    /// Pending power raises may be due.
    PowerPoll,
    /// Telemetry sampling.
    Sample,
    /// A draining GPU finished its role switch.
    DrainDone { gpu: usize, epoch: u64 },
    /// An environment disturbance is due: index into the cluster's
    /// expanded `env_timeline` (cap step, GPU failure/recovery, thermal
    /// derate — see `crate::env`).
    Env { idx: usize },
}

struct HeapItem {
    at: Micros,
    seq: u64,
    event: Event,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with deterministic FIFO tie-breaking.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<HeapItem>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue::with_capacity(0)
    }

    /// Preallocate the heap: steady-state sims keep roughly one in-flight
    /// event per GPU plus the periodic timers, so sizing up-front avoids
    /// the early growth reallocations on every run of a sweep.
    pub fn with_capacity(capacity: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(capacity),
            seq: 0,
        }
    }

    pub fn push(&mut self, at: Micros, event: Event) {
        self.seq += 1;
        self.heap.push(HeapItem {
            at,
            seq: self.seq,
            event,
        });
    }

    pub fn pop(&mut self) -> Option<(Micros, Event)> {
        self.heap.pop().map(|i| (i.at, i.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::Arrival);
        q.push(10, Event::ControllerTick);
        q.push(20, Event::Sample);
        assert_eq!(q.pop().unwrap().0, 10);
        assert_eq!(q.pop().unwrap().0, 20);
        assert_eq!(q.pop().unwrap().0, 30);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_pop_fifo() {
        let mut q = EventQueue::new();
        q.push(5, Event::StepDone { gpu: 1, epoch: 0 });
        q.push(5, Event::StepDone { gpu: 2, epoch: 0 });
        q.push(5, Event::StepDone { gpu: 3, epoch: 0 });
        let order: Vec<usize> = (0..3)
            .map(|_| match q.pop().unwrap().1 {
                Event::StepDone { gpu, .. } => gpu,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn decode_item_context() {
        let item = DecodeItem {
            req: Request {
                id: crate::types::RequestId(0),
                arrival: 0,
                input_tokens: 500,
                output_tokens: 10,
                slo: crate::types::Slo::paper_default(),
            },
            prefill_start: 0,
            first_token: 0,
            tokens_done: 3,
        };
        assert_eq!(item.ctx_tokens(), 503);
        assert_eq!(item.remaining(), 7);
    }
}
