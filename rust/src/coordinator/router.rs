//! Central request router (paper §3.2).
//!
//! "A central scheduler process receives incoming requests, routes them
//! to a specific worker, and coordinates inter-stage communication."
//! Routing is least-loaded: prefill by queued prompt tokens (prompt cost
//! is token-proportional), decode by active+pending request count
//! (decode cost is batch-slot-proportional).

use crate::types::GpuId;

/// Load summary of one candidate worker, as the router sees it.
#[derive(Debug, Clone, Copy)]
pub struct WorkerLoad {
    pub gpu: GpuId,
    /// Node hosting this worker (cross-node KV transfers are slower).
    pub node: usize,
    /// Queued prompt tokens (prefill) — the unit of prefill backlog.
    pub queued_tokens: u64,
    /// Queued + active requests — the unit of decode occupancy.
    pub requests: usize,
    /// Workers mid-drain are not eligible.
    pub accepting: bool,
}

/// Pick the prefill worker with the least queued prompt tokens.
///
/// Called once per arrival/publish on the simulator's hot path — the
/// cluster core reuses one scratch `Vec<WorkerLoad>` across calls so a
/// routing decision allocates nothing.
#[inline]
pub fn pick_prefill(loads: &[WorkerLoad]) -> Option<GpuId> {
    loads
        .iter()
        .filter(|l| l.accepting)
        .min_by_key(|l| (l.queued_tokens, l.requests, l.gpu.0))
        .map(|l| l.gpu)
}

/// Pick the decode worker with the fewest resident requests.
#[inline]
pub fn pick_decode(loads: &[WorkerLoad]) -> Option<GpuId> {
    loads
        .iter()
        .filter(|l| l.accepting)
        .min_by_key(|l| (l.requests, l.queued_tokens, l.gpu.0))
        .map(|l| l.gpu)
}

/// Extra resident requests we tolerate on a same-node decode worker
/// before paying a cross-node KV transfer instead (locality bias).
pub const LOCALITY_SLACK_REQS: usize = 4;

/// Pick a decode worker preferring `node` (where the KV cache already
/// lives): take the least-loaded local worker unless a remote worker is
/// more than `LOCALITY_SLACK_REQS` requests lighter.
#[inline]
pub fn pick_decode_prefer_node(loads: &[WorkerLoad], node: usize) -> Option<GpuId> {
    let global = pick_decode(loads)?;
    let global_load = loads
        .iter()
        .find(|l| l.gpu == global)
        .map(|l| l.requests)
        .unwrap_or(0);
    let local = loads
        .iter()
        .filter(|l| l.accepting && l.node == node)
        .min_by_key(|l| (l.requests, l.queued_tokens, l.gpu.0));
    match local {
        Some(l) if l.requests <= global_load + LOCALITY_SLACK_REQS => Some(l.gpu),
        _ => Some(global),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(gpu: usize, tokens: u64, reqs: usize, accepting: bool) -> WorkerLoad {
        WorkerLoad {
            gpu: GpuId(gpu),
            node: gpu / 8,
            queued_tokens: tokens,
            requests: reqs,
            accepting,
        }
    }

    #[test]
    fn prefill_prefers_fewest_tokens() {
        let loads = [load(0, 5000, 1, true), load(1, 200, 9, true), load(2, 3000, 0, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(1)));
    }

    #[test]
    fn decode_prefers_fewest_requests() {
        let loads = [load(0, 0, 7, true), load(1, 0, 2, true), load(2, 0, 4, true)];
        assert_eq!(pick_decode(&loads), Some(GpuId(1)));
    }

    #[test]
    fn draining_workers_skipped() {
        let loads = [load(0, 0, 0, false), load(1, 9000, 30, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(1)));
        assert_eq!(pick_decode(&loads), Some(GpuId(1)));
        let none = [load(0, 0, 0, false)];
        assert_eq!(pick_prefill(&none), None);
    }

    #[test]
    fn ties_break_by_gpu_id_for_determinism() {
        let loads = [load(2, 100, 1, true), load(0, 100, 1, true), load(1, 100, 1, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(0)));
        assert_eq!(pick_decode(&loads), Some(GpuId(0)));
    }

    #[test]
    fn empty_pool_is_none() {
        assert_eq!(pick_prefill(&[]), None);
        assert_eq!(pick_decode(&[]), None);
        assert_eq!(pick_decode_prefer_node(&[], 0), None);
    }

    #[test]
    fn locality_keeps_kv_on_node_when_loads_close() {
        // gpu 1 is on node 0 (local, slightly busier), gpu 9 on node 1.
        let loads = [load(1, 0, 3, true), load(9, 0, 1, true)];
        assert_eq!(pick_decode_prefer_node(&loads, 0), Some(GpuId(1)));
        // Without a local candidate it falls back to the global pick.
        assert_eq!(pick_decode_prefer_node(&loads, 2), Some(GpuId(9)));
    }

    #[test]
    fn locality_yields_to_big_imbalance() {
        // Local worker is far busier than the remote one: pay the link.
        let loads = [load(1, 0, 30, true), load(9, 0, 1, true)];
        assert_eq!(pick_decode_prefer_node(&loads, 0), Some(GpuId(9)));
    }

    #[test]
    fn locality_skips_draining_local_workers() {
        let loads = [load(1, 0, 0, false), load(9, 0, 5, true)];
        assert_eq!(pick_decode_prefer_node(&loads, 0), Some(GpuId(9)));
    }
}
