//! The cluster core: a discrete-event simulation of one or more GPU
//! nodes under a pluggable control policy.
//!
//! This layer owns what used to be entangled inside the `sim::engine`
//! monolith:
//!
//! * **topology** — `n_nodes` identical nodes of `n_gpus` each, with the
//!   per-node prefill/decode split of [`crate::config::Topology`];
//! * **routing** — central least-loaded dispatch across all nodes, with
//!   KV locality (same-node decode preferred; cross-node transfers pay
//!   the slower RDMA link);
//! * **drain/epoch lifecycle** — role switches drain a GPU, bump its
//!   epoch so stale completions are dropped, and re-route queued work;
//! * **the KV ring** — per-node ring-slot accounting between prefill and
//!   decode (backpressure, paper §3.2);
//! * **hierarchical power** — [`crate::power::PowerManager`] enforcing
//!   per-node budgets under a cluster-wide cap;
//! * **multi-tenant admission & preemption** — [`admission`] sheds
//!   arrivals lowest-tier-first when an `[admission]` table is present
//!   (shed requests become SLO-violation records, never silent drops,
//!   so request conservation holds), and saturated decode batches swap
//!   a waiting higher-tier request in for the lowest-tier active
//!   decode, preserving the victim's `tokens_done` progress and HBM
//!   reservation.
//!
//! **Bit-identity contract**: without `[tenant.*]` and `[admission]`
//! tables both mechanisms are structurally inert — the admission gate
//! is never consulted and the preemption comparison can never fire
//! (every request is the same standard tier) — so untenanted runs are
//! bit-identical to pre-tenant builds.
//!
//! Per-role step behavior lives in [`crate::sim::worker`]; control lives
//! behind [`policy::Policy`]. The public entry point remains
//! [`crate::sim::run`].

pub mod admission;
pub mod env;
pub mod policy;
pub mod store;

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::config::ClusterConfig;
use crate::coordinator::router::{self, LoadIndex, LoadKey, WorkerLoad};
use crate::coordinator::{Action, Snapshot};
use crate::env::EnvEvent;
use crate::fleet::Fleet;
use crate::mem::MemState;
use crate::metrics::RunResult;
use crate::power::{PowerManager, PowerModel};
use crate::sim::engine::SimOptions;
use crate::sim::event::{Event, EventQueue};
use crate::sim::gpu::GpuSim;
use crate::sim::worker;
use crate::types::{GpuId, Micros, Request, RequestRecord, Role, SECOND};
use crate::util::slab::SlotId;
use crate::util::stats::TimeSeries;
use crate::workload::Trace;

use policy::Policy;
use store::{ReqState, RequestStore};

/// Struct-of-arrays mirror of the per-GPU fields the controller reads
/// every tick. `snapshot()`, the tick TTFT projection and the router's
/// load fills walk these flat vectors instead of hopping across
/// `GpuSim` structs (each several cache lines wide); the arrays are
/// kept coherent at the same choke points that maintain the
/// [`LoadIndex`] (`reindex`/`sync_hot`), and debug builds re-derive
/// every field from the live `GpuSim`s each tick and assert equality.
pub(crate) struct HotState {
    /// Current role (`GpuSim::role`).
    pub role: Vec<Role>,
    /// Committed role (drain target while draining).
    pub committed: Vec<Role>,
    pub failed: Vec<bool>,
    pub accepting: Vec<bool>,
    pub pf_len: Vec<u32>,
    pub co_len: Vec<u32>,
    pub dec_pending_len: Vec<u32>,
    pub dec_active_len: Vec<u32>,
    pub pf_tokens: Vec<u64>,
    pub co_tokens: Vec<u64>,
    /// Arrival of the head queued prompt (prefill queue, or chunk queue
    /// on coalesced GPUs); `u64::MAX` when the queue is empty.
    pub head_arrival: Vec<Micros>,
    /// TTFT SLO of the head queued prompt (µs; 1 when empty).
    pub head_ttft: Vec<Micros>,
}

impl HotState {
    fn new(total: usize) -> Self {
        HotState {
            role: vec![Role::Decode; total],
            committed: vec![Role::Decode; total],
            failed: vec![false; total],
            accepting: vec![true; total],
            pf_len: vec![0; total],
            co_len: vec![0; total],
            dec_pending_len: vec![0; total],
            dec_active_len: vec![0; total],
            pf_tokens: vec![0; total],
            co_tokens: vec![0; total],
            head_arrival: vec![u64::MAX; total],
            head_ttft: vec![1; total],
        }
    }
}

/// The cluster simulation state. Fields are `pub(crate)` so the role
/// behaviors in `sim::worker` can operate on it directly.
pub struct Cluster {
    pub(crate) cfg: ClusterConfig,
    /// Per-GPU SKU view: perf/power models, envelopes, router scales.
    pub(crate) fleet: Fleet,
    pub(crate) power: PowerManager,
    pub(crate) policy: Box<dyn Policy>,
    pub(crate) gpus: Vec<GpuSim>,
    /// Slab of in-flight request state; queues and events carry
    /// [`SlotId`]s into this store (see [`store`]).
    pub(crate) store: RequestStore,
    /// Per-GPU hot-field mirror for the tick-rate readers.
    pub(crate) hot: HotState,
    pub(crate) events: EventQueue,
    pub(crate) now: Micros,
    /// Shared immutable workload: study cells borrow one arena-built
    /// trace instead of cloning it per cell (an `Arc` bump).
    pub(crate) trace: Arc<Trace>,
    pub(crate) next_arrival: usize,
    pub(crate) records: Vec<RequestRecord>,
    /// KV ring occupancy per node (slots in flight between prefill and
    /// decode on that node's ring).
    pub(crate) ring_used: Vec<usize>,
    pub(crate) opts: SimOptions,
    /// Expanded environment disturbance timeline (empty = undisturbed;
    /// see `crate::env` and `cluster::env`).
    pub(crate) env_timeline: Vec<EnvEvent>,
    /// Disturbances actually applied: (t, label) for RunResult.
    pub(crate) env_applied: Vec<(Micros, String)>,
    /// Cluster-budget steps: (t, new budget).
    pub(crate) budget_trace: Vec<(Micros, f64)>,
    /// Work stranded when every eligible GPU was down; re-routed on the
    /// next recovery (or recorded as violations at the hard stop).
    pub(crate) orphan_reqs: Vec<SlotId>,
    pub(crate) orphan_items: Vec<SlotId>,
    /// KV memory subsystem: per-GPU HBM pools, tiered offload and the
    /// prefix cache (DESIGN.md §14). Inert unless `[mem]` is configured.
    pub(crate) mem: MemState,
    /// Per-request conversation identity from the multi-turn workload
    /// transform: request id → (conversation id, reusable prefix tokens).
    pub(crate) conv_of: HashMap<u64, (u64, u32)>,
    /// Per-node KV re-transfers deferred because the ring was full,
    /// (via GPU, slot); drained FIFO as ring slots free in `on_kv_arrive`.
    pub(crate) retransfer_wait: Vec<VecDeque<(usize, SlotId)>>,
    /// Fleet-max HBM occupancy per telemetry sample (the series the
    /// "resident KV <= HBM capacity" ShapeCheck walks).
    pub(crate) mem_trace: Vec<(Micros, f64)>,
    /// Admission control (DESIGN.md §15). Inert (`!active()`) unless an
    /// `[admission]` table selected a shedding mode.
    pub(crate) admission: admission::AdmissionState,
    /// Tenant id -> priority tier (index 0 = untenanted standard).
    pub(crate) tenant_tiers: Vec<u8>,
    /// Decode preemptions suffered per tier (preempted side).
    pub(crate) preempted_by_tier: [u64; 3],
    // --- result accumulation ---
    cluster_power: TimeSeries,
    node_power: Vec<TimeSeries>,
    pub(crate) cap_trace: Vec<(Micros, Vec<f64>)>,
    role_trace: Vec<(Micros, usize, usize)>,
    pub(crate) decisions: Vec<(Micros, String)>,
    provisioned_integral: f64,
    last_sample_at: Micros,
    hard_stop: Micros,
    /// Telemetry-only RNG: models sub-sample-interval power microbursts
    /// (kernel gaps, transfer stalls) that a 10 ms meter sees on real
    /// hardware. Never feeds back into scheduling decisions' latencies.
    sample_rng: crate::util::rng::Rng,
    /// Events processed so far (RunResult::sim_events).
    events_handled: u64,
    // --- incremental routing state (thousand-node fleets) ---
    /// Live members of each role (`role == X && !failed`), ascending GPU
    /// id — the linear reference fills walk these instead of every GPU.
    pub(crate) prefill_ids: Vec<usize>,
    pub(crate) decode_ids: Vec<usize>,
    pub(crate) coalesced_ids: Vec<usize>,
    /// Ordered pick indexes over *accepting* workers, maintained at
    /// every load/role/failure mutation; picks are O(log n).
    prefill_index: LoadIndex,
    decode_index: LoadIndex,
    // --- reused scratch (hot paths allocate nothing per event) ---
    /// Router view buffer, refilled per routing decision.
    scratch_loads: Vec<WorkerLoad>,
    /// Prefill batch formation buffer (`kick_prefill`).
    pub(crate) scratch_batch: Vec<SlotId>,
    /// Finished-decode buffer (`on_decode_step` / `on_coalesced_step`).
    pub(crate) scratch_done: Vec<SlotId>,
    /// Per-node power accumulation buffer (`on_sample`).
    scratch_node_w: Vec<f64>,
    /// Set once the run is over (records complete, hard stop passed or
    /// the event queue drained); `step_events` then refuses to proceed.
    done: bool,
    /// `RAPID_DEBUG_TICKS` looked up once at construction — an env::var
    /// probe per tick allocates, which the steady-state allocation test
    /// forbids.
    debug_ticks: bool,
    /// Observability sink (DESIGN.md §17). `None` unless
    /// `SimOptions::obs_events > 0`: the disabled path is a single
    /// `Option::is_none` branch per record site, constructs no event,
    /// and leaves `RunResult` bit-identical (golden-tested).
    pub(crate) obs: Option<Box<crate::obs::ObsSink>>,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig, trace: Arc<Trace>, opts: SimOptions) -> Self {
        let fleet = Fleet::of_config(&cfg);
        let total = cfg.total_gpus();
        // Initial caps: the role's configured cap, clamped into each
        // slot's SKU envelope — the same `slot_cap` the budget
        // validation sums, so validation and runtime cannot disagree.
        let caps: Vec<f64> = (0..total).map(|i| cfg.slot_cap(i % cfg.n_gpus)).collect();
        let node_of: Vec<usize> = (0..total).map(|i| cfg.node_of(i)).collect();
        let power = PowerManager::with_limits(
            &caps,
            node_of,
            vec![cfg.node_budget_w; cfg.n_nodes],
            cfg.cluster_budget(),
            cfg.enforce_budget,
            fleet.floors(),
            fleet.maxes(),
        );
        let gpus: Vec<GpuSim> = (0..total).map(|i| GpuSim::new(cfg.initial_role(i))).collect();
        let policy = policy::make_policy(&cfg);
        let hard_stop = trace
            .requests
            .last()
            .map(|r| r.arrival)
            .unwrap_or(0)
            + opts.drain_grace;
        let n_requests = trace.requests.len();
        let env_timeline = cfg.env.expand(total, cfg.cluster_budget(), hard_stop);
        // The memory subsystem only engages on the disaggregated
        // topology (its hooks live on the prefill→decode KV path); with
        // no `[mem]` table it is structurally inert and the run is
        // bit-identical to a build without the subsystem.
        let mem = match (&cfg.mem, &cfg.topology) {
            (Some(mc), crate::config::Topology::Disaggregated { .. }) => {
                MemState::new(mc.clone(), &fleet.hbm_caps())
            }
            _ => MemState::inactive(),
        };
        let conv_of: HashMap<u64, (u64, u32)> = trace
            .conv
            .iter()
            .map(|c| (c.req_id, (c.conv, c.prefix_tokens)))
            .collect();
        let admission = admission::AdmissionState::new(cfg.admission.clone(), &cfg.tenants);
        let tenant_tiers = crate::workload::tracespec::tier_table(&cfg.tenants);
        let obs = if opts.obs_events > 0 {
            Some(Box::new(crate::obs::ObsSink::new(
                opts.obs_events,
                (0..total).map(|i| cfg.node_of(i) as u32).collect(),
            )))
        } else {
            None
        };
        let mut cl = Cluster {
            fleet,
            power,
            policy,
            gpus,
            // In-system population is bounded by queue depths, far below
            // the trace length; the cap only bounds the pre-reservation.
            store: RequestStore::with_capacity(n_requests.min(1024)),
            hot: HotState::new(total),
            events: EventQueue::with_capacity(2 * total + 16),
            now: 0,
            trace,
            next_arrival: 0,
            records: Vec::with_capacity(n_requests),
            ring_used: vec![0; cfg.n_nodes],
            env_timeline,
            env_applied: Vec::new(),
            budget_trace: Vec::new(),
            orphan_reqs: Vec::new(),
            orphan_items: Vec::new(),
            mem,
            conv_of,
            retransfer_wait: (0..cfg.n_nodes).map(|_| VecDeque::with_capacity(8)).collect(),
            mem_trace: Vec::new(),
            admission,
            tenant_tiers,
            preempted_by_tier: [0; 3],
            cluster_power: TimeSeries::new(),
            node_power: (0..cfg.n_nodes).map(|_| TimeSeries::new()).collect(),
            cap_trace: Vec::new(),
            role_trace: Vec::new(),
            decisions: Vec::new(),
            provisioned_integral: 0.0,
            last_sample_at: 0,
            opts,
            hard_stop,
            sample_rng: crate::util::rng::Rng::new(0xF16_3),
            events_handled: 0,
            prefill_ids: Vec::new(),
            decode_ids: Vec::new(),
            coalesced_ids: Vec::new(),
            prefill_index: LoadIndex::new(total, cfg.n_nodes),
            decode_index: LoadIndex::new(total, cfg.n_nodes),
            scratch_loads: Vec::with_capacity(total),
            scratch_batch: Vec::with_capacity(cfg.batch.max_prefill_reqs),
            scratch_done: Vec::with_capacity(cfg.batch.max_decode_reqs),
            scratch_node_w: Vec::with_capacity(cfg.n_nodes),
            done: false,
            debug_ticks: std::env::var("RAPID_DEBUG_TICKS").is_ok(),
            obs,
            cfg,
        };
        for gi in 0..cl.gpus.len() {
            cl.refresh_worker(gi);
        }
        cl
    }

    pub fn run(mut self) -> RunResult {
        self.prime();
        self.step_events(u64::MAX);
        self.finish()
    }

    /// Seed the initial event population: first arrival, controller
    /// tick, environment timeline, telemetry sample. Split from [`run`]
    /// so tests can drive the loop incrementally via [`step_events`].
    pub fn prime(&mut self) {
        if !self.trace.requests.is_empty() {
            self.events.push(self.trace.requests[0].arrival, Event::Arrival);
        }
        self.events.push(self.cfg.controller.tick, Event::ControllerTick);
        // Env events enqueue before the first Sample so that at equal
        // timestamps a disturbance always applies before telemetry (and
        // before any controller tick pushed later): every cap-trace
        // point reflects the budget in force at its instant.
        for i in 0..self.env_timeline.len() {
            let at = self.env_timeline[i].at;
            self.events.push(at, Event::Env { idx: i });
        }
        self.events.push(0, Event::Sample);
        self.record_roles();
    }

    /// Process up to `n` events, returning how many were handled. Stops
    /// early — and latches `done` — when the run is over: every record
    /// accounted for, the hard stop passed, or the queue drained.
    /// `run()` is exactly `prime()` + `step_events(u64::MAX)` +
    /// `finish()`; the split exists for incremental drivers (the
    /// steady-state allocation test steps a warmed run event by event).
    pub fn step_events(&mut self, n: u64) -> u64 {
        let total = self.trace.requests.len();
        let mut handled = 0u64;
        while handled < n && !self.done {
            let Some((at, ev)) = self.events.pop() else {
                self.done = true;
                break;
            };
            debug_assert!(at >= self.now, "time went backwards");
            self.now = at;
            if self.records.len() >= total || self.now > self.hard_stop {
                self.done = true;
                break;
            }
            self.events_handled += 1;
            handled += 1;
            self.handle(ev);
        }
        handled
    }

    /// Current simulated time (µs).
    pub fn now(&self) -> Micros {
        self.now
    }

    // ------------------------------------------------------------------
    // topology helpers
    // ------------------------------------------------------------------

    /// Node hosting cluster-global GPU `gi`.
    pub(crate) fn node_of(&self, gi: usize) -> usize {
        gi / self.cfg.n_gpus
    }

    /// Perf/power model of GPU `gi` (per-SKU; allocation-free lookup).
    #[inline]
    pub(crate) fn model_of(&self, gi: usize) -> &PowerModel {
        self.fleet.model(gi)
    }

    /// Free KV ring slots on `node`.
    pub(crate) fn ring_free(&self, node: usize) -> usize {
        self.cfg.batch.ring_slots.saturating_sub(self.ring_used[node])
    }

    /// Projected peak KV footprint of a decode context hosted on `gi`:
    /// prompt + reused prefix + full output, in that SKU's bytes/token —
    /// the same sizing the per-SKU re-fetch cost model uses.
    pub(crate) fn kv_bytes_for(&self, gi: usize, st: &ReqState) -> u64 {
        let tokens =
            st.req.input_tokens as u64 + st.cached_tokens as u64 + st.req.output_tokens as u64;
        tokens * self.model_of(gi).cfg().kv_bytes_per_token
    }

    /// KV footprint of the request behind `slot` when hosted on `gi`.
    pub(crate) fn kv_bytes_for_slot(&self, gi: usize, slot: SlotId) -> u64 {
        self.kv_bytes_for(gi, self.store.get(slot))
    }

    /// Register the demotion work a successful `reserve` incurred on
    /// `gi`: extend the decode stall deadline, schedule the epoch-guarded
    /// resume event and let the policy weigh the eviction cost.
    pub(crate) fn note_eviction(&mut self, gi: usize, ev: crate::mem::Eviction) {
        if ev.bytes == 0 {
            return;
        }
        if let Some(o) = self.obs.as_deref_mut() {
            o.record(crate::obs::ObsEvent::MemEvict {
                at: self.now,
                gpu: gi,
                bytes: ev.bytes,
            });
        }
        let until = (self.now + ev.time).max(self.mem.evict_until[gi]);
        self.mem.evict_until[gi] = until;
        let epoch = self.gpus[gi].epoch;
        self.events.push(until, Event::MemEvict { gpu: gi, epoch });
        let occ = self.mem.occupancy(gi);
        let now = self.now;
        self.policy.on_memory_pressure(now, gi, occ, ev.bytes);
    }

    // ------------------------------------------------------------------
    // incremental routing state
    // ------------------------------------------------------------------

    /// Re-derive `gi`'s entries in both pick indexes from its live
    /// state. Called after every mutation that can change a routing
    /// decision: enqueue, batch start, decode completion, drain begin,
    /// role flip, failure, recovery. Cheap when nothing changed.
    pub(crate) fn reindex(&mut self, gi: usize) {
        let node = self.node_of(gi);
        let (pf, dec) = {
            let g = &self.gpus[gi];
            let pf = (g.role == Role::Prefill && g.accepting()).then(|| {
                LoadKey::prefill(
                    g.pf_queued_tokens,
                    g.pf_queue.len(),
                    self.fleet.prefill_scale(gi),
                    0.0,
                    gi,
                )
            });
            let dec = (g.role == Role::Decode && g.accepting()).then(|| {
                LoadKey::decode(
                    g.decode_load(),
                    0,
                    self.fleet.decode_scale(gi),
                    self.mem.pressure(gi, self.cfg.batch.max_decode_reqs),
                    gi,
                )
            });
            (pf, dec)
        };
        self.prefill_index.update(gi, node, pf);
        self.decode_index.update(gi, node, dec);
        self.sync_hot(gi);
    }

    /// Refresh `gi`'s row of the [`HotState`] mirror from the live
    /// `GpuSim`. O(1); called from [`Self::reindex`] plus the few
    /// mutation sites that change tick-visible fields without touching
    /// the routing indexes (coalesced queue moves, drain teardown,
    /// decode admission swaps via the `kick_*` wrappers).
    pub(crate) fn sync_hot(&mut self, gi: usize) {
        let g = &self.gpus[gi];
        let h = &mut self.hot;
        h.role[gi] = g.role;
        h.committed[gi] = g.committed_role();
        h.failed[gi] = g.failed;
        h.accepting[gi] = g.accepting();
        h.pf_len[gi] = g.pf_queue.len() as u32;
        h.co_len[gi] = g.co_queue.len() as u32;
        h.dec_pending_len[gi] = g.dec_pending.len() as u32;
        h.dec_active_len[gi] = g.dec_active.len() as u32;
        h.pf_tokens[gi] = g.pf_queued_tokens;
        h.co_tokens[gi] = g.co_tokens;
        let head = match g.role {
            Role::Coalesced => g.co_queue.front(),
            _ => g.pf_queue.front(),
        };
        match head {
            Some(&s) => {
                let r = &self.store.get(s).req;
                h.head_arrival[gi] = r.arrival;
                h.head_ttft[gi] = r.slo.ttft;
            }
            None => {
                h.head_arrival[gi] = u64::MAX;
                h.head_ttft[gi] = 1;
            }
        }
    }

    /// Debug-build coherence comparator (the golden-comparator pattern):
    /// re-derive every `HotState` field from the live `GpuSim`s and
    /// assert the mirror matches. Runs each controller tick in debug
    /// builds, so any missed `sync_hot` site fails loudly under the
    /// whole test suite rather than skewing release-mode decisions.
    #[cfg(debug_assertions)]
    fn assert_hot_coherent(&self) {
        for (gi, g) in self.gpus.iter().enumerate() {
            let h = &self.hot;
            debug_assert_eq!(h.role[gi], g.role, "hot.role stale for gpu {gi}");
            debug_assert_eq!(h.committed[gi], g.committed_role(), "hot.committed stale for gpu {gi}");
            debug_assert_eq!(h.failed[gi], g.failed, "hot.failed stale for gpu {gi}");
            debug_assert_eq!(h.accepting[gi], g.accepting(), "hot.accepting stale for gpu {gi}");
            debug_assert_eq!(h.pf_len[gi] as usize, g.pf_queue.len(), "hot.pf_len stale for gpu {gi}");
            debug_assert_eq!(h.co_len[gi] as usize, g.co_queue.len(), "hot.co_len stale for gpu {gi}");
            debug_assert_eq!(
                h.dec_pending_len[gi] as usize,
                g.dec_pending.len(),
                "hot.dec_pending_len stale for gpu {gi}"
            );
            debug_assert_eq!(
                h.dec_active_len[gi] as usize,
                g.dec_active.len(),
                "hot.dec_active_len stale for gpu {gi}"
            );
            debug_assert_eq!(h.pf_tokens[gi], g.pf_queued_tokens, "hot.pf_tokens stale for gpu {gi}");
            debug_assert_eq!(h.co_tokens[gi], g.co_tokens, "hot.co_tokens stale for gpu {gi}");
            let head = match g.role {
                Role::Coalesced => g.co_queue.front(),
                _ => g.pf_queue.front(),
            };
            let (want_arrival, want_ttft) = match head {
                Some(&s) => {
                    let r = &self.store.get(s).req;
                    (r.arrival, r.slo.ttft)
                }
                None => (u64::MAX, 1),
            };
            debug_assert_eq!(h.head_arrival[gi], want_arrival, "hot.head_arrival stale for gpu {gi}");
            debug_assert_eq!(h.head_ttft[gi], want_ttft, "hot.head_ttft stale for gpu {gi}");
        }
    }

    /// Reindex plus role-list membership — for role flips, failures and
    /// recoveries (load-only changes take the cheaper [`Self::reindex`]).
    pub(crate) fn refresh_worker(&mut self, gi: usize) {
        for role in [Role::Prefill, Role::Decode, Role::Coalesced] {
            let member = {
                let g = &self.gpus[gi];
                g.role == role && !g.failed
            };
            let ids = match role {
                Role::Prefill => &mut self.prefill_ids,
                Role::Decode => &mut self.decode_ids,
                Role::Coalesced => &mut self.coalesced_ids,
            };
            match (ids.binary_search(&gi), member) {
                (Ok(pos), false) => {
                    ids.remove(pos);
                }
                (Err(pos), true) => ids.insert(pos, gi),
                _ => {}
            }
        }
        self.reindex(gi);
    }

    /// Router view of every prefill worker, into a caller-owned buffer.
    /// `perf_scale` normalizes queued tokens by SKU throughput so a
    /// faster part absorbs proportionally more backlog (1.0 everywhere
    /// on a homogeneous fleet). Only the maintained role members are
    /// walked, so the debug-build reference comparator stays cheap.
    fn fill_prefill_loads(&self, out: &mut Vec<WorkerLoad>) {
        out.clear();
        for &i in &self.prefill_ids {
            out.push(WorkerLoad {
                gpu: GpuId(i),
                node: self.node_of(i),
                queued_tokens: self.hot.pf_tokens[i],
                requests: self.hot.pf_len[i] as usize,
                accepting: self.hot.accepting[i],
                perf_scale: self.fleet.prefill_scale(i),
                mem_pressure: 0.0,
            });
        }
    }

    /// Router view of every decode worker, optionally excluding one GPU
    /// (drain re-routing must not pick the drainer itself).
    fn fill_decode_loads(&self, exclude: Option<usize>, out: &mut Vec<WorkerLoad>) {
        out.clear();
        for &i in &self.decode_ids {
            if Some(i) == exclude {
                continue;
            }
            out.push(WorkerLoad {
                gpu: GpuId(i),
                node: self.node_of(i),
                queued_tokens: 0,
                requests: (self.hot.dec_pending_len[i] + self.hot.dec_active_len[i]) as usize,
                accepting: self.hot.accepting[i],
                perf_scale: self.fleet.decode_scale(i),
                // Deliberately a live read: pressure moves with HBM
                // reservations, which do not pass through `sync_hot`.
                mem_pressure: self.mem.pressure(i, self.cfg.batch.max_decode_reqs),
            });
        }
    }

    /// Least-loaded accepting prefill worker, read off the incremental
    /// index (O(log n)). Debug builds re-derive the pick with the linear
    /// reference scan and assert equality, exact ties included.
    pub(crate) fn pick_prefill_gpu(&mut self) -> Option<GpuId> {
        let pick = self.prefill_index.pick(None);
        #[cfg(debug_assertions)]
        {
            let mut loads = std::mem::take(&mut self.scratch_loads);
            self.fill_prefill_loads(&mut loads);
            let reference = router::pick_prefill(&loads);
            self.scratch_loads = loads;
            debug_assert_eq!(pick, reference, "indexed prefill pick != linear reference");
        }
        pick
    }

    /// Least-loaded accepting decode worker with same-node preference,
    /// read off the incremental index (O(log n)); debug builds assert
    /// equality against the linear reference.
    pub(crate) fn pick_decode_gpu(
        &mut self,
        exclude: Option<usize>,
        prefer_node: usize,
    ) -> Option<GpuId> {
        let pick = self.decode_index.pick_prefer_node(prefer_node, exclude);
        #[cfg(debug_assertions)]
        {
            let mut loads = std::mem::take(&mut self.scratch_loads);
            self.fill_decode_loads(exclude, &mut loads);
            let reference = router::pick_decode_prefer_node(&loads, prefer_node);
            self.scratch_loads = loads;
            debug_assert_eq!(pick, reference, "indexed decode pick != linear reference");
        }
        pick
    }

    /// Append a completion record.
    pub(crate) fn push_record(
        &mut self,
        req: &Request,
        prefill_start: Micros,
        first_token: Micros,
        finish: Micros,
    ) {
        self.records.push(RequestRecord {
            id: req.id,
            arrival: req.arrival,
            prefill_start,
            first_token,
            finish,
            input_tokens: req.input_tokens,
            output_tokens: req.output_tokens,
            slo: req.slo,
            tenant: req.tenant,
            shed: false,
        });
    }

    /// Priority tier of a tenant id (untenanted and out-of-range ids
    /// read as standard).
    pub(crate) fn tier_of(&self, tenant: u8) -> u8 {
        self.tenant_tiers
            .get(tenant as usize)
            .copied()
            .unwrap_or(crate::workload::tracespec::TIER_STANDARD)
    }

    // ------------------------------------------------------------------
    // event dispatch
    // ------------------------------------------------------------------

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::Arrival => self.on_arrival(),
            Event::StepDone { gpu, epoch } => {
                let role = self.gpus[gpu].role;
                worker::behavior(role).on_step_done(self, gpu, epoch);
            }
            Event::KvArrive { gpu, src_node, slot } => self.on_kv_arrive(gpu, src_node, slot),
            Event::ControllerTick => self.on_tick(),
            Event::PowerPoll => self.on_power_poll(),
            Event::Sample => self.on_sample(),
            Event::DrainDone { gpu, epoch } => self.on_drain_done(gpu, epoch),
            Event::Env { idx } => self.on_env(idx),
            Event::MemEvict { gpu, epoch } => {
                if self.gpus[gpu].epoch == epoch {
                    self.kick_decode(gpu); // eviction stall elapsed
                }
            }
        }
    }

    fn on_arrival(&mut self) {
        let mut req = self.trace.requests[self.next_arrival];
        self.next_arrival += 1;
        if self.next_arrival < self.trace.requests.len() {
            self.events
                .push(self.trace.requests[self.next_arrival].arrival, Event::Arrival);
        }
        if let Some(o) = self.obs.as_deref_mut() {
            o.record(crate::obs::ObsEvent::Arrival {
                at: self.now,
                req: req.id.0,
                tenant: req.tenant,
                input: req.input_tokens,
                output: req.output_tokens,
            });
        }
        // Admission control (inert without an `[admission]` table): a
        // shed arrival is decided before any routing or prefix-cache
        // work, so it leaves no trace beyond its violation record.
        if self.admission.active() {
            let in_system = self.next_arrival - self.records.len();
            let tier = self.tier_of(req.tenant);
            let now = self.now;
            if !self.admission.admit(now, req.tenant, tier, in_system) {
                if let Some(o) = self.obs.as_deref_mut() {
                    o.record(crate::obs::ObsEvent::Shed {
                        at: now,
                        req: req.id.0,
                        tenant: req.tenant,
                        in_system,
                    });
                }
                self.shed_request(&req);
                return;
            }
        }
        // Multi-turn prefix reuse: a cache hit shrinks the prompt to the
        // un-cached suffix (skipping its re-prefill); the tier fetch time
        // is paid when the KV publishes to the decode pool.
        if self.mem.active() {
            if let Some(&(conv, prefix)) = self.conv_of.get(&req.id.0) {
                let bpt = self.cfg.perf.kv_bytes_per_token;
                if let Some(cached) =
                    self.mem.prefix_lookup(req.id.0, conv, prefix, req.input_tokens, bpt)
                {
                    req.input_tokens -= cached;
                    if let Some(o) = self.obs.as_deref_mut() {
                        o.record(crate::obs::ObsEvent::PrefixHit {
                            at: self.now,
                            req: req.id.0,
                            tokens: cached,
                        });
                    }
                }
            }
        }
        // The slot is born here — after admission (shed arrivals never
        // touch the store) and after the prefix-cache prompt shrink —
        // and dies where its completion record is pushed.
        let slot = self.store.insert(ReqState::new(req));
        self.route_request(slot);
    }

    /// Account a shed arrival: an immediate SLO-violation record with
    /// the `shed` flag (conservation counts it, attainment does not —
    /// same "infinite latency" shape as the unfinished-request records
    /// in [`Self::finish`]), plus the policy overload hook so a dynamic
    /// controller can trade power moves against further shedding.
    fn shed_request(&mut self, req: &Request) {
        let now = self.now;
        self.records.push(RequestRecord {
            id: req.id,
            arrival: req.arrival,
            prefill_start: now,
            first_token: now + 3600 * SECOND,
            finish: now + 7200 * SECOND,
            input_tokens: req.input_tokens,
            output_tokens: req.output_tokens,
            slo: req.slo,
            tenant: req.tenant,
            shed: true,
        });
        self.policy.on_overload(now);
    }

    /// Route by topology (arrivals, failure requeues, orphan re-entry).
    pub(crate) fn route_request(&mut self, slot: SlotId) {
        match self.cfg.topology {
            crate::config::Topology::Coalesced => self.route_coalesced(slot),
            crate::config::Topology::Disaggregated { .. } => self.route_prefill(slot),
        }
    }

    /// Centrally route a prompt to the least-loaded prefill worker of any
    /// node (paper §3.2's central scheduler, now cluster-wide).
    pub(crate) fn route_prefill(&mut self, slot: SlotId) {
        let input = self.store.get(slot).req.input_tokens;
        let Some(gpu) = self.pick_prefill_gpu() else {
            // No accepting prefill GPU (all draining): park on one with
            // the committed prefill role; it picks the work up after the
            // drain. With failures in play even that can be empty — then
            // the request waits in the orphan pool for a recovery.
            let fallback = self
                .gpus
                .iter()
                .position(|g| !g.failed && g.committed_role() == Role::Prefill);
            match fallback {
                Some(i) => {
                    self.gpus[i].push_prefill(slot, input);
                    self.reindex(i);
                    if let Some(o) = self.obs.as_deref_mut() {
                        let req = self.store.get(slot).req.id.0;
                        o.record(crate::obs::ObsEvent::PrefillQueued { at: self.now, req, gpu: i });
                    }
                }
                None => self.orphan_reqs.push(slot),
            }
            return;
        };
        self.gpus[gpu.0].push_prefill(slot, input);
        self.reindex(gpu.0);
        if let Some(o) = self.obs.as_deref_mut() {
            let req = self.store.get(slot).req.id.0;
            o.record(crate::obs::ObsEvent::PrefillQueued { at: self.now, req, gpu: gpu.0 });
        }
        self.kick_prefill(gpu.0);
    }

    /// Router view of every live coalesced worker, into a caller-owned
    /// buffer — shared by arrival routing and the failure re-dispatch
    /// path so both rank workers identically.
    pub(crate) fn fill_coalesced_loads(&self, exclude: Option<usize>, out: &mut Vec<WorkerLoad>) {
        out.clear();
        for &i in &self.coalesced_ids {
            if Some(i) == exclude {
                continue;
            }
            let g = &self.gpus[i];
            out.push(WorkerLoad {
                gpu: GpuId(i),
                node: self.node_of(i),
                queued_tokens: g.co_queued_tokens(),
                requests: g.co_queue.len() + g.dec_active.len(),
                accepting: g.accepting(),
                perf_scale: self.fleet.prefill_scale(i),
                mem_pressure: 0.0,
            });
        }
    }

    fn route_coalesced(&mut self, slot: SlotId) {
        let mut loads = std::mem::take(&mut self.scratch_loads);
        self.fill_coalesced_loads(None, &mut loads);
        let pick = router::pick_prefill(&loads);
        self.scratch_loads = loads;
        let Some(gpu) = pick else {
            // Every coalesced GPU is down or draining: wait for recovery.
            self.orphan_reqs.push(slot);
            return;
        };
        {
            // (Re-)entering the chunk queue resets chunked-prefill
            // progress — failure requeues restart the prompt, exactly as
            // the old fresh-`ChunkProgress` construction did.
            let st = self.store.get_mut(slot);
            st.chunk_done = 0;
            st.started = None;
            let input = st.req.input_tokens as u64;
            let g = &mut self.gpus[gpu.0];
            g.co_queue.push_back(slot);
            g.co_tokens += input;
        }
        self.sync_hot(gpu.0);
        if let Some(o) = self.obs.as_deref_mut() {
            let req = self.store.get(slot).req.id.0;
            o.record(crate::obs::ObsEvent::PrefillQueued { at: self.now, req, gpu: gpu.0 });
        }
        self.kick_coalesced(gpu.0);
    }

    // ------------------------------------------------------------------
    // policy tick + action execution
    // ------------------------------------------------------------------

    fn on_tick(&mut self) {
        self.events
            .push(self.now + self.cfg.controller.tick, Event::ControllerTick);
        // Every tick-rate reader below walks the HotState mirror; prove
        // it coherent against the live GpuSims first (debug builds).
        #[cfg(debug_assertions)]
        self.assert_hot_coherent();
        // Project queue pressure into the TTFT window: queue buildup must
        // trigger *before* completions report violations (paper §3.3:
        // "queue buildup as an early indicator of stress"). The projection
        // is head wait + expected drain time of the whole backlog, so a
        // deep queue keeps the signal high even right after a power boost
        // clears the head.
        if self.policy.is_dynamic() {
            // Contiguous HotState reads (no GpuSim chasing) plus
            // field-disjoint borrows (hot shared, policy mut) keep this
            // loop allocation-free — no samples buffer.
            let now = self.now;
            for i in 0..self.hot.failed.len() {
                if self.hot.failed[i] || self.hot.head_arrival[i] == u64::MAX {
                    continue;
                }
                let backlog_tokens = match self.hot.role[i] {
                    Role::Coalesced => self.hot.co_tokens[i],
                    _ => self.hot.pf_tokens[i],
                };
                let age = now.saturating_sub(self.hot.head_arrival[i]);
                let cap = self.power.effective(GpuId(i), now);
                let drain =
                    (backlog_tokens as f64 / self.fleet.model(i).prefill_rate(cap) * 1e6) as Micros;
                let projected = age + drain;
                self.policy
                    .observe_ttft(now, projected as f64 / self.hot.head_ttft[i] as f64);
            }
        }
        let snap = self.snapshot();
        if self.debug_ticks {
            eprintln!(
                "tick t={:.2} qP={} qD={} p_sat={} d_sat={} P={} D={}",
                self.now as f64 / 1e6,
                snap.prefill_queue,
                snap.decode_queue,
                snap.prefill_power_saturated,
                snap.decode_power_saturated,
                snap.prefill_gpus,
                snap.decode_gpus
            );
        }
        if let Some(action) = self.policy.decide(&snap) {
            self.execute(action);
        }
    }

    fn pool(&self, role: Role) -> Vec<GpuId> {
        let ids = match role {
            Role::Prefill => &self.prefill_ids,
            Role::Decode => &self.decode_ids,
            Role::Coalesced => &self.coalesced_ids,
        };
        ids.iter()
            .copied()
            .filter(|&i| self.gpus[i].accepting())
            .map(GpuId)
            .collect()
    }

    fn snapshot(&self) -> Snapshot {
        // Single allocation-free pass over the HotState arrays (struct
        // of arrays — contiguous, no per-GpuSim cache-line hops): this
        // runs every controller tick, so it must not build per-role
        // pool vectors.
        let c = &self.cfg.controller;
        let h = &self.hot;
        let mut prefill_queue = 0usize;
        let mut decode_queue = 0usize;
        let mut prefill_committed = 0usize;
        let mut decode_committed = 0usize;
        let mut prefill_pool = 0usize; // accepting members only
        let mut decode_pool = 0usize;
        // Vacuously true over empty pools, exactly like `.all()` on an
        // empty iterator in the pool-vector formulation.
        let mut p_all_at_max = true;
        let mut p_all_at_min = true;
        let mut d_all_at_min = true;
        let mut d_all_at_ceiling = true;
        for i in 0..h.failed.len() {
            if h.failed[i] {
                continue;
            }
            prefill_queue += (h.pf_len[i] + h.co_len[i]) as usize;
            decode_queue += h.dec_pending_len[i] as usize;
            match h.committed[i] {
                Role::Prefill => prefill_committed += 1,
                Role::Decode => decode_committed += 1,
                Role::Coalesced => {}
            }
            if !h.accepting[i] {
                continue;
            }
            let target = self.power.target(GpuId(i));
            // Saturation is judged against each GPU's own envelope (==
            // MIN_P/MAX_P on a homogeneous fleet): a 400 W-max part
            // pinned at 400 W *is* at max even though MAX_P says 750.
            let gpu_max = self.power.max_of(GpuId(i));
            let gpu_min = self.power.min_of(GpuId(i));
            match h.role[i] {
                Role::Prefill => {
                    prefill_pool += 1;
                    p_all_at_max &= target >= gpu_max - 1.0;
                    p_all_at_min &= target <= gpu_min + 1.0;
                }
                Role::Decode => {
                    decode_pool += 1;
                    d_all_at_min &= target <= gpu_min + 1.0;
                    d_all_at_ceiling &= target >= c.decode_ceiling_w.min(gpu_max) - 1.0;
                }
                Role::Coalesced => {}
            }
        }
        let either_pool_empty = prefill_pool == 0 || decode_pool == 0;
        Snapshot {
            now: self.now,
            prefill_queue,
            decode_queue,
            prefill_gpus: prefill_committed,
            decode_gpus: decode_committed,
            // MovePower(D->P) is exhausted when prefill caps hit MAX or
            // decode caps hit MIN.
            prefill_power_saturated: p_all_at_max || d_all_at_min || either_pool_empty,
            // MovePower(P->D) is exhausted when decode caps hit their
            // ceiling (decode gains nothing above the knee) or prefill
            // caps hit MIN.
            decode_power_saturated: d_all_at_ceiling || p_all_at_min || either_pool_empty,
        }
    }

    fn execute(&mut self, action: Action) {
        match action {
            Action::MovePower { from } => {
                let to = if from == Role::Decode {
                    Role::Prefill
                } else {
                    Role::Decode
                };
                let sources = self.pool(from);
                let sinks = self.pool(to);
                if sources.is_empty() || sinks.is_empty() {
                    return;
                }
                let ceiling = if to == Role::Decode {
                    self.cfg.controller.decode_ceiling_w
                } else {
                    self.cfg.controller.max_gpu_w
                };
                let total = self.cfg.controller.power_step_w * sources.len() as f64;
                // Heterogeneous fleets reallocate by marginal tokens/s
                // per watt (steepest sink gains most, flattest source
                // gives most); homogeneous pools keep the paper's
                // uniform split, bit-identically.
                let weighted = self.fleet.heterogeneous()
                    && self.policy.power_weighting() == policy::PowerWeighting::MarginalTps;
                // Audit snapshot before the books move (reads only; both
                // are cached sums, so the disabled path skips them).
                let (budget, committed_before) = if self.obs.is_some() {
                    (self.power.budget(), self.power.committed_total())
                } else {
                    (0.0, 0.0)
                };
                let result = if weighted {
                    let now = self.now;
                    let src_w: Vec<f64> = sources
                        .iter()
                        .map(|&g| {
                            let cap = self.power.target(g);
                            self.fleet.source_weight(g.0, from, cap)
                        })
                        .collect();
                    let sink_w: Vec<f64> = sinks
                        .iter()
                        .map(|&g| {
                            let cap = self.power.target(g);
                            self.fleet.sink_weight(g.0, to, cap)
                        })
                        .collect();
                    self.power
                        .move_power_weighted(now, &sources, &sinks, &src_w, &sink_w, total, ceiling)
                } else {
                    self.power.move_power(self.now, &sources, &sinks, total, ceiling)
                };
                let ok = result.is_ok();
                match result {
                    Ok(mv) => {
                        self.decisions.push((
                            self.now,
                            format!("MovePower {from}->{to}: {:?}", mv.raised),
                        ));
                        self.events.push(mv.effective_at, Event::PowerPoll);
                    }
                    Err(e) => {
                        self.decisions
                            .push((self.now, format!("MovePower {from}->{to} failed: {e}")));
                    }
                }
                if self.obs.is_some() {
                    let committed_after = self.power.committed_total();
                    if let Some(o) = self.obs.as_deref_mut() {
                        o.record(crate::obs::ObsEvent::PowerMove {
                            at: self.now,
                            from,
                            to,
                            watts: total,
                            ok,
                            budget,
                            committed_before,
                            committed_after,
                        });
                    }
                }
            }
            Action::MoveGpu { from } => {
                let to = if from == Role::Decode {
                    Role::Prefill
                } else {
                    Role::Decode
                };
                // Donor: least-loaded accepting GPU of the source role,
                // keeping >= 1 GPU in the source pool (cluster-wide).
                let pool = self.pool(from);
                if pool.len() <= 1 {
                    return;
                }
                let donor = *pool
                    .iter()
                    .min_by_key(|&&g| {
                        let gpu = &self.gpus[g.0];
                        match from {
                            Role::Prefill => gpu.pf_queued_tokens as usize,
                            _ => gpu.decode_load(),
                        }
                    })
                    .unwrap();
                self.decisions
                    .push((self.now, format!("MoveGpu {donor} {from}->{to}")));
                if let Some(o) = self.obs.as_deref_mut() {
                    o.record(crate::obs::ObsEvent::GpuMove {
                        at: self.now,
                        gpu: donor.0,
                        from,
                        to,
                    });
                }
                self.begin_drain(donor.0, to);
                // Paper line 14: uniform power across all GPUs after a
                // role change.
                let settle = self.power.distribute_uniform(self.now);
                self.events.push(settle, Event::PowerPoll);
                self.record_roles();
            }
        }
    }

    // ------------------------------------------------------------------
    // drain / epoch lifecycle
    // ------------------------------------------------------------------

    fn begin_drain(&mut self, gi: usize, to: Role) {
        {
            let g = &mut self.gpus[gi];
            if g.draining_to.is_some() {
                return;
            }
            g.draining_to = Some(to);
        }
        // A drainer accepts nothing: drop out of the pick indexes before
        // its queued work re-routes (it must not pick itself up again).
        self.reindex(gi);
        // Re-route queued (not yet running) work to peers.
        let queued: Vec<SlotId> = {
            let g = &mut self.gpus[gi];
            let drained: Vec<SlotId> = g.pf_queue.drain(..).collect();
            g.pf_queued_tokens = 0;
            drained
        };
        for s in queued {
            self.route_prefill(s);
        }
        let pending: Vec<SlotId> = self.gpus[gi].dec_pending.drain(..).collect();
        let src_node = self.node_of(gi);
        for slot in pending {
            // A full ring used to over-commit here (the slot count ran
            // past `ring_slots`); defer instead and drain FIFO as slots
            // free in `on_kv_arrive`. The drainer's reservation moves
            // with the item (released now, re-reserved at dispatch).
            if self.ring_free(src_node) == 0 {
                if self.mem.active() {
                    let b = self.kv_bytes_for_slot(gi, slot);
                    self.mem.release(gi, b);
                }
                self.retransfer_wait[src_node].push_back((gi, slot));
                continue;
            }
            // Send to the least-loaded other decode GPU, preferring the
            // same node (KV re-transfer is charged: the cache must move
            // with the request, and cross-node hops pay the slower link).
            if let Some(target) = self.pick_decode_gpu(Some(gi), src_node) {
                // The new host must fit the context before the transfer
                // commits; if its pool cannot evict enough, the item
                // stays (it finishes here before the flip).
                if self.mem.active() {
                    let b_new = self.kv_bytes_for_slot(target.0, slot);
                    match self.mem.reserve(target.0, b_new) {
                        Ok(ev) => {
                            self.note_eviction(target.0, ev);
                            let b_old = self.kv_bytes_for_slot(gi, slot);
                            self.mem.release(gi, b_old);
                            self.reindex(target.0);
                        }
                        Err(()) => {
                            self.gpus[gi].dec_pending.push_back(slot);
                            continue;
                        }
                    }
                }
                let same_node = self.node_of(target.0) == src_node;
                let input = self.store.get(slot).req.input_tokens;
                let t = self
                    .fleet
                    .kv_transfer_time_between(gi, target.0, input, same_node);
                self.events.push(
                    self.now + t,
                    Event::KvArrive { gpu: target.0, src_node, slot },
                );
                if let Some(o) = self.obs.as_deref_mut() {
                    let req = self.store.get(slot).req.id.0;
                    let at = self.now;
                    o.record(crate::obs::ObsEvent::Requeue { at, req, gpu: gi, why: "drain" });
                    o.record(crate::obs::ObsEvent::KvSend {
                        at,
                        req,
                        src: gi,
                        dst: target.0,
                        arrive_at: at + t,
                    });
                }
                self.ring_used[src_node] += 1; // re-transfer occupies a slot
                debug_assert!(self.ring_used[src_node] <= self.cfg.batch.ring_slots);
            } else {
                // No other decode GPU: keep it; it finishes before the flip.
                self.gpus[gi].dec_pending.push_back(slot);
            }
        }
        self.sync_hot(gi);
        self.maybe_finish_drain(gi);
    }

    pub(crate) fn maybe_finish_drain(&mut self, gi: usize) {
        let g = &self.gpus[gi];
        if g.draining_to.is_some() && g.drained() {
            let epoch = g.epoch;
            self.events.push(
                self.now + self.cfg.controller.gpu_move_overhead,
                Event::DrainDone { gpu: gi, epoch },
            );
        }
    }

    fn on_drain_done(&mut self, gi: usize, epoch: u64) {
        let g = &mut self.gpus[gi];
        if g.epoch != epoch || g.draining_to.is_none() {
            return;
        }
        g.role = g.draining_to.take().unwrap();
        g.epoch += 1;
        g.busy = false;
        self.refresh_worker(gi);
        self.record_roles();
        let role = self.gpus[gi].role;
        if let Some(o) = self.obs.as_deref_mut() {
            o.record(crate::obs::ObsEvent::RoleFlip { at: self.now, gpu: gi, role });
        }
        worker::behavior(role).kick(self, gi);
        // Rebalance: peers may hold queued work this GPU could take; the
        // router only balances new arrivals, so steal half the longest
        // peer queue (cheap work-stealing on role flips).
        if role == Role::Prefill {
            self.steal_prefill_work(gi);
        }
    }

    fn steal_prefill_work(&mut self, gi: usize) {
        let Some(victim) = self
            .prefill_ids
            .iter()
            .copied()
            .filter(|&i| i != gi)
            .max_by_key(|&i| self.gpus[i].pf_queued_tokens)
        else {
            return;
        };
        let steal_n = self.gpus[victim].pf_queue.len() / 2;
        for _ in 0..steal_n {
            if let Some(s) = self.gpus[victim].pf_queue.pop_back() {
                let input = self.store.get(s).req.input_tokens;
                self.gpus[victim].pf_queued_tokens -= input as u64;
                self.gpus[gi].push_prefill(s, input);
            }
        }
        self.reindex(victim);
        self.reindex(gi);
        self.kick_prefill(gi);
    }

    // ------------------------------------------------------------------
    // power + telemetry
    // ------------------------------------------------------------------

    fn on_power_poll(&mut self) {
        let applied = self.power.poll(self.now);
        if !applied.is_empty() {
            self.cap_trace.push((self.now, self.power.targets()));
            if let Some(o) = self.obs.as_deref_mut() {
                for &(g, w) in &applied {
                    o.record(crate::obs::ObsEvent::CapApplied {
                        at: self.now,
                        gpu: g.0,
                        watts: w,
                    });
                }
            }
        }
        if let Some(at) = self.power.next_pending_at() {
            self.events.push(at, Event::PowerPoll);
        }
    }

    fn on_sample(&mut self) {
        let now = self.now;
        let dt = (now - self.last_sample_at) as f64;
        self.last_sample_at = now;
        let mut per_node = std::mem::take(&mut self.scratch_node_w);
        per_node.clear();
        per_node.resize(self.cfg.n_nodes, 0.0);
        for (i, g) in self.gpus.iter().enumerate() {
            if g.failed {
                continue; // down: draws nothing, meters read nothing
            }
            let cap = self.power.effective(GpuId(i), now);
            let is_prefill_like = matches!(g.role, Role::Prefill | Role::Coalesced);
            let model = self.fleet.model(i);
            let mut mean_draw = model.draw(cap, g.util(), is_prefill_like);
            // Host-side iteration gaps (scheduling, sampling,
            // detokenization) idle the GPU between iterations; a 10 ms
            // meter catches them as deep dips (paper Fig 3's burstiness).
            if g.busy && self.sample_rng.chance(0.12) {
                mean_draw = model.idle_w() + 0.18 * (mean_draw - model.idle_w());
            }
            // Microburst variation around the mean draw (per-kernel power
            // phases under a 10 ms meter).
            let jitter = 1.0 + 0.08 * self.sample_rng.normal();
            per_node[self.node_of(i)] += (mean_draw * jitter).clamp(model.idle_w().min(cap), cap);
        }
        let total: f64 = per_node.iter().sum();
        for (nd, &w) in per_node.iter().enumerate() {
            self.node_power[nd].push(now, w);
        }
        self.scratch_node_w = per_node;
        self.cluster_power.push(now, total);
        // One targets() materialization per sample: the cap trace keeps
        // the vector, the provisioned integral just sums it first.
        let targets = self.power.targets();
        self.provisioned_integral += targets.iter().sum::<f64>() * dt;
        self.cap_trace.push((now, targets));
        if self.mem.active() {
            self.mem_trace.push((now, self.mem.sample_occupancy()));
        }
        self.events.push(now + self.opts.sample_period, Event::Sample);
    }

    fn record_roles(&mut self) {
        let p = self
            .gpus
            .iter()
            .filter(|g| !g.failed && g.committed_role() == Role::Prefill)
            .count();
        let d = self
            .gpus
            .iter()
            .filter(|g| !g.failed && g.committed_role() == Role::Decode)
            .count();
        self.role_trace.push((self.now, p, d));
    }

    fn finish(mut self) -> RunResult {
        let obs = self.obs.take().map(|s| Box::new(s.into_report()));
        let duration = self.now.max(1);
        let mean_provisioned_w = if duration > 0 {
            self.provisioned_integral / duration as f64
        } else {
            0.0
        };
        // Unfinished requests are recorded as violations (never completed):
        // give them "infinite" latency records so attainment counts them.
        let completed: std::collections::HashSet<u64> =
            self.records.iter().map(|r| r.id.0).collect();
        for req in &self.trace.requests[..self.next_arrival] {
            if !completed.contains(&req.id.0) {
                self.records.push(RequestRecord {
                    id: req.id,
                    arrival: req.arrival,
                    prefill_start: self.now,
                    first_token: self.now + 3600 * SECOND,
                    finish: self.now + 7200 * SECOND,
                    input_tokens: req.input_tokens,
                    output_tokens: req.output_tokens,
                    slo: req.slo,
                    tenant: req.tenant,
                    shed: false,
                });
            }
        }
        // Resilience aggregates span the first to the last disturbance
        // actually applied (None when the run was undisturbed).
        let window = self
            .env_applied
            .first()
            .map(|e| e.0)
            .zip(self.env_applied.last().map(|e| e.0));
        let resilience = window.map(|(first, last)| {
            crate::metrics::compute_resilience(&self.records, first, last, duration)
        });
        let mem = if self.mem.active() {
            Some(self.mem.summary())
        } else {
            None
        };
        let mut result = RunResult {
            config_name: self.cfg.name.clone(),
            records: self.records,
            node_power: self.cluster_power,
            node_power_by_node: self.node_power,
            cap_trace: self.cap_trace,
            role_trace: self.role_trace,
            decisions: self.decisions,
            duration,
            mean_provisioned_w,
            sim_events: self.events_handled,
            env_events: self.env_applied,
            budget_trace: self.budget_trace,
            resilience,
            mem,
            mem_trace: self.mem_trace,
            // Tier table only for multi-tenant runs: an empty table
            // keeps `Summary.tenants` None (emitters stay silent).
            tenant_tiers: if self.cfg.tenants.is_empty() {
                Vec::new()
            } else {
                self.tenant_tiers
            },
            preempted_by_tier: self.preempted_by_tier,
            obs,
            summary_cache: None,
        };
        // Aggregate once here so emitters/figure drivers never re-scan
        // the record and power series per metric.
        result.seal_summary();
        result
    }
}
