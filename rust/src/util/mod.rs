//! Shared substrates: PRNG, statistics, JSON, parallel fan-out,
//! property testing.

pub mod check;
pub mod json;
pub mod par;
pub mod rng;
pub mod stats;
