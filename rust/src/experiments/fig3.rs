//! Fig 3: time series of total GPU power for an *uncapped* node running
//! LongBench (≤8 K inputs) at QPS/GPU = 1.5, plotted as 10 ms rolling
//! averages against the 4800 W budget line. The point of the figure:
//! without caps the node frequently exceeds the budget (while staying
//! under the 6000 W hardware limit) — power must be actively managed.

use crate::config::presets;
use crate::experiments::ShapeCheck;
use crate::scenario::{Scenario, Study};
use crate::types::MILLIS;
use crate::util::stats::TimeSeries;

pub struct Fig3 {
    /// 10 ms rolling average of node GPU power.
    pub rolling: TimeSeries,
    pub budget_w: f64,
    pub hw_limit_w: f64,
    pub frac_above_budget: f64,
    pub peak_w: f64,
}

/// Single-cell scenario: the uncapped coalesced node at 1.5 QPS/GPU
/// with the paper's 10 ms telemetry.
pub fn scenario(seed: u64, n: usize) -> Scenario {
    Scenario::new("fig3", presets::uncapped_coalesced())
        .seed(seed)
        .requests(n)
        .rate(1.5)
        .sample_period(10 * MILLIS)
}

pub fn run(seed: u64, n: usize) -> Fig3 {
    let study = Study::new(scenario(seed, n)).run(None).expect("fig3 scenario");
    let result = study.cells[0].result().expect("sim cell");
    let rolling = result.node_power.rolling_mean(10 * MILLIS);
    let frac_above_budget = rolling.frac_above(4800.0);
    let peak_w = rolling.max();
    Fig3 {
        rolling,
        budget_w: 4800.0,
        hw_limit_w: 6000.0,
        frac_above_budget,
        peak_w,
    }
}

impl Fig3 {
    pub fn render(&self) -> String {
        let mut out = String::from(
            "Uncapped node power (10 ms rolling avg), LongBench @1.5 QPS/GPU\n",
        );
        out.push_str(&format!(
            "budget line: {:.0} W | hw limit: {:.0} W | peak: {:.0} W | time above budget: {:.1}%\n",
            self.budget_w,
            self.hw_limit_w,
            self.peak_w,
            self.frac_above_budget * 100.0
        ));
        // Sparkline-style series (sampled down to ~80 columns).
        let pts = &self.rolling.points;
        if !pts.is_empty() {
            let stride = (pts.len() / 80).max(1);
            out.push_str("series (W): ");
            for (i, &(_, v)) in pts.iter().enumerate() {
                if i % stride == 0 {
                    out.push(match v {
                        v if v > 4800.0 => '#',
                        v if v > 3600.0 => '+',
                        v if v > 2400.0 => '-',
                        _ => '.',
                    });
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn checks(&self) -> Vec<ShapeCheck> {
        vec![
            ShapeCheck::new(
                "uncapped node frequently exceeds the 4800 W budget",
                self.frac_above_budget > 0.05,
                format!("{:.1}% of samples above", self.frac_above_budget * 100.0),
            ),
            ShapeCheck::new(
                "... while staying under the 6000 W hardware limit",
                self.peak_w <= self.hw_limit_w + 1.0,
                format!("peak {:.0} W", self.peak_w),
            ),
            ShapeCheck::new(
                "power is bursty, not pinned at the peak",
                self.frac_above_budget < 0.95,
                format!("{:.1}% above", self.frac_above_budget * 100.0),
            ),
        ]
    }
}
