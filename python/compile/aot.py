"""AOT export: lower the L2 model to HLO *text* + weights + manifest.

This is the single build-time bridge between python and rust. It runs once
(`make artifacts`) and produces:

  artifacts/prefill_b{B}.hlo.txt   — prefill executable per batch variant
  artifacts/decode_b{B}.hlo.txt    — decode-step executable per batch variant
  artifacts/weights.bin            — raw little-endian f32, params in
                                     ModelConfig.param_specs() order
  artifacts/manifest.json          — config, param table (name/shape/offset),
                                     variant table (arg & output shapes)

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Calling convention (positional, recorded in the manifest). To keep the
serving hot path free of host<->device tuple traffic (this PJRT build
cannot untuple buffer-execution outputs, and re-uploading weights or KV
per step dominates latency — EXPERIMENTS.md §Perf), each executable has a
SINGLE flat f32 output, the "state":

  state    = concat(k_cache.ravel(), v_cache.ravel(), logits.ravel())
  prefill: [*params, tokens i32[B,S], lens i32[B]]      -> state
  decode:  [*params, token i32[B], pos i32[B], state]   -> state
  extract: [state]                                      -> logits f32[B,V]

The rust runtime keeps `state` as a device-resident buffer chained
between steps; only `extract`'s logits (a few KB) come to the host.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

PREFILL_BATCHES = (1, 2, 4)
DECODE_BATCHES = (1, 2, 4, 8)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    return_tuple=False: every module has exactly ONE array output (the
    flat state or the logits), so the root compiles to a plain array —
    required because this xla_extension's PJRT neither untuples buffer-
    execution outputs nor converts tuple buffers to literals.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _flat_params(cfg: M.ModelConfig, params: Dict[str, jax.Array]) -> List[jax.Array]:
    return [params[name] for name, _ in cfg.param_specs()]


def _shape_entry(arr) -> dict:
    return {"shape": list(arr.shape), "dtype": str(arr.dtype)}


def cache_shape(cfg: M.ModelConfig, batch: int):
    return (cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)


def cache_elems(cfg: M.ModelConfig, batch: int) -> int:
    s = cache_shape(cfg, batch)
    return int(np.prod(s))


def state_elems(cfg: M.ModelConfig, batch: int) -> int:
    return 2 * cache_elems(cfg, batch) + batch * cfg.vocab


def _pack(cfg, batch, logits, kc, vc):
    return jnp.concatenate([kc.ravel(), vc.ravel(), logits.ravel()])


def _unpack_caches(cfg, batch, state):
    n = cache_elems(cfg, batch)
    kc = state[:n].reshape(cache_shape(cfg, batch))
    vc = state[n : 2 * n].reshape(cache_shape(cfg, batch))
    return kc, vc


def lower_prefill(cfg: M.ModelConfig, batch: int):
    """Lower the prefill entry point (single flat state output)."""
    specs = cfg.param_specs()

    def fn(*args):
        flat, tokens, lens = args[: len(specs)], args[len(specs)], args[len(specs) + 1]
        params = {name: a for (name, _), a in zip(specs, flat)}
        logits, kc, vc = M.prefill(cfg, params, tokens, lens)
        return _pack(cfg, batch, logits, kc, vc)

    example = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    example.append(jax.ShapeDtypeStruct((batch, cfg.prefill_seq), jnp.int32))
    example.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    return jax.jit(fn).lower(*example), example


def lower_decode(cfg: M.ModelConfig, batch: int):
    """Lower the decode step (state in, state out — device-chainable)."""
    specs = cfg.param_specs()

    def fn(*args):
        n = len(specs)
        flat, token, pos, state = args[:n], args[n], args[n + 1], args[n + 2]
        params = {name: a for (name, _), a in zip(specs, flat)}
        kc, vc = _unpack_caches(cfg, batch, state)
        logits, kc2, vc2 = M.decode(cfg, params, token, pos, kc, vc)
        return _pack(cfg, batch, logits, kc2, vc2)

    example = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    example.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    example.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    example.append(jax.ShapeDtypeStruct((state_elems(cfg, batch),), jnp.float32))
    return jax.jit(fn).lower(*example), example


def lower_extract(cfg: M.ModelConfig, batch: int):
    """Lower the logits extraction: state -> f32[batch, vocab]."""

    def fn(state):
        n = 2 * cache_elems(cfg, batch)
        return state[n:].reshape(batch, cfg.vocab)

    example = [jax.ShapeDtypeStruct((state_elems(cfg, batch),), jnp.float32)]
    return jax.jit(fn).lower(*example), example


def golden_sample(cfg: M.ModelConfig, params, n_decode: int = 8) -> dict:
    """Greedy continuation the rust runtime must reproduce exactly.

    Uses the byte-level toy tokenizer convention (BOS=256 + raw bytes).
    """
    import jax
    import jax.numpy as jnp

    text = "the power-aware scheduler shifts watts"
    tokens = [256] + [b for b in text.encode()]
    s = cfg.prefill_seq
    padded = tokens[:s] + [0] * max(0, s - len(tokens))
    tok = jnp.array([padded], jnp.int32)
    lens = jnp.array([min(len(tokens), s)], jnp.int32)
    logits, kc, vc = M.prefill(cfg, params, tok, lens)
    out = [int(jnp.argmax(logits[0]))]
    pos = lens
    cur = jnp.array([out[0]], jnp.int32)
    for _ in range(n_decode):
        logits, kc, vc = M.decode(cfg, params, cur, pos, kc, vc)
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        cur = jnp.array([nxt], jnp.int32)
        pos = pos + 1
    return {"prompt_text": text, "prompt_tokens": tokens, "greedy": out}


def export(out_dir: str, seed: int = 0) -> dict:
    """Write all artifacts; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    cfg = M.ModelConfig()
    params = M.init_params(cfg, seed)

    # --- weights.bin + param table -------------------------------------
    offset = 0
    param_table = []
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for name, shape in cfg.param_specs():
            arr = np.asarray(params[name], dtype="<f4")
            f.write(arr.tobytes())
            param_table.append(
                {"name": name, "shape": list(shape), "offset_elems": offset}
            )
            offset += arr.size

    # --- executables -----------------------------------------------------
    variants = []
    for b in PREFILL_BATCHES:
        lowered, example = lower_prefill(cfg, b)
        fname = f"prefill_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        variants.append(
            {
                "kind": "prefill",
                "batch": b,
                "file": fname,
                "state_elems": state_elems(cfg, b),
                "data_args": [
                    {"name": "tokens", "shape": [b, cfg.prefill_seq], "dtype": "int32"},
                    {"name": "lens", "shape": [b], "dtype": "int32"},
                ],
                "outputs": [
                    {"name": "state", "shape": [state_elems(cfg, b)], "dtype": "float32"}
                ],
            }
        )
    for b in DECODE_BATCHES:
        lowered, example = lower_decode(cfg, b)
        fname = f"decode_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        variants.append(
            {
                "kind": "decode",
                "batch": b,
                "file": fname,
                "state_elems": state_elems(cfg, b),
                "data_args": [
                    {"name": "token", "shape": [b], "dtype": "int32"},
                    {"name": "pos", "shape": [b], "dtype": "int32"},
                    {"name": "state", "shape": [state_elems(cfg, b)], "dtype": "float32"},
                ],
                "outputs": [
                    {"name": "state", "shape": [state_elems(cfg, b)], "dtype": "float32"}
                ],
            }
        )
    for b in sorted(set(PREFILL_BATCHES) | set(DECODE_BATCHES)):
        lowered, example = lower_extract(cfg, b)
        fname = f"extract_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        variants.append(
            {
                "kind": "extract",
                "batch": b,
                "file": fname,
                "state_elems": state_elems(cfg, b),
                "data_args": [
                    {"name": "state", "shape": [state_elems(cfg, b)], "dtype": "float32"}
                ],
                "outputs": [
                    {"name": "logits", "shape": [b, cfg.vocab], "dtype": "float32"}
                ],
            }
        )

    manifest = {
        "format_version": 2,
        "seed": seed,
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "prefill_seq": cfg.prefill_seq,
        },
        "weights": {"file": "weights.bin", "total_elems": offset},
        "params": param_table,
        "variants": variants,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden_sample(cfg, params), f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    manifest = export(args.out, args.seed)
    n = len(manifest["variants"])
    print(f"wrote {n} executables + weights to {args.out}")


if __name__ == "__main__":
    main()
