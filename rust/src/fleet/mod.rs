//! Heterogeneous GPU fleets: the SKU catalog and the per-GPU
//! perf/power-model plumbing (DESIGN.md §11).
//!
//! The paper's testbed is homogeneous (one MI300X-class part), but real
//! fleets mix SKUs with different perf-per-watt curves — exactly where
//! power reallocation pays off most, since watts should flow to the
//! GPUs with the steepest marginal tokens/s-per-watt curve. This module
//! owns:
//!
//! * [`GpuSku`] — one part number: a calibrated [`PerfModelConfig`] plus
//!   its power envelope (`idle_w`, `cap_floor_w`, `max_w`);
//! * [`skus`] — the built-in catalog (`mi300x`, `h100`, `a100`), each
//!   calibrated *relative to* the paper's part so homogeneous `mi300x`
//!   fleets reproduce the paper exactly;
//! * [`FleetConfig`] — a per-node ordered SKU mix (`"mi300x:2+a100:2"`
//!   or TOML `cluster.skus = ["mi300x:2", "a100:2"]`), resolved against
//!   the catalog plus any `[sku.<name>]` tables in the config file;
//! * [`Fleet`] — the runtime view the cluster core reads on its hot
//!   paths: per-GPU SKU ids indexing per-SKU [`PowerModel`]s (a plain
//!   `Vec` double-index, allocation-free; see the `fleet/model_lookup`
//!   hot-path bench), per-GPU cap floors/ceilings for the power manager,
//!   router throughput scales, slower-endpoint KV bandwidth resolution,
//!   and the marginal tokens/s-per-watt weights the power reallocator
//!   uses on heterogeneous pools.
//!
//! A config without an explicit mix gets one implicit SKU built from
//! `cfg.perf` and the controller's MIN_P/MAX_P — all single-SKU paths
//! are bit-identical to the pre-fleet code.

use crate::config::{ClusterConfig, PerfModelConfig};
use crate::power::PowerModel;
use crate::types::{Micros, Role, Watts};

/// One GPU part number: its calibrated performance model and power
/// envelope. `idle_w` mirrors `perf.idle_w` (kept in both places so the
/// catalog entry is self-describing and the model stays self-contained).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSku {
    pub name: String,
    pub perf: PerfModelConfig,
    /// Idle draw (W); always equal to `perf.idle_w`.
    pub idle_w: Watts,
    /// Hardware max power cap (W) — the per-GPU ceiling for this SKU.
    pub max_w: Watts,
    /// Lowest cap firmware accepts (W) — the per-GPU floor for this SKU.
    pub cap_floor_w: Watts,
    /// HBM capacity (GB) for the KV memory subsystem. `None` leaves the
    /// SKU uncapped; only enforced when a `[mem]` table activates the
    /// subsystem (DESIGN.md §14).
    pub hbm_gb: Option<f64>,
}

impl GpuSku {
    /// Build a SKU from a perf model and a power envelope (idle comes
    /// from the perf model, keeping the two in sync).
    pub fn new(
        name: impl Into<String>,
        perf: PerfModelConfig,
        cap_floor_w: Watts,
        max_w: Watts,
    ) -> Self {
        GpuSku {
            name: name.into(),
            idle_w: perf.idle_w,
            perf,
            max_w,
            cap_floor_w,
            hbm_gb: None,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.cap_floor_w <= 0.0 || self.max_w <= 0.0 {
            return Err(format!("sku '{}': power envelope must be positive", self.name));
        }
        if self.cap_floor_w > self.max_w {
            return Err(format!(
                "sku '{}': cap_floor_w {} above max_w {}",
                self.name, self.cap_floor_w, self.max_w
            ));
        }
        if (self.idle_w - self.perf.idle_w).abs() > 1e-9 {
            return Err(format!(
                "sku '{}': idle_w {} disagrees with perf.idle_w {}",
                self.name, self.idle_w, self.perf.idle_w
            ));
        }
        if let Some(gb) = self.hbm_gb {
            if gb <= 0.0 {
                return Err(format!("sku '{}': hbm_gb {gb} must be > 0", self.name));
            }
        }
        Ok(())
    }
}

/// The built-in SKU catalog. Constants are calibrated relative to the
/// paper's MI300X-class measurements (DESIGN.md §4): `mi300x` *is* the
/// paper's part; the others are plausible same-model deployments on
/// neighboring hardware classes, chosen so mixed fleets exercise both a
/// stronger-prefill part and a small-envelope part whose caps are
/// nearly immobile (the realistic heterogeneity regime).
pub mod skus {
    use super::*;

    /// The paper's part: `PerfModelConfig::default()` with the
    /// controller's MIN_P/MAX_P envelope. Homogeneous `mi300x` fleets
    /// are bit-identical to the implicit (pre-fleet) configuration.
    pub fn mi300x() -> GpuSku {
        let mut sku = GpuSku::new("mi300x", PerfModelConfig::default(), 400.0, 750.0);
        sku.hbm_gb = Some(192.0);
        sku
    }

    /// Compute-strong 700 W-class part: slightly lower peak prompt rate
    /// than the 750 W part but an earlier prefill knee, weaker decode
    /// scaling, lower idle.
    pub fn h100() -> GpuSku {
        let perf = PerfModelConfig {
            prefill_rate_tps: 8_400.0,
            decode_base: 9_800,
            decode_per_req: 110,
            prefill_speedup_max: 1.7,
            prefill_knee_w: 650.0,
            decode_speedup_max: 1.35,
            decode_knee_w: 480.0,
            idle_w: 110.0,
            ref_w: 350.0,
            rated_w: 700.0,
            decode_rated_w: 480.0,
            ..PerfModelConfig::default()
        };
        let mut sku = GpuSku::new("h100", perf, 350.0, 700.0);
        sku.hbm_gb = Some(80.0);
        sku
    }

    /// Previous-generation 400 W-class part: roughly half the prompt
    /// rate, slower HBM (longer decode base, slower links), and a
    /// narrow 250–400 W envelope that leaves its caps nearly immobile —
    /// watts flow among the bigger parts instead.
    pub fn a100() -> GpuSku {
        let perf = PerfModelConfig {
            prefill_rate_tps: 4_600.0,
            decode_base: 15_000,
            decode_per_req: 150,
            decode_kv_us_per_ktok: 780.0,
            prefill_speedup_max: 1.45,
            prefill_knee_w: 390.0,
            decode_speedup_max: 1.2,
            decode_knee_w: 340.0,
            idle_w: 60.0,
            xgmi_bw: 32e9,
            inter_node_bw: 12.5e9,
            ref_w: 250.0,
            rated_w: 400.0,
            decode_rated_w: 340.0,
            ..PerfModelConfig::default()
        };
        let mut sku = GpuSku::new("a100", perf, 250.0, 400.0);
        sku.hbm_gb = Some(40.0);
        sku
    }

    /// Catalog lookup by name.
    pub fn by_name(name: &str) -> Option<GpuSku> {
        match name {
            "mi300x" => Some(mi300x()),
            "h100" => Some(h100()),
            "a100" => Some(a100()),
            _ => None,
        }
    }

    /// All built-in SKU names (CLI help + docs + tests).
    pub const NAMES: &[&str] = &["mi300x", "h100", "a100"];
}

/// A declared per-node SKU mix: resolved SKUs plus an ordered list of
/// `(sku index, count)` runs. GPU slot `i` on every node gets the SKU
/// the runs assign it, in declaration order — so with a disaggregated
/// `prefill` split the first runs land in the prefill pool.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Resolved SKU table (unique names).
    pub skus: Vec<GpuSku>,
    /// Ordered mix: `(index into skus, count)`; counts sum to the
    /// per-node GPU count.
    pub mix: Vec<(usize, usize)>,
}

impl FleetConfig {
    /// Resolve a mix expression against the built-in catalog plus
    /// `extra` file-defined SKUs (which shadow built-ins by name).
    /// Entries look like `"a100:2"`; `parse_mix` accepts either a slice
    /// of such entries or one `+`-joined string split by the caller.
    pub fn resolve(entries: &[String], extra: &[GpuSku]) -> Result<FleetConfig, String> {
        let mut skus: Vec<GpuSku> = Vec::new();
        let mut mix: Vec<(usize, usize)> = Vec::new();
        for entry in entries {
            let (name, count) = entry
                .rsplit_once(':')
                .ok_or_else(|| format!("sku mix entry '{entry}' must look like 'name:count'"))?;
            let count: usize = count
                .parse()
                .ok()
                .filter(|&c| c > 0)
                .ok_or_else(|| {
                    format!("sku mix entry '{entry}': count must be a positive integer")
                })?;
            let sku = extra
                .iter()
                .find(|s| s.name == name)
                .cloned()
                .or_else(|| skus::by_name(name))
                .ok_or_else(|| {
                    format!(
                        "unknown sku '{name}' (built-in: {}; or define [sku.{name}])",
                        skus::NAMES.join(", ")
                    )
                })?;
            let idx = match skus.iter().position(|s| s.name == name) {
                Some(i) => i,
                None => {
                    skus.push(sku);
                    skus.len() - 1
                }
            };
            mix.push((idx, count));
        }
        if mix.is_empty() {
            return Err("sku mix is empty".into());
        }
        let fc = FleetConfig { skus, mix };
        fc.validate()?;
        Ok(fc)
    }

    /// Parse a single `+`-joined mix string (`"mi300x:2+a100:2"`), the
    /// form the scenario `sku_mix` axis uses.
    pub fn parse_mix(s: &str, extra: &[GpuSku]) -> Result<FleetConfig, String> {
        let entries: Vec<String> = s.split('+').map(|p| p.trim().to_string()).collect();
        FleetConfig::resolve(&entries, extra)
    }

    pub fn validate(&self) -> Result<(), String> {
        for sku in &self.skus {
            sku.validate()?;
        }
        for &(idx, count) in &self.mix {
            if idx >= self.skus.len() {
                return Err("sku mix index out of range".into());
            }
            if count == 0 {
                return Err("sku mix counts must be positive".into());
            }
        }
        Ok(())
    }

    /// GPUs per node this mix describes (sum of the run counts).
    pub fn gpus_per_node(&self) -> usize {
        self.mix.iter().map(|&(_, c)| c).sum()
    }

    /// More than one distinct SKU in the mix?
    pub fn heterogeneous(&self) -> bool {
        let first = self.mix.first().map(|&(i, _)| i);
        self.mix.iter().any(|&(i, _)| Some(i) != first)
    }

    /// SKU index of per-node slot `slot` (0..gpus_per_node).
    pub fn sku_of_slot(&self, slot: usize) -> usize {
        let mut at = 0;
        for &(idx, count) in &self.mix {
            at += count;
            if slot < at {
                return idx;
            }
        }
        self.mix.last().map(|&(i, _)| i).unwrap_or(0)
    }

    /// Canonical `name:count+...` rendering (labels, config names).
    pub fn mix_label(&self) -> String {
        self.mix
            .iter()
            .map(|&(i, c)| format!("{}:{c}", self.skus[i].name))
            .collect::<Vec<_>>()
            .join("+")
    }
}

/// The runtime fleet view: per-SKU models and envelopes plus the
/// per-GPU SKU index, sized for the whole cluster. All accessors are
/// `#[inline]` double-indexes into pre-built `Vec`s — the DES hot paths
/// (per-step model lookups, router load fills, power sampling) touch no
/// allocator through this type.
#[derive(Debug)]
pub struct Fleet {
    /// Per-SKU power/perf models.
    models: Vec<PowerModel>,
    /// Per-SKU cap floors / ceilings (W).
    floor_w: Vec<Watts>,
    max_w: Vec<Watts>,
    /// Per-SKU HBM capacity (GB); `None` = uncapped.
    hbm_gb: Vec<Option<f64>>,
    /// Per-SKU router throughput scales, relative to SKU 0: prefill by
    /// rated prompt rate, decode by rated step time. Exactly 1.0 across
    /// the board for homogeneous fleets.
    prefill_scale: Vec<f64>,
    decode_scale: Vec<f64>,
    /// SKU index of every cluster-global GPU.
    sku_of: Vec<u32>,
    hetero: bool,
}

impl Fleet {
    /// Build the runtime fleet for a configuration. With no explicit
    /// mix, the whole cluster is one implicit SKU made of `cfg.perf`
    /// and the controller's MIN_P/MAX_P envelope (the pre-fleet shape).
    pub fn of_config(cfg: &ClusterConfig) -> Fleet {
        let skus: Vec<GpuSku> = match &cfg.fleet {
            Some(fc) => fc.skus.clone(),
            None => vec![GpuSku::new(
                "default",
                cfg.perf.clone(),
                cfg.controller.min_gpu_w,
                cfg.controller.max_gpu_w,
            )],
        };
        let total = cfg.total_gpus();
        let sku_of: Vec<u32> = (0..total)
            .map(|gi| match &cfg.fleet {
                Some(fc) => fc.sku_of_slot(gi % cfg.n_gpus) as u32,
                None => 0,
            })
            .collect();
        let ref_prefill = skus[0].perf.prefill_rate_tps;
        let ref_decode = skus[0].perf.decode_base as f64;
        let prefill_scale = skus
            .iter()
            .map(|s| s.perf.prefill_rate_tps / ref_prefill)
            .collect();
        let decode_scale = skus
            .iter()
            .map(|s| ref_decode / s.perf.decode_base as f64)
            .collect();
        let hetero = {
            let first = sku_of.first().copied().unwrap_or(0);
            skus.len() > 1 && sku_of.iter().any(|&i| i != first)
        };
        Fleet {
            floor_w: skus.iter().map(|s| s.cap_floor_w).collect(),
            max_w: skus.iter().map(|s| s.max_w).collect(),
            hbm_gb: skus.iter().map(|s| s.hbm_gb).collect(),
            models: skus.into_iter().map(|s| PowerModel::new(s.perf)).collect(),
            prefill_scale,
            decode_scale,
            sku_of,
            hetero,
        }
    }

    /// Number of distinct SKUs.
    pub fn n_skus(&self) -> usize {
        self.models.len()
    }

    /// Does the fleet actually mix SKUs? Homogeneous fleets keep every
    /// pre-fleet code path (uniform power splits, raw router loads).
    #[inline]
    pub fn heterogeneous(&self) -> bool {
        self.hetero
    }

    /// SKU index of cluster-global GPU `gi`.
    #[inline]
    pub fn sku_of(&self, gi: usize) -> usize {
        self.sku_of[gi] as usize
    }

    /// The perf/power model of GPU `gi` (allocation-free double index —
    /// the per-event lookup the `fleet/model_lookup` bench tracks).
    #[inline]
    pub fn model(&self, gi: usize) -> &PowerModel {
        &self.models[self.sku_of[gi] as usize]
    }

    /// Cap floor of GPU `gi` (W).
    #[inline]
    pub fn floor_w(&self, gi: usize) -> Watts {
        self.floor_w[self.sku_of[gi] as usize]
    }

    /// Cap ceiling of GPU `gi` (W).
    #[inline]
    pub fn max_w(&self, gi: usize) -> Watts {
        self.max_w[self.sku_of[gi] as usize]
    }

    /// Router prefill-throughput scale of GPU `gi` (1.0 = SKU 0).
    #[inline]
    pub fn prefill_scale(&self, gi: usize) -> f64 {
        self.prefill_scale[self.sku_of[gi] as usize]
    }

    /// Router decode-throughput scale of GPU `gi` (1.0 = SKU 0).
    #[inline]
    pub fn decode_scale(&self, gi: usize) -> f64 {
        self.decode_scale[self.sku_of[gi] as usize]
    }

    /// HBM capacity (GB) of GPU `gi`'s SKU; `None` = uncapped.
    #[inline]
    pub fn hbm_gb(&self, gi: usize) -> Option<f64> {
        self.hbm_gb[self.sku_of[gi] as usize]
    }

    /// Per-GPU SKU HBM capacities, the slot list `mem::MemState::new`
    /// resolves its pool sizes from.
    pub fn hbm_caps(&self) -> Vec<Option<f64>> {
        (0..self.sku_of.len()).map(|gi| self.hbm_gb(gi)).collect()
    }

    /// Per-GPU cap floors / ceilings for the power manager.
    pub fn floors(&self) -> Vec<Watts> {
        (0..self.sku_of.len()).map(|gi| self.floor_w(gi)).collect()
    }

    pub fn maxes(&self) -> Vec<Watts> {
        (0..self.sku_of.len()).map(|gi| self.max_w(gi)).collect()
    }

    /// Clamp a configured role cap into GPU `gi`'s envelope (a 600 W
    /// uniform cap becomes 400 W on a 400 W-max part).
    pub fn initial_cap(&self, gi: usize, configured: Watts) -> Watts {
        configured.clamp(self.floor_w(gi), self.max_w(gi))
    }

    /// KV transfer time between two endpoints: the **slower endpoint's
    /// bandwidth wins** on the shared hop (a fast NIC cannot push bytes
    /// a slow NIC cannot absorb). Same-node hops use the XGMI-class
    /// link, cross-node hops the RDMA-class link.
    pub fn kv_transfer_time_between(
        &self,
        src: usize,
        dst: usize,
        tokens: u32,
        same_node: bool,
    ) -> Micros {
        let (a, b) = (self.model(src).cfg(), self.model(dst).cfg());
        let bw = if same_node {
            a.xgmi_bw.min(b.xgmi_bw)
        } else {
            a.inter_node_bw.min(b.inter_node_bw)
        };
        self.model(src).kv_transfer_time_at_bw(tokens, bw)
    }

    /// Marginal tokens/s per watt of GPU `gi` at cap `w` in `role` —
    /// the quantity the power reallocator weighs: sinks with the
    /// steepest curve receive the most watts, sources with the
    /// flattest give up the most. Central finite difference over a
    /// ±5 W window clamped to the SKU envelope; 0 on a flat curve
    /// (above the knee, or a pinned envelope).
    pub fn marginal_tps_per_w(&self, gi: usize, role: Role, w: Watts) -> f64 {
        let lo = self.floor_w(gi);
        let hi = self.max_w(gi);
        let a = (w - 5.0).max(lo);
        let b = (w + 5.0).min(hi);
        if b - a < 1e-9 {
            return 0.0;
        }
        let m = self.model(gi);
        match role {
            Role::Prefill | Role::Coalesced => (m.prefill_rate(b) - m.prefill_rate(a)) / (b - a),
            Role::Decode => {
                // Decode throughput ∝ speedup(w) / decode_base; the
                // absolute scale only matters relative to other decode
                // GPUs, which is what the weights compare.
                let base = m.cfg().decode_base as f64;
                (m.decode_speedup(b) - m.decode_speedup(a)) / (b - a) * (1e6 / base)
            }
        }
    }

    /// MovePower sink weight: steeper marginal curve ⇒ more watts.
    pub fn sink_weight(&self, gi: usize, role: Role, w: Watts) -> f64 {
        self.marginal_tps_per_w(gi, role, w) + 1e-6
    }

    /// MovePower source weight: flatter marginal curve ⇒ cheaper donor
    /// ⇒ gives up more watts.
    pub fn source_weight(&self, gi: usize, role: Role, w: Watts) -> f64 {
        1.0 / (self.marginal_tps_per_w(gi, role, w) + 1e-6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn hetero_cfg() -> ClusterConfig {
        let mut cfg = presets::rapid_600();
        cfg.fleet = Some(
            FleetConfig::parse_mix("mi300x:2+a100:2+mi300x:2+a100:2", &[]).unwrap(),
        );
        cfg
    }

    #[test]
    fn builtin_catalog_validates() {
        for name in skus::NAMES {
            let sku = skus::by_name(name).unwrap();
            sku.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(sku.name, *name);
        }
        assert!(skus::by_name("tpu-v9").is_none());
    }

    #[test]
    fn mix_parses_and_orders_slots() {
        let fc = FleetConfig::parse_mix("mi300x:2+a100:2", &[]).unwrap();
        assert_eq!(fc.gpus_per_node(), 4);
        assert!(fc.heterogeneous());
        assert_eq!(fc.skus.len(), 2);
        assert_eq!(fc.sku_of_slot(0), 0);
        assert_eq!(fc.sku_of_slot(1), 0);
        assert_eq!(fc.sku_of_slot(2), 1);
        assert_eq!(fc.sku_of_slot(3), 1);
        assert_eq!(fc.mix_label(), "mi300x:2+a100:2");
        // Repeated runs of the same SKU share one catalog entry.
        let fc2 = FleetConfig::parse_mix("mi300x:1+a100:1+mi300x:2", &[]).unwrap();
        assert_eq!(fc2.skus.len(), 2);
        assert_eq!(fc2.gpus_per_node(), 4);
        assert_eq!(fc2.sku_of_slot(3), 0);
        assert!(!FleetConfig::parse_mix("mi300x:4", &[]).unwrap().heterogeneous());
    }

    #[test]
    fn bad_mixes_rejected() {
        assert!(FleetConfig::parse_mix("mi300x", &[]).is_err());
        assert!(FleetConfig::parse_mix("mi300x:0", &[]).is_err());
        assert!(FleetConfig::parse_mix("mi300x:-2", &[]).is_err());
        assert!(FleetConfig::parse_mix("warp9:4", &[]).is_err());
        assert!(FleetConfig::parse_mix("", &[]).is_err());
    }

    #[test]
    fn file_defined_skus_shadow_builtins() {
        let mut custom = skus::mi300x();
        custom.name = "a100".into(); // shadow the built-in
        custom.max_w = 500.0;
        let fc = FleetConfig::parse_mix("a100:4", &[custom]).unwrap();
        assert_eq!(fc.skus[0].max_w, 500.0);
    }

    #[test]
    fn catalog_hbm_capacities() {
        assert_eq!(skus::mi300x().hbm_gb, Some(192.0));
        assert_eq!(skus::h100().hbm_gb, Some(80.0));
        assert_eq!(skus::a100().hbm_gb, Some(40.0));
        // The implicit SKU is uncapped: no [mem] table can be surprised
        // by a capacity it never declared.
        let fleet = Fleet::of_config(&presets::p4d4(600.0));
        assert!(fleet.hbm_caps().iter().all(Option::is_none));
        // Hetero fleets expose per-slot capacities.
        let fleet = Fleet::of_config(&hetero_cfg());
        assert_eq!(fleet.hbm_gb(0), Some(192.0));
        assert_eq!(fleet.hbm_gb(2), Some(40.0));
        // hbm_gb must be positive when set.
        let mut bad = skus::mi300x();
        bad.hbm_gb = Some(0.0);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn implicit_fleet_is_single_default_sku() {
        let cfg = presets::p4d4(600.0);
        let fleet = Fleet::of_config(&cfg);
        assert_eq!(fleet.n_skus(), 1);
        assert!(!fleet.heterogeneous());
        for gi in 0..cfg.total_gpus() {
            assert_eq!(fleet.sku_of(gi), 0);
            assert_eq!(fleet.prefill_scale(gi), 1.0);
            assert_eq!(fleet.decode_scale(gi), 1.0);
            assert_eq!(fleet.floor_w(gi), cfg.controller.min_gpu_w);
            assert_eq!(fleet.max_w(gi), cfg.controller.max_gpu_w);
            assert_eq!(fleet.initial_cap(gi, 600.0), 600.0);
        }
    }

    #[test]
    fn hetero_fleet_maps_slots_across_nodes() {
        let mut cfg = hetero_cfg();
        cfg.n_nodes = 2;
        let fleet = Fleet::of_config(&cfg);
        assert!(fleet.heterogeneous());
        for node in 0..2 {
            let base = node * cfg.n_gpus;
            assert_eq!(fleet.sku_of(base), 0);
            assert_eq!(fleet.sku_of(base + 2), 1);
            assert_eq!(fleet.sku_of(base + 4), 0);
            assert_eq!(fleet.sku_of(base + 7), 1);
        }
        // The a100 slots clamp a 600 W cap to their 400 W envelope.
        assert_eq!(fleet.initial_cap(2, 600.0), 400.0);
        assert_eq!(fleet.initial_cap(0, 600.0), 600.0);
        // Router scales favor the stronger prefill part.
        assert!(fleet.prefill_scale(2) < fleet.prefill_scale(0));
        assert!(fleet.decode_scale(2) < fleet.decode_scale(0));
    }

    #[test]
    fn kv_transfer_uses_slower_endpoint() {
        let cfg = hetero_cfg();
        let fleet = Fleet::of_config(&cfg);
        // GPU 0 = mi300x (64 GB/s XGMI), GPU 2 = a100 (32 GB/s).
        let fast_fast = fleet.kv_transfer_time_between(0, 1, 4096, true);
        let fast_slow = fleet.kv_transfer_time_between(0, 2, 4096, true);
        let slow_fast = fleet.kv_transfer_time_between(2, 0, 4096, true);
        let slow_slow = fleet.kv_transfer_time_between(2, 3, 4096, true);
        assert!(fast_slow > fast_fast, "{fast_slow} vs {fast_fast}");
        assert_eq!(fast_slow, slow_fast, "slower endpoint wins symmetrically");
        assert_eq!(fast_slow, slow_slow, "a100 link binds either way");
        // Cross-node hops pay the slower RDMA NIC of the pair.
        let x_fast = fleet.kv_transfer_time_between(0, 5, 4096, false);
        let x_slow = fleet.kv_transfer_time_between(0, 2, 4096, false);
        assert!(x_slow > x_fast);
        // Homogeneous fleet matches the plain single-model helper.
        let homo = Fleet::of_config(&presets::p4d4(600.0));
        let m = PowerModel::new(PerfModelConfig::default());
        assert_eq!(
            homo.kv_transfer_time_between(0, 4, 4096, true),
            m.kv_transfer_time_between(4096, true)
        );
        assert_eq!(
            homo.kv_transfer_time_between(0, 4, 4096, false),
            m.kv_transfer_time_between(4096, false)
        );
    }

    #[test]
    fn marginal_weights_rank_steeper_curves_higher() {
        let cfg = hetero_cfg();
        let fleet = Fleet::of_config(&cfg);
        // mi300x prefill at 500 W is on the steep shoulder; at 740 W it
        // is nearly flat.
        let steep = fleet.marginal_tps_per_w(0, Role::Prefill, 500.0);
        let flat = fleet.marginal_tps_per_w(0, Role::Prefill, 745.0);
        assert!(steep > flat, "{steep} vs {flat}");
        assert!(steep > 0.0);
        // An a100 pinned at its 400 W max has no cap mobility upward;
        // the window clamps to [395, 400] where its curve is flat.
        let pinned = fleet.marginal_tps_per_w(2, Role::Prefill, 400.0);
        assert!(pinned < steep);
        // Sink weight follows the marginal; source weight inverts it.
        let (sink_steep, sink_flat) = (
            fleet.sink_weight(0, Role::Prefill, 500.0),
            fleet.sink_weight(0, Role::Prefill, 745.0),
        );
        assert!(sink_steep > sink_flat);
        let (src_flat, src_steep) = (
            fleet.source_weight(0, Role::Prefill, 745.0),
            fleet.source_weight(0, Role::Prefill, 500.0),
        );
        assert!(src_flat > src_steep);
        // Decode above the knee is flat: weight collapses to the epsilon.
        let d = fleet.marginal_tps_per_w(0, Role::Decode, 700.0);
        assert!(d.abs() < 1e-9);
    }
}
