//! Experiment metrics: request records → paper-figure aggregates.

use crate::types::{Micros, RequestRecord, Watts, SECOND};
use crate::util::stats::{percentile, percentile_sorted, TimeSeries};

/// Everything a run produces; each paper figure is a view over this.
#[derive(Debug, Default, Clone)]
pub struct RunResult {
    pub config_name: String,
    pub records: Vec<RequestRecord>,
    /// Cluster-total GPU power draw over time (for a single-node run this
    /// is the node's series, the paper's Fig 3 view).
    pub node_power: TimeSeries,
    /// Per-node power draw over time (multi-node runs; one entry per
    /// node, summing to `node_power`).
    pub node_power_by_node: Vec<TimeSeries>,
    /// Per-GPU cap targets over time (Fig 9a): (t, caps per gpu).
    pub cap_trace: Vec<(Micros, Vec<Watts>)>,
    /// (t, prefill_gpus, decode_gpus) role changes (Fig 9b).
    pub role_trace: Vec<(Micros, usize, usize)>,
    /// Controller decisions (Fig 9c annotations).
    pub decisions: Vec<(Micros, String)>,
    /// Virtual/wall time the run covered.
    pub duration: Micros,
    /// Mean provisioned GPU power (sum of caps averaged over time).
    pub mean_provisioned_w: Watts,
    /// Discrete events the simulation processed — the denominator of the
    /// `rapid bench` / `benches/study_throughput` events-per-second
    /// throughput metric.
    pub sim_events: u64,
    /// Environment disturbances actually applied, in time order
    /// (empty for undisturbed runs — see DESIGN.md §12).
    pub env_events: Vec<(Micros, String)>,
    /// Cluster-budget steps over time: (t, new budget). The budget
    /// before the first entry is the configured one. Populated only by
    /// disturbed runs.
    pub budget_trace: Vec<(Micros, Watts)>,
    /// Resilience aggregates around the disturbance window; `None` for
    /// undisturbed runs.
    pub resilience: Option<Resilience>,
    /// KV memory subsystem aggregates; `None` when the run had no
    /// `[mem]` table (the subsystem was structurally inactive).
    pub mem: Option<crate::mem::MemSummary>,
    /// Fleet-max HBM occupancy fraction per telemetry sample — the
    /// series the "resident KV <= HBM capacity" ShapeCheck walks.
    /// Empty when the memory subsystem is inactive.
    pub mem_trace: Vec<(Micros, f64)>,
    /// Tier lookup table: index = request tenant id (0 = untenanted),
    /// value = priority tier (see [`crate::workload::tracespec`]).
    /// Empty when the run had no `[tenant.*]` classes; per-tier
    /// aggregates in [`Summary::tenants`] exist only when non-empty.
    pub tenant_tiers: Vec<u8>,
    /// Decode preemptions per priority tier (the preempted side):
    /// `[interactive, standard, batch]`.
    pub preempted_by_tier: [u64; 3],
    /// Observability report (event log + counter registry) from a run
    /// executed with recording enabled (`SimOptions::obs_events > 0`);
    /// `None` — and structurally absent from every emitter — otherwise.
    /// See DESIGN.md §17.
    pub obs: Option<Box<crate::obs::ObsReport>>,
    /// Summary computed once when the run finishes, so study emitters
    /// and figure drivers never re-scan the record/power series.
    /// Hand-built results (tests) fall back to computing on demand.
    pub(crate) summary_cache: Option<Summary>,
}

impl RunResult {
    /// Fraction of requests meeting both SLOs (paper's "SLO attainment").
    /// Served from the sealed summary when present so repeated calls
    /// don't re-scan the record series.
    pub fn attainment(&self) -> f64 {
        if let Some(s) = self.summary_cache {
            return s.attainment;
        }
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().filter(|r| r.attained()).count() as f64
            / self.records.len() as f64
    }

    /// Attained requests per second (paper's "goodput", Fig 1).
    pub fn goodput_qps(&self) -> f64 {
        if let Some(s) = self.summary_cache {
            return s.goodput_qps;
        }
        if self.duration == 0 {
            return 0.0;
        }
        let attained = self.records.iter().filter(|r| r.attained()).count();
        attained as f64 / (self.duration as f64 / SECOND as f64)
    }

    /// Goodput per provisioned watt (the paper's QPS/W, §5.1).
    pub fn qps_per_kw(&self) -> f64 {
        if let Some(s) = self.summary_cache {
            return s.qps_per_kw;
        }
        if self.mean_provisioned_w <= 0.0 {
            return 0.0;
        }
        self.goodput_qps() / (self.mean_provisioned_w / 1000.0)
    }

    pub fn ttft_percentile(&self, p: f64) -> f64 {
        percentile(
            &self.records.iter().map(|r| r.ttft() as f64).collect::<Vec<_>>(),
            p,
        )
    }

    pub fn tpot_percentile(&self, p: f64) -> f64 {
        percentile(
            &self
                .records
                .iter()
                .filter(|r| r.output_tokens > 1)
                .map(|r| r.tpot() as f64)
                .collect::<Vec<_>>(),
            p,
        )
    }

    /// Mean queueing delay / exec time split (Fig 6).
    pub fn ttft_breakdown(&self) -> (f64, f64) {
        if self.records.is_empty() {
            return (0.0, 0.0);
        }
        let n = self.records.len() as f64;
        let q: f64 = self.records.iter().map(|r| r.queueing_delay() as f64).sum();
        let e: f64 = self.records.iter().map(|r| r.exec_time() as f64).sum();
        (q / n, e / n)
    }

    /// Flat aggregate view of this run — the per-cell payload every
    /// study emitter (text/JSON/CSV) renders. Served from the cache the
    /// simulator populates at the end of a run; computed on demand for
    /// hand-built results.
    pub fn summary(&self) -> Summary {
        if let Some(s) = self.summary_cache {
            return s;
        }
        self.compute_summary()
    }

    /// One-pass Summary computation: a single scan over the records
    /// (attainment + latency series) and one sort per latency series,
    /// instead of a scan-and-sort per accessor per emitter. Percentiles
    /// stay exact — the streaming `LatencyHistogram` is for per-tick
    /// paths, never the final Summary.
    pub(crate) fn compute_summary(&self) -> Summary {
        let n = self.records.len();
        let mut ttfts: Vec<f64> = Vec::with_capacity(n);
        let mut tpots: Vec<f64> = Vec::with_capacity(n);
        let mut attained = 0usize;
        let tiered = !self.tenant_tiers.is_empty();
        let mut tier_req = [0usize; 3];
        let mut tier_att = [0usize; 3];
        let mut tier_shed = [0usize; 3];
        for r in &self.records {
            ttfts.push(r.ttft() as f64);
            if r.output_tokens > 1 {
                tpots.push(r.tpot() as f64);
            }
            if r.attained() {
                attained += 1;
            }
            if tiered {
                let tier = self
                    .tenant_tiers
                    .get(r.tenant as usize)
                    .copied()
                    .unwrap_or(crate::workload::tracespec::TIER_STANDARD)
                    as usize;
                tier_req[tier] += 1;
                if r.attained() {
                    tier_att[tier] += 1;
                }
                if r.shed {
                    tier_shed[tier] += 1;
                }
            }
        }
        ttfts.sort_by(|a, b| a.total_cmp(b));
        tpots.sort_by(|a, b| a.total_cmp(b));
        self.assemble_summary(attained, tier_req, tier_att, tier_shed, &ttfts, &tpots)
    }

    /// [`compute_summary`] through one reused scratch buffer: both
    /// latency series live in a single allocation (TTFTs first, then
    /// the multi-token TPOTs), split and sorted in place with
    /// `sort_unstable_by(total_cmp)`. Bit-identical to the two-vector
    /// reference — `total_cmp`-equal `f64`s share a bit pattern, so an
    /// unstable sort produces the same sorted sequence and the same
    /// percentile cuts (regression-tested against `compute_summary`).
    /// This is what `seal_summary` runs once per cell at study scale.
    pub(crate) fn compute_summary_scratch(&self) -> Summary {
        let n = self.records.len();
        let mut scratch: Vec<f64> = Vec::with_capacity(2 * n);
        let mut attained = 0usize;
        let tiered = !self.tenant_tiers.is_empty();
        let mut tier_req = [0usize; 3];
        let mut tier_att = [0usize; 3];
        let mut tier_shed = [0usize; 3];
        for r in &self.records {
            scratch.push(r.ttft() as f64);
            if r.attained() {
                attained += 1;
            }
            if tiered {
                let tier = self
                    .tenant_tiers
                    .get(r.tenant as usize)
                    .copied()
                    .unwrap_or(crate::workload::tracespec::TIER_STANDARD)
                    as usize;
                tier_req[tier] += 1;
                if r.attained() {
                    tier_att[tier] += 1;
                }
                if r.shed {
                    tier_shed[tier] += 1;
                }
            }
        }
        let n_ttft = scratch.len();
        for r in &self.records {
            if r.output_tokens > 1 {
                scratch.push(r.tpot() as f64);
            }
        }
        let (ttfts, tpots) = scratch.split_at_mut(n_ttft);
        ttfts.sort_unstable_by(|a, b| a.total_cmp(b));
        tpots.sort_unstable_by(|a, b| a.total_cmp(b));
        self.assemble_summary(attained, tier_req, tier_att, tier_shed, ttfts, tpots)
    }

    /// Final assembly shared by both summary paths; `ttfts`/`tpots`
    /// must already be sorted.
    fn assemble_summary(
        &self,
        attained: usize,
        tier_req: [usize; 3],
        tier_att: [usize; 3],
        tier_shed: [usize; 3],
        ttfts: &[f64],
        tpots: &[f64],
    ) -> Summary {
        let n = self.records.len();
        let tiered = !self.tenant_tiers.is_empty();
        let attainment = if n == 0 { 0.0 } else { attained as f64 / n as f64 };
        let goodput_qps = if self.duration == 0 {
            0.0
        } else {
            attained as f64 / (self.duration as f64 / SECOND as f64)
        };
        let qps_per_kw = if self.mean_provisioned_w <= 0.0 {
            0.0
        } else {
            goodput_qps / (self.mean_provisioned_w / 1000.0)
        };
        let dur_s = self.duration as f64 / SECOND as f64;
        let tenants = if tiered {
            let mut out = [TierSummary::default(); 3];
            for (t, slot) in out.iter_mut().enumerate() {
                *slot = TierSummary {
                    requests: tier_req[t],
                    attained: tier_att[t],
                    // An empty tier attains vacuously (matches the
                    // resilience-window convention above).
                    attainment: if tier_req[t] == 0 {
                        1.0
                    } else {
                        tier_att[t] as f64 / tier_req[t] as f64
                    },
                    goodput_qps: if self.duration == 0 {
                        0.0
                    } else {
                        tier_att[t] as f64 / dur_s
                    },
                    shed: tier_shed[t],
                    preempted: self.preempted_by_tier[t],
                };
            }
            Some(out)
        } else {
            None
        };
        Summary {
            requests: n,
            attainment,
            goodput_qps,
            qps_per_kw,
            ttft_p50_ms: percentile_sorted(ttfts, 50.0) / 1000.0,
            ttft_p90_ms: percentile_sorted(ttfts, 90.0) / 1000.0,
            tpot_p50_ms: percentile_sorted(tpots, 50.0) / 1000.0,
            tpot_p90_ms: percentile_sorted(tpots, 90.0) / 1000.0,
            mean_provisioned_w: self.mean_provisioned_w,
            peak_node_w: self.node_power.max(),
            duration_s: self.duration as f64 / SECOND as f64,
            resilience: self.resilience,
            mem: self.mem,
            tenants,
        }
    }

    /// Populate the summary cache (called once by the simulator's
    /// `finish`; later `summary()` calls are free). Uses the
    /// single-scratch sort path, proven bit-identical to the reference.
    pub(crate) fn seal_summary(&mut self) {
        self.summary_cache = Some(self.compute_summary_scratch());
    }

    /// Attainment over completion-time buckets (Fig 6/9 time axes).
    pub fn attainment_over_time(&self, bucket: Micros) -> Vec<(Micros, f64)> {
        if self.records.is_empty() {
            return Vec::new();
        }
        let max_t = self.records.iter().map(|r| r.finish).max().unwrap();
        let n_buckets = (max_t / bucket + 1) as usize;
        let mut hit = vec![0u32; n_buckets];
        let mut tot = vec![0u32; n_buckets];
        for r in &self.records {
            let b = (r.finish / bucket) as usize;
            tot[b] += 1;
            if r.attained() {
                hit[b] += 1;
            }
        }
        (0..n_buckets)
            .filter(|&b| tot[b] > 0)
            .map(|b| (b as Micros * bucket, hit[b] as f64 / tot[b] as f64))
            .collect()
    }
}

/// Flat per-run aggregates (ms-scale latencies, W-scale power) shared
/// by every study emitter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub requests: usize,
    pub attainment: f64,
    pub goodput_qps: f64,
    pub qps_per_kw: f64,
    pub ttft_p50_ms: f64,
    pub ttft_p90_ms: f64,
    pub tpot_p50_ms: f64,
    pub tpot_p90_ms: f64,
    pub mean_provisioned_w: f64,
    pub peak_node_w: f64,
    pub duration_s: f64,
    /// Disturbance-recovery aggregates; `None` for undisturbed runs.
    pub resilience: Option<Resilience>,
    /// KV memory aggregates; `None` when the subsystem was inactive.
    pub mem: Option<crate::mem::MemSummary>,
    /// Per-priority-tier aggregates, indexed `[interactive, standard,
    /// batch]`; `None` when the run had no tenant classes.
    pub tenants: Option<[TierSummary; 3]>,
}

/// Aggregates for one priority tier of a multi-tenant run.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TierSummary {
    /// Requests that arrived for this tier (shed ones included —
    /// request conservation counts every arrival exactly once).
    pub requests: usize,
    /// Requests that met both SLOs.
    pub attained: usize,
    /// `attained / requests` (vacuously 1.0 for an empty tier).
    pub attainment: f64,
    /// Attained requests per second of run duration.
    pub goodput_qps: f64,
    /// Requests rejected by admission control before routing.
    pub shed: usize,
    /// Decode preemptions suffered by this tier.
    pub preempted: u64,
}

/// Goodput bucket width for the resilience aggregates (coarse enough
/// that a bucket holds tens of completions at paper-scale rates).
pub const RESILIENCE_BUCKET: Micros = 5 * SECOND;

/// How a run rode out its disturbance window (DESIGN.md §12): the
/// window spans the first to the last applied environment event.
/// Deterministic — a pure function of the request records, so it is
/// bit-identical at any sweep thread count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Resilience {
    /// Reference goodput: mean over the complete pre-disturbance
    /// buckets (whole-run mean when the disturbance hits inside the
    /// first bucket).
    pub pre_goodput_qps: f64,
    /// Worst bucket goodput while disturbed.
    pub dip_goodput_qps: f64,
    /// `1 - dip/pre`, clamped into [0, 1] (0 = no dip).
    pub dip_depth: f64,
    /// Seconds after the last disturbance until bucket goodput first
    /// returns to 95% of the reference (0 when it never dipped below
    /// that bar; infinite when it never recovers).
    pub recovery_s: f64,
    /// SLO attainment split by completion time: before the first
    /// event, inside the window, after the last event. Requests that
    /// never finished count as post-window violations.
    pub attainment_pre: f64,
    pub attainment_during: f64,
    pub attainment_post: f64,
}

/// Compute the resilience aggregates for a disturbed run whose applied
/// environment events span `[first, last]`.
pub fn compute_resilience(
    records: &[RequestRecord],
    first: Micros,
    last: Micros,
    duration: Micros,
) -> Resilience {
    let bucket = RESILIENCE_BUCKET;
    let duration = duration.max(1);
    let n_buckets = (duration / bucket + 1) as usize;
    let mut hit = vec![0u32; n_buckets];
    let mut win_hit = [0u32; 3];
    let mut win_tot = [0u32; 3];
    for r in records {
        let f = r.finish.min(duration);
        let attained = r.attained();
        if attained {
            hit[(f / bucket) as usize] += 1;
        }
        let w = if f < first {
            0
        } else if f <= last {
            1
        } else {
            2
        };
        win_tot[w] += 1;
        if attained {
            win_hit[w] += 1;
        }
    }
    let bucket_s = bucket as f64 / SECOND as f64;
    let goodput = |b: usize| hit[b] as f64 / bucket_s;
    let pre_full = (first / bucket) as usize;
    let pre = if pre_full > 0 {
        (0..pre_full).map(goodput).sum::<f64>() / pre_full as f64
    } else {
        hit.iter().map(|&h| h as f64).sum::<f64>() / (duration as f64 / SECOND as f64)
    };
    let b_first = ((first / bucket) as usize).min(n_buckets - 1);
    let b_last = ((last / bucket) as usize).min(n_buckets - 1);
    let dip = (b_first..=b_last).map(goodput).fold(f64::INFINITY, f64::min);
    let dip = if dip.is_finite() { dip } else { 0.0 };
    let dip_depth = if pre > 0.0 { ((pre - dip) / pre).clamp(0.0, 1.0) } else { 0.0 };
    let bar = 0.95 * pre;
    let recovery_s = if dip >= bar {
        0.0
    } else {
        let mut found = f64::INFINITY;
        for b in ((last / bucket) as usize + 1)..n_buckets {
            if goodput(b) >= bar {
                found = (b as Micros * bucket).saturating_sub(last) as f64 / SECOND as f64;
                break;
            }
        }
        found
    };
    let att = |w: usize| {
        if win_tot[w] == 0 {
            // An empty window attains vacuously (matches `.all()` on an
            // empty iterator; keeps the field finite and comparable).
            1.0
        } else {
            win_hit[w] as f64 / win_tot[w] as f64
        }
    };
    Resilience {
        pre_goodput_qps: pre,
        dip_goodput_qps: dip,
        dip_depth,
        recovery_s,
        attainment_pre: att(0),
        attainment_during: att(1),
        attainment_post: att(2),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{RequestId, Slo, MILLIS};

    fn record(id: u64, arrival: Micros, first: Micros, finish: Micros, out: u32) -> RequestRecord {
        RequestRecord {
            id: RequestId(id),
            arrival,
            prefill_start: arrival + 10 * MILLIS,
            first_token: first,
            finish,
            input_tokens: 1000,
            output_tokens: out,
            slo: Slo::paper_default(),
            tenant: 0,
            shed: false,
        }
    }

    fn result_with(records: Vec<RequestRecord>, duration: Micros) -> RunResult {
        RunResult {
            records,
            duration,
            mean_provisioned_w: 4800.0,
            ..Default::default()
        }
    }

    #[test]
    fn attainment_and_goodput() {
        // one attained (fast), one TTFT-violating
        let r = result_with(
            vec![
                record(0, 0, 500 * MILLIS, SECOND, 20),
                record(1, 0, 2 * SECOND, 3 * SECOND, 20),
            ],
            10 * SECOND,
        );
        assert!((r.attainment() - 0.5).abs() < 1e-9);
        assert!((r.goodput_qps() - 0.1).abs() < 1e-9);
        assert!((r.qps_per_kw() - 0.1 / 4.8).abs() < 1e-9);
    }

    #[test]
    fn percentiles_over_records() {
        let recs = (0..10)
            .map(|i| record(i, 0, (i + 1) * 100 * MILLIS, 5 * SECOND, 10))
            .collect();
        let r = result_with(recs, 10 * SECOND);
        assert!(r.ttft_percentile(50.0) > 400_000.0);
        assert!(r.ttft_percentile(90.0) <= 1_000_000.0);
    }

    #[test]
    fn breakdown_sums_to_ttft() {
        let r = result_with(vec![record(0, 0, 800 * MILLIS, SECOND, 4)], SECOND);
        let (q, e) = r.ttft_breakdown();
        assert!((q + e - 800_000.0).abs() < 1.0);
        assert!((q - 10_000.0).abs() < 1.0);
    }

    #[test]
    fn attainment_over_time_buckets() {
        let r = result_with(
            vec![
                record(0, 0, 100 * MILLIS, 700 * MILLIS, 20),    // bucket 0, attained
                record(1, 0, 5 * SECOND, 6 * SECOND, 20),        // bucket 1, violated
                record(2, 0, 100 * MILLIS, 6500 * MILLIS, 200),  // bucket 1
            ],
            10 * SECOND,
        );
        let buckets = r.attainment_over_time(5 * SECOND);
        assert_eq!(buckets.len(), 2);
        assert!((buckets[0].1 - 1.0).abs() < 1e-9);
        assert!(buckets[1].1 < 1.0);
    }

    #[test]
    fn summary_mirrors_accessors() {
        let r = result_with(
            vec![
                record(0, 0, 500 * MILLIS, SECOND, 20),
                record(1, 0, 2 * SECOND, 3 * SECOND, 20),
            ],
            10 * SECOND,
        );
        let s = r.summary();
        assert_eq!(s.requests, 2);
        assert_eq!(s.attainment, r.attainment());
        assert_eq!(s.goodput_qps, r.goodput_qps());
        assert_eq!(s.qps_per_kw, r.qps_per_kw());
        assert_eq!(s.ttft_p90_ms, r.ttft_percentile(90.0) / 1000.0);
        assert_eq!(s.mean_provisioned_w, 4800.0);
        assert_eq!(s.duration_s, 10.0);
    }

    #[test]
    fn scratch_summary_bit_identical_to_reference() {
        // The sealed path (one scratch, unstable total_cmp sorts) must
        // reproduce the two-vector stable-sort reference bit for bit —
        // including p50/p90 cuts over duplicated and adversarially
        // ordered latencies, and the per-tier aggregates.
        use crate::workload::tracespec::{TIER_BATCH, TIER_INTERACTIVE, TIER_STANDARD};
        let mut recs = Vec::new();
        let mut x = 7u64;
        for i in 0..257u64 {
            // LCG-scrambled first-token offsets with deliberate repeats.
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let first = (x % 97 + 1) * 37 * MILLIS;
            let out = if i % 5 == 0 { 1 } else { 16 + (i % 3) as u32 };
            let mut r = record(i, 0, first, first + 2 * SECOND, out);
            r.tenant = (i % 3) as u8;
            recs.push(r);
        }
        let mut res = result_with(recs, 30 * SECOND);
        res.tenant_tiers = vec![TIER_STANDARD, TIER_INTERACTIVE, TIER_BATCH];
        res.preempted_by_tier = [1, 2, 3];
        let reference = res.compute_summary();
        let scratch = res.compute_summary_scratch();
        assert_eq!(scratch, reference);
        assert_eq!(scratch.ttft_p50_ms.to_bits(), reference.ttft_p50_ms.to_bits());
        assert_eq!(scratch.ttft_p90_ms.to_bits(), reference.ttft_p90_ms.to_bits());
        assert_eq!(scratch.tpot_p50_ms.to_bits(), reference.tpot_p50_ms.to_bits());
        assert_eq!(scratch.tpot_p90_ms.to_bits(), reference.tpot_p90_ms.to_bits());
        // The empty case too (NaN percentiles compare by bits).
        let empty = RunResult::default();
        let a = empty.compute_summary();
        let b = empty.compute_summary_scratch();
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.ttft_p50_ms.to_bits(), b.ttft_p50_ms.to_bits());
    }

    #[test]
    fn sealed_summary_matches_recompute() {
        let mut r = result_with(
            vec![
                record(0, 0, 500 * MILLIS, SECOND, 20),
                record(1, 0, 2 * SECOND, 3 * SECOND, 20),
            ],
            10 * SECOND,
        );
        let fresh = r.compute_summary();
        r.seal_summary();
        assert_eq!(r.summary(), fresh);
        // Cache is a snapshot: mutating records afterwards must not
        // change what emitters render.
        r.records.pop();
        assert_eq!(r.summary(), fresh);
    }

    #[test]
    fn empty_result_is_zeroes() {
        let r = RunResult::default();
        assert_eq!(r.attainment(), 0.0);
        assert_eq!(r.goodput_qps(), 0.0);
        assert!(r.ttft_percentile(90.0).is_nan());
    }

    #[test]
    fn resilience_dip_window_and_recovery() {
        // 5 attained completions/s for t in [0, 10 s); nothing during the
        // [10 s, 20 s] disturbance window; 5/s again in [20 s, 30 s).
        let mut recs = Vec::new();
        let mut id = 0u64;
        let mut push_attained = |recs: &mut Vec<RequestRecord>, finish: Micros| {
            recs.push(record(id, finish - 700 * MILLIS, finish - 200 * MILLIS, finish, 20));
            id += 1;
        };
        for i in 0..50 {
            push_attained(&mut recs, SECOND + i * 200 * MILLIS); // 1.0 .. 10.8 s
        }
        for i in 0..50 {
            push_attained(&mut recs, 20 * SECOND + 500 * MILLIS + i * 200 * MILLIS);
        }
        // Keep the pre window clean: drop the few that spilled past 10 s.
        recs.retain(|r| r.finish < 10 * SECOND || r.finish >= 20 * SECOND);
        let r = compute_resilience(&recs, 10 * SECOND, 20 * SECOND, 30 * SECOND);
        assert!((r.pre_goodput_qps - 4.5).abs() < 0.6, "pre={}", r.pre_goodput_qps);
        assert_eq!(r.dip_goodput_qps, 0.0);
        assert_eq!(r.dip_depth, 1.0);
        assert_eq!(r.recovery_s, 5.0, "first full bucket after the window recovers");
        assert_eq!(r.attainment_pre, 1.0);
        assert_eq!(r.attainment_during, 1.0, "empty window attains vacuously");
        assert_eq!(r.attainment_post, 1.0);
        // A violating completion inside the window splits attainment.
        recs.push(record(999, 10 * SECOND, 14 * SECOND, 15 * SECOND, 20));
        let r2 = compute_resilience(&recs, 10 * SECOND, 20 * SECOND, 30 * SECOND);
        assert_eq!(r2.attainment_during, 0.0);
        assert_eq!(r2.attainment_pre, 1.0);
        // No dip at all -> depth 0, recovery 0.
        let flat: Vec<RequestRecord> = (0..150u64)
            .map(|i| {
                let f = SECOND + i * 200 * MILLIS;
                record(i, f - 700 * MILLIS, f - 200 * MILLIS, f, 20)
            })
            .collect();
        let r3 = compute_resilience(&flat, 10 * SECOND, 20 * SECOND, 31 * SECOND);
        assert!(r3.dip_depth < 0.2, "steady goodput has no meaningful dip");
        assert_eq!(r3.recovery_s, 0.0);
    }

    #[test]
    fn per_tier_summary_splits_by_tenant() {
        use crate::workload::tracespec::{TIER_BATCH, TIER_INTERACTIVE, TIER_STANDARD};
        let mut r = result_with(
            vec![
                record(0, 0, 500 * MILLIS, SECOND, 20),   // attained
                record(1, 0, 2 * SECOND, 3 * SECOND, 20), // TTFT-violating
            ],
            10 * SECOND,
        );
        r.records[0].tenant = 1;
        r.records[1].tenant = 2;
        let mut shed = record(2, 0, 3600 * SECOND, 7200 * SECOND, 20);
        shed.tenant = 2;
        shed.shed = true;
        r.records.push(shed);
        assert!(r.summary().tenants.is_none(), "no tier table -> no tier view");
        // tenant 0 (untenanted) standard, tenant 1 interactive, tenant 2 batch
        r.tenant_tiers = vec![TIER_STANDARD, TIER_INTERACTIVE, TIER_BATCH];
        r.preempted_by_tier = [0, 0, 3];
        let tiers = r.compute_summary().tenants.unwrap();
        let it = tiers[TIER_INTERACTIVE as usize];
        assert_eq!((it.requests, it.attained, it.shed), (1, 1, 0));
        assert_eq!(it.attainment, 1.0);
        assert!((it.goodput_qps - 0.1).abs() < 1e-9);
        let batch = tiers[TIER_BATCH as usize];
        assert_eq!((batch.requests, batch.attained, batch.shed), (2, 0, 1));
        assert_eq!(batch.attainment, 0.0);
        assert_eq!(batch.preempted, 3);
        let std_tier = tiers[TIER_STANDARD as usize];
        assert_eq!(std_tier.requests, 0);
        assert_eq!(std_tier.attainment, 1.0, "empty tier attains vacuously");
        // Conservation: tier requests sum to the record count.
        let total: usize = tiers.iter().map(|t| t.requests).sum();
        assert_eq!(total, r.records.len());
    }

    #[test]
    fn tpot_percentile_skips_single_token() {
        let mut recs = vec![record(0, 0, SECOND, SECOND, 1)]; // excluded
        recs.push(record(1, 0, SECOND, 2 * SECOND, 21)); // 50ms tpot
        let r = result_with(recs, 10 * SECOND);
        assert!((r.tpot_percentile(50.0) - 50_000.0).abs() < 1.0);
    }
}
