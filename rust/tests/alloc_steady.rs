//! Steady-state allocation discipline for the DES hot loop.
//!
//! Only compiled with `--features alloc-count`, which swaps in the
//! counting `#[global_allocator]` (util::alloc_count). The contract
//! under test: once a run is warmed — slab at its resident population,
//! calendar buckets grown, metric windows full, scratch buffers sized —
//! stepping the event loop performs ZERO heap allocations. Every
//! container the per-event path touches is pre-sized at construction
//! (see `Cluster::new`, `Slab::with_capacity`, `GpuSim::new`,
//! `SlidingWindow::new`) or reused via take/restore scratch, so a
//! regression here means someone put an allocating call back on the
//! hot path.
#![cfg(feature = "alloc-count")]

use std::sync::Arc;

use rapid::cluster::Cluster;
use rapid::config::presets;
use rapid::scenario::longbench_trace;
use rapid::sim::SimOptions;
use rapid::types::{Slo, SECOND};
use rapid::util::alloc_count::allocation_count;

#[test]
fn warmed_des_window_is_allocation_free() {
    let cfg = presets::rapid_600();
    // Comfortable stationary load: no SLO violations in steady state, so
    // the dynamic controller observes but never acts (an action would
    // legitimately allocate for its decision-log entry).
    let trace = longbench_trace(42, 1.0 * cfg.total_gpus() as f64, 2000, Slo::paper_default());
    let opts = SimOptions {
        // Telemetry samples legitimately append to the power/cap series;
        // push the next sample past the horizon so the measured window
        // contains only arrival/step/tick traffic.
        sample_period: 3600 * SECOND,
        ..SimOptions::default()
    };
    let mut cl = Cluster::new(cfg, Arc::new(trace), opts);
    cl.prime();
    // Warmup: grows every container to its steady level, including any
    // that overshoot their initial pre-size (e.g. a metric window on a
    // busy tick cadence). Capacity is never given back, so what the
    // warmup grew stays grown.
    let warmed = cl.step_events(6_000);
    assert_eq!(warmed, 6_000, "trace too short: warmup ran off the end");

    let before = allocation_count();
    let stepped = cl.step_events(1_000);
    let delta = allocation_count() - before;
    assert_eq!(stepped, 1_000, "trace too short: window ran off the end");
    assert_eq!(
        delta, 0,
        "steady-state DES window performed {delta} heap allocations"
    );
}

#[test]
fn warmed_traced_window_is_allocation_free() {
    // Same harness with the observability sink ENABLED, at a capacity
    // small enough that the 6k-event warmup wraps the ring: the window
    // then exercises the overwrite path, which must be a store plus an
    // index bump. Every event payload is POD and the ring Vec is
    // pre-reserved at construction, so recording never allocates —
    // whether appending below capacity or overwriting past it.
    let cfg = presets::rapid_600();
    let trace = longbench_trace(42, 1.0 * cfg.total_gpus() as f64, 2000, Slo::paper_default());
    let opts = SimOptions {
        sample_period: 3600 * SECOND,
        obs_events: 4096,
        ..SimOptions::default()
    };
    let mut cl = Cluster::new(cfg, Arc::new(trace), opts);
    cl.prime();
    let warmed = cl.step_events(6_000);
    assert_eq!(warmed, 6_000, "trace too short: warmup ran off the end");

    let before = allocation_count();
    let stepped = cl.step_events(1_000);
    let delta = allocation_count() - before;
    assert_eq!(stepped, 1_000, "trace too short: window ran off the end");
    assert_eq!(
        delta, 0,
        "traced steady-state window performed {delta} heap allocations"
    );
}
