//! The shipped example configs must parse and validate.

use rapid::config::ClusterConfig;

#[test]
fn shipped_configs_parse_and_validate() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/configs");
    let mut count = 0;
    for entry in std::fs::read_dir(dir).expect("configs/ present") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let cfg = ClusterConfig::from_toml(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        count += 1;
    }
    assert!(count >= 3, "expected the shipped example configs");
}

#[test]
fn custom_topology_config_resolves() {
    let text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/configs/custom-6p2d.toml"
    ))
    .unwrap();
    let cfg = ClusterConfig::from_toml(&text).unwrap();
    assert_eq!(cfg.name, "6P-550W/2D-750W");
    assert_eq!(cfg.prefill_gpus(), 6);
    assert_eq!(cfg.total_initial_caps(), 6.0 * 550.0 + 2.0 * 750.0);
    assert!(cfg.total_initial_caps() <= cfg.node_budget_w);
}
