//! Node power manager: budget enforcement + source-before-sink shifting.
//!
//! Owns every GPU's `CapState` and guarantees the paper's §2.2 safety
//! protocol: total *allowed* GPU power never exceeds the node budget, and
//! when power moves between pools the source caps are lowered and given
//! time to settle before the sink caps rise. Raises are queued as pending
//! operations released by `poll(now)`.

use crate::power::capper::{CapState, RampProfile};
use crate::types::{GpuId, Micros, Watts};

#[derive(Debug, thiserror::Error)]
pub enum PowerError {
    #[error("cap change would exceed node budget: {total:.0} W > {budget:.0} W")]
    BudgetExceeded { total: Watts, budget: Watts },
    #[error("cap {cap:.0} W outside limits [{min:.0}, {max:.0}]")]
    OutOfLimits { cap: Watts, min: Watts, max: Watts },
    #[error("no gpus in {0} pool")]
    EmptyPool(&'static str),
}

/// A deferred cap raise, released once the paired lowers have settled.
#[derive(Debug, Clone)]
struct PendingRaise {
    gpu: GpuId,
    cap: Watts,
    at: Micros,
}

/// Outcome of a `move_power` call (for logging / Fig 9 traces).
#[derive(Debug, Clone, PartialEq)]
pub struct PowerMove {
    pub lowered: Vec<(GpuId, Watts)>,
    pub raised: Vec<(GpuId, Watts)>,
    /// When the raises take effect (sources settled).
    pub effective_at: Micros,
}

#[derive(Debug)]
pub struct PowerManager {
    caps: Vec<CapState>,
    pending: Vec<PendingRaise>,
    profile: RampProfile,
    budget: Watts,
    enforce: bool,
    min_w: Watts,
    max_w: Watts,
}

impl PowerManager {
    pub fn new(
        initial_caps: &[Watts],
        budget: Watts,
        enforce: bool,
        min_w: Watts,
        max_w: Watts,
    ) -> Self {
        PowerManager {
            caps: initial_caps.iter().map(|&w| CapState::new(w)).collect(),
            pending: Vec::new(),
            profile: RampProfile::default(),
            budget,
            enforce,
            min_w,
            max_w,
        }
    }

    pub fn n_gpus(&self) -> usize {
        self.caps.len()
    }

    pub fn budget(&self) -> Watts {
        self.budget
    }

    pub fn profile(&self) -> &RampProfile {
        &self.profile
    }

    /// Target cap of one GPU (what was last requested).
    pub fn target(&self, gpu: GpuId) -> Watts {
        self.caps[gpu.0].target()
    }

    /// Effective (firmware-enforced) cap right now, mid-transient.
    pub fn effective(&self, gpu: GpuId, now: Micros) -> Watts {
        self.caps[gpu.0].effective(now)
    }

    /// Sum of target caps plus any pending raises (the committed power).
    pub fn committed_total(&self) -> Watts {
        let mut per_gpu: Vec<Watts> = self.caps.iter().map(|c| c.target()).collect();
        for p in &self.pending {
            per_gpu[p.gpu.0] = per_gpu[p.gpu.0].max(p.cap);
        }
        per_gpu.iter().sum()
    }

    fn check_limits(&self, cap: Watts) -> Result<(), PowerError> {
        if cap < self.min_w - 1e-9 || cap > self.max_w + 1e-9 {
            return Err(PowerError::OutOfLimits {
                cap,
                min: self.min_w,
                max: self.max_w,
            });
        }
        Ok(())
    }

    /// Immediately retarget one GPU's cap (budget-checked).
    pub fn set_cap(&mut self, now: Micros, gpu: GpuId, cap: Watts) -> Result<Micros, PowerError> {
        self.check_limits(cap)?;
        if self.enforce {
            let delta = cap - self.caps[gpu.0].target();
            let total = self.committed_total() + delta.max(0.0);
            if delta > 0.0 && total > self.budget + 1e-6 {
                return Err(PowerError::BudgetExceeded {
                    total,
                    budget: self.budget,
                });
            }
        }
        Ok(self.caps[gpu.0].set_target(now, cap, &self.profile))
    }

    /// Move `total_w` watts from `sources` to `sinks` (split evenly inside
    /// each pool, clamped to limits). Sources lower now; sinks raise after
    /// every source's settle deadline. Returns what actually moved — the
    /// clamps can reduce it (the controller's POWERLIMITSREACHED signal).
    pub fn move_power(
        &mut self,
        now: Micros,
        sources: &[GpuId],
        sinks: &[GpuId],
        total_w: Watts,
        sink_ceiling: Watts,
    ) -> Result<PowerMove, PowerError> {
        if sources.is_empty() {
            return Err(PowerError::EmptyPool("source"));
        }
        if sinks.is_empty() {
            return Err(PowerError::EmptyPool("sink"));
        }
        // A pending raise on a source would land *after* we lower it and
        // overshoot the budget: cancel source-side pending raises first.
        self.pending.retain(|p| !sources.contains(&p.gpu));
        // Sink room must account for raises already committed to them.
        let committed_cap = |mgr: &Self, g: GpuId| {
            let mut c = mgr.caps[g.0].target();
            for p in &mgr.pending {
                if p.gpu == g {
                    c = c.max(p.cap);
                }
            }
            c
        };
        // How much can each side actually absorb?
        let per_source = total_w / sources.len() as f64;
        let mut takeable = 0.0;
        let mut lowers: Vec<(GpuId, Watts)> = Vec::new();
        for &g in sources {
            let cur = self.caps[g.0].target();
            let new = (cur - per_source).max(self.min_w);
            takeable += cur - new;
            lowers.push((g, new));
        }
        let ceiling = sink_ceiling.min(self.max_w);
        let mut givable = 0.0;
        for &g in sinks {
            givable += (ceiling - committed_cap(self, g)).max(0.0);
        }
        let moved = takeable.min(givable);
        if moved < 1.0 {
            // Nothing meaningful can move; report zero-move.
            return Ok(PowerMove {
                lowered: Vec::new(),
                raised: Vec::new(),
                effective_at: now,
            });
        }
        // Scale the lowers down if sinks can't absorb everything.
        let scale = moved / takeable;
        let mut settle_deadline = now;
        let mut lowered = Vec::new();
        for (g, _) in &mut lowers {
            let cur = self.caps[g.0].target();
            let reduce = (cur - ((cur - per_source).max(self.min_w))) * scale;
            let new = cur - reduce;
            let d = self.caps[g.0].set_target(now, new, &self.profile);
            settle_deadline = settle_deadline.max(d);
            lowered.push((*g, new));
        }
        // Queue the raises for after the sources settle.
        let per_sink_room: Vec<Watts> = sinks
            .iter()
            .map(|&g| (ceiling - committed_cap(self, g)).max(0.0))
            .collect();
        let room_total: f64 = per_sink_room.iter().sum();
        let mut raised = Vec::new();
        for (&g, &room) in sinks.iter().zip(&per_sink_room) {
            if room <= 0.0 {
                continue;
            }
            let share = moved * room / room_total;
            let cap = committed_cap(self, g) + share;
            self.pending.push(PendingRaise {
                gpu: g,
                cap,
                at: settle_deadline,
            });
            raised.push((g, cap));
        }
        Ok(PowerMove {
            lowered,
            raised,
            effective_at: settle_deadline,
        })
    }

    /// Set every GPU to `budget / n` (paper: DISTRIBUTEUNIFORMPOWER after a
    /// role move). Lower-first/raise-later sequencing applies here too.
    pub fn distribute_uniform(&mut self, now: Micros) -> Micros {
        let uniform = (self.budget / self.caps.len() as f64).clamp(self.min_w, self.max_w);
        self.pending.clear();
        let mut settle = now;
        // Phase 1: all lowers immediately.
        for i in 0..self.caps.len() {
            if self.caps[i].target() > uniform {
                let d = self.caps[i].set_target(now, uniform, &self.profile);
                settle = settle.max(d);
            }
        }
        // Phase 2: raises queued after the lowers settle.
        for i in 0..self.caps.len() {
            if self.caps[i].target() < uniform {
                self.pending.push(PendingRaise {
                    gpu: GpuId(i),
                    cap: uniform,
                    at: settle,
                });
            }
        }
        settle
    }

    /// Apply any pending raises that are due; returns them for logging.
    pub fn poll(&mut self, now: Micros) -> Vec<(GpuId, Watts)> {
        let mut applied = Vec::new();
        let mut remaining = Vec::new();
        let pending = std::mem::take(&mut self.pending);
        for p in pending {
            if p.at <= now {
                // Raise within limits; budget holds by construction.
                let cap = p.cap.clamp(self.min_w, self.max_w);
                self.caps[p.gpu.0].set_target(now, cap, &self.profile);
                applied.push((p.gpu, cap));
            } else {
                remaining.push(p);
            }
        }
        self.pending = remaining;
        applied
    }

    /// Earliest pending-raise deadline (so the DES can schedule a poll).
    pub fn next_pending_at(&self) -> Option<Micros> {
        self.pending.iter().map(|p| p.at).min()
    }

    /// Budget invariant on committed power (property-tested).
    pub fn budget_ok(&self) -> bool {
        !self.enforce || self.committed_total() <= self.budget + 1e-6
    }

    /// All target caps (Fig 9a trace).
    pub fn targets(&self) -> Vec<Watts> {
        self.caps.iter().map(|c| c.target()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SECOND;

    fn manager_4p4d() -> PowerManager {
        PowerManager::new(&[600.0; 8], 4800.0, true, 400.0, 750.0)
    }

    #[test]
    fn set_cap_respects_budget() {
        let mut m = manager_4p4d();
        // Raising one GPU to 750 would commit 4950 W.
        let err = m.set_cap(0, GpuId(0), 750.0).unwrap_err();
        assert!(matches!(err, PowerError::BudgetExceeded { .. }));
        // Lowering is always fine.
        m.set_cap(0, GpuId(0), 450.0).unwrap();
        // Now there's headroom for a raise elsewhere.
        m.set_cap(1 * SECOND, GpuId(1), 750.0).unwrap();
        assert!(m.budget_ok());
    }

    #[test]
    fn set_cap_respects_limits() {
        let mut m = manager_4p4d();
        assert!(m.set_cap(0, GpuId(0), 300.0).is_err());
        assert!(m.set_cap(0, GpuId(0), 800.0).is_err());
    }

    #[test]
    fn move_power_sequences_source_before_sink() {
        let mut m = manager_4p4d();
        let sources: Vec<GpuId> = (4..8).map(GpuId).collect();
        let sinks: Vec<GpuId> = (0..4).map(GpuId).collect();
        let mv = m
            .move_power(0, &sources, &sinks, 200.0, 750.0)
            .unwrap();
        assert_eq!(mv.lowered.len(), 4);
        assert!(mv.effective_at > 0, "raises must wait for settle");
        // Sinks unchanged until poll after effective_at.
        assert_eq!(m.target(GpuId(0)), 600.0);
        assert!(m.poll(mv.effective_at - 1).is_empty());
        let applied = m.poll(mv.effective_at);
        assert_eq!(applied.len(), 4);
        assert!((m.target(GpuId(0)) - 650.0).abs() < 1e-6);
        assert!((m.target(GpuId(4)) - 550.0).abs() < 1e-6);
        assert!(m.budget_ok());
    }

    #[test]
    fn move_power_clamps_at_min() {
        let mut m = PowerManager::new(&[420.0, 420.0, 600.0, 600.0], 4800.0, true, 400.0, 750.0);
        let mv = m
            .move_power(0, &[GpuId(0), GpuId(1)], &[GpuId(2), GpuId(3)], 200.0, 750.0)
            .unwrap();
        // Each source can only give 20 W.
        let total_lowered: f64 = mv
            .lowered
            .iter()
            .map(|&(g, new)| 420.0 - new.max(400.0) + (g.0 as f64) * 0.0)
            .sum();
        assert!(total_lowered <= 40.0 + 1e-6, "lowered {total_lowered}");
        m.poll(mv.effective_at);
        assert!(m.budget_ok());
        for i in 0..2 {
            assert!(m.target(GpuId(i)) >= 400.0 - 1e-9);
        }
    }

    #[test]
    fn move_power_respects_sink_ceiling() {
        let mut m = manager_4p4d();
        let mv = m
            .move_power(0, &[GpuId(4)], &[GpuId(0)], 200.0, 650.0)
            .unwrap();
        m.poll(mv.effective_at);
        assert!(m.target(GpuId(0)) <= 650.0 + 1e-9);
    }

    #[test]
    fn move_power_zero_when_sinks_full() {
        let mut m = PowerManager::new(&[750.0, 400.0], 1150.0, true, 400.0, 750.0);
        let mv = m
            .move_power(0, &[GpuId(1)], &[GpuId(0)], 100.0, 750.0)
            .unwrap();
        assert!(mv.raised.is_empty(), "sink already at max: {mv:?}");
        // Source untouched by a zero-move.
        assert_eq!(m.target(GpuId(1)), 400.0);
    }

    #[test]
    fn distribute_uniform_converges_to_budget_share() {
        let mut m = PowerManager::new(
            &[750.0, 750.0, 750.0, 750.0, 450.0, 450.0, 450.0, 450.0],
            4800.0,
            true,
            400.0,
            750.0,
        );
        let settle = m.distribute_uniform(0);
        m.poll(settle);
        for i in 0..8 {
            assert!((m.target(GpuId(i)) - 600.0).abs() < 1e-6);
        }
        assert!(m.budget_ok());
    }

    #[test]
    fn committed_total_counts_pending() {
        let mut m = manager_4p4d();
        let mv = m
            .move_power(0, &[GpuId(4)], &[GpuId(0)], 100.0, 750.0)
            .unwrap();
        // Before the raise lands, committed must already include it so a
        // concurrent set_cap cannot double-spend the headroom.
        assert!(m.committed_total() >= 4800.0 - 1e-6);
        let err = m.set_cap(1, GpuId(1), 700.0);
        assert!(err.is_err(), "double-spend must be rejected");
        m.poll(mv.effective_at);
        assert!(m.budget_ok());
    }

    #[test]
    fn unenforced_budget_allows_oversubscription() {
        let mut m = PowerManager::new(&[750.0; 8], 4800.0, false, 400.0, 750.0);
        // 6000 W committed but enforce=false (Fig 3's uncapped run).
        assert!(m.committed_total() > m.budget());
        assert!(m.budget_ok());
        m.set_cap(0, GpuId(0), 750.0).unwrap();
    }

    #[test]
    fn next_pending_at_reports_earliest() {
        let mut m = manager_4p4d();
        assert!(m.next_pending_at().is_none());
        let mv = m
            .move_power(0, &[GpuId(4)], &[GpuId(0)], 50.0, 750.0)
            .unwrap();
        assert_eq!(m.next_pending_at(), Some(mv.effective_at));
    }
}
