//! Deterministic PRNG + distributions (offline substitute for `rand`).
//!
//! xoshiro256++ (Blackman & Vigna) with a splitmix64 seeder, plus the
//! distributions the workload generators need: uniform, exponential
//! (Poisson inter-arrivals), Poisson counts, log-normal and Zipf (prompt
//! length shapes). All experiments take explicit seeds so every paper
//! figure regenerates bit-identically.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-GPU / per-source rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        // Lemire-style rejection-free mapping is overkill here; modulo bias
        // is negligible for the ranges we use (< 2^32).
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.range_u64(0, n as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with the given rate (mean 1/rate). Inter-arrival times
    /// of a Poisson process — the paper's arrival model (§4).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Poisson-distributed count (Knuth for small lambda, normal approx
    /// above 30 — counts are only used for burst sizing).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = lambda + lambda.sqrt() * self.normal();
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }

    /// Standard normal (Box-Muller; one value per call, simple over fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given log-space mean/sigma (LongBench prompt
    /// lengths are long-tailed; see workload/longbench.rs).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf rank sampler over [1, n] with exponent `s` — inverse transform
    /// over the finite support (n is small in our use: length buckets).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        let total: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let target = self.f64() * total;
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            if acc >= target {
                return k;
            }
        }
        n
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(11);
        let rate = 4.0;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_and_variance() {
        let mut r = Rng::new(13);
        for &lambda in &[0.5, 3.0, 50.0] {
            let n = 100_000;
            let xs: Vec<f64> = (0..n).map(|_| r.poisson(lambda) as f64).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
            assert!((mean - lambda).abs() < lambda * 0.05 + 0.05, "mean={mean} vs {lambda}");
            assert!((var - lambda).abs() < lambda * 0.15 + 0.15, "var={var} vs {lambda}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn lognormal_positive_and_long_tailed() {
        let mut r = Rng::new(19);
        let xs: Vec<f64> = (0..50_000).map(|_| r.lognormal(7.0, 1.0)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let median = {
            let mut s = xs.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s[s.len() / 2]
        };
        assert!(mean > median, "long tail: mean {mean} > median {median}");
    }

    #[test]
    fn zipf_rank_one_most_likely() {
        let mut r = Rng::new(23);
        let mut counts = [0u64; 9];
        for _ in 0..20_000 {
            let k = r.zipf(8, 1.2);
            assert!((1..=8).contains(&k));
            counts[k as usize] += 1;
        }
        assert!(counts[1] > counts[2] && counts[2] > counts[4]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
