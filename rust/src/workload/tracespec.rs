//! Trace-replay workloads and tenant classes (DESIGN.md §15).
//!
//! The paper evaluates on Poisson/bursty arrivals with fixed ISL/OSL
//! mixes; production traffic is trace-shaped — diurnal rate curves,
//! flash crowds, heavy-tailed length distributions. [`TraceSpec`] is a
//! deterministic replica of that shape:
//!
//! * a **piecewise-constant diurnal curve** ([`RateSegment`]s, cycled)
//!   that scales the cell's base rate over simulated time;
//! * an optional **flash crowd** window ([`FlashCrowd`]): between
//!   `start_s` and `start_s + dur_s` the instantaneous rate is further
//!   multiplied by `mult`;
//! * **empirical ISL/OSL distributions** ([`LenBucket`] tables sampled
//!   seed-stably: pick a bucket by weight, then uniform inside it).
//!
//! Arrivals are an *exact* piecewise-constant-rate Poisson process: by
//! memorylessness, a draw that crosses a rate boundary is discarded and
//! redrawn from the boundary, so segment rates are honored without
//! thinning bias. Two presets ship — `mt-4400x1200` (multi-tenant
//! production mix, mean 4400/1200 ISL/OSL, ±40 % diurnal swing) and
//! `synth-8192x256` (flat-rate synthetic prefill-heavy stress) — and
//! load from TOML (`[workload.trace]`) or the compact `trace` scenario
//! axis atom `<preset>[:flash:<start_s>:<dur_s>:<mult>]` | `none`:
//!
//! ```
//! use rapid::workload::tracespec::TraceSpec;
//! let ts = TraceSpec::parse_compact("mt-4400x1200:flash:120:60:3").unwrap().unwrap();
//! assert_eq!(ts.flash.unwrap().mult, 3.0);
//! assert!(TraceSpec::parse_compact("none").unwrap().is_none());
//! assert!(TraceSpec::parse_compact("warp:9").is_err());
//! ```
//!
//! [`TenantClass`] models multi-tenant SLO tiers: each class has an
//! arrival share, a priority tier (interactive/standard/batch) and an
//! SLO scale (TTFT/TPOT multipliers on the scenario SLO). Requests are
//! tagged post-build by [`assign_tenants`] from an independent RNG
//! stream (`fork(3)`), so untenanted traces are bit-identical to the
//! pre-tenant builders. Shares must sum to 1:
//!
//! ```
//! use rapid::workload::tracespec::TenantClass;
//! let ts = TenantClass::parse_compact("prime:0.5:interactive+bulk:0.5:batch:2").unwrap();
//! assert_eq!(ts.len(), 2);
//! assert_eq!(ts[1].slo_scale, 2.0);
//! assert!(TenantClass::parse_compact("a:0.9:interactive").is_err()); // shares != 1
//! assert!(TenantClass::parse_compact("none").unwrap().is_empty());
//! ```

use crate::types::{Micros, Request, RequestId, Slo};
use crate::util::rng::Rng;
use crate::workload::Trace;

/// Priority tiers, ordered: lower value = higher priority.
pub const TIER_INTERACTIVE: u8 = 0;
pub const TIER_STANDARD: u8 = 1;
pub const TIER_BATCH: u8 = 2;
/// Number of priority tiers.
pub const N_TIERS: usize = 3;

/// Human name of a tier index.
pub fn tier_name(tier: u8) -> &'static str {
    match tier {
        TIER_INTERACTIVE => "interactive",
        TIER_STANDARD => "standard",
        _ => "batch",
    }
}

/// Parse a tier name (`interactive` | `standard` | `batch`).
pub fn parse_tier(s: &str) -> Result<u8, String> {
    match s {
        "interactive" => Ok(TIER_INTERACTIVE),
        "standard" => Ok(TIER_STANDARD),
        "batch" => Ok(TIER_BATCH),
        other => Err(format!(
            "unknown tier '{other}' (interactive | standard | batch)"
        )),
    }
}

// ---------------------------------------------------------------------------
// TraceSpec
// ---------------------------------------------------------------------------

/// One piecewise-constant segment of the diurnal rate curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RateSegment {
    /// Segment length in seconds.
    pub dur_s: f64,
    /// Multiplier on the base rate while the segment is active.
    pub scale: f64,
}

/// One empirical length bucket: `weight` probability mass, lengths
/// uniform in `[lo, hi]` (inclusive).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LenBucket {
    pub weight: f64,
    pub lo: u32,
    pub hi: u32,
}

/// A flash-crowd window: rate multiplied by `mult` while
/// `start_s <= t < start_s + dur_s`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlashCrowd {
    pub start_s: f64,
    pub dur_s: f64,
    pub mult: f64,
}

/// Deterministic trace-replay spec: diurnal curve + optional flash
/// crowd + empirical ISL/OSL bucket tables (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    /// Preset name (label in axis coords and config names).
    pub preset: &'static str,
    /// Cycled diurnal rate-scale segments; must be non-empty.
    pub diurnal: Vec<RateSegment>,
    /// Input-length (ISL) buckets; weights need not be normalized.
    pub isl: Vec<LenBucket>,
    /// Output-length (OSL) buckets.
    pub osl: Vec<LenBucket>,
    pub flash: Option<FlashCrowd>,
}

/// Names accepted by [`TraceSpec::preset`].
pub const PRESETS: &[&str] = &["mt-4400x1200", "synth-8192x256"];

impl TraceSpec {
    /// A shipped preset by name (`mt-4400x1200` | `synth-8192x256`).
    pub fn preset(name: &str) -> Result<TraceSpec, String> {
        match name {
            // Multi-tenant production mix: mean ISL ~4400, mean OSL
            // ~1200, a 6-minute "day" with a ±40% swing around the base
            // rate (mean scale exactly 1.0 so the long-run rate matches
            // the cell's base rate).
            "mt-4400x1200" => Ok(TraceSpec {
                preset: "mt-4400x1200",
                diurnal: vec![
                    RateSegment { dur_s: 90.0, scale: 0.6 },
                    RateSegment { dur_s: 90.0, scale: 1.0 },
                    RateSegment { dur_s: 90.0, scale: 1.4 },
                    RateSegment { dur_s: 90.0, scale: 1.0 },
                ],
                isl: vec![
                    LenBucket { weight: 0.25, lo: 256, hi: 2048 },
                    LenBucket { weight: 0.45, lo: 2048, hi: 6144 },
                    LenBucket { weight: 0.30, lo: 6144, hi: 9000 },
                ],
                osl: vec![
                    LenBucket { weight: 0.35, lo: 64, hi: 512 },
                    LenBucket { weight: 0.40, lo: 512, hi: 2048 },
                    LenBucket { weight: 0.25, lo: 2048, hi: 2650 },
                ],
                flash: None,
            }),
            // Flat-rate synthetic prefill-heavy stress: ~8K prompts,
            // short outputs, no diurnal modulation.
            "synth-8192x256" => Ok(TraceSpec {
                preset: "synth-8192x256",
                diurnal: vec![RateSegment { dur_s: 60.0, scale: 1.0 }],
                isl: vec![
                    LenBucket { weight: 0.7, lo: 8192, hi: 8192 },
                    LenBucket { weight: 0.3, lo: 7168, hi: 9216 },
                ],
                osl: vec![LenBucket { weight: 1.0, lo: 128, hi: 384 }],
                flash: None,
            }),
            other => Err(format!(
                "unknown trace preset '{other}' ({})",
                PRESETS.join(" | ")
            )),
        }
    }

    /// Parse the compact scenario-axis atom:
    /// `none` | `<preset>` | `<preset>:flash:<start_s>:<dur_s>:<mult>`.
    /// `Ok(None)` is the inert comparison cell.
    pub fn parse_compact(atom: &str) -> Result<Option<TraceSpec>, String> {
        if atom == "none" {
            return Ok(None);
        }
        let mut parts = atom.splitn(2, ':');
        let name = parts.next().unwrap_or("");
        let mut spec = TraceSpec::preset(name)?;
        if let Some(rest) = parts.next() {
            let fields: Vec<&str> = rest.split(':').collect();
            if fields.len() != 4 || fields[0] != "flash" {
                return Err(format!(
                    "bad trace atom '{atom}' \
                     (expect <preset>[:flash:<start_s>:<dur_s>:<mult>])"
                ));
            }
            let num = |s: &str, what: &str| -> Result<f64, String> {
                s.parse::<f64>()
                    .map_err(|_| format!("trace atom '{atom}': bad {what} '{s}'"))
            };
            let flash = FlashCrowd {
                start_s: num(fields[1], "flash start_s")?,
                dur_s: num(fields[2], "flash dur_s")?,
                mult: num(fields[3], "flash mult")?,
            };
            spec = spec.with_flash(flash)?;
        }
        Ok(Some(spec))
    }

    /// Attach a validated flash-crowd window.
    pub fn with_flash(mut self, flash: FlashCrowd) -> Result<TraceSpec, String> {
        if flash.start_s < 0.0 || flash.dur_s <= 0.0 {
            return Err(format!(
                "flash window start_s {} / dur_s {} must be >= 0 / > 0",
                flash.start_s, flash.dur_s
            ));
        }
        if flash.mult <= 1.0 {
            return Err(format!("flash mult {} must be > 1", flash.mult));
        }
        self.flash = Some(flash);
        Ok(self)
    }

    /// The atom this spec round-trips to (axis labels, config names).
    pub fn label(&self) -> String {
        match self.flash {
            None => self.preset.to_string(),
            Some(f) => format!(
                "{}:flash:{}:{}:{}",
                self.preset, f.start_s, f.dur_s, f.mult
            ),
        }
    }

    fn cycle_s(&self) -> f64 {
        self.diurnal.iter().map(|s| s.dur_s).sum()
    }

    /// Instantaneous rate multiplier at simulated time `t_s` (diurnal
    /// scale × flash multiplier).
    pub fn scale_at(&self, t_s: f64) -> f64 {
        let cycle = self.cycle_s();
        let mut pos = t_s % cycle;
        let mut scale = self.diurnal[self.diurnal.len() - 1].scale;
        for seg in &self.diurnal {
            if pos < seg.dur_s {
                scale = seg.scale;
                break;
            }
            pos -= seg.dur_s;
        }
        if let Some(f) = self.flash {
            if t_s >= f.start_s && t_s < f.start_s + f.dur_s {
                scale *= f.mult;
            }
        }
        scale
    }

    /// Integral of the rate multiplier over `[0, t_s]` — the expected
    /// arrival count over `[0, t_s]` is `base_qps * integral`.
    pub fn integrated_scale(&self, t_s: f64) -> f64 {
        // Walk boundaries; segments are short so this stays cheap for
        // test-sized horizons.
        let mut acc = 0.0;
        let mut t = 0.0;
        while t < t_s {
            let b = self.next_boundary(t).min(t_s);
            acc += self.scale_at(t + (b - t) * 0.5) * (b - t);
            t = b;
        }
        acc
    }

    /// The first rate boundary strictly after `t_s` (segment edge or
    /// flash-window edge).
    fn next_boundary(&self, t_s: f64) -> f64 {
        let cycle = self.cycle_s();
        let base = (t_s / cycle).floor() * cycle;
        let mut next = base + cycle;
        let mut edge = base;
        for seg in &self.diurnal {
            edge += seg.dur_s;
            if edge > t_s + 1e-9 {
                next = edge;
                break;
            }
        }
        if let Some(f) = self.flash {
            for e in [f.start_s, f.start_s + f.dur_s] {
                if e > t_s + 1e-9 && e < next {
                    next = e;
                }
            }
        }
        next
    }

    /// Next arrival after `t_us` for a base rate of `base_qps`: exact
    /// piecewise-constant-rate Poisson via memorylessness (a gap that
    /// crosses a boundary is redrawn from the boundary).
    pub fn next_arrival(&self, mut t_us: Micros, base_qps: f64, rng: &mut Rng) -> Micros {
        loop {
            let t_s = t_us as f64 / 1e6;
            let rate = (base_qps * self.scale_at(t_s)).max(1e-9);
            let boundary = self.next_boundary(t_s);
            let gap_s = rng.exponential(rate);
            if t_s + gap_s < boundary {
                return t_us + ((gap_s * 1e6).max(1.0)) as Micros;
            }
            t_us = ((boundary * 1e6).ceil() as Micros).max(t_us + 1);
        }
    }

    /// Build an `n`-request trace at base rate `base_qps` (node-level
    /// QPS). RNG forks match the other builders: `fork(1)` arrivals,
    /// `fork(2)` sizes.
    pub fn build(&self, seed: u64, base_qps: f64, n: usize, slo: Slo) -> Trace {
        let mut root = Rng::new(seed);
        let mut arrivals = root.fork(1);
        let mut sizes = root.fork(2);
        let mut requests = Vec::with_capacity(n);
        let mut t: Micros = 0;
        for i in 0..n {
            t = self.next_arrival(t, base_qps, &mut arrivals);
            let input_tokens = sample_bucket(&self.isl, &mut sizes);
            let output_tokens = sample_bucket(&self.osl, &mut sizes);
            requests.push(Request {
                id: RequestId(i as u64),
                arrival: t,
                input_tokens,
                output_tokens,
                slo,
                tenant: 0,
            });
        }
        Trace { requests, ..Trace::default() }
    }

    /// Structural checks shared by the TOML and axis loaders.
    pub fn validate(&self) -> Result<(), String> {
        if self.diurnal.is_empty() || self.cycle_s() <= 0.0 {
            return Err("trace diurnal curve must have positive total duration".into());
        }
        for tbl in [&self.isl, &self.osl] {
            if tbl.is_empty() || tbl.iter().map(|b| b.weight).sum::<f64>() <= 0.0 {
                return Err("trace length buckets must carry positive weight".into());
            }
            for b in tbl {
                if b.lo == 0 || b.hi < b.lo {
                    return Err(format!("bad length bucket [{}, {}]", b.lo, b.hi));
                }
            }
        }
        Ok(())
    }
}

/// Weighted-bucket empirical sampler: pick a bucket proportional to its
/// weight, then uniform in `[lo, hi]`.
fn sample_bucket(buckets: &[LenBucket], rng: &mut Rng) -> u32 {
    let total: f64 = buckets.iter().map(|b| b.weight).sum();
    let target = rng.f64() * total;
    let mut acc = 0.0;
    let mut chosen = &buckets[buckets.len() - 1];
    for b in buckets {
        acc += b.weight;
        if acc >= target {
            chosen = b;
            break;
        }
    }
    if chosen.hi == chosen.lo {
        chosen.lo
    } else {
        chosen.lo + rng.range_u64(0, (chosen.hi - chosen.lo + 1) as u64) as u32
    }
}

// ---------------------------------------------------------------------------
// Tenant classes
// ---------------------------------------------------------------------------

/// One tenant class: arrival share, priority tier, SLO scale. Tenant
/// ids on [`Request`] are 1-based indexes into the class list (0 =
/// untenanted).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantClass {
    pub name: String,
    /// Fraction of arrivals assigned to this class; shares sum to 1.
    pub share: f64,
    /// [`TIER_INTERACTIVE`] | [`TIER_STANDARD`] | [`TIER_BATCH`].
    pub tier: u8,
    /// TTFT/TPOT multiplier on the scenario SLO (1.0 = unchanged).
    pub slo_scale: f64,
}

impl TenantClass {
    /// Parse the compact tenants atom: `none` (empty set) or `+`-joined
    /// `name:share:tier[:slo_scale]` entries. Shares must sum to 1.
    pub fn parse_compact(atom: &str) -> Result<Vec<TenantClass>, String> {
        if atom == "none" {
            return Ok(Vec::new());
        }
        let mut classes = Vec::new();
        for entry in atom.split('+') {
            let fields: Vec<&str> = entry.split(':').collect();
            if fields.len() < 3 || fields.len() > 4 {
                return Err(format!(
                    "bad tenant entry '{entry}' (expect name:share:tier[:slo_scale])"
                ));
            }
            let share = fields[1]
                .parse::<f64>()
                .map_err(|_| format!("tenant '{}': bad share '{}'", fields[0], fields[1]))?;
            let tier = parse_tier(fields[2])?;
            let slo_scale = match fields.get(3) {
                Some(s) => s.parse::<f64>().map_err(|_| {
                    format!("tenant '{}': bad slo_scale '{s}'", fields[0])
                })?,
                None => 1.0,
            };
            classes.push(TenantClass {
                name: fields[0].to_string(),
                share,
                tier,
                slo_scale,
            });
        }
        validate_tenants(&classes)?;
        Ok(classes)
    }

    /// The atom a class list round-trips to.
    pub fn label(classes: &[TenantClass]) -> String {
        if classes.is_empty() {
            return "none".into();
        }
        classes
            .iter()
            .map(|c| format!("{}:{}:{}:{}", c.name, c.share, tier_name(c.tier), c.slo_scale))
            .collect::<Vec<_>>()
            .join("+")
    }
}

/// Structural checks on a tenant-class list: unique names, positive
/// shares summing to 1 (±1e-6), positive SLO scales.
pub fn validate_tenants(classes: &[TenantClass]) -> Result<(), String> {
    if classes.is_empty() {
        return Ok(());
    }
    let mut sum = 0.0;
    for (i, c) in classes.iter().enumerate() {
        if c.name.is_empty() {
            return Err("tenant name must be non-empty".into());
        }
        if classes[..i].iter().any(|o| o.name == c.name) {
            return Err(format!("duplicate tenant '{}'", c.name));
        }
        if c.share <= 0.0 || c.share > 1.0 {
            return Err(format!("tenant '{}' share {} must be in (0, 1]", c.name, c.share));
        }
        if c.slo_scale <= 0.0 {
            return Err(format!(
                "tenant '{}' slo_scale {} must be > 0",
                c.name, c.slo_scale
            ));
        }
        if c.tier as usize >= N_TIERS {
            return Err(format!("tenant '{}' tier {} out of range", c.name, c.tier));
        }
        sum += c.share;
    }
    if (sum - 1.0).abs() > 1e-6 {
        return Err(format!("tenant shares sum to {sum}, must sum to 1"));
    }
    Ok(())
}

/// Tenant-id → tier lookup table: index 0 is the untenanted default
/// (standard), index `i+1` is class `i`'s tier.
pub fn tier_table(classes: &[TenantClass]) -> Vec<u8> {
    let mut t = Vec::with_capacity(classes.len() + 1);
    t.push(TIER_STANDARD);
    t.extend(classes.iter().map(|c| c.tier));
    t
}

/// Tag every request with a tenant id drawn by share and scale its SLO
/// by the class's `slo_scale`. Uses an independent RNG stream
/// (`fork(3)`), so traces built without tenants are untouched and
/// bit-identical to the pre-tenant builders.
pub fn assign_tenants(trace: &mut Trace, classes: &[TenantClass], seed: u64) {
    if classes.is_empty() {
        return;
    }
    let mut root = Rng::new(seed);
    let mut rng = root.fork(3);
    for req in &mut trace.requests {
        let u = rng.f64();
        let mut acc = 0.0;
        let mut idx = classes.len() - 1;
        for (i, c) in classes.iter().enumerate() {
            acc += c.share;
            if u < acc {
                idx = i;
                break;
            }
        }
        req.tenant = (idx + 1) as u8;
        if classes[idx].slo_scale != 1.0 {
            req.slo = req.slo.scaled(classes[idx].slo_scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate_and_mean_lengths_match_names() {
        for &name in PRESETS {
            let spec = TraceSpec::preset(name).unwrap();
            spec.validate().unwrap();
            let trace = spec.build(7, 20.0, 4000, Slo::paper_default());
            let mean_in: f64 = trace.requests.iter().map(|r| r.input_tokens as f64).sum::<f64>()
                / trace.len() as f64;
            let mean_out: f64 = trace.requests.iter().map(|r| r.output_tokens as f64).sum::<f64>()
                / trace.len() as f64;
            let (want_in, want_out) = match name {
                "mt-4400x1200" => (4400.0, 1200.0),
                _ => (8192.0, 256.0),
            };
            assert!((mean_in / want_in - 1.0).abs() < 0.1, "{name} ISL mean {mean_in}");
            assert!((mean_out / want_out - 1.0).abs() < 0.1, "{name} OSL mean {mean_out}");
        }
        assert!(TraceSpec::preset("nope").is_err());
    }

    #[test]
    fn long_run_arrivals_match_integrated_rate() {
        // Satellite property: arrival count over [0, T] tracks
        // base_qps * integrated_scale(T) for the diurnal curve.
        let spec = TraceSpec::preset("mt-4400x1200").unwrap();
        let trace = spec.build(3, 30.0, 6000, Slo::paper_default());
        let t_end = trace.requests.last().unwrap().arrival as f64 / 1e6;
        let expected = 30.0 * spec.integrated_scale(t_end);
        let got = trace.len() as f64;
        assert!(
            (got / expected - 1.0).abs() < 0.08,
            "got {got} arrivals, integrated curve expects {expected:.0}"
        );
    }

    #[test]
    fn flash_crowd_rate_exceeds_base() {
        let spec = TraceSpec::preset("synth-8192x256")
            .unwrap()
            .with_flash(FlashCrowd { start_s: 50.0, dur_s: 50.0, mult: 4.0 })
            .unwrap();
        let trace = spec.build(11, 10.0, 4000, Slo::paper_default());
        let count_in = |lo: f64, hi: f64| {
            trace
                .requests
                .iter()
                .filter(|r| {
                    let t = r.arrival as f64 / 1e6;
                    t >= lo && t < hi
                })
                .count() as f64
        };
        let flash_rate = count_in(50.0, 100.0) / 50.0;
        let base_rate = count_in(0.0, 50.0) / 50.0;
        assert!(
            flash_rate > base_rate * 2.0,
            "flash {flash_rate}/s vs base {base_rate}/s"
        );
        // And the instantaneous multiplier reflects the window.
        assert_eq!(spec.scale_at(75.0), 4.0);
        assert_eq!(spec.scale_at(150.0), 1.0);
    }

    #[test]
    fn sampling_is_seed_stable() {
        let spec = TraceSpec::parse_compact("mt-4400x1200:flash:30:30:2")
            .unwrap()
            .unwrap();
        let a = spec.build(9, 12.0, 500, Slo::paper_default());
        let b = spec.build(9, 12.0, 500, Slo::paper_default());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.input_tokens, y.input_tokens);
            assert_eq!(x.output_tokens, y.output_tokens);
        }
        let c = spec.build(10, 12.0, 500, Slo::paper_default());
        assert_ne!(a.requests[0].arrival, c.requests[0].arrival);
    }

    #[test]
    fn compact_atoms_round_trip_and_reject_garbage() {
        let ts = TraceSpec::parse_compact("mt-4400x1200").unwrap().unwrap();
        assert_eq!(ts.label(), "mt-4400x1200");
        let ts = TraceSpec::parse_compact("synth-8192x256:flash:120:60:3").unwrap().unwrap();
        assert_eq!(ts.label(), "synth-8192x256:flash:120:60:3");
        assert!(TraceSpec::parse_compact("none").unwrap().is_none());
        for bad in [
            "nope",
            "mt-4400x1200:flash:1:2",
            "mt-4400x1200:surge:1:2:3",
            "mt-4400x1200:flash:a:2:3",
            "mt-4400x1200:flash:10:0:3",
            "mt-4400x1200:flash:10:10:1",
        ] {
            assert!(TraceSpec::parse_compact(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn tenant_atoms_validate_shares() {
        let ts =
            TenantClass::parse_compact("prime:0.5:interactive+std:0.3:standard+bulk:0.2:batch")
                .unwrap();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[0].tier, TIER_INTERACTIVE);
        assert_eq!(ts[2].tier, TIER_BATCH);
        assert_eq!(ts[1].slo_scale, 1.0);
        assert_eq!(tier_table(&ts), vec![TIER_STANDARD, 0, 1, 2]);
        for bad in [
            "a:0.5:interactive",                   // shares sum to 0.5
            "a:0.6:interactive+b:0.6:batch",       // sum to 1.2
            "a:0.5:interactive+a:0.5:batch",       // duplicate name
            "a:0.5:warp+b:0.5:batch",              // unknown tier
            "a:0.5:interactive:0+b:0.5:batch",     // slo_scale <= 0
            "a:x:interactive+b:0.5:batch",         // bad share
            "a:0.5",                               // too few fields
        ] {
            assert!(TenantClass::parse_compact(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn assign_tenants_tags_by_share_and_scales_slo() {
        let classes =
            TenantClass::parse_compact("prime:0.5:interactive:0.5+bulk:0.5:batch:2").unwrap();
        let spec = TraceSpec::preset("synth-8192x256").unwrap();
        let mut trace = spec.build(5, 20.0, 2000, Slo::paper_default());
        assign_tenants(&mut trace, &classes, 5);
        let n1 = trace.requests.iter().filter(|r| r.tenant == 1).count();
        let n2 = trace.requests.iter().filter(|r| r.tenant == 2).count();
        assert_eq!(n1 + n2, trace.len(), "every request tagged");
        let frac = n1 as f64 / trace.len() as f64;
        assert!((frac - 0.5).abs() < 0.05, "share ~0.5, got {frac}");
        let base = Slo::paper_default();
        for r in &trace.requests {
            if r.tenant == 1 {
                assert_eq!(r.slo.ttft, base.ttft / 2);
            } else {
                assert_eq!(r.slo.ttft, base.ttft * 2);
            }
        }
        // Deterministic across calls.
        let mut again = spec.build(5, 20.0, 2000, Slo::paper_default());
        assign_tenants(&mut again, &classes, 5);
        for (a, b) in trace.requests.iter().zip(&again.requests) {
            assert_eq!(a.tenant, b.tenant);
        }
    }
}
