//! Fig 1: goodput vs QPS/GPU for 4P4D-600W, 5P3D-600W and the RAPID
//! non-uniform 4P-750W/4D-450W, all inside the 4800 W node budget
//! (LongBench, TTFT = 1 s / TPOT = 40 ms). The RAPID curve should
//! dominate, especially at high request rates.

use crate::config::{presets, ClusterConfig};
use crate::experiments::{RatePoint, ShapeCheck};
use crate::scenario::{Axis, Scenario, Study};

pub struct Fig1 {
    pub curves: Vec<(ClusterConfig, Vec<RatePoint>)>,
}

pub const RATES: &[f64] = &[0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75, 2.0];

/// The declarative form of this figure: three config curves × the
/// rate axis, LongBench at the paper SLO.
pub fn scenario(seed: u64, n: usize) -> Scenario {
    Scenario::new("fig1", presets::p4d4(600.0))
        .seed(seed)
        .requests(n)
        .axis(Axis::Config(vec![
            presets::p4d4(600.0),
            presets::p5d3_600(),
            presets::p4_750_d4_450(), // "[4P4D]-RAPID" in the figure
        ]))
        .axis(Axis::RatePerGpu(RATES.to_vec()))
}

pub fn run(seed: u64, n: usize) -> Fig1 {
    let study = Study::new(scenario(seed, n)).run(None).expect("fig1 scenario");
    Fig1 {
        curves: study.rate_curves(),
    }
}

impl Fig1 {
    fn curve(&self, name: &str) -> &[RatePoint] {
        &self
            .curves
            .iter()
            .find(|(c, _)| c.name == name)
            .expect("curve")
            .1
    }

    pub fn render(&self) -> String {
        let mut out = String::from(
            "Goodput (attained QPS, node total) vs QPS/GPU — 4800 W budget, LongBench\n",
        );
        out.push_str(&format!("{:<18}", "QPS/GPU"));
        for r in RATES {
            out.push_str(&format!("{r:>7.2}"));
        }
        out.push('\n');
        for (cfg, pts) in &self.curves {
            out.push_str(&format!("{:<18}", cfg.name));
            for p in pts {
                out.push_str(&format!("{:>7.2}", p.goodput_qps));
            }
            out.push('\n');
        }
        out
    }

    pub fn checks(&self) -> Vec<ShapeCheck> {
        let rapid = self.curve("4P-750W/4D-450W");
        let p4d4 = self.curve("4P4D-600W");
        let p5d3 = self.curve("5P3D-600W");
        // At high rate (>= 1.5 QPS/GPU) RAPID must dominate both.
        let hi = |pts: &[RatePoint]| {
            pts.iter()
                .filter(|p| p.qps_per_gpu >= 1.5)
                .map(|p| p.goodput_qps)
                .sum::<f64>()
        };
        let (g_rapid, g_44, g_53) = (hi(rapid), hi(p4d4), hi(p5d3));
        // Peak goodput across the sweep.
        let peak = |pts: &[RatePoint]| pts.iter().map(|p| p.goodput_qps).fold(0.0, f64::max);
        vec![
            ShapeCheck::new(
                "RAPID non-uniform power wins at high QPS (Fig 1)",
                g_rapid > g_44 && g_rapid > g_53,
                format!("sum-goodput@>=1.5: rapid={g_rapid:.1} 4p4d={g_44:.1} 5p3d={g_53:.1}"),
            ),
            ShapeCheck::new(
                "5P3D improves on uniform 4P4D-600W but not on RAPID",
                g_53 >= g_44 * 0.95 && g_53 <= g_rapid,
                format!("{g_53:.1} in [{:.1}, {g_rapid:.1}]", g_44 * 0.95),
            ),
            ShapeCheck::new(
                "RAPID peak goodput at least ties the best (within 5%)",
                peak(rapid) >= 0.95 * peak(p4d4).max(peak(p5d3)),
                format!(
                    "peaks: rapid={:.1} 4p4d={:.1} 5p3d={:.1}",
                    peak(rapid),
                    peak(p4d4),
                    peak(p5d3)
                ),
            ),
        ]
    }
}
