//! KV-cache transfer ring buffer (paper §3.2).
//!
//! The paper transfers KV from prefill to decode GPUs through "a
//! persistent ring buffer shared across GPUs … per-slot atomic ready
//! flags and … low-overhead polling", with a pull model and a request
//! buffer of 32 slots. This is that structure, built on atomics:
//!
//! * the producer (prefill worker) reserves a slot, writes the payload,
//!   then sets the slot's ready flag (release ordering);
//! * the consumer (decode worker) polls the head slot's flag (acquire),
//!   takes the payload, and frees the slot;
//! * when all slots are in flight the producer sees backpressure
//!   (`try_publish` returns `RingFull`) — exactly the stall the paper's
//!   queue-based controller watches for.
//!
//! The same type serves the real PJRT path (multi-threaded) and the
//! simulator (single-threaded slot accounting).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Debug, PartialEq, Eq)]
pub enum RingError {
    RingFull(usize),
}

impl std::fmt::Display for RingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RingError::RingFull(n) => write!(f, "ring full: all {n} slots in flight"),
        }
    }
}

impl std::error::Error for RingError {}

/// `try_publish` hands the payload back on failure so callers can retry.
pub type PublishRejected<T> = (RingError, T);

/// One slot: payload guarded by a ready flag. The Mutex is uncontended by
/// construction (a slot has exactly one writer then one reader between
/// flag transitions); it exists to keep the payload Send+Sync without
/// unsafe.
struct Slot<T> {
    ready: AtomicBool,
    payload: Mutex<Option<T>>,
}

/// MPSC ring: many prefill workers publish, one decode-side puller drains
/// per consumer index. Slots are freed on consume, so capacity bounds the
/// number of undrained KV handles (the paper's "request buffer of 32").
pub struct KvRing<T> {
    slots: Vec<Slot<T>>,
    /// Next slot to try publishing into.
    head: AtomicU64,
    /// Next slot to consume.
    tail: AtomicU64,
    published: AtomicU64,
    consumed: AtomicU64,
}

impl<T> KvRing<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        KvRing {
            slots: (0..capacity)
                .map(|_| Slot {
                    ready: AtomicBool::new(false),
                    payload: Mutex::new(None),
                })
                .collect(),
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
            published: AtomicU64::new(0),
            consumed: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Number of published-but-unconsumed slots.
    pub fn in_flight(&self) -> usize {
        (self.published.load(Ordering::Acquire) - self.consumed.load(Ordering::Acquire))
            as usize
    }

    pub fn is_full(&self) -> bool {
        self.in_flight() >= self.capacity()
    }

    /// Publish a payload; returns the slot index, or hands the payload
    /// back with a backpressure error.
    pub fn try_publish(&self, payload: T) -> Result<usize, PublishRejected<T>> {
        // Reserve: head may only advance if a slot is free.
        loop {
            let head = self.head.load(Ordering::Acquire);
            let tail = self.tail.load(Ordering::Acquire);
            if head - tail >= self.capacity() as u64 {
                return Err((RingError::RingFull(self.capacity()), payload));
            }
            if self
                .head
                .compare_exchange(head, head + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                let idx = (head % self.capacity() as u64) as usize;
                let slot = &self.slots[idx];
                *slot.payload.lock().unwrap() = Some(payload);
                slot.ready.store(true, Ordering::Release); // publish
                self.published.fetch_add(1, Ordering::AcqRel);
                return Ok(idx);
            }
        }
    }

    /// Poll the tail slot; consume it if ready (the decode pull).
    pub fn try_consume(&self) -> Option<T> {
        let tail = self.tail.load(Ordering::Acquire);
        let head = self.head.load(Ordering::Acquire);
        if tail >= head {
            return None;
        }
        let idx = (tail % self.capacity() as u64) as usize;
        let slot = &self.slots[idx];
        if !slot.ready.load(Ordering::Acquire) {
            return None; // producer reserved but hasn't finished writing
        }
        if self
            .tail
            .compare_exchange(tail, tail + 1, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return None; // another consumer won (MPMC-safe, though we use SPSC)
        }
        let payload = slot.payload.lock().unwrap().take();
        slot.ready.store(false, Ordering::Release);
        self.consumed.fetch_add(1, Ordering::AcqRel);
        payload
    }

    /// Publish, spinning with `backoff` while the ring is full (the
    /// producer-side stall of the paper's backpressure design).
    pub fn publish_blocking(&self, mut payload: T, mut backoff: impl FnMut()) -> usize {
        loop {
            match self.try_publish(payload) {
                Ok(idx) => return idx,
                Err(returned) => {
                    payload = returned.1;
                    backoff();
                }
            }
        }
    }

    /// Drain everything currently ready (used on role-change drains).
    pub fn drain(&self) -> Vec<T> {
        let mut out = Vec::new();
        while let Some(x) = self.try_consume() {
            out.push(x);
        }
        out
    }

    /// Totals for conservation checks: (published, consumed).
    pub fn totals(&self) -> (u64, u64) {
        (
            self.published.load(Ordering::Acquire),
            self.consumed.load(Ordering::Acquire),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn publish_then_consume_fifo() {
        let ring = KvRing::new(4);
        for i in 0..4 {
            ring.try_publish(i).unwrap();
        }
        assert!(ring.is_full());
        let (err, returned) = ring.try_publish(99).unwrap_err();
        assert_eq!(err, RingError::RingFull(4));
        assert_eq!(returned, 99, "payload handed back on backpressure");
        for i in 0..4 {
            assert_eq!(ring.try_consume(), Some(i));
        }
        assert_eq!(ring.try_consume(), None);
    }

    #[test]
    fn slots_recycle_after_consume() {
        let ring = KvRing::new(2);
        for round in 0..10 {
            ring.try_publish(round * 2).unwrap();
            ring.try_publish(round * 2 + 1).unwrap();
            assert!(ring.is_full());
            assert_eq!(ring.try_consume(), Some(round * 2));
            assert_eq!(ring.try_consume(), Some(round * 2 + 1));
        }
        let (p, c) = ring.totals();
        assert_eq!(p, 20);
        assert_eq!(c, 20);
    }

    #[test]
    fn drain_empties_ring() {
        let ring = KvRing::new(8);
        for i in 0..5 {
            ring.try_publish(i).unwrap();
        }
        assert_eq!(ring.drain(), vec![0, 1, 2, 3, 4]);
        assert_eq!(ring.in_flight(), 0);
    }

    #[test]
    fn concurrent_producers_single_consumer_conserve() {
        let ring = Arc::new(KvRing::new(32));
        let n_producers = 4;
        let per_producer = 2000u64;
        let mut handles = Vec::new();
        for p in 0..n_producers {
            let r = Arc::clone(&ring);
            handles.push(std::thread::spawn(move || {
                let mut sent = 0;
                while sent < per_producer {
                    match r.try_publish(p * 1_000_000 + sent) {
                        Ok(_) => sent += 1,
                        Err(_) => std::thread::yield_now(),
                    }
                }
            }));
        }
        let consumer = {
            let r = Arc::clone(&ring);
            std::thread::spawn(move || {
                let total = n_producers as usize * per_producer as usize;
                let mut got = Vec::with_capacity(total);
                while got.len() < total {
                    match r.try_consume() {
                        Some(v) => got.push(v),
                        None => std::thread::yield_now(),
                    }
                }
                got
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        let mut got = consumer.join().unwrap();
        assert_eq!(got.len(), 8000);
        got.sort();
        got.dedup();
        assert_eq!(got.len(), 8000, "no duplicates, no losses");
        let (p, c) = ring.totals();
        assert_eq!(p, c);
    }

    #[test]
    fn per_producer_order_preserved() {
        // FIFO overall implies per-producer FIFO.
        let ring = Arc::new(KvRing::<u64>::new(16));
        let r = Arc::clone(&ring);
        let producer = std::thread::spawn(move || {
            for i in 0..5000u64 {
                loop {
                    if r.try_publish(i).is_ok() {
                        break;
                    }
                    std::thread::yield_now();
                }
            }
        });
        let mut last = None;
        let mut seen = 0;
        while seen < 5000 {
            if let Some(v) = ring.try_consume() {
                if let Some(l) = last {
                    assert!(v > l, "order violated: {v} after {l}");
                }
                last = Some(v);
                seen += 1;
            }
        }
        producer.join().unwrap();
    }
}
