//! Pluggable study renderers: text tables, JSON, CSV.
//!
//! The emitter contract (DESIGN.md §9): every emitter consumes the same
//! [`StudyResult`] and exposes the same per-cell values — the
//! `metrics::Summary` aggregates for sim cells, the scalar for
//! microbench cells — so the attainment/goodput a text table shows is
//! byte-for-byte the number the JSON and CSV carry (modulo the text
//! table's fixed-width rounding). JSON goes through `util::json::Json`,
//! so the output is parseable by the same parser the crate ships.

use super::{Cell, CellOut, StudyResult};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Output format of the `rapid study` subcommand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Text,
    Json,
    Csv,
}

impl std::str::FromStr for Format {
    type Err = String;
    fn from_str(s: &str) -> Result<Format, String> {
        match s {
            "text" => Ok(Format::Text),
            "json" => Ok(Format::Json),
            "csv" => Ok(Format::Csv),
            other => Err(format!("unknown format '{other}' (text | json | csv)")),
        }
    }
}

/// A study renderer. Implementations must not reorder cells.
pub trait Emitter {
    fn emit(&self, study: &StudyResult) -> String;
}

/// Render `study` in `format`.
pub fn emit(study: &StudyResult, format: Format) -> String {
    emitter(format).emit(study)
}

/// The emitter registered for a format.
pub fn emitter(format: Format) -> &'static dyn Emitter {
    match format {
        Format::Text => &TextEmitter,
        Format::Json => &JsonEmitter,
        Format::Csv => &CsvEmitter,
    }
}

fn all_scalar(study: &StudyResult) -> bool {
    study
        .cells
        .iter()
        .all(|c| matches!(c.out, CellOut::Scalar(_)))
}

/// Any disturbed cell in the study? Gates the resilience columns so
/// undisturbed studies render byte-identically to pre-env output.
fn any_resilience(study: &StudyResult) -> bool {
    study.cells.iter().any(|c| c.resilience().is_some())
}

/// Any cell with an active KV capacity model? Gates the memory columns
/// so capacity-free studies render byte-identically to pre-mem output.
fn any_mem(study: &StudyResult) -> bool {
    study.cells.iter().any(|c| c.mem().is_some())
}

/// Any multi-tenant cell in the study? Gates the per-tier columns so
/// untenanted studies render byte-identically to pre-tenant output.
fn any_tenants(study: &StudyResult) -> bool {
    study.cells.iter().any(|c| c.tenants().is_some())
}

/// Any traced cell in the study? Gates the observability counter
/// columns so untraced studies (every plain `rapid study` run — the
/// sink is only enabled by `rapid trace`) render byte-identically to
/// pre-obs output.
fn any_obs(study: &StudyResult) -> bool {
    study.cells.iter().any(|c| c.obs().is_some())
}

/// Total events a traced cell recorded (resident plus ring-dropped).
fn obs_events_total(r: &crate::obs::ObsReport) -> u64 {
    r.events.len() as u64 + r.dropped
}

// ---------------------------------------------------------------------------
// Text
// ---------------------------------------------------------------------------

pub struct TextEmitter;

/// Named per-cell metric with its table formatting.
struct Metric {
    name: &'static str,
    value: fn(&Cell) -> f64,
    fmt: fn(f64) -> String,
}

fn text_metrics(study: &StudyResult) -> Vec<Metric> {
    if all_scalar(study) {
        vec![Metric {
            name: "value (us)",
            value: Cell::value,
            fmt: |v| format!("{v:.0}"),
        }]
    } else {
        let mut metrics = vec![
            Metric {
                name: "attainment",
                value: Cell::attainment,
                fmt: |v| format!("{v:.4}"),
            },
            Metric {
                name: "goodput_qps",
                value: Cell::goodput_qps,
                fmt: |v| format!("{v:.3}"),
            },
        ];
        if any_resilience(study) {
            metrics.push(Metric {
                name: "dip_depth",
                value: |c| c.resilience().map_or(0.0, |r| r.dip_depth),
                fmt: |v| format!("{v:.3}"),
            });
            metrics.push(Metric {
                name: "recovery_s",
                value: |c| c.resilience().map_or(0.0, |r| r.recovery_s),
                // Infinite = never recovered before the run ended.
                fmt: |v| if v.is_finite() { format!("{v:.1}") } else { "never".into() },
            });
        }
        if any_mem(study) {
            metrics.push(Metric {
                name: "peak_kv_occ",
                value: |c| c.mem().map_or(0.0, |m| m.peak_occupancy),
                fmt: |v| format!("{v:.3}"),
            });
            metrics.push(Metric {
                name: "prefix_hit_rate",
                value: |c| c.mem().map_or(0.0, |m| m.hit_rate),
                fmt: |v| format!("{v:.3}"),
            });
        }
        if any_tenants(study) {
            use crate::workload::tracespec::{TIER_BATCH, TIER_INTERACTIVE};
            metrics.push(Metric {
                name: "interactive_attainment",
                value: |c| c.tenants().map_or(0.0, |t| t[TIER_INTERACTIVE as usize].attainment),
                fmt: |v| format!("{v:.4}"),
            });
            metrics.push(Metric {
                name: "batch_attainment",
                value: |c| c.tenants().map_or(0.0, |t| t[TIER_BATCH as usize].attainment),
                fmt: |v| format!("{v:.4}"),
            });
            metrics.push(Metric {
                name: "shed",
                value: |c| {
                    c.tenants()
                        .map_or(0.0, |t| t.iter().map(|x| x.shed as f64).sum())
                },
                fmt: |v| format!("{v:.0}"),
            });
            metrics.push(Metric {
                name: "preempted",
                value: |c| {
                    c.tenants()
                        .map_or(0.0, |t| t.iter().map(|x| x.preempted as f64).sum())
                },
                fmt: |v| format!("{v:.0}"),
            });
        }
        if any_obs(study) {
            metrics.push(Metric {
                name: "obs_events",
                value: |c| c.obs().map_or(0.0, |o| obs_events_total(o) as f64),
                fmt: |v| format!("{v:.0}"),
            });
            metrics.push(Metric {
                name: "power_moves",
                value: |c| c.obs().map_or(0.0, |o| o.counters.power_moves as f64),
                fmt: |v| format!("{v:.0}"),
            });
            metrics.push(Metric {
                name: "requeues",
                value: |c| c.obs().map_or(0.0, |o| o.counters.requeues as f64),
                fmt: |v| format!("{v:.0}"),
            });
        }
        metrics
    }
}

impl Emitter for TextEmitter {
    fn emit(&self, study: &StudyResult) -> String {
        let s = &study.scenario;
        let axis_desc = if s.axes.is_empty() {
            "no axes".to_string()
        } else {
            s.axes
                .iter()
                .map(|a| format!("{}[{}]", a.key(), a.len()))
                .collect::<Vec<_>>()
                .join(" x ")
        };
        let mut out = format!(
            "study {} — {} cells ({axis_desc}), workload {}, seed {}, {} requests/cell\n",
            s.name,
            study.cells.len(),
            s.workload.kind(),
            s.seed,
            s.requests
        );
        let n_cols = s.axes.last().map_or(1, super::Axis::len);
        let col_labels: Vec<String> = match s.axes.last() {
            Some(axis) => (0..axis.len()).map(|i| axis.label(i)).collect(),
            None => vec!["value".to_string()],
        };
        let row_label = |cell: &Cell| -> String {
            let n = cell.coords.len().saturating_sub(1);
            if n == 0 {
                s.name.clone()
            } else {
                cell.coords[..n]
                    .iter()
                    .map(|(_, v)| v.clone())
                    .collect::<Vec<_>>()
                    .join(" ")
            }
        };
        let label_w = study
            .cells
            .iter()
            .map(|c| row_label(c).len())
            .max()
            .unwrap_or(8)
            .max(8)
            + 2;
        let col_w = col_labels.iter().map(String::len).max().unwrap_or(7).max(9) + 2;
        for metric in text_metrics(study) {
            out.push_str(&format!("\n[{}]\n{:<label_w$}", metric.name, ""));
            for l in &col_labels {
                out.push_str(&format!("{l:>col_w$}"));
            }
            out.push('\n');
            for row in study.cells.chunks(n_cols) {
                out.push_str(&format!("{:<label_w$}", row_label(&row[0])));
                for cell in row {
                    out.push_str(&format!("{:>col_w$}", (metric.fmt)((metric.value)(cell))));
                }
                out.push('\n');
            }
        }
        let (passed, total) = study.checks_passed();
        if total > 0 {
            out.push_str(&format!("\ncell checks: {passed}/{total} passed\n"));
            for cell in &study.cells {
                for c in cell.checks.iter().filter(|c| !c.pass) {
                    out.push_str(&format!(
                        "  [FAIL] {:?} {} ({})\n",
                        cell.coords, c.what, c.detail
                    ));
                }
            }
        }
        let study_checks = study.study_checks();
        if !study_checks.is_empty() {
            let passed = study_checks.iter().filter(|c| c.pass).count();
            out.push_str(&format!(
                "study checks: {passed}/{} passed\n",
                study_checks.len()
            ));
            for c in &study_checks {
                out.push_str(&format!(
                    "  [{}] {} ({})\n",
                    if c.pass { "PASS" } else { "FAIL" },
                    c.what,
                    c.detail
                ));
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// JSON
// ---------------------------------------------------------------------------

pub struct JsonEmitter;

/// JSON numbers must be finite; NaN/inf (e.g. percentiles of an empty
/// record set) map to null.
fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn cell_json(cell: &Cell) -> Json {
    let mut obj = BTreeMap::new();
    let coords: BTreeMap<String, Json> = cell
        .coords
        .iter()
        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
        .collect();
    obj.insert("coords".into(), Json::Obj(coords));
    obj.insert("config".into(), Json::Str(cell.config.name.clone()));
    obj.insert("rate_per_gpu".into(), num(cell.rate_per_gpu));
    match &cell.out {
        CellOut::Scalar(v) => {
            obj.insert("value_us".into(), num(*v));
        }
        CellOut::Sim(r) => {
            let s = r.summary();
            let mut m = BTreeMap::new();
            m.insert("requests".into(), Json::Num(s.requests as f64));
            m.insert("attainment".into(), num(s.attainment));
            m.insert("goodput_qps".into(), num(s.goodput_qps));
            m.insert("qps_per_kw".into(), num(s.qps_per_kw));
            m.insert("ttft_p50_ms".into(), num(s.ttft_p50_ms));
            m.insert("ttft_p90_ms".into(), num(s.ttft_p90_ms));
            m.insert("tpot_p50_ms".into(), num(s.tpot_p50_ms));
            m.insert("tpot_p90_ms".into(), num(s.tpot_p90_ms));
            m.insert("mean_provisioned_w".into(), num(s.mean_provisioned_w));
            m.insert("peak_node_w".into(), num(s.peak_node_w));
            m.insert("duration_s".into(), num(s.duration_s));
            if let Some(res) = s.resilience {
                m.insert("dip_depth".into(), num(res.dip_depth));
                m.insert("recovery_s".into(), num(res.recovery_s));
                m.insert("pre_goodput_qps".into(), num(res.pre_goodput_qps));
                m.insert("dip_goodput_qps".into(), num(res.dip_goodput_qps));
                m.insert("attainment_pre".into(), num(res.attainment_pre));
                m.insert("attainment_during".into(), num(res.attainment_during));
                m.insert("attainment_post".into(), num(res.attainment_post));
            }
            if let Some(mem) = s.mem {
                m.insert("peak_kv_occ".into(), num(mem.peak_occupancy));
                m.insert("kv_evictions".into(), Json::Num(mem.evictions as f64));
                m.insert("kv_offload_bytes".into(), Json::Num(mem.offload_bytes as f64));
                m.insert("prefix_hits".into(), Json::Num(mem.prefix_hits as f64));
                m.insert("prefix_lookups".into(), Json::Num(mem.prefix_lookups as f64));
                m.insert("prefix_hit_rate".into(), num(mem.hit_rate));
            }
            if let Some(tiers) = s.tenants {
                for (i, t) in tiers.iter().enumerate() {
                    let tier = crate::workload::tracespec::tier_name(i as u8);
                    m.insert(format!("{tier}_requests"), Json::Num(t.requests as f64));
                    m.insert(format!("{tier}_attainment"), num(t.attainment));
                    m.insert(format!("{tier}_goodput_qps"), num(t.goodput_qps));
                    m.insert(format!("{tier}_shed"), Json::Num(t.shed as f64));
                    m.insert(format!("{tier}_preempted"), Json::Num(t.preempted as f64));
                }
            }
            obj.insert("metrics".into(), Json::Obj(m));
            if let Some(o) = r.obs.as_deref() {
                let c = &o.counters;
                let mut ob = BTreeMap::new();
                ob.insert("events".into(), Json::Num(obs_events_total(o) as f64));
                ob.insert("dropped".into(), Json::Num(o.dropped as f64));
                for (k, v) in [
                    ("arrivals", c.arrivals),
                    ("sheds", c.sheds),
                    ("gpu_steps", c.gpu_steps),
                    ("first_tokens", c.first_tokens),
                    ("kv_transfers", c.kv_transfers),
                    ("decode_admits", c.decode_admits),
                    ("preemptions", c.preemptions),
                    ("requeues", c.requeues),
                    ("finishes", c.finishes),
                    ("power_moves", c.power_moves),
                    ("gpu_moves", c.gpu_moves),
                    ("role_flips", c.role_flips),
                    ("cap_updates", c.cap_updates),
                    ("budget_changes", c.budget_changes),
                    ("env_applied", c.env_applied),
                    ("prefix_hits", c.prefix_hits),
                    ("evictions", c.evictions),
                ] {
                    ob.insert(k.into(), Json::Num(v as f64));
                }
                obj.insert("obs".into(), Json::Obj(ob));
            }
        }
    }
    let checks: Vec<Json> = cell
        .checks
        .iter()
        .map(|c| {
            let mut m = BTreeMap::new();
            m.insert("what".into(), Json::Str(c.what.clone()));
            m.insert("pass".into(), Json::Bool(c.pass));
            m.insert("detail".into(), Json::Str(c.detail.clone()));
            Json::Obj(m)
        })
        .collect();
    obj.insert("checks".into(), Json::Arr(checks));
    Json::Obj(obj)
}

impl Emitter for JsonEmitter {
    fn emit(&self, study: &StudyResult) -> String {
        let s = &study.scenario;
        let mut obj = BTreeMap::new();
        obj.insert("scenario".into(), Json::Str(s.name.clone()));
        obj.insert("seed".into(), Json::Num(s.seed as f64));
        obj.insert("requests".into(), Json::Num(s.requests as f64));
        obj.insert("workload".into(), Json::Str(s.workload.kind().into()));
        let axes: Vec<Json> = s
            .axes
            .iter()
            .map(|a| {
                let mut m = BTreeMap::new();
                m.insert("key".into(), Json::Str(a.key().into()));
                m.insert(
                    "values".into(),
                    Json::Arr((0..a.len()).map(|i| Json::Str(a.label(i))).collect()),
                );
                Json::Obj(m)
            })
            .collect();
        obj.insert("axes".into(), Json::Arr(axes));
        obj.insert(
            "cells".into(),
            Json::Arr(study.cells.iter().map(cell_json).collect()),
        );
        let study_checks: Vec<Json> = study
            .study_checks()
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert("what".into(), Json::Str(c.what.clone()));
                m.insert("pass".into(), Json::Bool(c.pass));
                m.insert("detail".into(), Json::Str(c.detail.clone()));
                Json::Obj(m)
            })
            .collect();
        obj.insert("study_checks".into(), Json::Arr(study_checks));
        let mut out = Json::Obj(obj).to_string();
        out.push('\n');
        out
    }
}

// ---------------------------------------------------------------------------
// CSV
// ---------------------------------------------------------------------------

pub struct CsvEmitter;

fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

impl Emitter for CsvEmitter {
    fn emit(&self, study: &StudyResult) -> String {
        let axis_keys: Vec<&str> = study.scenario.axes.iter().map(super::Axis::key).collect();
        let scalar = all_scalar(study);
        let resilience = any_resilience(study);
        let mem = any_mem(study);
        let tenants = any_tenants(study);
        let obs = any_obs(study);
        let mut out = String::new();
        for k in &axis_keys {
            out.push_str(k);
            out.push(',');
        }
        // `config_name`, not `config`: a Config axis already contributes
        // a `config` coordinate column.
        if scalar {
            out.push_str("config_name,value_us\n");
        } else {
            out.push_str(
                "config_name,attainment,goodput_qps,qps_per_kw,ttft_p90_ms,tpot_p90_ms,\
                 mean_provisioned_w",
            );
            if resilience {
                out.push_str(",dip_depth,recovery_s");
            }
            if mem {
                out.push_str(",peak_kv_occ,kv_evictions,kv_offload_bytes,prefix_hit_rate");
            }
            if tenants {
                out.push_str(
                    ",interactive_attainment,standard_attainment,batch_attainment,shed,preempted",
                );
            }
            if obs {
                out.push_str(",obs_events,power_moves,requeues");
            }
            out.push('\n');
        }
        for cell in &study.cells {
            for (_, v) in &cell.coords {
                out.push_str(&csv_field(v));
                out.push(',');
            }
            out.push_str(&csv_field(&cell.config.name));
            match &cell.out {
                CellOut::Scalar(v) => out.push_str(&format!(",{v}")),
                CellOut::Sim(r) => {
                    let s = r.summary();
                    out.push_str(&format!(
                        ",{},{},{},{},{},{}",
                        s.attainment,
                        s.goodput_qps,
                        s.qps_per_kw,
                        s.ttft_p90_ms,
                        s.tpot_p90_ms,
                        s.mean_provisioned_w
                    ));
                    if resilience {
                        let (dip, rec) = s
                            .resilience
                            .map_or((0.0, 0.0), |r| (r.dip_depth, r.recovery_s));
                        // Never-recovered runs leave the field empty
                        // (standard CSV missing value), matching the
                        // JSON emitter's null for non-finite numbers.
                        if rec.is_finite() {
                            out.push_str(&format!(",{dip},{rec}"));
                        } else {
                            out.push_str(&format!(",{dip},"));
                        }
                    }
                    if mem {
                        // Inactive cells in a mem study emit zeros (the
                        // capacity model never engaged there).
                        let (occ, ev, off, hr) = s.mem.map_or((0.0, 0, 0, 0.0), |m| {
                            (m.peak_occupancy, m.evictions, m.offload_bytes, m.hit_rate)
                        });
                        out.push_str(&format!(",{occ},{ev},{off},{hr}"));
                    }
                    if tenants {
                        // Untenanted cells in a tenants study emit
                        // zeros (no tier ever saw a request there).
                        let tiers = s.tenants.unwrap_or_default();
                        let shed: u64 = tiers.iter().map(|t| t.shed).sum();
                        let preempted: u64 = tiers.iter().map(|t| t.preempted).sum();
                        out.push_str(&format!(
                            ",{},{},{},{shed},{preempted}",
                            tiers[0].attainment, tiers[1].attainment, tiers[2].attainment
                        ));
                    }
                    if obs {
                        // Untraced cells in a mixed study emit zeros.
                        let (ev, pm, rq) = r.obs.as_deref().map_or((0, 0, 0), |o| {
                            (obs_events_total(o), o.counters.power_moves, o.counters.requeues)
                        });
                        out.push_str(&format!(",{ev},{pm},{rq}"));
                    }
                }
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::scenario::{Axis, Scenario, Study, WorkloadSpec};

    fn small_study() -> StudyResult {
        Study::new(
            Scenario::new("emit-test", presets::p4d4(600.0))
                .requests(40)
                .seed(9)
                .axis(Axis::Config(vec![
                    presets::p4d4(600.0),
                    presets::p4_750_d4_450(),
                ]))
                .axis(Axis::RatePerGpu(vec![0.5, 1.5])),
        )
        .run(Some(1))
        .unwrap()
    }

    #[test]
    fn json_parses_and_matches_cells() {
        let study = small_study();
        let text = emit(&study, Format::Json);
        let v = Json::parse(text.trim()).unwrap();
        assert_eq!(v.get("scenario").unwrap().as_str(), Some("emit-test"));
        let cells = v.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), study.cells.len());
        for (jc, cell) in cells.iter().zip(&study.cells) {
            let m = jc.get("metrics").unwrap();
            assert_eq!(
                m.get("attainment").unwrap().as_f64(),
                Some(cell.attainment())
            );
            assert_eq!(
                m.get("goodput_qps").unwrap().as_f64(),
                Some(cell.goodput_qps())
            );
        }
        let axes = v.get("axes").unwrap().as_arr().unwrap();
        assert_eq!(axes.len(), 2);
        assert_eq!(axes[0].get("key").unwrap().as_str(), Some("config"));
    }

    #[test]
    fn csv_has_header_plus_one_row_per_cell() {
        let study = small_study();
        let text = emit(&study, Format::Csv);
        let lines: Vec<&str> = text.trim_end().lines().collect();
        assert_eq!(lines.len(), 1 + study.cells.len());
        assert!(lines[0].starts_with("config,rate_per_gpu,config_name,attainment"));
        for (line, cell) in lines[1..].iter().zip(&study.cells) {
            assert!(line.contains(&format!(",{},", cell.attainment())), "{line}");
        }
    }

    #[test]
    fn text_tables_cover_all_cells() {
        let study = small_study();
        let text = emit(&study, Format::Text);
        assert!(text.contains("[attainment]"));
        assert!(text.contains("[goodput_qps]"));
        assert!(text.contains("4P4D-600W"));
        assert!(text.contains("4P-750W/4D-450W"));
        assert!(text.contains("cell checks:"));
        for cell in &study.cells {
            let rounded = format!("{:.4}", cell.attainment());
            assert!(text.contains(&rounded), "missing {rounded}");
        }
    }

    #[test]
    fn scalar_studies_emit_value_column() {
        let study = Study::new(
            Scenario::new("micro", presets::p4d4(600.0))
                .workload(WorkloadSpec::DecodeMicrobench {
                    context_tokens: 4096.0,
                })
                .axis(Axis::Batch(vec![8, 64]))
                .axis(Axis::PowerW(vec![400.0, 600.0])),
        )
        .run(Some(1))
        .unwrap();
        let csv = emit(&study, Format::Csv);
        assert!(csv.lines().next().unwrap().ends_with("config_name,value_us"));
        let json = emit(&study, Format::Json);
        let v = Json::parse(json.trim()).unwrap();
        let cells = v.get("cells").unwrap().as_arr().unwrap();
        assert!(cells[0].get("value_us").unwrap().as_f64().unwrap() > 0.0);
        let text = emit(&study, Format::Text);
        assert!(text.contains("[value (us)]"));
    }

    #[test]
    fn resilience_rendered_only_for_disturbed_studies() {
        // Undisturbed studies keep the pre-env output shape exactly.
        let plain = small_study();
        assert!(!emit(&plain, Format::Text).contains("[dip_depth]"));
        assert!(!emit(&plain, Format::Csv).lines().next().unwrap().contains("dip_depth"));
        // A disturbed study renders the resilience block everywhere.
        let study = Study::new(
            Scenario::new("env-emit", presets::rapid_600())
                .requests(60)
                .seed(3)
                .axis(Axis::Env(vec!["cap:2:4000".into()])),
        )
        .run(Some(1))
        .unwrap();
        let text = emit(&study, Format::Text);
        assert!(text.contains("[dip_depth]"), "{text}");
        assert!(text.contains("[recovery_s]"), "{text}");
        let json = emit(&study, Format::Json);
        let v = Json::parse(json.trim()).unwrap();
        let cells = v.get("cells").unwrap().as_arr().unwrap();
        let m = cells[0].get("metrics").unwrap();
        assert!(m.get("dip_depth").is_some());
        assert!(m.get("attainment_during").is_some());
        let csv = emit(&study, Format::Csv);
        assert!(csv.lines().next().unwrap().ends_with("dip_depth,recovery_s"), "{csv}");
        assert_eq!(csv.trim_end().lines().count(), 2);
    }

    #[test]
    fn mem_rendered_only_for_mem_studies() {
        // Capacity-free studies keep the pre-mem output shape exactly.
        let plain = small_study();
        assert!(!emit(&plain, Format::Text).contains("[peak_kv_occ]"));
        assert!(!emit(&plain, Format::Csv).lines().next().unwrap().contains("peak_kv_occ"));
        // A capacity-model study renders the memory block everywhere.
        let study = Study::new(
            Scenario::new("mem-emit", presets::p4d4(600.0))
                .requests(40)
                .seed(7)
                .axis(Axis::Mem(vec!["none".into(), "multiturn:3:0.5+hbm:64".into()])),
        )
        .run(Some(1))
        .unwrap();
        let text = emit(&study, Format::Text);
        assert!(text.contains("[peak_kv_occ]"), "{text}");
        assert!(text.contains("[prefix_hit_rate]"), "{text}");
        let json = emit(&study, Format::Json);
        let v = Json::parse(json.trim()).unwrap();
        let cells = v.get("cells").unwrap().as_arr().unwrap();
        // Cell 0 is the inactive comparison cell: no mem metrics.
        let m0 = cells[0].get("metrics").unwrap();
        assert!(m0.get("peak_kv_occ").is_none());
        let m1 = cells[1].get("metrics").unwrap();
        assert!(m1.get("peak_kv_occ").is_some());
        assert!(m1.get("prefix_hit_rate").is_some());
        assert!(m1.get("kv_evictions").is_some());
        let csv = emit(&study, Format::Csv);
        assert!(
            csv.lines().next().unwrap().ends_with(
                "peak_kv_occ,kv_evictions,kv_offload_bytes,prefix_hit_rate"
            ),
            "{csv}"
        );
        assert_eq!(csv.trim_end().lines().count(), 3);
    }

    #[test]
    fn tenants_rendered_only_for_multitenant_studies() {
        // Untenanted studies keep the pre-tenant output shape exactly.
        let plain = small_study();
        assert!(!emit(&plain, Format::Text).contains("[interactive_attainment]"));
        assert!(!emit(&plain, Format::Csv).lines().next().unwrap().contains("interactive"));
        // A multi-tenant study renders the per-tier block everywhere.
        let study = Study::new(
            Scenario::new("tenant-emit", presets::p4d4(600.0))
                .requests(60)
                .seed(5)
                .axis(Axis::Tenants(vec![
                    "none".into(),
                    "chat:0.6:interactive+jobs:0.4:batch:4".into(),
                ])),
        )
        .run(Some(1))
        .unwrap();
        let text = emit(&study, Format::Text);
        assert!(text.contains("[interactive_attainment]"), "{text}");
        assert!(text.contains("[batch_attainment]"), "{text}");
        assert!(text.contains("[shed]"), "{text}");
        let json = emit(&study, Format::Json);
        let v = Json::parse(json.trim()).unwrap();
        let cells = v.get("cells").unwrap().as_arr().unwrap();
        // Cell 0 is the untenanted comparison cell: no tier metrics.
        let m0 = cells[0].get("metrics").unwrap();
        assert!(m0.get("interactive_attainment").is_none());
        let m1 = cells[1].get("metrics").unwrap();
        assert!(m1.get("interactive_attainment").is_some());
        assert!(m1.get("batch_goodput_qps").is_some());
        assert!(m1.get("standard_requests").is_some());
        let csv = emit(&study, Format::Csv);
        assert!(
            csv.lines().next().unwrap().ends_with(
                "interactive_attainment,standard_attainment,batch_attainment,shed,preempted"
            ),
            "{csv}"
        );
        assert_eq!(csv.trim_end().lines().count(), 3);
    }

    #[test]
    fn obs_rendered_only_for_traced_studies() {
        // Untraced studies keep the pre-obs output shape exactly.
        let plain = small_study();
        assert!(!emit(&plain, Format::Text).contains("[obs_events]"));
        assert!(!emit(&plain, Format::Csv).lines().next().unwrap().contains("obs_events"));
        assert!(!emit(&plain, Format::Json).contains("\"obs\""));
        // A study carrying a traced cell renders the counter block.
        let study = Study::new(
            Scenario::new("obs-emit", presets::p4d4(600.0)).requests(40).seed(9),
        );
        let (spec, res) = study.run_traced(&[]).unwrap();
        assert!(res.obs.is_some());
        let traced = StudyResult {
            scenario: study.scenario.clone(),
            cells: vec![Cell {
                coords: spec.coords.clone(),
                config: spec.config.clone(),
                rate_per_gpu: spec.rate_per_gpu,
                slo: spec.slo,
                out: CellOut::Sim(res),
                checks: Vec::new(),
            }],
        };
        let text = emit(&traced, Format::Text);
        assert!(text.contains("[obs_events]"), "{text}");
        assert!(text.contains("[power_moves]"), "{text}");
        let json = emit(&traced, Format::Json);
        let v = Json::parse(json.trim()).unwrap();
        let cells = v.get("cells").unwrap().as_arr().unwrap();
        let ob = cells[0].get("obs").unwrap();
        assert!(ob.get("events").unwrap().as_f64().unwrap() > 0.0);
        assert!(ob.get("gpu_steps").unwrap().as_f64().unwrap() > 0.0);
        assert!(ob.get("finishes").unwrap().as_f64().is_some());
        let csv = emit(&traced, Format::Csv);
        assert!(
            csv.lines().next().unwrap().ends_with("obs_events,power_moves,requeues"),
            "{csv}"
        );
    }

    #[test]
    fn csv_field_quoting() {
        assert_eq!(csv_field("plain"), "plain");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn format_parses() {
        assert_eq!("json".parse::<Format>().unwrap(), Format::Json);
        assert_eq!("csv".parse::<Format>().unwrap(), Format::Csv);
        assert_eq!("text".parse::<Format>().unwrap(), Format::Text);
        assert!("yaml".parse::<Format>().is_err());
    }
}
