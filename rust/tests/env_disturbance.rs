//! Environment-subsystem integration tests (ISSUE-5 acceptance
//! criteria, DESIGN.md §12).
//!
//! * **Golden inertness**: an empty `EnvProfile` — and a profile whose
//!   only event lies beyond the run horizon — leave the `RunResult`
//!   bit-identical to the undisturbed run on the shipped
//!   `configs/rapid-600.toml` and `configs/hetero-4p4d.toml`.
//! * **Cap steps** are respected the instant they land: total allocated
//!   power never exceeds the instantaneous cluster budget at any
//!   cap-trace point.
//! * **GPU failure** loses zero requests (accounting), and the fleet
//!   converges back after recovery (roles and caps return).
//! * **`scenarios/curtailment.toml`**: RapidDynamic >= StaticPolicy
//!   goodput under curtailment (the study-level ShapeCheck).
//! * **Resilience metrics** are bit-identical across sweep thread
//!   counts.

use rapid::env::EnvProfile;
use rapid::scenario::{Scenario, Study};
use rapid::sim::{self, SimOptions};
use rapid::types::{Micros, Slo, SECOND};
use rapid::util::rng::Rng;
use rapid::workload::{build_trace, sonnet::Sonnet, ArrivalProcess};

#[path = "support/mod.rs"]
mod support;
use support::{assert_bit_identical, shipped_config};

fn trace(n: usize, qps: f64, input: u32, output: u32) -> rapid::workload::Trace {
    let mut ap = ArrivalProcess::poisson(Rng::new(81), qps);
    let mut sizes = Sonnet::new(Rng::new(82), input, output);
    build_trace(n, &mut ap, &mut sizes, Slo::paper_default())
}

/// Cluster budget in force at `t` given the base budget and the
/// recorded step trace.
fn budget_at(base: f64, steps: &[(Micros, f64)], t: Micros) -> f64 {
    steps
        .iter()
        .take_while(|&&(st, _)| st <= t)
        .last()
        .map(|&(_, b)| b)
        .unwrap_or(base)
}

#[test]
fn empty_env_profile_is_bit_identical_on_shipped_configs() {
    for (file, n, qps, input, output) in [
        ("rapid-600.toml", 200, 16.0, 3000, 32),
        ("hetero-4p4d.toml", 200, 14.0, 3000, 32),
    ] {
        let plain = shipped_config(file);
        assert!(plain.env.is_empty(), "{file} must not declare an env");
        // Same config with a disturbance far beyond the run horizon:
        // the wiring is live but nothing ever applies.
        let mut beyond = plain.clone();
        beyond.env = EnvProfile::parse_compact("cap:100000:4800").unwrap();
        beyond.validate().unwrap();
        let t = trace(n, qps, input, output);
        let a = sim::run(&plain, &t, &SimOptions::default());
        let b = sim::run(&beyond, &t, &SimOptions::default());
        assert_bit_identical(&a, &b);
        assert!(a.resilience.is_none() && b.resilience.is_none());
        assert!(a.env_events.is_empty() && b.env_events.is_empty());
        assert!(a.budget_trace.is_empty() && b.budget_trace.is_empty());
    }
}

#[test]
fn cluster_cap_step_is_respected_instantly_and_always() {
    let mut cfg = shipped_config("rapid-600.toml");
    cfg.env = EnvProfile::parse_compact("cap:10:4000+cap:25:4800").unwrap();
    cfg.validate().unwrap();
    let t = trace(450, 16.0, 2500, 48);
    let r = sim::run(&cfg, &t, &SimOptions::default());
    assert_eq!(r.env_events.len(), 2, "both cap steps apply: {:?}", r.env_events);
    assert_eq!(r.budget_trace, vec![(10 * SECOND, 4000.0), (25 * SECOND, 4800.0)]);
    // (a) The step is respected the instant it lands — the env handler
    // records a cap-trace point at the event time itself, already
    // within the new budget — and at every later point too.
    let base = cfg.cluster_budget();
    let mut saw_step_point = false;
    for (at, caps) in &r.cap_trace {
        let sum: f64 = caps.iter().sum();
        let budget = budget_at(base, &r.budget_trace, *at);
        assert!(
            sum <= budget + 1e-6,
            "t={at}: allocated {sum:.1} W exceeds instantaneous budget {budget:.1} W"
        );
        if *at == 10 * SECOND {
            saw_step_point = true;
            assert!(sum <= 4000.0 + 1e-6, "shed must land within the event tick");
        }
    }
    assert!(saw_step_point, "the env handler must trace the step instant");
    assert!(r.resilience.is_some());
    // Dynamic policy reclaims the restored budget: after the 25 s
    // restore some cap-trace point rises well above the curtailed
    // 4000 W total (MovePower raises are pending mid-move, so the very
    // last point need not sit at exactly 4800 W).
    let reclaimed = r
        .cap_trace
        .iter()
        .filter(|(at, _)| *at > 25 * SECOND)
        .map(|(_, caps)| caps.iter().sum::<f64>())
        .fold(0.0f64, f64::max);
    assert!(
        reclaimed > 4400.0,
        "restored budget must be reclaimed by the dynamic policy, peak {reclaimed:.1} W"
    );
}

#[test]
fn gpu_failure_loses_zero_requests_and_fleet_converges_back() {
    // Static 4P4D so the only role/cap motion is the failure handling.
    let mut cfg = rapid::config::presets::p4d4(600.0);
    cfg.env = EnvProfile::parse_compact("fail:8:5+recover:20:5").unwrap();
    cfg.validate().unwrap();
    let n = 300;
    let t = trace(n, 8.0, 1500, 32);
    let r = sim::run(&cfg, &t, &SimOptions::default());
    // (b) Conservation: every request gets exactly one record.
    assert_eq!(r.records.len(), n, "a failure must lose zero requests");
    let unique: std::collections::HashSet<u64> = r.records.iter().map(|x| x.id.0).collect();
    assert_eq!(unique.len(), n, "no request recorded twice");
    for rec in &r.records {
        assert!(rec.arrival <= rec.prefill_start, "{rec:?}");
        assert!(rec.prefill_start <= rec.first_token && rec.first_token <= rec.finish);
    }
    assert_eq!(r.env_events.len(), 2);
    // Role trace shows the decode pool dip and the convergence back.
    assert!(
        r.role_trace.iter().any(|&(_, p, d)| p == 4 && d == 3),
        "failure must shrink the decode pool: {:?}",
        r.role_trace
    );
    let &(_, p_end, d_end) = r.role_trace.last().unwrap();
    assert_eq!((p_end, d_end), (4, 4), "fleet converges back after recovery");
    // Power converges back too: final caps uniform at 600 W.
    let (_, last_caps) = r.cap_trace.last().unwrap();
    for (i, c) in last_caps.iter().enumerate() {
        assert!((c - 600.0).abs() < 1.0, "gpu{i} cap {c} after recovery");
    }
    // Light load on 7 GPUs: the run must still serve well.
    assert!(r.attainment() > 0.8, "attainment={}", r.attainment());
    // Deterministic under failures.
    let r2 = sim::run(&cfg, &t, &SimOptions::default());
    assert_bit_identical(&r, &r2);
}

#[test]
fn rapid_dynamic_beats_static_on_curtailment_scenario() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/curtailment.toml");
    let mut scenario = Scenario::from_toml_file(path).expect("shipped scenario loads");
    scenario.requests = 400; // keep the test quick; CI smoke runs it too
    let study = Study::new(scenario).run(Some(2)).expect("study runs");
    assert_eq!(study.cells.len(), 4, "2 policies x 2 env profiles");
    let (passed, total) = study.checks_passed();
    assert_eq!(passed, total, "per-cell invariants hold");
    // (c) The study-level check: dynamic >= static under curtailment.
    let checks = study.study_checks();
    assert_eq!(checks.len(), 1, "one dynamic policy, one curtailment group");
    assert!(checks[0].what.contains("rapid"), "{}", checks[0].what);
    assert!(checks[0].pass, "{}: {}", checks[0].what, checks[0].detail);
    // Direct comparison for good measure.
    let goodput = |policy: &str, env: &str| {
        study
            .cells
            .iter()
            .find(|c| {
                c.coords.iter().any(|(k, v)| k == "policy" && v == policy)
                    && c.coords.iter().any(|(k, v)| k == "env" && v.contains(env))
            })
            .map(|c| c.goodput_qps())
            .expect("cell present")
    };
    assert!(goodput("rapid", "curtail") + 1e-9 >= goodput("static", "curtail"));
    // Curtailed cells carry resilience; 'none' cells do not.
    for cell in &study.cells {
        let disturbed = cell.coords.iter().any(|(k, v)| k == "env" && v != "none");
        let res = cell.result().unwrap();
        assert_eq!(res.resilience.is_some(), disturbed, "{:?}", cell.coords);
    }
}

#[test]
fn gpu_churn_scenario_conserves_every_request() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/gpu-churn.toml");
    let mut scenario = Scenario::from_toml_file(path).expect("shipped scenario loads");
    scenario.requests = 250;
    let study = Study::new(scenario).run(Some(2)).expect("study runs");
    let (passed, total) = study.checks_passed();
    assert_eq!(passed, total, "conservation + budget invariants hold under churn");
    for cell in &study.cells {
        let res = cell.result().unwrap();
        assert_eq!(res.records.len(), 250, "{:?}", cell.coords);
    }
}

#[test]
fn resilience_metrics_deterministic_across_thread_counts() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/curtailment.toml");
    let mut scenario = Scenario::from_toml_file(path).expect("shipped scenario loads");
    scenario.requests = 200;
    let serial = Study::new(scenario.clone()).run(Some(1)).expect("serial");
    let par = Study::new(scenario).run(Some(4)).expect("parallel");
    let mut compared = 0;
    for (a, b) in serial.cells.iter().zip(&par.cells) {
        let (ra, rb) = (a.result().unwrap(), b.result().unwrap());
        assert_eq!(ra.resilience.is_some(), rb.resilience.is_some());
        if let (Some(x), Some(y)) = (ra.resilience, rb.resilience) {
            compared += 1;
            // (d) Bit-identical, not just approximately equal.
            assert_eq!(x.pre_goodput_qps.to_bits(), y.pre_goodput_qps.to_bits());
            assert_eq!(x.dip_goodput_qps.to_bits(), y.dip_goodput_qps.to_bits());
            assert_eq!(x.dip_depth.to_bits(), y.dip_depth.to_bits());
            assert_eq!(x.recovery_s.to_bits(), y.recovery_s.to_bits());
            assert_eq!(x.attainment_during.to_bits(), y.attainment_during.to_bits());
        }
        assert_eq!(a.goodput_qps().to_bits(), b.goodput_qps().to_bits());
    }
    assert!(compared >= 2, "both curtailed cells must carry resilience");
}
