//! Per-role worker behavior over [`GpuSim`](crate::sim::gpu::GpuSim).
//!
//! The prefill / decode / coalesced step logic that used to be inlined in
//! the `sim::engine` monolith (`kick_*` / `on_*`) now lives behind the
//! [`RoleBehavior`] trait, one implementation per [`Role`]:
//!
//! * [`prefill::PrefillBehavior`] — FIFO batch formation under the token
//!   budget, ring-slot backpressure, publish into the KV ring;
//! * [`decode::DecodeBehavior`] — continuous batching with admissions at
//!   step boundaries;
//! * [`coalesced::CoalescedBehavior`] — Sarathi-style chunked prefill
//!   co-scheduled with the resident decode batch (the vLLM baseline).
//!
//! The cluster core dispatches `StepDone` events through
//! [`behavior`]; role switches are epoch-guarded, so a completion that
//! raced a role change is dropped inside `on_step_done`.

pub mod coalesced;
pub mod decode;
pub mod prefill;

use crate::cluster::Cluster;
use crate::types::Role;

/// One role's step behavior. Implementations are stateless unit structs:
/// all state lives in the [`GpuSim`](crate::sim::gpu::GpuSim) entries of
/// the cluster, which is what makes role flips cheap.
pub trait RoleBehavior: Sync {
    /// The role this behavior drives.
    fn role(&self) -> Role;
    /// Try to start the next unit of work on GPU `gi` (no-op if busy,
    /// mid-drain into another role, or out of work).
    fn kick(&self, cl: &mut Cluster, gi: usize);
    /// Handle completion of the in-flight unit on GPU `gi`. Stale
    /// completions (epoch mismatch after a role change) are dropped.
    fn on_step_done(&self, cl: &mut Cluster, gi: usize, epoch: u64);
}

/// The behavior driving `role`.
pub fn behavior(role: Role) -> &'static dyn RoleBehavior {
    match role {
        Role::Prefill => &prefill::PrefillBehavior,
        Role::Decode => &decode::DecodeBehavior,
        Role::Coalesced => &coalesced::CoalescedBehavior,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn behavior_matches_role() {
        for role in [Role::Prefill, Role::Decode, Role::Coalesced] {
            assert_eq!(behavior(role).role(), role);
        }
    }
}
