//! Simulation entry point: run a trace through a cluster configuration.
//!
//! This is the substitution substrate for the paper's physical testbed
//! (see DESIGN.md §2): simulated GPUs execute the calibrated latency
//! model of `power::model`, the power manager enforces budget + ramp
//! dynamics, and the *actual paper logic* — router, batcher, Algorithm 1
//! controller — runs unmodified on top, exactly as it does on the real
//! PJRT serving path.
//!
//! The discrete-event core itself lives in [`crate::cluster`] (topology,
//! routing, drain/epoch lifecycle, KV ring, pluggable policies) with the
//! per-role step logic in [`crate::sim::worker`]; this module only holds
//! the options type and the `run` façade, plus the engine-level
//! regression tests.

use std::sync::Arc;

use crate::config::ClusterConfig;
use crate::metrics::RunResult;
use crate::types::{Micros, SECOND};
use crate::workload::Trace;

/// Tunables that are about the *simulation*, not the system under test.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Telemetry sampling period (Fig 3 wants 10 ms; sweeps use coarser).
    pub sample_period: Micros,
    /// Hard wall: stop this long after the last arrival even if requests
    /// are still unfinished (they count as SLO violations).
    pub drain_grace: Micros,
    /// Observability event-ring capacity; 0 (the default) disables
    /// recording entirely — no sink is constructed, the per-event
    /// record sites reduce to an `Option::is_none` branch, and the run
    /// is bit-identical to one built before the subsystem existed
    /// (DESIGN.md §17, golden-tested in `rust/tests/obs_trace.rs`).
    pub obs_events: usize,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            // Coarse default: sweep figures only need budget/provisioning
            // aggregates; Fig 3 overrides to the paper's 10 ms.
            sample_period: 200_000,
            drain_grace: 120 * SECOND,
            obs_events: 0,
        }
    }
}

/// Default event-ring capacity for a traced run (`rapid trace`): large
/// enough to hold every event of the shipped scenarios at their default
/// request counts; the ring drops oldest-first beyond it (the export
/// records how many).
pub const TRACE_EVENT_CAPACITY: usize = 1 << 20;

/// Run one experiment: a trace through a cluster configuration.
pub fn run(cfg: &ClusterConfig, trace: &Trace, opts: &SimOptions) -> RunResult {
    run_shared(cfg, &Arc::new(trace.clone()), opts)
}

/// [`run`] over a shared trace arena: the cluster borrows the `Arc`
/// instead of deep-copying the request list. This is the study hot
/// path — a sweep cell whose trace is already built bumps a refcount
/// where it used to clone tens of thousands of requests. Bit-identical
/// to [`run`] (which now delegates here).
pub fn run_shared(cfg: &ClusterConfig, trace: &Arc<Trace>, opts: &SimOptions) -> RunResult {
    crate::cluster::Cluster::new(cfg.clone(), Arc::clone(trace), opts.clone()).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::types::{Request, RequestId, Slo, MILLIS};
    use crate::util::rng::Rng;
    use crate::workload::{build_trace, sonnet::Sonnet, ArrivalProcess};

    fn small_trace(n: usize, qps: f64, input: u32, output: u32) -> Trace {
        let mut ap = ArrivalProcess::poisson(Rng::new(42), qps);
        let mut sizes = Sonnet::new(Rng::new(43), input, output);
        build_trace(n, &mut ap, &mut sizes, Slo::paper_default())
    }

    #[test]
    fn all_requests_complete_disaggregated() {
        let cfg = presets::p4d4(600.0);
        let trace = small_trace(100, 8.0, 1024, 32);
        let r = run(&cfg, &trace, &SimOptions::default());
        assert_eq!(r.records.len(), 100);
        // Light load: everything should attain.
        assert!(r.attainment() > 0.9, "attainment={}", r.attainment());
    }

    #[test]
    fn all_requests_complete_coalesced() {
        let cfg = presets::coalesced(750.0);
        let trace = small_trace(100, 8.0, 1024, 32);
        let r = run(&cfg, &trace, &SimOptions::default());
        assert_eq!(r.records.len(), 100);
        assert!(r.attainment() > 0.8, "attainment={}", r.attainment());
    }

    #[test]
    fn ttft_increases_under_overload() {
        let cfg = presets::p4d4(600.0);
        let light = run(&cfg, &small_trace(80, 4.0, 2048, 32), &SimOptions::default());
        let heavy = run(&cfg, &small_trace(300, 40.0, 2048, 32), &SimOptions::default());
        assert!(
            heavy.ttft_percentile(90.0) > light.ttft_percentile(90.0) * 2.0,
            "overload must queue: light={} heavy={}",
            light.ttft_percentile(90.0),
            heavy.ttft_percentile(90.0)
        );
        assert!(heavy.attainment() < light.attainment());
    }

    #[test]
    fn records_are_causally_ordered() {
        let cfg = presets::p4d4(600.0);
        let trace = small_trace(150, 12.0, 1500, 64);
        let r = run(&cfg, &trace, &SimOptions::default());
        for rec in &r.records {
            assert!(rec.arrival <= rec.prefill_start, "{rec:?}");
            assert!(rec.prefill_start <= rec.first_token);
            assert!(rec.first_token <= rec.finish);
        }
    }

    #[test]
    fn node_power_stays_under_budget_when_enforced() {
        let cfg = presets::p4d4(600.0);
        let trace = small_trace(200, 16.0, 2048, 64);
        let r = run(&cfg, &trace, &SimOptions::default());
        // Draw <= sum of caps <= budget (within ramp epsilon).
        assert!(
            r.node_power.max() <= cfg.node_budget_w + 10.0,
            "peak draw {} > budget",
            r.node_power.max()
        );
    }

    #[test]
    fn uncapped_node_can_exceed_budget_line() {
        let cfg = presets::uncapped_coalesced();
        let trace = small_trace(300, 14.0, 4096, 64);
        let r = run(&cfg, &trace, &SimOptions::default());
        assert!(
            r.node_power.max() > 4800.0,
            "uncapped peak {} should exceed the 4800 W line",
            r.node_power.max()
        );
    }

    #[test]
    fn dynamic_rapid_reallocates_under_prefill_pressure() {
        let mut cfg = presets::rapid_600();
        cfg.controller.queue_threshold = 4;
        // Prefill-heavy overload: long prompts, tiny outputs.
        let trace = small_trace(400, 20.0, 6000, 16);
        let r = run(&cfg, &trace, &SimOptions::default());
        assert!(
            !r.decisions.is_empty(),
            "controller should act under pressure"
        );
        let moved_power = r.decisions.iter().any(|(_, d)| d.contains("MovePower"));
        assert!(moved_power, "decisions: {:?}", &r.decisions[..r.decisions.len().min(5)]);
    }

    #[test]
    fn static_policy_makes_no_decisions() {
        let cfg = presets::p4d4(600.0);
        let trace = small_trace(200, 20.0, 6000, 16);
        let r = run(&cfg, &trace, &SimOptions::default());
        assert!(r.decisions.is_empty());
    }

    #[test]
    fn power_only_policy_shifts_power_without_gpu_moves() {
        let cfg = presets::power_only_600();
        // Prefill-heavy overload: the ablation policy must move power
        // toward prefill but never reassign GPUs.
        let trace = small_trace(400, 20.0, 6000, 16);
        let r = run(&cfg, &trace, &SimOptions::default());
        assert!(
            r.decisions.iter().any(|(_, d)| d.contains("MovePower")),
            "power-only should act under pressure: {:?}",
            &r.decisions[..r.decisions.len().min(5)]
        );
        assert!(
            r.decisions.iter().all(|(_, d)| !d.contains("MoveGpu")),
            "power-only must never move GPUs"
        );
    }

    #[test]
    fn deterministic_given_same_inputs() {
        let cfg = presets::rapid_600();
        let trace = small_trace(150, 12.0, 2048, 64);
        let a = run(&cfg, &trace, &SimOptions::default());
        let b = run(&cfg, &trace, &SimOptions::default());
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.finish, y.finish);
        }
        assert_eq!(a.decisions.len(), b.decisions.len());
    }

    #[test]
    fn single_token_requests_finish_at_prefill() {
        let cfg = presets::p4d4(600.0);
        let trace = Trace {
            requests: vec![Request {
                id: RequestId(0),
                arrival: 0,
                input_tokens: 512,
                output_tokens: 1,
                slo: Slo::paper_default(),
                tenant: 0,
            }],
            ..Trace::default()
        };
        let r = run(&cfg, &trace, &SimOptions::default());
        assert_eq!(r.records.len(), 1);
        assert_eq!(r.records[0].first_token, r.records[0].finish);
        assert!(r.records[0].finish < 200 * MILLIS);
    }

    #[test]
    fn hard_stop_records_unfinished_as_violations() {
        let cfg = presets::p4d4(600.0);
        // Hopeless overload with a short grace: some requests never finish.
        let trace = small_trace(500, 100.0, 8000, 400);
        let opts = SimOptions {
            drain_grace: 5 * SECOND,
            ..Default::default()
        };
        let r = run(&cfg, &trace, &opts);
        assert_eq!(r.records.len(), r.records.iter().map(|x| x.id.0).collect::<std::collections::HashSet<_>>().len());
        assert!(r.attainment() < 0.5);
    }
}
