//! End-to-end multi-node cluster tests: a 2-node disaggregated config
//! expressed purely in TOML runs through the simulator with hierarchical
//! budgets holding at both levels (the ISSUE-1 acceptance criterion).

use rapid::config::{presets, ClusterConfig};
use rapid::sim::{self, SimOptions};
use rapid::types::Slo;
use rapid::util::rng::Rng;
use rapid::workload::{build_trace, sonnet::Sonnet, ArrivalProcess};

fn two_node_cfg() -> ClusterConfig {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/two-node-4p4d.toml");
    let text = std::fs::read_to_string(path).expect("shipped two-node config");
    ClusterConfig::from_toml(&text).expect("two-node config parses")
}

fn trace(n: usize, qps: f64, input: u32, output: u32) -> rapid::workload::Trace {
    let mut ap = ArrivalProcess::poisson(Rng::new(11), qps);
    let mut sizes = Sonnet::new(Rng::new(12), input, output);
    build_trace(n, &mut ap, &mut sizes, Slo::paper_default())
}

#[test]
fn two_node_toml_runs_end_to_end() {
    let cfg = two_node_cfg();
    assert_eq!(cfg.n_nodes, 2);
    assert_eq!(cfg.total_gpus(), 16);
    assert!(cfg.enforce_budget);
    // 16 GPUs worth of traffic.
    let t = trace(300, 16.0, 2048, 64);
    let r = sim::run(&cfg, &t, &SimOptions::default());
    assert_eq!(r.records.len(), 300, "every request must get a record");
    assert!(r.attainment() > 0.5, "light load should mostly attain: {}", r.attainment());
}

#[test]
fn node_and_cluster_budgets_hold_under_load() {
    let cfg = two_node_cfg();
    let t = trace(500, 40.0, 4096, 64);
    let r = sim::run(&cfg, &t, &SimOptions::default());
    assert_eq!(r.node_power_by_node.len(), 2);
    for (nd, series) in r.node_power_by_node.iter().enumerate() {
        assert!(
            series.max() <= cfg.node_budget_w + 10.0,
            "node {nd} peak {} > node budget {}",
            series.max(),
            cfg.node_budget_w
        );
    }
    assert!(
        r.node_power.max() <= cfg.cluster_budget() + 10.0,
        "cluster peak {} > cluster budget {}",
        r.node_power.max(),
        cfg.cluster_budget()
    );
}

#[test]
fn per_node_series_sum_to_cluster_series() {
    let cfg = two_node_cfg();
    let t = trace(200, 12.0, 1500, 48);
    let r = sim::run(&cfg, &t, &SimOptions::default());
    let a = &r.node_power_by_node[0].points;
    let b = &r.node_power_by_node[1].points;
    let total = &r.node_power.points;
    assert_eq!(a.len(), total.len());
    assert_eq!(b.len(), total.len());
    for i in 0..total.len() {
        assert_eq!(a[i].0, total[i].0);
        assert!(
            (a[i].1 + b[i].1 - total[i].1).abs() < 1e-6,
            "sample {i}: {} + {} != {}",
            a[i].1,
            b[i].1,
            total[i].1
        );
    }
}

#[test]
fn two_node_dynamic_keeps_roles_covered() {
    let mut cfg = presets::scaled_to_nodes(presets::rapid_600(), 2);
    cfg.controller.queue_threshold = 3;
    let t = trace(400, 30.0, 6000, 16);
    let r = sim::run(&cfg, &t, &SimOptions::default());
    for &(at, p, d) in &r.role_trace {
        assert!(p >= 1 && d >= 1, "at t={at}: {p}P {d}D");
        assert_eq!(p + d, cfg.total_gpus());
    }
    assert_eq!(r.records.len(), 400);
}

#[test]
fn single_node_cluster_is_the_old_engine() {
    // n_nodes = 1 must be byte-identical to the classic single-node path.
    let cfg = presets::p4d4(600.0);
    let wrapped = presets::scaled_to_nodes(presets::p4d4(600.0), 1);
    let t = trace(150, 10.0, 2048, 64);
    let a = sim::run(&cfg, &t, &SimOptions::default());
    let b = sim::run(&wrapped, &t, &SimOptions::default());
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.first_token, y.first_token);
        assert_eq!(x.finish, y.finish);
    }
}

#[test]
fn two_nodes_beat_one_on_heavy_load() {
    // Scaling sanity: the same offered load that crushes one node is
    // comfortable for two.
    let one = presets::p4d4(600.0);
    let two = presets::scaled_to_nodes(presets::p4d4(600.0), 2);
    // ~48K prompt tokens/s offered: past one node's prefill capacity
    // (~33K tok/s at 600 W) but inside two nodes' (~65K tok/s).
    let t = trace(400, 16.0, 3000, 64);
    let r1 = sim::run(&one, &t, &SimOptions::default());
    let r2 = sim::run(&two, &t, &SimOptions::default());
    assert!(
        r2.attainment() > r1.attainment() + 0.05,
        "2 nodes {} vs 1 node {}",
        r2.attainment(),
        r1.attainment()
    );
}
