//! Declarative experiment surface: `Scenario` → `Study` → `StudyResult`.
//!
//! Every paper figure, design-space sweep and "what if" question is the
//! same shape: a workload + SLO + a grid of swept parameters, each cell
//! an independent deterministic simulation. A [`Scenario`] declares that
//! shape (base config, workload spec, one or more [`Axis`]es); a
//! [`Study`] expands the axis grid and fans every cell through
//! `util::par::parallel_map_threads` (bit-identical at any thread
//! count); the [`StudyResult`] holds typed [`Cell`]s — `RunResult`
//! aggregates plus per-cell invariant [`ShapeCheck`]s — consumed by the
//! figure drivers, the pluggable [`emit`] renderers (text/JSON/CSV) and
//! the `rapid study` CLI. Scenario TOML files (`scenarios/*.toml`) load
//! through [`file`], turning new experiments into data instead of code.
//!
//! String-valued axes use the same compact grammars the TOML loader
//! accepts, parsed and rejected at validation time before any cell
//! runs:
//!
//! ```
//! use rapid::env::EnvProfile;
//! use rapid::fleet::FleetConfig;
//! use rapid::mem::MemAxis;
//! use rapid::workload::tracespec::{TenantClass, TraceSpec};
//!
//! FleetConfig::parse_mix("mi300x:4+a100:4", &[]).unwrap();
//! EnvProfile::parse_compact("curtail:30:0.5:0.75:10").unwrap();
//! MemAxis::parse_compact("multiturn:4:0.6+hbm:32").unwrap();
//! TraceSpec::parse_compact("mt-4400x1200:flash:120:60:3").unwrap();
//! TenantClass::parse_compact("chat:0.5:interactive+jobs:0.5:batch:4").unwrap();
//! ```

pub mod emit;
pub mod file;

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::{presets, ClusterConfig, ControlPolicy, Topology};
use crate::metrics::RunResult;
use crate::power::PowerModel;
use crate::sim::{self, SimOptions};
use crate::types::{Micros, Slo};
use crate::util::par::{parallel_map_threads, parallel_map_threads_progress};
use crate::util::rng::Rng;
use crate::workload::sonnet::{mixed_phases, MixedPhasesSpec, Sonnet};
use crate::workload::tracespec::{assign_tenants, TraceSpec};
use crate::workload::{build_trace, longbench::LongBench, ArrivalProcess, Trace};

// ---------------------------------------------------------------------------
// Shape checks (shared with the figure drivers; re-exported by
// `experiments`).
// ---------------------------------------------------------------------------

/// One shape assertion: description + pass/fail + the measured detail.
#[derive(Debug, Clone)]
pub struct ShapeCheck {
    pub what: String,
    pub pass: bool,
    pub detail: String,
}

impl ShapeCheck {
    pub fn new(what: impl Into<String>, pass: bool, detail: impl Into<String>) -> Self {
        ShapeCheck {
            what: what.into(),
            pass,
            detail: detail.into(),
        }
    }
}

/// Render checks as a PASS/FAIL block.
pub fn render_checks(checks: &[ShapeCheck]) -> String {
    let mut out = String::new();
    for c in checks {
        out.push_str(&format!(
            "  [{}] {} ({})\n",
            if c.pass { "PASS" } else { "FAIL" },
            c.what,
            c.detail
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Rate-curve analysis helpers (shared across figures).
// ---------------------------------------------------------------------------

/// A point on an attainment-vs-rate curve.
#[derive(Debug, Clone)]
pub struct RatePoint {
    pub qps_per_gpu: f64,
    pub attainment: f64,
    pub goodput_qps: f64,
    pub qps_per_kw: f64,
}

/// Highest swept rate whose attainment still meets `threshold`
/// (the paper's "sustainable rate at 80% SLO attainment").
pub fn sustainable_rate(points: &[RatePoint], threshold: f64) -> f64 {
    points
        .iter()
        .filter(|p| p.attainment >= threshold)
        .map(|p| p.qps_per_gpu)
        .fold(0.0, f64::max)
}

/// Linear-interpolated rate at which attainment crosses `threshold`
/// (finer than `sustainable_rate` for factor comparisons).
pub fn crossing_rate(points: &[RatePoint], threshold: f64) -> f64 {
    let mut prev: Option<&RatePoint> = None;
    for p in points {
        if let Some(q) = prev {
            if q.attainment >= threshold && p.attainment < threshold {
                let frac = (q.attainment - threshold) / (q.attainment - p.attainment);
                return q.qps_per_gpu + frac * (p.qps_per_gpu - q.qps_per_gpu);
            }
        }
        prev = Some(p);
    }
    sustainable_rate(points, threshold)
}

// ---------------------------------------------------------------------------
// Trace builders (the canonical seed→trace conventions every cell uses).
// ---------------------------------------------------------------------------

/// Build a LongBench trace at a node-level rate (QPS across all GPUs).
pub fn longbench_trace(seed: u64, node_qps: f64, n: usize, slo: Slo) -> Trace {
    longbench_trace_bursty(seed, node_qps, n, slo, 1.0, 0.0)
}

/// LongBench trace with optional Markov-modulated bursts: `factor <= 1`
/// keeps plain Poisson arrivals; the RNG fork structure is identical in
/// both cases so the Poisson path stays bit-stable.
pub fn longbench_trace_bursty(
    seed: u64,
    node_qps: f64,
    n: usize,
    slo: Slo,
    factor: f64,
    burst_frac: f64,
) -> Trace {
    let mut root = Rng::new(seed);
    let mut ap = if factor > 1.0 {
        ArrivalProcess::bursty(root.fork(1), node_qps, factor, burst_frac)
    } else {
        ArrivalProcess::poisson(root.fork(1), node_qps)
    };
    let mut sizes = LongBench::new(root.fork(2));
    build_trace(n, &mut ap, &mut sizes, slo)
}

/// Fixed-shape Sonnet trace (controlled workloads), optionally bursty.
pub fn sonnet_trace(
    seed: u64,
    node_qps: f64,
    n: usize,
    slo: Slo,
    input_tokens: u32,
    output_tokens: u32,
    factor: f64,
    burst_frac: f64,
) -> Trace {
    let mut root = Rng::new(seed);
    let mut ap = if factor > 1.0 {
        ArrivalProcess::bursty(root.fork(1), node_qps, factor, burst_frac)
    } else {
        ArrivalProcess::poisson(root.fork(1), node_qps)
    };
    let mut sizes = Sonnet::new(root.fork(2), input_tokens, output_tokens);
    build_trace(n, &mut ap, &mut sizes, slo)
}

/// The Fig 8/9 two-phase mixed Sonnet trace: `n / 2` prefill-heavy then
/// `n - n / 2` decode-heavy requests at a node-level rate.
pub fn mixed_phases_trace(seed: u64, n: usize, node_qps: f64) -> Trace {
    mixed_phases(
        seed,
        MixedPhasesSpec {
            prefill_heavy_count: n / 2,
            decode_heavy_count: n - n / 2,
            rate_qps: node_qps,
            ..Default::default()
        },
    )
}

// ---------------------------------------------------------------------------
// Scenario declaration.
// ---------------------------------------------------------------------------

/// What each grid cell runs.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// Long-tailed prompts capped at 8K tokens (paper §4), Poisson or
    /// bursty arrivals.
    LongBench,
    /// Fixed-shape requests with small jitter (controlled experiments).
    Sonnet {
        input_tokens: u32,
        output_tokens: u32,
    },
    /// The Fig 8/9 two-phase trace (prefill-heavy then decode-heavy,
    /// TPOT SLO tightening at the boundary). Request count splits in two.
    MixedPhases,
    /// Analytic power-model probe: prefill batch latency at the cell's
    /// power/batch (Fig 4a). Produces a scalar cell, no simulation.
    PrefillMicrobench { input_tokens: u32 },
    /// Analytic power-model probe: decode step latency (Fig 4b).
    DecodeMicrobench { context_tokens: f64 },
}

impl WorkloadSpec {
    fn is_micro(&self) -> bool {
        matches!(
            self,
            WorkloadSpec::PrefillMicrobench { .. } | WorkloadSpec::DecodeMicrobench { .. }
        )
    }

    pub fn kind(&self) -> &'static str {
        match self {
            WorkloadSpec::LongBench => "longbench",
            WorkloadSpec::Sonnet { .. } => "sonnet",
            WorkloadSpec::MixedPhases => "mixed",
            WorkloadSpec::PrefillMicrobench { .. } => "prefill-microbench",
            WorkloadSpec::DecodeMicrobench { .. } => "decode-microbench",
        }
    }
}

/// One sweep dimension. A scenario's grid is the cartesian product of
/// its axes, expanded in declaration order with the **last axis
/// innermost** (it becomes the column axis of the text tables).
#[derive(Debug, Clone)]
pub enum Axis {
    /// Cluster configurations — the "curves" of most figures.
    Config(Vec<ClusterConfig>),
    /// Per-GPU request rate (QPS/GPU); node rate = rate × total GPUs.
    RatePerGpu(Vec<f64>),
    /// Uniform per-GPU power `w`: caps = `w`, node budget = `w × n_gpus`
    /// (the §5.1 budget parametrization, `presets::uniform_power`). For
    /// microbench workloads this is the model's power-cap argument.
    PowerW(Vec<f64>),
    /// Identical-node cluster sizes.
    NNodes(Vec<usize>),
    /// Controller policy overrides.
    Policy(Vec<ControlPolicy>),
    /// Uniform SLO scale factors applied to the scenario SLO (Fig 7).
    SloScale(Vec<f64>),
    /// Markov-modulated burst factor; `1.0` = plain Poisson.
    BurstFactor(Vec<f64>),
    /// Prefill/decode split override: prefill GPUs out of `n_gpus`.
    PrefillGpus(Vec<usize>),
    /// Batch size (microbench workloads).
    Batch(Vec<usize>),
    /// Per-node SKU mixes (`"mi300x:8"`, `"mi300x:4+a100:4"`), resolved
    /// against the built-in `fleet::skus` catalog. Each mix must cover
    /// exactly the base config's `n_gpus`, so homogeneous and mixed
    /// fleets of equal GPU count sweep under one power cap.
    SkuMix(Vec<String>),
    /// Workload RNG seeds: replicate every other cell across seeds (no
    /// aggregation — each seed is its own cell, emitted unchanged).
    Seed(Vec<u64>),
    /// Environment disturbance profiles in the compact grammar of
    /// [`crate::env::EnvProfile::parse_compact`] (`"none"`,
    /// `"curtail:30:0.5:0.75:10"`, `"faults:25:10:7"`, ...).
    Env(Vec<String>),
    /// Memory-subsystem cells in the compact grammar of
    /// [`crate::mem::MemAxis::parse_compact`] (`"none"`, `"hbm:16"`,
    /// `"multiturn:4:0.6+hbm:32"`, ...). An `hbm` atom activates the KV
    /// capacity model with that uniform per-GPU capacity; a `multiturn`
    /// atom rewrites the cell's trace into conversations; `"none"` is
    /// the inert comparison cell (no `[mem]` table, cache disabled).
    Mem(Vec<String>),
    /// Trace-replay arrival curves in the compact grammar of
    /// [`TraceSpec::parse_compact`] (`"none"`, `"mt-4400x1200"`,
    /// `"synth-8192x256:flash:120:60:3"`). A non-`none` atom replaces
    /// the cell's arrival process and size sampler with the preset's
    /// diurnal rate curve and empirical length distributions; `"none"`
    /// keeps the scenario workload (the inert comparison cell).
    Trace(Vec<String>),
    /// Tenant-class mixes in the compact grammar of
    /// [`crate::workload::tracespec::TenantClass::parse_compact`]
    /// (`"none"`, `"chat:0.5:interactive+jobs:0.5:batch:4"`). A
    /// non-`none` atom tags every request with a tenant, scales its
    /// SLO, and activates per-tier metrics and decode preemption;
    /// `"none"` is the untenanted comparison cell.
    Tenants(Vec<String>),
}

impl Axis {
    /// Stable key, used for coords, TOML axes and emitter columns.
    pub fn key(&self) -> &'static str {
        match self {
            Axis::Config(_) => "config",
            Axis::RatePerGpu(_) => "rate_per_gpu",
            Axis::PowerW(_) => "power_w",
            Axis::NNodes(_) => "n_nodes",
            Axis::Policy(_) => "policy",
            Axis::SloScale(_) => "slo_scale",
            Axis::BurstFactor(_) => "burst_factor",
            Axis::PrefillGpus(_) => "prefill_gpus",
            Axis::Batch(_) => "batch",
            Axis::SkuMix(_) => "sku_mix",
            Axis::Seed(_) => "seed",
            Axis::Env(_) => "env",
            Axis::Mem(_) => "mem",
            Axis::Trace(_) => "trace",
            Axis::Tenants(_) => "tenants",
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Axis::Config(v) => v.len(),
            Axis::RatePerGpu(v) | Axis::PowerW(v) | Axis::SloScale(v) | Axis::BurstFactor(v) => {
                v.len()
            }
            Axis::NNodes(v) | Axis::PrefillGpus(v) | Axis::Batch(v) => v.len(),
            Axis::Policy(v) => v.len(),
            Axis::SkuMix(v) | Axis::Env(v) | Axis::Mem(v) | Axis::Trace(v) | Axis::Tenants(v) => {
                v.len()
            }
            Axis::Seed(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Human label of the i-th value (table headers, coords).
    pub fn label(&self, i: usize) -> String {
        match self {
            Axis::Config(v) => v[i].name.clone(),
            Axis::RatePerGpu(v) | Axis::PowerW(v) | Axis::SloScale(v) | Axis::BurstFactor(v) => {
                format!("{}", v[i])
            }
            Axis::NNodes(v) | Axis::PrefillGpus(v) | Axis::Batch(v) => format!("{}", v[i]),
            Axis::Policy(v) => v[i].name().to_string(),
            Axis::SkuMix(v) | Axis::Env(v) | Axis::Mem(v) | Axis::Trace(v) | Axis::Tenants(v) => {
                v[i].clone()
            }
            Axis::Seed(v) => format!("{}", v[i]),
        }
    }
}

/// A declarative experiment: workload + SLO + base config + sweep axes.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub seed: u64,
    /// Requests per cell (mixed workloads split this across phases).
    pub requests: usize,
    /// Starting configuration; a `Config` axis replaces it per cell.
    pub base: ClusterConfig,
    pub workload: WorkloadSpec,
    /// Baseline SLO; an `SloScale` axis scales it per cell.
    pub slo: Slo,
    /// Per-GPU rate used when no `RatePerGpu` axis is declared.
    pub rate_per_gpu: f64,
    /// Long-run fraction of time bursting when a `BurstFactor` axis is
    /// active (paper-style Markov modulation).
    pub burst_frac: f64,
    /// Telemetry sampling period override (Fig 3 wants 10 ms).
    pub sample_period: Option<Micros>,
    /// Rewrite every cell's trace into multi-turn conversations:
    /// `(turns, reuse_frac)` as in [`crate::workload::make_multiturn`].
    /// A `multiturn` atom on a `Mem` axis overrides this per cell.
    pub multiturn: Option<(u32, f64)>,
    /// Trace-replay spec (`[workload.trace]`): replaces the workload's
    /// arrival process and size sampler with a deterministic diurnal
    /// curve + empirical length distributions. A `Trace` axis overrides
    /// this per cell.
    pub trace: Option<TraceSpec>,
    pub axes: Vec<Axis>,
}

#[derive(Debug)]
pub struct ScenarioError(pub String);

impl std::fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "scenario: {}", self.0)
    }
}

impl std::error::Error for ScenarioError {}

impl Scenario {
    pub fn new(name: impl Into<String>, base: ClusterConfig) -> Self {
        Scenario {
            name: name.into(),
            seed: 42,
            requests: 1200,
            base,
            workload: WorkloadSpec::LongBench,
            slo: Slo::paper_default(),
            rate_per_gpu: 1.5,
            burst_frac: 0.2,
            sample_period: None,
            multiturn: None,
            trace: None,
            axes: Vec::new(),
        }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn requests(mut self, n: usize) -> Self {
        self.requests = n;
        self
    }

    pub fn workload(mut self, w: WorkloadSpec) -> Self {
        self.workload = w;
        self
    }

    pub fn slo(mut self, slo: Slo) -> Self {
        self.slo = slo;
        self
    }

    pub fn rate(mut self, rate_per_gpu: f64) -> Self {
        self.rate_per_gpu = rate_per_gpu;
        self
    }

    pub fn sample_period(mut self, period: Micros) -> Self {
        self.sample_period = Some(period);
        self
    }

    pub fn multiturn(mut self, turns: u32, reuse_frac: f64) -> Self {
        self.multiturn = Some((turns, reuse_frac));
        self
    }

    pub fn trace(mut self, spec: TraceSpec) -> Self {
        self.trace = Some(spec);
        self
    }

    pub fn axis(mut self, axis: Axis) -> Self {
        self.axes.push(axis);
        self
    }

    /// Total grid size (product of axis lengths; 1 with no axes).
    pub fn n_cells(&self) -> usize {
        self.axes.iter().map(Axis::len).product()
    }

    /// Structural validation, run before any cell executes.
    pub fn validate(&self) -> Result<(), ScenarioError> {
        let err = |m: String| Err(ScenarioError(m));
        if self.requests == 0 {
            return err("requests must be > 0".into());
        }
        if self.rate_per_gpu <= 0.0 {
            return err(format!("rate_per_gpu {} must be > 0", self.rate_per_gpu));
        }
        let mut seen = Vec::new();
        for axis in &self.axes {
            if axis.is_empty() {
                return err(format!("axis '{}' has no values", axis.key()));
            }
            if seen.contains(&axis.key()) {
                return err(format!("duplicate axis '{}'", axis.key()));
            }
            seen.push(axis.key());
            match axis {
                Axis::RatePerGpu(v) if v.iter().any(|&r| r <= 0.0) => {
                    return err("rate_per_gpu values must be > 0".into());
                }
                Axis::Batch(v) if v.iter().any(|&b| b == 0) => {
                    return err("batch values must be >= 1".into());
                }
                _ => {}
            }
        }
        let has = |k: &str| seen.contains(&k);
        if has("burst_factor") {
            if self.workload == WorkloadSpec::MixedPhases {
                return err("burst_factor axis is not supported with the mixed workload".into());
            }
            if !(0.0..1.0).contains(&self.burst_frac) {
                return err(format!("burst_frac {} must be in [0, 1)", self.burst_frac));
            }
            if let Some(Axis::BurstFactor(v)) = self.axes.iter().find(|a| a.key() == "burst_factor")
            {
                if v.iter().any(|&f| f < 1.0) {
                    return err("burst factors must be >= 1 (1 = plain Poisson)".into());
                }
            }
        }
        if has("batch") && !self.workload.is_micro() {
            return err("batch axis only applies to microbench workloads".into());
        }
        if self.workload.is_micro() {
            const SIM_ONLY: &[&str] = &[
                "rate_per_gpu", "slo_scale", "burst_factor", "n_nodes", "sku_mix", "seed",
                "env", "mem", "trace", "tenants",
            ];
            for &k in SIM_ONLY {
                if has(k) {
                    return err(format!("{k} axis does not apply to microbench workloads"));
                }
            }
            if self.multiturn.is_some() {
                return err("multiturn does not apply to microbench workloads".into());
            }
            if self.trace.is_some() {
                return err("a trace spec does not apply to microbench workloads".into());
            }
        }
        // Trace replay owns the arrival process end to end; layering
        // Markov burst modulation on top would double-model the surges
        // the trace already encodes (flash-crowd segments).
        if (self.trace.is_some() || has("trace")) && has("burst_factor") {
            return err("a trace spec cannot be combined with a burst_factor axis".into());
        }
        if self.trace.is_some() && self.workload == WorkloadSpec::MixedPhases {
            return err("a trace spec cannot be combined with the mixed workload".into());
        }
        if let Some(spec) = &self.trace {
            spec.validate().map_err(ScenarioError)?;
        }
        if let Some((turns, reuse)) = self.multiturn {
            if turns < 2 {
                return err(format!("multiturn turns {turns} must be >= 2"));
            }
            if !(0.0..=1.0).contains(&reuse) {
                return err(format!("multiturn reuse_frac {reuse} must be in [0, 1]"));
            }
        }
        if let Some(Axis::SkuMix(mixes)) = self.axes.iter().find(|a| a.key() == "sku_mix") {
            for mix in mixes {
                crate::fleet::FleetConfig::parse_mix(mix, &[]).map_err(ScenarioError)?;
            }
        }
        if let Some(Axis::Env(profiles)) = self.axes.iter().find(|a| a.key() == "env") {
            for p in profiles {
                crate::env::EnvProfile::parse_compact(p).map_err(ScenarioError)?;
            }
        }
        if let Some(Axis::Mem(cells)) = self.axes.iter().find(|a| a.key() == "mem") {
            for c in cells {
                crate::mem::MemAxis::parse_compact(c).map_err(ScenarioError)?;
            }
        }
        if let Some(Axis::Trace(specs)) = self.axes.iter().find(|a| a.key() == "trace") {
            for s in specs {
                TraceSpec::parse_compact(s).map_err(ScenarioError)?;
            }
        }
        if let Some(Axis::Tenants(mixes)) = self.axes.iter().find(|a| a.key() == "tenants") {
            for m in mixes {
                crate::workload::tracespec::TenantClass::parse_compact(m)
                    .map_err(ScenarioError)?;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Grid expansion.
// ---------------------------------------------------------------------------

/// A fully-resolved grid point, ready to run.
#[derive(Debug, Clone)]
pub struct CellSpec {
    /// (axis key, value label) pairs in axis order.
    pub coords: Vec<(String, String)>,
    pub config: ClusterConfig,
    pub rate_per_gpu: f64,
    pub slo: Slo,
    /// `1.0` = plain Poisson arrivals.
    pub burst_factor: f64,
    /// Model power cap for microbench cells (from a `PowerW` axis).
    pub power_w: Option<f64>,
    /// Batch size for microbench cells.
    pub batch: usize,
    /// Workload seed override (from a `Seed` axis).
    pub seed: Option<u64>,
    /// Multi-turn trace transform for this cell (scenario default,
    /// overridden by a `multiturn` atom on a `Mem` axis).
    pub multiturn: Option<(u32, f64)>,
    /// Trace-replay spec for this cell (scenario default, overridden
    /// by a `Trace` axis atom; `None` = the scenario workload).
    pub trace: Option<TraceSpec>,
}

fn index_tuples(axes: &[Axis]) -> Vec<Vec<usize>> {
    let mut tuples = vec![Vec::new()];
    for axis in axes {
        let mut next = Vec::with_capacity(tuples.len() * axis.len());
        for t in &tuples {
            for i in 0..axis.len() {
                let mut t2 = t.clone();
                t2.push(i);
                next.push(t2);
            }
        }
        tuples = next;
    }
    tuples
}

fn resolve_cell(scenario: &Scenario, tuple: &[usize]) -> Result<CellSpec, ScenarioError> {
    let mut spec = CellSpec {
        coords: Vec::with_capacity(tuple.len()),
        config: scenario.base.clone(),
        rate_per_gpu: scenario.rate_per_gpu,
        slo: scenario.slo,
        burst_factor: 1.0,
        power_w: None,
        batch: 1,
        seed: None,
        multiturn: scenario.multiturn,
        trace: scenario.trace.clone(),
    };
    for (axis, &i) in scenario.axes.iter().zip(tuple) {
        spec.coords.push((axis.key().to_string(), axis.label(i)));
        match axis {
            Axis::Config(v) => spec.config = v[i].clone(),
            Axis::RatePerGpu(v) => spec.rate_per_gpu = v[i],
            Axis::PowerW(v) => {
                spec.config = presets::uniform_power(spec.config, v[i]);
                // Caps changed; keep the reported name truthful.
                spec.config.name = format!("{}@{:.0}W", spec.config.name, v[i]);
                spec.power_w = Some(v[i]);
            }
            Axis::NNodes(v) => spec.config = presets::scaled_to_nodes(spec.config, v[i]),
            Axis::Policy(v) => spec.config.control = v[i],
            Axis::SloScale(v) => spec.slo = scenario.slo.scaled(v[i]),
            Axis::BurstFactor(v) => spec.burst_factor = v[i],
            Axis::PrefillGpus(v) => {
                let p = v[i];
                if p == 0 || p >= spec.config.n_gpus {
                    return Err(ScenarioError(format!(
                        "prefill_gpus {p} must be in 1..{}",
                        spec.config.n_gpus
                    )));
                }
                spec.config.topology = Topology::Disaggregated {
                    prefill: p,
                    decode: spec.config.n_gpus - p,
                };
            }
            Axis::Batch(v) => spec.batch = v[i],
            Axis::Seed(v) => spec.seed = Some(v[i]),
            Axis::Env(v) => {
                let profile =
                    crate::env::EnvProfile::parse_compact(&v[i]).map_err(ScenarioError)?;
                if !profile.is_empty() {
                    spec.config.name = format!("{}@{}", spec.config.name, v[i]);
                }
                spec.config.env = profile;
            }
            Axis::Mem(v) => {
                let mem = crate::mem::MemAxis::parse_compact(&v[i]).map_err(ScenarioError)?;
                if let Some(gb) = mem.hbm_gb {
                    spec.config.mem = Some(crate::mem::MemConfig {
                        hbm_gb: Some(gb),
                        ..Default::default()
                    });
                }
                if let Some(mt) = mem.multiturn {
                    spec.multiturn = Some(mt);
                }
                if !mem.is_empty() {
                    spec.config.name = format!("{}@{}", spec.config.name, v[i]);
                }
            }
            Axis::Trace(v) => {
                let ts = TraceSpec::parse_compact(&v[i]).map_err(ScenarioError)?;
                if let Some(ts) = &ts {
                    ts.validate().map_err(ScenarioError)?;
                    spec.config.name = format!("{}@{}", spec.config.name, v[i]);
                }
                spec.trace = ts;
            }
            Axis::Tenants(v) => {
                let classes = crate::workload::tracespec::TenantClass::parse_compact(&v[i])
                    .map_err(ScenarioError)?;
                if !classes.is_empty() {
                    spec.config.name = format!("{}@{}", spec.config.name, v[i]);
                }
                spec.config.tenants = classes;
            }
            Axis::SkuMix(v) => {
                let fc = crate::fleet::FleetConfig::parse_mix(&v[i], &[])
                    .map_err(ScenarioError)?;
                if fc.gpus_per_node() != spec.config.n_gpus {
                    return Err(ScenarioError(format!(
                        "sku mix '{}' covers {} GPUs but the cell's config has n_gpus {}",
                        v[i],
                        fc.gpus_per_node(),
                        spec.config.n_gpus
                    )));
                }
                spec.config.name = format!("{}@{}", spec.config.name, fc.mix_label());
                spec.config.fleet = Some(fc);
            }
        }
    }
    spec.config
        .validate()
        .map_err(|e| ScenarioError(format!("cell {:?}: {e}", spec.coords)))?;
    Ok(spec)
}

// ---------------------------------------------------------------------------
// Study runner.
// ---------------------------------------------------------------------------

/// Expands a [`Scenario`]'s grid and runs every cell in parallel.
pub struct Study {
    pub scenario: Scenario,
}

impl Study {
    pub fn new(scenario: Scenario) -> Self {
        Study { scenario }
    }

    /// Expand the axis grid into fully-resolved cell specs (validated,
    /// in grid order: first axis outermost, last innermost).
    pub fn cells(&self) -> Result<Vec<CellSpec>, ScenarioError> {
        self.scenario.validate()?;
        index_tuples(&self.scenario.axes)
            .iter()
            .map(|t| resolve_cell(&self.scenario, t))
            .collect()
    }

    /// Run the study. `threads` overrides the worker count (wins over
    /// `RAPID_SWEEP_THREADS`); results are bit-identical regardless.
    ///
    /// Traces are pre-built once per unique trace fingerprint into a
    /// shared arena ([`build_trace_arena`]); cells with identical
    /// workload inputs (common along `Policy`, `Config`, `PrefillGpus`
    /// and `SkuMix` axes, which sweep the *cluster* while the workload
    /// is fixed) bump an `Arc` refcount instead of re-sampling tens of
    /// thousands of requests per cell. Bit-identical to the per-cell
    /// builds of [`Study::run_uncached`] — trace construction is a pure
    /// function of the fingerprinted inputs.
    pub fn run(&self, threads: Option<usize>) -> Result<StudyResult, ScenarioError> {
        let specs = self.cells()?;
        let arena = build_trace_arena(&self.scenario, &specs);
        let cells = parallel_map_threads(&specs, threads, |spec| {
            run_cell(&self.scenario, spec, Some(&arena))
        });
        Ok(StudyResult {
            scenario: self.scenario.clone(),
            cells,
        })
    }

    /// [`Study::run`] without the shared trace arena: every cell builds
    /// its own trace, exactly as studies ran before arenas existed.
    /// Kept as the golden reference the arena path is regression-tested
    /// against (tests prove bit-identical emitter output at 1 and 4
    /// threads).
    pub fn run_uncached(&self, threads: Option<usize>) -> Result<StudyResult, ScenarioError> {
        let specs = self.cells()?;
        let cells =
            parallel_map_threads(&specs, threads, |spec| run_cell(&self.scenario, spec, None));
        Ok(StudyResult {
            scenario: self.scenario.clone(),
            cells,
        })
    }

    /// [`Study::run`] with a completion callback: `on_done(done, total)`
    /// fires after each cell finishes, from whichever worker completed
    /// it. Drives `rapid study --progress`; results are bit-identical to
    /// [`Study::run`] (the callback only observes).
    pub fn run_with_progress<P>(
        &self,
        threads: Option<usize>,
        on_done: P,
    ) -> Result<StudyResult, ScenarioError>
    where
        P: Fn(usize, usize) + Sync,
    {
        let specs = self.cells()?;
        let arena = build_trace_arena(&self.scenario, &specs);
        let cells = parallel_map_threads_progress(
            &specs,
            threads,
            |spec| run_cell(&self.scenario, spec, Some(&arena)),
            on_done,
        );
        Ok(StudyResult {
            scenario: self.scenario.clone(),
            cells,
        })
    }

    /// Run one grid cell with the observability sink enabled (the
    /// `rapid trace` / `rapid explain` entry point). `selector` is a
    /// list of `(axis key, value label)` pairs; the first cell (grid
    /// order) whose coords match every pair wins, so an empty selector
    /// picks the grid's first cell. Microbench cells are rejected —
    /// they are analytic closed forms with no event timeline to record.
    ///
    /// The traced run is always serial (one cell) and records into a
    /// ring of [`sim::TRACE_EVENT_CAPACITY`] events; everything else
    /// matches [`Study::run`]'s per-cell setup exactly, so the returned
    /// `RunResult` differs from the untraced cell only by its `obs`
    /// report.
    pub fn run_traced(
        &self,
        selector: &[(String, String)],
    ) -> Result<(CellSpec, RunResult), ScenarioError> {
        if self.scenario.workload.is_micro() {
            return Err(ScenarioError(
                "microbench scenarios have no event timeline to trace".into(),
            ));
        }
        let specs = self.cells()?;
        let matches = |spec: &CellSpec| {
            selector.iter().all(|(k, v)| {
                spec.coords
                    .iter()
                    .any(|(ck, cv)| ck == k && cv == v)
            })
        };
        let Some(spec) = specs.iter().find(|s| matches(s)) else {
            let grid: Vec<String> = specs
                .iter()
                .map(|s| {
                    s.coords
                        .iter()
                        .map(|(k, v)| format!("{k}={v}"))
                        .collect::<Vec<_>>()
                        .join(",")
                })
                .collect();
            return Err(ScenarioError(format!(
                "no cell matches selector {:?}; grid cells: [{}]",
                selector
                    .iter()
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect::<Vec<_>>()
                    .join(","),
                grid.join(" | ")
            )));
        };
        let trace = Arc::new(build_cell_trace(&self.scenario, spec));
        let mut opts = SimOptions::default();
        if let Some(p) = self.scenario.sample_period {
            opts.sample_period = p;
        }
        opts.obs_events = sim::TRACE_EVENT_CAPACITY;
        let res = sim::run_shared(&spec.config, &trace, &opts);
        Ok((spec.clone(), res))
    }
}

/// One evaluated grid point.
#[derive(Debug, Clone)]
pub struct Cell {
    /// (axis key, value label) pairs in axis order.
    pub coords: Vec<(String, String)>,
    pub config: ClusterConfig,
    pub rate_per_gpu: f64,
    pub slo: Slo,
    pub out: CellOut,
    /// Per-cell invariant checks (completion, budget conformance).
    pub checks: Vec<ShapeCheck>,
}

#[derive(Debug, Clone)]
pub enum CellOut {
    /// Full simulation output.
    Sim(RunResult),
    /// Analytic microbench value (latency in microseconds).
    Scalar(f64),
}

impl Cell {
    pub fn result(&self) -> Option<&RunResult> {
        match &self.out {
            CellOut::Sim(r) => Some(r),
            CellOut::Scalar(_) => None,
        }
    }

    pub fn into_result(self) -> Option<RunResult> {
        match self.out {
            CellOut::Sim(r) => Some(r),
            CellOut::Scalar(_) => None,
        }
    }

    /// Headline value: attainment for sim cells, the scalar otherwise.
    pub fn value(&self) -> f64 {
        match &self.out {
            CellOut::Sim(r) => r.summary().attainment,
            CellOut::Scalar(v) => *v,
        }
    }

    // The scalar accessors read the run's sealed `Summary` (computed once
    // when the cell finished), so emitters that render several metrics
    // per cell never re-scan the record series.

    pub fn attainment(&self) -> f64 {
        self.result().map_or(0.0, |r| r.summary().attainment)
    }

    pub fn goodput_qps(&self) -> f64 {
        self.result().map_or(0.0, |r| r.summary().goodput_qps)
    }

    pub fn qps_per_kw(&self) -> f64 {
        self.result().map_or(0.0, |r| r.summary().qps_per_kw)
    }

    /// Resilience aggregates of a disturbed sim cell (`None` for
    /// microbench cells and undisturbed runs).
    pub fn resilience(&self) -> Option<crate::metrics::Resilience> {
        self.result().and_then(|r| r.summary().resilience)
    }

    /// Memory-subsystem aggregates (`None` for microbench cells and
    /// runs without an active KV capacity model).
    pub fn mem(&self) -> Option<crate::mem::MemSummary> {
        self.result().and_then(|r| r.summary().mem)
    }

    /// Per-tier tenant aggregates (`None` for microbench cells and
    /// untenanted runs).
    pub fn tenants(&self) -> Option<[crate::metrics::TierSummary; 3]> {
        self.result().and_then(|r| r.summary().tenants)
    }

    /// Observability report of a traced cell (`None` for microbench
    /// cells and for every untraced run — studies never enable the
    /// sink, so plain study output is unaffected by its existence).
    pub fn obs(&self) -> Option<&crate::obs::ObsReport> {
        self.result().and_then(|r| r.obs.as_deref())
    }

    pub fn rate_point(&self) -> RatePoint {
        RatePoint {
            qps_per_gpu: self.rate_per_gpu,
            attainment: self.attainment(),
            goodput_qps: self.goodput_qps(),
            qps_per_kw: self.qps_per_kw(),
        }
    }
}

/// Typed grid of evaluated cells, in grid order.
#[derive(Debug, Clone)]
pub struct StudyResult {
    pub scenario: Scenario,
    pub cells: Vec<Cell>,
}

impl StudyResult {
    /// (passed, total) across every cell's invariant checks.
    pub fn checks_passed(&self) -> (usize, usize) {
        let total: usize = self.cells.iter().map(|c| c.checks.len()).sum();
        let passed = self
            .cells
            .iter()
            .flat_map(|c| &c.checks)
            .filter(|c| c.pass)
            .count();
        (passed, total)
    }

    /// Cross-cell invariants the per-cell checks cannot see:
    ///
    /// * with a `SkuMix` axis, every *mixed* fleet must achieve at
    ///   least the goodput of the *worst homogeneous* fleet of equal
    ///   GPU count under the same power cap (SKU-aware reallocation
    ///   cannot lose to the all-worst fleet);
    /// * with `Env` × `Policy` axes, every dynamic policy must achieve
    ///   at least the static policy's goodput under a pure-curtailment
    ///   profile — the tentpole claim that *dynamic* reallocation is
    ///   what rides out budget disturbances;
    /// * with a `Mem` axis, every cache-enabled cell that actually hit
    ///   the prefix cache must show mean TTFT no worse than the
    ///   cache-disabled cell running the identical trace (skipped
    ///   prefill cannot slow a request down).
    pub fn study_checks(&self) -> Vec<ShapeCheck> {
        let mut checks = self.sku_mix_checks();
        checks.extend(self.env_policy_checks());
        checks.extend(self.mem_ttft_checks());
        checks
    }

    fn sku_mix_checks(&self) -> Vec<ShapeCheck> {
        let Some(mix_pos) = self.scenario.axes.iter().position(|a| a.key() == "sku_mix") else {
            return Vec::new();
        };
        let is_hetero = |cell: &Cell| {
            crate::fleet::FleetConfig::parse_mix(&cell.coords[mix_pos].1, &[])
                .map(|fc| fc.heterogeneous())
                .unwrap_or(false)
        };
        // Group by every coordinate except the mix itself.
        let mut groups: std::collections::BTreeMap<String, Vec<&Cell>> =
            std::collections::BTreeMap::new();
        for cell in &self.cells {
            let key = cell
                .coords
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != mix_pos)
                .map(|(_, (k, v))| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" ");
            groups.entry(key).or_default().push(cell);
        }
        let mut checks = Vec::new();
        for (key, cells) in groups {
            let worst_homog = cells
                .iter()
                .filter(|c| !is_hetero(c))
                .map(|c| (c.coords[mix_pos].1.clone(), c.goodput_qps()))
                .min_by(|a, b| a.1.total_cmp(&b.1));
            let Some((worst_mix, worst_goodput)) = worst_homog else { continue };
            for cell in cells.iter().filter(|c| is_hetero(c)) {
                let mix = &cell.coords[mix_pos].1;
                let goodput = cell.goodput_qps();
                let at = if key.is_empty() { String::new() } else { format!(" at {key}") };
                checks.push(ShapeCheck::new(
                    format!("mixed fleet '{mix}' >= worst homogeneous fleet{at}"),
                    goodput + 1e-9 >= worst_goodput,
                    format!("{goodput:.3} qps vs {worst_goodput:.3} qps ({worst_mix})"),
                ));
            }
        }
        checks
    }

    /// Dynamic >= static goodput under pure-curtailment profiles (see
    /// `study_checks`). Fault profiles are excluded: a failure landing
    /// on a rebalanced layout can legitimately hurt more than on a
    /// static one, so only the budget-step claim is a hard invariant.
    fn env_policy_checks(&self) -> Vec<ShapeCheck> {
        let axes = &self.scenario.axes;
        let Some(env_pos) = axes.iter().position(|a| a.key() == "env") else {
            return Vec::new();
        };
        let Some(pol_pos) = axes.iter().position(|a| a.key() == "policy") else {
            return Vec::new();
        };
        let is_pure_curtailment = |label: &str| {
            crate::env::EnvProfile::parse_compact(label)
                .map(|p| p.curtailment.is_some() && p.faults.is_none() && p.events.is_empty())
                .unwrap_or(false)
        };
        let mut groups: std::collections::BTreeMap<String, Vec<&Cell>> =
            std::collections::BTreeMap::new();
        for cell in &self.cells {
            if !is_pure_curtailment(&cell.coords[env_pos].1) {
                continue;
            }
            let key = cell
                .coords
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != pol_pos)
                .map(|(_, (k, v))| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" ");
            groups.entry(key).or_default().push(cell);
        }
        let mut checks = Vec::new();
        for (key, cells) in groups {
            let Some(static_cell) = cells.iter().find(|c| c.coords[pol_pos].1 == "static") else {
                continue;
            };
            let static_goodput = static_cell.goodput_qps();
            for cell in cells.iter().filter(|c| c.coords[pol_pos].1 != "static") {
                let policy = &cell.coords[pol_pos].1;
                let goodput = cell.goodput_qps();
                checks.push(ShapeCheck::new(
                    format!("policy '{policy}' >= static goodput under curtailment at {key}"),
                    goodput + 1e-9 >= static_goodput,
                    format!("{goodput:.3} qps vs {static_goodput:.3} qps"),
                ));
            }
        }
        checks
    }

    /// Prefix-cache TTFT win vs the cache-disabled cell (see
    /// `study_checks`). Cells are grouped by every coordinate except
    /// the mem axis; within a group the baseline is the mem-inactive
    /// cell whose `multiturn` atom matches, so both cells ran the
    /// byte-identical trace and differ only in the cache.
    fn mem_ttft_checks(&self) -> Vec<ShapeCheck> {
        let Some(mem_pos) = self.scenario.axes.iter().position(|a| a.key() == "mem") else {
            return Vec::new();
        };
        let multiturn_of = |label: &str| {
            crate::mem::MemAxis::parse_compact(label)
                .map(|a| a.multiturn)
                .unwrap_or(None)
        };
        let mean_ttft_us = |c: &Cell| -> Option<f64> {
            let r = c.result()?;
            if r.records.is_empty() {
                return None;
            }
            let sum: f64 = r.records.iter().map(|rec| rec.ttft() as f64).sum();
            Some(sum / r.records.len() as f64)
        };
        let mut groups: std::collections::BTreeMap<String, Vec<&Cell>> =
            std::collections::BTreeMap::new();
        for cell in &self.cells {
            let key = cell
                .coords
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != mem_pos)
                .map(|(_, (k, v))| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" ");
            groups.entry(key).or_default().push(cell);
        }
        let mut checks = Vec::new();
        for (key, cells) in groups {
            for &cell in &cells {
                let Some(mem) = cell.mem() else { continue };
                if mem.prefix_hits == 0 {
                    continue;
                }
                let label = &cell.coords[mem_pos].1;
                let mt = multiturn_of(label);
                let Some(&base) = cells.iter().find(|c| {
                    c.mem().is_none() && multiturn_of(&c.coords[mem_pos].1) == mt
                }) else {
                    continue;
                };
                let (Some(hit), Some(off)) = (mean_ttft_us(cell), mean_ttft_us(base)) else {
                    continue;
                };
                let at = if key.is_empty() { String::new() } else { format!(" at {key}") };
                checks.push(ShapeCheck::new(
                    format!(
                        "prefix cache '{label}' mean TTFT <= cache-off '{}'{at}",
                        base.coords[mem_pos].1
                    ),
                    hit <= off + 1e-9,
                    format!(
                        "{:.1} ms vs {:.1} ms ({} hits, {:.0}% hit rate)",
                        hit / 1000.0,
                        off / 1000.0,
                        mem.prefix_hits,
                        mem.hit_rate * 100.0
                    ),
                ));
            }
        }
        checks
    }

    /// View a `[Config, RatePerGpu]` study as per-config rate curves
    /// (the shape most figures plot).
    pub fn rate_curves(&self) -> Vec<(ClusterConfig, Vec<RatePoint>)> {
        let [Axis::Config(cfgs), Axis::RatePerGpu(rates)] = &self.scenario.axes[..] else {
            panic!("rate_curves() needs exactly [Config, RatePerGpu] axes");
        };
        let nr = rates.len();
        cfgs.iter()
            .enumerate()
            .map(|(ci, cfg)| {
                let pts = self.cells[ci * nr..(ci + 1) * nr]
                    .iter()
                    .map(Cell::rate_point)
                    .collect();
                (cfg.clone(), pts)
            })
            .collect()
    }
}

fn build_workload_trace(scenario: &Scenario, spec: &CellSpec, seed: u64, node_qps: f64) -> Trace {
    match &scenario.workload {
        WorkloadSpec::LongBench => longbench_trace_bursty(
            seed,
            node_qps,
            scenario.requests,
            spec.slo,
            spec.burst_factor,
            scenario.burst_frac,
        ),
        WorkloadSpec::Sonnet {
            input_tokens,
            output_tokens,
        } => sonnet_trace(
            seed,
            node_qps,
            scenario.requests,
            spec.slo,
            *input_tokens,
            *output_tokens,
            spec.burst_factor,
            scenario.burst_frac,
        ),
        WorkloadSpec::MixedPhases => mixed_phases_trace(seed, scenario.requests, node_qps),
        WorkloadSpec::PrefillMicrobench { .. } | WorkloadSpec::DecodeMicrobench { .. } => {
            unreachable!("microbench cells do not build traces")
        }
    }
}

fn build_cell_trace(scenario: &Scenario, spec: &CellSpec) -> Trace {
    let node_qps = spec.rate_per_gpu * spec.config.total_gpus() as f64;
    let seed = spec.seed.unwrap_or(scenario.seed);
    // Trace replay owns arrivals and sizes; the scenario workload only
    // contributes the rate anchor and request count.
    let mut trace = match &spec.trace {
        Some(ts) => ts.build(seed, node_qps, scenario.requests, spec.slo),
        None => build_workload_trace(scenario, spec, seed, node_qps),
    };
    if let Some((turns, reuse)) = spec.multiturn {
        crate::workload::make_multiturn(&mut trace, turns, reuse);
    }
    if !spec.config.tenants.is_empty() {
        assign_tenants(&mut trace, &spec.config.tenants, seed);
    }
    trace
}

/// Shared immutable traces, keyed by [`trace_fingerprint`]. Built once
/// per study ([`build_trace_arena`]); cells borrow via `Arc` bumps.
pub type TraceArena = HashMap<String, Arc<Trace>>;

/// Canonical key of every input `build_cell_trace` consumes: workload
/// shape, trace-replay spec, seed, node-level rate, request count, SLO,
/// burst modulation, multi-turn rewrite and tenant mix. Two cells with
/// equal fingerprints build byte-identical traces (construction is a
/// pure function of these inputs), so the arena may hand both the same
/// `Arc<Trace>`. Direct `f64` inputs are keyed by `to_bits` so distinct
/// bit patterns never alias; nested floats ride on `Debug`'s exact
/// shortest-round-trip formatting.
fn trace_fingerprint(scenario: &Scenario, spec: &CellSpec) -> String {
    let node_qps = spec.rate_per_gpu * spec.config.total_gpus() as f64;
    let seed = spec.seed.unwrap_or(scenario.seed);
    format!(
        "w={:?}|t={:?}|seed={seed}|qps={:016x}|n={}|slo={:?}|bf={:016x}|bfr={:016x}|mt={:?}|ten={:?}",
        scenario.workload,
        spec.trace,
        node_qps.to_bits(),
        scenario.requests,
        spec.slo,
        spec.burst_factor.to_bits(),
        scenario.burst_frac.to_bits(),
        spec.multiturn,
        spec.config.tenants,
    )
}

/// Pre-build each unique trace exactly once, serially, in grid order.
/// Microbench scenarios build nothing (their cells are analytic). The
/// serial build keeps the arena deterministic and contention-free; the
/// parallel fan-out then only reads it.
fn build_trace_arena(scenario: &Scenario, specs: &[CellSpec]) -> TraceArena {
    let mut arena = TraceArena::new();
    if scenario.workload.is_micro() {
        return arena;
    }
    for spec in specs {
        let key = trace_fingerprint(scenario, spec);
        arena
            .entry(key)
            .or_insert_with(|| Arc::new(build_cell_trace(scenario, spec)));
    }
    arena
}

fn cell_checks(config: &ClusterConfig, n_requests: usize, res: &RunResult) -> Vec<ShapeCheck> {
    let summary = res.summary();
    let mut checks = vec![
        ShapeCheck::new(
            "all requests completed or accounted",
            res.records.len() == n_requests,
            format!("{}/{n_requests} records", res.records.len()),
        ),
        ShapeCheck::new(
            "attainment within [0, 1]",
            (0.0..=1.0).contains(&summary.attainment),
            format!("{:.4}", summary.attainment),
        ),
    ];
    if config.enforce_budget {
        let budget = config.cluster_budget();
        checks.push(ShapeCheck::new(
            "provisioned power within cluster budget",
            res.mean_provisioned_w <= budget + 1e-6,
            format!("{:.0} W <= {:.0} W", res.mean_provisioned_w, budget),
        ));
    }
    if config.enforce_budget && !res.env_events.is_empty() {
        // Time-varying budgets need the stronger instantaneous form:
        // at every cap-trace point the summed targets must fit the
        // budget in force at that instant (budget steps land before
        // same-time samples, so the walk below is exact).
        let mut budget = config.cluster_budget();
        let mut steps = res.budget_trace.iter().peekable();
        let mut ok = true;
        let mut worst = 0.0f64;
        for (t, caps) in &res.cap_trace {
            while let Some(&&(st, b)) = steps.peek() {
                if st <= *t {
                    budget = b;
                    steps.next();
                } else {
                    break;
                }
            }
            let sum: f64 = caps.iter().sum();
            if sum > budget + 1e-6 {
                ok = false;
                worst = worst.max(sum - budget);
            }
        }
        checks.push(ShapeCheck::new(
            "allocated power within instantaneous budget",
            ok,
            if ok {
                format!("{} cap points checked", res.cap_trace.len())
            } else {
                format!("worst overage {worst:.1} W")
            },
        ));
    }
    if let Some(mem) = res.mem {
        // The pool invariant, checked at every telemetry sample rather
        // than only at the end: resident KV never exceeds HBM capacity.
        let worst = res
            .mem_trace
            .iter()
            .map(|&(_, occ)| occ)
            .fold(0.0f64, f64::max);
        checks.push(ShapeCheck::new(
            "resident KV within HBM capacity at every sample",
            worst <= 1.0 + 1e-9,
            format!(
                "peak occupancy {:.3} over {} samples ({} evictions)",
                mem.peak_occupancy,
                res.mem_trace.len(),
                mem.evictions
            ),
        ));
    }
    if let Some(tiers) = summary.tenants {
        use crate::workload::tracespec::{TIER_BATCH, TIER_INTERACTIVE};
        let shed: u64 = tiers.iter().map(|t| t.shed).sum();
        let preempted: u64 = tiers.iter().map(|t| t.preempted).sum();
        let inter = tiers[TIER_INTERACTIVE as usize];
        let batch = tiers[TIER_BATCH as usize];
        // The tier ordering only binds when prioritization actually
        // fired (shed or preempted work) and both tiers saw traffic;
        // an unloaded run attains ~1.0 everywhere and proves nothing.
        if shed + preempted > 0 && inter.requests > 0 && batch.requests > 0 {
            checks.push(ShapeCheck::new(
                "interactive attainment >= batch attainment under overload",
                inter.attainment + 1e-9 >= batch.attainment,
                format!(
                    "{:.4} vs {:.4} ({shed} shed, {preempted} preempted)",
                    inter.attainment, batch.attainment
                ),
            ));
        }
    }
    checks
}

fn run_cell(scenario: &Scenario, spec: &CellSpec, arena: Option<&TraceArena>) -> Cell {
    let (out, checks) = match &scenario.workload {
        WorkloadSpec::PrefillMicrobench { input_tokens } => {
            let model = PowerModel::new(spec.config.perf.clone());
            let w = spec.power_w.unwrap_or(spec.config.prefill_cap_w);
            let t = model.prefill_batch_time(input_tokens * spec.batch as u32, w);
            (CellOut::Scalar(t as f64), Vec::new())
        }
        WorkloadSpec::DecodeMicrobench { context_tokens } => {
            let model = PowerModel::new(spec.config.perf.clone());
            let w = spec.power_w.unwrap_or(spec.config.decode_cap_w);
            let t = model.decode_step_time(spec.batch, *context_tokens, w);
            (CellOut::Scalar(t as f64), Vec::new())
        }
        _ => {
            // Arena hit: an Arc bump instead of rebuilding (and then
            // deep-copying into the cluster) the whole request list.
            let trace: Arc<Trace> = match arena.and_then(|a| a.get(&trace_fingerprint(scenario, spec))) {
                Some(t) => Arc::clone(t),
                None => Arc::new(build_cell_trace(scenario, spec)),
            };
            let n_requests = trace.len();
            let mut opts = SimOptions::default();
            if let Some(p) = scenario.sample_period {
                opts.sample_period = p;
            }
            let res = sim::run_shared(&spec.config, &trace, &opts);
            let checks = cell_checks(&spec.config, n_requests, &res);
            (CellOut::Sim(res), checks)
        }
    };
    Cell {
        coords: spec.coords.clone(),
        config: spec.config.clone(),
        rate_per_gpu: spec.rate_per_gpu,
        slo: spec.slo,
        out,
        checks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{MILLIS, SECOND};

    fn pt(q: f64, a: f64) -> RatePoint {
        RatePoint {
            qps_per_gpu: q,
            attainment: a,
            goodput_qps: 0.0,
            qps_per_kw: 0.0,
        }
    }

    #[test]
    fn sustainable_rate_picks_last_above_threshold() {
        let pts = vec![pt(0.5, 0.99), pt(1.0, 0.92), pt(1.5, 0.70), pt(2.0, 0.30)];
        assert_eq!(sustainable_rate(&pts, 0.8), 1.0);
        assert_eq!(sustainable_rate(&pts, 0.95), 0.5);
        assert_eq!(sustainable_rate(&pts, 0.2), 2.0);
    }

    #[test]
    fn crossing_rate_interpolates() {
        let pts = vec![pt(1.0, 0.9), pt(2.0, 0.7)];
        let x = crossing_rate(&pts, 0.8);
        assert!((x - 1.5).abs() < 1e-9, "x={x}");
    }

    #[test]
    fn longbench_trace_matches_rate() {
        let t = longbench_trace(1, 12.0, 600, Slo::paper_default());
        assert_eq!(t.len(), 600);
        assert!((t.offered_qps() / 12.0 - 1.0).abs() < 0.2);
    }

    #[test]
    fn grid_expands_last_axis_innermost() {
        let s = Scenario::new("t", presets::p4d4(600.0))
            .axis(Axis::PowerW(vec![500.0, 600.0]))
            .axis(Axis::RatePerGpu(vec![0.5, 1.0, 1.5]));
        let cells = Study::new(s).cells().unwrap();
        assert_eq!(cells.len(), 6);
        // power outermost, rate innermost
        assert_eq!(cells[0].coords[0].1, "500");
        assert_eq!(cells[0].coords[1].1, "0.5");
        assert_eq!(cells[1].coords[1].1, "1");
        assert_eq!(cells[3].coords[0].1, "600");
        assert_eq!(cells[3].coords[1].1, "0.5");
        // power axis reparametrizes the config like presets::p4d4(w),
        // and the reported name tracks the override
        assert_eq!(cells[0].config.prefill_cap_w, 500.0);
        assert_eq!(cells[0].config.node_budget_w, 4000.0);
        assert_eq!(cells[0].config.name, "4P4D-600W@500W");
    }

    #[test]
    fn no_axes_is_one_base_cell() {
        let s = Scenario::new("t", presets::p4d4(600.0));
        assert_eq!(s.n_cells(), 1);
        let cells = Study::new(s).cells().unwrap();
        assert_eq!(cells.len(), 1);
        assert!(cells[0].coords.is_empty());
    }

    #[test]
    fn axis_overrides_apply_in_order() {
        let s = Scenario::new("t", presets::p4d4(600.0))
            .axis(Axis::Policy(vec![ControlPolicy::DynPowerGpu]))
            .axis(Axis::PrefillGpus(vec![6]))
            .axis(Axis::SloScale(vec![0.5]));
        let cells = Study::new(s).cells().unwrap();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.config.control, ControlPolicy::DynPowerGpu);
        assert_eq!(
            c.config.topology,
            Topology::Disaggregated {
                prefill: 6,
                decode: 2
            }
        );
        assert_eq!(c.slo.ttft, SECOND / 2);
        assert_eq!(c.slo.tpot, 20 * MILLIS);
    }

    #[test]
    fn invalid_scenarios_rejected() {
        let dup = Scenario::new("t", presets::p4d4(600.0))
            .axis(Axis::RatePerGpu(vec![1.0]))
            .axis(Axis::RatePerGpu(vec![2.0]));
        assert!(dup.validate().is_err());
        let burst_mixed = Scenario::new("t", presets::p4d4(600.0))
            .workload(WorkloadSpec::MixedPhases)
            .axis(Axis::BurstFactor(vec![4.0]));
        assert!(burst_mixed.validate().is_err());
        let batch_sim =
            Scenario::new("t", presets::p4d4(600.0)).axis(Axis::Batch(vec![1, 2]));
        assert!(batch_sim.validate().is_err());
        let empty_axis =
            Scenario::new("t", presets::p4d4(600.0)).axis(Axis::RatePerGpu(Vec::new()));
        assert!(empty_axis.validate().is_err());
        let zero_rate =
            Scenario::new("t", presets::p4d4(600.0)).axis(Axis::RatePerGpu(vec![0.5, 0.0]));
        assert!(zero_rate.validate().is_err());
        let bad_split =
            Scenario::new("t", presets::p4d4(600.0)).axis(Axis::PrefillGpus(vec![8]));
        assert!(Study::new(bad_split).cells().is_err());
    }

    #[test]
    fn microbench_cells_match_direct_model_calls() {
        let s = Scenario::new("fig4a", presets::p4d4(600.0))
            .workload(WorkloadSpec::PrefillMicrobench { input_tokens: 4096 })
            .axis(Axis::Batch(vec![1, 2]))
            .axis(Axis::PowerW(vec![400.0, 750.0]));
        let study = Study::new(s).run(Some(1)).unwrap();
        assert_eq!(study.cells.len(), 4);
        let model = PowerModel::new(crate::config::PerfModelConfig::default());
        for (cell, (b, w)) in study
            .cells
            .iter()
            .zip([(1u32, 400.0), (1, 750.0), (2, 400.0), (2, 750.0)])
        {
            let expect = model.prefill_batch_time(4096 * b, w) as f64;
            assert_eq!(cell.value(), expect);
            assert!(cell.result().is_none());
        }
    }

    #[test]
    fn study_results_bit_identical_across_thread_counts() {
        let s = Scenario::new("t", presets::p4d4(600.0))
            .requests(60)
            .seed(7)
            .axis(Axis::RatePerGpu(vec![0.5, 1.0]));
        let serial = Study::new(s.clone()).run(Some(1)).unwrap();
        let par = Study::new(s).run(Some(4)).unwrap();
        for (a, b) in serial.cells.iter().zip(&par.cells) {
            assert_eq!(a.rate_per_gpu, b.rate_per_gpu);
            assert_eq!(a.attainment(), b.attainment());
            assert_eq!(a.goodput_qps(), b.goodput_qps());
        }
    }

    #[test]
    fn rate_curves_group_by_config() {
        let configs = vec![presets::p4d4(600.0), presets::p5d3_600()];
        let rates = vec![0.5, 1.0, 1.5];
        let s = Scenario::new("t", presets::p4d4(600.0))
            .requests(40)
            .seed(3)
            .axis(Axis::Config(configs))
            .axis(Axis::RatePerGpu(rates.clone()));
        let curves = Study::new(s).run(None).unwrap().rate_curves();
        assert_eq!(curves.len(), 2);
        for (_, pts) in &curves {
            assert_eq!(pts.len(), rates.len());
            for (p, &r) in pts.iter().zip(rates.iter()) {
                assert_eq!(p.qps_per_gpu, r);
            }
        }
    }

    #[test]
    fn sim_cells_carry_invariant_checks() {
        let s = Scenario::new("t", presets::p4d4(600.0)).requests(40).seed(5);
        let study = Study::new(s).run(Some(1)).unwrap();
        let cell = &study.cells[0];
        assert!(!cell.checks.is_empty());
        assert!(cell.checks.iter().all(|c| c.pass), "{:?}", cell.checks);
        let (passed, total) = study.checks_passed();
        assert_eq!(passed, total);
    }

    #[test]
    fn sku_mix_axis_sets_fleet_and_name() {
        let s = Scenario::new("t", presets::rapid_600())
            .axis(Axis::SkuMix(vec!["mi300x:8".into(), "mi300x:4+a100:4".into()]));
        let cells = Study::new(s).cells().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(!cells[0].config.fleet.as_ref().unwrap().heterogeneous());
        let mixed = cells[1].config.fleet.as_ref().unwrap();
        assert!(mixed.heterogeneous());
        assert_eq!(mixed.gpus_per_node(), 8);
        assert!(cells[1].config.name.ends_with("@mi300x:4+a100:4"));
        assert_eq!(cells[1].coords[0], ("sku_mix".to_string(), "mi300x:4+a100:4".to_string()));
        // Mixes must cover the config's n_gpus exactly.
        let bad = Scenario::new("t", presets::rapid_600())
            .axis(Axis::SkuMix(vec!["mi300x:4".into()]));
        assert!(Study::new(bad).cells().is_err());
        // Unknown SKUs are rejected at validation time.
        let unknown = Scenario::new("t", presets::rapid_600())
            .axis(Axis::SkuMix(vec!["warp9:8".into()]));
        assert!(unknown.validate().is_err());
        // Microbench workloads reject the axis.
        let micro = Scenario::new("t", presets::p4d4(600.0))
            .workload(WorkloadSpec::PrefillMicrobench { input_tokens: 1024 })
            .axis(Axis::SkuMix(vec!["mi300x:8".into()]));
        assert!(micro.validate().is_err());
    }

    #[test]
    fn study_checks_compare_mixed_to_worst_homogeneous() {
        let s = Scenario::new("t", presets::p4d4(600.0))
            .requests(60)
            .seed(11)
            .axis(Axis::SkuMix(vec![
                "mi300x:8".into(),
                "a100:8".into(),
                "mi300x:4+a100:4".into(),
            ]));
        let study = Study::new(s).run(Some(1)).unwrap();
        let checks = study.study_checks();
        assert_eq!(checks.len(), 1, "one mixed cell, one group");
        assert!(checks[0].what.contains("mi300x:4+a100:4"), "{}", checks[0].what);
        assert!(checks[0].pass, "{}: {}", checks[0].what, checks[0].detail);
        // No SkuMix axis -> no study checks.
        let plain = Scenario::new("t", presets::p4d4(600.0)).requests(20);
        assert!(Study::new(plain).run(Some(1)).unwrap().study_checks().is_empty());
    }

    #[test]
    fn seed_axis_replicates_cells_without_aggregation() {
        let s = Scenario::new("t", presets::p4d4(600.0))
            .requests(40)
            .axis(Axis::Seed(vec![1, 2]))
            .axis(Axis::RatePerGpu(vec![1.0]));
        let study = Study::new(s.clone()).run(Some(1)).unwrap();
        assert_eq!(study.cells.len(), 2);
        assert_eq!(study.cells[0].coords[0], ("seed".to_string(), "1".to_string()));
        assert_eq!(study.cells[1].coords[0], ("seed".to_string(), "2".to_string()));
        // Different seeds build different traces...
        let a0 = study.cells[0].result().unwrap().records[0].arrival;
        let a1 = study.cells[1].result().unwrap().records[0].arrival;
        assert_ne!(a0, a1, "seed must change the workload");
        // ...and the same grid re-runs bit-identically (per-seed cells
        // are plain cells: no aggregation anywhere).
        let again = Study::new(s).run(Some(2)).unwrap();
        for (x, y) in study.cells.iter().zip(&again.cells) {
            assert_eq!(x.goodput_qps(), y.goodput_qps());
        }
        // Seed axis is meaningless for analytic microbenches.
        let micro = Scenario::new("t", presets::p4d4(600.0))
            .workload(WorkloadSpec::PrefillMicrobench { input_tokens: 1024 })
            .axis(Axis::Seed(vec![1]));
        assert!(micro.validate().is_err());
    }

    #[test]
    fn env_axis_sets_profile_and_name() {
        let s = Scenario::new("t", presets::rapid_600())
            .axis(Axis::Env(vec!["none".into(), "curtail:30:0.5:0.75:10".into()]));
        let cells = Study::new(s).cells().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells[0].config.env.is_empty());
        assert_eq!(cells[0].config.name, "DynGPU-DynPower", "'none' keeps the name");
        assert!(cells[1].config.env.curtailment.is_some());
        assert!(cells[1].config.name.ends_with("@curtail:30:0.5:0.75:10"));
        assert_eq!(cells[1].coords[0].0, "env");
        // Bad atoms fail at validation time, before any cell runs.
        let bad = Scenario::new("t", presets::rapid_600()).axis(Axis::Env(vec!["warp:9".into()]));
        assert!(bad.validate().is_err());
        // Structurally-infeasible profiles fail at cell resolution.
        let deep = Scenario::new("t", presets::rapid_600())
            .axis(Axis::Env(vec!["curtail:30:0.5:0.5".into()]));
        assert!(Study::new(deep).cells().is_err(), "curtailed below the cap floor");
    }

    #[test]
    fn disturbed_cells_carry_resilience_and_budget_checks() {
        let s = Scenario::new("t", presets::rapid_600())
            .requests(60)
            .seed(5)
            .axis(Axis::Env(vec!["cap:2:4000".into()]));
        let study = Study::new(s).run(Some(1)).unwrap();
        let cell = &study.cells[0];
        let res = cell.result().unwrap();
        assert!(!res.env_events.is_empty(), "the cap step must fire");
        assert!(res.resilience.is_some());
        assert!(
            cell.checks
                .iter()
                .any(|c| c.what.contains("instantaneous budget")),
            "{:?}",
            cell.checks
        );
        assert!(cell.checks.iter().all(|c| c.pass), "{:?}", cell.checks);
    }

    #[test]
    fn mem_axis_sets_capacity_and_multiturn() {
        let s = Scenario::new("t", presets::p4d4(600.0))
            .axis(Axis::Mem(vec!["none".into(), "multiturn:4:0.6+hbm:32".into()]));
        let cells = Study::new(s).cells().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells[0].config.mem.is_none(), "'none' stays inactive");
        assert!(cells[0].multiturn.is_none());
        assert_eq!(cells[0].config.name, "4P4D-600W", "'none' keeps the name");
        let c = &cells[1];
        assert_eq!(c.config.mem.as_ref().unwrap().hbm_gb, Some(32.0));
        assert_eq!(c.multiturn, Some((4, 0.6)));
        assert!(c.config.name.ends_with("@multiturn:4:0.6+hbm:32"));
        assert_eq!(c.coords[0].0, "mem");
        // Bad atoms fail at validation time, before any cell runs.
        let bad = Scenario::new("t", presets::p4d4(600.0)).axis(Axis::Mem(vec!["hbm:0".into()]));
        assert!(bad.validate().is_err());
        // Microbench workloads reject the axis and the transform.
        let micro = Scenario::new("t", presets::p4d4(600.0))
            .workload(WorkloadSpec::PrefillMicrobench { input_tokens: 1024 })
            .axis(Axis::Mem(vec!["hbm:16".into()]));
        assert!(micro.validate().is_err());
        let micro_mt = Scenario::new("t", presets::p4d4(600.0))
            .workload(WorkloadSpec::PrefillMicrobench { input_tokens: 1024 })
            .multiturn(4, 0.5);
        assert!(micro_mt.validate().is_err());
        // Scenario-level multiturn values are validated too.
        assert!(Scenario::new("t", presets::p4d4(600.0)).multiturn(1, 0.5).validate().is_err());
        assert!(Scenario::new("t", presets::p4d4(600.0)).multiturn(4, 1.5).validate().is_err());
    }

    #[test]
    fn mem_cells_carry_occupancy_check_and_prefix_traffic() {
        let s = Scenario::new("t", presets::p4d4(600.0))
            .requests(80)
            .seed(9)
            .axis(Axis::Mem(vec![
                "multiturn:4:0.6".into(),
                "multiturn:4:0.6+hbm:64".into(),
            ]));
        let study = Study::new(s).run(Some(1)).unwrap();
        // Cache-off cell: identical trace, no mem summary, no mem check.
        assert!(study.cells[0].mem().is_none());
        assert!(!study.cells[0]
            .checks
            .iter()
            .any(|c| c.what.contains("HBM capacity")));
        // Cache-on cell: summary, per-sample occupancy check, lookups.
        let mem = study.cells[1].mem().expect("mem active");
        assert!(mem.prefix_lookups > 0, "multi-turn arrivals must look up");
        assert!(study.cells[1]
            .checks
            .iter()
            .any(|c| c.what.contains("HBM capacity")));
        assert!(
            study.cells[1].checks.iter().all(|c| c.pass),
            "{:?}",
            study.cells[1].checks
        );
        // With any hits the study-level TTFT comparison must pass (the
        // cache can only skip prefill work, never add it).
        if mem.prefix_hits > 0 {
            let checks = study.study_checks();
            assert!(checks.iter().any(|c| c.what.contains("prefix cache")));
            assert!(checks.iter().all(|c| c.pass), "{checks:?}");
        }
    }

    #[test]
    fn trace_axis_replaces_arrivals_and_names_the_cell() {
        let s = Scenario::new("t", presets::p4d4(600.0))
            .requests(40)
            .seed(7)
            .axis(Axis::Trace(vec!["none".into(), "synth-8192x256".into()]));
        let cells = Study::new(s.clone()).cells().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells[0].trace.is_none(), "'none' keeps the workload");
        assert_eq!(cells[0].config.name, "4P4D-600W");
        let ts = cells[1].trace.as_ref().unwrap();
        assert_eq!(ts.preset, "synth-8192x256");
        assert!(cells[1].config.name.ends_with("@synth-8192x256"));
        // Replayed cells really run a different arrival sequence.
        let study = Study::new(s).run(Some(1)).unwrap();
        let a0 = study.cells[0].result().unwrap().records[0].arrival;
        let a1 = study.cells[1].result().unwrap().records[0].arrival;
        assert_ne!(a0, a1, "trace replay must change the workload");
        // Bad atoms fail at validation time; trace x burst_factor and
        // microbench workloads are rejected structurally.
        let bad = Scenario::new("t", presets::p4d4(600.0))
            .axis(Axis::Trace(vec!["warp-drive".into()]));
        assert!(bad.validate().is_err());
        let burst = Scenario::new("t", presets::p4d4(600.0))
            .axis(Axis::Trace(vec!["mt-4400x1200".into()]))
            .axis(Axis::BurstFactor(vec![4.0]));
        assert!(burst.validate().is_err());
        let micro = Scenario::new("t", presets::p4d4(600.0))
            .workload(WorkloadSpec::PrefillMicrobench { input_tokens: 1024 })
            .axis(Axis::Trace(vec!["mt-4400x1200".into()]));
        assert!(micro.validate().is_err());
    }

    #[test]
    fn tenants_axis_tags_requests_and_summarizes_tiers() {
        let mix = "chat:0.5:interactive+api:0.3:standard+jobs:0.2:batch:4";
        let s = Scenario::new("t", presets::p4d4(600.0))
            .requests(60)
            .seed(9)
            .axis(Axis::Tenants(vec!["none".into(), mix.into()]));
        let study = Study::new(s).run(Some(1)).unwrap();
        assert_eq!(study.cells.len(), 2);
        // Untenanted cell: no tenants in config, no per-tier summary.
        assert!(study.cells[0].config.tenants.is_empty());
        assert!(study.cells[0].tenants().is_none());
        assert_eq!(study.cells[0].config.name, "4P4D-600W");
        // Tenant cell: classes applied (name-sorted), requests tagged,
        // per-tier summary conserves the request count.
        let c = &study.cells[1];
        assert_eq!(c.config.tenants.len(), 3);
        assert!(c.config.name.ends_with(mix));
        let tiers = c.tenants().expect("multi-tenant summary");
        let total: u64 = tiers.iter().map(|t| t.requests).sum();
        assert_eq!(total, 60);
        let res = c.result().unwrap();
        assert!(res.records.iter().any(|r| r.tenant > 0));
        // Bad atoms fail at validation time.
        let bad = Scenario::new("t", presets::p4d4(600.0))
            .axis(Axis::Tenants(vec!["chat:0.4:interactive".into()]));
        assert!(bad.validate().is_err(), "shares must sum to 1");
    }

    #[test]
    fn trace_arena_shares_equal_workloads_and_splits_distinct_ones() {
        // Policy axis sweeps the cluster, not the workload: one arena
        // entry feeds both cells. A rate axis changes node_qps: two
        // entries.
        let pol = Scenario::new("t", presets::p4d4(600.0))
            .requests(30)
            .axis(Axis::Policy(vec![ControlPolicy::Static, ControlPolicy::DynPowerGpu]));
        let specs = Study::new(pol.clone()).cells().unwrap();
        let arena = build_trace_arena(&pol, &specs);
        assert_eq!(arena.len(), 1, "same workload -> one shared trace");
        let rates = Scenario::new("t", presets::p4d4(600.0))
            .requests(30)
            .axis(Axis::RatePerGpu(vec![0.5, 1.0]));
        let specs = Study::new(rates.clone()).cells().unwrap();
        let arena = build_trace_arena(&rates, &specs);
        assert_eq!(arena.len(), 2, "distinct rates -> distinct traces");
        // Arena entries are exactly what the per-cell builder makes.
        for spec in &specs {
            let shared = &arena[&trace_fingerprint(&rates, spec)];
            let fresh = build_cell_trace(&rates, spec);
            assert_eq!(shared.requests, fresh.requests);
        }
        // Microbench scenarios build nothing.
        let micro = Scenario::new("t", presets::p4d4(600.0))
            .workload(WorkloadSpec::PrefillMicrobench { input_tokens: 1024 })
            .axis(Axis::Batch(vec![1, 2]));
        let specs = Study::new(micro.clone()).cells().unwrap();
        assert!(build_trace_arena(&micro, &specs).is_empty());
    }

    #[test]
    fn arena_backed_study_matches_uncached_reference() {
        // The tentpole equivalence at unit scale (the full golden suite
        // lives in tests/storage_golden.rs): shared-arena cells must be
        // bit-identical to per-cell trace builds at both thread counts.
        let s = Scenario::new("t", presets::p4d4(600.0))
            .requests(40)
            .seed(7)
            .axis(Axis::Policy(vec![ControlPolicy::Static, ControlPolicy::DynPowerGpu]))
            .axis(Axis::RatePerGpu(vec![1.0, 2.0]));
        let cached = Study::new(s.clone()).run(Some(1)).unwrap();
        let uncached = Study::new(s.clone()).run_uncached(Some(1)).unwrap();
        let par = Study::new(s).run(Some(4)).unwrap();
        for (a, b) in cached.cells.iter().zip(&uncached.cells) {
            let (ra, rb) = (a.result().unwrap(), b.result().unwrap());
            assert_eq!(ra.records.len(), rb.records.len());
            for (x, y) in ra.records.iter().zip(&rb.records) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.finish, y.finish);
            }
            assert_eq!(a.goodput_qps(), b.goodput_qps());
        }
        for (a, c) in cached.cells.iter().zip(&par.cells) {
            assert_eq!(a.goodput_qps(), c.goodput_qps());
            assert_eq!(a.attainment(), c.attainment());
        }
    }

    #[test]
    fn bursty_axis_changes_the_trace_but_not_the_grid() {
        let s = Scenario::new("t", presets::p4d4(600.0))
            .requests(50)
            .axis(Axis::BurstFactor(vec![1.0, 4.0]));
        let cells = Study::new(s).cells().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].burst_factor, 1.0);
        assert_eq!(cells[1].burst_factor, 4.0);
    }
}
