//! Machine-readable bench reports with a stable JSON schema.
//!
//! Schema (version 1) — every field below is load-bearing for the CI
//! regression gate, so additions are fine but renames/removals bump
//! [`SCHEMA_VERSION`]:
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "suite": "hotpath",
//!   "entries": [
//!     {"name": "router/pick_prefill_8", "iters": 12000, "batch": 1,
//!      "mean_us": 0.4, "p50_us": 0.4, "p99_us": 0.7,
//!      "min_us": 0.3, "max_us": 1.2, "per_sec": 2500000.0}
//!   ],
//!   "meta": {"free-form": "string key/values"}
//! }
//! ```
//!
//! `per_sec` is derived (`batch / mean`) and ignored on load. A baseline
//! entry whose times are `0` (or non-finite) means "not yet recorded" —
//! comparisons skip it instead of failing, which is how the committed
//! bootstrap baseline stays advisory until CI records real numbers.
//! Comparisons gate on the batch-normalized median (`p50_us / batch`),
//! not the mean — see [`Comparison`].

use std::collections::BTreeMap;

use super::Timing;
use crate::util::json::Json;

/// Bump on any backwards-incompatible change to the report shape.
pub const SCHEMA_VERSION: u64 = 1;

/// A named collection of [`Timing`]s plus free-form string metadata.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BenchReport {
    pub suite: String,
    pub entries: Vec<Timing>,
    pub meta: BTreeMap<String, String>,
}

/// One current-vs-baseline pairing from [`BenchReport::compare`].
/// Times are batch-normalized medians ([`Timing::per_item_p50_us`]):
/// median so a single noisy CI iteration cannot fake a regression, and
/// per-item so whole-sim runs at different request counts (different
/// `batch`) remain comparable.
#[derive(Debug, Clone, PartialEq)]
pub struct Comparison {
    pub name: String,
    pub baseline_us: f64,
    pub current_us: f64,
    /// Positive = slower than baseline, in percent of the baseline time.
    pub delta_pct: f64,
}

impl Comparison {
    pub fn regressed(&self, max_regress_pct: f64) -> bool {
        self.delta_pct > max_regress_pct
    }
}

impl BenchReport {
    pub fn new(suite: &str) -> BenchReport {
        BenchReport {
            suite: suite.to_string(),
            entries: Vec::new(),
            meta: BTreeMap::new(),
        }
    }

    pub fn entry(&self, name: &str) -> Option<&Timing> {
        self.entries.iter().find(|t| t.name == name)
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("schema_version".into(), Json::Num(SCHEMA_VERSION as f64));
        obj.insert("suite".into(), Json::Str(self.suite.clone()));
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|t| {
                let mut e = BTreeMap::new();
                e.insert("name".into(), Json::Str(t.name.clone()));
                e.insert("iters".into(), Json::Num(t.iters as f64));
                e.insert("batch".into(), Json::Num(t.batch as f64));
                e.insert("mean_us".into(), Json::Num(t.mean_us));
                e.insert("p50_us".into(), Json::Num(t.p50_us));
                e.insert("p99_us".into(), Json::Num(t.p99_us));
                e.insert("min_us".into(), Json::Num(t.min_us));
                e.insert("max_us".into(), Json::Num(t.max_us));
                e.insert("per_sec".into(), Json::Num(t.per_sec()));
                Json::Obj(e)
            })
            .collect();
        obj.insert("entries".into(), Json::Arr(entries));
        obj.insert(
            "meta".into(),
            Json::Obj(
                self.meta
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }

    pub fn from_json(v: &Json) -> Result<BenchReport, String> {
        let sv = v
            .get("schema_version")
            .and_then(Json::as_u64)
            .ok_or_else(|| "bench report: missing schema_version".to_string())?;
        if sv != SCHEMA_VERSION {
            return Err(format!(
                "bench report: unsupported schema_version {sv} (expected {SCHEMA_VERSION})"
            ));
        }
        let suite = v
            .get("suite")
            .and_then(Json::as_str)
            .ok_or_else(|| "bench report: missing suite".to_string())?
            .to_string();
        let mut entries = Vec::new();
        let arr = v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| "bench report: missing entries".to_string())?;
        for e in arr {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| "bench entry: missing name".to_string())?
                .to_string();
            let num = |k: &str| {
                e.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("bench entry '{name}': missing {k}"))
            };
            entries.push(Timing {
                iters: num("iters")? as usize,
                batch: e.get("batch").and_then(Json::as_usize).unwrap_or(1),
                mean_us: num("mean_us")?,
                p50_us: num("p50_us")?,
                p99_us: num("p99_us")?,
                min_us: num("min_us")?,
                max_us: num("max_us")?,
                name,
            });
        }
        let mut meta = BTreeMap::new();
        if let Some(Json::Obj(m)) = v.get("meta") {
            for (k, val) in m {
                let s = match val {
                    Json::Str(s) => s.clone(),
                    other => other.to_string(),
                };
                meta.insert(k.clone(), s);
            }
        }
        Ok(BenchReport { suite, entries, meta })
    }

    pub fn parse(text: &str) -> Result<BenchReport, String> {
        let v = Json::parse(text).map_err(|e| format!("bench report: {e}"))?;
        BenchReport::from_json(&v)
    }

    pub fn load(path: &str) -> Result<BenchReport, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        BenchReport::parse(&text).map_err(|e| format!("{path}: {e}"))
    }

    /// Write the pretty-printed report (stable, diffable formatting).
    pub fn write(&self, path: &str) -> Result<(), String> {
        let mut text = self.to_json().pretty();
        text.push('\n');
        std::fs::write(path, text).map_err(|e| format!("{path}: {e}"))
    }

    /// Pair every current entry with the like-named baseline entry,
    /// comparing batch-normalized median per-item times (see
    /// [`Comparison`]). Entries missing from the baseline, and baseline
    /// entries that were never recorded ([`Timing::is_recorded`]),
    /// produce no comparison.
    pub fn compare(&self, baseline: &BenchReport) -> Vec<Comparison> {
        let mut out = Vec::new();
        for cur in &self.entries {
            let Some(base) = baseline.entry(&cur.name) else {
                continue;
            };
            if !base.is_recorded() {
                continue;
            }
            let (b, c) = (base.per_item_p50_us(), cur.per_item_p50_us());
            out.push(Comparison {
                name: cur.name.clone(),
                baseline_us: b,
                current_us: c,
                delta_pct: (c - b) / b * 100.0,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timing(name: &str, mean: f64) -> Timing {
        Timing {
            name: name.into(),
            iters: 100,
            batch: 1,
            mean_us: mean,
            p50_us: mean,
            p99_us: mean * 1.5,
            min_us: mean * 0.5,
            max_us: mean * 2.0,
        }
    }

    #[test]
    fn round_trips_through_json() {
        let mut r = BenchReport::new("hotpath");
        r.meta.insert("host".into(), "ci".into());
        r.entries.push(timing("a/b", 123.456));
        let mut t = Timing::single("fig/total", 5.5e6);
        t.batch = 28_000;
        r.entries.push(t);
        let compact = BenchReport::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(compact, r);
        let mut pretty = r.to_json().pretty();
        pretty.push('\n');
        assert_eq!(BenchReport::parse(&pretty).unwrap(), r);
    }

    #[test]
    fn rejects_wrong_schema_version() {
        let text = r#"{"schema_version": 2, "suite": "x", "entries": []}"#;
        assert!(BenchReport::parse(text).unwrap_err().contains("schema_version"));
        assert!(BenchReport::parse("{}").is_err());
    }

    #[test]
    fn compare_computes_deltas_and_skips_unrecorded() {
        let mut base = BenchReport::new("hotpath");
        base.entries.push(timing("hot", 100.0));
        base.entries.push(timing("bootstrap", 0.0)); // not yet recorded
        base.entries.push(timing("removed", 50.0));
        let mut cur = BenchReport::new("hotpath");
        cur.entries.push(timing("hot", 130.0));
        cur.entries.push(timing("bootstrap", 10.0));
        cur.entries.push(timing("brand-new", 5.0));
        let cmps = cur.compare(&base);
        assert_eq!(cmps.len(), 1);
        assert_eq!(cmps[0].name, "hot");
        assert!((cmps[0].delta_pct - 30.0).abs() < 1e-9);
        assert!(cmps[0].regressed(25.0));
        assert!(!cmps[0].regressed(35.0));
    }
}
