//! Deterministic observability: typed event recording, decision audit,
//! and trace export (DESIGN.md §17).
//!
//! The simulator's end-of-run summaries can say *that* goodput dipped,
//! never *why*. This module records the causal record: every request's
//! hop through the cluster (arrival → admission/shed → prefill queue →
//! batch → KV transfer → decode → preemption/requeue → finish), every
//! power-control action with the budgets and committed sums it saw
//! (`MovePower`/`MoveGpu`/role flips audited against `PowerManager`
//! books), every environment disturbance, and the memory events
//! (prefix hits, tier evictions) that shape decode admission.
//!
//! Recording is `Option`-gated at the [`crate::cluster::Cluster`]: with
//! the sink disabled (the default) no event is constructed and no byte
//! of `RunResult` changes — the goldens in `rust/tests/obs_trace.rs`
//! hold the disabled path to bit-identity and the `alloc-count` harness
//! holds it to zero allocations. Enabled, events land in a pre-sized
//! ring buffer: recording is a store plus an index bump, so a warmed
//! window allocates nothing either (the ring overwrites its oldest
//! entry and counts the drop).
//!
//! Every payload field is plain-old-data (`u64`/`f64`/[`Role`]/
//! `&'static str`) — constructing an event never allocates, and the
//! log is a pure function of the seed, so exports are byte-identical
//! across thread counts and event-queue backends.

pub mod chrome;
pub mod explain;

use crate::types::{Micros, Role};

/// One recorded observation. Variants carry their own timestamp `at`
/// (sim µs) plus the minimum payload to reconstruct the decision or
/// hop; request ids are the raw `RequestId` integers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ObsEvent {
    /// A request entered the router (post trace replay, pre admission).
    Arrival { at: Micros, req: u64, tenant: u8, input: u32, output: u32 },
    /// Admission control shed the request (`in_system` = queue+active
    /// population the decision saw).
    Shed { at: Micros, req: u64, tenant: u8, in_system: usize },
    /// Routed into a prefill (or coalesced) queue.
    PrefillQueued { at: Micros, req: u64, gpu: usize },
    /// A GPU started a work unit (prefill batch, decode iteration or
    /// coalesced chunk) scheduled to complete at `until`. These become
    /// the role-colored busy slices on the per-GPU Perfetto tracks.
    GpuStep {
        at: Micros,
        gpu: usize,
        node: u32,
        until: Micros,
        role: Role,
        reqs: u32,
        tokens: u64,
    },
    /// First output token produced (prefill completed).
    FirstToken { at: Micros, req: u64, gpu: usize },
    /// KV handoff published onto the ring; lands at `arrive_at`.
    KvSend { at: Micros, req: u64, src: usize, dst: usize, arrive_at: Micros },
    /// KV handoff landed on the decode GPU.
    KvArrive { at: Micros, req: u64, gpu: usize },
    /// Admitted into a decode batch.
    DecodeAdmit { at: Micros, req: u64, gpu: usize },
    /// Tier preemption: `by` displaced `victim` inside a full decode
    /// batch (victim keeps progress, re-queues).
    Preempt { at: Micros, victim: u64, by: u64, gpu: usize, victim_tier: u8, by_tier: u8 },
    /// A request went back to a queue (GPU failure, preemption, memory
    /// stall retry); `why` is a static reason tag.
    Requeue { at: Micros, req: u64, gpu: usize, why: &'static str },
    /// Request completed; `tokens` output tokens served.
    Finish { at: Micros, req: u64, gpu: usize, tokens: u32 },
    /// Power-control audit: a `MovePower` attempt with the cluster
    /// budget and committed sums immediately before/after (reconciles
    /// against `budget_trace`/`cap_trace`).
    PowerMove {
        at: Micros,
        from: Role,
        to: Role,
        watts: f64,
        ok: bool,
        budget: f64,
        committed_before: f64,
        committed_after: f64,
    },
    /// A `MoveGpu` decision began draining `gpu` toward `to`.
    GpuMove { at: Micros, gpu: usize, from: Role, to: Role },
    /// A drain completed: `gpu` now serves `role`.
    RoleFlip { at: Micros, gpu: usize, role: Role },
    /// A deferred cap raise (or derate restore) took effect.
    CapApplied { at: Micros, gpu: usize, watts: f64 },
    /// A cluster (`node == -1`) or node budget changed; `committed` is
    /// the committed sum after the books re-settled.
    BudgetChange { at: Micros, node: i64, watts: f64, committed: f64 },
    /// An environment disturbance was applied (`gpu == -1` when the
    /// event targets the whole cluster or a node).
    EnvApplied { at: Micros, kind: &'static str, gpu: i64 },
    /// Prefix-cache hit at arrival: `tokens` prompt tokens skipped.
    PrefixHit { at: Micros, req: u64, tokens: u32 },
    /// KV tier eviction (demotion) charged to an admission on `gpu`.
    MemEvict { at: Micros, gpu: usize, bytes: u64 },
}

impl ObsEvent {
    /// The event's timestamp (sim µs).
    pub fn at(&self) -> Micros {
        use ObsEvent::*;
        match *self {
            Arrival { at, .. }
            | Shed { at, .. }
            | PrefillQueued { at, .. }
            | GpuStep { at, .. }
            | FirstToken { at, .. }
            | KvSend { at, .. }
            | KvArrive { at, .. }
            | DecodeAdmit { at, .. }
            | Preempt { at, .. }
            | Requeue { at, .. }
            | Finish { at, .. }
            | PowerMove { at, .. }
            | GpuMove { at, .. }
            | RoleFlip { at, .. }
            | CapApplied { at, .. }
            | BudgetChange { at, .. }
            | EnvApplied { at, .. }
            | PrefixHit { at, .. }
            | MemEvict { at, .. } => at,
        }
    }

    /// The request id this event concerns, if any.
    pub fn req(&self) -> Option<u64> {
        use ObsEvent::*;
        match *self {
            Arrival { req, .. }
            | Shed { req, .. }
            | PrefillQueued { req, .. }
            | FirstToken { req, .. }
            | KvSend { req, .. }
            | KvArrive { req, .. }
            | DecodeAdmit { req, .. }
            | Requeue { req, .. }
            | Finish { req, .. }
            | PrefixHit { req, .. } => Some(req),
            Preempt { victim, .. } => Some(victim),
            _ => None,
        }
    }
}

/// The aggregate counter registry: one monotonic count per event kind,
/// bumped on every `record` (including events the ring later drops), so
/// the totals survive even when the ring wraps. Aggregated into
/// `RunResult.obs` for the emitters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ObsCounters {
    pub arrivals: u64,
    pub sheds: u64,
    pub gpu_steps: u64,
    pub first_tokens: u64,
    pub kv_transfers: u64,
    pub decode_admits: u64,
    pub preemptions: u64,
    pub requeues: u64,
    pub finishes: u64,
    pub power_moves: u64,
    pub gpu_moves: u64,
    pub role_flips: u64,
    pub cap_updates: u64,
    pub budget_changes: u64,
    pub env_applied: u64,
    pub prefix_hits: u64,
    pub evictions: u64,
}

/// What a traced run carries out of the simulator: the (possibly
/// wrapped) event log in chronological order, the counter registry,
/// and the GPU→node map the exporter needs to group tracks.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsReport {
    pub counters: ObsCounters,
    pub events: Vec<ObsEvent>,
    /// Events overwritten because the ring was full.
    pub dropped: u64,
    /// Node index of each GPU (topology snapshot for the exporter).
    pub node_of: Vec<u32>,
}

/// The recording sink the `Cluster` holds (`Option`-gated). A fixed-
/// capacity ring: below capacity events append into pre-reserved
/// storage; at capacity the oldest event is overwritten and counted in
/// `dropped`. Either way a `record` is allocation-free.
#[derive(Debug)]
pub struct ObsSink {
    events: Vec<ObsEvent>,
    /// Oldest entry once the ring has wrapped; next overwrite target.
    head: usize,
    dropped: u64,
    cap: usize,
    pub counters: ObsCounters,
    node_of: Vec<u32>,
}

impl ObsSink {
    /// A sink retaining at most `cap` events (≥ 1), with the GPU→node
    /// topology the Chrome exporter groups tracks by.
    pub fn new(cap: usize, node_of: Vec<u32>) -> Self {
        let cap = cap.max(1);
        ObsSink {
            events: Vec::with_capacity(cap),
            head: 0,
            dropped: 0,
            cap,
            counters: ObsCounters::default(),
            node_of,
        }
    }

    /// Record one event: bump its counter, then append (or overwrite
    /// the oldest once full). Never allocates.
    #[inline]
    pub fn record(&mut self, ev: ObsEvent) {
        self.bump(&ev);
        if self.events.len() < self.cap {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head += 1;
            if self.head == self.cap {
                self.head = 0;
            }
            self.dropped += 1;
        }
    }

    #[inline]
    fn bump(&mut self, ev: &ObsEvent) {
        let c = &mut self.counters;
        match ev {
            ObsEvent::Arrival { .. } => c.arrivals += 1,
            ObsEvent::Shed { .. } => c.sheds += 1,
            ObsEvent::PrefillQueued { .. } => {}
            ObsEvent::GpuStep { .. } => c.gpu_steps += 1,
            ObsEvent::FirstToken { .. } => c.first_tokens += 1,
            ObsEvent::KvSend { .. } => c.kv_transfers += 1,
            ObsEvent::KvArrive { .. } => {}
            ObsEvent::DecodeAdmit { .. } => c.decode_admits += 1,
            ObsEvent::Preempt { .. } => c.preemptions += 1,
            ObsEvent::Requeue { .. } => c.requeues += 1,
            ObsEvent::Finish { .. } => c.finishes += 1,
            ObsEvent::PowerMove { .. } => c.power_moves += 1,
            ObsEvent::GpuMove { .. } => c.gpu_moves += 1,
            ObsEvent::RoleFlip { .. } => c.role_flips += 1,
            ObsEvent::CapApplied { .. } => c.cap_updates += 1,
            ObsEvent::BudgetChange { .. } => c.budget_changes += 1,
            ObsEvent::EnvApplied { .. } => c.env_applied += 1,
            ObsEvent::PrefixHit { .. } => c.prefix_hits += 1,
            ObsEvent::MemEvict { .. } => c.evictions += 1,
        }
    }

    /// Events recorded and still resident (≤ capacity).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Unroll the ring into a chronological report.
    pub fn into_report(mut self) -> ObsReport {
        // When wrapped, `head` indexes the oldest entry; rotating it to
        // the front restores chronological order. Unwrapped, head is 0
        // and the rotate is a no-op.
        self.events.rotate_left(self.head);
        ObsReport {
            counters: self.counters,
            events: self.events,
            dropped: self.dropped,
            node_of: self.node_of,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: Micros) -> ObsEvent {
        ObsEvent::FirstToken { at, req: at, gpu: 0 }
    }

    #[test]
    fn ring_appends_below_capacity() {
        let mut s = ObsSink::new(4, vec![0]);
        for t in 0..3 {
            s.record(ev(t));
        }
        let r = s.into_report();
        assert_eq!(r.dropped, 0);
        assert_eq!(r.counters.first_tokens, 3);
        let ats: Vec<Micros> = r.events.iter().map(|e| e.at()).collect();
        assert_eq!(ats, vec![0, 1, 2]);
    }

    #[test]
    fn ring_overwrites_oldest_and_stays_chronological() {
        let mut s = ObsSink::new(4, vec![0]);
        for t in 0..10 {
            s.record(ev(t));
        }
        let r = s.into_report();
        assert_eq!(r.dropped, 6);
        assert_eq!(r.counters.first_tokens, 10, "counters survive drops");
        let ats: Vec<Micros> = r.events.iter().map(|e| e.at()).collect();
        assert_eq!(ats, vec![6, 7, 8, 9]);
    }

    #[test]
    fn counters_classify_event_kinds() {
        let mut s = ObsSink::new(16, vec![0, 0]);
        s.record(ObsEvent::Arrival { at: 0, req: 1, tenant: 0, input: 10, output: 2 });
        s.record(ObsEvent::Shed { at: 1, req: 2, tenant: 1, in_system: 30 });
        s.record(ObsEvent::PowerMove {
            at: 2,
            from: Role::Decode,
            to: Role::Prefill,
            watts: 50.0,
            ok: true,
            budget: 4800.0,
            committed_before: 4000.0,
            committed_after: 4000.0,
        });
        s.record(ObsEvent::Preempt { at: 3, victim: 1, by: 2, gpu: 0, victim_tier: 2, by_tier: 0 });
        let c = s.into_report().counters;
        assert_eq!((c.arrivals, c.sheds, c.power_moves, c.preemptions), (1, 1, 1, 1));
    }

    #[test]
    fn req_accessor_tracks_victim() {
        let p = ObsEvent::Preempt { at: 0, victim: 7, by: 9, gpu: 1, victim_tier: 2, by_tier: 0 };
        assert_eq!(p.req(), Some(7));
        assert_eq!(ev(5).req(), Some(5));
    }
}
