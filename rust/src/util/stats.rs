//! Statistics primitives: percentiles, histograms, rolling windows.
//!
//! Everything the paper's metrics need: P90 latencies (Fig 4), SLO
//! attainment curves (Fig 5/7/8), 10 ms rolling power averages (Fig 3),
//! and sliding recent-latency windows for the Algorithm-1 controller.

use crate::types::Micros;

/// Exact percentile over a sample (sorts a copy; fine at our sizes).
///
/// NaN-tolerant: samples are ordered with `total_cmp`, so NaNs sort to
/// the end instead of panicking mid-sort (a single NaN latency in a
/// series must not abort a whole study).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    let mut s: Vec<f64> = samples.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&s, p)
}

/// [`percentile`] over an already-sorted slice — the zero-copy variant
/// for callers that compute several percentiles from one sort (e.g. the
/// final [`crate::metrics::Summary`]).
pub fn percentile_sorted(s: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile out of range: {p}");
    if s.is_empty() {
        return f64::NAN;
    }
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let frac = rank - lo as f64;
        s[lo] * (1.0 - frac) + s[hi] * frac
    }
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Fixed-capacity sliding window of (time, value) observations.
///
/// The Algorithm-1 controller reads "recent TTFT / TPOT" from these; the
/// window evicts by age so the controller reacts to the current regime,
/// not the whole history.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    span: Micros,
    entries: std::collections::VecDeque<(Micros, f64)>,
}

impl SlidingWindow {
    pub fn new(span: Micros) -> Self {
        SlidingWindow {
            span,
            // Sized for steady state up front: the controller's latency
            // windows hold hundreds of samples, and growth mid-run would
            // show up in the alloc-count steady-state test.
            entries: std::collections::VecDeque::with_capacity(1024),
        }
    }

    pub fn push(&mut self, now: Micros, value: f64) {
        self.entries.push_back((now, value));
        self.evict(now);
    }

    fn evict(&mut self, now: Micros) {
        let cutoff = now.saturating_sub(self.span);
        while let Some(&(t, _)) = self.entries.front() {
            if t < cutoff {
                self.entries.pop_front();
            } else {
                break;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn values(&self) -> Vec<f64> {
        self.entries.iter().map(|&(_, v)| v).collect()
    }

    pub fn percentile(&self, now: Micros, p: f64) -> Option<f64> {
        let cutoff = now.saturating_sub(self.span);
        let vals: Vec<f64> = self
            .entries
            .iter()
            .filter(|&&(t, _)| t >= cutoff)
            .map(|&(_, v)| v)
            .collect();
        if vals.is_empty() {
            None
        } else {
            Some(percentile(&vals, p))
        }
    }

    /// Fraction of in-window samples strictly above `threshold` —
    /// O(n) with no allocation or sort. `percentile(p) > t` is exactly
    /// `frac_above(t) > 1 - p/100`, which is all the Algorithm-1 trigger
    /// needs (hot path: called every controller tick).
    pub fn frac_above(&self, now: Micros, threshold: f64) -> Option<f64> {
        let cutoff = now.saturating_sub(self.span);
        let mut n = 0usize;
        let mut above = 0usize;
        for &(t, v) in &self.entries {
            if t >= cutoff {
                n += 1;
                if v > threshold {
                    above += 1;
                }
            }
        }
        if n == 0 {
            None
        } else {
            Some(above as f64 / n as f64)
        }
    }

    pub fn mean(&self, now: Micros) -> Option<f64> {
        let cutoff = now.saturating_sub(self.span);
        let vals: Vec<f64> = self
            .entries
            .iter()
            .filter(|&&(t, _)| t >= cutoff)
            .map(|&(_, v)| v)
            .collect();
        if vals.is_empty() {
            None
        } else {
            Some(mean(&vals))
        }
    }
}

/// Log-spaced latency histogram (for cheap streaming percentiles when
/// sample vectors would be too large).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [min * ratio^i, min * ratio^(i+1))
    min: f64,
    ratio: f64,
    counts: Vec<u64>,
    total: u64,
    overflow: u64,
}

impl LatencyHistogram {
    /// `min`..`max` with `buckets` log-spaced bins.
    pub fn new(min: f64, max: f64, buckets: usize) -> Self {
        assert!(min > 0.0 && max > min && buckets > 0);
        let ratio = (max / min).powf(1.0 / buckets as f64);
        LatencyHistogram {
            min,
            ratio,
            counts: vec![0; buckets],
            total: 0,
            overflow: 0,
        }
    }

    pub fn record(&mut self, value: f64) {
        self.total += 1;
        if value < self.min {
            self.counts[0] += 1;
            return;
        }
        let idx = ((value / self.min).ln() / self.ratio.ln()) as usize;
        if idx >= self.counts.len() {
            self.overflow += 1;
        } else {
            self.counts[idx] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate quantile (bucket lower edge).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.min * self.ratio.powi(i as i32);
            }
        }
        self.min * self.ratio.powi(self.counts.len() as i32)
    }
}

/// Time series with rolling-average reduction (Fig 3: 10 ms rolling power).
#[derive(Debug, Clone, Default)]
pub struct TimeSeries {
    pub points: Vec<(Micros, f64)>,
}

impl TimeSeries {
    pub fn new() -> Self {
        TimeSeries { points: Vec::new() }
    }

    pub fn push(&mut self, t: Micros, v: f64) {
        debug_assert!(self.points.last().map_or(true, |&(pt, _)| pt <= t));
        self.points.push((t, v));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Rolling mean over a trailing window, sampled at each point.
    pub fn rolling_mean(&self, window: Micros) -> TimeSeries {
        let mut out = TimeSeries::new();
        let mut start = 0usize;
        let mut sum = 0.0;
        let mut cnt = 0usize;
        for i in 0..self.points.len() {
            let (t, v) = self.points[i];
            sum += v;
            cnt += 1;
            while self.points[start].0 + window < t {
                sum -= self.points[start].1;
                cnt -= 1;
                start += 1;
            }
            out.push(t, sum / cnt as f64);
        }
        out
    }

    /// Max value (e.g. peak node power).
    pub fn max(&self) -> f64 {
        self.points.iter().map(|&(_, v)| v).fold(f64::MIN, f64::max)
    }

    /// Fraction of samples strictly above a threshold (Fig 3: time above
    /// the 4800 W line).
    pub fn frac_above(&self, threshold: f64) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().filter(|&&(_, v)| v > threshold).count() as f64
            / self.points.len() as f64
    }

    /// Piecewise-constant time integral (J if values are W and t is us).
    pub fn integral(&self) -> f64 {
        let mut acc = 0.0;
        for w in self.points.windows(2) {
            let (t0, v0) = w[0];
            let (t1, _) = w[1];
            acc += v0 * (t1 - t0) as f64;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 90.0) - 90.1).abs() < 1e-9);
    }

    #[test]
    fn percentile_single_and_empty() {
        assert_eq!(percentile(&[7.0], 90.0), 7.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // Regression: `partial_cmp().unwrap()` used to panic here. NaNs
        // now sort last (total order), so low percentiles stay usable.
        let xs = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn percentile_sorted_matches_percentile() {
        let xs: Vec<f64> = (1..=50).rev().map(|x| x as f64).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(percentile(&xs, p), percentile_sorted(&sorted, p));
        }
    }

    #[test]
    fn sliding_window_evicts_by_age() {
        let mut w = SlidingWindow::new(1000);
        w.push(0, 1.0);
        w.push(500, 2.0);
        w.push(1600, 3.0); // evicts t=0 and t=500
        assert_eq!(w.values(), vec![3.0]);
    }

    #[test]
    fn frac_above_matches_percentile_semantics() {
        let mut w = SlidingWindow::new(10_000);
        for i in 0..100 {
            w.push(i, i as f64 / 100.0); // values 0.00..0.99
        }
        let f = w.frac_above(99, 0.9).unwrap();
        assert!((f - 0.09).abs() < 1e-9, "f={f}");
        // p90 > 0.9 iff frac_above(0.9) > 0.1 — not the case here (0.09).
        assert!(w.percentile(99, 90.0).unwrap() <= 0.9 + 1e-9);
        assert!(w.frac_above(99, 2.0).unwrap() == 0.0);
        assert!(SlidingWindow::new(10).frac_above(5, 0.0).is_none());
    }

    #[test]
    fn sliding_window_percentile_respects_now() {
        let mut w = SlidingWindow::new(1000);
        for i in 0..10 {
            w.push(i * 100, i as f64);
        }
        let p = w.percentile(900, 100.0).unwrap();
        assert_eq!(p, 9.0);
        // far-future `now` excludes everything
        assert!(w.percentile(10_000, 50.0).is_none());
    }

    #[test]
    fn histogram_quantiles_approximate() {
        let mut h = LatencyHistogram::new(1.0, 1e6, 200);
        for i in 1..=10_000 {
            h.record(i as f64);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!((p50 / 5000.0 - 1.0).abs() < 0.10, "p50={p50}");
        assert!((p99 / 9900.0 - 1.0).abs() < 0.10, "p99={p99}");
    }

    #[test]
    fn histogram_overflow_and_underflow() {
        let mut h = LatencyHistogram::new(10.0, 100.0, 10);
        h.record(1.0); // below min -> bucket 0
        h.record(1e9); // overflow
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn rolling_mean_smooths() {
        let mut ts = TimeSeries::new();
        for i in 0..100u64 {
            ts.push(i * 1000, if i % 2 == 0 { 0.0 } else { 10.0 });
        }
        let smooth = ts.rolling_mean(10_000);
        // after warmup every window holds ~half zeros, half tens
        let tail: Vec<f64> = smooth.points[20..].iter().map(|&(_, v)| v).collect();
        for v in tail {
            assert!((v - 5.0).abs() <= 1.0, "v={v}");
        }
    }

    #[test]
    fn frac_above_counts_threshold_crossings() {
        let mut ts = TimeSeries::new();
        for i in 0..10u64 {
            ts.push(i, if i < 3 { 5000.0 } else { 4000.0 });
        }
        assert!((ts.frac_above(4800.0) - 0.3).abs() < 1e-9);
    }

    #[test]
    fn integral_piecewise_constant() {
        let mut ts = TimeSeries::new();
        ts.push(0, 100.0);
        ts.push(10, 200.0);
        ts.push(20, 0.0);
        assert_eq!(ts.integral(), 100.0 * 10.0 + 200.0 * 10.0);
    }
}
