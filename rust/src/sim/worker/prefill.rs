//! Prefill worker behavior: FIFO batch formation under the token budget,
//! KV-ring backpressure, and publish into the decode pool (paper §3.2).

use crate::cluster::Cluster;
use crate::coordinator::batcher;
use crate::sim::event::Event;
use crate::sim::worker::RoleBehavior;
use crate::types::{GpuId, Role};

pub struct PrefillBehavior;

impl RoleBehavior for PrefillBehavior {
    fn role(&self) -> Role {
        Role::Prefill
    }

    fn kick(&self, cl: &mut Cluster, gi: usize) {
        cl.kick_prefill(gi);
    }

    fn on_step_done(&self, cl: &mut Cluster, gi: usize, epoch: u64) {
        cl.on_prefill_done(gi, epoch);
    }
}

impl Cluster {
    pub(crate) fn kick_prefill(&mut self, gi: usize) {
        let ring_free = self.ring_free(self.node_of(gi));
        let now = self.now;
        {
            let g = &self.gpus[gi];
            if g.busy || g.failed || g.role != Role::Prefill || g.pf_queue.is_empty() {
                return;
            }
            // Backpressure: wait for ring slots before starting a new
            // batch (the paper's prefill stall when decode cannot drain).
            if !g.publish_wait.is_empty() || ring_free == 0 {
                return;
            }
        }
        // Batch formation reuses the cluster-wide scratch buffer: a busy
        // prefill GPU forms thousands of batches per run without touching
        // the allocator. Taken only after the guards so every return path
        // past this point restores it.
        let mut scratch = std::mem::take(&mut self.scratch_batch);
        let store = &self.store;
        let g = &mut self.gpus[gi];
        let total_tokens = batcher::form_prefill_batch_ids(
            &mut g.pf_queue,
            &self.cfg.batch,
            |s| store.get(s).req.input_tokens,
            &mut scratch,
        );
        if scratch.is_empty() {
            self.scratch_batch = scratch;
            return;
        }
        g.pop_prefill_tokens(total_tokens as u64);
        g.pf_batch.clear();
        g.pf_batch.extend(scratch.drain(..));
        g.busy = true;
        let epoch = g.epoch;
        self.scratch_batch = scratch;
        // Stamp the batch's prefill start in the store (formerly the
        // per-item tuple element in `pf_batch`).
        for k in 0..self.gpus[gi].pf_batch.len() {
            let s = self.gpus[gi].pf_batch[k];
            self.store.get_mut(s).prefill_start = now;
        }
        self.reindex(gi); // queue shrank: update the pick index
        let power = self.power.effective(GpuId(gi), now);
        let t = self.model_of(gi).prefill_batch_time(total_tokens, power);
        self.events.push(now + t, Event::StepDone { gpu: gi, epoch });
        if self.obs.is_some() {
            let node = self.node_of(gi) as u32;
            let reqs = self.gpus[gi].pf_batch.len() as u32;
            if let Some(o) = self.obs.as_deref_mut() {
                o.record(crate::obs::ObsEvent::GpuStep {
                    at: now,
                    gpu: gi,
                    node,
                    until: now + t,
                    role: Role::Prefill,
                    reqs,
                    tokens: total_tokens as u64,
                });
            }
        }
    }

    pub(crate) fn on_prefill_done(&mut self, gi: usize, epoch: u64) {
        if self.gpus[gi].epoch != epoch {
            return; // stale (role changed mid-flight)
        }
        self.gpus[gi].busy = false;
        // Drain-and-restore keeps pf_batch's capacity across batches.
        let mut batch = std::mem::take(&mut self.gpus[gi].pf_batch);
        let dynamic = self.policy.is_dynamic();
        for slot in batch.drain(..) {
            let (id, arrival, ttft_slo, output_tokens, prefill_start) = {
                let st = self.store.get(slot);
                (
                    st.req.id.0,
                    st.req.arrival,
                    st.req.slo.ttft,
                    st.req.output_tokens,
                    st.prefill_start,
                )
            };
            if dynamic {
                let ratio = (self.now - arrival) as f64 / ttft_slo as f64;
                self.policy.observe_ttft(self.now, ratio);
            }
            if output_tokens <= 1 {
                // Single-token request: done at prefill. Drop any parked
                // prefix-hit state — it never reaches the decode pool.
                self.mem.take_cached_tokens(id);
                self.mem.take_fetch(id);
                let now = self.now;
                let st = self.store.remove(slot);
                self.push_record(&st.req, prefill_start, now, now);
                if let Some(o) = self.obs.as_deref_mut() {
                    o.record(crate::obs::ObsEvent::FirstToken { at: now, req: id, gpu: gi });
                    o.record(crate::obs::ObsEvent::Finish {
                        at: now,
                        req: id,
                        gpu: gi,
                        tokens: output_tokens,
                    });
                }
                continue;
            }
            let cached = self.mem.take_cached_tokens(id);
            {
                let st = self.store.get_mut(slot);
                st.first_token = self.now;
                st.tokens_done = 1;
                st.cached_tokens = cached;
            }
            if let Some(o) = self.obs.as_deref_mut() {
                o.record(crate::obs::ObsEvent::FirstToken { at: self.now, req: id, gpu: gi });
            }
            self.gpus[gi].publish_wait.push_back(slot);
        }
        self.gpus[gi].pf_batch = batch;
        self.try_publish(gi);
        // Drain handling: if this GPU is switching roles and is now empty,
        // the switch can proceed.
        self.maybe_finish_drain(gi);
        self.kick_prefill(gi);
    }

    /// Push completed prefills into the KV ring as capacity allows,
    /// routing each to a decode worker with same-node preference (a
    /// cross-node target pays the slower RDMA hop).
    pub(crate) fn try_publish(&mut self, gi: usize) {
        let src_node = self.node_of(gi);
        while self.ring_used[src_node] < self.cfg.batch.ring_slots {
            let Some(slot) = self.gpus[gi].publish_wait.pop_front() else {
                break;
            };
            let target = self.pick_decode_gpu(None, src_node).or_else(|| {
                self.gpus
                    .iter()
                    .position(|g| !g.failed && g.committed_role() == Role::Decode)
                    .map(GpuId)
            });
            let Some(target) = target else {
                // Every decode worker is down: park the item back; a
                // recovery re-triggers publishing.
                self.gpus[gi].publish_wait.push_front(slot);
                break;
            };
            // Admission control: the decode pool must fit the context's
            // projected KV before the transfer commits. A pool that
            // cannot evict enough stalls this publisher exactly like
            // ring backpressure (retried on completions/arrivals).
            if self.mem.active() {
                let bytes = self.kv_bytes_for_slot(target.0, slot);
                match self.mem.reserve(target.0, bytes) {
                    Ok(ev) => {
                        self.note_eviction(target.0, ev);
                        self.reindex(target.0);
                    }
                    Err(()) => {
                        self.gpus[gi].publish_wait.push_front(slot);
                        break;
                    }
                }
            }
            self.ring_used[src_node] += 1;
            let same_node = self.node_of(target.0) == src_node;
            // Heterogeneous endpoints: the slower side's link binds. A
            // prefix-cache hit additionally pays its tier fetch here.
            let (input, id) = {
                let r = &self.store.get(slot).req;
                (r.input_tokens, r.id.0)
            };
            let t = self
                .fleet
                .kv_transfer_time_between(gi, target.0, input, same_node)
                + self.mem.take_fetch(id);
            self.events.push(
                self.now + t,
                Event::KvArrive { gpu: target.0, src_node, slot },
            );
            if let Some(o) = self.obs.as_deref_mut() {
                let at = self.now;
                o.record(crate::obs::ObsEvent::KvSend {
                    at,
                    req: id,
                    src: gi,
                    dst: target.0,
                    arrive_at: at + t,
                });
            }
        }
    }
}
