//! The discrete-event simulator: an 8-GPU MI300X-class node under the
//! RAPID coordinator.
//!
//! This is the substitution substrate for the paper's physical testbed
//! (see DESIGN.md §2): simulated GPUs execute the calibrated latency
//! model of `power::model`, the power manager enforces budget + ramp
//! dynamics, and the *actual paper logic* — router, batcher, Algorithm 1
//! controller — runs unmodified on top, exactly as it does on the real
//! PJRT serving path.

use std::collections::VecDeque;

use crate::config::{ClusterConfig, Topology};
use crate::coordinator::batcher::{self, ChunkProgress};
use crate::coordinator::router::{self, WorkerLoad};
use crate::coordinator::{Action, Controller, Snapshot};
use crate::metrics::RunResult;
use crate::power::{PowerManager, PowerModel};
use crate::sim::event::{DecodeItem, Event, EventQueue};
use crate::sim::gpu::{ChunkMeta, GpuSim};
use crate::types::{
    GpuId, Micros, Request, RequestRecord, Role, SECOND,
};
use crate::workload::Trace;

/// Tunables that are about the *simulation*, not the system under test.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Telemetry sampling period (Fig 3 wants 10 ms; sweeps use coarser).
    pub sample_period: Micros,
    /// Hard wall: stop this long after the last arrival even if requests
    /// are still unfinished (they count as SLO violations).
    pub drain_grace: Micros,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            // Coarse default: sweep figures only need budget/provisioning
            // aggregates; Fig 3 overrides to the paper's 10 ms.
            sample_period: 200_000,
            drain_grace: 120 * SECOND,
        }
    }
}

/// Run one experiment: a trace through a cluster configuration.
pub fn run(cfg: &ClusterConfig, trace: &Trace, opts: &SimOptions) -> RunResult {
    Sim::new(cfg.clone(), trace.clone(), opts.clone()).run()
}

struct Sim {
    cfg: ClusterConfig,
    model: PowerModel,
    power: PowerManager,
    controller: Controller,
    gpus: Vec<GpuSim>,
    events: EventQueue,
    now: Micros,
    trace: Vec<Request>,
    next_arrival: usize,
    records: Vec<RequestRecord>,
    /// KV ring occupancy (slots in flight between prefill and decode).
    ring_used: usize,
    opts: SimOptions,
    // --- result accumulation ---
    node_power: crate::util::stats::TimeSeries,
    cap_trace: Vec<(Micros, Vec<f64>)>,
    role_trace: Vec<(Micros, usize, usize)>,
    decisions: Vec<(Micros, String)>,
    provisioned_integral: f64,
    last_sample_at: Micros,
    hard_stop: Micros,
    /// Telemetry-only RNG: models sub-sample-interval power microbursts
    /// (kernel gaps, transfer stalls) that a 10 ms meter sees on real
    /// hardware. Never feeds back into scheduling decisions' latencies.
    sample_rng: crate::util::rng::Rng,
}

impl Sim {
    fn new(cfg: ClusterConfig, trace: Trace, opts: SimOptions) -> Self {
        let model = PowerModel::new(cfg.perf.clone());
        let caps: Vec<f64> = (0..cfg.n_gpus)
            .map(|i| match cfg.topology {
                Topology::Coalesced => cfg.prefill_cap_w,
                Topology::Disaggregated { prefill, .. } => {
                    if i < prefill {
                        cfg.prefill_cap_w
                    } else {
                        cfg.decode_cap_w
                    }
                }
            })
            .collect();
        let power = PowerManager::new(
            &caps,
            cfg.node_budget_w,
            cfg.enforce_budget,
            cfg.controller.min_gpu_w,
            cfg.controller.max_gpu_w,
        );
        let gpus: Vec<GpuSim> = (0..cfg.n_gpus)
            .map(|i| {
                GpuSim::new(match cfg.topology {
                    Topology::Coalesced => Role::Coalesced,
                    Topology::Disaggregated { prefill, .. } => {
                        if i < prefill {
                            Role::Prefill
                        } else {
                            Role::Decode
                        }
                    }
                })
            })
            .collect();
        let controller = Controller::new(cfg.controller.clone(), cfg.control);
        let hard_stop = trace
            .requests
            .last()
            .map(|r| r.arrival)
            .unwrap_or(0)
            + opts.drain_grace;
        Sim {
            model,
            power,
            controller,
            gpus,
            events: EventQueue::new(),
            now: 0,
            trace: trace.requests,
            next_arrival: 0,
            records: Vec::new(),
            ring_used: 0,
            node_power: crate::util::stats::TimeSeries::new(),
            cap_trace: Vec::new(),
            role_trace: Vec::new(),
            decisions: Vec::new(),
            provisioned_integral: 0.0,
            last_sample_at: 0,
            opts,
            cfg,
            hard_stop,
            sample_rng: crate::util::rng::Rng::new(0xF16_3),
        }
    }

    fn run(mut self) -> RunResult {
        if !self.trace.is_empty() {
            self.events.push(self.trace[0].arrival, Event::Arrival);
        }
        self.events.push(self.cfg.controller.tick, Event::ControllerTick);
        self.events.push(0, Event::Sample);
        self.record_roles();

        let total = self.trace.len();
        while let Some((at, ev)) = self.events.pop() {
            debug_assert!(at >= self.now, "time went backwards");
            self.now = at;
            if self.records.len() >= total || self.now > self.hard_stop {
                break;
            }
            self.handle(ev);
        }
        self.finish(total)
    }

    // ------------------------------------------------------------------
    // event dispatch
    // ------------------------------------------------------------------

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::Arrival => self.on_arrival(),
            Event::PrefillDone { gpu, epoch } => self.on_prefill_done(gpu, epoch),
            Event::DecodeStep { gpu, epoch } => self.on_decode_step(gpu, epoch),
            Event::CoalescedStep { gpu, epoch } => self.on_coalesced_step(gpu, epoch),
            Event::KvArrive { gpu, item } => self.on_kv_arrive(gpu, item),
            Event::ControllerTick => self.on_tick(),
            Event::PowerPoll => self.on_power_poll(),
            Event::Sample => self.on_sample(),
            Event::DrainDone { gpu, epoch } => self.on_drain_done(gpu, epoch),
        }
    }

    fn on_arrival(&mut self) {
        let req = self.trace[self.next_arrival].clone();
        self.next_arrival += 1;
        if self.next_arrival < self.trace.len() {
            self.events
                .push(self.trace[self.next_arrival].arrival, Event::Arrival);
        }
        match self.cfg.topology {
            Topology::Coalesced => self.route_coalesced(req),
            Topology::Disaggregated { .. } => self.route_prefill(req),
        }
    }

    fn route_prefill(&mut self, req: Request) {
        let loads: Vec<WorkerLoad> = self
            .gpus
            .iter()
            .enumerate()
            .filter(|(_, g)| g.role == Role::Prefill)
            .map(|(i, g)| WorkerLoad {
                gpu: GpuId(i),
                queued_tokens: g.pf_queued_tokens,
                requests: g.pf_queue.len(),
                accepting: g.accepting(),
            })
            .collect();
        let Some(gpu) = router::pick_prefill(&loads) else {
            // No accepting prefill GPU (all draining): park on the one with
            // the committed prefill role; it will pick the work up after
            // the drain. This cannot happen with >= 1 GPU per phase.
            let fallback = self
                .gpus
                .iter()
                .position(|g| g.committed_role() == Role::Prefill)
                .expect("at least one prefill-committed GPU");
            self.gpus[fallback].push_prefill(req);
            return;
        };
        self.gpus[gpu.0].push_prefill(req);
        self.kick_prefill(gpu.0);
    }

    fn route_coalesced(&mut self, req: Request) {
        let loads: Vec<WorkerLoad> = self
            .gpus
            .iter()
            .enumerate()
            .map(|(i, g)| WorkerLoad {
                gpu: GpuId(i),
                queued_tokens: g.co_queued_tokens(),
                requests: g.co_queue.len() + g.dec_active.len(),
                accepting: g.accepting(),
            })
            .collect();
        let gpu = router::pick_prefill(&loads).expect("coalesced pool nonempty");
        self.gpus[gpu.0].co_queue.push_back(ChunkMeta {
            prog: ChunkProgress::new(req),
            started: None,
        });
        self.kick_coalesced(gpu.0);
    }

    // ------------------------------------------------------------------
    // prefill pool
    // ------------------------------------------------------------------

    fn kick_prefill(&mut self, gi: usize) {
        let ring_free = self.cfg.batch.ring_slots.saturating_sub(self.ring_used);
        let g = &mut self.gpus[gi];
        if g.busy || g.role != Role::Prefill || g.pf_queue.is_empty() {
            return;
        }
        // Backpressure: wait for ring slots before starting a new batch
        // (the paper's prefill stall when decode cannot drain).
        if !g.publish_wait.is_empty() || ring_free == 0 {
            return;
        }
        let batch = batcher::form_prefill_batch(&mut g.pf_queue, &self.cfg.batch);
        if batch.requests.is_empty() {
            return;
        }
        g.pop_prefill_tokens(batch.total_tokens as u64);
        g.pf_batch = batch
            .requests
            .into_iter()
            .map(|r| (r, self.now))
            .collect();
        g.busy = true;
        let power = self.power.effective(GpuId(gi), self.now);
        let t = self.model.prefill_batch_time(batch.total_tokens, power);
        let epoch = g.epoch;
        self.events.push(self.now + t, Event::PrefillDone { gpu: gi, epoch });
    }

    fn on_prefill_done(&mut self, gi: usize, epoch: u64) {
        if self.gpus[gi].epoch != epoch {
            return; // stale (role changed mid-flight)
        }
        self.gpus[gi].busy = false;
        let batch = std::mem::take(&mut self.gpus[gi].pf_batch);
        let dynamic = self.cfg.control.is_dynamic();
        for (req, prefill_start) in batch {
            if dynamic {
                let ratio = (self.now - req.arrival) as f64 / req.slo.ttft as f64;
                self.controller.observe_ttft(self.now, ratio);
            }
            if req.output_tokens <= 1 {
                // Single-token request: done at prefill.
                self.records.push(RequestRecord {
                    id: req.id,
                    arrival: req.arrival,
                    prefill_start,
                    first_token: self.now,
                    finish: self.now,
                    input_tokens: req.input_tokens,
                    output_tokens: req.output_tokens,
                    slo: req.slo,
                });
                continue;
            }
            let item = DecodeItem {
                req,
                prefill_start,
                first_token: self.now,
                tokens_done: 1,
            };
            self.gpus[gi].publish_wait.push_back(item);
        }
        self.try_publish(gi);
        // Drain handling: if this GPU is switching roles and is now empty,
        // the switch can proceed.
        self.maybe_finish_drain(gi);
        self.kick_prefill(gi);
    }

    /// Push completed prefills into the KV ring as capacity allows.
    fn try_publish(&mut self, gi: usize) {
        while self.ring_used < self.cfg.batch.ring_slots {
            let Some(item) = self.gpus[gi].publish_wait.pop_front() else {
                break;
            };
            let loads: Vec<WorkerLoad> = self
                .gpus
                .iter()
                .enumerate()
                .filter(|(_, g)| g.role == Role::Decode)
                .map(|(i, g)| WorkerLoad {
                    gpu: GpuId(i),
                    queued_tokens: 0,
                    requests: g.decode_load(),
                    accepting: g.accepting(),
                })
                .collect();
            let target = router::pick_decode(&loads)
                .or_else(|| {
                    self.gpus
                        .iter()
                        .position(|g| g.committed_role() == Role::Decode)
                        .map(GpuId)
                })
                .expect("at least one decode-committed GPU");
            self.ring_used += 1;
            let t = self.model.kv_transfer_time(item.req.input_tokens);
            self.events
                .push(self.now + t, Event::KvArrive { gpu: target.0, item });
        }
    }

    // ------------------------------------------------------------------
    // decode pool
    // ------------------------------------------------------------------

    fn on_kv_arrive(&mut self, gi: usize, item: DecodeItem) {
        self.ring_used = self.ring_used.saturating_sub(1);
        self.gpus[gi].dec_pending.push_back(item);
        // A slot freed: stalled prefill GPUs may publish now.
        for i in 0..self.gpus.len() {
            if !self.gpus[i].publish_wait.is_empty() {
                self.try_publish(i);
                self.kick_prefill(i);
            }
        }
        self.kick_decode(gi);
    }

    fn kick_decode(&mut self, gi: usize) {
        let g = &mut self.gpus[gi];
        if g.busy || g.role != Role::Decode {
            return;
        }
        // Admissions at step boundaries (continuous batching). Draining
        // GPUs stop admitting.
        if g.accepting() {
            let n = batcher::decode_admissions(
                g.dec_active.len(),
                g.dec_pending.len(),
                &self.cfg.batch,
            );
            for _ in 0..n {
                let item = g.dec_pending.pop_front().unwrap();
                g.dec_active.push(item);
            }
        }
        if g.dec_active.is_empty() {
            return;
        }
        g.busy = true;
        let batch = g.dec_active.len();
        let ctx = g.mean_ctx();
        let power = self.power.effective(GpuId(gi), self.now);
        let t = self.model.decode_step_time(batch, ctx, power);
        self.gpus[gi].dec_step_time = t;
        let epoch = self.gpus[gi].epoch;
        self.events.push(self.now + t, Event::DecodeStep { gpu: gi, epoch });
    }

    fn on_decode_step(&mut self, gi: usize, epoch: u64) {
        if self.gpus[gi].epoch != epoch {
            return;
        }
        let step = self.gpus[gi].dec_step_time;
        self.gpus[gi].busy = false;
        let mut ratio_sum = 0.0;
        let mut finished: Vec<DecodeItem> = Vec::new();
        {
            let g = &mut self.gpus[gi];
            let mut idx = 0;
            while idx < g.dec_active.len() {
                g.dec_active[idx].tokens_done += 1;
                ratio_sum += step as f64 / g.dec_active[idx].req.slo.tpot as f64;
                if g.dec_active[idx].remaining() == 0 {
                    finished.push(g.dec_active.swap_remove(idx));
                } else {
                    idx += 1;
                }
            }
            if self.cfg.control.is_dynamic()
                && (!g.dec_active.is_empty() || !finished.is_empty())
            {
                let n = g.dec_active.len() + finished.len();
                // One TPOT sample per step: the batch-mean SLO ratio.
                let ratio = ratio_sum / n as f64;
                self.controller.observe_tpot(self.now, ratio);
            }
        }
        for item in finished {
            self.records.push(RequestRecord {
                id: item.req.id,
                arrival: item.req.arrival,
                prefill_start: item.prefill_start,
                first_token: item.first_token,
                finish: self.now,
                input_tokens: item.req.input_tokens,
                output_tokens: item.req.output_tokens,
                slo: item.req.slo,
            });
        }
        self.maybe_finish_drain(gi);
        self.kick_decode(gi);
    }

    // ------------------------------------------------------------------
    // coalesced pool (chunked prefill baseline)
    // ------------------------------------------------------------------

    fn kick_coalesced(&mut self, gi: usize) {
        let chunk_budget = self.cfg.perf.chunk_tokens;
        let g = &mut self.gpus[gi];
        if g.busy || g.role != Role::Coalesced {
            return;
        }
        if g.co_queue.is_empty() && g.dec_active.is_empty() && g.dec_pending.is_empty() {
            return;
        }
        // Admit locally-finished prefills (they sit in dec_pending).
        let n = batcher::decode_admissions(
            g.dec_active.len(),
            g.dec_pending.len(),
            &self.cfg.batch,
        );
        for _ in 0..n {
            let item = g.dec_pending.pop_front().unwrap();
            g.dec_active.push(item);
        }
        // Take the next prefill chunk (if any prompt is queued).
        let mut done_before = 0u32;
        if let Some(head) = g.co_queue.front_mut() {
            if head.started.is_none() {
                head.started = Some(self.now);
            }
            done_before = head.prog.done_tokens;
        }
        let mut queue = std::mem::take(&mut g.co_queue);
        // Mark start times for any prompt the chunk reaches.
        let (used, finished_reqs) = {
            let mut progs: VecDeque<ChunkProgress> =
                queue.iter().map(|c| c.prog.clone()).collect();
            let r = batcher::take_chunk(&mut progs, chunk_budget);
            // Write back progress into the metas that remain.
            let consumed = queue.len() - progs.len();
            let finished_meta: Vec<ChunkMeta> = queue.drain(..consumed).collect();
            for (meta, prog) in queue.iter_mut().zip(progs.iter()) {
                meta.prog = prog.clone();
                if meta.prog.done_tokens > 0 && meta.started.is_none() {
                    meta.started = Some(self.now);
                }
            }
            let mut finished = Vec::new();
            for meta in finished_meta {
                finished.push((meta.prog.request.clone(), meta.started.unwrap_or(self.now)));
            }
            (r.0, finished)
        };
        g.co_queue = queue;
        g.co_finishing = finished_reqs;
        g.co_step_chunk = used;
        if used == 0 && g.dec_active.is_empty() {
            return; // nothing to do this iteration
        }
        g.busy = true;
        let batch = g.dec_active.len();
        let ctx = g.mean_ctx();
        let power = self.power.effective(GpuId(gi), self.now);
        let t = self
            .model
            .coalesced_step_time(used, done_before, batch, ctx, power);
        self.gpus[gi].dec_step_time = t;
        let epoch = self.gpus[gi].epoch;
        self.events
            .push(self.now + t, Event::CoalescedStep { gpu: gi, epoch });
    }

    fn on_coalesced_step(&mut self, gi: usize, epoch: u64) {
        if self.gpus[gi].epoch != epoch {
            return;
        }
        let step = self.gpus[gi].dec_step_time;
        self.gpus[gi].busy = false;
        // Prefill completions: first token now; join local decode.
        let finishing = std::mem::take(&mut self.gpus[gi].co_finishing);
        let dynamic = self.cfg.control.is_dynamic();
        for (req, started) in finishing {
            if dynamic {
                let ratio = (self.now - req.arrival) as f64 / req.slo.ttft as f64;
                self.controller.observe_ttft(self.now, ratio);
            }
            if req.output_tokens <= 1 {
                self.records.push(RequestRecord {
                    id: req.id,
                    arrival: req.arrival,
                    prefill_start: started,
                    first_token: self.now,
                    finish: self.now,
                    input_tokens: req.input_tokens,
                    output_tokens: req.output_tokens,
                    slo: req.slo,
                });
                continue;
            }
            self.gpus[gi].dec_pending.push_back(DecodeItem {
                req,
                prefill_start: started,
                first_token: self.now,
                tokens_done: 1,
            });
        }
        // Decode completions.
        let mut ratio_sum = 0.0;
        let mut finished: Vec<DecodeItem> = Vec::new();
        {
            let g = &mut self.gpus[gi];
            let mut idx = 0;
            while idx < g.dec_active.len() {
                g.dec_active[idx].tokens_done += 1;
                ratio_sum += step as f64 / g.dec_active[idx].req.slo.tpot as f64;
                if g.dec_active[idx].remaining() == 0 {
                    finished.push(g.dec_active.swap_remove(idx));
                } else {
                    idx += 1;
                }
            }
            let n = g.dec_active.len() + finished.len();
            if n > 0 && self.cfg.control.is_dynamic() {
                self.controller.observe_tpot(self.now, ratio_sum / n as f64);
            }
        }
        for item in finished {
            self.records.push(RequestRecord {
                id: item.req.id,
                arrival: item.req.arrival,
                prefill_start: item.prefill_start,
                first_token: item.first_token,
                finish: self.now,
                input_tokens: item.req.input_tokens,
                output_tokens: item.req.output_tokens,
                slo: item.req.slo,
            });
        }
        self.kick_coalesced(gi);
    }

    // ------------------------------------------------------------------
    // controller + power
    // ------------------------------------------------------------------

    fn on_tick(&mut self) {
        self.events
            .push(self.now + self.cfg.controller.tick, Event::ControllerTick);
        // Project queue pressure into the TTFT window: queue buildup must
        // trigger *before* completions report violations (paper §3.3:
        // "queue buildup as an early indicator of stress"). The projection
        // is head wait + expected drain time of the whole backlog, so a
        // deep queue keeps the signal high even right after a power boost
        // clears the head.
        for (i, g) in self.gpus.iter().enumerate() {
            if !self.cfg.control.is_dynamic() {
                break;
            }
            let (head, backlog_tokens) = match g.role {
                Role::Coalesced => (
                    g.co_queue.front().map(|c| &c.prog.request),
                    g.co_queued_tokens(),
                ),
                _ => (g.pf_queue.front(), g.pf_queued_tokens),
            };
            if let Some(req) = head {
                let age = self.now.saturating_sub(req.arrival);
                let cap = self.power.effective(GpuId(i), self.now);
                let drain =
                    (backlog_tokens as f64 / self.model.prefill_rate(cap) * 1e6) as Micros;
                let projected = age + drain;
                self.controller
                    .observe_ttft(self.now, projected as f64 / req.slo.ttft as f64);
            }
        }
        let snap = self.snapshot();
        if std::env::var("RAPID_DEBUG_TICKS").is_ok() {
            eprintln!(
                "tick t={:.2} qP={} qD={} p_sat={} d_sat={} P={} D={}",
                self.now as f64 / 1e6,
                snap.prefill_queue,
                snap.decode_queue,
                snap.prefill_power_saturated,
                snap.decode_power_saturated,
                snap.prefill_gpus,
                snap.decode_gpus
            );
        }
        if let Some(action) = self.controller.decide(&snap) {
            self.execute(action);
        }
    }

    fn pool(&self, role: Role) -> Vec<GpuId> {
        self.gpus
            .iter()
            .enumerate()
            .filter(|(_, g)| g.role == role && g.accepting())
            .map(|(i, _)| GpuId(i))
            .collect()
    }

    fn snapshot(&self) -> Snapshot {
        let c = &self.cfg.controller;
        let prefill_pool = self.pool(Role::Prefill);
        let decode_pool = self.pool(Role::Decode);
        let prefill_queue: usize = self.gpus.iter().map(|g| g.pf_queue.len()).sum::<usize>()
            + self.gpus.iter().map(|g| g.co_queue.len()).sum::<usize>();
        let decode_queue: usize = self.gpus.iter().map(|g| g.dec_pending.len()).sum();
        // MovePower(D->P) is exhausted when prefill caps hit MAX or decode
        // caps hit MIN.
        let prefill_power_saturated = prefill_pool
            .iter()
            .all(|&g| self.power.target(g) >= c.max_gpu_w - 1.0)
            || decode_pool
                .iter()
                .all(|&g| self.power.target(g) <= c.min_gpu_w + 1.0)
            || prefill_pool.is_empty()
            || decode_pool.is_empty();
        // MovePower(P->D) is exhausted when decode caps hit their ceiling
        // (decode gains nothing above the knee) or prefill caps hit MIN.
        let decode_power_saturated = decode_pool
            .iter()
            .all(|&g| self.power.target(g) >= c.decode_ceiling_w - 1.0)
            || prefill_pool
                .iter()
                .all(|&g| self.power.target(g) <= c.min_gpu_w + 1.0)
            || prefill_pool.is_empty()
            || decode_pool.is_empty();
        Snapshot {
            now: self.now,
            prefill_queue,
            decode_queue,
            prefill_gpus: self
                .gpus
                .iter()
                .filter(|g| g.committed_role() == Role::Prefill)
                .count(),
            decode_gpus: self
                .gpus
                .iter()
                .filter(|g| g.committed_role() == Role::Decode)
                .count(),
            prefill_power_saturated,
            decode_power_saturated,
        }
    }

    fn execute(&mut self, action: Action) {
        match action {
            Action::MovePower { from } => {
                let to = if from == Role::Decode {
                    Role::Prefill
                } else {
                    Role::Decode
                };
                let sources = self.pool(from);
                let sinks = self.pool(to);
                if sources.is_empty() || sinks.is_empty() {
                    return;
                }
                let ceiling = if to == Role::Decode {
                    self.cfg.controller.decode_ceiling_w
                } else {
                    self.cfg.controller.max_gpu_w
                };
                let total = self.cfg.controller.power_step_w * sources.len() as f64;
                match self.power.move_power(self.now, &sources, &sinks, total, ceiling) {
                    Ok(mv) => {
                        self.decisions.push((
                            self.now,
                            format!("MovePower {from}->{to}: {:?}", mv.raised),
                        ));
                        self.events.push(mv.effective_at, Event::PowerPoll);
                    }
                    Err(e) => {
                        self.decisions
                            .push((self.now, format!("MovePower {from}->{to} failed: {e}")));
                    }
                }
            }
            Action::MoveGpu { from } => {
                let to = if from == Role::Decode {
                    Role::Prefill
                } else {
                    Role::Decode
                };
                // Donor: least-loaded accepting GPU of the source role,
                // keeping >= 1 GPU in the source pool.
                let pool = self.pool(from);
                if pool.len() <= 1 {
                    return;
                }
                let donor = *pool
                    .iter()
                    .min_by_key(|&&g| {
                        let gpu = &self.gpus[g.0];
                        match from {
                            Role::Prefill => gpu.pf_queued_tokens as usize,
                            _ => gpu.decode_load(),
                        }
                    })
                    .unwrap();
                self.decisions
                    .push((self.now, format!("MoveGpu {donor} {from}->{to}")));
                self.begin_drain(donor.0, to);
                // Paper line 14: uniform power across all GPUs after a
                // role change.
                let settle = self.power.distribute_uniform(self.now);
                self.events.push(settle, Event::PowerPoll);
                self.record_roles();
            }
        }
    }

    fn begin_drain(&mut self, gi: usize, to: Role) {
        {
            let g = &mut self.gpus[gi];
            if g.draining_to.is_some() {
                return;
            }
            g.draining_to = Some(to);
        }
        // Re-route queued (not yet running) work to peers.
        let queued: Vec<Request> = {
            let g = &mut self.gpus[gi];
            let drained: Vec<Request> = g.pf_queue.drain(..).collect();
            g.pf_queued_tokens = 0;
            drained
        };
        for r in queued {
            self.route_prefill(r);
        }
        let pending: Vec<DecodeItem> = self.gpus[gi].dec_pending.drain(..).collect();
        for item in pending {
            // Send to the least-loaded other decode GPU (KV re-transfer
            // is charged: the cache must move with the request).
            let loads: Vec<WorkerLoad> = self
                .gpus
                .iter()
                .enumerate()
                .filter(|(i, g)| *i != gi && g.role == Role::Decode)
                .map(|(i, g)| WorkerLoad {
                    gpu: GpuId(i),
                    queued_tokens: 0,
                    requests: g.decode_load(),
                    accepting: g.accepting(),
                })
                .collect();
            if let Some(target) = router::pick_decode(&loads) {
                let t = self.model.kv_transfer_time(item.req.input_tokens);
                self.events
                    .push(self.now + t, Event::KvArrive { gpu: target.0, item });
                self.ring_used += 1; // re-transfer occupies a slot
            } else {
                // No other decode GPU: keep it; it finishes before the flip.
                self.gpus[gi].dec_pending.push_back(item);
            }
        }
        self.maybe_finish_drain(gi);
    }

    fn maybe_finish_drain(&mut self, gi: usize) {
        let g = &self.gpus[gi];
        if g.draining_to.is_some() && g.drained() {
            let epoch = g.epoch;
            self.events.push(
                self.now + self.cfg.controller.gpu_move_overhead,
                Event::DrainDone { gpu: gi, epoch },
            );
        }
    }

    fn on_drain_done(&mut self, gi: usize, epoch: u64) {
        let g = &mut self.gpus[gi];
        if g.epoch != epoch || g.draining_to.is_none() {
            return;
        }
        g.role = g.draining_to.take().unwrap();
        g.epoch += 1;
        g.busy = false;
        self.record_roles();
        match self.gpus[gi].role {
            Role::Prefill => self.kick_prefill(gi),
            Role::Decode => self.kick_decode(gi),
            Role::Coalesced => self.kick_coalesced(gi),
        }
        // Rebalance: peers may hold queued work this GPU could take; the
        // router only balances new arrivals, so steal half the longest
        // peer queue (cheap work-stealing on role flips).
        if self.gpus[gi].role == Role::Prefill {
            self.steal_prefill_work(gi);
        }
    }

    fn steal_prefill_work(&mut self, gi: usize) {
        let Some(victim) = (0..self.gpus.len())
            .filter(|&i| i != gi && self.gpus[i].role == Role::Prefill)
            .max_by_key(|&i| self.gpus[i].pf_queued_tokens)
        else {
            return;
        };
        let steal_n = self.gpus[victim].pf_queue.len() / 2;
        for _ in 0..steal_n {
            if let Some(r) = self.gpus[victim].pf_queue.pop_back() {
                self.gpus[victim].pf_queued_tokens -= r.input_tokens as u64;
                self.gpus[gi].push_prefill(r);
            }
        }
        self.kick_prefill(gi);
    }

    fn on_power_poll(&mut self) {
        let applied = self.power.poll(self.now);
        if !applied.is_empty() {
            self.cap_trace.push((self.now, self.power.targets()));
        }
        if let Some(at) = self.power.next_pending_at() {
            self.events.push(at, Event::PowerPoll);
        }
    }

    fn on_sample(&mut self) {
        let dt = (self.now - self.last_sample_at) as f64;
        self.last_sample_at = self.now;
        let mut node = 0.0;
        for (i, g) in self.gpus.iter().enumerate() {
            let cap = self.power.effective(GpuId(i), self.now);
            let is_prefill_like = matches!(g.role, Role::Prefill | Role::Coalesced);
            let mut mean_draw = self.model.draw(cap, g.util(), is_prefill_like);
            // Host-side iteration gaps (scheduling, sampling,
            // detokenization) idle the GPU between iterations; a 10 ms
            // meter catches them as deep dips (paper Fig 3's burstiness).
            if g.busy && self.sample_rng.chance(0.12) {
                mean_draw = self.model.idle_w() + 0.18 * (mean_draw - self.model.idle_w());
            }
            // Microburst variation around the mean draw (per-kernel power
            // phases under a 10 ms meter).
            let jitter = 1.0 + 0.08 * self.sample_rng.normal();
            node += (mean_draw * jitter).clamp(self.model.idle_w(), cap);
        }
        self.node_power.push(self.now, node);
        self.provisioned_integral += self.power.targets().iter().sum::<f64>() * dt;
        self.cap_trace.push((self.now, self.power.targets()));
        self.events
            .push(self.now + self.opts.sample_period, Event::Sample);
    }

    fn record_roles(&mut self) {
        let p = self
            .gpus
            .iter()
            .filter(|g| g.committed_role() == Role::Prefill)
            .count();
        let d = self
            .gpus
            .iter()
            .filter(|g| g.committed_role() == Role::Decode)
            .count();
        self.role_trace.push((self.now, p, d));
    }

    fn finish(mut self, total_submitted: usize) -> RunResult {
        let duration = self.now.max(1);
        let mean_provisioned_w = if duration > 0 {
            self.provisioned_integral / duration as f64
        } else {
            0.0
        };
        // Unfinished requests are recorded as violations (never completed):
        // give them "infinite" latency records so attainment counts them.
        let completed: std::collections::HashSet<u64> =
            self.records.iter().map(|r| r.id.0).collect();
        for req in &self.trace[..self.next_arrival] {
            if !completed.contains(&req.id.0) {
                self.records.push(RequestRecord {
                    id: req.id,
                    arrival: req.arrival,
                    prefill_start: self.now,
                    first_token: self.now + 3600 * SECOND,
                    finish: self.now + 7200 * SECOND,
                    input_tokens: req.input_tokens,
                    output_tokens: req.output_tokens,
                    slo: req.slo,
                });
            }
        }
        let _ = total_submitted;
        RunResult {
            config_name: self.cfg.name.clone(),
            records: self.records,
            node_power: self.node_power,
            cap_trace: self.cap_trace,
            role_trace: self.role_trace,
            decisions: self.decisions,
            duration,
            mean_provisioned_w,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::types::{RequestId, Slo, MILLIS};
    use crate::util::rng::Rng;
    use crate::workload::{build_trace, sonnet::Sonnet, ArrivalProcess};

    fn small_trace(n: usize, qps: f64, input: u32, output: u32) -> Trace {
        let mut ap = ArrivalProcess::poisson(Rng::new(42), qps);
        let mut sizes = Sonnet::new(Rng::new(43), input, output);
        build_trace(n, &mut ap, &mut sizes, Slo::paper_default())
    }

    #[test]
    fn all_requests_complete_disaggregated() {
        let cfg = presets::p4d4(600.0);
        let trace = small_trace(100, 8.0, 1024, 32);
        let r = run(&cfg, &trace, &SimOptions::default());
        assert_eq!(r.records.len(), 100);
        // Light load: everything should attain.
        assert!(r.attainment() > 0.9, "attainment={}", r.attainment());
    }

    #[test]
    fn all_requests_complete_coalesced() {
        let cfg = presets::coalesced(750.0);
        let trace = small_trace(100, 8.0, 1024, 32);
        let r = run(&cfg, &trace, &SimOptions::default());
        assert_eq!(r.records.len(), 100);
        assert!(r.attainment() > 0.8, "attainment={}", r.attainment());
    }

    #[test]
    fn ttft_increases_under_overload() {
        let cfg = presets::p4d4(600.0);
        let light = run(&cfg, &small_trace(80, 4.0, 2048, 32), &SimOptions::default());
        let heavy = run(&cfg, &small_trace(300, 40.0, 2048, 32), &SimOptions::default());
        assert!(
            heavy.ttft_percentile(90.0) > light.ttft_percentile(90.0) * 2.0,
            "overload must queue: light={} heavy={}",
            light.ttft_percentile(90.0),
            heavy.ttft_percentile(90.0)
        );
        assert!(heavy.attainment() < light.attainment());
    }

    #[test]
    fn records_are_causally_ordered() {
        let cfg = presets::p4d4(600.0);
        let trace = small_trace(150, 12.0, 1500, 64);
        let r = run(&cfg, &trace, &SimOptions::default());
        for rec in &r.records {
            assert!(rec.arrival <= rec.prefill_start, "{rec:?}");
            assert!(rec.prefill_start <= rec.first_token);
            assert!(rec.first_token <= rec.finish);
        }
    }

    #[test]
    fn node_power_stays_under_budget_when_enforced() {
        let cfg = presets::p4d4(600.0);
        let trace = small_trace(200, 16.0, 2048, 64);
        let r = run(&cfg, &trace, &SimOptions::default());
        // Draw <= sum of caps <= budget (within ramp epsilon).
        assert!(
            r.node_power.max() <= cfg.node_budget_w + 10.0,
            "peak draw {} > budget",
            r.node_power.max()
        );
    }

    #[test]
    fn uncapped_node_can_exceed_budget_line() {
        let cfg = presets::uncapped_coalesced();
        let trace = small_trace(300, 14.0, 4096, 64);
        let r = run(&cfg, &trace, &SimOptions::default());
        assert!(
            r.node_power.max() > 4800.0,
            "uncapped peak {} should exceed the 4800 W line",
            r.node_power.max()
        );
    }

    #[test]
    fn dynamic_rapid_reallocates_under_prefill_pressure() {
        let mut cfg = presets::rapid_600();
        cfg.controller.queue_threshold = 4;
        // Prefill-heavy overload: long prompts, tiny outputs.
        let trace = small_trace(400, 20.0, 6000, 16);
        let r = run(&cfg, &trace, &SimOptions::default());
        assert!(
            !r.decisions.is_empty(),
            "controller should act under pressure"
        );
        let moved_power = r.decisions.iter().any(|(_, d)| d.contains("MovePower"));
        assert!(moved_power, "decisions: {:?}", &r.decisions[..r.decisions.len().min(5)]);
    }

    #[test]
    fn static_policy_makes_no_decisions() {
        let cfg = presets::p4d4(600.0);
        let trace = small_trace(200, 20.0, 6000, 16);
        let r = run(&cfg, &trace, &SimOptions::default());
        assert!(r.decisions.is_empty());
    }

    #[test]
    fn deterministic_given_same_inputs() {
        let cfg = presets::rapid_600();
        let trace = small_trace(150, 12.0, 2048, 64);
        let a = run(&cfg, &trace, &SimOptions::default());
        let b = run(&cfg, &trace, &SimOptions::default());
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.finish, y.finish);
        }
        assert_eq!(a.decisions.len(), b.decisions.len());
    }

    #[test]
    fn single_token_requests_finish_at_prefill() {
        let cfg = presets::p4d4(600.0);
        let trace = Trace {
            requests: vec![Request {
                id: RequestId(0),
                arrival: 0,
                input_tokens: 512,
                output_tokens: 1,
                slo: Slo::paper_default(),
            }],
        };
        let r = run(&cfg, &trace, &SimOptions::default());
        assert_eq!(r.records.len(), 1);
        assert_eq!(r.records[0].first_token, r.records[0].finish);
        assert!(r.records[0].finish < 200 * MILLIS);
    }

    #[test]
    fn hard_stop_records_unfinished_as_violations() {
        let cfg = presets::p4d4(600.0);
        // Hopeless overload with a short grace: some requests never finish.
        let trace = small_trace(500, 100.0, 8000, 400);
        let opts = SimOptions {
            drain_grace: 5 * SECOND,
            ..Default::default()
        };
        let r = run(&cfg, &trace, &opts);
        assert_eq!(r.records.len(), r.records.iter().map(|x| x.id.0).collect::<std::collections::HashSet<_>>().len());
        assert!(r.attainment() < 0.5);
    }
}
