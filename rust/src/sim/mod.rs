//! Discrete-event simulation of the GPU node (the testbed substitute).

pub mod engine;
pub mod event;
pub mod gpu;

pub use engine::{run, SimOptions};
