//! Pluggable control policies for the cluster core.
//!
//! The event loop used to hard-code Algorithm 1; now it drives a
//! [`Policy`] trait object, so controllers are swappable without touching
//! the simulator:
//!
//! * [`StaticPolicy`] — user-fixed roles and caps, never acts;
//! * [`RapidDynamic`] — the paper's Algorithm 1
//!   ([`crate::coordinator::Controller`]), covering the DynPower, DynGpu
//!   and full-RAPID variants;
//! * [`PowerOnly`] — an ablation: pure latency-driven power shifting with
//!   none of Algorithm 1's arbitration (no queue-pressure gate, no
//!   both-hot veto, no saturation-triggered GPU escalation). Comparing it
//!   to DynPower isolates what those extra signals contribute.

use crate::config::{ClusterConfig, ControlPolicy, ControllerConfig};
use crate::coordinator::{Action, Controller, Snapshot};
use crate::env::{EnvDisturbance, EnvEvent};
use crate::types::{Micros, Role};
use crate::util::stats::SlidingWindow;

/// What a policy asks the cluster core to do right after an environment
/// disturbance lands (in addition to the core's own failure handling).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnvResponse {
    /// Do nothing now; react through the normal decision ticks (or not
    /// at all — the static stance).
    None,
    /// Re-spread power uniformly under the new budgets/envelopes
    /// immediately (lower-first, raise-later), instead of waiting for a
    /// latency window to fill.
    RedistributeUniform,
}

/// How a `MovePower` action splits watts inside its source/sink pools.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PowerWeighting {
    /// The paper's uniform per-GPU split.
    Uniform,
    /// Weight by marginal tokens/s per watt: the steepest sink curve
    /// receives the most watts, the flattest source gives up the most.
    /// Only differentiates heterogeneous fleets — on a homogeneous pool
    /// the cluster core always uses the uniform split (bit-identical to
    /// the paper's behavior).
    MarginalTps,
}

/// A cluster controller: consumes SLO-normalized latency observations and
/// emits at most one [`Action`] per tick. The cluster core executes
/// actions; policies stay side-effect free.
pub trait Policy: std::fmt::Debug + Send {
    /// Name for decision traces.
    fn name(&self) -> &'static str;
    /// Should the cluster bother computing/feeding observations?
    fn is_dynamic(&self) -> bool;
    /// Record a completed-or-projected TTFT observation (ratio to SLO).
    fn observe_ttft(&mut self, _now: Micros, _ratio: f64) {}
    /// Record a decode step's per-token latency ratio to the SLO.
    fn observe_tpot(&mut self, _now: Micros, _ratio: f64) {}
    /// How the cluster core distributes this policy's `MovePower` watts.
    /// Defaults to marginal-throughput weighting so every built-in
    /// policy (Static/RapidDynamic/PowerOnly) is SKU-aware on mixed
    /// fleets without further changes; override to `Uniform` to ablate.
    fn power_weighting(&self) -> PowerWeighting {
        PowerWeighting::MarginalTps
    }
    /// An environment disturbance just landed (cap step, GPU
    /// failure/recovery, thermal derate — see [`crate::env`]). The core
    /// has already applied the mandatory safety work (budget shedding,
    /// failure requeue + uniform re-spread); the hook lets a *dynamic*
    /// policy additionally rebalance immediately instead of waiting for
    /// its sampling tick. The static default does nothing — cap
    /// restoration after a curtailment window is a reallocation
    /// decision, which a static policy by definition never takes.
    fn on_env_event(&mut self, _now: Micros, _ev: &EnvEvent) -> EnvResponse {
        EnvResponse::None
    }
    /// A decode admission on `gpu` just forced KV demotions: `occ_frac`
    /// is the pool's occupancy after the reserve, `evicted_bytes` what
    /// moved to a slower tier. Lets a dynamic policy weigh power moves
    /// against eviction cost; the default ignores memory entirely (and
    /// the hook never fires when the subsystem is inactive).
    fn on_memory_pressure(
        &mut self,
        _now: Micros,
        _gpu: usize,
        _occ_frac: f64,
        _evicted_bytes: u64,
    ) {
    }
    /// Admission control just shed an arrival (overload). Lets a
    /// dynamic policy trade power moves against shedding; the default
    /// ignores it (and the hook never fires without an `[admission]`
    /// table, preserving bit-identity for untenanted runs).
    fn on_overload(&mut self, _now: Micros) {}
    /// One decision tick.
    fn decide(&mut self, snap: &Snapshot) -> Option<Action>;
}

/// Build the policy a configuration asks for.
pub fn make_policy(cfg: &ClusterConfig) -> Box<dyn Policy> {
    match cfg.control {
        ControlPolicy::Static => Box::new(StaticPolicy),
        ControlPolicy::PowerOnly => Box::new(PowerOnly::new(cfg.controller.clone())),
        ControlPolicy::DynPower | ControlPolicy::DynGpu | ControlPolicy::DynPowerGpu => {
            Box::new(RapidDynamic::new(cfg.controller.clone(), cfg.control))
        }
    }
}

/// Fixed allocation: observes nothing, decides nothing.
#[derive(Debug, Default)]
pub struct StaticPolicy;

impl Policy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }
    fn is_dynamic(&self) -> bool {
        false
    }
    fn decide(&mut self, _snap: &Snapshot) -> Option<Action> {
        None
    }
}

/// Algorithm 1 (paper §3.3) behind the [`Policy`] interface.
#[derive(Debug)]
pub struct RapidDynamic {
    controller: Controller,
    /// Eviction-time HBM occupancy observations (same window length as
    /// the latency metrics). Empty for the whole run unless the memory
    /// subsystem is active — then Algorithm 1 is bit-identical.
    mem_occ: SlidingWindow,
}

impl RapidDynamic {
    pub fn new(cfg: ControllerConfig, policy: ControlPolicy) -> Self {
        let window = cfg.metric_window;
        RapidDynamic {
            controller: Controller::new(cfg, policy),
            mem_occ: SlidingWindow::new(window),
        }
    }

    /// The wrapped controller (tests / traces).
    pub fn controller(&self) -> &Controller {
        &self.controller
    }

    /// Is the decode pool too memory-hot to shrink? (Majority of recent
    /// evictions happened above 90% occupancy.)
    fn decode_memory_hot(&self, now: Micros) -> bool {
        self.mem_occ.frac_above(now, 0.9).map_or(false, |f| f > 0.5)
    }
}

impl Policy for RapidDynamic {
    fn name(&self) -> &'static str {
        "rapid-dynamic"
    }
    fn is_dynamic(&self) -> bool {
        true
    }
    fn observe_ttft(&mut self, now: Micros, ratio: f64) {
        self.controller.observe_ttft(now, ratio);
    }
    fn observe_tpot(&mut self, now: Micros, ratio: f64) {
        self.controller.observe_tpot(now, ratio);
    }
    fn on_env_event(&mut self, _now: Micros, ev: &EnvEvent) -> EnvResponse {
        dynamic_env_response(ev)
    }
    fn on_memory_pressure(&mut self, now: Micros, _gpu: usize, occ_frac: f64, _bytes: u64) {
        self.mem_occ.push(now, occ_frac);
    }
    fn on_overload(&mut self, now: Micros) {
        // A shed arrival is stronger evidence than any completed TTFT:
        // record a 2x-SLO violation so Algorithm 1's latency windows
        // heat up and it reallocates power/GPUs toward the bottleneck
        // instead of settling into a shedding equilibrium.
        self.controller.observe_ttft(now, 2.0);
    }
    fn decide(&mut self, snap: &Snapshot) -> Option<Action> {
        let action = self.controller.decide(snap);
        // Taking a GPU away from decode while its pools are evicting to
        // stay afloat trades an SLO miss for a worse one: the survivors
        // absorb the drained contexts and spiral into offload. Veto the
        // shrink; power moves and grows pass through untouched.
        if let Some(Action::MoveGpu { from: Role::Decode }) = action {
            if self.decode_memory_hot(snap.now) {
                return None;
            }
        }
        action
    }
}

/// The shared dynamic stance: budget steps and thermal events re-spread
/// power under the new constraints immediately (a raised budget is
/// reclaimed the instant curtailment ends); failures/recoveries return
/// `None` because the cluster core already redistributes as part of its
/// mandatory failure handling.
fn dynamic_env_response(ev: &EnvEvent) -> EnvResponse {
    match ev.what {
        EnvDisturbance::CapChange { .. }
        | EnvDisturbance::ThermalThrottle { .. }
        | EnvDisturbance::ThermalClear { .. } => EnvResponse::RedistributeUniform,
        EnvDisturbance::GpuFail { .. } | EnvDisturbance::GpuRecover { .. } => EnvResponse::None,
    }
}

/// Ablation policy: move power toward whichever phase's latency window is
/// hot, full stop. No queue threshold, no both-hot veto, no GPU moves —
/// when both windows are hot it thrashes power toward TTFT (prefill),
/// which is exactly the failure mode Algorithm 1's arbitration avoids.
#[derive(Debug)]
pub struct PowerOnly {
    cfg: ControllerConfig,
    ttft: SlidingWindow,
    tpot: SlidingWindow,
    last_move: Option<Micros>,
}

impl PowerOnly {
    pub fn new(cfg: ControllerConfig) -> Self {
        PowerOnly {
            ttft: SlidingWindow::new(cfg.metric_window),
            tpot: SlidingWindow::new(cfg.metric_window),
            cfg,
            last_move: None,
        }
    }

    fn cooled_down(&self, now: Micros) -> bool {
        self.last_move
            .map_or(true, |t| now.saturating_sub(t) >= self.cfg.cooldown)
    }
}

impl Policy for PowerOnly {
    fn name(&self) -> &'static str {
        "power-only"
    }
    fn is_dynamic(&self) -> bool {
        true
    }
    fn observe_ttft(&mut self, now: Micros, ratio: f64) {
        self.ttft.push(now, ratio);
    }
    fn observe_tpot(&mut self, now: Micros, ratio: f64) {
        self.tpot.push(now, ratio);
    }
    fn on_env_event(&mut self, _now: Micros, ev: &EnvEvent) -> EnvResponse {
        dynamic_env_response(ev)
    }
    fn decide(&mut self, snap: &Snapshot) -> Option<Action> {
        if !self.cooled_down(snap.now) {
            return None;
        }
        let viol_frac = (100.0 - self.cfg.trigger_percentile) / 100.0;
        let ttft_hot = self
            .ttft
            .frac_above(snap.now, 1.0)
            .map_or(false, |f| f > viol_frac);
        let tpot_hot = self
            .tpot
            .frac_above(snap.now, 1.0)
            .map_or(false, |f| f > viol_frac);
        let action = if ttft_hot {
            Some(Action::MovePower { from: Role::Decode })
        } else if tpot_hot {
            Some(Action::MovePower { from: Role::Prefill })
        } else {
            None
        };
        if action.is_some() {
            self.last_move = Some(snap.now);
        }
        action
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::types::SECOND;

    fn snap(now: Micros) -> Snapshot {
        Snapshot {
            now,
            prefill_queue: 0,
            decode_queue: 0,
            prefill_gpus: 4,
            decode_gpus: 4,
            prefill_power_saturated: false,
            decode_power_saturated: false,
        }
    }

    #[test]
    fn factory_maps_control_policy() {
        assert_eq!(make_policy(&presets::p4d4(600.0)).name(), "static");
        assert_eq!(make_policy(&presets::rapid_600()).name(), "rapid-dynamic");
        assert_eq!(make_policy(&presets::dyn_power_600()).name(), "rapid-dynamic");
        assert_eq!(make_policy(&presets::power_only_600()).name(), "power-only");
        assert!(!make_policy(&presets::p4d4(600.0)).is_dynamic());
        assert!(make_policy(&presets::power_only_600()).is_dynamic());
    }

    #[test]
    fn all_builtin_policies_default_to_marginal_weighting() {
        // The SKU-aware reallocation hook: every built-in policy opts in
        // by default (the cluster core still uses the uniform split on
        // homogeneous fleets, so the paper's behavior is unchanged).
        for preset in [presets::p4d4(600.0), presets::rapid_600(), presets::power_only_600()] {
            assert_eq!(
                make_policy(&preset).power_weighting(),
                PowerWeighting::MarginalTps,
                "{}",
                preset.name
            );
        }
    }

    #[test]
    fn static_policy_never_acts() {
        let mut p = StaticPolicy;
        assert_eq!(p.decide(&snap(10 * SECOND)), None);
    }

    #[test]
    fn power_only_ignores_queue_threshold() {
        // Algorithm 1 refuses to act on TTFT violations without queue
        // backlog; the ablation acts anyway — that is its point.
        let mut p = PowerOnly::new(ControllerConfig::default());
        let now = 10 * SECOND;
        for i in 0..10 {
            p.observe_ttft(now - i, 1.6);
            p.observe_tpot(now - i, 0.4);
        }
        let s = snap(now); // prefill_queue == 0
        assert_eq!(p.decide(&s), Some(Action::MovePower { from: Role::Decode }));
    }

    #[test]
    fn power_only_never_moves_gpus_and_respects_cooldown() {
        let mut p = PowerOnly::new(ControllerConfig::default());
        let now = 10 * SECOND;
        for i in 0..10 {
            p.observe_ttft(now - i, 1.6);
        }
        let first = p.decide(&snap(now));
        assert!(matches!(first, Some(Action::MovePower { .. })));
        for i in 0..10 {
            p.observe_ttft(now + 1 - i, 1.6);
        }
        assert_eq!(p.decide(&snap(now + 1)), None, "cooldown must hold");
        let later = now + ControllerConfig::default().cooldown;
        for i in 0..10 {
            p.observe_ttft(later - i, 1.6);
        }
        assert!(p.decide(&snap(later)).is_some());
    }

    #[test]
    fn env_hook_static_stays_put_dynamic_redistributes() {
        use crate::env::{CapScope, EnvDisturbance, EnvEvent};
        let cap = EnvEvent {
            at: 10 * SECOND,
            what: EnvDisturbance::CapChange { scope: CapScope::Cluster, watts: 4000.0 },
        };
        let fail = EnvEvent { at: 10 * SECOND, what: EnvDisturbance::GpuFail { gpu: 3 } };
        let throttle = EnvEvent {
            at: 10 * SECOND,
            what: EnvDisturbance::ThermalThrottle { gpu: 1, max_w: 500.0 },
        };
        let mut st = StaticPolicy;
        assert_eq!(st.on_env_event(0, &cap), EnvResponse::None);
        let mut r = RapidDynamic::new(ControllerConfig::default(), ControlPolicy::DynPowerGpu);
        assert_eq!(r.on_env_event(0, &cap), EnvResponse::RedistributeUniform);
        assert_eq!(r.on_env_event(0, &throttle), EnvResponse::RedistributeUniform);
        assert_eq!(r.on_env_event(0, &fail), EnvResponse::None, "core owns failure handling");
        let mut p = PowerOnly::new(ControllerConfig::default());
        assert_eq!(p.on_env_event(0, &cap), EnvResponse::RedistributeUniform);
    }

    #[test]
    fn overload_hook_feeds_ttft_pressure() {
        // Enough shed arrivals alone must push Algorithm 1 toward a
        // prefill power move — that is the trade between reallocation
        // and further shedding.
        let mut p = RapidDynamic::new(ControllerConfig::default(), ControlPolicy::DynPowerGpu);
        let now = 10 * SECOND;
        for i in 0..10 {
            p.on_overload(now - i);
            p.observe_tpot(now - i, 0.4);
        }
        let mut s = snap(now);
        s.prefill_queue = 20;
        assert_eq!(p.decide(&s), Some(Action::MovePower { from: Role::Decode }));
        // The static policy ignores the hook entirely.
        let mut st = StaticPolicy;
        st.on_overload(now);
        assert_eq!(st.decide(&snap(now)), None);
    }

    #[test]
    fn rapid_dynamic_delegates_to_algorithm_1() {
        let mut p = RapidDynamic::new(ControllerConfig::default(), ControlPolicy::DynPowerGpu);
        let now = 10 * SECOND;
        for i in 0..10 {
            p.observe_ttft(now - i, 1.6);
            p.observe_tpot(now - i, 0.4);
        }
        let mut s = snap(now);
        s.prefill_queue = 20;
        assert_eq!(p.decide(&s), Some(Action::MovePower { from: Role::Decode }));
    }

    /// Drive Algorithm 1 to a decode-pool shrink (TTFT hot, queue deep,
    /// power saturated); the memory hook must veto it only when recent
    /// evictions ran near-full, and stay inert with an empty window (the
    /// bit-identity guarantee for runs without a `[mem]` table).
    #[test]
    fn memory_pressure_vetoes_decode_shrink_only_when_hot() {
        let now = 10 * SECOND;
        let mut s = snap(now);
        s.prefill_queue = 20;
        s.prefill_power_saturated = true;

        let mut cold = RapidDynamic::new(ControllerConfig::default(), ControlPolicy::DynPowerGpu);
        for i in 0..10 {
            cold.observe_ttft(now - i, 1.6);
            cold.observe_tpot(now - i, 0.4);
        }
        assert_eq!(cold.decide(&s), Some(Action::MoveGpu { from: Role::Decode }));

        let mut hot = RapidDynamic::new(ControllerConfig::default(), ControlPolicy::DynPowerGpu);
        for i in 0..10 {
            hot.observe_ttft(now - i, 1.6);
            hot.observe_tpot(now - i, 0.4);
        }
        for i in 0..6 {
            hot.on_memory_pressure(now - i, 0, 0.97, 1 << 30);
        }
        assert_eq!(hot.decide(&s), None, "memory-hot decode pool vetoes the shrink");

        // Mostly-low occupancy evictions do not veto.
        let mut mild = RapidDynamic::new(ControllerConfig::default(), ControlPolicy::DynPowerGpu);
        for i in 0..10 {
            mild.observe_ttft(now - i, 1.6);
            mild.observe_tpot(now - i, 0.4);
        }
        for i in 0..6 {
            mild.on_memory_pressure(now - i, 0, 0.5, 1 << 20);
        }
        assert_eq!(mild.decide(&s), Some(Action::MoveGpu { from: Role::Decode }));
    }
}
