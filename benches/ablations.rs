//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   1. cooldown hysteresis (paper §3.3: prevents oscillation) — run the
//!      mixed trace with and without cooldown and count role flips;
//!   2. KV ring capacity (paper §3.2: 32 slots) — sweep slot counts and
//!      show the backpressure/TTFT trade-off;
//!   3. controller power step size — convergence speed vs stability;
//!   4. bursty vs Poisson arrivals (paper §3.3: "stability even under
//!      bursty or unpredictable workloads").
//!
//! `cargo bench --bench ablations`

use rapid::config::presets;
use rapid::experiments::longbench_trace;
use rapid::sim::{self, SimOptions};
use rapid::types::{Slo, MILLIS, SECOND};
use rapid::util::rng::Rng;
use rapid::workload::{build_trace, sonnet::mixed_phases, sonnet::MixedPhasesSpec, sonnet::Sonnet, ArrivalProcess};

fn main() {
    let n: usize = std::env::var("RAPID_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(800);
    let t0 = std::time::Instant::now();
    let mut checks_passed = 0usize;
    let mut checks_total = 0usize;

    // ------------------------------------------------------------------
    // 1. Cooldown hysteresis
    // ------------------------------------------------------------------
    println!("== ablation: controller cooldown (mixed trace, full RAPID) ==");
    let spec = MixedPhasesSpec {
        prefill_heavy_count: n / 2,
        decode_heavy_count: n / 2,
        ..Default::default()
    };
    let trace = mixed_phases(42, spec);
    println!("{:<16}{:>10}{:>12}{:>12}", "cooldown", "decisions", "role flips", "attainment");
    let mut flips_by_cooldown = Vec::new();
    for cd_ms in [0u64, 250, 1000, 2000, 6000] {
        let mut cfg = presets::rapid_600();
        cfg.controller.cooldown = cd_ms * MILLIS;
        cfg.controller.gpu_cooldown = (cd_ms * MILLIS).max(500 * MILLIS);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        let flips = res
            .decisions
            .iter()
            .filter(|(_, d)| d.contains("MoveGpu"))
            .count();
        flips_by_cooldown.push((cd_ms, flips, res.attainment()));
        println!(
            "{:<16}{:>10}{:>12}{:>11.1}%",
            format!("{cd_ms} ms"),
            res.decisions.len(),
            flips,
            res.attainment() * 100.0
        );
    }
    let no_cd = flips_by_cooldown[0].1;
    let paper_cd = flips_by_cooldown[3].1;
    let cooldown_ok = no_cd >= paper_cd;
    checks_total += 1;
    checks_passed += cooldown_ok as usize;
    println!(
        "  [{}] cooldown damps role churn (no-cooldown {} flips >= 2s-cooldown {})\n",
        if cooldown_ok { "PASS" } else { "FAIL" },
        no_cd,
        paper_cd
    );

    // ------------------------------------------------------------------
    // 2. KV ring capacity
    // ------------------------------------------------------------------
    println!("== ablation: KV ring slots (LongBench @1.5 QPS/GPU, 4P-750/4D-450) ==");
    println!("{:<10}{:>12}{:>14}", "slots", "attainment", "p90 TTFT ms");
    let lb = longbench_trace(42, 12.0, n, Slo::paper_default());
    let mut atts = Vec::new();
    for slots in [1usize, 2, 4, 8, 32, 128] {
        let mut cfg = presets::p4_750_d4_450();
        cfg.batch.ring_slots = slots;
        let res = sim::run(&cfg, &lb, &SimOptions::default());
        atts.push((slots, res.attainment()));
        println!(
            "{:<10}{:>11.1}%{:>14.0}",
            slots,
            res.attainment() * 100.0,
            res.ttft_percentile(90.0) / 1000.0
        );
    }
    let tiny = atts[0].1;
    let paper32 = atts.iter().find(|(s, _)| *s == 32).unwrap().1;
    let ring_ok = tiny <= paper32 + 0.02;
    checks_total += 1;
    checks_passed += ring_ok as usize;
    println!(
        "  [{}] starved ring (1 slot) hurts vs the paper's 32 ({:.1}% <= {:.1}%)\n",
        if ring_ok { "PASS" } else { "FAIL" },
        tiny * 100.0,
        paper32 * 100.0
    );

    // ------------------------------------------------------------------
    // 3. Power step size
    // ------------------------------------------------------------------
    println!("== ablation: MovePower step size (mixed trace, DynPower) ==");
    println!("{:<10}{:>12}{:>12}", "step W", "decisions", "attainment");
    for step in [10.0f64, 25.0, 50.0, 100.0, 200.0] {
        let mut cfg = presets::dyn_power_600();
        cfg.controller.power_step_w = step;
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        println!(
            "{:<10}{:>12}{:>11.1}%",
            step,
            res.decisions.len(),
            res.attainment() * 100.0
        );
    }
    println!();

    // ------------------------------------------------------------------
    // 4. Bursty arrivals (robustness, paper §3.3)
    // ------------------------------------------------------------------
    println!("== ablation: Poisson vs bursty arrivals (RAPID vs static) ==");
    let mk_bursty = |seed: u64| {
        let mut ap = ArrivalProcess::bursty(Rng::new(seed), 10.0, 4.0, 0.2);
        let mut sizes = Sonnet::new(Rng::new(seed ^ 5), 3000, 96);
        build_trace(n, &mut ap, &mut sizes, Slo::paper_default())
    };
    let mk_poisson = |seed: u64| {
        let mut ap = ArrivalProcess::poisson(Rng::new(seed), 10.0);
        let mut sizes = Sonnet::new(Rng::new(seed ^ 5), 3000, 96);
        build_trace(n, &mut ap, &mut sizes, Slo::paper_default())
    };
    let mut rows = Vec::new();
    for (label, trace) in [("poisson", mk_poisson(7)), ("bursty", mk_bursty(7))] {
        let stat = sim::run(&presets::p4d4(600.0), &trace, &SimOptions::default());
        let rapid = sim::run(&presets::rapid_600(), &trace, &SimOptions::default());
        println!(
            "  {label:<8} static-uniform {:>5.1}%  rapid {:>5.1}%",
            stat.attainment() * 100.0,
            rapid.attainment() * 100.0
        );
        rows.push((label, stat.attainment(), rapid.attainment()));
    }
    let bursty_gain = rows[1].2 - rows[1].1;
    let bursty_ok = bursty_gain > -0.02;
    checks_total += 1;
    checks_passed += bursty_ok as usize;
    println!(
        "  [{}] RAPID holds its edge under bursty arrivals (gain {:+.1} pts)\n",
        if bursty_ok { "PASS" } else { "FAIL" },
        bursty_gain * 100.0
    );
    let _ = SECOND;
    rapid::bench::write_figure_report(
        "ablations",
        t0.elapsed().as_secs_f64(),
        checks_passed,
        checks_total,
    );
}
