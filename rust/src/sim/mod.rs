//! Discrete-event simulation of the GPU cluster (the testbed
//! substitute). The event-loop core lives in [`crate::cluster`]; this
//! module holds the per-GPU state, role behaviors, event machinery and
//! the `run` façade.

pub mod engine;
pub mod event;
pub mod gpu;
pub mod worker;

pub use engine::{run, run_shared, SimOptions, TRACE_EVENT_CAPACITY};
