//! Power-cap ramp dynamics (paper Fig 4c, §2.2).
//!
//! AMD-SMI power caps are not instantaneous: after a large cap reduction
//! the power-management firmware takes hundreds of milliseconds to settle
//! at the new limit. RAPID therefore (a) lowers *source* GPUs and waits
//! for them to settle before raising *sink* GPUs, and (b) budgets a
//! conservative settle delay into the controller. `CapState` models that
//! transient as a first-order lag with a delta-proportional settle time.

use crate::types::{Micros, Watts, MILLIS};

/// Per-GPU cap state: the target (requested) cap plus the effective cap
/// the firmware currently enforces while ramping.
#[derive(Debug, Clone)]
pub struct CapState {
    target: Watts,
    /// Effective cap at `updated_at` (interpolate forward from here).
    effective_at_update: Watts,
    updated_at: Micros,
    /// Time constant of the exponential approach (us).
    tau: Micros,
}

/// Settle parameters: how long the firmware takes per watt of cap delta.
#[derive(Debug, Clone, Copy)]
pub struct RampProfile {
    /// Base latency of any cap change (command + firmware pickup).
    pub base: Micros,
    /// Additional settle time per watt of downward delta.
    pub per_watt_down: Micros,
    /// Upward changes apply faster (no thermal unwinding needed).
    pub per_watt_up: Micros,
}

impl Default for RampProfile {
    fn default() -> Self {
        // Fig 4c: a 47% cut (≈350 W) takes a few hundred ms to land.
        RampProfile {
            base: 20 * MILLIS,
            per_watt_down: 800, // 350 W down -> ~300 ms
            per_watt_up: 200,
        }
    }
}

impl RampProfile {
    /// Conservative settle estimate for a cap change `from -> to`.
    pub fn settle_time(&self, from: Watts, to: Watts) -> Micros {
        let delta = (from - to).abs();
        let per_watt = if to < from { self.per_watt_down } else { self.per_watt_up };
        self.base + (delta * per_watt as f64) as Micros
    }
}

impl CapState {
    pub fn new(cap: Watts) -> Self {
        CapState {
            target: cap,
            effective_at_update: cap,
            updated_at: 0,
            tau: 0,
        }
    }

    pub fn target(&self) -> Watts {
        self.target
    }

    /// Request a new cap at time `now`; returns the conservative settle
    /// deadline the caller must respect before relying on the new limit.
    pub fn set_target(&mut self, now: Micros, cap: Watts, profile: &RampProfile) -> Micros {
        let current = self.effective(now);
        let settle = profile.settle_time(current, cap);
        self.effective_at_update = current;
        self.updated_at = now;
        self.target = cap;
        // First-order lag: reach ~95% of the delta at the settle deadline.
        self.tau = (settle / 3).max(1);
        now + settle
    }

    /// Effective cap the firmware enforces at `now` (exponential approach).
    pub fn effective(&self, now: Micros) -> Watts {
        let dt = now.saturating_sub(self.updated_at);
        if self.tau == 0 {
            return self.target;
        }
        let frac = 1.0 - (-(dt as f64) / self.tau as f64).exp();
        self.effective_at_update + (self.target - self.effective_at_update) * frac
    }

    /// Has the transient effectively finished (within 1 W)?
    pub fn settled(&self, now: Micros) -> bool {
        (self.effective(now) - self.target).abs() < 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SECOND;

    #[test]
    fn settle_time_proportional_to_delta() {
        let p = RampProfile::default();
        let big = p.settle_time(750.0, 400.0);
        let small = p.settle_time(750.0, 700.0);
        assert!(big > small);
        // Fig 4c anchor: ~350 W cut lands in hundreds of ms.
        assert!((200 * MILLIS..600 * MILLIS).contains(&big), "big={big}");
    }

    #[test]
    fn upward_faster_than_downward() {
        let p = RampProfile::default();
        assert!(p.settle_time(400.0, 750.0) < p.settle_time(750.0, 400.0));
    }

    #[test]
    fn effective_cap_lags_then_settles() {
        let mut c = CapState::new(750.0);
        let deadline = c.set_target(0, 400.0, &RampProfile::default());
        // Immediately after the command, still near the old cap.
        assert!(c.effective(1 * MILLIS) > 700.0);
        // Half-way: in between.
        let mid = c.effective(deadline / 2);
        assert!(mid < 750.0 && mid > 400.0);
        // At the deadline: settled (within ~5%, then clamps close).
        assert!(c.effective(deadline) < 420.0);
        assert!(c.settled(deadline + SECOND));
    }

    #[test]
    fn new_state_is_instantly_settled() {
        let c = CapState::new(600.0);
        assert_eq!(c.effective(0), 600.0);
        assert!(c.settled(0));
    }

    #[test]
    fn retarget_mid_ramp_starts_from_current_effective() {
        let mut c = CapState::new(750.0);
        let d1 = c.set_target(0, 400.0, &RampProfile::default());
        let mid = c.effective(d1 / 4);
        c.set_target(d1 / 4, 700.0, &RampProfile::default());
        // Effective continues from `mid`, not from 400.
        let just_after = c.effective(d1 / 4 + 1);
        assert!((just_after - mid).abs() < 5.0, "{just_after} vs {mid}");
    }

    #[test]
    fn monotone_approach_no_overshoot() {
        let mut c = CapState::new(750.0);
        let deadline = c.set_target(0, 450.0, &RampProfile::default());
        let mut last = c.effective(0);
        for i in 0..50 {
            let t = deadline * i / 50;
            let e = c.effective(t);
            assert!(e <= last + 1e-9, "no overshoot at {t}");
            assert!(e >= 450.0 - 1e-9);
            last = e;
        }
    }
}
