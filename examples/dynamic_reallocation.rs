//! Watch Algorithm 1 work: run full RAPID (DynGPU + DynPower) over the
//! two-phase mixed workload and print every reallocation decision with
//! the cluster state around it — Fig 9(c) as a narrated text timeline.
//!
//! Run: `cargo run --release --example dynamic_reallocation`

use rapid::config::presets;
use rapid::sim::{self, SimOptions};
use rapid::types::SECOND;
use rapid::workload::sonnet::{mixed_phases, MixedPhasesSpec};

fn main() {
    let spec = MixedPhasesSpec {
        prefill_heavy_count: 600,
        decode_heavy_count: 600,
        ..Default::default()
    };
    let trace = mixed_phases(42, spec);
    let boundary = trace.requests[spec.prefill_heavy_count].arrival;
    println!(
        "mixed workload: {} prefill-heavy ({}in/{}out) then {} decode-heavy ({}in/{}out)",
        spec.prefill_heavy_count,
        spec.heavy_shape.0,
        spec.heavy_shape.1,
        spec.decode_heavy_count,
        spec.light_shape.0,
        spec.light_shape.1
    );
    println!(
        "phase boundary at {:.0} s; TPOT SLO tightens 40 ms -> 20 ms there\n",
        boundary as f64 / SECOND as f64
    );

    for preset in [presets::dyn_power_600(), presets::dyn_gpu_600(), presets::rapid_600()] {
        let name = preset.name.clone();
        let res = sim::run(&preset, &trace, &SimOptions::default());
        println!("=== {name}: attainment {:.1}% ===", res.attainment() * 100.0);
        let mut role_iter = res.role_trace.iter().peekable();
        let mut roles = (0, 0);
        for (t, what) in &res.decisions {
            // Roles in effect at this decision.
            while let Some(&&(rt, p, d)) = role_iter.peek() {
                if rt <= *t {
                    roles = (p, d);
                    role_iter.next();
                } else {
                    break;
                }
            }
            let phase = if *t < boundary { "phase1" } else { "phase2" };
            println!(
                "  t={:>6.1}s [{phase}] {}P/{}D  {what}",
                *t as f64 / SECOND as f64,
                roles.0,
                roles.1
            );
        }
        if let Some(&(t, p, d)) = res.role_trace.last() {
            println!(
                "  final roles at {:.1}s: {p} prefill / {d} decode\n",
                t as f64 / SECOND as f64
            );
        }
    }
}
