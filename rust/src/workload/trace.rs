//! Request traces: the unit of experiment input (record/replay-able).

use crate::types::{Micros, Request, RequestId, Slo, SECOND};

/// Conversation membership of one request in a multi-turn trace: which
/// conversation it belongs to and how many of its prompt tokens repeat
/// the prior turn's context (prefix-cacheable on a hit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConvTurn {
    pub req_id: u64,
    pub conv: u64,
    pub prefix_tokens: u32,
}

/// An ordered list of requests with non-decreasing arrival times.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub requests: Vec<Request>,
    /// Conversation structure for multi-turn traces (empty for the
    /// single-turn generators; see [`super::make_multiturn`]).
    pub conv: Vec<ConvTurn>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Wall-clock span from first to last arrival.
    pub fn span(&self) -> Micros {
        match (self.requests.first(), self.requests.last()) {
            (Some(f), Some(l)) => l.arrival - f.arrival,
            _ => 0,
        }
    }

    /// Mean offered rate in requests/second.
    pub fn offered_qps(&self) -> f64 {
        if self.requests.len() < 2 {
            return 0.0;
        }
        (self.requests.len() - 1) as f64 / (self.span() as f64 / SECOND as f64)
    }

    /// Total prompt tokens (prefill demand).
    pub fn total_input_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.input_tokens as u64).sum()
    }

    /// Total output tokens (decode demand).
    pub fn total_output_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.output_tokens as u64).sum()
    }

    /// Override every request's SLO (Fig 7's SLO-scale sweeps).
    pub fn with_slo(mut self, slo: Slo) -> Trace {
        for r in &mut self.requests {
            r.slo = slo;
        }
        self
    }

    /// Serialize to a simple CSV (id,arrival_us,in,out,ttft_slo,tpot_slo).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("id,arrival_us,input_tokens,output_tokens,ttft_slo_us,tpot_slo_us\n");
        for r in &self.requests {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                r.id.0, r.arrival, r.input_tokens, r.output_tokens, r.slo.ttft, r.slo.tpot
            ));
        }
        out
    }

    /// Parse the CSV produced by [`Trace::to_csv`].
    pub fn from_csv(text: &str) -> Result<Trace, String> {
        let mut requests = Vec::new();
        for (i, line) in text.lines().enumerate().skip(1) {
            if line.trim().is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split(',').collect();
            if fields.len() != 6 {
                return Err(format!("line {}: expected 6 fields", i + 1));
            }
            let parse =
                |s: &str| s.trim().parse::<u64>().map_err(|e| format!("line {}: {e}", i + 1));
            requests.push(Request {
                id: RequestId(parse(fields[0])?),
                arrival: parse(fields[1])?,
                input_tokens: parse(fields[2])? as u32,
                output_tokens: parse(fields[3])? as u32,
                slo: Slo::new(parse(fields[4])?, parse(fields[5])?),
                tenant: 0,
            });
        }
        Ok(Trace { requests, ..Trace::default() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace3() -> Trace {
        Trace {
            requests: vec![
                Request {
                    id: RequestId(0),
                    arrival: 0,
                    input_tokens: 100,
                    output_tokens: 10,
                    slo: Slo::paper_default(),
                    tenant: 0,
                },
                Request {
                    id: RequestId(1),
                    arrival: SECOND,
                    input_tokens: 200,
                    output_tokens: 20,
                    slo: Slo::paper_default(),
                    tenant: 0,
                },
                Request {
                    id: RequestId(2),
                    arrival: 2 * SECOND,
                    input_tokens: 300,
                    output_tokens: 30,
                    slo: Slo::paper_default(),
                    tenant: 0,
                },
            ],
            ..Trace::default()
        }
    }

    #[test]
    fn aggregates() {
        let t = trace3();
        assert_eq!(t.span(), 2 * SECOND);
        assert!((t.offered_qps() - 1.0).abs() < 1e-9);
        assert_eq!(t.total_input_tokens(), 600);
        assert_eq!(t.total_output_tokens(), 60);
    }

    #[test]
    fn csv_round_trip() {
        let t = trace3();
        let csv = t.to_csv();
        let back = Trace::from_csv(&csv).unwrap();
        assert_eq!(back.len(), 3);
        for (a, b) in t.requests.iter().zip(&back.requests) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.input_tokens, b.input_tokens);
            assert_eq!(a.slo.tpot, b.slo.tpot);
        }
    }

    #[test]
    fn from_csv_rejects_malformed() {
        assert!(Trace::from_csv("header\n1,2,3\n").is_err());
        assert!(Trace::from_csv("header\na,b,c,d,e,f\n").is_err());
    }

    #[test]
    fn with_slo_overrides_all() {
        let t = trace3().with_slo(Slo::new(1, 2));
        assert!(t.requests.iter().all(|r| r.slo.ttft == 1 && r.slo.tpot == 2));
    }
}
