//! The hot-path performance suite — the cases `rapid bench` runs
//! in-process and the CI `perf-gate` job regresses against
//! `benches/baseline.json` (DESIGN.md §10).
//!
//! Cases cover exactly the paths the DES core exercises per event:
//! KV-ring slot traffic, router picks, prefill batch formation, the
//! Algorithm-1 decide tick, the streaming stats the controller reads,
//! the sort-based exact percentile those paths avoid, and a whole-sim
//! run reported in simulated events per second.

use std::collections::VecDeque;

use crate::bench::{bench, bench_batch, BenchReport, Timing};
use crate::config::{presets, BatchConfig, ControlPolicy, ControllerConfig};
use crate::coordinator::batcher::form_prefill_batch_into;
use crate::coordinator::router::{
    pick_decode_prefer_node, pick_prefill, LoadIndex, LoadKey, WorkerLoad,
};
use crate::coordinator::{Controller, Snapshot};
use crate::kv::KvRing;
use crate::sim::{self, SimOptions};
use crate::types::{GpuId, Request, RequestId, Slo, SECOND};
use crate::util::rng::Rng;
use crate::util::stats::{percentile, LatencyHistogram, SlidingWindow};
use crate::workload::{build_trace, longbench::LongBench, sonnet::Sonnet, ArrivalProcess};

/// Name of the whole-sim case (`per_sec` = simulated events/second) —
/// the headline number `BENCH_hotpath.json` tracks across PRs.
pub const WHOLE_SIM: &str = "sim/whole_run";

/// The same events/second headline on the 1024-GPU kilo-node fleet
/// (`configs/kilo-node.toml`) — the scale the sub-linear DES paths are
/// proved at (DESIGN.md §13).
pub const WHOLE_SIM_1024: &str = "sim/whole_1024";

/// Suite knobs. Defaults match what CI gates on; tests shrink the
/// budgets to keep the suite exercisable in debug builds.
#[derive(Debug, Clone)]
pub struct SuiteConfig {
    /// Only run cases whose name contains this substring.
    pub filter: Option<String>,
    /// Per-case timing budget (the whole-sim case gets 5x).
    pub target_ms: u64,
    /// Iteration cap per case.
    pub max_iters: usize,
    /// Requests in the whole-sim case's trace.
    pub sim_requests: usize,
}

impl Default for SuiteConfig {
    fn default() -> Self {
        SuiteConfig {
            filter: None,
            target_ms: 300,
            max_iters: 5_000_000,
            sim_requests: 400,
        }
    }
}

impl SuiteConfig {
    /// Does the active filter select this case? Also used by the gate to
    /// avoid flagging intentionally-filtered-out baseline cases.
    pub fn wants(&self, name: &str) -> bool {
        self.filter.as_deref().map_or(true, |f| name.contains(f))
    }
}

/// Run the suite (honoring the filter) and collect a [`BenchReport`].
pub fn run_suite(cfg: &SuiteConfig) -> BenchReport {
    let mut report = BenchReport::new("hotpath");
    report
        .meta
        .insert("target_ms".into(), cfg.target_ms.to_string());
    report
        .meta
        .insert("sim_requests".into(), cfg.sim_requests.to_string());
    let mut push = |t: Timing| report.entries.push(t);

    // --- KV ring round trip ------------------------------------------
    if cfg.wants("kv_ring/publish_consume") {
        let ring: KvRing<u64> = KvRing::new(32);
        push(bench("kv_ring/publish_consume", cfg.target_ms, cfg.max_iters, || {
            ring.try_publish(1).unwrap();
            std::hint::black_box(ring.try_consume());
        }));
    }

    // --- router -------------------------------------------------------
    let loads: Vec<WorkerLoad> = (0..8)
        .map(|i| WorkerLoad {
            gpu: GpuId(i),
            node: i / 4,
            queued_tokens: (i as u64 * 37) % 5000,
            requests: i % 5,
            accepting: i != 3,
            perf_scale: if i % 2 == 0 { 1.0 } else { 0.55 },
            mem_pressure: 0.0,
        })
        .collect();
    if cfg.wants("router/pick_prefill_8") {
        push(bench("router/pick_prefill_8", cfg.target_ms, cfg.max_iters, || {
            std::hint::black_box(pick_prefill(std::hint::black_box(&loads)));
        }));
    }
    if cfg.wants("router/pick_decode_prefer_node_8") {
        push(bench(
            "router/pick_decode_prefer_node_8",
            cfg.target_ms,
            cfg.max_iters,
            || {
                std::hint::black_box(pick_decode_prefer_node(std::hint::black_box(&loads), 1));
            },
        ));
    }

    // --- indexed routing at kilo-node scale -----------------------------
    // 1024 workers over 128 nodes, mixed per-SKU scales. Each iteration
    // touches one worker's key (the enqueue/step cadence of the DES)
    // and re-picks — the maintained-index path `Cluster::pick_*` rides,
    // whose cost must not grow with the fleet.
    let scales = [1.0, 1.45, 0.62, 2.0];
    if cfg.wants("router/pick_prefill_1024") {
        let mut idx = LoadIndex::new(1024, 128);
        for i in 0..1024 {
            let key = LoadKey::prefill((i as u64 * 613) % 9000, i % 7, scales[i % 4], 0.0, i);
            idx.update(i, i / 8, Some(key));
        }
        let mut k = 0usize;
        let mut t = 0u64;
        push(bench("router/pick_prefill_1024", cfg.target_ms, cfg.max_iters, || {
            k = (k + 257) & 1023;
            t = t.wrapping_add(997);
            let key = LoadKey::prefill(t % 9000, (t % 7) as usize, scales[k % 4], 0.0, k);
            idx.update(k, k / 8, Some(key));
            std::hint::black_box(idx.pick(None));
        }));
    }
    if cfg.wants("router/pick_decode_1024") {
        let mut idx = LoadIndex::new(1024, 128);
        for i in 0..1024 {
            let key = LoadKey::decode(i % 60, (i as u64 * 311) % 4000, scales[i % 4], 0.0, i);
            idx.update(i, i / 8, Some(key));
        }
        let mut k = 0usize;
        let mut t = 0u64;
        push(bench("router/pick_decode_1024", cfg.target_ms, cfg.max_iters, || {
            k = (k + 257) & 1023;
            t = t.wrapping_add(997);
            let key = LoadKey::decode((t % 60) as usize, t % 4000, scales[k % 4], 0.0, k);
            idx.update(k, k / 8, Some(key));
            std::hint::black_box(idx.pick_prefer_node((k >> 3) & 127, None));
        }));
    }

    // --- batch formation ----------------------------------------------
    if cfg.wants("batcher/form_prefill_batch") {
        let bcfg = BatchConfig::default();
        let mk_queue = || -> VecDeque<Request> {
            (0..64)
                .map(|i| Request {
                    id: RequestId(i),
                    arrival: 0,
                    input_tokens: 500 + (i as u32 * 131) % 3000,
                    output_tokens: 64,
                    slo: Slo::paper_default(),
                    tenant: 0,
                })
                .collect()
        };
        let mut q = mk_queue();
        // The zero-allocation `_into` form with a reused scratch buffer —
        // exactly how `kick_prefill` forms batches.
        let mut scratch = Vec::new();
        push(bench("batcher/form_prefill_batch", cfg.target_ms, cfg.max_iters, || {
            if q.len() < 8 {
                q = mk_queue();
            }
            std::hint::black_box(form_prefill_batch_into(&mut q, &bcfg, &mut scratch));
        }));
    }

    // --- per-SKU model lookup (fleet hot path) --------------------------
    if cfg.wants("fleet/model_lookup") {
        // The double-index every sim event pays on a heterogeneous
        // fleet: GPU -> SKU -> model, plus one curve evaluation. Must
        // stay allocation-free (tracked against the router picks, which
        // share the same flat-lookup budget).
        let mut hetero = presets::rapid_600();
        hetero.fleet = Some(
            crate::fleet::FleetConfig::parse_mix("mi300x:2+a100:2+mi300x:2+a100:2", &[])
                .expect("builtin mix parses"),
        );
        let fleet = crate::fleet::Fleet::of_config(&hetero);
        let mut gi = 0usize;
        push(bench("fleet/model_lookup", cfg.target_ms, cfg.max_iters, || {
            gi = (gi + 5) & 7;
            let m = fleet.model(std::hint::black_box(gi));
            std::hint::black_box(m.prefill_speedup(std::hint::black_box(612.0)));
        }));
    }

    // --- environment disturbance application -----------------------------
    if cfg.wants("env/event_apply") {
        // The work one mid-run EnvEvent does on the power books: a
        // cluster-budget step (shed across 8 GPUs) plus a thermal
        // derate/restore. Must stay allocation-free — it runs inside
        // the DES event loop (see cluster::env::on_env).
        let mut pm = crate::power::PowerManager::new(&[600.0; 8], 4800.0, true, 400.0, 750.0);
        let mut t: u64 = 0;
        let mut low = false;
        push(bench("env/event_apply", cfg.target_ms, cfg.max_iters, || {
            t += 1000;
            low = !low;
            pm.set_cluster_budget(t, if low { 4000.0 } else { 4800.0 });
            pm.derate_gpu(t, GpuId(3), if low { 500.0 } else { 750.0 });
            std::hint::black_box(pm.target(GpuId(3)));
        }));
    }

    // --- power books at kilo-node scale ---------------------------------
    if cfg.wants("power/poll_1024") {
        // 1024 GPUs / 128 nodes: one cap step plus one poll per
        // iteration — the cadence the kilo-node DES drives the power
        // books at. The budget checks inside `set_cap` ride the cached
        // committed sums (refolded only when a mutation dirtied them)
        // instead of rebuilding a per-GPU vector per call.
        let node_of: Vec<usize> = (0..1024).map(|i| i / 8).collect();
        let mut pm = crate::power::PowerManager::with_nodes(
            &[550.0; 1024],
            node_of,
            vec![4800.0; 128],
            128.0 * 4800.0,
            true,
            400.0,
            750.0,
        );
        let mut k = 0usize;
        let mut t: u64 = 0;
        let mut up = false;
        push(bench("power/poll_1024", cfg.target_ms, cfg.max_iters, || {
            k = (k + 257) & 1023;
            t += 1000;
            up = !up;
            // 8 x 600 W fills a node budget exactly, so the raise always
            // clears both budget checks.
            pm.set_cap(t, GpuId(k), if up { 600.0 } else { 550.0 }).unwrap();
            std::hint::black_box(pm.poll(t).len());
        }));
    }

    // --- KV pool eviction (mem hot path) ---------------------------------
    if cfg.wants("mem/pool_evict") {
        // The admission-side reserve -> LRU demote -> finish-as-cached
        // cycle a capacity-bound decode pool pays per context
        // (DESIGN.md §14). The pool sits exactly at capacity, so every
        // reserve demotes one block to the remote tier; cycling a fixed
        // conversation set keeps the tier pools bounded (a re-finished
        // conversation consumes its stale demoted block).
        let mc = crate::mem::MemConfig { hbm_gb: Some(0.064), ..Default::default() };
        let mut pool = crate::mem::MemState::new(mc, &[Some(0.064)]);
        const BLOCK: u64 = 8_000_000;
        for conv in 0..8u64 {
            pool.reserve(0, BLOCK).expect("warmup fits");
            pool.finish(0, Some(conv), BLOCK, 512);
        }
        let mut conv = 8u64;
        push(bench("mem/pool_evict", cfg.target_ms, cfg.max_iters, || {
            conv = (conv + 1) % 64;
            let ev = pool.reserve(0, BLOCK).expect("a cached victim always exists");
            std::hint::black_box(ev.bytes);
            pool.finish(0, Some(conv), BLOCK, 512);
        }));
    }

    // --- observability event recording -----------------------------------
    if cfg.wants("obs/record_event") {
        // What a traced run pays per record site: one counter bump plus
        // a ring store (append below capacity, overwrite past it). The
        // ring here is small enough that the steady state exercises the
        // overwrite path — the one every long traced run lives in. Must
        // stay allocation-free and within the flat-lookup budget of the
        // router picks.
        let mut sink = crate::obs::ObsSink::new(4096, (0..8u32).map(|i| i / 4).collect());
        let mut t: u64 = 0;
        push(bench("obs/record_event", cfg.target_ms, cfg.max_iters, || {
            t += 1;
            sink.record(std::hint::black_box(crate::obs::ObsEvent::GpuStep {
                at: t,
                gpu: (t % 8) as usize,
                node: ((t % 8) / 4) as u32,
                until: t + 900,
                role: crate::types::Role::Decode,
                reqs: 12,
                tokens: 12,
            }));
            std::hint::black_box(sink.len());
        }));
    }

    // --- controller tick -----------------------------------------------
    if cfg.wants("controller/decide") {
        let mut ctl = Controller::new(ControllerConfig::default(), ControlPolicy::DynPowerGpu);
        for i in 0..64 {
            ctl.observe_ttft(i * 1000, 1.2);
            ctl.observe_tpot(i * 1000, 0.5);
        }
        let snap = Snapshot {
            now: 10 * SECOND,
            prefill_queue: 12,
            decode_queue: 0,
            prefill_gpus: 4,
            decode_gpus: 4,
            prefill_power_saturated: false,
            decode_power_saturated: false,
        };
        push(bench("controller/decide", cfg.target_ms, cfg.max_iters, || {
            let mut s = snap.clone();
            s.now += 1;
            std::hint::black_box(ctl.decide(&s));
        }));
    }

    // --- streaming stats the per-tick paths lean on ---------------------
    if cfg.wants("stats/window_frac_above_512") {
        let mut w = SlidingWindow::new(10 * SECOND);
        for i in 0..512u64 {
            w.push(i * 1000, (i % 97) as f64 / 60.0);
        }
        push(bench("stats/window_frac_above_512", cfg.target_ms, cfg.max_iters, || {
            std::hint::black_box(w.frac_above(512_000, 1.0));
        }));
    }
    if cfg.wants("stats/histogram_record") {
        let mut h = LatencyHistogram::new(1.0, 1e6, 128);
        let mut x = 1.0f64;
        push(bench("stats/histogram_record", cfg.target_ms, cfg.max_iters, || {
            x = if x > 9e5 { 1.0 } else { x * 1.37 };
            h.record(std::hint::black_box(x));
        }));
    }
    // The sort-per-call cost the streaming paths avoid — tracked so the
    // gap stays visible in the report.
    if cfg.wants("stats/percentile_sort_1k") {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 2654435761u64) % 10007) as f64).collect();
        push(bench("stats/percentile_sort_1k", cfg.target_ms, cfg.max_iters / 100, || {
            std::hint::black_box(percentile(std::hint::black_box(&xs), 90.0));
        }));
    }

    // --- slab request-store churn ----------------------------------------
    if cfg.wants("cluster/slab_churn") {
        use crate::cluster::store::{ReqState, RequestStore};
        // One insert + one oldest-remove per iteration with ~32 resident
        // — the arrival/completion cadence the generational slab pays on
        // every request lifecycle. Must stay allocation-free (free-list
        // reuse) and O(1) despite the ABA generation checks.
        let req = Request {
            id: RequestId(0),
            arrival: 0,
            input_tokens: 1024,
            output_tokens: 64,
            slo: Slo::paper_default(),
            tenant: 0,
        };
        let mut store = RequestStore::with_capacity(64);
        let mut slots: VecDeque<_> =
            (0..32).map(|_| store.insert(ReqState::new(req))).collect();
        push(bench("cluster/slab_churn", cfg.target_ms, cfg.max_iters, || {
            slots.push_back(store.insert(ReqState::new(req)));
            let old = slots.pop_front().unwrap();
            std::hint::black_box(store.remove(old).tokens_done);
        }));
    }

    // --- study-cell trace construction -----------------------------------
    if cfg.wants("workload/trace_expand_mt") {
        // What one arena miss costs: LongBench sampling plus the
        // multi-turn rewrite — the work `Study::run` now does once per
        // unique trace fingerprint instead of once per cell. `batch` is
        // the request count, so `per_sec` reads as requests expanded/s.
        const N: usize = 400;
        push(bench_batch(
            "workload/trace_expand_mt",
            N,
            cfg.target_ms,
            cfg.max_iters.min(2000),
            || {
                let mut root = Rng::new(11);
                let mut ap = ArrivalProcess::poisson(root.fork(1), 12.0);
                let mut sizes = LongBench::new(root.fork(2));
                let mut trace = build_trace(N, &mut ap, &mut sizes, Slo::paper_default());
                crate::workload::make_multiturn(&mut trace, 4, 0.6);
                std::hint::black_box(trace.len());
            },
        ));
    }

    // --- whole-study throughput ------------------------------------------
    if cfg.wants("study/cells_per_sec") {
        // A 2x2 policy x rate grid on rapid-600 through the shared trace
        // arena, serial. `per_sec` is study cells per second — the
        // headline number for study-scale refactors, reported alongside
        // events/s in the CI perf-gate summary.
        use crate::scenario::{Axis, Scenario, Study};
        let scen = Scenario::new("bench-cells", presets::rapid_600())
            .requests(cfg.sim_requests.min(120))
            .seed(3)
            .axis(Axis::Policy(vec![ControlPolicy::Static, ControlPolicy::DynPowerGpu]))
            .axis(Axis::RatePerGpu(vec![1.0, 1.5]));
        let study = Study::new(scen);
        push(bench_batch(
            "study/cells_per_sec",
            4,
            cfg.target_ms * 5,
            cfg.max_iters.min(500),
            || {
                std::hint::black_box(study.run(Some(1)).unwrap().cells.len());
            },
        ));
    }

    // --- end-to-end sim throughput -------------------------------------
    if cfg.wants(WHOLE_SIM) {
        let sim_cfg = presets::rapid_600();
        let mut ap = ArrivalProcess::poisson(Rng::new(1), 10.0);
        let mut sizes = Sonnet::new(Rng::new(2), 2048, 64);
        let trace = build_trace(cfg.sim_requests, &mut ap, &mut sizes, Slo::paper_default());
        // One probe run pins the exact event count this trace produces;
        // `per_sec` of the timing is then simulated events per second.
        let events = sim::run(&sim_cfg, &trace, &SimOptions::default()).sim_events as usize;
        push(bench_batch(
            WHOLE_SIM,
            events.max(1),
            cfg.target_ms * 5,
            cfg.max_iters.min(1000),
            || {
                std::hint::black_box(sim::run(&sim_cfg, &trace, &SimOptions::default()));
            },
        ));
    }

    // --- end-to-end sim throughput, kilo-node fleet ----------------------
    if cfg.wants(WHOLE_SIM_1024) {
        // Same probe-then-batch pattern on 128 rapid-600 nodes (1024
        // GPUs) near the knee (1.5 req/s/GPU): `per_sec` is simulated
        // events per second at the scale the indexed routing, cached
        // power sums and calendar queue are built for.
        let sim_cfg = presets::scaled_to_nodes(presets::rapid_600(), 128);
        let mut ap = ArrivalProcess::poisson(Rng::new(7), 1536.0);
        let mut sizes = Sonnet::new(Rng::new(8), 2048, 64);
        let trace = build_trace(cfg.sim_requests, &mut ap, &mut sizes, Slo::paper_default());
        let events = sim::run(&sim_cfg, &trace, &SimOptions::default()).sim_events as usize;
        push(bench_batch(
            WHOLE_SIM_1024,
            events.max(1),
            cfg.target_ms * 5,
            cfg.max_iters.min(200),
            || {
                std::hint::black_box(sim::run(&sim_cfg, &trace, &SimOptions::default()));
            },
        ));
    }

    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(filter: &str) -> SuiteConfig {
        SuiteConfig {
            filter: Some(filter.to_string()),
            target_ms: 3,
            max_iters: 100,
            sim_requests: 20,
        }
    }

    #[test]
    fn filter_selects_cases() {
        let rep = run_suite(&tiny("router"));
        assert_eq!(rep.entries.len(), 4);
        assert!(rep.entries.iter().all(|t| t.name.contains("router")));
        assert!(rep.entries.iter().all(|t| t.iters >= 3 && t.mean_us >= 0.0));
        assert!(run_suite(&tiny("no-such-case")).entries.is_empty());
    }

    #[test]
    fn kilo_scale_cases_run() {
        let rep = run_suite(&tiny("1024"));
        for name in ["router/pick_prefill_1024", "router/pick_decode_1024", "power/poll_1024"] {
            let t = rep.entry(name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(t.iters >= 3 && t.per_sec() > 0.0, "{name}");
        }
        let t = rep.entry(WHOLE_SIM_1024).expect("kilo whole-sim entry");
        assert!(t.batch > 100, "a kilo-node sim still has many events");
        assert!(t.per_sec() > 0.0);
    }

    #[test]
    fn fleet_lookup_case_runs() {
        let rep = run_suite(&tiny("fleet/model_lookup"));
        let t = rep.entry("fleet/model_lookup").expect("fleet entry");
        assert!(t.iters >= 3 && t.per_sec() > 0.0);
    }

    #[test]
    fn mem_pool_evict_case_runs() {
        let rep = run_suite(&tiny("mem/pool_evict"));
        let t = rep.entry("mem/pool_evict").expect("mem entry");
        assert!(t.iters >= 3 && t.mean_us >= 0.0);
    }

    #[test]
    fn env_event_apply_case_runs() {
        let rep = run_suite(&tiny("env/event_apply"));
        let t = rep.entry("env/event_apply").expect("env entry");
        assert!(t.iters >= 3 && t.mean_us >= 0.0);
    }

    #[test]
    fn obs_record_case_runs() {
        let rep = run_suite(&tiny("obs/record_event"));
        let t = rep.entry("obs/record_event").expect("obs entry");
        assert!(t.iters >= 3 && t.per_sec() > 0.0);
    }

    #[test]
    fn study_scale_cases_run() {
        for name in ["cluster/slab_churn", "workload/trace_expand_mt", "study/cells_per_sec"] {
            let rep = run_suite(&tiny(name));
            let t = rep.entry(name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(t.per_sec() > 0.0, "{name}");
        }
    }

    #[test]
    fn whole_sim_case_reports_event_throughput() {
        let rep = run_suite(&tiny(WHOLE_SIM));
        let t = rep.entry(WHOLE_SIM).expect("whole-sim entry");
        assert!(t.batch > 100, "a 20-request sim still has many events");
        assert!(t.per_sec() > 0.0);
    }
}
