//! Hot-path microbenchmarks (DESIGN.md §10) — a thin wrapper over the
//! in-process suite `rapid bench` runs, so this target, the CLI and the
//! CI perf gate all measure the same cases:
//!   * KV ring publish/consume round-trip,
//!   * router picks over an 8-GPU load table,
//!   * prefill batch formation,
//!   * controller decide() tick,
//!   * the streaming stats the per-tick paths lean on,
//!   * whole-sim throughput in simulated events/sec.
//!
//! `cargo bench --bench hotpath_micro [-- --filter F] [-- --json out.json]`

use rapid::bench::hotpath::{run_suite, SuiteConfig, WHOLE_SIM};
use rapid::bench::{arg_value, json_arg};

fn main() {
    let cfg = SuiteConfig {
        filter: arg_value("filter"),
        sim_requests: std::env::var("RAPID_BENCH_REQUESTS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(400),
        ..SuiteConfig::default()
    };
    let report = run_suite(&cfg);
    for t in &report.entries {
        println!("{}", t.report());
    }
    if let Some(t) = report.entry(WHOLE_SIM) {
        println!(
            "\n{}: {:.2} M simulated events/s ({} events/run)",
            WHOLE_SIM,
            t.per_sec() / 1e6,
            t.batch
        );
    }
    if let Some(path) = json_arg() {
        report.write(&path).expect("write bench json");
        println!("wrote {path}");
    }
}
