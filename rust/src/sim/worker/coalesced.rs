//! Coalesced worker behavior: Sarathi-style chunked prefill co-scheduled
//! with the resident decode batch — the vLLM baseline the paper
//! disaggregates away from.

use crate::cluster::Cluster;
use crate::coordinator::batcher;
use crate::sim::event::Event;
use crate::sim::worker::RoleBehavior;
use crate::types::{GpuId, Role};

pub struct CoalescedBehavior;

impl RoleBehavior for CoalescedBehavior {
    fn role(&self) -> Role {
        Role::Coalesced
    }

    fn kick(&self, cl: &mut Cluster, gi: usize) {
        cl.kick_coalesced(gi);
    }

    fn on_step_done(&self, cl: &mut Cluster, gi: usize, epoch: u64) {
        cl.on_coalesced_step(gi, epoch);
    }
}

impl Cluster {
    /// Start the next coalesced step if possible, then re-sync the hot
    /// mirror: chunk advances, queue pops and admissions all change
    /// tick-visible fields without passing through `reindex` (coalesced
    /// workers are not in the routing indexes).
    pub(crate) fn kick_coalesced(&mut self, gi: usize) {
        self.kick_coalesced_inner(gi);
        self.sync_hot(gi);
    }

    fn kick_coalesced_inner(&mut self, gi: usize) {
        // Chunk budget is a per-SKU constant (heterogeneous fleets may
        // mix chunk sizes; the implicit fleet reads cfg.perf as before).
        let chunk_budget = self.model_of(gi).cfg().chunk_tokens;
        let store = &mut self.store;
        let g = &mut self.gpus[gi];
        if g.busy || g.failed || g.role != Role::Coalesced {
            return;
        }
        if g.co_queue.is_empty() && g.dec_active.is_empty() && g.dec_pending.is_empty() {
            return;
        }
        // Admit locally-finished prefills (they sit in dec_pending).
        let n = batcher::decode_admissions(
            g.dec_active.len(),
            g.dec_pending.len(),
            &self.cfg.batch,
        );
        for _ in 0..n {
            let s = g.dec_pending.pop_front().unwrap();
            g.dec_active.push(s);
        }
        let admitted = n;
        // Take the next prefill chunk directly over the slot queue —
        // same packing as `batcher::take_chunk` (head-first, spilling
        // into later prompts when the head completes inside the budget)
        // but in place: no cloned progress queue per iteration.
        let now = self.now;
        let done_before = g.co_queue.front().map_or(0, |&s| store.get(s).chunk_done);
        let mut used = 0u32;
        while used < chunk_budget {
            let Some(&head) = g.co_queue.front() else { break };
            let st = store.get_mut(head);
            if st.started.is_none() {
                // The chunk reached this prompt: its execution starts now.
                st.started = Some(now);
            }
            let adv = st.chunk_advance(chunk_budget - used);
            used += adv;
            g.co_tokens -= adv as u64;
            if st.chunk_complete() {
                let s = g.co_queue.pop_front().unwrap();
                g.co_finishing.push(s);
            } else {
                break;
            }
        }
        g.co_step_chunk = used;
        if used == 0 && g.dec_active.is_empty() {
            return; // nothing to do this iteration
        }
        g.busy = true;
        let batch = g.dec_active.len();
        let ctx = g.mean_ctx(store);
        let power = self.power.effective(GpuId(gi), self.now);
        let t = self
            .model_of(gi)
            .coalesced_step_time(used, done_before, batch, ctx, power);
        self.gpus[gi].dec_step_time = t;
        let epoch = self.gpus[gi].epoch;
        self.events
            .push(self.now + t, Event::StepDone { gpu: gi, epoch });
        if self.obs.is_some() {
            // Admitted slots sit at the tail of `dec_active`; the chunk
            // loop above never reorders the decode batch.
            for k in 0..admitted {
                let idx = self.gpus[gi].dec_active.len() - admitted + k;
                let s = self.gpus[gi].dec_active[idx];
                let req = self.store.get(s).req.id.0;
                if let Some(o) = self.obs.as_deref_mut() {
                    o.record(crate::obs::ObsEvent::DecodeAdmit { at: self.now, req, gpu: gi });
                }
            }
            let node = self.node_of(gi) as u32;
            let at = self.now;
            if let Some(o) = self.obs.as_deref_mut() {
                o.record(crate::obs::ObsEvent::GpuStep {
                    at,
                    gpu: gi,
                    node,
                    until: at + t,
                    role: Role::Coalesced,
                    reqs: batch as u32,
                    // Chunked prefill tokens plus one decode token per
                    // active request this iteration.
                    tokens: used as u64 + batch as u64,
                });
            }
        }
    }

    pub(crate) fn on_coalesced_step(&mut self, gi: usize, epoch: u64) {
        if self.gpus[gi].epoch != epoch {
            return;
        }
        let step = self.gpus[gi].dec_step_time;
        self.gpus[gi].busy = false;
        // Prefill completions: first token now; join local decode.
        // Drain-and-restore keeps co_finishing's capacity across steps.
        let mut finishing = std::mem::take(&mut self.gpus[gi].co_finishing);
        let dynamic = self.policy.is_dynamic();
        for slot in finishing.drain(..) {
            let (id, arrival, ttft_slo, output_tokens, started) = {
                let st = self.store.get(slot);
                (
                    st.req.id.0,
                    st.req.arrival,
                    st.req.slo.ttft,
                    st.req.output_tokens,
                    st.started.unwrap_or(self.now),
                )
            };
            if dynamic {
                let ratio = (self.now - arrival) as f64 / ttft_slo as f64;
                self.policy.observe_ttft(self.now, ratio);
            }
            if output_tokens <= 1 {
                let now = self.now;
                let st = self.store.remove(slot);
                self.push_record(&st.req, started, now, now);
                if let Some(o) = self.obs.as_deref_mut() {
                    o.record(crate::obs::ObsEvent::FirstToken { at: now, req: id, gpu: gi });
                    o.record(crate::obs::ObsEvent::Finish {
                        at: now,
                        req: id,
                        gpu: gi,
                        tokens: output_tokens,
                    });
                }
                continue;
            }
            {
                let st = self.store.get_mut(slot);
                st.prefill_start = started;
                st.first_token = self.now;
                st.tokens_done = 1;
                st.cached_tokens = 0;
            }
            if let Some(o) = self.obs.as_deref_mut() {
                o.record(crate::obs::ObsEvent::FirstToken { at: self.now, req: id, gpu: gi });
            }
            self.gpus[gi].dec_pending.push_back(slot);
        }
        self.gpus[gi].co_finishing = finishing;
        // Decode completions, into the shared finished-items scratch.
        let mut ratio_sum = 0.0;
        let mut finished = std::mem::take(&mut self.scratch_done);
        finished.clear();
        let mut tpot_sample = None;
        {
            let store = &mut self.store;
            let g = &mut self.gpus[gi];
            let mut idx = 0;
            while idx < g.dec_active.len() {
                let st = store.get_mut(g.dec_active[idx]);
                st.tokens_done += 1;
                ratio_sum += step as f64 / st.req.slo.tpot as f64;
                if st.remaining() == 0 {
                    finished.push(g.dec_active.swap_remove(idx));
                } else {
                    idx += 1;
                }
            }
            let n = g.dec_active.len() + finished.len();
            if n > 0 {
                tpot_sample = Some(ratio_sum / n as f64);
            }
        }
        if dynamic {
            if let Some(ratio) = tpot_sample {
                self.policy.observe_tpot(self.now, ratio);
            }
        }
        for slot in finished.drain(..) {
            let now = self.now;
            let st = self.store.remove(slot);
            self.push_record(&st.req, st.prefill_start, st.first_token, now);
            if let Some(o) = self.obs.as_deref_mut() {
                o.record(crate::obs::ObsEvent::Finish {
                    at: now,
                    req: st.req.id.0,
                    gpu: gi,
                    tokens: st.req.output_tokens,
                });
            }
        }
        self.scratch_done = finished;
        self.kick_coalesced(gi);
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use crate::cluster::store::ReqState;
    use crate::cluster::Cluster;
    use crate::config::presets;
    use crate::sim::engine::SimOptions;
    use crate::types::{Request, RequestId, Slo};
    use crate::workload::Trace;

    fn req(id: u64, input: u32) -> Request {
        Request {
            id: RequestId(id),
            arrival: 0,
            input_tokens: input,
            output_tokens: 8,
            slo: Slo::paper_default(),
            tenant: 0,
        }
    }

    fn cluster() -> Cluster {
        Cluster::new(
            presets::coalesced(750.0),
            Arc::new(Trace::default()),
            SimOptions::default(),
        )
    }

    #[test]
    fn chunk_packs_across_prompts_in_place() {
        // The Sarathi packing invariant the in-place loop must keep: a
        // head that finishes inside the budget spills exactly the
        // remaining budget into the next prompt.
        let mut cl = cluster();
        let budget = cl.cfg.perf.chunk_tokens;
        assert!(budget > 300, "test assumes the first prompt fits one chunk");
        for (id, toks) in [(0u64, 300u32), (1, 5000)] {
            let slot = cl.store.insert(ReqState::new(req(id, toks)));
            cl.gpus[0].co_tokens += toks as u64;
            cl.gpus[0].co_queue.push_back(slot);
        }
        cl.sync_hot(0);
        cl.kick_coalesced(0);
        let g = &cl.gpus[0];
        assert_eq!(g.co_step_chunk, budget);
        assert_eq!(g.co_finishing.len(), 1);
        let done = cl.store.get(g.co_finishing[0]);
        assert_eq!(done.req.id.0, 0);
        assert_eq!(done.started, Some(0), "head's started stamp");
        let head = cl.store.get(*g.co_queue.front().unwrap());
        assert_eq!(head.req.id.0, 1);
        assert_eq!(head.chunk_done, budget - 300);
        assert_eq!(head.started, Some(0), "reached prompt is marked started");
        assert!(g.busy);
        // The incremental counter tracked both advances.
        assert_eq!(g.co_queued_tokens(), (5000 - (budget - 300)) as u64);
    }

    #[test]
    fn kick_with_empty_queue_is_a_noop() {
        let mut cl = cluster();
        cl.kick_coalesced(0);
        assert!(!cl.gpus[0].busy);
        assert_eq!(cl.gpus[0].co_step_chunk, 0);
    }
}
