//! Fig 5(a)/(b): SLO attainment vs request rate for the §5.1 static
//! configurations, LongBench, 4800 W (and the 6000 W references).
//!
//! (a) TTFT = 1 s, TPOT = 40 ms: 4P4D-750W sustains ~1.5x the coalesced
//!     rate at 80% attainment; dropping to 4800 W (4P4D-600W) costs ~20%;
//!     the non-uniform 4P-750W/4D-450W matches 4P4D-750W at 1200 W less.
//! (b) TPOT = 25 ms: 4P-750W/4D-450W degrades (decode starved);
//!     4P-675W/4D-525W wins — the sensitivity that motivates dynamic
//!     allocation.

use crate::config::{presets, ClusterConfig};
use crate::experiments::{crossing_rate, RatePoint, ShapeCheck};
use crate::scenario::{Axis, Scenario, Study};
use crate::types::{Slo, MILLIS, SECOND};

pub struct Fig5 {
    pub slo: Slo,
    /// (config, curve) in presentation order.
    pub curves: Vec<(ClusterConfig, Vec<RatePoint>)>,
}

pub const RATES: &[f64] = &[0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75, 2.0, 2.25, 2.5];

fn configs_5a() -> Vec<ClusterConfig> {
    vec![
        presets::coalesced(750.0),
        presets::coalesced(600.0),
        presets::p4d4(750.0),
        presets::p4d4(600.0),
        presets::p5d3_600(),
        presets::p4_750_d4_450(),
    ]
}

fn configs_5b() -> Vec<ClusterConfig> {
    let mut v = configs_5a();
    v.push(presets::p4_675_d4_525());
    v
}

/// The declarative form: the part's config list × the rate axis under
/// the part's SLO.
pub fn scenario(part_b: bool, seed: u64, n: usize) -> Scenario {
    let slo = if part_b {
        Slo::new(SECOND, 25 * MILLIS)
    } else {
        Slo::paper_default()
    };
    let configs = if part_b { configs_5b() } else { configs_5a() };
    Scenario::new(if part_b { "fig5b" } else { "fig5a" }, presets::p4d4(600.0))
        .seed(seed)
        .requests(n)
        .slo(slo)
        .axis(Axis::Config(configs))
        .axis(Axis::RatePerGpu(RATES.to_vec()))
}

pub fn run(part_b: bool, seed: u64, n: usize) -> Fig5 {
    let s = scenario(part_b, seed, n);
    let slo = s.slo;
    let study = Study::new(s).run(None).expect("fig5 scenario");
    Fig5 {
        slo,
        curves: study.rate_curves(),
    }
}

impl Fig5 {
    pub fn curve(&self, name: &str) -> Option<&[RatePoint]> {
        self.curves
            .iter()
            .find(|(c, _)| c.name == name)
            .map(|(_, pts)| pts.as_slice())
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "SLO attainment vs QPS/GPU (LongBench, TTFT={}ms TPOT={}ms)\n",
            self.slo.ttft / MILLIS,
            self.slo.tpot / MILLIS
        );
        out.push_str(&format!("{:<18}", "QPS/GPU"));
        for r in RATES {
            out.push_str(&format!("{r:>7.2}"));
        }
        out.push('\n');
        for (cfg, pts) in &self.curves {
            out.push_str(&format!("{:<18}", cfg.name));
            for p in pts {
                out.push_str(&format!("{:>7.2}", p.attainment * 100.0));
            }
            out.push('\n');
        }
        out.push_str("\nsustainable rate @80% attainment (QPS/GPU):\n");
        for (cfg, pts) in &self.curves {
            out.push_str(&format!(
                "  {:<18} {:.2}\n",
                cfg.name,
                crossing_rate(pts, 0.8)
            ));
        }
        out
    }

    /// QPS-per-provisioned-kW at the 80% sustainable point (§5.1 claims).
    pub fn qps_per_kw_at_80(&self, name: &str) -> f64 {
        let Some(pts) = self.curve(name) else { return 0.0 };
        let rate = crossing_rate(pts, 0.8);
        // Interpolate qps_per_kw at the crossing via the nearest point.
        pts.iter()
            .min_by(|a, b| {
                (a.qps_per_gpu - rate)
                    .abs()
                    .partial_cmp(&(b.qps_per_gpu - rate).abs())
                    .unwrap()
            })
            .map(|p| p.qps_per_kw)
            .unwrap_or(0.0)
    }

    pub fn checks(&self) -> Vec<ShapeCheck> {
        let cross = |name: &str| self.curve(name).map(|c| crossing_rate(c, 0.8)).unwrap_or(0.0);
        let coalesced = cross("Coalesced-750W");
        let disagg_750 = cross("4P4D-750W");
        let disagg_600 = cross("4P4D-600W");
        let _p5d3 = cross("5P3D-600W"); // used via mean-attainment check below
        let nonuniform = cross("4P-750W/4D-450W");
        let mut checks = vec![
            ShapeCheck::new(
                "disagg-750 sustains ~1.5x coalesced-750 (paper: 1.5x)",
                disagg_750 / coalesced >= 1.25 && disagg_750 / coalesced <= 2.0,
                format!("{disagg_750:.2} vs {coalesced:.2} = {:.2}x", disagg_750 / coalesced),
            ),
            ShapeCheck::new(
                "dropping 4P4D to 600 W costs rate (paper: 1.5x -> 1.2x)",
                disagg_600 < disagg_750,
                format!("600W {disagg_600:.2} < 750W {disagg_750:.2}"),
            ),
            {
                // Curve position over the swept operating range (the
                // paper's visual claim): 750/450 above 5P3D above
                // 4P4D-600W.
                let mean_att = |name: &str| {
                    self.curve(name).map_or(0.0, |c| {
                        let pts: Vec<f64> = c
                            .iter()
                            .filter(|p| p.qps_per_gpu <= 1.75)
                            .map(|p| p.attainment)
                            .collect();
                        pts.iter().sum::<f64>() / pts.len().max(1) as f64
                    })
                };
                let a_nu = mean_att("4P-750W/4D-450W");
                let a_53 = mean_att("5P3D-600W");
                let a_44 = mean_att("4P4D-600W");
                ShapeCheck::new(
                    "power shifting beats GPU shifting (750/450 > 5P3D > 4P4D-600)",
                    a_nu > a_53 && a_53 >= a_44 - 0.01,
                    format!("mean attainment: {a_nu:.3} > {a_53:.3} >= {a_44:.3}"),
                )
            },
        ];
        if self.slo.tpot == 25 * MILLIS {
            let tuned = cross("4P-675W/4D-525W");
            checks.push(ShapeCheck::new(
                "under 25 ms TPOT, 675/525 outperforms 750/450 (Fig 5b)",
                tuned > nonuniform,
                format!("{tuned:.2} > {nonuniform:.2}"),
            ));
            checks.push(ShapeCheck::new(
                "750/450 degrades under the stricter TPOT (decode starved)",
                nonuniform < disagg_750,
                format!("{nonuniform:.2} < {disagg_750:.2}"),
            ));
        } else {
            checks.push(ShapeCheck::new(
                "non-uniform 750/450 ~ matches 4P4D-750W at 1200 W less",
                nonuniform >= 0.9 * disagg_750,
                format!("{nonuniform:.2} vs {disagg_750:.2}"),
            ));
            let q_co = self.qps_per_kw_at_80("Coalesced-750W");
            let q_nu = self.qps_per_kw_at_80("4P-750W/4D-450W");
            let q_d750 = self.qps_per_kw_at_80("4P4D-750W");
            checks.push(ShapeCheck::new(
                "750/450 QPS/W beats 4P4D-750 (paper: 1.1x) and coalesced-750 (paper: 1.7x)",
                q_nu > q_d750 && q_nu > 1.3 * q_co,
                format!("{q_nu:.3} vs {q_d750:.3} vs {q_co:.3}"),
            ));
        }
        checks
    }
}
