//! Discrete-event machinery: the event queue and event types.
//!
//! The queue is a calendar queue (hierarchical timing wheel with one
//! level plus an overflow heap): near-future events land in fixed-width
//! time buckets indexed directly from their timestamp, far-future events
//! (beyond the bucket window) wait in a `BinaryHeap` and are decanted
//! into buckets when the window advances. Push and pop are O(1) +
//! O(log bucket_occupancy) instead of O(log n) over the whole fleet's
//! event population, which is what makes thousand-GPU runs tractable.
//! Pop order — strictly (at, seq), FIFO on timestamp ties — is identical
//! to the original single `BinaryHeap`, so `RunResult`s are bit-for-bit
//! unchanged. Set `RAPID_EVENTQ=heap` to fall back to the plain heap.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::types::Micros;
use crate::util::slab::SlotId;

/// Simulation events. Variants carry the minimum needed; `epoch` guards
/// against stale completions after a GPU role change. Requests travel as
/// slab [`SlotId`]s (the `Cluster`'s request store owns the state), so
/// every variant is a small POD and the calendar buckets stay compact.
#[derive(Debug)]
pub enum Event {
    /// Next trace arrival is due.
    Arrival,
    /// The in-flight work unit on `gpu` finished (a prefill batch, a
    /// decode iteration or a coalesced chunked-prefill iteration — the
    /// GPU's current role behavior interprets it; see `sim::worker`).
    StepDone { gpu: usize, epoch: u64 },
    /// A KV transfer landed on decode `gpu`; `src_node` owns the ring
    /// slot being released. `slot` indexes the cluster's request store.
    KvArrive { gpu: usize, src_node: usize, slot: SlotId },
    /// Controller (policy) tick.
    ControllerTick,
    /// Pending power raises may be due.
    PowerPoll,
    /// Telemetry sampling.
    Sample,
    /// A draining GPU finished its role switch.
    DrainDone { gpu: usize, epoch: u64 },
    /// An environment disturbance is due: index into the cluster's
    /// expanded `env_timeline` (cap step, GPU failure/recovery, thermal
    /// derate — see `crate::env`).
    Env { idx: usize },
    /// A KV eviction (tier demotion) on `gpu` completed; the decode
    /// worker may resume admissions. Epoch-guarded like `StepDone`.
    MemEvict { gpu: usize, epoch: u64 },
}

struct HeapItem {
    at: Micros,
    seq: u64,
    event: Event,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Bucket width exponent: 2^10 µs ≈ 1 ms per bucket. Decode steps,
/// prefill batches, KV hops and the telemetry/controller timers all land
/// within a few thousand buckets of "now".
const BUCKET_BITS: u32 = 10;
const BUCKET_WIDTH: Micros = 1 << BUCKET_BITS;
/// Window size: 4096 buckets ≈ 4.2 s of simulated time. Longer horizons
/// (the environment timeline, sparse arrivals) overflow into the heap.
const N_BUCKETS: usize = 4096;
const SPAN: Micros = BUCKET_WIDTH * N_BUCKETS as Micros;

/// The in-window part of the calendar: fixed-width buckets, each a small
/// (at, seq)-ordered heap, plus a cursor that only moves forward.
struct Calendar {
    buckets: Vec<BinaryHeap<HeapItem>>,
    /// Lowest bucket that may still hold events. Events pushed "into the
    /// past" (at below the cursor bucket — the DES never rewinds, but
    /// zero-delay events at the current instant do this) clamp to the
    /// cursor bucket, where the per-bucket heap restores exact order.
    cursor: usize,
    /// Timestamp of bucket 0's left edge.
    win_start: Micros,
    /// Events currently resident in buckets (not counting overflow).
    in_window: usize,
    /// Events at or beyond `win_start + SPAN`.
    overflow: BinaryHeap<HeapItem>,
}

impl Calendar {
    fn new(capacity: usize) -> Self {
        // Each bucket rarely holds more than a handful of events at once;
        // pre-sizing keeps steady-state pushes allocation-free (the
        // alloc-count test asserts zero allocations across 1k events).
        let mut buckets = Vec::with_capacity(N_BUCKETS);
        buckets.resize_with(N_BUCKETS, || BinaryHeap::with_capacity(8));
        Calendar {
            buckets,
            cursor: 0,
            win_start: 0,
            in_window: 0,
            overflow: BinaryHeap::with_capacity(capacity.min(64)),
        }
    }

    fn push(&mut self, item: HeapItem) {
        if item.at >= self.win_start + SPAN {
            self.overflow.push(item);
            return;
        }
        let idx = ((item.at.saturating_sub(self.win_start)) >> BUCKET_BITS) as usize;
        self.buckets[idx.max(self.cursor)].push(item);
        self.in_window += 1;
    }

    fn pop(&mut self) -> Option<HeapItem> {
        loop {
            if self.in_window > 0 {
                // The global minimum always sits in the first non-empty
                // bucket: every event in a later bucket has a strictly
                // later timestamp (clamped events land *at* the cursor,
                // never past it), and the per-bucket heap orders exact
                // (at, seq) within the bucket.
                while self.buckets[self.cursor].is_empty() {
                    self.cursor += 1;
                }
                self.in_window -= 1;
                return self.buckets[self.cursor].pop();
            }
            // Buckets are dry: jump the window to the overflow head and
            // decant everything that now fits. Overflow items all sit at
            // or past the old window's end, so the window never rewinds.
            let head_at = self.overflow.peek()?.at;
            self.win_start = head_at & !(BUCKET_WIDTH - 1);
            self.cursor = 0;
            while let Some(top) = self.overflow.peek() {
                if top.at >= self.win_start + SPAN {
                    break;
                }
                let item = self.overflow.pop().unwrap();
                let idx = ((item.at - self.win_start) >> BUCKET_BITS) as usize;
                self.buckets[idx].push(item);
                self.in_window += 1;
            }
        }
    }

    fn len(&self) -> usize {
        self.in_window + self.overflow.len()
    }
}

enum Backend {
    Calendar(Calendar),
    Heap(BinaryHeap<HeapItem>),
}

/// Earliest-first event queue with deterministic FIFO tie-breaking.
pub struct EventQueue {
    backend: Backend,
    seq: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue::with_capacity(0)
    }

    /// Build the default calendar-queue backend, or the legacy single
    /// `BinaryHeap` when `RAPID_EVENTQ=heap` is set (escape hatch and
    /// equivalence-testing aid; pop order is identical either way).
    /// Steady-state sims keep roughly one in-flight event per GPU plus
    /// the periodic timers; the capacity hint presizes the heap backend.
    pub fn with_capacity(capacity: usize) -> Self {
        match std::env::var("RAPID_EVENTQ") {
            Ok(v) if v == "heap" => EventQueue::heap_with_capacity(capacity),
            _ => EventQueue {
                backend: Backend::Calendar(Calendar::new(capacity)),
                seq: 0,
            },
        }
    }

    /// The legacy single-`BinaryHeap` backend, selectable directly (the
    /// wheel-vs-heap golden tests compare full runs across backends).
    pub fn heap_with_capacity(capacity: usize) -> Self {
        EventQueue {
            backend: Backend::Heap(BinaryHeap::with_capacity(capacity)),
            seq: 0,
        }
    }

    pub fn push(&mut self, at: Micros, event: Event) {
        self.seq += 1;
        let item = HeapItem { at, seq: self.seq, event };
        match &mut self.backend {
            Backend::Calendar(c) => c.push(item),
            Backend::Heap(h) => h.push(item),
        }
    }

    pub fn pop(&mut self) -> Option<(Micros, Event)> {
        let item = match &mut self.backend {
            Backend::Calendar(c) => c.pop(),
            Backend::Heap(h) => h.pop(),
        };
        item.map(|i| (i.at, i.event))
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Calendar(c) => c.len(),
            Backend::Heap(h) => h.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::Arrival);
        q.push(10, Event::ControllerTick);
        q.push(20, Event::Sample);
        assert_eq!(q.pop().unwrap().0, 10);
        assert_eq!(q.pop().unwrap().0, 20);
        assert_eq!(q.pop().unwrap().0, 30);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_pop_fifo() {
        let mut q = EventQueue::new();
        q.push(5, Event::StepDone { gpu: 1, epoch: 0 });
        q.push(5, Event::StepDone { gpu: 2, epoch: 0 });
        q.push(5, Event::StepDone { gpu: 3, epoch: 0 });
        let order: Vec<usize> = (0..3)
            .map(|_| match q.pop().unwrap().1 {
                Event::StepDone { gpu, .. } => gpu,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    /// Tag pops so two queues can be compared event-by-event.
    fn tag(q: &mut EventQueue, at: Micros, id: usize) {
        q.push(at, Event::StepDone { gpu: id, epoch: 0 });
    }

    fn pop_tag(q: &mut EventQueue) -> Option<(Micros, usize)> {
        q.pop().map(|(at, ev)| match ev {
            Event::StepDone { gpu, .. } => (at, gpu),
            _ => unreachable!(),
        })
    }

    #[test]
    fn calendar_matches_heap_on_random_workload() {
        // Interleaved pushes and pops with a monotone "now" (the DES
        // never schedules into the past) across short hops, zero-delay
        // events and far-future overflow jumps.
        let mut rng = Rng::new(0xE7E7);
        let mut cal = EventQueue::new();
        let mut heap = EventQueue::heap_with_capacity(0);
        let mut now: Micros = 0;
        let mut id = 0usize;
        for _ in 0..20_000 {
            if rng.chance(0.55) {
                let delay = match rng.index(10) {
                    0 => 0,                                 // same-instant
                    1 => SPAN + rng.range_u64(0, SPAN * 3), // overflow
                    _ => rng.range_u64(0, 40_000),          // typical hop
                };
                tag(&mut cal, now + delay, id);
                tag(&mut heap, now + delay, id);
                id += 1;
            } else {
                let a = pop_tag(&mut cal);
                let b = pop_tag(&mut heap);
                assert_eq!(a, b);
                if let Some((at, _)) = a {
                    now = at;
                }
            }
            assert_eq!(cal.len(), heap.len());
        }
        loop {
            let a = pop_tag(&mut cal);
            let b = pop_tag(&mut heap);
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn far_future_overflow_drains_in_order() {
        let mut q = EventQueue::new();
        // Beyond the window — parked in overflow, multiple jumps apart.
        tag(&mut q, SPAN * 3 + 7, 0);
        tag(&mut q, SPAN + 1, 1);
        tag(&mut q, SPAN * 10, 2);
        // In-window events pop first.
        tag(&mut q, 100, 3);
        assert_eq!(q.len(), 4);
        assert_eq!(pop_tag(&mut q), Some((100, 3)));
        assert_eq!(pop_tag(&mut q), Some((SPAN + 1, 1)));
        assert_eq!(pop_tag(&mut q), Some((SPAN * 3 + 7, 0)));
        assert_eq!(pop_tag(&mut q), Some((SPAN * 10, 2)));
        assert!(q.pop().is_none());
    }

    #[test]
    fn same_timestamp_fifo_survives_window_jump() {
        let mut q = EventQueue::new();
        let far = SPAN * 2 + 12_345;
        for i in 0..5 {
            tag(&mut q, far, i);
        }
        // Pops force a window jump; FIFO must survive the decant.
        for want in 0..5 {
            assert_eq!(pop_tag(&mut q), Some((far, want)));
        }
    }

    #[test]
    fn push_behind_cursor_clamps_and_pops_in_order() {
        let mut q = EventQueue::new();
        // Advance the cursor several buckets into the window…
        tag(&mut q, BUCKET_WIDTH * 4 + 100, 0);
        assert_eq!(pop_tag(&mut q), Some((BUCKET_WIDTH * 4 + 100, 0)));
        // …then push an event whose nominal bucket is behind the cursor.
        // It clamps into the cursor bucket and still pops strictly by
        // (at, seq) against later events.
        tag(&mut q, BUCKET_WIDTH + 7, 1);
        tag(&mut q, BUCKET_WIDTH * 5, 2);
        tag(&mut q, BUCKET_WIDTH + 7, 3); // FIFO tie with id 1
        assert_eq!(pop_tag(&mut q), Some((BUCKET_WIDTH + 7, 1)));
        assert_eq!(pop_tag(&mut q), Some((BUCKET_WIDTH + 7, 3)));
        assert_eq!(pop_tag(&mut q), Some((BUCKET_WIDTH * 5, 2)));
    }
}
