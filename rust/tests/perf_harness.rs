//! Perf-subsystem tests: the BenchReport schema the CI gate parses, the
//! regression comparison itself, the hot-path suite plumbing, and the
//! statistical contract between the streaming histogram and the exact
//! percentile it substitutes for on per-tick paths.

use rapid::bench::hotpath::{run_suite, SuiteConfig, WHOLE_SIM};
use rapid::bench::{BenchReport, Timing};
use rapid::config::presets;
use rapid::sim::{self, SimOptions};
use rapid::types::Slo;
use rapid::util::check::{ensure, property};
use rapid::util::stats::{percentile, LatencyHistogram};

fn timing(name: &str, mean_us: f64) -> Timing {
    Timing {
        name: name.into(),
        iters: 10,
        batch: 1,
        mean_us,
        p50_us: mean_us,
        p99_us: mean_us * 2.0,
        min_us: mean_us * 0.5,
        max_us: mean_us * 3.0,
    }
}

// ---------------------------------------------------------------------------
// BenchReport schema
// ---------------------------------------------------------------------------

#[test]
fn bench_report_round_trips_via_file() {
    let mut report = BenchReport::new("hotpath");
    report.meta.insert("note".into(), "round trip".into());
    report.entries.push(timing("router/pick", 0.75));
    let mut whole = timing(WHOLE_SIM, 1.25e6);
    whole.batch = 30_000;
    report.entries.push(whole);

    let dir = std::env::temp_dir().join(format!("rapid-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("report.json").to_string_lossy().into_owned();
    report.write(&path).unwrap();
    let loaded = BenchReport::load(&path).unwrap();
    assert_eq!(loaded, report);
    // The stable schema markers the CI gate greps for.
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"schema_version\": 1"));
    assert!(text.contains("\"per_sec\""));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn comparison_gates_an_injected_regression() {
    let mut baseline = BenchReport::new("hotpath");
    baseline.entries.push(timing("steady", 100.0));
    baseline.entries.push(timing("hot", 100.0));
    baseline.entries.push(timing("unrecorded", 0.0));

    // Inject a 40% regression on one case.
    let mut current = BenchReport::new("hotpath");
    current.entries.push(timing("steady", 104.0));
    current.entries.push(timing("hot", 140.0));
    current.entries.push(timing("unrecorded", 9.0));

    let cmps = current.compare(&baseline);
    assert_eq!(cmps.len(), 2, "unrecorded baselines are skipped");
    let regressed: Vec<&str> = cmps
        .iter()
        .filter(|c| c.regressed(25.0))
        .map(|c| c.name.as_str())
        .collect();
    assert_eq!(regressed, vec!["hot"]);
    // An improvement is a negative delta, never a regression.
    let steady = cmps.iter().find(|c| c.name == "steady").unwrap();
    assert!(!steady.regressed(25.0));
    assert!((steady.delta_pct - 4.0).abs() < 1e-9);
}

// ---------------------------------------------------------------------------
// Hot-path suite plumbing
// ---------------------------------------------------------------------------

#[test]
fn suite_report_round_trips_and_counts_events() {
    let cfg = SuiteConfig {
        filter: Some("sim/".into()),
        target_ms: 5,
        max_iters: 20,
        sim_requests: 30,
    };
    let report = run_suite(&cfg);
    let t = report.entry(WHOLE_SIM).expect("whole-sim case");
    assert!(t.batch > 0 && t.per_sec() > 0.0);
    let back = BenchReport::parse(&report.to_json().to_string()).unwrap();
    assert_eq!(back, report);
}

#[test]
fn sim_events_counter_is_populated_and_deterministic() {
    let cfg = presets::rapid_600();
    let trace = rapid::experiments::longbench_trace(7, 10.0, 60, Slo::paper_default());
    let a = sim::run(&cfg, &trace, &SimOptions::default());
    let b = sim::run(&cfg, &trace, &SimOptions::default());
    assert!(
        a.sim_events > a.records.len() as u64,
        "every request takes several events (got {})",
        a.sim_events
    );
    assert_eq!(a.sim_events, b.sim_events, "event count must be deterministic");
}

// ---------------------------------------------------------------------------
// Streaming histogram vs exact percentile
// ---------------------------------------------------------------------------

#[test]
fn histogram_quantiles_bracket_exact_percentile_within_one_bucket() {
    property("histogram brackets exact percentile", 150, |g| {
        let buckets = g.usize_range(16, 257);
        let (min, max) = (1.0f64, 1e6f64);
        let ratio = (max / min).powf(1.0 / buckets as f64);
        let mut h = LatencyHistogram::new(min, max, buckets);
        let n = g.usize_range(2, 1500);
        let mut xs = Vec::with_capacity(n);
        for _ in 0..n {
            // Log-uniform in [min, max/2]: every sample lands in a real
            // bucket (no underflow clamp, no overflow bucket).
            let v = min * (max / (2.0 * min)).powf(g.f64_range(0.0, 1.0));
            h.record(v);
            xs.push(v);
        }
        for &q in &[0.5, 0.9, 0.99] {
            let approx = h.quantile(q);
            // The histogram's convention is nearest-rank: evaluate the
            // exact percentile at that same rank so `percentile()`'s
            // interpolation agrees sample-for-sample.
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
            let p = 100.0 * rank as f64 / (n - 1) as f64;
            let exact = percentile(&xs, p);
            // Bracket within one bucket: the returned lower edge must not
            // exceed the exact value, and the exact value must lie below
            // the bucket's upper edge (1e-9 covers ln/powf rounding).
            ensure(
                approx <= exact * (1.0 + 1e-9),
                format!("q={q}: edge {approx} above exact {exact} (n={n})"),
            )?;
            ensure(
                exact <= approx * ratio * (1.0 + 1e-9),
                format!("q={q}: exact {exact} beyond bucket [{approx}, {})", approx * ratio),
            )?;
        }
        Ok(())
    });
}
