//! Typed configuration schema + the paper's named presets.
//!
//! Every experiment in the paper is expressible as a `ClusterConfig`; the
//! presets below reproduce each configuration named in §5 (Coalesced-750W,
//! 4P4D-600W, 5P3D-600W, 4P-750W/4D-450W, 4P4D-DynPower, DynGPU-600W,
//! DynGPU-DynPower, ...). Configs load from TOML files (`--config`) with
//! preset names as a starting point (`preset = "4p4d-600"`).

use crate::config::toml::{Document, Value};
use crate::env::EnvProfile;
use crate::fleet::{skus, FleetConfig, GpuSku};
use crate::types::{Micros, Watts, MILLIS, SECOND};

/// How GPUs are split across phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// vLLM-style coalesced serving with chunked prefill (the baseline).
    Coalesced,
    /// Disaggregated pools: `prefill` + `decode` GPUs (must sum to n_gpus).
    Disaggregated { prefill: usize, decode: usize },
}

/// Which resources the controller may move at runtime (paper §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlPolicy {
    /// User-fixed roles and caps.
    Static,
    /// Algorithm 1 restricted to MovePower.
    DynPower,
    /// Algorithm 1 restricted to MoveGPU (uniform caps).
    DynGpu,
    /// Full RAPID: power first, GPU reallocation when power saturates.
    DynPowerGpu,
    /// Ablation: latency-driven power shifting with none of Algorithm 1's
    /// arbitration (no queue-pressure gate, no both-hot veto, no GPU
    /// escalation). Isolates what the paper's extra signals contribute.
    PowerOnly,
}

impl ControlPolicy {
    /// Canonical config-file name (`control.policy`, scenario axes).
    pub fn name(&self) -> &'static str {
        match self {
            ControlPolicy::Static => "static",
            ControlPolicy::DynPower => "dyn-power",
            ControlPolicy::DynGpu => "dyn-gpu",
            ControlPolicy::DynPowerGpu => "rapid",
            ControlPolicy::PowerOnly => "power-only",
        }
    }

    pub fn moves_power(&self) -> bool {
        matches!(
            self,
            ControlPolicy::DynPower | ControlPolicy::DynPowerGpu | ControlPolicy::PowerOnly
        )
    }
    pub fn moves_gpus(&self) -> bool {
        matches!(self, ControlPolicy::DynGpu | ControlPolicy::DynPowerGpu)
    }
    pub fn is_dynamic(&self) -> bool {
        !matches!(self, ControlPolicy::Static)
    }
}

impl std::str::FromStr for ControlPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<ControlPolicy, String> {
        match s {
            "static" => Ok(ControlPolicy::Static),
            "dyn-power" => Ok(ControlPolicy::DynPower),
            "dyn-gpu" => Ok(ControlPolicy::DynGpu),
            "rapid" | "dyn-power-gpu" => Ok(ControlPolicy::DynPowerGpu),
            "power-only" => Ok(ControlPolicy::PowerOnly),
            other => Err(format!("unknown policy '{other}'")),
        }
    }
}

/// Algorithm-1 constants (paper names in comments).
#[derive(Debug, Clone, PartialEq)]
pub struct ControllerConfig {
    /// MIN_P: floor for any GPU's cap (W).
    pub min_gpu_w: Watts,
    /// MAX_P: ceiling for any GPU's cap (W).
    pub max_gpu_w: Watts,
    /// Decode caps above this are wasted (Fig 4b flattens); the controller
    /// never raises decode above it.
    pub decode_ceiling_w: Watts,
    /// THRESHOLD: prefill queue depth that signals structural imbalance.
    pub queue_threshold: usize,
    /// MIN_TIME: controller tick period.
    pub tick: Micros,
    /// COOLDOWN: minimum spacing between reallocation decisions.
    pub cooldown: Micros,
    /// Extra spacing required between GPU-role moves (drains are costly;
    /// paper: "GPU reallocation occurs at a slower pace, 2-5 s").
    pub gpu_cooldown: Micros,
    /// Power moved per decision (W, total across the source pool).
    pub power_step_w: Watts,
    /// Sliding window for recent TTFT/TPOT percentiles.
    pub metric_window: Micros,
    /// Percentile used for trigger comparisons.
    pub trigger_percentile: f64,
    /// Extra latency a role switch costs the moved GPU (drain + reload).
    pub gpu_move_overhead: Micros,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            min_gpu_w: 400.0,
            max_gpu_w: 750.0,
            decode_ceiling_w: 600.0,
            queue_threshold: 4,
            tick: 250 * MILLIS,
            cooldown: 2 * SECOND, // paper: 2-6 s
            gpu_cooldown: 5 * SECOND,
            power_step_w: 50.0,
            metric_window: 5 * SECOND,
            trigger_percentile: 90.0,
            gpu_move_overhead: 2 * SECOND, // paper: 2-5 s
        }
    }
}

/// Calibrated performance/power model constants (DESIGN.md §4).
#[derive(Debug, Clone, PartialEq)]
pub struct PerfModelConfig {
    /// Prompt tokens/s per prefill GPU at max power (750 W).
    pub prefill_rate_tps: f64,
    /// Fixed per-batch launch overhead for a prefill batch.
    pub prefill_overhead: Micros,
    /// Decode step latency at 600 W, batch 1 (us).
    pub decode_base: Micros,
    /// Additional decode step latency per active request (us).
    pub decode_per_req: Micros,
    /// Additional decode step latency per request per K-token of live
    /// context (KV reads scale with context length)...
    pub decode_kv_us_per_ktok: f64,
    /// ... saturating at this context length: beyond it the KV stream is
    /// fully bandwidth-bound and paging hides further growth.
    pub decode_kv_ctx_cap_tokens: f64,
    /// Prefill speedup at 750 W relative to 400 W (paper: ~1.8x).
    pub prefill_speedup_max: f64,
    /// Power above which prefill gains flatten (paper: ~700 W).
    pub prefill_knee_w: Watts,
    /// Decode speedup at/above the knee relative to 400 W (paper: 1.3-1.5x).
    pub decode_speedup_max: f64,
    /// Power above which decode gains are ~zero. The paper reports decode
    /// flattening "between 1.3x and 1.5x" with no useful gains above
    /// 600 W; we place the knee at 500 W, which reproduces both that and
    /// the §5.1 ordering (4x450 W decode > 3x600 W decode — memory-bound
    /// work barely scales with power).
    pub decode_knee_w: Watts,
    /// Idle power per GPU (W).
    pub idle_w: Watts,
    /// KV bytes per token (Llama-3.1-8B-class: ~128 KiB).
    pub kv_bytes_per_token: u64,
    /// Intra-node interconnect bandwidth per link (bytes/s), XGMI-class.
    pub xgmi_bw: f64,
    /// Cross-node interconnect bandwidth (bytes/s), RDMA-NIC-class; KV
    /// transfers between nodes pay this slower link instead of XGMI.
    pub inter_node_bw: f64,
    /// Chunked-prefill token budget per coalesced iteration.
    pub chunk_tokens: u32,
    /// Cross-chunk attention re-read cost: each chunk re-touches this
    /// fraction of the already-processed prompt (the efficiency tax of
    /// chunked prefill vs one-shot prefill).
    pub chunk_reread_frac: f64,
    /// Floor of the power/speedup curves: speedup == 1.0 at/below this
    /// cap (the lowest cap in Fig 4 for the paper's part). Per-SKU
    /// models with smaller power envelopes anchor lower.
    pub ref_w: Watts,
    /// Power at which `prefill_rate_tps` is quoted (max cap of the part).
    pub rated_w: Watts,
    /// Power at which `decode_base` is quoted.
    pub decode_rated_w: Watts,
}

impl Default for PerfModelConfig {
    fn default() -> Self {
        PerfModelConfig {
            prefill_rate_tps: 9_300.0,
            prefill_overhead: 4 * MILLIS,
            decode_base: 9_000,
            decode_per_req: 100,
            decode_kv_us_per_ktok: 510.0,
            decode_kv_ctx_cap_tokens: 2_500.0,
            prefill_speedup_max: 1.8,
            prefill_knee_w: 700.0,
            decode_speedup_max: 1.45,
            decode_knee_w: 500.0,
            idle_w: 140.0,
            kv_bytes_per_token: 131_072,
            xgmi_bw: 64e9,
            inter_node_bw: 25e9,
            chunk_tokens: 512,
            chunk_reread_frac: 0.15,
            ref_w: 400.0,
            rated_w: 750.0,
            decode_rated_w: 600.0,
        }
    }
}

/// Batching limits (per-GPU local schedulers, paper §3.2).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchConfig {
    /// Max prompt tokens per prefill batch.
    pub max_prefill_tokens: u32,
    /// Max requests per prefill batch.
    pub max_prefill_reqs: usize,
    /// Max concurrent decode requests per GPU (memory capacity).
    pub max_decode_reqs: usize,
    /// KV ring-buffer slots between prefill and decode (paper: 32).
    pub ring_slots: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_prefill_tokens: 8192,
            max_prefill_reqs: 8,
            max_decode_reqs: 64,
            ring_slots: 32,
        }
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub name: String,
    /// GPUs **per node**; the cluster has `n_nodes * n_gpus` total.
    pub n_gpus: usize,
    /// Number of identical nodes in the cluster (1 = the paper's testbed).
    pub n_nodes: usize,
    /// Optional cluster-wide budget (W). `None` means the trivial
    /// `n_nodes * node_budget_w`; a smaller value makes the cluster cap
    /// bind before any node cap (facility-level constraint).
    pub cluster_budget_w: Option<Watts>,
    /// Total GPU power budget for one node (W). Fig 5 uses 4800 and 6000.
    pub node_budget_w: Watts,
    /// If false, caps are set to gpu max and the budget line is only
    /// reported, not enforced (Fig 3's uncapped run).
    pub enforce_budget: bool,
    pub topology: Topology,
    /// Initial per-phase caps (uniform inside a phase, paper §3.3).
    pub prefill_cap_w: Watts,
    pub decode_cap_w: Watts,
    pub control: ControlPolicy,
    pub controller: ControllerConfig,
    pub perf: PerfModelConfig,
    pub batch: BatchConfig,
    /// Optional per-node SKU mix (heterogeneous fleet, DESIGN.md §11).
    /// `None` means one implicit SKU built from `perf` and the
    /// controller envelope — the paper's homogeneous testbed.
    pub fleet: Option<FleetConfig>,
    /// Timed operational disturbances (DESIGN.md §12). Empty (the
    /// default) injects nothing and is bit-identical to pre-env code.
    pub env: EnvProfile,
    /// KV memory subsystem (DESIGN.md §14): HBM capacity accounting,
    /// tiered offload, and the prefix cache. `None` (the default) keeps
    /// memory infinite and is bit-identical to pre-mem code.
    pub mem: Option<crate::mem::MemConfig>,
    /// Admission control (DESIGN.md §15). The default (`mode = none`)
    /// admits everything and is bit-identical to pre-admission code.
    pub admission: crate::cluster::admission::AdmissionConfig,
    /// Tenant classes (`[tenant.<name>]` tables, DESIGN.md §15), in
    /// name-sorted order; tenant id `i+1` is `tenants[i]`, id 0 the
    /// untenanted default. Empty disables all multi-tenant machinery.
    pub tenants: Vec<crate::workload::tracespec::TenantClass>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        presets::p4d4(600.0)
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Invalid(String),
    UnknownPreset(String),
    Toml(crate::config::toml::TomlError),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Invalid(m) => write!(f, "config: {m}"),
            ConfigError::UnknownPreset(p) => write!(f, "unknown preset '{p}'"),
            ConfigError::Toml(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Toml(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::config::toml::TomlError> for ConfigError {
    fn from(e: crate::config::toml::TomlError) -> Self {
        ConfigError::Toml(e)
    }
}

impl ClusterConfig {
    /// Validate cross-field invariants; every constructor funnels here.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let err = |m: String| Err(ConfigError::Invalid(m));
        if self.n_gpus == 0 {
            return err("n_gpus must be > 0".into());
        }
        if self.n_nodes == 0 {
            return err("n_nodes must be > 0".into());
        }
        if let Some(cb) = self.cluster_budget_w {
            if cb <= 0.0 {
                return err(format!("cluster budget {cb} W must be positive"));
            }
        }
        if let Topology::Disaggregated { prefill, decode } = self.topology {
            if prefill + decode != self.n_gpus {
                return err(format!(
                    "prefill({prefill}) + decode({decode}) != n_gpus({})",
                    self.n_gpus
                ));
            }
            if prefill == 0 || decode == 0 {
                return err("each phase needs >= 1 GPU".into());
            }
        }
        let c = &self.controller;
        if c.min_gpu_w > c.max_gpu_w {
            return err(format!("min_gpu_w {} > max_gpu_w {}", c.min_gpu_w, c.max_gpu_w));
        }
        if let Some(fc) = &self.fleet {
            fc.validate().map_err(ConfigError::Invalid)?;
            if fc.gpus_per_node() != self.n_gpus {
                return err(format!(
                    "sku mix '{}' covers {} GPUs per node but cluster.n_gpus is {}",
                    fc.mix_label(),
                    fc.gpus_per_node(),
                    self.n_gpus
                ));
            }
            for (label, cap) in [("prefill", self.prefill_cap_w), ("decode", self.decode_cap_w)] {
                if cap <= 0.0 {
                    return err(format!("{label} cap {cap} must be positive"));
                }
            }
        } else {
            for (label, cap) in [("prefill", self.prefill_cap_w), ("decode", self.decode_cap_w)] {
                if cap < c.min_gpu_w || cap > c.max_gpu_w {
                    return err(format!(
                        "{label} cap {cap} outside [{}, {}]",
                        c.min_gpu_w, c.max_gpu_w
                    ));
                }
            }
        }
        if self.enforce_budget {
            let per_node = self.total_initial_caps();
            if per_node > self.node_budget_w + 1e-6 {
                return err(format!(
                    "initial caps sum to {per_node} W per node > node budget {} W",
                    self.node_budget_w
                ));
            }
            let floor = self.cap_floor_per_node();
            if floor > self.node_budget_w + 1e-6 {
                return err(format!(
                    "node budget {} W below the cap floor {} W ({} GPUs, per-GPU floors summed)",
                    self.node_budget_w, floor, self.n_gpus
                ));
            }
            let cluster_total = per_node * self.n_nodes as f64;
            if cluster_total > self.cluster_budget() + 1e-6 {
                return err(format!(
                    "initial caps sum to {cluster_total} W > cluster budget {} W",
                    self.cluster_budget()
                ));
            }
            let cluster_floor = floor * self.n_nodes as f64;
            if cluster_floor > self.cluster_budget() + 1e-6 {
                return err(format!(
                    "cluster budget {} W below the cap floor {cluster_floor} W",
                    self.cluster_budget()
                ));
            }
        }
        if self.batch.ring_slots == 0 || self.batch.max_prefill_reqs == 0 {
            return err("batch limits must be positive".into());
        }
        if let Some(mem) = &self.mem {
            mem.validate().map_err(ConfigError::Invalid)?;
        }
        self.admission.validate().map_err(ConfigError::Invalid)?;
        crate::workload::tracespec::validate_tenants(&self.tenants)
            .map_err(ConfigError::Invalid)?;
        self.env
            .validate(
                self.total_gpus(),
                self.n_nodes,
                self.enforce_budget,
                self.cap_floor_per_node() * self.n_nodes as f64,
                self.cap_floor_per_node(),
                self.cluster_budget(),
            )
            .map_err(ConfigError::Invalid)?;
        Ok(())
    }

    /// Sum of the configured per-GPU caps **per node** (clamped into
    /// each slot's SKU envelope when a fleet mix is declared).
    pub fn total_initial_caps(&self) -> Watts {
        if self.fleet.is_some() {
            return (0..self.n_gpus).map(|s| self.slot_cap(s)).sum();
        }
        match self.topology {
            Topology::Coalesced => self.prefill_cap_w * self.n_gpus as f64,
            Topology::Disaggregated { prefill, decode } => {
                self.prefill_cap_w * prefill as f64 + self.decode_cap_w * decode as f64
            }
        }
    }

    /// Initial cap of per-node GPU slot `slot`: the role's configured
    /// cap, clamped into the slot's SKU envelope.
    pub fn slot_cap(&self, slot: usize) -> Watts {
        let configured = match self.initial_role(slot) {
            crate::types::Role::Prefill | crate::types::Role::Coalesced => self.prefill_cap_w,
            crate::types::Role::Decode => self.decode_cap_w,
        };
        match &self.fleet {
            Some(fc) => {
                let sku = &fc.skus[fc.sku_of_slot(slot)];
                configured.clamp(sku.cap_floor_w, sku.max_w)
            }
            None => configured,
        }
    }

    /// Sum of per-GPU cap floors **per node** (SKU floors when a mix is
    /// declared, MIN_P otherwise).
    pub fn cap_floor_per_node(&self) -> Watts {
        match &self.fleet {
            Some(fc) => (0..self.n_gpus)
                .map(|s| fc.skus[fc.sku_of_slot(s)].cap_floor_w)
                .sum(),
            None => self.controller.min_gpu_w * self.n_gpus as f64,
        }
    }

    /// GPUs across all nodes.
    pub fn total_gpus(&self) -> usize {
        self.n_nodes * self.n_gpus
    }

    /// Effective cluster-wide budget (W).
    pub fn cluster_budget(&self) -> Watts {
        self.cluster_budget_w
            .unwrap_or(self.node_budget_w * self.n_nodes as f64)
    }

    /// Node index of a cluster-global GPU index.
    pub fn node_of(&self, gpu: usize) -> usize {
        gpu / self.n_gpus
    }

    /// Initial role of a cluster-global GPU index: each node gets the
    /// same per-node split.
    pub fn initial_role(&self, gpu: usize) -> crate::types::Role {
        match self.topology {
            Topology::Coalesced => crate::types::Role::Coalesced,
            Topology::Disaggregated { prefill, .. } => {
                if gpu % self.n_gpus < prefill {
                    crate::types::Role::Prefill
                } else {
                    crate::types::Role::Decode
                }
            }
        }
    }

    /// Number of GPUs initially serving prefill **per node** (coalesced
    /// counts all).
    pub fn prefill_gpus(&self) -> usize {
        match self.topology {
            Topology::Coalesced => self.n_gpus,
            Topology::Disaggregated { prefill, .. } => prefill,
        }
    }

    /// Load from TOML text, starting from `preset` if given. Unknown
    /// keys are rejected with an error naming the key and its table.
    pub fn from_toml(text: &str) -> Result<ClusterConfig, ConfigError> {
        let doc = Document::parse(text)?;
        check_unknown_keys(&doc)?;
        let mut cfg = match doc.get_str("preset") {
            Some(name) => presets::by_name(name)?,
            None => ClusterConfig::default(),
        };
        apply_overrides(&mut cfg, &doc)?;
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Keys `from_toml` accepts, by table (`""` = top level). Used by the
/// strict unknown-key validation so a misspelled key fails loudly
/// instead of being silently ignored.
const KNOWN_TABLES: &[(&str, &[&str])] = &[
    ("", &["preset", "name"]),
    ("cluster", &["n_gpus", "n_nodes", "topology", "prefill_gpus", "skus"]),
    (
        "power",
        &["budget_w", "cluster_budget_w", "enforce_budget", "prefill_cap_w", "decode_cap_w"],
    ),
    ("control", &["policy"]),
    (
        "controller",
        &[
            "min_gpu_w",
            "max_gpu_w",
            "decode_ceiling_w",
            "queue_threshold",
            "tick_ms",
            "cooldown_ms",
            "power_step_w",
        ],
    ),
    (
        "perf",
        &[
            "prefill_rate_tps",
            "decode_base_us",
            "decode_per_req_us",
            "idle_w",
            "kv_bytes_per_token",
            "xgmi_bw_gbps",
            "inter_node_bw_gbps",
            "chunk_tokens",
        ],
    ),
    (
        "batch",
        &["max_prefill_tokens", "max_prefill_reqs", "max_decode_reqs", "ring_slots"],
    ),
    (
        "env",
        &["cluster_cap", "node_cap", "fail", "recover", "throttle", "clear"],
    ),
    ("env.curtailment", &["period_s", "duty", "budget_frac", "start_s"]),
    ("env.faults", &["mtbf_s", "mttr_s", "seed", "max_failures"]),
    (
        "mem",
        &[
            "hbm_gb",
            "remote_gb",
            "local_bw_gbps",
            "remote_bw_gbps",
            "disk_bw_gbps",
            "remote_lat_us",
            "disk_lat_us",
            "prefix_cache",
        ],
    ),
    ("admission", &["mode", "queue_depth", "bucket_rps", "bucket_burst"]),
];

/// Fields a `[tenant.<name>]` table accepts.
pub(crate) const TENANT_KEYS: &[&str] = &["share", "tier", "slo_scale"];

/// Fields a `[sku.<name>]` table accepts: the power envelope plus every
/// calibrated perf-model constant.
const SKU_KEYS: &[&str] = &[
    "max_w",
    "cap_floor_w",
    "idle_w",
    "prefill_rate_tps",
    "prefill_overhead_ms",
    "decode_base_us",
    "decode_per_req_us",
    "decode_kv_us_per_ktok",
    "decode_kv_ctx_cap_tokens",
    "prefill_speedup_max",
    "prefill_knee_w",
    "decode_speedup_max",
    "decode_knee_w",
    "kv_bytes_per_token",
    "xgmi_bw_gbps",
    "inter_node_bw_gbps",
    "chunk_tokens",
    "chunk_reread_frac",
    "ref_w",
    "rated_w",
    "decode_rated_w",
    "hbm_gb",
];

/// Reject any key the config loader would silently ignore, naming the
/// key and its table (and the keys that table does accept).
fn check_unknown_keys(doc: &Document) -> Result<(), ConfigError> {
    doc.check_known_keys(KNOWN_TABLES, &[("sku", SKU_KEYS), ("tenant", TENANT_KEYS)])
        .map_err(ConfigError::Invalid)
}

/// Parse every `[tenant.<name>]` table into a name-sorted class list
/// (sorted so tenant ids are stable regardless of file layout).
pub(crate) fn parse_tenant_tables(
    doc: &Document,
) -> Result<Vec<crate::workload::tracespec::TenantClass>, ConfigError> {
    use crate::workload::tracespec::{parse_tier, validate_tenants, TenantClass, TIER_STANDARD};
    let mut names: Vec<&str> = Vec::new();
    for key in doc.entries.keys() {
        if let Some(rest) = key.strip_prefix("tenant.") {
            if let Some((name, _field)) = rest.rsplit_once('.') {
                if !names.contains(&name) {
                    names.push(name);
                }
            }
        }
    }
    names.sort_unstable();
    let mut out = Vec::with_capacity(names.len());
    for name in names {
        let share = doc.get_f64(&format!("tenant.{name}.share")).ok_or_else(|| {
            ConfigError::Invalid(format!("[tenant.{name}] needs a share key"))
        })?;
        let tier = match doc.get_str(&format!("tenant.{name}.tier")) {
            Some(s) => parse_tier(s).map_err(ConfigError::Invalid)?,
            None => TIER_STANDARD,
        };
        let slo_scale = doc.get_f64(&format!("tenant.{name}.slo_scale")).unwrap_or(1.0);
        out.push(TenantClass { name: name.to_string(), share, tier, slo_scale });
    }
    validate_tenants(&out).map_err(ConfigError::Invalid)?;
    Ok(out)
}

/// Parse every `[sku.<name>]` table: start from the built-in catalog
/// entry of that name (or the paper's default part for new names) and
/// apply the overrides.
fn parse_sku_tables(doc: &Document) -> Result<Vec<GpuSku>, ConfigError> {
    let mut names: Vec<&str> = Vec::new();
    for key in doc.entries.keys() {
        if let Some(rest) = key.strip_prefix("sku.") {
            if let Some((name, _field)) = rest.rsplit_once('.') {
                if !names.contains(&name) {
                    names.push(name);
                }
            }
        }
    }
    let mut out = Vec::with_capacity(names.len());
    for name in names {
        let mut sku = skus::by_name(name)
            .unwrap_or_else(|| GpuSku::new(name, PerfModelConfig::default(), 400.0, 750.0));
        let get = |field: &str| doc.get_f64(&format!("sku.{name}.{field}"));
        if let Some(v) = get("max_w") {
            sku.max_w = v;
        }
        if let Some(v) = get("cap_floor_w") {
            sku.cap_floor_w = v;
        }
        if let Some(v) = get("idle_w") {
            sku.idle_w = v;
            sku.perf.idle_w = v;
        }
        let p = &mut sku.perf;
        if let Some(v) = get("prefill_rate_tps") {
            p.prefill_rate_tps = v;
        }
        if let Some(v) = get("prefill_overhead_ms") {
            p.prefill_overhead = (v * MILLIS as f64) as Micros;
        }
        if let Some(v) = get("decode_base_us") {
            p.decode_base = v as Micros;
        }
        if let Some(v) = get("decode_per_req_us") {
            p.decode_per_req = v as Micros;
        }
        if let Some(v) = get("decode_kv_us_per_ktok") {
            p.decode_kv_us_per_ktok = v;
        }
        if let Some(v) = get("decode_kv_ctx_cap_tokens") {
            p.decode_kv_ctx_cap_tokens = v;
        }
        if let Some(v) = get("prefill_speedup_max") {
            p.prefill_speedup_max = v;
        }
        if let Some(v) = get("prefill_knee_w") {
            p.prefill_knee_w = v;
        }
        if let Some(v) = get("decode_speedup_max") {
            p.decode_speedup_max = v;
        }
        if let Some(v) = get("decode_knee_w") {
            p.decode_knee_w = v;
        }
        if let Some(v) = get("kv_bytes_per_token") {
            p.kv_bytes_per_token = v as u64;
        }
        if let Some(v) = get("xgmi_bw_gbps") {
            p.xgmi_bw = v * 1e9;
        }
        if let Some(v) = get("inter_node_bw_gbps") {
            p.inter_node_bw = v * 1e9;
        }
        if let Some(v) = get("chunk_tokens") {
            p.chunk_tokens = v as u32;
        }
        if let Some(v) = get("chunk_reread_frac") {
            p.chunk_reread_frac = v;
        }
        if let Some(v) = get("ref_w") {
            p.ref_w = v;
        }
        if let Some(v) = get("rated_w") {
            p.rated_w = v;
        }
        if let Some(v) = get("decode_rated_w") {
            p.decode_rated_w = v;
        }
        if let Some(v) = get("hbm_gb") {
            sku.hbm_gb = Some(v);
        }
        sku.validate().map_err(ConfigError::Invalid)?;
        out.push(sku);
    }
    Ok(out)
}

fn get_watts(doc: &Document, key: &str) -> Option<Watts> {
    doc.get_f64(key)
}

fn apply_overrides(cfg: &mut ClusterConfig, doc: &Document) -> Result<(), ConfigError> {
    if let Some(name) = doc.get_str("name") {
        cfg.name = name.to_string();
    }
    if let Some(n) = doc.get_i64("cluster.n_gpus") {
        cfg.n_gpus = n as usize;
    }
    if let Some(n) = doc.get_i64("cluster.n_nodes") {
        cfg.n_nodes = n as usize;
    }
    if let Some(w) = get_watts(doc, "power.budget_w") {
        cfg.node_budget_w = w;
    }
    if let Some(w) = get_watts(doc, "power.cluster_budget_w") {
        cfg.cluster_budget_w = Some(w);
    }
    if let Some(b) = doc.get_bool("power.enforce_budget") {
        cfg.enforce_budget = b;
    }
    if let Some(w) = get_watts(doc, "power.prefill_cap_w") {
        cfg.prefill_cap_w = w;
    }
    if let Some(w) = get_watts(doc, "power.decode_cap_w") {
        cfg.decode_cap_w = w;
    }
    match (doc.get_str("cluster.topology"), doc.get_i64("cluster.prefill_gpus")) {
        (Some("coalesced"), _) => cfg.topology = Topology::Coalesced,
        (Some("disaggregated"), Some(p)) => {
            let p = p as usize;
            if p >= cfg.n_gpus {
                return Err(ConfigError::Invalid(format!(
                    "prefill_gpus {p} must be < n_gpus {}",
                    cfg.n_gpus
                )));
            }
            cfg.topology = Topology::Disaggregated {
                prefill: p,
                decode: cfg.n_gpus - p,
            };
        }
        (Some("disaggregated"), None) => {
            return Err(ConfigError::Invalid(
                "disaggregated topology needs cluster.prefill_gpus".into(),
            ))
        }
        (Some(other), _) => {
            return Err(ConfigError::Invalid(format!("unknown topology '{other}'")))
        }
        (None, _) => {}
    }
    if let Some(policy) = doc.get_str("control.policy") {
        cfg.control = policy.parse().map_err(ConfigError::Invalid)?;
    }
    let c = &mut cfg.controller;
    if let Some(w) = get_watts(doc, "controller.min_gpu_w") {
        c.min_gpu_w = w;
    }
    if let Some(w) = get_watts(doc, "controller.max_gpu_w") {
        c.max_gpu_w = w;
    }
    if let Some(w) = get_watts(doc, "controller.decode_ceiling_w") {
        c.decode_ceiling_w = w;
    }
    if let Some(n) = doc.get_i64("controller.queue_threshold") {
        c.queue_threshold = n as usize;
    }
    if let Some(ms) = doc.get_f64("controller.tick_ms") {
        c.tick = (ms * MILLIS as f64) as Micros;
    }
    if let Some(ms) = doc.get_f64("controller.cooldown_ms") {
        c.cooldown = (ms * MILLIS as f64) as Micros;
    }
    if let Some(w) = get_watts(doc, "controller.power_step_w") {
        c.power_step_w = w;
    }
    let p = &mut cfg.perf;
    if let Some(v) = doc.get_f64("perf.prefill_rate_tps") {
        p.prefill_rate_tps = v;
    }
    if let Some(v) = doc.get_f64("perf.decode_base_us") {
        p.decode_base = v as Micros;
    }
    if let Some(v) = doc.get_f64("perf.decode_per_req_us") {
        p.decode_per_req = v as Micros;
    }
    if let Some(v) = doc.get_f64("perf.idle_w") {
        p.idle_w = v;
    }
    if let Some(v) = doc.get_f64("perf.kv_bytes_per_token") {
        p.kv_bytes_per_token = v as u64;
    }
    if let Some(v) = doc.get_f64("perf.xgmi_bw_gbps") {
        p.xgmi_bw = v * 1e9;
    }
    if let Some(v) = doc.get_f64("perf.inter_node_bw_gbps") {
        p.inter_node_bw = v * 1e9;
    }
    if let Some(v) = doc.get_i64("perf.chunk_tokens") {
        p.chunk_tokens = v as u32;
    }
    let b = &mut cfg.batch;
    if let Some(v) = doc.get_i64("batch.max_prefill_tokens") {
        b.max_prefill_tokens = v as u32;
    }
    if let Some(v) = doc.get_i64("batch.max_prefill_reqs") {
        b.max_prefill_reqs = v as usize;
    }
    if let Some(v) = doc.get_i64("batch.max_decode_reqs") {
        b.max_decode_reqs = v as usize;
    }
    if let Some(v) = doc.get_i64("batch.ring_slots") {
        b.ring_slots = v as usize;
    }
    // Environment disturbances: `[env]` tables (DESIGN.md §12).
    if let Some(profile) = EnvProfile::from_doc(doc).map_err(ConfigError::Invalid)? {
        cfg.env = profile;
    }
    // KV memory subsystem: a `[mem]` table activates capacity
    // enforcement (DESIGN.md §14). Any mem.* key present — even just
    // `prefix_cache = false` — turns the subsystem on.
    if doc.entries.keys().any(|k| k.starts_with("mem.")) {
        let mut mem = crate::mem::MemConfig::default();
        if let Some(v) = doc.get_f64("mem.hbm_gb") {
            mem.hbm_gb = Some(v);
        }
        if let Some(v) = doc.get_f64("mem.remote_gb") {
            mem.remote_gb = v;
        }
        if let Some(v) = doc.get_f64("mem.local_bw_gbps") {
            mem.local_bw_gbps = v;
        }
        if let Some(v) = doc.get_f64("mem.remote_bw_gbps") {
            mem.remote_bw_gbps = v;
        }
        if let Some(v) = doc.get_f64("mem.disk_bw_gbps") {
            mem.disk_bw_gbps = v;
        }
        if let Some(v) = doc.get_f64("mem.remote_lat_us") {
            mem.remote_lat_us = v as Micros;
        }
        if let Some(v) = doc.get_f64("mem.disk_lat_us") {
            mem.disk_lat_us = v as Micros;
        }
        if let Some(b) = doc.get_bool("mem.prefix_cache") {
            mem.prefix_cache = b;
        }
        cfg.mem = Some(mem);
    }
    // Admission control: an `[admission]` table selects a shedding
    // policy (DESIGN.md §15); absent, the default mode admits all.
    if let Some(adm) = crate::cluster::admission::AdmissionConfig::from_doc(doc)
        .map_err(ConfigError::Invalid)?
    {
        cfg.admission = adm;
    }
    // Tenant classes: `[tenant.<name>]` tables, name-sorted for stable
    // tenant ids.
    let tenants = parse_tenant_tables(doc)?;
    if !tenants.is_empty() {
        cfg.tenants = tenants;
    }
    // Fleet mix: `[sku.<name>]` tables resolve first, then the ordered
    // `cluster.skus = ["name:count", ...]` mix references them (plus the
    // built-in catalog).
    let file_skus = parse_sku_tables(doc)?;
    match doc.get("cluster.skus") {
        Some(Value::Array(values)) => {
            let entries = values
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        ConfigError::Invalid(
                            "cluster.skus entries must be \"name:count\" strings".into(),
                        )
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            cfg.fleet =
                Some(FleetConfig::resolve(&entries, &file_skus).map_err(ConfigError::Invalid)?);
        }
        Some(_) => {
            return Err(ConfigError::Invalid(
                "cluster.skus must be an array of \"name:count\" strings".into(),
            ))
        }
        None => {
            if !file_skus.is_empty() {
                return Err(ConfigError::Invalid(format!(
                    "[sku.{}] is defined but cluster.skus declares no mix using it",
                    file_skus[0].name
                )));
            }
        }
    }
    // With an explicit mix, per-GPU perf and power envelopes come from
    // the SKU tables — a top-level [perf] override or controller
    // min/max would be silently ignored (the exact trap the strict key
    // validation exists to prevent), so reject the combination.
    if cfg.fleet.is_some() {
        if let Some(key) = doc.entries.keys().find(|k| k.starts_with("perf.")) {
            return Err(ConfigError::Invalid(format!(
                "'{key}' has no effect when cluster.skus is declared — set it inside a \
                 [sku.<name>] table instead"
            )));
        }
        for key in ["controller.min_gpu_w", "controller.max_gpu_w"] {
            if doc.get(key).is_some() {
                return Err(ConfigError::Invalid(format!(
                    "'{key}' has no effect when cluster.skus is declared — per-GPU limits \
                     come from each SKU's cap_floor_w/max_w"
                )));
            }
        }
    }
    Ok(())
}

/// The paper's named configurations (§5).
pub mod presets {
    use super::*;

    fn base(name: &str) -> ClusterConfig {
        ClusterConfig {
            name: name.to_string(),
            n_gpus: 8,
            n_nodes: 1,
            cluster_budget_w: None,
            node_budget_w: 4800.0,
            enforce_budget: true,
            topology: Topology::Disaggregated { prefill: 4, decode: 4 },
            prefill_cap_w: 600.0,
            decode_cap_w: 600.0,
            control: ControlPolicy::Static,
            controller: ControllerConfig::default(),
            perf: PerfModelConfig::default(),
            batch: BatchConfig::default(),
            fleet: None,
            env: EnvProfile::default(),
            mem: None,
            admission: crate::cluster::admission::AdmissionConfig::default(),
            tenants: Vec::new(),
        }
    }

    /// Reparametrize any config to a uniform per-GPU cap `w` with the
    /// node budget tracking it (`w × n_gpus`) — the §5.1 budget-sweep
    /// axis shared by the presets and `scenario::Axis::PowerW`.
    pub fn uniform_power(mut cfg: ClusterConfig, w: Watts) -> ClusterConfig {
        cfg.prefill_cap_w = w;
        cfg.decode_cap_w = w;
        cfg.node_budget_w = w * cfg.n_gpus as f64;
        cfg
    }

    /// Coalesced-`{w}`W: vLLM chunked-prefill baseline, uniform caps.
    pub fn coalesced(w: Watts) -> ClusterConfig {
        let mut c = base(&format!("Coalesced-{}W", w as u32));
        c.topology = Topology::Coalesced;
        uniform_power(c, w)
    }

    /// 4P4D-`{w}`W: uniform-power disaggregation.
    pub fn p4d4(w: Watts) -> ClusterConfig {
        uniform_power(base(&format!("4P4D-{}W", w as u32)), w)
    }

    /// 5P3D-600W: shifting a GPU instead of power.
    pub fn p5d3_600() -> ClusterConfig {
        let mut c = base("5P3D-600W");
        c.topology = Topology::Disaggregated { prefill: 5, decode: 3 };
        c
    }

    /// 4P-750W/4D-450W: RAPID's static non-uniform allocation (Fig 5a's
    /// winner at TPOT=40ms). 4*750 + 4*450 = 4800 W.
    pub fn p4_750_d4_450() -> ClusterConfig {
        let mut c = base("4P-750W/4D-450W");
        c.prefill_cap_w = 750.0;
        c.decode_cap_w = 450.0;
        c
    }

    /// 4P-675W/4D-525W: the Fig 5b winner under the tighter 25 ms TPOT.
    pub fn p4_675_d4_525() -> ClusterConfig {
        let mut c = base("4P-675W/4D-525W");
        c.prefill_cap_w = 675.0;
        c.decode_cap_w = 525.0;
        c
    }

    /// 4P4D-DynPower: dynamic power shifting only (Fig 8/9a).
    pub fn dyn_power_600() -> ClusterConfig {
        let mut c = base("4P4D-DynPower");
        c.control = ControlPolicy::DynPower;
        c
    }

    /// DynGPU-600W: dynamic GPU reallocation, uniform 600 W caps (Fig 8/9b).
    pub fn dyn_gpu_600() -> ClusterConfig {
        let mut c = base("DynGPU-600W");
        c.control = ControlPolicy::DynGpu;
        c
    }

    /// DynGPU-DynPower: full RAPID (Fig 8/9c).
    pub fn rapid_600() -> ClusterConfig {
        let mut c = base("DynGPU-DynPower");
        c.control = ControlPolicy::DynPowerGpu;
        c
    }

    /// PowerOnly-600W: the ablation policy — latency-driven power
    /// shifting with none of Algorithm 1's arbitration.
    pub fn power_only_600() -> ClusterConfig {
        let mut c = base("PowerOnly-600W");
        c.control = ControlPolicy::PowerOnly;
        c
    }

    /// Scale any preset out to `nodes` identical nodes (used by
    /// `rapid sweep --nodes N` and the multi-node tests).
    pub fn scaled_to_nodes(mut cfg: ClusterConfig, nodes: usize) -> ClusterConfig {
        cfg.n_nodes = nodes;
        if nodes > 1 {
            cfg.name = format!("{}x{nodes}nodes", cfg.name);
        }
        cfg
    }

    /// Uncapped node (Fig 3): caps at hardware max, budget reported only.
    pub fn uncapped_coalesced() -> ClusterConfig {
        let mut c = coalesced(750.0);
        c.name = "Uncapped-Coalesced".into();
        c.node_budget_w = 4800.0;
        c.enforce_budget = false;
        c
    }

    pub fn by_name(name: &str) -> Result<ClusterConfig, ConfigError> {
        let cfg = match name {
            "coalesced-750" => coalesced(750.0),
            "coalesced-600" => coalesced(600.0),
            "4p4d-750" => p4d4(750.0),
            "4p4d-600" => p4d4(600.0),
            "5p3d-600" => p5d3_600(),
            "4p750-4d450" => p4_750_d4_450(),
            "4p675-4d525" => p4_675_d4_525(),
            "dyn-power-600" => dyn_power_600(),
            "dyn-gpu-600" => dyn_gpu_600(),
            "rapid-600" => rapid_600(),
            "power-only-600" => power_only_600(),
            "uncapped" => uncapped_coalesced(),
            other => return Err(ConfigError::UnknownPreset(other.to_string())),
        };
        Ok(cfg)
    }

    /// All preset names (CLI help + tests).
    pub const NAMES: &[&str] = &[
        "coalesced-750",
        "coalesced-600",
        "4p4d-750",
        "4p4d-600",
        "5p3d-600",
        "4p750-4d450",
        "4p675-4d525",
        "dyn-power-600",
        "dyn-gpu-600",
        "rapid-600",
        "power-only-600",
        "uncapped",
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        for name in presets::NAMES {
            let cfg = presets::by_name(name).unwrap();
            cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn paper_static_winner_fits_budget_exactly() {
        let cfg = presets::p4_750_d4_450();
        assert_eq!(cfg.total_initial_caps(), 4800.0);
        assert!(cfg.enforce_budget);
    }

    #[test]
    fn budget_violation_rejected() {
        let mut cfg = presets::p4d4(600.0);
        cfg.prefill_cap_w = 750.0; // 4*750 + 4*600 = 5400 > 4800
        cfg.node_budget_w = 4800.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn topology_counts_must_sum() {
        let mut cfg = presets::p4d4(600.0);
        cfg.topology = Topology::Disaggregated { prefill: 3, decode: 4 };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn zero_phase_rejected() {
        let mut cfg = presets::p4d4(600.0);
        cfg.topology = Topology::Disaggregated { prefill: 8, decode: 0 };
        cfg.n_gpus = 8;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn caps_outside_limits_rejected() {
        let mut cfg = presets::p4d4(600.0);
        cfg.decode_cap_w = 300.0; // < MIN_P
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn toml_preset_plus_overrides() {
        let cfg = ClusterConfig::from_toml(
            r#"
preset = "4p4d-600"
name = "custom"
[power]
prefill_cap_w = 700
decode_cap_w = 500
[controller]
cooldown_ms = 4000
[batch]
ring_slots = 16
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "custom");
        assert_eq!(cfg.prefill_cap_w, 700.0);
        assert_eq!(cfg.decode_cap_w, 500.0);
        assert_eq!(cfg.controller.cooldown, 4 * SECOND);
        assert_eq!(cfg.batch.ring_slots, 16);
    }

    #[test]
    fn toml_topology_override() {
        let cfg = ClusterConfig::from_toml(
            r#"
preset = "4p4d-600"
[cluster]
topology = "disaggregated"
prefill_gpus = 6
"#,
        )
        .unwrap();
        assert_eq!(cfg.topology, Topology::Disaggregated { prefill: 6, decode: 2 });
    }

    #[test]
    fn toml_bad_policy_rejected() {
        let r = ClusterConfig::from_toml("[control]\npolicy = \"yolo\"");
        assert!(r.is_err());
    }

    #[test]
    fn toml_unknown_preset_rejected() {
        let r = ClusterConfig::from_toml("preset = \"8p0d\"");
        assert!(matches!(r, Err(ConfigError::UnknownPreset(_))));
    }

    #[test]
    fn uncapped_preset_reports_but_does_not_enforce() {
        let cfg = presets::uncapped_coalesced();
        assert!(!cfg.enforce_budget);
        assert!(cfg.total_initial_caps() > cfg.node_budget_w);
        cfg.validate().unwrap(); // allowed because enforce_budget = false
    }

    #[test]
    fn control_policy_capabilities() {
        assert!(!ControlPolicy::Static.is_dynamic());
        assert!(ControlPolicy::DynPower.moves_power());
        assert!(!ControlPolicy::DynPower.moves_gpus());
        assert!(ControlPolicy::DynGpu.moves_gpus());
        assert!(ControlPolicy::DynPowerGpu.moves_power());
        assert!(ControlPolicy::DynPowerGpu.moves_gpus());
        assert!(ControlPolicy::PowerOnly.moves_power());
        assert!(!ControlPolicy::PowerOnly.moves_gpus());
        assert!(ControlPolicy::PowerOnly.is_dynamic());
    }

    #[test]
    fn multi_node_defaults_and_totals() {
        let cfg = presets::p4d4(600.0);
        assert_eq!(cfg.n_nodes, 1);
        assert_eq!(cfg.total_gpus(), 8);
        assert_eq!(cfg.cluster_budget(), cfg.node_budget_w);
        let two = presets::scaled_to_nodes(presets::p4d4(600.0), 2);
        assert_eq!(two.total_gpus(), 16);
        assert_eq!(two.cluster_budget(), 9600.0);
        assert_eq!(two.node_of(0), 0);
        assert_eq!(two.node_of(7), 0);
        assert_eq!(two.node_of(8), 1);
        assert_eq!(two.initial_role(3), crate::types::Role::Prefill);
        assert_eq!(two.initial_role(4), crate::types::Role::Decode);
        assert_eq!(two.initial_role(11), crate::types::Role::Prefill);
        assert_eq!(two.initial_role(15), crate::types::Role::Decode);
        two.validate().unwrap();
    }

    #[test]
    fn multi_node_toml_round_trip() {
        let cfg = ClusterConfig::from_toml(
            r#"
preset = "4p4d-600"
name = "two-node"
[cluster]
n_nodes = 2
[power]
cluster_budget_w = 9600
[perf]
inter_node_bw_gbps = 20
"#,
        )
        .unwrap();
        assert_eq!(cfg.n_nodes, 2);
        assert_eq!(cfg.cluster_budget(), 9600.0);
        assert_eq!(cfg.perf.inter_node_bw, 20e9);
        assert_eq!(cfg.total_gpus(), 16);
    }

    #[test]
    fn cluster_budget_tighter_than_caps_rejected() {
        let mut cfg = presets::scaled_to_nodes(presets::p4d4(600.0), 2);
        cfg.cluster_budget_w = Some(9000.0); // 2 * 8 * 600 = 9600 committed
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn node_budget_below_cap_floor_rejected() {
        let mut cfg = presets::p4d4(600.0);
        // 8 GPUs x 400 W min = 3200 W floor; a 3000 W budget cannot host it.
        cfg.node_budget_w = 3000.0;
        cfg.prefill_cap_w = 400.0;
        cfg.decode_cap_w = 400.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn power_only_policy_parses() {
        let cfg = ClusterConfig::from_toml("[control]\npolicy = \"power-only\"").unwrap();
        assert_eq!(cfg.control, ControlPolicy::PowerOnly);
    }

    #[test]
    fn unknown_keys_rejected_with_table_named() {
        // A misspelled key in a known table names both the key and table.
        let err = ClusterConfig::from_toml("[controller]\ncooldown_msx = 4000").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("cooldown_msx"), "{msg}");
        assert!(msg.contains("[controller]"), "{msg}");
        assert!(msg.contains("cooldown_ms"), "should list valid keys: {msg}");
        // Unknown top-level key.
        let err = ClusterConfig::from_toml("presett = \"4p4d-600\"").unwrap_err();
        assert!(err.to_string().contains("presett"), "{err}");
        // Unknown table.
        let err = ClusterConfig::from_toml("[powr]\nbudget_w = 4800").unwrap_err();
        assert!(err.to_string().contains("powr.budget_w"), "{err}");
        // Unknown field inside a sku table.
        let err = ClusterConfig::from_toml(
            "[cluster]\nskus = [\"x:8\"]\n[sku.x]\nmax_watts = 700",
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("max_watts") && msg.contains("[sku.x]"), "{msg}");
    }

    #[test]
    fn sku_mix_toml_round_trip() {
        let cfg = ClusterConfig::from_toml(
            r#"
preset = "rapid-600"
name = "hetero"
[cluster]
skus = ["mi300x:2", "a100:2", "mi300x:2", "a100:2"]
"#,
        )
        .unwrap();
        let fc = cfg.fleet.as_ref().expect("fleet parsed");
        assert_eq!(fc.gpus_per_node(), 8);
        assert!(fc.heterogeneous());
        assert_eq!(fc.mix_label(), "mi300x:2+a100:2+mi300x:2+a100:2");
        // a100 slots clamp the 600 W cap to their 400 W envelope.
        assert_eq!(cfg.slot_cap(0), 600.0);
        assert_eq!(cfg.slot_cap(2), 400.0);
        assert!(cfg.total_initial_caps() < 8.0 * 600.0);
        assert!(cfg.cap_floor_per_node() < 8.0 * 400.0);
        cfg.validate().unwrap();
    }

    #[test]
    fn sku_table_overrides_and_custom_skus() {
        let cfg = ClusterConfig::from_toml(
            r#"
preset = "rapid-600"
[cluster]
skus = ["mi300x:4", "mi300x-derated:4"]
[sku.mi300x-derated]
max_w = 650
cap_floor_w = 400
prefill_rate_tps = 8000
idle_w = 120
"#,
        )
        .unwrap();
        let fc = cfg.fleet.unwrap();
        assert_eq!(fc.skus.len(), 2);
        let derated = &fc.skus[1];
        assert_eq!(derated.max_w, 650.0);
        assert_eq!(derated.perf.prefill_rate_tps, 8000.0);
        assert_eq!(derated.idle_w, 120.0);
        assert_eq!(derated.perf.idle_w, 120.0);
    }

    #[test]
    fn sku_mix_must_cover_n_gpus() {
        let err = ClusterConfig::from_toml(
            "preset = \"rapid-600\"\n[cluster]\nskus = [\"mi300x:2\", \"a100:2\"]",
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("4 GPUs") && msg.contains("n_gpus is 8"), "{msg}");
    }

    #[test]
    fn sku_tables_without_mix_rejected() {
        let err = ClusterConfig::from_toml("[sku.h100]\nmax_w = 700").unwrap_err();
        assert!(err.to_string().contains("declares no mix"), "{err}");
        let err = ClusterConfig::from_toml("[cluster]\nskus = [\"nope:8\"]").unwrap_err();
        assert!(err.to_string().contains("unknown sku 'nope'"), "{err}");
    }

    #[test]
    fn env_tables_round_trip_and_validate() {
        let cfg = ClusterConfig::from_toml(
            r#"
preset = "rapid-600"
[env]
cluster_cap = ["10:4000", "25:4800"]
fail = ["8:5"]
recover = ["20:5"]
[env.curtailment]
period_s = 30
duty = 0.5
budget_frac = 0.75
start_s = 10
"#,
        )
        .unwrap();
        assert_eq!(cfg.env.events.len(), 4);
        assert!(cfg.env.curtailment.is_some());
        assert!(!cfg.env.is_empty());
        // Unknown env key rejected with the table named.
        let err = ClusterConfig::from_toml("[env]\nfial = [\"8:5\"]").unwrap_err();
        assert!(err.to_string().contains("fial"), "{err}");
        // A curtailed budget below the fleet cap floor is structural.
        let err = ClusterConfig::from_toml(
            "preset = \"rapid-600\"\n[env.curtailment]\nperiod_s = 30\nbudget_frac = 0.5",
        )
        .unwrap_err();
        assert!(err.to_string().contains("cap floor"), "{err}");
        // A GPU index beyond the cluster is structural too.
        let err =
            ClusterConfig::from_toml("preset = \"rapid-600\"\n[env]\nfail = [\"8:9\"]").unwrap_err();
        assert!(err.to_string().contains("gpu 9"), "{err}");
    }

    #[test]
    fn mem_table_round_trip_and_validate() {
        let cfg = ClusterConfig::from_toml(
            r#"
preset = "rapid-600"
[mem]
hbm_gb = 16
remote_gb = 256
remote_bw_gbps = 12
disk_lat_us = 3000
prefix_cache = false
"#,
        )
        .unwrap();
        let mem = cfg.mem.as_ref().expect("mem table parsed");
        assert_eq!(mem.hbm_gb, Some(16.0));
        assert_eq!(mem.remote_gb, 256.0);
        assert_eq!(mem.remote_bw_gbps, 12.0);
        assert_eq!(mem.disk_lat_us, 3000);
        assert!(!mem.prefix_cache);
        // No [mem] table means no subsystem (bit-identity default).
        assert!(ClusterConfig::from_toml("preset = \"rapid-600\"").unwrap().mem.is_none());
        // Unknown mem key rejected with the table named.
        let err = ClusterConfig::from_toml("[mem]\nhbm_gbx = 16").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("hbm_gbx") && msg.contains("[mem]"), "{msg}");
        // Structural checks ride ClusterConfig::validate (rapid validate).
        let err = ClusterConfig::from_toml("[mem]\nhbm_gb = 0").unwrap_err();
        assert!(err.to_string().contains("must be > 0"), "{err}");
        // Tier ordering: remote faster than local is structural nonsense.
        let err = ClusterConfig::from_toml("[mem]\nremote_bw_gbps = 128").unwrap_err();
        assert!(err.to_string().contains("local >= remote >= disk"), "{err}");
    }

    #[test]
    fn sku_hbm_gb_override() {
        let cfg = ClusterConfig::from_toml(
            r#"
preset = "rapid-600"
[cluster]
skus = ["mi300x:4", "mi300x-slim:4"]
[sku.mi300x-slim]
hbm_gb = 96
"#,
        )
        .unwrap();
        let fc = cfg.fleet.unwrap();
        assert_eq!(fc.skus[0].hbm_gb, Some(192.0), "catalog value");
        assert_eq!(fc.skus[1].hbm_gb, Some(96.0), "table override");
        // Zero/negative capacities are rejected by sku validation.
        let err = ClusterConfig::from_toml(
            "[cluster]\nskus = [\"x:8\"]\n[sku.x]\nhbm_gb = -4",
        )
        .unwrap_err();
        assert!(err.to_string().contains("hbm_gb"), "{err}");
    }

    #[test]
    fn admission_and_tenant_tables_round_trip() {
        use crate::cluster::admission::AdmissionMode;
        use crate::workload::tracespec::{TIER_BATCH, TIER_INTERACTIVE, TIER_STANDARD};
        let cfg = ClusterConfig::from_toml(
            r#"
preset = "rapid-600"
[admission]
mode = "queue-depth"
queue_depth = 48
[tenant.chat]
share = 0.5
tier = "interactive"
[tenant.jobs]
share = 0.3
tier = "batch"
slo_scale = 4.0
[tenant.api]
share = 0.2
"#,
        )
        .unwrap();
        assert_eq!(cfg.admission.mode, AdmissionMode::QueueDepth);
        assert_eq!(cfg.admission.queue_depth, 48);
        // Tenant ids follow name-sorted order: api, chat, jobs.
        assert_eq!(cfg.tenants.len(), 3);
        assert_eq!(cfg.tenants[0].name, "api");
        assert_eq!(cfg.tenants[0].tier, TIER_STANDARD, "tier defaults to standard");
        assert_eq!(cfg.tenants[1].name, "chat");
        assert_eq!(cfg.tenants[1].tier, TIER_INTERACTIVE);
        assert_eq!(cfg.tenants[2].tier, TIER_BATCH);
        assert_eq!(cfg.tenants[2].slo_scale, 4.0);
        // No tables -> inert defaults (the bit-identity contract).
        let plain = ClusterConfig::from_toml("preset = \"rapid-600\"").unwrap();
        assert_eq!(plain.admission.mode, AdmissionMode::None);
        assert!(plain.tenants.is_empty());
    }

    #[test]
    fn admission_and_tenant_tables_rejected_when_malformed() {
        // Shares must sum to 1.
        let err = ClusterConfig::from_toml(
            "[tenant.a]\nshare = 0.5\n[tenant.b]\nshare = 0.2",
        )
        .unwrap_err();
        assert!(err.to_string().contains("sum to 1"), "{err}");
        // A tenant table needs its share.
        let err = ClusterConfig::from_toml("[tenant.a]\ntier = \"batch\"").unwrap_err();
        assert!(err.to_string().contains("share"), "{err}");
        // Unknown tier names are named back.
        let err =
            ClusterConfig::from_toml("[tenant.a]\nshare = 1.0\ntier = \"vip\"").unwrap_err();
        assert!(err.to_string().contains("vip"), "{err}");
        // Unknown tenant keys hit the strict key check.
        let err =
            ClusterConfig::from_toml("[tenant.a]\nshare = 1.0\nsharee = 2").unwrap_err();
        assert!(err.to_string().contains("sharee"), "{err}");
        // Admission mode is mandatory when the table is present.
        let err = ClusterConfig::from_toml("[admission]\nqueue_depth = 8").unwrap_err();
        assert!(err.to_string().contains("mode"), "{err}");
    }

    #[test]
    fn perf_and_envelope_overrides_rejected_alongside_sku_mix() {
        // A [perf] override would be silently shadowed by the SKU tables;
        // it must be rejected, pointing at the [sku.*] grammar.
        let err = ClusterConfig::from_toml(
            "[cluster]\nskus = [\"mi300x:8\"]\n[perf]\nprefill_rate_tps = 5000",
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("perf.prefill_rate_tps") && msg.contains("[sku."), "{msg}");
        // Same for the uniform controller envelope.
        let err = ClusterConfig::from_toml(
            "[cluster]\nskus = [\"mi300x:8\"]\n[controller]\nmin_gpu_w = 300",
        )
        .unwrap_err();
        assert!(err.to_string().contains("controller.min_gpu_w"), "{err}");
        // Other controller knobs (cooldown etc.) still apply and pass.
        ClusterConfig::from_toml(
            "[cluster]\nskus = [\"mi300x:8\"]\n[controller]\ncooldown_ms = 3000",
        )
        .unwrap();
    }
}
