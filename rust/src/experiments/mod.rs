//! Experiment drivers: one module per paper figure/table.
//!
//! Each driver is now a thin declaration over the [`crate::scenario`]
//! API: it states its `Scenario` (workload + SLO + sweep axes), runs it
//! through a `Study` (which fans every grid cell over `parallel_map`),
//! and keeps only the figure-specific `render()` tables and
//! paper-shape `checks()` (DESIGN.md §6). The `benches/` targets, the
//! `rapid fig*` subcommands and `rapid study` all share that one
//! experiment surface.
//!
//! The names re-exported below used to be defined here; they live in
//! `scenario` / `util::par` now so lower layers can use them too.

pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;

pub use crate::scenario::{
    crossing_rate, longbench_trace, render_checks, sustainable_rate, RatePoint, ShapeCheck,
};
pub use crate::util::par::{parallel_map, parallel_map_threads, sweep_threads, sweep_threads_with};

/// Default request count per simulated run. Large enough for stable
/// percentiles, small enough that full sweeps run in seconds.
pub const DEFAULT_REQUESTS: usize = 1200;
