//! Parallel sweep runner speedup: a 4-point Fig-5-style rate Study run
//! serially (explicit `threads = 1`) vs fanned across all cores, with a
//! bit-identical-results check (each Study cell derives everything from
//! its seed, so thread count must not change a single number).
//!
//! `cargo bench --bench sweep_parallel`
//! Acceptance: >= 2x wall-clock speedup on a multi-core runner.

use rapid::config::presets;
use rapid::experiments::sweep_threads;
use rapid::scenario::{Axis, Scenario, Study, StudyResult};

const RATES: &[f64] = &[0.75, 1.25, 1.75, 2.25];

fn run_once(n: usize, threads: Option<usize>) -> StudyResult {
    Study::new(
        Scenario::new("sweep-parallel", presets::p4_750_d4_450())
            .seed(42)
            .requests(n)
            .axis(Axis::RatePerGpu(RATES.to_vec())),
    )
    .run(threads)
    .expect("bench scenario")
}

fn main() {
    let n: usize = std::env::var("RAPID_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1500);

    let t0 = std::time::Instant::now();
    let serial = run_once(n, Some(1));
    let t_serial = t0.elapsed().as_secs_f64();

    let cores = sweep_threads();
    let t1 = std::time::Instant::now();
    let parallel = run_once(n, None);
    let t_parallel = t1.elapsed().as_secs_f64();

    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(a.rate_per_gpu, b.rate_per_gpu);
        assert_eq!(a.attainment(), b.attainment(), "thread count changed results!");
        assert_eq!(a.goodput_qps(), b.goodput_qps());
    }

    let speedup = t_serial / t_parallel.max(1e-9);
    println!(
        "sweep_parallel: {} points x {n} reqs | serial {t_serial:.2}s | \
         parallel({cores} threads) {t_parallel:.2}s | speedup {speedup:.2}x",
        RATES.len()
    );
    let expected = if cores >= 4 { 2.0 } else { 1.2 };
    println!(
        "  [{}] parallel sweep >= {expected}x over serial on this {cores}-core runner",
        if speedup >= expected { "PASS" } else { "FAIL" }
    );
    if let Some(path) = rapid::bench::json_arg() {
        let mut report = rapid::bench::BenchReport::new("sweep_parallel");
        report
            .entries
            .push(rapid::bench::Timing::single("sweep/serial", t_serial * 1e6));
        report
            .entries
            .push(rapid::bench::Timing::single("sweep/parallel", t_parallel * 1e6));
        report.meta.insert("speedup".into(), format!("{speedup:.3}"));
        report.meta.insert("threads".into(), cores.to_string());
        report.write(&path).expect("write bench json");
        println!("wrote {path}");
    }
}
