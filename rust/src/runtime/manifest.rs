//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime (shapes, dtypes, parameter order, variant files).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub prefill_seq: usize,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_elems: usize,
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VariantKind {
    Prefill,
    Decode,
    /// Logits extraction: state -> f32[batch, vocab] (tiny, per step).
    Extract,
}

#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub kind: VariantKind,
    pub batch: usize,
    pub file: String,
    /// Flat state length: 2 * cache elems + batch * vocab.
    pub state_elems: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seed: u64,
    pub model: ModelSpec,
    pub weights_file: String,
    pub total_elems: usize,
    pub params: Vec<ParamSpec>,
    pub variants: Vec<VariantSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(dir, &j)
    }

    fn from_json(dir: PathBuf, j: &Json) -> Result<Manifest> {
        let field = |o: &Json, k: &str| -> Result<Json> {
            Ok(o.get(k).ok_or_else(|| anyhow!("missing '{k}'"))?.clone())
        };
        let version = field(j, "format_version")?
            .as_u64()
            .ok_or_else(|| anyhow!("bad format_version"))?;
        if version != 2 {
            bail!("unsupported manifest version {version} (rebuild: make artifacts)");
        }
        let m = field(j, "model")?;
        let u = |k: &str| -> Result<usize> {
            field(&m, k)?.as_usize().ok_or_else(|| anyhow!("bad model.{k}"))
        };
        let model = ModelSpec {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            head_dim: u("head_dim")?,
            d_ff: u("d_ff")?,
            max_seq: u("max_seq")?,
            prefill_seq: u("prefill_seq")?,
        };
        let w = field(j, "weights")?;
        let weights_file = field(&w, "file")?
            .as_str()
            .ok_or_else(|| anyhow!("bad weights.file"))?
            .to_string();
        let total_elems = field(&w, "total_elems")?
            .as_usize()
            .ok_or_else(|| anyhow!("bad weights.total_elems"))?;
        let mut params = Vec::new();
        for p in field(j, "params")?.as_arr().unwrap_or(&[]) {
            params.push(ParamSpec {
                name: field(p, "name")?.as_str().unwrap_or("").to_string(),
                shape: field(p, "shape")?
                    .as_dims()
                    .ok_or_else(|| anyhow!("bad param shape"))?,
                offset_elems: field(p, "offset_elems")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("bad offset"))?,
            });
        }
        if params.is_empty() {
            bail!("manifest has no params");
        }
        let mut variants = Vec::new();
        for v in field(j, "variants")?.as_arr().unwrap_or(&[]) {
            let kind = match field(v, "kind")?.as_str() {
                Some("prefill") => VariantKind::Prefill,
                Some("decode") => VariantKind::Decode,
                Some("extract") => VariantKind::Extract,
                other => bail!("unknown variant kind {other:?}"),
            };
            variants.push(VariantSpec {
                kind,
                batch: field(v, "batch")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("bad batch"))?,
                file: field(v, "file")?
                    .as_str()
                    .ok_or_else(|| anyhow!("bad file"))?
                    .to_string(),
                state_elems: field(v, "state_elems")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("bad state_elems"))?,
            });
        }
        if variants.is_empty() {
            bail!("manifest has no variants");
        }
        let seed = field(j, "seed")?.as_u64().unwrap_or(0);
        Ok(Manifest {
            dir,
            seed,
            model,
            weights_file,
            total_elems,
            params,
            variants,
        })
    }

    /// Consistency checks (offsets contiguous, sizes match weights.bin).
    pub fn validate(&self) -> Result<()> {
        let mut offset = 0;
        for p in &self.params {
            if p.offset_elems != offset {
                bail!("param {} offset {} != expected {offset}", p.name, p.offset_elems);
            }
            offset += p.elems();
        }
        if offset != self.total_elems {
            bail!("param elems {offset} != total {}", self.total_elems);
        }
        let wpath = self.dir.join(&self.weights_file);
        let len = std::fs::metadata(&wpath)
            .with_context(|| format!("weights file {}", wpath.display()))?
            .len();
        if len != self.total_elems as u64 * 4 {
            bail!("weights.bin size {len} != {} f32 elems", self.total_elems);
        }
        Ok(())
    }

    /// The available batch sizes for a kind, ascending.
    pub fn batches(&self, kind: VariantKind) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .variants
            .iter()
            .filter(|x| x.kind == kind)
            .map(|x| x.batch)
            .collect();
        v.sort();
        v
    }

    /// Smallest variant batch that fits `n` requests (None if n exceeds
    /// the largest — caller must split).
    pub fn pick_batch(&self, kind: VariantKind, n: usize) -> Option<usize> {
        self.batches(kind).into_iter().find(|&b| b >= n)
    }

    pub fn variant(&self, kind: VariantKind, batch: usize) -> Option<&VariantSpec> {
        self.variants
            .iter()
            .find(|v| v.kind == kind && v.batch == batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_and_validates_built_artifacts() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        m.validate().unwrap();
        assert_eq!(m.model.d_model, m.model.n_heads * m.model.head_dim);
        assert!(!m.batches(VariantKind::Prefill).is_empty());
        assert!(!m.batches(VariantKind::Decode).is_empty());
        // Every decode batch has a matching extract module.
        for b in m.batches(VariantKind::Decode) {
            assert!(m.variant(VariantKind::Extract, b).is_some(), "extract b{b}");
        }
    }

    #[test]
    fn pick_batch_rounds_up() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let batches = m.batches(VariantKind::Decode);
        let largest = *batches.last().unwrap();
        assert_eq!(m.pick_batch(VariantKind::Decode, 1), Some(batches[0]));
        assert_eq!(m.pick_batch(VariantKind::Decode, largest), Some(largest));
        assert_eq!(m.pick_batch(VariantKind::Decode, largest + 1), None);
    }

    #[test]
    fn rejects_bad_manifest() {
        let tmp = std::env::temp_dir().join(format!("rapid-mani-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), "{\"format_version\": 99}").unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::write(tmp.join("manifest.json"), "not json").unwrap();
        assert!(Manifest::load(&tmp).is_err());
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
