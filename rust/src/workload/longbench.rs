//! LongBench statistical replica (paper §4).
//!
//! LongBench prompts are long-context documents; the paper truncates to a
//! maximum of 8 K input tokens. We model the published length profile as
//! a log-normal body with a hard cap at 8 K (the cap produces the mass
//! spike at the maximum the paper mentions as "a unique distribution of
//! long requests"). Outputs are short summaries/answers: uniform 64–192
//! tokens around the paper's 128-token working point.

use crate::util::rng::Rng;
use crate::workload::SizeSampler;

pub const MAX_INPUT_TOKENS: u32 = 8192;

#[derive(Debug, Clone)]
pub struct LongBench {
    rng: Rng,
    max_input: u32,
}

impl LongBench {
    pub fn new(rng: Rng) -> Self {
        LongBench {
            rng,
            max_input: MAX_INPUT_TOKENS,
        }
    }

    pub fn with_max_input(rng: Rng, max_input: u32) -> Self {
        LongBench { rng, max_input }
    }

    /// Mean prompt length of the (capped) distribution, by simulation.
    pub fn mean_input_tokens(seed: u64, n: usize) -> f64 {
        let mut lb = LongBench::new(Rng::new(seed));
        let total: u64 = (0..n).map(|i| lb.sample(i).0 as u64).sum();
        total as f64 / n as f64
    }
}

impl SizeSampler for LongBench {
    fn sample(&mut self, _i: usize) -> (u32, u32) {
        // Log-normal: median ~2000 tokens, sigma 0.8 -> long tail that the
        // 8K cap folds into a spike at max (LongBench's doc-length shape).
        let raw = self.rng.lognormal(7.6, 0.8);
        let input = (raw as u32).clamp(64, self.max_input);
        let output = 64 + self.rng.range_u64(0, 129) as u32; // 64..=192
        (input, output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inputs_within_bounds() {
        let mut lb = LongBench::new(Rng::new(1));
        for i in 0..10_000 {
            let (inp, out) = lb.sample(i);
            assert!((64..=MAX_INPUT_TOKENS).contains(&inp));
            assert!((64..=192).contains(&out));
        }
    }

    #[test]
    fn long_tailed_with_cap_spike() {
        let mut lb = LongBench::new(Rng::new(2));
        let samples: Vec<u32> = (0..20_000).map(|i| lb.sample(i).0).collect();
        let at_cap = samples.iter().filter(|&&x| x == MAX_INPUT_TOKENS).count();
        // A visible but minority spike at the cap.
        let frac = at_cap as f64 / samples.len() as f64;
        assert!((0.01..0.30).contains(&frac), "cap spike frac={frac}");
        let mut sorted = samples.clone();
        sorted.sort();
        let median = sorted[sorted.len() / 2] as f64;
        let mean = samples.iter().map(|&x| x as f64).sum::<f64>() / samples.len() as f64;
        assert!(mean > median, "long tail: mean {mean} > median {median}");
        // Working point: mean ~2-3K tokens, median ~2K.
        assert!((1500.0..3500.0).contains(&mean), "mean={mean}");
    }

    #[test]
    fn custom_cap_respected() {
        let mut lb = LongBench::with_max_input(Rng::new(3), 1024);
        for i in 0..1000 {
            assert!(lb.sample(i).0 <= 1024);
        }
    }
}
