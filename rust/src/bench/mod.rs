//! Bench harness (offline substitute for `criterion`).
//!
//! Used by every `benches/*` target (all `harness = false`): warmup,
//! timed iterations, mean / p50 / p99, and a one-line report compatible
//! with eyeballing regressions. Also hosts `Table` for the figure benches
//! that print paper-style rows rather than timings.

use std::time::Instant;

use crate::util::stats::percentile;

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
}

impl Timing {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>10.1} us  p50 {:>10.1} us  p99 {:>10.1} us",
            self.name, self.iters, self.mean_us, self.p50_us, self.p99_us
        )
    }
}

/// Time `f` with warmup; iteration count adapts so the run takes roughly
/// `target_ms` total (bounded by `max_iters`).
pub fn bench<F: FnMut()>(name: &str, target_ms: u64, max_iters: usize, mut f: F) -> Timing {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_ms as f64 / 1000.0 / once) as usize)
        .clamp(3, max_iters.max(3));
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    Timing {
        name: name.to_string(),
        iters,
        mean_us: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_us: percentile(&samples, 50.0),
        p99_us: percentile(&samples, 99.0),
    }
}

/// Throughput helper: events per second given a timing and batch size.
pub fn per_second(t: &Timing, batch: usize) -> f64 {
    batch as f64 / (t.mean_us / 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut acc = 0u64;
        let t = bench("noop-ish", 10, 1000, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(t.iters >= 3);
        assert!(t.mean_us >= 0.0);
        assert!(t.report().contains("noop-ish"));
    }

    #[test]
    fn per_second_scales_with_batch() {
        let t = Timing {
            name: "x".into(),
            iters: 1,
            mean_us: 1000.0, // 1 ms
            p50_us: 1000.0,
            p99_us: 1000.0,
        };
        assert!((per_second(&t, 100) - 100_000.0).abs() < 1e-6);
    }
}
