//! Integration: load real artifacts, run prefill + decode chain on PJRT.
//! Requires `make artifacts`; tests are skipped (pass trivially) if the
//! artifact directory is absent so `cargo test` works pre-build.
#![cfg(feature = "pjrt")]

use rapid::runtime::{tokenizer, Engine};

fn engine() -> Option<Engine> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("artifacts/ missing; skipping runtime smoke test");
        return None;
    }
    Some(Engine::load(dir).expect("engine load"))
}

#[test]
fn prefill_then_decode_chain_runs() {
    let Some(eng) = engine() else { return };
    let prompt = tokenizer::encode("the power-aware scheduler shifts watts");
    let out = eng.prefill(&[prompt.clone()]).expect("prefill");
    assert_eq!(out.kv.batch, 1);
    let vocab = eng.manifest.model.vocab as i64;
    assert!((0..vocab).contains(&out.tokens[0]));

    // Decode 8 more tokens greedily.
    let mut kv = out.kv;
    let mut tok = out.tokens[0];
    let mut pos = prompt.len() as i64; // slot of the token being decoded
    let mut generated = vec![tok];
    for _ in 0..8 {
        let step = eng.decode(&[tok], &[pos], &kv).expect("decode");
        kv = step.kv;
        tok = step.tokens[0];
        pos += 1;
        assert!((0..vocab).contains(&tok));
        generated.push(tok);
    }
    assert_eq!(generated.len(), 9);
}

#[test]
fn decode_is_deterministic() {
    let Some(eng) = engine() else { return };
    let prompt = tokenizer::encode("determinism check");
    let a = eng.prefill(&[prompt.clone()]).unwrap();
    let b = eng.prefill(&[prompt]).unwrap();
    assert_eq!(a.tokens, b.tokens);
    let da = eng.decode(&[a.tokens[0]], &[18], &a.kv).unwrap();
    let db = eng.decode(&[b.tokens[0]], &[18], &b.kv).unwrap();
    assert_eq!(da.tokens, db.tokens);
}

#[test]
fn batched_prefill_matches_single() {
    let Some(eng) = engine() else { return };
    let p1 = tokenizer::encode("first prompt here");
    let p2 = tokenizer::encode("a second, longer prompt for lane two");
    let both = eng.prefill(&[p1.clone(), p2.clone()]).unwrap();
    let solo1 = eng.prefill(&[p1]).unwrap();
    let solo2 = eng.prefill(&[p2]).unwrap();
    assert_eq!(both.tokens[0], solo1.tokens[0], "lane 0 differs");
    assert_eq!(both.tokens[1], solo2.tokens[0], "lane 1 differs");
}
