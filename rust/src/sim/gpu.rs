//! Per-GPU simulated worker state (prefill / decode / coalesced).
//!
//! Queues and batches hold slab [`SlotId`]s into the cluster's
//! `RequestStore` — shuffling requests between pools moves 8-byte ids,
//! not whole `Request` structs (see `cluster::store`).

use std::collections::VecDeque;

use crate::cluster::store::RequestStore;
use crate::types::{Micros, Role};
use crate::util::slab::SlotId;

/// One simulated GPU worker.
#[derive(Debug)]
pub struct GpuSim {
    pub role: Role,
    /// Set while the GPU drains toward a new role.
    pub draining_to: Option<Role>,
    /// Bumped on every role change; in-flight events with an older epoch
    /// are stale and ignored.
    pub epoch: u64,
    /// An execution (prefill batch / decode step / coalesced step) is in
    /// flight.
    pub busy: bool,
    /// Down due to an environment `GpuFail`: accepts nothing, draws
    /// nothing, counts for nothing until `GpuRecover`.
    pub failed: bool,

    // --- prefill ---
    pub pf_queue: VecDeque<SlotId>,
    pub pf_queued_tokens: u64,
    /// In-flight prefill batch (each slot's `prefill_start` is stamped in
    /// the store when the batch forms).
    pub pf_batch: Vec<SlotId>,
    /// Completed prefills waiting for a free ring slot (backpressure).
    pub publish_wait: VecDeque<SlotId>,

    // --- decode ---
    pub dec_pending: VecDeque<SlotId>,
    pub dec_active: Vec<SlotId>,
    /// Duration of the decode step currently in flight.
    pub dec_step_time: Micros,

    // --- coalesced ---
    pub co_queue: VecDeque<SlotId>,
    /// Queued coalesced prompt tokens remaining, maintained incrementally
    /// (+= on route, -= as chunks advance, = 0 on fail drain) so the
    /// router reads a counter instead of walking the queue.
    pub co_tokens: u64,
    /// Prompts completing in the in-flight coalesced step.
    pub co_finishing: Vec<SlotId>,
    /// Chunk tokens being processed in the in-flight step.
    pub co_step_chunk: u32,
}

impl GpuSim {
    pub fn new(role: Role) -> Self {
        GpuSim {
            role,
            draining_to: None,
            epoch: 0,
            busy: false,
            failed: false,
            // Pre-sized so steady-state traffic never grows them (the
            // alloc-count test asserts zero allocations across 1k events).
            pf_queue: VecDeque::with_capacity(32),
            pf_queued_tokens: 0,
            pf_batch: Vec::with_capacity(16),
            publish_wait: VecDeque::with_capacity(32),
            dec_pending: VecDeque::with_capacity(32),
            dec_active: Vec::with_capacity(32),
            dec_step_time: 0,
            co_queue: VecDeque::with_capacity(32),
            co_tokens: 0,
            co_finishing: Vec::with_capacity(16),
            co_step_chunk: 0,
        }
    }

    /// The role this GPU is committed to (target role while draining).
    pub fn committed_role(&self) -> Role {
        self.draining_to.unwrap_or(self.role)
    }

    /// May the router send new work here?
    pub fn accepting(&self) -> bool {
        self.draining_to.is_none() && !self.failed
    }

    pub fn push_prefill(&mut self, slot: SlotId, input_tokens: u32) {
        self.pf_queued_tokens += input_tokens as u64;
        self.pf_queue.push_back(slot);
    }

    pub fn pop_prefill_tokens(&mut self, tokens: u64) {
        self.pf_queued_tokens -= tokens;
    }

    /// Decode occupancy: resident + pending requests.
    pub fn decode_load(&self) -> usize {
        self.dec_active.len() + self.dec_pending.len()
    }

    /// Mean live context across active decode requests.
    pub fn mean_ctx(&self, store: &RequestStore) -> f64 {
        if self.dec_active.is_empty() {
            return 0.0;
        }
        self.dec_active
            .iter()
            .map(|&s| store.get(s).ctx_tokens() as f64)
            .sum::<f64>()
            / self.dec_active.len() as f64
    }

    /// Queued coalesced prompt tokens remaining (O(1) counter).
    pub fn co_queued_tokens(&self) -> u64 {
        self.co_tokens
    }

    /// Has this GPU fully drained (safe to flip roles)?
    pub fn drained(&self) -> bool {
        !self.busy
            && self.pf_queue.is_empty()
            && self.pf_batch.is_empty()
            && self.publish_wait.is_empty()
            && self.dec_pending.is_empty()
            && self.dec_active.is_empty()
            && self.co_queue.is_empty()
            && self.co_finishing.is_empty()
    }

    /// Utilization estimate for the power-draw model.
    pub fn util(&self) -> f64 {
        if !self.busy {
            return 0.0;
        }
        match self.role {
            Role::Prefill | Role::Coalesced => 1.0,
            Role::Decode => {
                // Memory-bound: utilization grows with batch occupancy.
                0.35 + 0.65 * (self.dec_active.len() as f64 / 24.0).min(1.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::store::ReqState;
    use crate::types::{Request, RequestId, Slo};

    fn req(id: u64, input: u32) -> Request {
        Request {
            id: RequestId(id),
            arrival: 0,
            input_tokens: input,
            output_tokens: 8,
            slo: Slo::paper_default(),
            tenant: 0,
        }
    }

    fn slot(store: &mut RequestStore, id: u64, input: u32, tokens_done: u32) -> SlotId {
        let mut st = ReqState::new(req(id, input));
        st.tokens_done = tokens_done;
        store.insert(st)
    }

    #[test]
    fn prefill_token_accounting() {
        let mut store = RequestStore::new();
        let mut g = GpuSim::new(Role::Prefill);
        let a = slot(&mut store, 0, 1000, 0);
        let b = slot(&mut store, 1, 500, 0);
        g.push_prefill(a, store.get(a).req.input_tokens);
        g.push_prefill(b, store.get(b).req.input_tokens);
        assert_eq!(g.pf_queued_tokens, 1500);
        g.pop_prefill_tokens(1000);
        assert_eq!(g.pf_queued_tokens, 500);
    }

    #[test]
    fn committed_role_reflects_drain_target() {
        let mut g = GpuSim::new(Role::Decode);
        assert_eq!(g.committed_role(), Role::Decode);
        assert!(g.accepting());
        g.draining_to = Some(Role::Prefill);
        assert_eq!(g.committed_role(), Role::Prefill);
        assert!(!g.accepting());
    }

    #[test]
    fn drained_requires_everything_empty() {
        let mut store = RequestStore::new();
        let mut g = GpuSim::new(Role::Decode);
        assert!(g.drained());
        g.dec_active.push(slot(&mut store, 0, 100, 1));
        assert!(!g.drained());
        g.dec_active.clear();
        g.busy = true;
        assert!(!g.drained());
    }

    #[test]
    fn util_by_role() {
        let mut store = RequestStore::new();
        let mut g = GpuSim::new(Role::Prefill);
        assert_eq!(g.util(), 0.0);
        g.busy = true;
        assert_eq!(g.util(), 1.0);
        let mut d = GpuSim::new(Role::Decode);
        d.busy = true;
        let low = d.util();
        for i in 0..24 {
            d.dec_active.push(slot(&mut store, i, 100, 1));
        }
        assert!(d.util() > low);
        assert!(d.util() <= 1.0);
    }

    #[test]
    fn mean_ctx_over_active() {
        let mut store = RequestStore::new();
        let mut g = GpuSim::new(Role::Decode);
        assert_eq!(g.mean_ctx(&store), 0.0);
        for (i, inp) in [(0u64, 100u32), (1, 300)] {
            g.dec_active.push(slot(&mut store, i, inp, 10));
        }
        assert!((g.mean_ctx(&store) - 210.0).abs() < 1e-9); // (110 + 310) / 2
    }

    #[test]
    fn co_tokens_counter_is_o1() {
        let mut store = RequestStore::new();
        let mut g = GpuSim::new(Role::Coalesced);
        let a = slot(&mut store, 0, 4000, 0);
        g.co_queue.push_back(a);
        g.co_tokens += 4000;
        assert_eq!(g.co_queued_tokens(), 4000);
        // A chunk advances 2048 tokens: the counter mirrors the store.
        let adv = store.get_mut(a).chunk_advance(2048);
        g.co_tokens -= adv as u64;
        assert_eq!(g.co_queued_tokens(), store.get(a).chunk_remaining() as u64);
    }
}
