//! Golden tests for the declarative Scenario/Study API: a two-axis
//! (rate × budget) Study must reproduce the equivalent hand-rolled
//! loop bit-for-bit, at 1 thread and at N threads; the emitters must
//! agree with each other; and the shipped scenario files must load and
//! run.

use rapid::config::presets;
use rapid::scenario::{emit, longbench_trace, Axis, Scenario, Study};
use rapid::sim::{self, SimOptions};
use rapid::types::Slo;
use rapid::util::json::Json;

const SEED: u64 = 11;
const REQUESTS: usize = 80;
const RATES: &[f64] = &[0.75, 1.5];
const BUDGETS: &[f64] = &[500.0, 600.0];

fn golden_scenario() -> Scenario {
    Scenario::new("golden", presets::p4d4(600.0))
        .seed(SEED)
        .requests(REQUESTS)
        .axis(Axis::PowerW(BUDGETS.to_vec()))
        .axis(Axis::RatePerGpu(RATES.to_vec()))
}

/// The loop the Study replaces: `presets::p4d4(w)` per budget, a
/// LongBench trace per (budget, rate), one sim per cell.
fn hand_rolled() -> Vec<(f64, f64, f64)> {
    let mut out = Vec::new();
    for &w in BUDGETS {
        let cfg = presets::p4d4(w);
        for &r in RATES {
            let trace = longbench_trace(
                SEED,
                r * cfg.total_gpus() as f64,
                REQUESTS,
                Slo::paper_default(),
            );
            let res = sim::run(&cfg, &trace, &SimOptions::default());
            out.push((res.attainment(), res.goodput_qps(), res.qps_per_kw()));
        }
    }
    out
}

#[test]
fn two_axis_study_matches_hand_rolled_loop_bit_identical() {
    let expected = hand_rolled();
    let serial = Study::new(golden_scenario()).run(Some(1)).unwrap();
    let fanned = Study::new(golden_scenario()).run(Some(4)).unwrap();
    for (label, study) in [("1 thread", &serial), ("4 threads", &fanned)] {
        assert_eq!(study.cells.len(), expected.len(), "{label}");
        for (cell, &(att, goodput, qpkw)) in study.cells.iter().zip(&expected) {
            // Bitwise equality: the Study must not perturb a single ulp.
            assert_eq!(cell.attainment(), att, "{label} {:?}", cell.coords);
            assert_eq!(cell.goodput_qps(), goodput, "{label} {:?}", cell.coords);
            assert_eq!(cell.qps_per_kw(), qpkw, "{label} {:?}", cell.coords);
        }
    }
    // And the two runs agree with each other cell-by-cell.
    for (a, b) in serial.cells.iter().zip(&fanned.cells) {
        assert_eq!(a.attainment(), b.attainment());
        assert_eq!(a.goodput_qps(), b.goodput_qps());
    }
}

#[test]
fn emitters_agree_on_attainment_and_goodput() {
    let study = Study::new(golden_scenario()).run(Some(2)).unwrap();

    // JSON parses with the crate's own parser and carries the exact
    // cell values.
    let json_text = emit::emit(&study, emit::Format::Json);
    let v = Json::parse(json_text.trim()).unwrap();
    let cells = v.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), study.cells.len());
    for (jc, cell) in cells.iter().zip(&study.cells) {
        let m = jc.get("metrics").unwrap();
        assert_eq!(
            m.get("attainment").unwrap().as_f64(),
            Some(cell.attainment())
        );
        assert_eq!(
            m.get("goodput_qps").unwrap().as_f64(),
            Some(cell.goodput_qps())
        );
    }

    // CSV: header + one row per cell, same values.
    let csv = emit::emit(&study, emit::Format::Csv);
    let lines: Vec<&str> = csv.trim_end().lines().collect();
    assert_eq!(lines.len(), 1 + study.cells.len());
    for (line, cell) in lines[1..].iter().zip(&study.cells) {
        let fields: Vec<&str> = line.split(',').collect();
        // power_w, rate_per_gpu, config, attainment, goodput, ...
        assert_eq!(fields[3].parse::<f64>().unwrap(), cell.attainment());
        assert_eq!(fields[4].parse::<f64>().unwrap(), cell.goodput_qps());
    }

    // Text: shows every cell's attainment at the emitters' rounding.
    let text = emit::emit(&study, emit::Format::Text);
    for cell in &study.cells {
        assert!(text.contains(&format!("{:.4}", cell.attainment())));
    }
}

#[test]
fn shipped_scenarios_load_and_run() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios");
    let mut count = 0;
    for entry in std::fs::read_dir(dir).expect("scenarios/ present") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let mut s = Scenario::from_toml_file(path.to_str().unwrap())
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(s.n_cells() >= 1);
        // Shrink for test speed; the grid shape is what we exercise.
        s.requests = 30;
        let study = Study::new(s).run(Some(2)).unwrap();
        assert_eq!(study.cells.len(), study.scenario.n_cells());
        let json = emit::emit(&study, emit::Format::Json);
        Json::parse(json.trim()).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        count += 1;
    }
    assert!(count >= 2, "expected the shipped scenario files");
}

#[test]
fn study_cell_checks_pass_on_shipped_grid() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/rate-budget-grid.toml");
    let mut s = Scenario::from_toml_file(path).unwrap();
    s.requests = 40;
    let study = Study::new(s).run(None).unwrap();
    let (passed, total) = study.checks_passed();
    assert_eq!(passed, total, "per-cell invariant checks must pass");
    // Budget axis really reparametrizes the config per cell.
    assert_eq!(study.cells[0].config.node_budget_w, 4000.0);
    assert_eq!(study.cells.last().unwrap().config.node_budget_w, 6000.0);
}
