//! Fig 7: SLO-scale sweep. SLOs scaled uniformly from 2.0x (relaxed,
//! TTFT = 2 s / TPOT = 80 ms) down to 0.5x (strict, 0.5 s / 20 ms) at
//! QPS/GPU in {1.25, 1.375, 1.5}. The non-uniform power configuration
//! should match the 6000 W 4P4D-750W until the SLOs get very tight, and
//! beat the same-budget uniform configs throughout.

use crate::config::{presets, ClusterConfig};
use crate::experiments::ShapeCheck;
use crate::scenario::{Axis, Scenario, Study};

pub const SCALES: &[f64] = &[2.0, 1.5, 1.25, 1.0, 0.75, 0.5];
pub const RATES: &[f64] = &[1.25, 1.375, 1.5];

pub struct Fig7 {
    /// [rate][config] -> attainment per scale.
    pub grids: Vec<Vec<(ClusterConfig, Vec<f64>)>>,
}

fn configs() -> Vec<ClusterConfig> {
    vec![
        presets::p4d4(750.0),
        presets::p4d4(600.0),
        presets::p5d3_600(),
        presets::p4_750_d4_450(),
    ]
}

/// Three axes — rate × config × SLO scale — one flat grid fanned
/// across cores (no barrier between curves).
pub fn scenario(seed: u64, n: usize) -> Scenario {
    Scenario::new("fig7", presets::p4d4(600.0))
        .seed(seed)
        .requests(n)
        .axis(Axis::RatePerGpu(RATES.to_vec()))
        .axis(Axis::Config(configs()))
        .axis(Axis::SloScale(SCALES.to_vec()))
}

pub fn run(seed: u64, n: usize) -> Fig7 {
    let study = Study::new(scenario(seed, n)).run(None).expect("fig7 scenario");
    let cfgs = configs();
    let mut it = study.cells.iter().map(crate::scenario::Cell::attainment);
    let grids = RATES
        .iter()
        .map(|_| {
            cfgs.iter()
                .map(|cfg| {
                    let row: Vec<f64> = SCALES.iter().map(|_| it.next().unwrap()).collect();
                    (cfg.clone(), row)
                })
                .collect()
        })
        .collect();
    Fig7 { grids }
}

impl Fig7 {
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (ri, rate) in RATES.iter().enumerate() {
            out.push_str(&format!("\nQPS/GPU = {rate}\n{:<18}", "SLO scale"));
            for s in SCALES {
                out.push_str(&format!("{s:>7.2}"));
            }
            out.push('\n');
            for (cfg, atts) in &self.grids[ri] {
                out.push_str(&format!("{:<18}", cfg.name));
                for a in atts {
                    out.push_str(&format!("{:>7.1}", a * 100.0));
                }
                out.push('\n');
            }
        }
        out
    }

    fn curve<'a>(&'a self, rate_idx: usize, name: &str) -> &'a [f64] {
        &self.grids[rate_idx]
            .iter()
            .find(|(c, _)| c.name == name)
            .expect("config present")
            .1
    }

    pub fn checks(&self) -> Vec<ShapeCheck> {
        let mut checks = Vec::new();
        for (ri, rate) in RATES.iter().enumerate() {
            let nonuni = self.curve(ri, "4P-750W/4D-450W");
            let uni600 = self.curve(ri, "4P4D-600W");
            let full750 = self.curve(ri, "4P4D-750W");
            // Non-uniform beats uniform-600 at every relaxed-to-baseline scale.
            let dominates = SCALES
                .iter()
                .zip(nonuni.iter().zip(uni600))
                .filter(|(s, _)| **s >= 1.0)
                .all(|(_, (a, b))| a >= &(b - 0.03));
            checks.push(ShapeCheck::new(
                format!("@{rate} QPS/GPU: non-uniform >= uniform 600 W for scales >= 1"),
                dominates,
                format!("nonuni={nonuni:.2?} uni={uni600:.2?}"),
            ));
            // Matches the 6000 W config until the SLOs get very strict.
            let relaxed_match = SCALES
                .iter()
                .zip(nonuni.iter().zip(full750))
                .filter(|(s, _)| **s >= 1.25)
                .all(|(_, (a, b))| a >= &(b - 0.05));
            checks.push(ShapeCheck::new(
                format!("@{rate} QPS/GPU: matches 4P4D-750W while SLOs relaxed"),
                relaxed_match,
                format!("nonuni={nonuni:.2?} 750={full750:.2?}"),
            ));
        }
        // Attainment must degrade monotonically-ish as SLOs tighten.
        let nonuni = self.curve(0, "4P-750W/4D-450W");
        let monotone = nonuni.windows(2).all(|w| w[1] <= w[0] + 0.05);
        checks.push(ShapeCheck::new(
            "attainment degrades as SLOs tighten",
            monotone,
            format!("{nonuni:.2?}"),
        ));
        checks
    }
}
