//! Fig 4: (a) prefill P90 TTFT and (b) decode P90 TPOT vs per-GPU power
//! cap (400–750 W in 50 W steps) across batch sizes, for the paper's
//! microbenchmark shape (4096 input / 128 output tokens); (c) the power-
//! cap step-response transient (a 47% cap cut takes hundreds of ms).
//!
//! Values are normalized to the 400 W configuration like the paper
//! ("performance results are relative to the P90 latencies of the 400 W
//! configuration"), so (a) plots the speedup curves the scheduler
//! exploits: prefill keeps gaining to ~700 W, decode flattens at ~600 W.
//!
//! Parts (a)/(b) are batch × power microbench grids declared through
//! the Scenario/Study API (analytic power-model cells, no simulation);
//! part (c) is a single cap-ramp transient, not a sweep.

use crate::config::presets;
use crate::experiments::ShapeCheck;
use crate::power::capper::{CapState, RampProfile};
use crate::scenario::{Axis, Scenario, Study, StudyResult, WorkloadSpec};
use crate::types::{Micros, MILLIS};

pub const POWERS: &[f64] = &[400.0, 450.0, 500.0, 550.0, 600.0, 650.0, 700.0, 750.0];
pub const PREFILL_BATCHES: &[usize] = &[1, 2, 4, 8];
pub const DECODE_BATCHES: &[usize] = &[8, 16, 32, 64];
const INPUT_TOKENS: u32 = 4096;

pub struct Fig4 {
    /// [batch][power] relative prefill speedup vs 400 W.
    pub prefill_speedup: Vec<Vec<f64>>,
    /// [batch][power] relative decode speedup vs 400 W.
    pub decode_speedup: Vec<Vec<f64>>,
    /// (t, effective cap) samples of the 750 W -> 400 W step (Fig 4c).
    pub step_response: Vec<(Micros, f64)>,
    /// When the cap settled within 1 W.
    pub settle_time: Micros,
}

/// Fig 4(a): prefill latency over the batch × power grid.
pub fn scenario_prefill() -> Scenario {
    Scenario::new("fig4a", presets::p4d4(600.0))
        .workload(WorkloadSpec::PrefillMicrobench {
            input_tokens: INPUT_TOKENS,
        })
        .axis(Axis::Batch(PREFILL_BATCHES.to_vec()))
        .axis(Axis::PowerW(POWERS.to_vec()))
}

/// Fig 4(b): decode step latency over the batch × power grid.
pub fn scenario_decode() -> Scenario {
    Scenario::new("fig4b", presets::p4d4(600.0))
        .workload(WorkloadSpec::DecodeMicrobench {
            context_tokens: INPUT_TOKENS as f64,
        })
        .axis(Axis::Batch(DECODE_BATCHES.to_vec()))
        .axis(Axis::PowerW(POWERS.to_vec()))
}

/// [batch][power] speedups vs the 400 W column (POWERS[0]).
fn speedups(study: &StudyResult) -> Vec<Vec<f64>> {
    study
        .cells
        .chunks(POWERS.len())
        .map(|row| {
            let t400 = row[0].value();
            row.iter().map(|c| t400 / c.value()).collect()
        })
        .collect()
}

pub fn run() -> Fig4 {
    let prefill = Study::new(scenario_prefill()).run(None).expect("fig4a");
    let decode = Study::new(scenario_decode()).run(None).expect("fig4b");
    // Fig 4c: 47% cut (750 -> ~400 W).
    let mut cap = CapState::new(750.0);
    let profile = RampProfile::default();
    let deadline = cap.set_target(0, 400.0, &profile);
    let mut step_response = Vec::new();
    let mut settle_time = deadline;
    let horizon = deadline * 2;
    let mut t = 0;
    while t <= horizon {
        let eff = cap.effective(t);
        step_response.push((t, eff));
        if (eff - 400.0).abs() < 1.0 && settle_time == deadline {
            settle_time = t;
        }
        t += MILLIS;
    }
    Fig4 {
        prefill_speedup: speedups(&prefill),
        decode_speedup: speedups(&decode),
        step_response,
        settle_time,
    }
}

impl Fig4 {
    pub fn render(&self) -> String {
        let mut out = String::from("(a) Prefill speedup vs 400 W (P90 TTFT ratio)\n");
        out.push_str(&format!("{:<10}", "batch"));
        for w in POWERS {
            out.push_str(&format!("{:>7.0}", w));
        }
        out.push('\n');
        for (bi, b) in PREFILL_BATCHES.iter().enumerate() {
            out.push_str(&format!("{:<10}", b));
            for v in &self.prefill_speedup[bi] {
                out.push_str(&format!("{v:>7.2}"));
            }
            out.push('\n');
        }
        out.push_str("\n(b) Decode speedup vs 400 W (P90 TPOT ratio)\n");
        out.push_str(&format!("{:<10}", "batch"));
        for w in POWERS {
            out.push_str(&format!("{:>7.0}", w));
        }
        out.push('\n');
        for (bi, b) in DECODE_BATCHES.iter().enumerate() {
            out.push_str(&format!("{:<10}", b));
            for v in &self.decode_speedup[bi] {
                out.push_str(&format!("{v:>7.2}"));
            }
            out.push('\n');
        }
        out.push_str(&format!(
            "\n(c) 750->400 W cap step: settles in {} ms\n",
            self.settle_time / MILLIS
        ));
        out
    }

    pub fn checks(&self) -> Vec<ShapeCheck> {
        let p_max = self.prefill_speedup[0].last().copied().unwrap_or(0.0);
        let d_max = self.decode_speedup[0].last().copied().unwrap_or(0.0);
        let d600 = self.decode_speedup[0][4]; // 600 W column
        let p700 = self.prefill_speedup[0][6];
        vec![
            ShapeCheck::new(
                "prefill gains ~1.8x from 400->750 W (paper: up to 1.8x)",
                (1.6..=2.0).contains(&p_max),
                format!("{p_max:.2}x"),
            ),
            ShapeCheck::new(
                "decode flattens at 1.3-1.5x (paper: 1.3x-1.5x)",
                (1.3..=1.5).contains(&d_max),
                format!("{d_max:.2}x"),
            ),
            ShapeCheck::new(
                "decode gains above 600 W are ~zero",
                (d_max - d600).abs() < 0.02,
                format!("600W={d600:.2} 750W={d_max:.2}"),
            ),
            ShapeCheck::new(
                "prefill still gaining between 600 and 700 W",
                p700 > self.prefill_speedup[0][4] + 0.02,
                format!("600W={:.2} 700W={p700:.2}", self.prefill_speedup[0][4]),
            ),
            ShapeCheck::new(
                "cap step settles in hundreds of ms (Fig 4c)",
                (100 * MILLIS..800 * MILLIS).contains(&self.settle_time),
                format!("{} ms", self.settle_time / MILLIS),
            ),
            ShapeCheck::new(
                "transient is monotone (no overshoot below target)",
                self.step_response.windows(2).all(|w| w[1].1 <= w[0].1 + 1e-9)
                    && self.step_response.iter().all(|&(_, v)| v >= 400.0 - 1e-9),
                "monotone decreasing to 400 W".to_string(),
            ),
        ]
    }
}
