//! Chrome-trace-event JSON export (Perfetto-loadable).
//!
//! Layout: pid 0 is the cluster (counter tracks for budget vs committed
//! power, role split and KV occupancy, plus decision instants), pid
//! `1 + node` carries one thread per GPU (role-colored busy slices from
//! [`ObsEvent::GpuStep`], role-flip instants, per-GPU cap counters),
//! and pid [`REQUESTS_PID`] carries one thread per request with its
//! lifecycle as stage slices (prefill → kv → decode-wait → decode,
//! preemption segments included).
//!
//! Timestamps are sim microseconds, which is exactly the trace format's
//! `ts` unit. Output is fully deterministic: events are collected with
//! an insertion sequence and stable-sorted by (pid, tid, ts, seq), so
//! every track is monotonic in time (the CI validator asserts this) and
//! two runs of the same seed export byte-identical files.

use std::collections::BTreeMap;

use crate::metrics::RunResult;
use crate::obs::{ObsEvent, ObsReport};
use crate::types::{Micros, Role};
use crate::util::json::Json;

/// The synthetic process that holds one track per request.
pub const REQUESTS_PID: u64 = 10_000;

/// Reserved-color names Perfetto maps to stable palette entries.
fn role_color(role: Role) -> &'static str {
    match role {
        Role::Prefill => "thread_state_running",
        Role::Decode => "thread_state_runnable",
        Role::Coalesced => "thread_state_iowait",
    }
}

struct Out {
    /// (pid, tid, ts, insertion seq, event) — the sort key that makes
    /// every track monotonic while keeping ties deterministic.
    events: Vec<(u64, u64, Micros, usize, Json)>,
    meta: Vec<Json>,
}

impl Out {
    fn push(&mut self, pid: u64, tid: u64, ts: Micros, ev: Json) {
        let seq = self.events.len();
        self.events.push((pid, tid, ts, seq, ev));
    }

    fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

fn base(name: &str, ph: &str, ts: Micros, pid: u64, tid: u64) -> Vec<(&'static str, Json)> {
    // Field names are inserted into a BTreeMap, so declaration order
    // here is cosmetic; the wire order is alphabetical.
    let mut v: Vec<(&'static str, Json)> = Vec::with_capacity(8);
    v.push(("name", Json::Str(name.to_string())));
    v.push(("ph", Json::Str(ph.to_string())));
    v.push(("ts", Json::Num(ts as f64)));
    v.push(("pid", Json::Num(pid as f64)));
    v.push(("tid", Json::Num(tid as f64)));
    v
}

fn args(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn metadata(kind: &str, pid: u64, value: Json) -> Json {
    let key = if kind == "process_sort_index" { "sort_index" } else { "name" };
    Out::obj(vec![
        ("name", Json::Str(kind.to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("args", args(vec![(key, value)])),
    ])
}

fn thread_meta(pid: u64, tid: u64, name: String) -> Json {
    Out::obj(vec![
        ("name", Json::Str("thread_name".to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", args(vec![("name", Json::Str(name))])),
    ])
}

fn counter(out: &mut Out, ts: Micros, name: &str, pairs: Vec<(&str, Json)>) {
    let mut f = base(name, "C", ts, 0, 0);
    f.push(("args", args(pairs)));
    out.push(0, 0, ts, Out::obj(f));
}

fn instant(out: &mut Out, ts: Micros, pid: u64, tid: u64, name: String, a: Vec<(&str, Json)>) {
    let mut f = base(&name, "i", ts, pid, tid);
    f.push(("s", Json::Str("t".to_string())));
    if !a.is_empty() {
        f.push(("args", args(a)));
    }
    out.push(pid, tid, ts, Out::obj(f));
}

fn slice(
    out: &mut Out,
    pid: u64,
    tid: u64,
    start: Micros,
    end: Micros,
    name: &str,
    cname: Option<&'static str>,
    a: Vec<(&str, Json)>,
) {
    let mut f = base(name, "X", start, pid, tid);
    f.push(("dur", Json::Num(end.saturating_sub(start) as f64)));
    if let Some(c) = cname {
        f.push(("cname", Json::Str(c.to_string())));
    }
    if !a.is_empty() {
        f.push(("args", args(a)));
    }
    out.push(pid, tid, start, Out::obj(f));
}

/// Export a traced run as Chrome-trace-event JSON. Requires
/// `result.obs` (a run executed with recording enabled); runs without
/// a report export only the counter tracks derived from the metric
/// series.
pub fn chrome_trace(result: &RunResult) -> String {
    let empty;
    let obs: &ObsReport = match result.obs.as_deref() {
        Some(o) => o,
        None => {
            empty = ObsReport::default();
            &empty
        }
    };
    let node_pid = |gpu: usize| -> u64 { 1 + obs.node_of.get(gpu).copied().unwrap_or(0) as u64 };

    let mut out = Out { events: Vec::new(), meta: Vec::new() };

    // --- process/thread metadata -------------------------------------
    out.meta.push(metadata("process_name", 0, Json::Str("cluster".to_string())));
    out.meta.push(metadata("process_sort_index", 0, Json::Num(0.0)));
    let n_nodes = obs.node_of.iter().map(|n| *n as u64 + 1).max().unwrap_or(0);
    for n in 0..n_nodes {
        out.meta.push(metadata("process_name", 1 + n, Json::Str(format!("node {n}"))));
        out.meta.push(metadata("process_sort_index", 1 + n, Json::Num((1 + n) as f64)));
    }
    for (g, n) in obs.node_of.iter().enumerate() {
        out.meta.push(thread_meta(1 + *n as u64, g as u64, format!("gpu{g}")));
    }
    out.meta.push(metadata("process_name", REQUESTS_PID, Json::Str("requests".to_string())));
    out.meta
        .push(metadata("process_sort_index", REQUESTS_PID, Json::Num(REQUESTS_PID as f64)));

    // --- counter tracks from the metric series -----------------------
    for (t, caps) in &result.cap_trace {
        let committed: f64 = caps.iter().sum();
        counter(&mut out, *t, "cluster power (W)", vec![("committed", Json::Num(committed))]);
    }
    for (t, w) in &result.budget_trace {
        counter(&mut out, *t, "cluster budget (W)", vec![("budget", Json::Num(*w))]);
    }
    for (t, p, d) in &result.role_trace {
        counter(
            &mut out,
            *t,
            "roles",
            vec![("decode", Json::Num(*d as f64)), ("prefill", Json::Num(*p as f64))],
        );
    }
    for (t, occ) in &result.mem_trace {
        counter(&mut out, *t, "kv occupancy (max frac)", vec![("occ", Json::Num(*occ))]);
    }

    // --- the recorded event log --------------------------------------
    // Open request-stage slices: req -> (start, stage name).
    let mut open: BTreeMap<u64, (Micros, &'static str)> = BTreeMap::new();
    let mut close = |out: &mut Out, open: &mut BTreeMap<u64, (Micros, &'static str)>,
                     req: u64,
                     at: Micros| {
        if let Some((start, stage)) = open.remove(&req) {
            slice(out, REQUESTS_PID, req, start, at, stage, None, vec![]);
        }
    };

    for ev in &obs.events {
        match *ev {
            ObsEvent::Arrival { at, req, tenant, input, output } => {
                instant(
                    &mut out,
                    at,
                    REQUESTS_PID,
                    req,
                    "arrival".to_string(),
                    vec![
                        ("input", Json::Num(input as f64)),
                        ("output", Json::Num(output as f64)),
                        ("tenant", Json::Num(tenant as f64)),
                    ],
                );
            }
            ObsEvent::Shed { at, req, tenant, in_system } => {
                instant(
                    &mut out,
                    at,
                    REQUESTS_PID,
                    req,
                    "shed".to_string(),
                    vec![
                        ("in_system", Json::Num(in_system as f64)),
                        ("tenant", Json::Num(tenant as f64)),
                    ],
                );
            }
            ObsEvent::PrefillQueued { at, req, gpu } => {
                close(&mut out, &mut open, req, at);
                open.insert(req, (at, "prefill"));
                instant(
                    &mut out,
                    at,
                    REQUESTS_PID,
                    req,
                    format!("queued gpu{gpu}"),
                    vec![],
                );
            }
            ObsEvent::GpuStep { at, gpu, node, until, role, reqs, tokens } => {
                slice(
                    &mut out,
                    1 + node as u64,
                    gpu as u64,
                    at,
                    until,
                    &role.to_string(),
                    Some(role_color(role)),
                    vec![
                        ("reqs", Json::Num(reqs as f64)),
                        ("tokens", Json::Num(tokens as f64)),
                    ],
                );
            }
            ObsEvent::FirstToken { at, req, gpu: _ } => {
                close(&mut out, &mut open, req, at);
            }
            ObsEvent::KvSend { at, req, src, dst, arrive_at: _ } => {
                close(&mut out, &mut open, req, at);
                open.insert(req, (at, "kv"));
                instant(
                    &mut out,
                    at,
                    REQUESTS_PID,
                    req,
                    format!("kv gpu{src}->gpu{dst}"),
                    vec![],
                );
            }
            ObsEvent::KvArrive { at, req, gpu: _ } => {
                close(&mut out, &mut open, req, at);
                open.insert(req, (at, "decode-wait"));
            }
            ObsEvent::DecodeAdmit { at, req, gpu: _ } => {
                close(&mut out, &mut open, req, at);
                open.insert(req, (at, "decode"));
            }
            ObsEvent::Preempt { at, victim, by, gpu, victim_tier, by_tier } => {
                close(&mut out, &mut open, victim, at);
                open.insert(victim, (at, "preempted"));
                instant(
                    &mut out,
                    at,
                    REQUESTS_PID,
                    victim,
                    format!("preempted by r{by} on gpu{gpu}"),
                    vec![
                        ("by_tier", Json::Num(by_tier as f64)),
                        ("victim_tier", Json::Num(victim_tier as f64)),
                    ],
                );
            }
            ObsEvent::Requeue { at, req, gpu, why } => {
                close(&mut out, &mut open, req, at);
                open.insert(req, (at, "requeued"));
                instant(
                    &mut out,
                    at,
                    REQUESTS_PID,
                    req,
                    format!("requeue ({why}) gpu{gpu}"),
                    vec![],
                );
            }
            ObsEvent::Finish { at, req, gpu: _, tokens } => {
                close(&mut out, &mut open, req, at);
                instant(
                    &mut out,
                    at,
                    REQUESTS_PID,
                    req,
                    "finish".to_string(),
                    vec![("tokens", Json::Num(tokens as f64))],
                );
            }
            ObsEvent::PowerMove { at, from, to, watts, ok, budget, committed_before, committed_after } => {
                instant(
                    &mut out,
                    at,
                    0,
                    0,
                    format!("MovePower {from}->{to} {watts:.0}W{}", if ok { "" } else { " (failed)" }),
                    vec![
                        ("budget", Json::Num(budget)),
                        ("committed_after", Json::Num(committed_after)),
                        ("committed_before", Json::Num(committed_before)),
                    ],
                );
            }
            ObsEvent::GpuMove { at, gpu, from, to } => {
                instant(
                    &mut out,
                    at,
                    node_pid(gpu),
                    gpu as u64,
                    format!("drain {from}->{to}"),
                    vec![],
                );
            }
            ObsEvent::RoleFlip { at, gpu, role } => {
                instant(
                    &mut out,
                    at,
                    node_pid(gpu),
                    gpu as u64,
                    format!("role={role}"),
                    vec![],
                );
            }
            ObsEvent::CapApplied { at, gpu, watts } => {
                let mut f = base(&format!("cap gpu{gpu} (W)"), "C", at, node_pid(gpu), 0);
                f.push(("args", args(vec![("cap", Json::Num(watts))])));
                let pid = node_pid(gpu);
                out.push(pid, 0, at, Out::obj(f));
            }
            ObsEvent::BudgetChange { at, node, watts, committed } => {
                let scope = if node < 0 { "cluster".to_string() } else { format!("node {node}") };
                instant(
                    &mut out,
                    at,
                    0,
                    0,
                    format!("budget {scope} -> {watts:.0}W"),
                    vec![("committed", Json::Num(committed))],
                );
            }
            ObsEvent::EnvApplied { at, kind, gpu } => {
                let tgt = if gpu < 0 { String::new() } else { format!(" gpu{gpu}") };
                instant(&mut out, at, 0, 0, format!("env:{kind}{tgt}"), vec![]);
            }
            ObsEvent::PrefixHit { at, req, tokens } => {
                instant(
                    &mut out,
                    at,
                    REQUESTS_PID,
                    req,
                    "prefix hit".to_string(),
                    vec![("tokens", Json::Num(tokens as f64))],
                );
            }
            ObsEvent::MemEvict { at, gpu, bytes } => {
                instant(
                    &mut out,
                    at,
                    node_pid(gpu),
                    gpu as u64,
                    "kv evict".to_string(),
                    vec![("bytes", Json::Num(bytes as f64))],
                );
            }
        }
    }
    // Close anything still open at the end of the run.
    let tail: Vec<u64> = open.keys().copied().collect();
    for req in tail {
        close(&mut out, &mut open, req, result.duration);
    }

    // Stable sort by (pid, tid, ts, seq): per-track monotonic, ties in
    // original record order — fully deterministic.
    out.events.sort_by(|a, b| (a.0, a.1, a.2, a.3).cmp(&(b.0, b.1, b.2, b.3)));

    let mut all: Vec<Json> = out.meta;
    all.extend(out.events.into_iter().map(|(_, _, _, _, e)| e));
    let mut top = BTreeMap::new();
    top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    top.insert("traceEvents".to_string(), Json::Arr(all));
    Json::Obj(top).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ObsCounters;

    fn traced_result() -> RunResult {
        let mut r = RunResult::default();
        r.duration = 2_000_000;
        r.cap_trace = vec![(0, vec![400.0, 500.0]), (1_000_000, vec![450.0, 450.0])];
        let report = ObsReport {
            counters: ObsCounters::default(),
            events: vec![
                ObsEvent::Arrival { at: 10, req: 1, tenant: 0, input: 100, output: 8 },
                ObsEvent::PrefillQueued { at: 10, req: 1, gpu: 0 },
                ObsEvent::GpuStep {
                    at: 20,
                    gpu: 0,
                    node: 0,
                    until: 120,
                    role: Role::Prefill,
                    reqs: 1,
                    tokens: 100,
                },
                ObsEvent::FirstToken { at: 120, req: 1, gpu: 0 },
                ObsEvent::KvSend { at: 120, req: 1, src: 0, dst: 1, arrive_at: 140 },
                ObsEvent::KvArrive { at: 140, req: 1, gpu: 1 },
                ObsEvent::DecodeAdmit { at: 150, req: 1, gpu: 1 },
                ObsEvent::Finish { at: 900, req: 1, gpu: 1, tokens: 8 },
            ],
            dropped: 0,
            node_of: vec![0, 0],
        };
        r.obs = Some(Box::new(report));
        r
    }

    #[test]
    fn export_is_valid_json_with_trace_events() {
        let text = chrome_trace(&traced_result());
        let v = Json::parse(&text).expect("exporter emits valid JSON");
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(evs.len() >= 10);
        // Required keys on a slice event.
        let x = evs
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .expect("at least one duration slice");
        for key in ["name", "ts", "dur", "pid", "tid"] {
            assert!(x.get(key).is_some(), "slice missing {key}");
        }
    }

    #[test]
    fn tracks_are_time_monotonic() {
        let text = chrome_trace(&traced_result());
        let v = Json::parse(&text).unwrap();
        let mut last: BTreeMap<(u64, u64), f64> = BTreeMap::new();
        for e in v.get("traceEvents").unwrap().as_arr().unwrap() {
            if e.get("ph").and_then(|p| p.as_str()) == Some("M") {
                continue;
            }
            let key = (
                e.get("pid").unwrap().as_u64().unwrap(),
                e.get("tid").map(|t| t.as_u64().unwrap()).unwrap_or(0),
            );
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            if let Some(prev) = last.get(&key) {
                assert!(ts >= *prev, "track {key:?} went backwards: {prev} -> {ts}");
            }
            last.insert(key, ts);
        }
    }

    #[test]
    fn export_is_deterministic() {
        let r = traced_result();
        assert_eq!(chrome_trace(&r), chrome_trace(&r));
    }

    #[test]
    fn stage_slices_cover_the_lifecycle() {
        let text = chrome_trace(&traced_result());
        for stage in ["\"prefill\"", "\"kv\"", "\"decode-wait\"", "\"decode\""] {
            assert!(text.contains(stage), "missing stage {stage}");
        }
        assert!(text.contains("cluster power (W)"));
    }
}
