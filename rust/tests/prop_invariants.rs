//! Property tests on coordinator/power/simulator invariants, using the
//! in-repo property framework (`rapid::util::check`). Each property runs
//! across randomized workloads, configurations and seeds.

use rapid::config::{presets, ClusterConfig, ControlPolicy, Topology};
use rapid::power::PowerManager;
use rapid::sim::{self, SimOptions};
use rapid::types::{GpuId, Slo, MILLIS, SECOND};
use rapid::util::check::{ensure, property, CaseResult, Gen};
use rapid::workload::{build_trace, sonnet::Sonnet, ArrivalProcess, Trace};

fn random_config(g: &mut Gen) -> ClusterConfig {
    let mut cfg = match *g.choice(&[0, 1, 2, 3, 4]) {
        0 => presets::p4d4(600.0),
        1 => presets::p5d3_600(),
        2 => presets::p4_750_d4_450(),
        3 => presets::rapid_600(),
        _ => presets::dyn_gpu_600(),
    };
    // Jitter the controller knobs inside legal ranges.
    cfg.controller.queue_threshold = g.usize_range(2, 12);
    cfg.controller.cooldown = g.u64_range(500, 4000) * MILLIS;
    cfg.batch.ring_slots = g.usize_range(4, 64);
    cfg
}

fn random_trace(g: &mut Gen, n: usize) -> Trace {
    let qps = g.f64_range(2.0, 24.0);
    let input = g.u64_range(128, 6000) as u32;
    let output = g.u64_range(4, 300) as u32;
    let seed = g.u64_range(0, 1 << 32);
    let mut ap = ArrivalProcess::poisson(rapid::util::rng::Rng::new(seed), qps);
    let mut sizes = Sonnet::new(rapid::util::rng::Rng::new(seed ^ 7), input, output);
    build_trace(n, &mut ap, &mut sizes, Slo::paper_default())
}

#[test]
fn prop_every_request_gets_exactly_one_record() {
    property("request conservation", 40, |g| {
        let cfg = random_config(g);
        let trace = random_trace(g, 120);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        ensure(
            res.records.len() == trace.len(),
            format!("{} records for {} requests", res.records.len(), trace.len()),
        )?;
        let mut ids: Vec<u64> = res.records.iter().map(|r| r.id.0).collect();
        ids.sort();
        ids.dedup();
        ensure(ids.len() == trace.len(), "duplicate or missing record ids")
    });
}

#[test]
fn prop_records_causally_ordered() {
    property("causal ordering", 30, |g| {
        let cfg = random_config(g);
        let trace = random_trace(g, 100);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        for r in &res.records {
            ensure(r.arrival <= r.prefill_start, format!("{r:?}"))?;
            ensure(r.prefill_start <= r.first_token, format!("{r:?}"))?;
            ensure(r.first_token <= r.finish, format!("{r:?}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_power_draw_never_exceeds_enforced_budget() {
    property("budget safety", 30, |g| {
        let mut cfg = random_config(g);
        cfg.enforce_budget = true;
        let trace = random_trace(g, 150);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        ensure(
            res.node_power.max() <= cfg.node_budget_w + 10.0,
            format!("peak {} > budget {}", res.node_power.max(), cfg.node_budget_w),
        )
    });
}

#[test]
fn prop_roles_always_cover_both_phases() {
    property("min one GPU per phase", 25, |g| {
        let mut cfg = random_config(g);
        cfg.control = if g.bool() {
            ControlPolicy::DynPowerGpu
        } else {
            ControlPolicy::DynGpu
        };
        let trace = random_trace(g, 200);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        for &(t, p, d) in &res.role_trace {
            ensure(
                p >= 1 && d >= 1 && p + d == cfg.n_gpus,
                format!("at t={t}: {p}P {d}D of {}", cfg.n_gpus),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_caps_stay_within_limits() {
    property("cap limits", 25, |g| {
        let cfg = random_config(g);
        let trace = random_trace(g, 150);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        let (lo, hi) = (cfg.controller.min_gpu_w - 1.0, cfg.controller.max_gpu_w + 1.0);
        for (t, caps) in &res.cap_trace {
            for &c in caps {
                ensure((lo..=hi).contains(&c), format!("cap {c} at t={t}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_decision_spacing_respects_cooldown() {
    property("cooldown hysteresis", 20, |g| {
        let mut cfg = presets::rapid_600();
        cfg.controller.cooldown = g.u64_range(1000, 5000) * MILLIS;
        cfg.controller.queue_threshold = 3;
        let trace = random_trace(g, 250);
        let res = sim::run(&cfg, &trace, &SimOptions::default());
        for w in res.decisions.windows(2) {
            let gap = w[1].0 - w[0].0;
            ensure(
                gap + MILLIS >= cfg.controller.cooldown,
                format!("decisions {} us apart < cooldown {}", gap, cfg.controller.cooldown),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_goodput_monotone_in_slo_relaxation() {
    property("slo monotonicity", 15, |g| {
        let cfg = presets::p4d4(600.0);
        let base = random_trace(g, 150);
        let strict = sim::run(
            &cfg,
            &base.clone().with_slo(Slo::new(500 * MILLIS, 15 * MILLIS)),
            &SimOptions::default(),
        );
        let relaxed = sim::run(
            &cfg,
            &base.with_slo(Slo::new(4 * SECOND, 200 * MILLIS)),
            &SimOptions::default(),
        );
        ensure(
            relaxed.attainment() >= strict.attainment() - 1e-9,
            format!("{} < {}", relaxed.attainment(), strict.attainment()),
        )
    });
}

#[test]
fn prop_power_manager_never_double_spends() {
    property("manager budget", 60, |g| {
        let n = g.usize_range(2, 10);
        let budget = g.f64_range(400.0 * n as f64, 750.0 * n as f64);
        let init = (budget / n as f64).min(750.0).max(400.0);
        let mut m = PowerManager::new(&vec![init; n], budget, true, 400.0, 750.0);
        let mut now = 0u64;
        for _ in 0..30 {
            now += g.u64_range(1, 500) * MILLIS;
            m.poll(now);
            let op = g.usize_range(0, 3);
            match op {
                0 => {
                    let gpu = GpuId(g.usize_range(0, n));
                    let cap = g.f64_range(400.0, 750.0);
                    let _ = m.set_cap(now, gpu, cap);
                }
                1 => {
                    let split = g.usize_range(1, n);
                    let sources: Vec<GpuId> = (0..split).map(GpuId).collect();
                    let sinks: Vec<GpuId> = (split..n).map(GpuId).collect();
                    if !sinks.is_empty() {
                        let _ = m.move_power(now, &sources, &sinks, g.f64_range(10.0, 400.0), 750.0);
                    }
                }
                _ => {
                    m.distribute_uniform(now);
                }
            }
            ensure(m.budget_ok(), format!("budget violated after op {op} at {now}"))?;
        }
        // Let everything settle; still within budget.
        m.poll(now + 10 * SECOND);
        ensure(m.budget_ok(), "budget violated after final settle")
    });
}

#[test]
fn prop_coalesced_and_disaggregated_complete_same_workload() {
    property("topology completeness", 15, |g| {
        let trace = random_trace(g, 80);
        for topo in [Topology::Coalesced, Topology::Disaggregated { prefill: 4, decode: 4 }] {
            let mut cfg = presets::p4d4(600.0);
            if topo == Topology::Coalesced {
                cfg = presets::coalesced(600.0);
            }
            let res = sim::run(&cfg, &trace, &SimOptions::default());
            ensure(
                res.records.len() == trace.len(),
                format!("{:?} lost requests", cfg.topology),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_higher_rate_never_improves_tail_latency() {
    property("load monotonicity (p90 ttft)", 12, |g| {
        let cfg = presets::p4d4(600.0);
        let seed = g.u64_range(0, 1 << 30);
        let mk = |qps: f64| {
            let mut ap = ArrivalProcess::poisson(rapid::util::rng::Rng::new(seed), qps);
            let mut sizes = Sonnet::new(rapid::util::rng::Rng::new(seed ^ 3), 2048, 64);
            build_trace(200, &mut ap, &mut sizes, Slo::paper_default())
        };
        let low = sim::run(&cfg, &mk(4.0), &SimOptions::default());
        let high = sim::run(&cfg, &mk(30.0), &SimOptions::default());
        ensure(
            high.ttft_percentile(90.0) >= low.ttft_percentile(90.0) * 0.8,
            format!(
                "p90 ttft high={} low={}",
                high.ttft_percentile(90.0),
                low.ttft_percentile(90.0)
            ),
        )
    });
}
