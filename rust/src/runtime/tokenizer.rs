//! Byte-level toy tokenizer for the demo model (vocab 512: 256 raw bytes,
//! specials, and headroom). Deterministic and reversible — enough to feed
//! realistic prompt text through the real serving path.

/// Special token ids (above the byte range).
pub const BOS: i64 = 256;
pub const EOS: i64 = 257;
pub const PAD: i64 = 0;

/// Encode UTF-8 text as BOS + raw bytes.
pub fn encode(text: &str) -> Vec<i64> {
    let mut out = Vec::with_capacity(text.len() + 1);
    out.push(BOS);
    out.extend(text.bytes().map(|b| b as i64));
    out
}

/// Decode token ids back to text (specials skipped, lossy UTF-8).
pub fn decode(tokens: &[i64]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let text = "power-aware disaggregation";
        let toks = encode(text);
        assert_eq!(toks[0], BOS);
        assert_eq!(decode(&toks), text);
    }

    #[test]
    fn specials_skipped_on_decode() {
        assert_eq!(decode(&[BOS, b'h' as i64, b'i' as i64, EOS]), "hi");
    }

    #[test]
    fn unicode_lossy_but_safe() {
        let text = "héllo";
        let toks = encode(text);
        assert_eq!(decode(&toks), text); // utf-8 bytes survive
    }
}
