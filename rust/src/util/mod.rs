//! Shared substrates: PRNG, statistics, JSON, property testing.

pub mod check;
pub mod json;
pub mod rng;
pub mod stats;
