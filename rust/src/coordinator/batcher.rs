//! Local per-GPU batching (paper §3.2: "Each worker process has a local
//! scheduler that batches requests based on the GPU's memory capacity").
//!
//! * Prefill: FIFO batch formation under a token budget and a request cap
//!   (vLLM-style: never reorder, fill until a limit trips).
//! * Decode: continuous batching — admissions happen at step boundaries
//!   up to the memory-capacity slot limit.
//! * Coalesced: chunked prefill — one token-budgeted chunk of the head
//!   prompt per iteration, co-scheduled with the resident decode batch.

use std::collections::VecDeque;

use crate::config::BatchConfig;
use crate::types::Request;

/// A formed prefill batch.
#[derive(Debug, Clone, Default)]
pub struct PrefillBatch {
    pub requests: Vec<Request>,
    pub total_tokens: u32,
}

/// Pop a FIFO prefill batch respecting the token and request budgets.
/// Always admits at least one request (a single over-budget prompt must
/// not deadlock the queue).
pub fn form_prefill_batch(queue: &mut VecDeque<Request>, cfg: &BatchConfig) -> PrefillBatch {
    let mut batch = PrefillBatch::default();
    while let Some(front) = queue.front() {
        let would_be = batch.total_tokens + front.input_tokens;
        let fits = batch.requests.is_empty()
            || (would_be <= cfg.max_prefill_tokens
                && batch.requests.len() < cfg.max_prefill_reqs);
        if !fits {
            break;
        }
        let r = queue.pop_front().unwrap();
        batch.total_tokens += r.input_tokens;
        batch.requests.push(r);
    }
    batch
}

/// Decode admission: how many pending requests may join given the current
/// resident count and the slot limit.
pub fn decode_admissions(resident: usize, pending: usize, cfg: &BatchConfig) -> usize {
    cfg.max_decode_reqs.saturating_sub(resident).min(pending)
}

/// Chunked-prefill scheduling state for one prompt on a coalesced GPU.
#[derive(Debug, Clone)]
pub struct ChunkProgress {
    pub request: Request,
    pub done_tokens: u32,
}

impl ChunkProgress {
    pub fn new(request: Request) -> Self {
        ChunkProgress {
            request,
            done_tokens: 0,
        }
    }

    pub fn remaining(&self) -> u32 {
        self.request.input_tokens - self.done_tokens
    }

    /// Advance by up to `budget` tokens; returns tokens consumed.
    pub fn advance(&mut self, budget: u32) -> u32 {
        let step = self.remaining().min(budget);
        self.done_tokens += step;
        step
    }

    pub fn complete(&self) -> bool {
        self.done_tokens >= self.request.input_tokens
    }
}

/// Take the next chunk across queued prompts (head-first, spilling into
/// later prompts if the head finishes inside the budget — Sarathi packs
/// chunks to the budget).
pub fn take_chunk(queue: &mut VecDeque<ChunkProgress>, budget: u32) -> (u32, Vec<Request>) {
    let mut used = 0u32;
    let mut finished = Vec::new();
    while used < budget {
        let Some(head) = queue.front_mut() else { break };
        used += head.advance(budget - used);
        if head.complete() {
            finished.push(queue.pop_front().unwrap().request);
        } else {
            break;
        }
    }
    (used, finished)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{RequestId, Slo};

    fn req(id: u64, tokens: u32) -> Request {
        Request {
            id: RequestId(id),
            arrival: 0,
            input_tokens: tokens,
            output_tokens: 16,
            slo: Slo::paper_default(),
        }
    }

    fn cfg() -> BatchConfig {
        BatchConfig {
            max_prefill_tokens: 4096,
            max_prefill_reqs: 4,
            max_decode_reqs: 8,
            ring_slots: 32,
        }
    }

    #[test]
    fn prefill_batch_respects_token_budget() {
        let mut q: VecDeque<Request> =
            vec![req(0, 2000), req(1, 1500), req(2, 1500)].into();
        let b = form_prefill_batch(&mut q, &cfg());
        assert_eq!(b.requests.len(), 2);
        assert_eq!(b.total_tokens, 3500);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn prefill_batch_respects_request_cap() {
        let mut q: VecDeque<Request> = (0..10).map(|i| req(i, 10)).collect();
        let b = form_prefill_batch(&mut q, &cfg());
        assert_eq!(b.requests.len(), 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn oversized_prompt_still_admitted_alone() {
        let mut q: VecDeque<Request> = vec![req(0, 9999), req(1, 100)].into();
        let b = form_prefill_batch(&mut q, &cfg());
        assert_eq!(b.requests.len(), 1);
        assert_eq!(b.total_tokens, 9999);
    }

    #[test]
    fn fifo_order_never_reordered() {
        let mut q: VecDeque<Request> = vec![req(5, 100), req(3, 100), req(9, 100)].into();
        let b = form_prefill_batch(&mut q, &cfg());
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id.0).collect();
        assert_eq!(ids, vec![5, 3, 9]);
    }

    #[test]
    fn empty_queue_empty_batch() {
        let mut q = VecDeque::new();
        let b = form_prefill_batch(&mut q, &cfg());
        assert!(b.requests.is_empty());
        assert_eq!(b.total_tokens, 0);
    }

    #[test]
    fn decode_admissions_respect_capacity() {
        let c = cfg();
        assert_eq!(decode_admissions(0, 100, &c), 8);
        assert_eq!(decode_admissions(6, 100, &c), 2);
        assert_eq!(decode_admissions(8, 100, &c), 0);
        assert_eq!(decode_admissions(2, 1, &c), 1);
    }

    #[test]
    fn chunk_progress_advances_and_completes() {
        let mut p = ChunkProgress::new(req(0, 5000));
        assert_eq!(p.advance(2048), 2048);
        assert_eq!(p.advance(2048), 2048);
        assert!(!p.complete());
        assert_eq!(p.advance(2048), 904);
        assert!(p.complete());
    }

    #[test]
    fn take_chunk_packs_across_prompts() {
        let mut q: VecDeque<ChunkProgress> =
            vec![ChunkProgress::new(req(0, 1000)), ChunkProgress::new(req(1, 5000))].into();
        let (used, finished) = take_chunk(&mut q, 2048);
        assert_eq!(used, 2048);
        assert_eq!(finished.len(), 1);
        assert_eq!(finished[0].id.0, 0);
        // Head of queue is now request 1 with 1048 tokens done.
        assert_eq!(q.front().unwrap().done_tokens, 1048);
    }

    #[test]
    fn take_chunk_empty_queue() {
        let mut q = VecDeque::new();
        let (used, finished) = take_chunk(&mut q, 2048);
        assert_eq!(used, 0);
        assert!(finished.is_empty());
    }
}
