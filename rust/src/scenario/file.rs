//! Scenario files: declare a whole study as TOML (`scenarios/*.toml`).
//!
//! ```toml
//! name = "rate-budget-grid"
//! seed = 42
//! requests = 400
//! rate_per_gpu = 1.5          # used when no rate axis is declared
//!
//! [workload]
//! kind = "longbench"          # longbench | mixed | sonnet
//! # input_tokens = 3000       # sonnet only
//! # output_tokens = 96        # sonnet only
//! # burst_frac = 0.2          # dwell fraction for burst_factor axes
//!
//! [slo]
//! ttft_ms = 1000
//! tpot_ms = 40
//!
//! [base]
//! preset = "4p4d-600"
//!
//! [axes]
//! power_w = [500, 600, 750]
//! rate_per_gpu = [0.5, 1.0, 1.5, 2.0]
//! # preset = ["4p4d-600", "rapid-600"]      -> config axis
//! # policy = ["static", "rapid"]
//! # n_nodes = [1, 2]
//! # prefill_gpus = [2, 4, 6]
//! # burst_factor = [1.0, 4.0]
//! # slo_scale = [2.0, 1.0, 0.5]
//! ```
//!
//! TOML tables are unordered, so axes expand in a fixed canonical
//! order regardless of file order (outermost → innermost): `seed`,
//! `preset`, `sku_mix`, `policy`, `env`, `mem`, `trace`, `tenants`,
//! `n_nodes`, `prefill_gpus`, `power_w`, `batch`, `burst_factor`,
//! `slo_scale`, `rate_per_gpu`. The last declared axis
//! becomes the column axis of the text tables. Unknown keys anywhere in
//! the file are rejected with an error naming the key and its table.
//!
//! Multi-tenant studies add three optional tables: `[workload.trace]`
//! (a trace-replay preset plus an optional flash-crowd window),
//! `[tenant.<name>]` classes (share / tier / slo_scale) and
//! `[admission]` (shedding policy), all applied to every cell's base
//! config.

use super::{Axis, Scenario, ScenarioError, WorkloadSpec};
use crate::config::toml::{Document, Value};
use crate::config::{presets, ControlPolicy};
use crate::types::{Slo, MILLIS};
use crate::workload::tracespec::{FlashCrowd, TraceSpec};

/// Canonical axis expansion order for TOML-declared scenarios.
const AXIS_ORDER: &[&str] = &[
    "seed",
    "preset",
    "sku_mix",
    "policy",
    "env",
    "mem",
    "trace",
    "tenants",
    "n_nodes",
    "prefill_gpus",
    "power_w",
    "batch",
    "burst_factor",
    "slo_scale",
    "rate_per_gpu",
];

/// Keys a scenario file accepts, by table (`""` = top level).
const KNOWN_TABLES: &[(&str, &[&str])] = &[
    ("", &["name", "seed", "requests", "rate_per_gpu"]),
    ("workload", &["kind", "input_tokens", "output_tokens", "burst_frac", "turns", "reuse_frac"]),
    ("workload.trace", &["preset", "flash_start_s", "flash_dur_s", "flash_mult"]),
    ("slo", &["ttft_ms", "tpot_ms"]),
    ("base", &["preset"]),
    ("sim", &["sample_period_ms"]),
    ("admission", &["mode", "queue_depth", "bucket_rps", "bucket_burst"]),
    ("axes", AXIS_ORDER),
];

/// Reject any key the scenario loader would silently ignore, naming the
/// key and its table (and the keys that table does accept).
fn check_unknown_keys(doc: &Document) -> Result<(), ScenarioError> {
    doc.check_known_keys(KNOWN_TABLES, &[("tenant", crate::config::schema::TENANT_KEYS)])
        .map_err(ScenarioError)
}

impl Scenario {
    /// Parse a scenario from TOML text.
    pub fn from_toml(text: &str) -> Result<Scenario, ScenarioError> {
        let doc = Document::parse(text).map_err(|e| ScenarioError(e.to_string()))?;
        check_unknown_keys(&doc)?;
        let base = match doc.get_str("base.preset") {
            Some(name) => presets::by_name(name).map_err(|e| ScenarioError(e.to_string()))?,
            None => presets::p4d4(600.0),
        };
        let mut s = Scenario::new(doc.get_str("name").unwrap_or("study"), base);
        if let Some(seed) = doc.get_i64("seed") {
            s.seed = seed as u64;
        }
        if let Some(n) = doc.get_i64("requests") {
            if n <= 0 {
                return Err(ScenarioError(format!("requests {n} must be > 0")));
            }
            s.requests = n as usize;
        }
        if let Some(r) = doc.get_f64("rate_per_gpu") {
            s.rate_per_gpu = r;
        }
        if let Some(ms) = doc.get_f64("sim.sample_period_ms") {
            s.sample_period = Some((ms * MILLIS as f64) as crate::types::Micros);
        }
        s.workload = parse_workload(&doc)?;
        if let Some(f) = doc.get_f64("workload.burst_frac") {
            s.burst_frac = f;
        }
        s.trace = parse_trace_table(&doc)?;
        s.base.tenants =
            crate::config::schema::parse_tenant_tables(&doc).map_err(|e| ScenarioError(e.to_string()))?;
        if let Some(adm) = crate::cluster::admission::AdmissionConfig::from_doc(&doc)
            .map_err(ScenarioError)?
        {
            s.base.admission = adm;
        }
        // Multi-turn transform: both keys or neither (`Scenario::validate`
        // checks the value ranges).
        match (doc.get_i64("workload.turns"), doc.get_f64("workload.reuse_frac")) {
            (Some(turns), Some(reuse)) => {
                if turns < 2 {
                    return Err(ScenarioError(format!("workload.turns {turns} must be >= 2")));
                }
                s.multiturn = Some((turns as u32, reuse));
            }
            (None, None) => {}
            _ => {
                return Err(ScenarioError(
                    "workload.turns and workload.reuse_frac must be set together".into(),
                ));
            }
        }
        let mut slo = Slo::paper_default();
        if let Some(ms) = doc.get_f64("slo.ttft_ms") {
            slo.ttft = (ms * MILLIS as f64) as crate::types::Micros;
        }
        if let Some(ms) = doc.get_f64("slo.tpot_ms") {
            slo.tpot = (ms * MILLIS as f64) as crate::types::Micros;
        }
        s.slo = slo;
        for &name in AXIS_ORDER {
            if let Some(values) = doc.get_array(&format!("axes.{name}")) {
                s.axes.push(parse_axis(name, values)?);
            } else if doc.get(&format!("axes.{name}")).is_some() {
                return Err(ScenarioError(format!("axis '{name}' must be an array")));
            }
        }
        s.validate()?;
        Ok(s)
    }

    /// Load a scenario from a TOML file on disk.
    pub fn from_toml_file(path: &str) -> Result<Scenario, ScenarioError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ScenarioError(format!("{path}: {e}")))?;
        Scenario::from_toml(&text).map_err(|e| ScenarioError(format!("{path}: {}", e.0)))
    }
}

/// Parse the optional `[workload.trace]` table: a preset name plus an
/// optional flash-crowd window (the three `flash_*` keys are
/// all-or-none).
fn parse_trace_table(doc: &Document) -> Result<Option<TraceSpec>, ScenarioError> {
    if !doc.entries.keys().any(|k| k.starts_with("workload.trace.")) {
        return Ok(None);
    }
    let preset = doc
        .get_str("workload.trace.preset")
        .ok_or_else(|| ScenarioError("[workload.trace] needs a preset key".into()))?;
    let spec = TraceSpec::preset(preset).map_err(ScenarioError)?;
    let flash = (
        doc.get_f64("workload.trace.flash_start_s"),
        doc.get_f64("workload.trace.flash_dur_s"),
        doc.get_f64("workload.trace.flash_mult"),
    );
    let spec = match flash {
        (None, None, None) => spec,
        (Some(start_s), Some(dur_s), Some(mult)) => spec
            .with_flash(FlashCrowd { start_s, dur_s, mult })
            .map_err(ScenarioError)?,
        _ => {
            return Err(ScenarioError(
                "flash_start_s, flash_dur_s and flash_mult must be set together".into(),
            ))
        }
    };
    Ok(Some(spec))
}

fn parse_workload(doc: &Document) -> Result<WorkloadSpec, ScenarioError> {
    match doc.get_str("workload.kind").unwrap_or("longbench") {
        "longbench" => Ok(WorkloadSpec::LongBench),
        "mixed" => Ok(WorkloadSpec::MixedPhases),
        "sonnet" => {
            let input = doc
                .get_i64("workload.input_tokens")
                .ok_or_else(|| ScenarioError("sonnet workload needs input_tokens".into()))?;
            let output = doc
                .get_i64("workload.output_tokens")
                .ok_or_else(|| ScenarioError("sonnet workload needs output_tokens".into()))?;
            Ok(WorkloadSpec::Sonnet {
                input_tokens: input as u32,
                output_tokens: output as u32,
            })
        }
        other => Err(ScenarioError(format!(
            "unknown workload kind '{other}' (longbench | mixed | sonnet)"
        ))),
    }
}

fn floats(name: &str, values: &[Value]) -> Result<Vec<f64>, ScenarioError> {
    values
        .iter()
        .map(|v| {
            v.as_f64()
                .ok_or_else(|| ScenarioError(format!("axis '{name}' needs numeric values")))
        })
        .collect()
}

fn ints(name: &str, values: &[Value]) -> Result<Vec<usize>, ScenarioError> {
    values
        .iter()
        .map(|v| {
            v.as_i64()
                .filter(|&i| i > 0)
                .map(|i| i as usize)
                .ok_or_else(|| ScenarioError(format!("axis '{name}' needs positive integers")))
        })
        .collect()
}

/// Validate one TOML file as *either* a cluster config or a scenario —
/// the `rapid validate` subcommand and CI's fail-fast TOML gate. Both
/// loaders already do strict unknown-key checking, so a file that
/// parses as neither reports both errors.
pub fn validate_path(path: &str) -> Result<&'static str, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    validate_toml(&text)
}

/// [`validate_path`] over in-memory text. Returns which grammar the
/// file satisfied (`"config"` or `"scenario"`).
pub fn validate_toml(text: &str) -> Result<&'static str, String> {
    let config_err = match crate::config::ClusterConfig::from_toml(text) {
        Ok(_) => return Ok("config"),
        Err(e) => e,
    };
    match Scenario::from_toml(text) {
        Ok(_) => Ok("scenario"),
        Err(scenario_err) => Err(format!(
            "not a valid config ({config_err}); not a valid scenario ({scenario_err})"
        )),
    }
}

fn parse_axis(name: &str, values: &[Value]) -> Result<Axis, ScenarioError> {
    match name {
        "preset" => {
            let cfgs = values
                .iter()
                .map(|v| {
                    let p = v.as_str().ok_or_else(|| {
                        ScenarioError("axis 'preset' needs preset-name strings".into())
                    })?;
                    presets::by_name(p).map_err(|e| ScenarioError(e.to_string()))
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Axis::Config(cfgs))
        }
        "policy" => {
            let policies = values
                .iter()
                .map(|v| {
                    v.as_str()
                        .ok_or_else(|| ScenarioError("axis 'policy' needs strings".into()))?
                        .parse::<ControlPolicy>()
                        .map_err(ScenarioError)
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Axis::Policy(policies))
        }
        "seed" => {
            let seeds = values
                .iter()
                .map(|v| {
                    v.as_i64().filter(|&x| x >= 0).map(|x| x as u64).ok_or_else(|| {
                        ScenarioError("axis 'seed' needs non-negative integers".into())
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Axis::Seed(seeds))
        }
        "env" => {
            let profiles = values
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        ScenarioError(
                            "axis 'env' needs profile strings like \"curtail:30:0.5:0.75\"".into(),
                        )
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Axis::Env(profiles))
        }
        "mem" => {
            let cells = values
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        ScenarioError(
                            "axis 'mem' needs strings like \"hbm:16\" or \
                             \"multiturn:4:0.6\"".into(),
                        )
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Axis::Mem(cells))
        }
        "trace" => {
            let specs = values
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        ScenarioError(
                            "axis 'trace' needs strings like \"mt-4400x1200\" or \
                             \"synth-8192x256:flash:120:60:3\"".into(),
                        )
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Axis::Trace(specs))
        }
        "tenants" => {
            let mixes = values
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        ScenarioError(
                            "axis 'tenants' needs strings like \
                             \"chat:0.5:interactive+jobs:0.5:batch\"".into(),
                        )
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Axis::Tenants(mixes))
        }
        "sku_mix" => {
            let mixes = values
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        ScenarioError(
                            "axis 'sku_mix' needs mix strings like \"mi300x:4+a100:4\"".into(),
                        )
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Axis::SkuMix(mixes))
        }
        "n_nodes" => Ok(Axis::NNodes(ints(name, values)?)),
        "prefill_gpus" => Ok(Axis::PrefillGpus(ints(name, values)?)),
        "batch" => Ok(Axis::Batch(ints(name, values)?)),
        "power_w" => Ok(Axis::PowerW(floats(name, values)?)),
        "burst_factor" => Ok(Axis::BurstFactor(floats(name, values)?)),
        "slo_scale" => Ok(Axis::SloScale(floats(name, values)?)),
        "rate_per_gpu" => Ok(Axis::RatePerGpu(floats(name, values)?)),
        other => Err(ScenarioError(format!("unknown axis '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SECOND;

    #[test]
    fn full_scenario_round_trip() {
        let s = Scenario::from_toml(
            r#"
name = "grid"
seed = 7
requests = 200
rate_per_gpu = 1.25

[workload]
kind = "longbench"
burst_frac = 0.3

[slo]
ttft_ms = 500
tpot_ms = 25

[base]
preset = "rapid-600"

[axes]
power_w = [500, 600]
rate_per_gpu = [0.5, 1.0, 1.5]
"#,
        )
        .unwrap();
        assert_eq!(s.name, "grid");
        assert_eq!(s.seed, 7);
        assert_eq!(s.requests, 200);
        assert_eq!(s.burst_frac, 0.3);
        assert_eq!(s.slo.ttft, SECOND / 2);
        assert_eq!(s.base.name, "DynGPU-DynPower");
        assert_eq!(s.axes.len(), 2);
        assert_eq!(s.axes[0].key(), "power_w");
        assert_eq!(s.axes[1].key(), "rate_per_gpu");
        assert_eq!(s.n_cells(), 6);
    }

    #[test]
    fn defaults_when_sparse() {
        let s = Scenario::from_toml("name = \"tiny\"").unwrap();
        assert_eq!(s.seed, 42);
        assert_eq!(s.requests, 1200);
        assert_eq!(s.workload, WorkloadSpec::LongBench);
        assert_eq!(s.n_cells(), 1);
    }

    #[test]
    fn preset_and_policy_axes() {
        let s = Scenario::from_toml(
            r#"
[axes]
preset = ["4p4d-600", "5p3d-600"]
policy = ["static", "rapid"]
rate_per_gpu = [1.0]
"#,
        )
        .unwrap();
        assert_eq!(s.axes.len(), 3);
        assert_eq!(s.axes[0].key(), "config");
        assert_eq!(s.axes[1].key(), "policy");
        assert_eq!(s.n_cells(), 4);
    }

    #[test]
    fn sonnet_workload_requires_shape() {
        assert!(Scenario::from_toml("[workload]\nkind = \"sonnet\"").is_err());
        let s = Scenario::from_toml(
            "[workload]\nkind = \"sonnet\"\ninput_tokens = 3000\noutput_tokens = 96",
        )
        .unwrap();
        assert_eq!(
            s.workload,
            WorkloadSpec::Sonnet {
                input_tokens: 3000,
                output_tokens: 96
            }
        );
    }

    #[test]
    fn bad_inputs_rejected() {
        assert!(Scenario::from_toml("requests = -1").is_err());
        assert!(Scenario::from_toml("[axes]\nrate_per_gpu = [0.0]").is_err());
        assert!(Scenario::from_toml("[axes]\nwarp_speed = [9]").is_err());
        assert!(Scenario::from_toml("[axes]\nrate_per_gpu = 2").is_err());
        assert!(Scenario::from_toml("[axes]\npolicy = [\"yolo\"]").is_err());
        assert!(Scenario::from_toml("[axes]\npreset = [\"nope\"]").is_err());
        assert!(Scenario::from_toml("[workload]\nkind = \"tweets\"").is_err());
        assert!(Scenario::from_toml("[base]\npreset = \"nope\"").is_err());
        // mixed + burst_factor is a structural conflict
        assert!(Scenario::from_toml(
            "[workload]\nkind = \"mixed\"\n[axes]\nburst_factor = [2.0]"
        )
        .is_err());
    }

    #[test]
    fn unknown_keys_rejected_with_table_named() {
        let err = Scenario::from_toml("[slo]\nttft_msx = 500").unwrap_err();
        assert!(err.0.contains("ttft_msx") && err.0.contains("[slo]"), "{}", err.0);
        assert!(err.0.contains("ttft_ms"), "lists valid keys: {}", err.0);
        let err = Scenario::from_toml("reqests = 100").unwrap_err();
        assert!(err.0.contains("reqests"), "{}", err.0);
        let err = Scenario::from_toml("[workloads]\nkind = \"longbench\"").unwrap_err();
        assert!(err.0.contains("workloads.kind"), "{}", err.0);
    }

    #[test]
    fn seed_and_env_axes_parse_in_canonical_order() {
        let s = Scenario::from_toml(
            r#"
[base]
preset = "rapid-600"
[axes]
rate_per_gpu = [1.0]
env = ["none", "curtail:30:0.5:0.75:10"]
seed = [1, 2, 3]
"#,
        )
        .unwrap();
        // seed outermost, then env, rate innermost — file order ignored.
        assert_eq!(s.axes[0].key(), "seed");
        assert_eq!(s.axes[1].key(), "env");
        assert_eq!(s.axes[2].key(), "rate_per_gpu");
        assert_eq!(s.n_cells(), 6);
        assert_eq!(s.axes[0].label(2), "3");
        assert_eq!(s.axes[1].label(1), "curtail:30:0.5:0.75:10");
        // Bad values fail at load time.
        assert!(Scenario::from_toml("[axes]\nseed = [-1]").is_err());
        assert!(Scenario::from_toml("[axes]\nseed = [\"a\"]").is_err());
        assert!(Scenario::from_toml("[axes]\nenv = [9]").is_err());
        assert!(Scenario::from_toml("[axes]\nenv = [\"warp:9\"]").is_err());
    }

    #[test]
    fn validate_toml_distinguishes_configs_and_scenarios() {
        assert_eq!(validate_toml("preset = \"rapid-600\"").unwrap(), "config");
        assert_eq!(
            validate_toml("requests = 100\n[axes]\nrate_per_gpu = [1.0]").unwrap(),
            "scenario"
        );
        let err = validate_toml("[powr]\nbudget_w = 1").unwrap_err();
        assert!(
            err.contains("not a valid config") && err.contains("not a valid scenario"),
            "{err}"
        );
    }

    #[test]
    fn mem_axis_and_multiturn_workload_parse() {
        let s = Scenario::from_toml(
            r#"
[base]
preset = "rapid-600"
[workload]
kind = "longbench"
turns = 4
reuse_frac = 0.6
[axes]
mem = ["none", "hbm:16", "hbm:64"]
rate_per_gpu = [1.0]
"#,
        )
        .unwrap();
        assert_eq!(s.multiturn, Some((4, 0.6)));
        // mem expands after env, before n_nodes; rate innermost.
        assert_eq!(s.axes[0].key(), "mem");
        assert_eq!(s.axes[0].label(1), "hbm:16");
        assert_eq!(s.axes[1].key(), "rate_per_gpu");
        assert_eq!(s.n_cells(), 3);
        // Bad values fail at load time.
        assert!(Scenario::from_toml("[axes]\nmem = [9]").is_err());
        assert!(Scenario::from_toml("[axes]\nmem = [\"hbm:0\"]").is_err());
        assert!(Scenario::from_toml("[axes]\nmem = [\"warp:9\"]").is_err());
        // turns/reuse_frac must be set together and in range.
        assert!(Scenario::from_toml("[workload]\nturns = 4").is_err());
        assert!(Scenario::from_toml("[workload]\nreuse_frac = 0.5").is_err());
        assert!(Scenario::from_toml("[workload]\nturns = 1\nreuse_frac = 0.5").is_err());
        assert!(Scenario::from_toml("[workload]\nturns = 4\nreuse_frac = 1.5").is_err());
    }

    #[test]
    fn trace_table_and_tenant_tables_parse() {
        let s = Scenario::from_toml(
            r#"
name = "flash"
[base]
preset = "rapid-600"
[workload.trace]
preset = "mt-4400x1200"
flash_start_s = 120
flash_dur_s = 60
flash_mult = 3.0
[tenant.chat]
share = 0.5
tier = "interactive"
[tenant.jobs]
share = 0.5
tier = "batch"
slo_scale = 4.0
[admission]
mode = "queue-depth"
queue_depth = 32
[axes]
policy = ["static", "rapid"]
"#,
        )
        .unwrap();
        let ts = s.trace.as_ref().unwrap();
        assert_eq!(ts.preset, "mt-4400x1200");
        assert!(ts.flash.is_some());
        assert_eq!(s.base.tenants.len(), 2);
        assert_eq!(s.base.tenants[0].name, "chat");
        assert_eq!(s.base.tenants[1].slo_scale, 4.0);
        assert_eq!(
            s.base.admission.mode,
            crate::cluster::admission::AdmissionMode::QueueDepth
        );
        // Flash keys are all-or-none; the preset key is required; bad
        // tenant keys and shares are named back.
        assert!(Scenario::from_toml(
            "[workload.trace]\npreset = \"mt-4400x1200\"\nflash_start_s = 120"
        )
        .is_err());
        assert!(Scenario::from_toml("[workload.trace]\nflash_mult = 3.0").is_err());
        assert!(Scenario::from_toml("[workload.trace]\npreset = \"warp\"").is_err());
        assert!(Scenario::from_toml("[tenant.chat]\nshare = 0.4").is_err());
        assert!(Scenario::from_toml("[tenant.chat]\nshare = 1.0\nsharee = 2").is_err());
    }

    #[test]
    fn trace_and_tenants_axes_parse_in_canonical_order() {
        let s = Scenario::from_toml(
            r#"
[base]
preset = "rapid-600"
[axes]
rate_per_gpu = [1.0]
tenants = ["none", "chat:0.5:interactive+jobs:0.5:batch"]
trace = ["none", "synth-8192x256"]
"#,
        )
        .unwrap();
        // trace before tenants, rate innermost — file order ignored.
        assert_eq!(s.axes[0].key(), "trace");
        assert_eq!(s.axes[1].key(), "tenants");
        assert_eq!(s.axes[2].key(), "rate_per_gpu");
        assert_eq!(s.n_cells(), 4);
        assert_eq!(s.axes[0].label(1), "synth-8192x256");
        // Bad values fail at load time.
        assert!(Scenario::from_toml("[axes]\ntrace = [9]").is_err());
        assert!(Scenario::from_toml("[axes]\ntrace = [\"warp\"]").is_err());
        assert!(Scenario::from_toml("[axes]\ntenants = [\"chat:0.4:interactive\"]").is_err());
        // trace x burst_factor is a structural conflict.
        assert!(Scenario::from_toml(
            "[workload.trace]\npreset = \"mt-4400x1200\"\n[axes]\nburst_factor = [4.0]"
        )
        .is_err());
    }

    #[test]
    fn sku_mix_axis_parses() {
        let s = Scenario::from_toml(
            r#"
[base]
preset = "rapid-600"
[axes]
sku_mix = ["mi300x:8", "mi300x:4+a100:4"]
rate_per_gpu = [1.0]
"#,
        )
        .unwrap();
        assert_eq!(s.axes.len(), 2);
        assert_eq!(s.axes[0].key(), "sku_mix");
        assert_eq!(s.axes[0].label(1), "mi300x:4+a100:4");
        assert_eq!(s.n_cells(), 2);
        // Bad mixes fail at load time.
        assert!(Scenario::from_toml("[axes]\nsku_mix = [\"warp9:8\"]").is_err());
        assert!(Scenario::from_toml("[axes]\nsku_mix = [9]").is_err());
    }
}
